//! Three-tier city: 2,000 phones → 3 metro edge sites → the core cloud.
//!
//! The planner solves the 2-D `(l1, l2)` genome per quantised device
//! state: head layers stay on the phone, torso layers contend at the
//! assigned edge site's M/G/c queue, tail layers (if any) cross the
//! wired backhaul into the cloud. Run for a deep conv net (VGG16 — the
//! ResNet-class heavyweight of this zoo) and a mobile-first net
//! (MobileNetV2), printing per-tier utilisation and the `(l1, l2)`
//! split-plan heat table.
//!
//!     cargo run --release --example edge_tiered
//!
//! The run is deterministic: same seed, same report, every time.

use std::collections::BTreeMap;

use smartsplit::sim;

fn heat_table(dist: &[(smartsplit::edge::SplitPlan, u64)]) {
    // Rows: l1 (head depth). Columns: observed l2 values (torso end).
    let mut l2s: Vec<usize> = dist.iter().map(|(p, _)| p.l2).collect();
    l2s.sort_unstable();
    l2s.dedup();
    let mut rows: BTreeMap<usize, BTreeMap<usize, u64>> = BTreeMap::new();
    for (p, n) in dist {
        *rows.entry(p.l1).or_default().entry(p.l2).or_insert(0) += n;
    }
    print!("    l1\\l2 |");
    for l2 in &l2s {
        print!(" {l2:>5}");
    }
    println!();
    print!("    ------+");
    for _ in &l2s {
        print!("------");
    }
    println!();
    for (l1, cols) in rows {
        print!("    {l1:>5} |");
        for l2 in &l2s {
            match cols.get(l2) {
                Some(n) => print!(" {n:>5}"),
                None => print!("     ·"),
            }
        }
        println!();
    }
}

fn main() -> anyhow::Result<()> {
    let devices = 2_000;
    let sites = 3;
    let duration_s = 300.0;

    for model in ["vgg16", "mobilenet_v2"] {
        let cfg = sim::city_scale_tiered(model, devices, sites, duration_s, 7);
        let spec = cfg.edge.as_ref().unwrap();
        println!(
            "== {model}: {devices} devices → {sites} edge sites × {} servers \
             ({} Mbps backhaul) → {} clouds × {} servers ==",
            spec.servers_per_site,
            spec.backhaul.bandwidth_mbps,
            cfg.clouds,
            cfg.cloud_servers
        );
        let report = sim::run(&cfg)?;
        report.print();

        println!();
        println!("-- per-tier view --");
        let edge_served: u64 = report.edges.iter().map(|e| e.served).sum();
        let cloud_served: u64 = report.clouds.iter().map(|c| c.served).sum();
        for (i, e) in report.edges.iter().enumerate() {
            println!(
                "edge site {i}  : util {:>5.1}%  served {:>7}  peak queue {:>4}",
                e.utilization * 100.0,
                e.served,
                e.peak_queue
            );
        }
        println!(
            "edge tier    : torso-q p95 {:.2} ms (merged across sites)",
            report.edge_queue_delay.p95() * 1e3
        );
        let cloud_util = report.clouds.iter().map(|c| c.utilization).sum::<f64>()
            / report.clouds.len().max(1) as f64;
        println!(
            "cloud tier   : util {:>5.1}%  served {:>7}  tail-q p95 {:.2} ms",
            cloud_util * 100.0,
            cloud_served,
            report.queue_delay.p95() * 1e3
        );
        println!(
            "torso share  : {edge_served} of {} completed requests crossed the edge tier",
            report.completed
        );
        println!();
        println!("-- (l1, l2) split-plan heat table (active devices) --");
        heat_table(&report.split_distribution);
        println!();
        assert!(report.completed > 0, "a tiered city that serves nothing is a ghost town");
    }
    Ok(())
}
