//! Quickstart: plan a split for AlexNet on a Samsung J6 over 10 Mbps WiFi
//! using the full SmartSplit pipeline (NSGA-II → Pareto set → TOPSIS) and
//! inspect the trade-off surface. Pure analytical path — no artifacts
//! needed.
//!
//!     cargo run --release --example quickstart

use smartsplit::coordinator::{optimize_report, Config};
use smartsplit::device::profiles;
use smartsplit::figures::perf_model;
use smartsplit::models::zoo;
use smartsplit::optimizer::{smartsplit, Nsga2Params};

fn main() -> anyhow::Result<()> {
    // 1. High-level report: Pareto set + decisions of all six algorithms.
    let cfg = Config::default();
    print!("{}", optimize_report(&cfg)?);

    // 2. The same decision through the library API.
    let spec = zoo::alexnet();
    let profile = spec.analyze(1);
    let pm = perf_model(&profile, profiles::samsung_j6(), 10.0);
    let result = smartsplit(&pm, &Nsga2Params::default());
    let l1 = result.decision.l1;
    println!("\nchosen split: layers 1..={l1} on the phone, {}..={} on the cloud",
             l1 + 1, profile.num_layers);
    println!("  end-to-end latency (Eq. 14): {:.3} s", pm.f1(l1));
    println!("  smartphone energy  (Eq. 15): {:.3} J", pm.f2(l1));
    println!("  smartphone memory  (Eq. 16): {}",
             smartsplit::util::fmt_bytes(pm.f3(l1) as u64));
    println!("  intermediate upload I|l1   : {}",
             smartsplit::util::fmt_bytes(profile.intermediate_bytes(l1)));

    // 3. How the decision reacts to network conditions.
    println!("\nsplit vs bandwidth:");
    for bw in [0.5, 2.0, 10.0, 50.0, 200.0] {
        let pm = perf_model(&profile, profiles::samsung_j6(), bw);
        let d = smartsplit(&pm, &Nsga2Params::default()).decision;
        println!("  {bw:>6.1} Mbps → l1 = {:<2} (latency {:.3} s, energy {:.3} J)",
                 d.l1, pm.f1(d.l1), pm.f2(d.l1));
    }
    Ok(())
}
