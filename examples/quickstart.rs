//! Quickstart: plan a split for AlexNet on a Samsung J6 over 10 Mbps WiFi
//! using the full SmartSplit pipeline (NSGA-II → Pareto set → TOPSIS) and
//! inspect the trade-off surface. Pure analytical path — no artifacts
//! needed.
//!
//!     cargo run --release --example quickstart

use std::sync::Arc;

use smartsplit::coordinator::battery::BatteryBand;
use smartsplit::coordinator::{optimize_report, Config};
use smartsplit::device::profiles;
use smartsplit::models::zoo;
use smartsplit::optimizer::Nsga2Params;
use smartsplit::planner::{PlanRequest, Planner, PlannerConfig, Strategy};

fn main() -> anyhow::Result<()> {
    // 1. High-level report: Pareto set + every strategy's decision.
    let cfg = Config::default();
    print!("{}", optimize_report(&cfg)?);

    // 2. The same decision through the planning façade — the one
    //    supported API for every splitting decision.
    let profile = Arc::new(zoo::alexnet().analyze(1));
    let planner = Planner::new(PlannerConfig::paper(Nsga2Params::default()));
    let req = PlanRequest::two_tier(
        Arc::clone(&profile),
        profiles::samsung_j6(),
        BatteryBand::Comfort,
        10.0,
        Strategy::SmartSplit,
    );
    let outcome = planner.plan(&req);
    let plan = outcome.plan.expect("feasible split");
    let o = outcome.objectives.expect("objectives");
    println!("\nchosen split: layers 1..={} on the phone, {}..={} on the cloud",
             plan.l1, plan.l1 + 1, profile.num_layers);
    println!("  end-to-end latency (Eq. 14): {:.3} s", o[0]);
    println!("  smartphone energy  (Eq. 15): {:.3} J", o[1]);
    println!("  smartphone memory  (Eq. 16): {}",
             smartsplit::util::fmt_bytes(o[2] as u64));
    println!("  intermediate upload I|l1   : {}",
             smartsplit::util::fmt_bytes(profile.intermediate_bytes(plan.l1)));
    println!("  provenance: {:?} via {:?}, seed {:#x}, {} GA evaluations",
             outcome.provenance.strategy, outcome.provenance.cache,
             outcome.provenance.derived_seed, outcome.provenance.evaluations);

    // 3. How the decision reacts to network conditions.
    println!("\nsplit vs bandwidth:");
    for bw in [0.5, 2.0, 10.0, 50.0, 200.0] {
        let mut req = req.clone();
        req.bandwidth_mbps = bw;
        let out = planner.plan(&req);
        let (plan, o) = (out.plan.expect("split"), out.objectives.expect("objectives"));
        println!("  {bw:>6.1} Mbps → l1 = {:<2} (latency {:.3} s, energy {:.3} J)",
                 plan.l1, o[0], o[1]);
    }
    Ok(())
}
