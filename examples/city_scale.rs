//! City-scale SmartSplit, no artifacts and no sockets required: 10,000
//! heterogeneous virtual phones ride a compressed diurnal day against a
//! pool of virtual cloud servers, with device churn, per-device bandwidth
//! wobble, and batteries draining into the Saver/Critical bands — the
//! scale the paper's two-phone testbed (and real TCP loopback) cannot
//! reach, driven entirely by the §III analytical models.
//!
//!     cargo run --release --example city_scale
//!
//! The run is deterministic: same seed, same report, every time.

use smartsplit::sim;

fn main() -> anyhow::Result<()> {
    let devices = 10_000;
    let virtual_day_s = 600.0; // 24 h compressed into 10 virtual minutes
    let cfg = sim::city_scale("alexnet", devices, virtual_day_s, 7);

    println!(
        "== city scale: {} devices, {:.0}s virtual day, {} clouds × {} servers ==",
        devices, virtual_day_s, cfg.clouds, cfg.cloud_servers
    );
    let report = sim::run(&cfg)?;
    report.print();

    // The two headline effects only scale can show:
    println!();
    println!("-- what the 2-phone testbed cannot see --");
    println!(
        "cloud queueing  : p95 {:.1} ms across {} clouds (Eq. 5 has no such term)",
        report.queue_delay.quantile(0.95) * 1e3,
        report.clouds.len()
    );
    println!(
        "fleet adaptation: {} re-splits from bandwidth wobble + battery bands, \
         {} batteries died, {} devices churned out",
        report.resplits, report.batteries_exhausted, report.left
    );
    println!(
        "planner cache   : {} optimiser solves served {} split decisions \
         ({:.1}% hit rate over {} sweeps)",
        report.planner.solves,
        report.decision_count,
        report.planner.hit_rate() * 100.0,
        report.reopt_sweeps
    );
    assert!(report.completed > 0, "a city that serves nothing is a ghost town");
    Ok(())
}
