//! A city on the move: the tiered metro (phones → edge sites → cloud)
//! with every device running a deterministic waypoint walk between the
//! sites' cells.
//!
//! Each cell crossing is an edge handover: the in-flight torso state is
//! relayed over the *old* site's backhaul (plus a fixed control-plane
//! cost), the device re-attaches, and its `(l1, l2)` split is re-planned
//! through the planner façade with the new tier context — a *migration*
//! re-solve, accounted separately from battery/drift re-splits. The run
//! is compared against the identical city frozen static, so the printout
//! is the mobility tax in one screen.
//!
//!     cargo run --release --example edge_mobile
//!
//! The run is deterministic: same seed, same report, every time.

use smartsplit::sim::{self, Mobility};

fn main() -> anyhow::Result<()> {
    let devices = 2_000;
    let sites = 4;
    let duration_s = 300.0;

    let mobile_cfg = sim::city_mobile("alexnet", devices, sites, duration_s, 7);
    let mut static_cfg = mobile_cfg.clone();
    static_cfg.mobility = Mobility::Static;

    println!(
        "== alexnet: {devices} devices walking over {sites} edge sites for {duration_s:.0}s \
         virtual (vs the same city frozen static) =="
    );
    let mobile = sim::run(&mobile_cfg)?;
    let frozen = sim::run(&static_cfg)?;
    mobile.print();

    println!();
    println!("-- mobility view --");
    println!(
        "handovers    : {} completed ({:.2} per device), {} migration re-plans",
        mobile.handovers,
        mobile.handovers as f64 / mobile.devices_created.max(1) as f64,
        mobile.migration_replans,
    );
    let reqs: u64 = mobile.planner.requests_by_reason.iter().sum();
    println!(
        "planner asks : {:?} by reason [spawn, drift, band, migration] — \
         {:.1}% migration-driven, cache hit rate {:.1}%",
        mobile.planner.requests_by_reason,
        100.0 * mobile.planner.migration_requests() as f64 / reqs.max(1) as f64,
        mobile.planner.hit_rate() * 100.0,
    );
    println!(
        "per-site load: mobile {:?} vs static {:?} (requests served per edge site)",
        mobile.edges.iter().map(|e| e.served).collect::<Vec<_>>(),
        frozen.edges.iter().map(|e| e.served).collect::<Vec<_>>(),
    );
    println!(
        "mobility tax : p50 {:.2} ms vs {:.2} ms static, p95 {:.2} ms vs {:.2} ms static",
        mobile.latency.p50() * 1e3,
        frozen.latency.p50() * 1e3,
        mobile.latency.p95() * 1e3,
        frozen.latency.p95() * 1e3,
    );
    // `resplits` counts plan *moves* from any trigger (band, drift,
    // migration); `migration_replans` counts adopted migration
    // re-solves whether or not the plan moved — related, not nested.
    println!(
        "plan moves   : {} mobile vs {} static ({} migration re-solves adopted)",
        mobile.resplits, frozen.resplits, mobile.migration_replans,
    );

    assert!(mobile.handovers > 0, "a mobile city where nobody moves is misconfigured");
    assert_eq!(frozen.handovers, 0, "the frozen city must not move");
    assert!(mobile.completed > 0 && frozen.completed > 0);
    Ok(())
}
