//! Heterogeneous fleet serving (paper future-work (iii)): a Samsung J6 on
//! a congested link and a Redmi Note 8 on a healthy one share one cloud
//! daemon. Each phone gets its own SmartSplit decision; the dispatcher
//! routes requests by shortest expected delay.
//!
//!     make artifacts && cargo run --release --example fleet_serving

use smartsplit::coordinator::fleet::{Fleet, FleetConfig, FleetMember};
use smartsplit::device::profiles;
use smartsplit::optimizer::Nsga2Params;
use smartsplit::workload::{generate, Arrival};

fn main() -> anyhow::Result<()> {
    let cfg = FleetConfig {
        artifacts_dir: smartsplit::artifacts_dir(),
        model: "alexnet".into(),
        batch: 1,
        members: vec![
            FleetMember { profile: profiles::samsung_j6(), bandwidth_mbps: 8.0 },
            FleetMember { profile: profiles::redmi_note8(), bandwidth_mbps: 30.0 },
        ],
        strategy: smartsplit::planner::Strategy::SmartSplit,
        nsga2: Nsga2Params { pop_size: 60, generations: 60, ..Default::default() },
        emulate_slowdown: false,
    };
    println!("== heterogeneous fleet: J6 @ 8 Mbps + Redmi @ 30 Mbps ==");
    let fleet = Fleet::start(cfg)?;
    println!("per-device splits: {:?}", fleet.splits());

    let reqs = generate(24, Arrival::Poisson { rps: 6.0 }, 21);
    let report = fleet.serve(&reqs)?;
    report.print();
    assert_eq!(report.completed + report.errors, 24);
    fleet.shutdown();
    Ok(())
}
