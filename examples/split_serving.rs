//! End-to-end split serving (the EXPERIMENTS.md §E2E driver): a cloud
//! daemon and a device client in one process, real PJRT execution of the
//! AOT-compiled AlexNet on both sides, batched requests over a
//! token-bucket-shaped TCP link, energy/memory/latency accounting.
//!
//!     make artifacts && cargo run --release --example split_serving
//!
//! Flags: --requests N --model M --batch B --max-batch K --bandwidth-mbps B
//!        --planner S --no-slowdown

use std::time::Duration;

use smartsplit::coordinator::{Config, Deployment};
use smartsplit::device::profiles;
use smartsplit::optimizer::Nsga2Params;
use smartsplit::serve::RouterConfig;
use smartsplit::util::cli::Cli;
use smartsplit::workload::{generate, Arrival};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = Cli::new("split_serving — end-to-end split-serving driver")
        .opt("model", "alexnet", "model to serve")
        .opt("batch", "1", "hardware batch of the artifacts (1 or 8)")
        .opt("max-batch", "1", "router batching degree")
        .opt("requests", "24", "number of requests")
        .opt("rps", "0", "open-loop Poisson rate (0 = closed loop)")
        .opt("bandwidth-mbps", "10", "shaped link bandwidth")
        .planner_opt()
        .opt("device-profile", "samsung_j6", "phone profile")
        .flag("no-slowdown", "run device at host speed");
    let p = match cli.parse(&args) {
        Ok(p) => p,
        Err(u) => {
            println!("{u}");
            return Ok(());
        }
    };

    let cfg = Config {
        model: p.get("model").into(),
        batch: p.get_usize("batch"),
        bandwidth_mbps: p.get_f64("bandwidth-mbps"),
        strategy: p.planner().expect("strategy"),
        device_profile: profiles::by_name(p.get("device-profile")).expect("profile"),
        router: RouterConfig {
            max_batch: p.get_usize("max-batch"),
            max_wait: Duration::from_millis(100),
        },
        emulate_slowdown: !p.get_bool("no-slowdown"),
        nsga2: Nsga2Params::default(),
        ..Config::default()
    };
    let n = p.get_usize("requests");
    let arrival = match p.get_f64("rps") {
        r if r > 0.0 => Arrival::Poisson { rps: r },
        _ => Arrival::ClosedLoop,
    };

    println!(
        "== split serving: {} b{} on {} over {} Mbps, policy {} ==",
        cfg.model, cfg.batch, cfg.device_profile.name, cfg.bandwidth_mbps,
        cfg.strategy.name()
    );
    let t0 = std::time::Instant::now();
    let dep = Deployment::start(cfg.clone())?;
    println!(
        "deployment up in {:?}: split l1={} (device) / l2={} (cloud), cloud at {}",
        t0.elapsed(), dep.split.l1,
        dep.device.num_layers() - dep.split.l1, dep.cloud.addr
    );

    let reqs = generate(n, arrival, 42);
    let report = dep.serve(&reqs)?;
    report.print();
    println!(
        "battery used: {:.4}% of {} mAh",
        dep.device.energy.battery_fraction_used() * 100.0,
        dep.device.profile.battery_mah.unwrap_or(0.0)
    );
    dep.shutdown();
    Ok(())
}
