//! Pareto-surface explorer: sweep bandwidth × device and print how the
//! NSGA-II Pareto set and the TOPSIS compromise move. Useful for building
//! intuition about Eq. 14–16 — and a compact regression of the optimiser
//! stack. Analytical only; no artifacts needed.
//!
//!     cargo run --release --example pareto_explorer -- --model vgg16

use smartsplit::bench::Table;
use smartsplit::device::profiles;
use smartsplit::figures::{normalise_columns, pareto_and_choice, perf_model};
use smartsplit::models::zoo;
use smartsplit::optimizer::Nsga2Params;
use smartsplit::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = Cli::new("pareto_explorer").opt("model", "vgg16", "model to explore");
    let p = match cli.parse(&args) {
        Ok(p) => p,
        Err(u) => {
            println!("{u}");
            return Ok(());
        }
    };
    let model = p.get("model");
    let params = Nsga2Params::default();

    for phone in [profiles::samsung_j6(), profiles::redmi_note8()] {
        println!("\n== {model} on {} ==", phone.name);
        let mut t = Table::new(&["bandwidth", "Pareto set (l1)", "TOPSIS l1", "f1 (s)", "f2 (J)", "f3 (MB)"]);
        for bw in [0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 500.0] {
            let r = pareto_and_choice(model, phone, bw, &params)?;
            let profile = zoo::by_name(model).unwrap().analyze(1);
            let pm = perf_model(&profile, phone, bw);
            let set: Vec<usize> = r.pareto.iter().map(|(l1, _)| *l1).collect();
            let l1 = r.decision.l1;
            t.row(&[
                format!("{bw} Mbps"),
                format!("{set:?}"),
                l1.to_string(),
                format!("{:.3}", pm.f1(l1)),
                format!("{:.3}", pm.f2(l1)),
                format!("{:.1}", pm.f3(l1) / 1e6),
            ]);
        }
        t.print();
    }

    // Show one full normalised Pareto surface (Fig. 6 style).
    println!("\nnormalised Pareto surface at 10 Mbps on samsung_j6:");
    let r = pareto_and_choice(model, profiles::samsung_j6(), 10.0, &params)?;
    let raw: Vec<[f64; 3]> = r.pareto.iter().map(|(_, o)| *o).collect();
    let mut t = Table::new(&["l1", "norm f1", "norm f2", "norm f3", ""]);
    for ((l1, _), n) in r.pareto.iter().zip(normalise_columns(&raw)) {
        t.row(&[
            l1.to_string(),
            format!("{:.3}", n[0]),
            format!("{:.3}", n[1]),
            format!("{:.3}", n[2]),
            if *l1 == r.decision.l1 { "◀ TOPSIS".into() } else { String::new() },
        ]);
    }
    t.print();
    Ok(())
}
