//! A city that breaks: the 3-site tiered metro under the scripted
//! `city-faulty` schedule — one mid-run site outage (down at 25 % of
//! the horizon, back at 55 %), one backhaul brownout (35 %–65 % at a
//! quarter bandwidth), and one flash crowd pinned to the last site
//! (50 %–70 % at 4× arrivals).
//!
//! The outage storms every attached device through the epoch-guarded
//! reattach path onto the nearest live site and relays queued torso
//! work to the cloud — conservation holds, nothing is silently lost.
//! The run is compared against the identical city with the fault plan
//! cleared, so the printout is the failure tax in one screen.
//!
//!     cargo run --release --example edge_faulty
//!
//! The run is deterministic: same seed, same report, every time.

use smartsplit::sim::{self, FaultPlan};

fn main() -> anyhow::Result<()> {
    let devices = 2_000;
    let sites = 3;
    let duration_s = 300.0;

    let faulty_cfg = sim::city_faulty("alexnet", devices, sites, duration_s, 7);
    let mut calm_cfg = faulty_cfg.clone();
    calm_cfg.faults = FaultPlan::none();

    println!(
        "== alexnet: {devices} devices over {sites} edge sites for {duration_s:.0}s virtual, \
         {} scheduled fault(s) (vs the same city fault-free) ==",
        faulty_cfg.faults.events.len()
    );
    for e in &faulty_cfg.faults.events {
        println!("  t={:>5.0}s {}", e.at_s, e.kind.name());
    }
    let faulty = sim::run(&faulty_cfg)?;
    let calm = sim::run(&calm_cfg)?;
    faulty.print();

    println!();
    println!("-- failure view --");
    println!(
        "faults       : {} edges applied, {} forced reattaches, {} requests relayed to \
         the cloud off the dead site",
        faulty.fault_events, faulty.failover_reattaches, faulty.requests_rerouted,
    );
    let reqs: u64 = faulty.planner.requests_by_reason.iter().sum();
    println!(
        "planner asks : {:?} by reason [spawn, drift, band, migration, failover] — \
         {:.1}% failover-driven, {} failover re-solves adopted",
        faulty.planner.requests_by_reason,
        100.0 * faulty.planner.failover_requests() as f64 / reqs.max(1) as f64,
        faulty.failover_replans,
    );
    // Per-site utilisation: the dead site idles through its outage, its
    // neighbours absorb the storm, and the crowd site runs hot.
    println!(
        "per-site util: faulty {:?} vs calm {:?} (%)",
        faulty.edges.iter().map(|e| (e.utilization * 100.0).round()).collect::<Vec<_>>(),
        calm.edges.iter().map(|e| (e.utilization * 100.0).round()).collect::<Vec<_>>(),
    );
    println!(
        "per-site load: faulty {:?} vs calm {:?} (requests served per edge site)",
        faulty.edges.iter().map(|e| e.served).collect::<Vec<_>>(),
        calm.edges.iter().map(|e| e.served).collect::<Vec<_>>(),
    );
    println!(
        "failure tax  : p50 {:.2} ms vs {:.2} ms calm, p95 {:.2} ms vs {:.2} ms calm",
        faulty.latency.p50() * 1e3,
        calm.latency.p50() * 1e3,
        faulty.latency.p95() * 1e3,
        calm.latency.p95() * 1e3,
    );

    // Conservation is the headline guarantee: every request the faulty
    // city issued completed or dropped exactly once.
    assert_eq!(faulty.generated, faulty.completed + faulty.dropped, "requests leaked");
    assert!(faulty.fault_events > 0, "the schedule never fired");
    assert!(faulty.failover_reattaches > 0, "the outage stormed nobody");
    assert_eq!(calm.fault_events, 0, "the calm city must not fault");
    assert!(faulty.completed > 0 && calm.completed > 0);
    Ok(())
}
