//! Adaptive re-optimisation: serve a steady request stream while the WiFi
//! link degrades and recovers; the coordinator re-runs SmartSplit at each
//! bandwidth step and MOVES the split on the live deployment. This is the
//! scenario behind the paper's takeaway (i): "network bandwidth is a
//! crucial parameter to consider when splitting CNNs".
//!
//!     make artifacts && cargo run --release --example adaptive_bandwidth

use std::time::Duration;

use smartsplit::coordinator::{Config, Deployment};
use smartsplit::netsim::BandwidthTrace;
use smartsplit::optimizer::Nsga2Params;
use smartsplit::workload::{generate, Arrival};

fn main() -> anyhow::Result<()> {
    let cfg = Config {
        model: "alexnet".into(),
        bandwidth_mbps: 100.0,
        emulate_slowdown: false,
        nsga2: Nsga2Params { pop_size: 60, generations: 60, ..Default::default() },
        ..Config::default()
    };
    // Link: healthy 100 Mbps → congested 0.5 Mbps → recovers to 40 Mbps.
    let trace = BandwidthTrace {
        points: vec![
            (Duration::ZERO, 100.0),
            (Duration::from_secs(4), 0.5),
            (Duration::from_secs(8), 40.0),
        ],
    };

    println!("== adaptive split under a bandwidth trace ==");
    for (t, bw) in &trace.points {
        println!("  t={:>4.1}s  {:>6.1} Mbps", t.as_secs_f64(), bw);
    }
    let dep = Deployment::start(cfg)?;
    println!("initial split: l1={}", dep.split.l1);

    let reqs = generate(36, Arrival::Uniform { rps: 3.0 }, 9);
    let report = dep.serve_with_trace(&reqs, Some(&trace))?;
    report.print();
    println!("\nsplit trajectory (request id, l1): {:?}", report.split_history);
    assert!(report.split_history.len() > 1, "the split should have moved");
    dep.shutdown();
    Ok(())
}
