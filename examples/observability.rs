//! The observability layer (DESIGN.md §12) on a walking city: trace a
//! mobile tiered simulation, print one request's span timeline and the
//! causal events around a handover, show the windowed time series, and
//! export both machine-readable formats.
//!
//!     cargo run --release --example observability
//!
//! Everything printed here is deterministic — virtual-clock timestamps
//! only, so the same seed reproduces the same timeline byte-for-byte.

use smartsplit::sim;
use smartsplit::trace::CausalEvent;

fn main() -> anyhow::Result<()> {
    let devices = 1_000;
    let sites = 3;
    let duration_s = 180.0;

    let mut cfg = sim::city_mobile("alexnet", devices, sites, duration_s, 7);
    // Trace every request, cut the series into 15 s windows.
    cfg.observability = sim::ObservabilityConfig::full(15.0);

    println!(
        "== alexnet: {devices} devices / {sites} edge sites / {duration_s:.0}s virtual, \
         fully traced =="
    );
    let report = sim::run(&cfg)?;
    report.print();

    let trace = report.trace.as_ref().expect("tracing was enabled");
    let series = report.series.as_ref().expect("windowing was enabled");

    // -- one request, span by span ------------------------------------
    // Pick the traced request with the worst end-to-end latency: the
    // timeline shows exactly where that time went.
    let worst = trace
        .requests
        .iter()
        .max_by(|a, b| a.latency_s().partial_cmp(&b.latency_s()).unwrap())
        .expect("a traced run serves at least one request");
    println!("\n-- worst traced request: #{} on device {} --", worst.req, worst.device);
    for s in &worst.spans {
        let site = s.site.map(|i| format!(" @site {i}")).unwrap_or_default();
        println!(
            "  {:<12} [{:>9.4}s → {:>9.4}s] {:>8.3} ms{}",
            s.kind.name(),
            s.start_s,
            s.end_s,
            (s.end_s - s.start_s) * 1e3,
            site
        );
    }
    println!(
        "  spans tile the request exactly: {:.4}s issued → {:.4}s completed ({:.1} ms)",
        worst.issued_s,
        worst.completed_s,
        worst.latency_s() * 1e3
    );

    // -- causal events around the first handover ----------------------
    if let Some(relay_at) = trace.events.iter().find_map(|e| match e {
        CausalEvent::HandoverRelay { start_s, .. } => Some(*start_s),
        _ => None,
    }) {
        println!("\n-- causal events around the first handover ({relay_at:.2}s) --");
        for e in trace
            .events
            .iter()
            .filter(|e| (e.t_s() - relay_at).abs() < 5.0)
            .take(8)
        {
            match e {
                CausalEvent::HandoverRelay { start_s, end_s, device, from_site, to_site, state_bytes } => {
                    println!(
                        "  {start_s:>8.3}s relay    device {device}: site {from_site} → {to_site}, \
                         {state_bytes} B of torso state, {:.1} ms",
                        (end_s - start_s) * 1e3
                    );
                }
                CausalEvent::Reattach { t_s, device, site, replanned } => {
                    println!(
                        "  {t_s:>8.3}s reattach device {device} @site {site} (replanned: {replanned})"
                    );
                }
                CausalEvent::Replan { t_s, device, reason, cache, plan, .. } => {
                    println!(
                        "  {t_s:>8.3}s replan   device {device}: {reason:?}/{cache:?} → {plan:?}"
                    );
                }
                CausalEvent::Fault { t_s, kind, site, value } => {
                    println!("  {t_s:>8.3}s fault    {kind} @site {site} (value {value})");
                }
                CausalEvent::Failover { t_s, req, device, from_site } => {
                    println!(
                        "  {t_s:>8.3}s failover req {req} on device {device} rerouted off site {from_site}"
                    );
                }
            }
        }
    }

    // -- the windowed series ------------------------------------------
    println!();
    series.print_brief();
    let curve: Vec<String> =
        series.hit_rate_curve().iter().map(|h| format!("{:.2}", h)).collect();
    println!("planner hit rate per window: [{}]", curve.join(", "));

    // -- machine-readable exports -------------------------------------
    let dir = std::env::temp_dir();
    let jsonl = dir.join("smartsplit_observability.jsonl");
    let chrome = dir.join("smartsplit_observability.json");
    trace.export(&jsonl)?;
    trace.export(&chrome)?;
    println!(
        "\nexported {} traced requests + {} events:\n  JSONL        → {}\n  chrome trace → {} (open in chrome://tracing or Perfetto)",
        trace.requests.len(),
        trace.events.len(),
        jsonl.display(),
        chrome.display()
    );

    assert_eq!(trace.unfinished, 0, "every begun request must complete under drain");
    assert_eq!(trace.requests.len() as u64, report.completed);
    Ok(())
}
