//! The analysis layer (DESIGN.md §14) on the faulty city: run the
//! scripted outage scenario fully traced, attribute every request's
//! latency to its pipeline stages, audit two SLOs window by window,
//! charge each fault interval its impact, and diff the run against a
//! different seed to see what the diff classifier flags.
//!
//!     cargo run --release --example analyze_run
//!
//! Everything printed is deterministic: the same binary reproduces the
//! same report byte-for-byte, and analysing the serialized exports
//! offline reproduces the in-process analysis exactly — both are
//! asserted at the end.

use smartsplit::analyze::{diff_reports, AnalyzeReport, RunData, Slo};
use smartsplit::sim;

fn main() -> anyhow::Result<()> {
    let devices = 1_000;
    let sites = 3;
    let duration_s = 180.0;

    let mut cfg = sim::city_faulty("alexnet", devices, sites, duration_s, 7);
    cfg.observability = sim::ObservabilityConfig::full(15.0);

    println!(
        "== alexnet: {devices} devices / {sites} edge sites / {duration_s:.0}s virtual, \
         scripted faults, fully traced =="
    );
    let report = sim::run(&cfg)?;

    // -- the analysis, in-process --------------------------------------
    let slos: Vec<Slo> = ["p99<30s", "p50<0.2s", "drop<50%"]
        .iter()
        .map(|s| Slo::parse(s).expect("slo grammar"))
        .collect();
    let data = RunData::from_report(&report)?;
    let analysis = AnalyzeReport::build(&data, &slos);
    analysis.print();

    // Attribution is a partition, not an estimate: the nine stage
    // shares of every request re-fold to its end-to-end latency
    // bit-for-bit (`rust/tests/analyze.rs` pins this for the suite).
    for rec in &data.requests {
        assert_eq!(rec.share_sum().to_bits(), rec.latency_s().to_bits());
    }
    println!(
        "\nevery one of the {} stage decompositions re-folds to its latency exactly",
        data.requests.len()
    );

    // -- offline agreement ---------------------------------------------
    // The CLI path (`simulate --trace-out/--metrics-out` then
    // `analyze --trace/--metrics`) must land on the same report.
    let dir = std::env::temp_dir();
    let trace_path = dir.join("smartsplit_analyze_trace.jsonl");
    let metrics_path = dir.join("smartsplit_analyze_metrics.json");
    report.trace.as_ref().expect("tracing was on").export(&trace_path)?;
    std::fs::write(
        &metrics_path,
        report.metrics_json().expect("series was on").to_string_pretty(),
    )?;
    let offline = RunData::from_export_files(Some(&trace_path), Some(&metrics_path))?;
    let offline_report = AnalyzeReport::build(&offline, &slos);
    assert_eq!(
        analysis.to_json().to_string_pretty(),
        offline_report.to_json().to_string_pretty(),
        "offline analysis diverged from the in-process analysis"
    );
    println!(
        "offline re-analysis of {} + {} is byte-identical to the in-process report",
        trace_path.display(),
        metrics_path.display()
    );

    // -- run-vs-run diff ------------------------------------------------
    // Self-diff is exactly empty; a different seed shows the classifier
    // separating regressions from improvements from neutral drift.
    let selfdiff = diff_reports(&analysis.to_json(), &analysis.to_json());
    assert!(selfdiff.is_empty(), "a run diffed against itself must be empty");
    println!("\nself-diff: empty, as required");

    let mut other_cfg = sim::city_faulty("alexnet", devices, sites, duration_s, 8);
    other_cfg.observability = sim::ObservabilityConfig::full(15.0);
    let other = sim::run(&other_cfg)?;
    let other_report =
        AnalyzeReport::build(&RunData::from_report(&other)?, &slos);
    println!("\n-- seed 7 (baseline) vs seed 8 (candidate) --");
    let d = diff_reports(&analysis.to_json(), &other_report.to_json());
    println!(
        "{} changed leaves: {} regressions, {} improvements",
        d.changes.len(),
        d.regressions,
        d.improvements
    );
    for c in d.changes.iter().filter(|c| c.class != "neutral").take(8) {
        println!("  [{:<11}] {}: {} -> {}", c.class, c.path, c.baseline, c.candidate);
    }
    Ok(())
}
