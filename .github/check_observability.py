#!/usr/bin/env python3
"""Schema check for the observability exports (rust/DESIGN.md §12).

Usage: check_observability.py TRACE_FILE... METRICS_FILE...

File role is picked by shape, not order: a `.jsonl` file is validated
as a line-delimited trace, a JSON object with "traceEvents" as a
Chrome trace, and a JSON object with "series" as a --metrics-out
export. The checks mirror what `rust/tests/observability.rs` asserts
in-process: span tiling, ordered causal events, window partition —
here re-asserted on the serialized bytes, through an independent JSON
parser, so a malformed export can't hide behind the in-process view.
"""
import json
import sys

SPAN_KINDS = {
    "device_queue", "head_compute", "uplink", "edge_queue",
    "edge_service", "backhaul", "cloud_queue", "cloud_service",
    "downlink",
}
EVENT_TYPES = {"replan", "handover_relay", "reattach", "fault", "failover"}
FAULT_KINDS = {
    "site_down", "site_up", "backhaul_degrade", "backhaul_restore",
    "flash_crowd_start", "flash_crowd_end",
}


def fail(path, msg):
    sys.exit(f"{path}: {msg}")


def check_jsonl_trace(path, lines):
    meta = json.loads(lines[0])
    if meta.get("type") != "meta" or meta.get("format") != "smartsplit-trace":
        fail(path, "first line is not a smartsplit-trace meta header")
    if meta["sample_every"] < 1 or meta["unfinished"] != 0:
        fail(path, f"bad meta: {meta}")
    requests = events = 0
    last_event_t = float("-inf")
    for line in lines[1:]:
        obj = json.loads(line)
        kind = obj["type"]
        if kind == "request":
            requests += 1
            spans = obj["spans"]
            if not spans:
                fail(path, f"request {obj['req']} has no spans")
            if spans[0]["start_s"] != obj["issued_s"]:
                fail(path, f"request {obj['req']}: first span does not start at issue")
            if spans[-1]["end_s"] != obj["completed_s"]:
                fail(path, f"request {obj['req']}: last span does not end at completion")
            if spans[-1]["kind"] != "downlink":
                fail(path, f"request {obj['req']}: timeline does not end in downlink")
            for prev, cur in zip(spans, spans[1:]):
                if prev["end_s"] != cur["start_s"]:
                    fail(path, f"request {obj['req']}: gap between {prev['kind']} and {cur['kind']}")
            for s in spans:
                if s["kind"] not in SPAN_KINDS:
                    fail(path, f"unknown span kind {s['kind']!r}")
                if s["end_s"] < s["start_s"]:
                    fail(path, f"negative-duration span {s}")
        elif kind in EVENT_TYPES:
            events += 1
            t = obj["start_s"] if kind == "handover_relay" else obj["t_s"]
            if t < last_event_t:
                fail(path, "causal events are not in nondecreasing time order")
            last_event_t = t
            if kind == "replan" and not obj["derived_seed"].startswith("0x"):
                fail(path, "replan derived_seed is not a hex string")
            if kind == "fault" and obj["kind"] not in FAULT_KINDS:
                fail(path, f"unknown fault kind {obj['kind']!r}")
        else:
            fail(path, f"unknown line type {kind!r}")
    if requests != meta["requests"] or events != meta["events"]:
        fail(path, "meta counts do not match body")
    if requests == 0:
        fail(path, "trace recorded no requests")
    return f"{requests} requests, {events} events"


def check_chrome_trace(path, doc):
    events = doc["traceEvents"]
    if not events:
        fail(path, "empty traceEvents")
    for e in events:
        if e["ph"] not in ("X", "i"):
            fail(path, f"unexpected phase {e['ph']!r}")
        if e["ph"] == "X" and (e["dur"] < 0 or e["name"] not in SPAN_KINDS):
            fail(path, f"bad complete event {e['name']!r}")
    if doc["otherData"]["format"] != "smartsplit-trace":
        fail(path, "missing smartsplit meta in otherData")
    return f"{len(events)} trace events"


def check_metrics(path, doc):
    for key in ("model", "seed", "duration_s", "generated", "completed", "series"):
        if key not in doc:
            fail(path, f"missing top-level key {key!r}")
    series = doc["series"]
    if series["window_s"] <= 0 or not series["windows"]:
        fail(path, "empty or unwindowed series")
    totals = {"generated": 0, "completed": 0}
    prev_end = 0.0
    for i, w in enumerate(series["windows"]):
        if w["index"] != i or w["start_s"] != prev_end:
            fail(path, f"window {i} does not partition the run")
        prev_end = w["end_s"]
        for key in totals:
            totals[key] += w[key]
        lat = w["latency"]
        if not lat["p50_s"] <= lat["p95_s"] <= lat["p99_s"] <= lat["max_s"]:
            fail(path, f"window {i}: latency quantiles out of order")
        if not 0.0 <= w["planner"]["hit_rate"] <= 1.0:
            fail(path, f"window {i}: hit rate out of range")
    for key, total in totals.items():
        if total != doc[key]:
            fail(path, f"per-window {key} sums to {total}, run total is {doc[key]}")
    return f"{len(series['windows'])} windows of {series['window_s']}s"


def main(paths):
    if not paths:
        sys.exit("usage: check_observability.py FILE...")
    for path in paths:
        with open(path) as f:
            text = f.read()
        if path.endswith(".jsonl"):
            summary = check_jsonl_trace(path, text.splitlines())
        else:
            doc = json.loads(text)
            if "traceEvents" in doc:
                summary = check_chrome_trace(path, doc)
            elif "series" in doc:
                summary = check_metrics(path, doc)
            else:
                fail(path, "neither a chrome trace nor a metrics export")
        print(f"ok {path}: {summary}")


if __name__ == "__main__":
    main(sys.argv[1:])
