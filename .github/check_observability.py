#!/usr/bin/env python3
"""Schema check for the observability exports (rust/DESIGN.md §12, §14).

Usage: check_observability.py TRACE_FILE... METRICS_FILE... REPORT_FILE...

File role is picked by shape, not order: a `.jsonl` file is validated
as a line-delimited trace, a JSON object with "traceEvents" as a
Chrome trace, one with "series" as a --metrics-out export, one with
format "smartsplit-analyze" as a --report-out analysis, and one with
format "smartsplit-analyze-diff" as a --diff-out run diff. The checks
mirror what `rust/tests/observability.rs` / `rust/tests/analyze.rs`
assert in-process: span tiling, ordered causal events, window
partition, attribution shares, SLO verdict consistency — here
re-asserted on the serialized bytes, through an independent JSON
parser, so a malformed export can't hide behind the in-process view.

Every format is versioned; unknown schema_versions fail the check so a
silent format drift can't pass CI.
"""
import json
import sys

SPAN_KINDS = {
    "device_queue", "head_compute", "uplink", "edge_queue",
    "edge_service", "backhaul", "cloud_queue", "cloud_service",
    "downlink",
}
EVENT_TYPES = {"replan", "handover_relay", "reattach", "fault", "failover"}
FAULT_KINDS = {
    "site_down", "site_up", "backhaul_degrade", "backhaul_restore",
    "flash_crowd_start", "flash_crowd_end",
}
# Trace schema 1 used the key "version"; 2 renamed it to the uniform
# "schema_version" (readers accept both, writers emit 2).
TRACE_SCHEMA_ACCEPTED = {1, 2}
METRICS_SCHEMA_VERSION = 1
ANALYZE_SCHEMA_VERSION = 1
SLO_METRICS = {"p50", "p95", "p99", "mean", "max", "drop"}
SLO_VERDICTS = {"pass", "fail"}


def fail(path, msg):
    sys.exit(f"{path}: {msg}")


def check_schema_version(path, doc, accepted):
    v = doc.get("schema_version", doc.get("version"))
    if v not in accepted:
        fail(path, f"schema_version {v!r} not in accepted set {sorted(accepted)}")
    return v


def check_jsonl_trace(path, lines):
    meta = json.loads(lines[0])
    if meta.get("type") != "meta" or meta.get("format") != "smartsplit-trace":
        fail(path, "first line is not a smartsplit-trace meta header")
    check_schema_version(path, meta, TRACE_SCHEMA_ACCEPTED)
    if meta["sample_every"] < 1 or meta["unfinished"] != 0:
        fail(path, f"bad meta: {meta}")
    requests = events = 0
    last_event_t = float("-inf")
    for line in lines[1:]:
        obj = json.loads(line)
        kind = obj["type"]
        if kind == "request":
            requests += 1
            spans = obj["spans"]
            if not spans:
                fail(path, f"request {obj['req']} has no spans")
            if spans[0]["start_s"] != obj["issued_s"]:
                fail(path, f"request {obj['req']}: first span does not start at issue")
            if spans[-1]["end_s"] != obj["completed_s"]:
                fail(path, f"request {obj['req']}: last span does not end at completion")
            if spans[-1]["kind"] != "downlink":
                fail(path, f"request {obj['req']}: timeline does not end in downlink")
            for prev, cur in zip(spans, spans[1:]):
                if prev["end_s"] != cur["start_s"]:
                    fail(path, f"request {obj['req']}: gap between {prev['kind']} and {cur['kind']}")
            for s in spans:
                if s["kind"] not in SPAN_KINDS:
                    fail(path, f"unknown span kind {s['kind']!r}")
                if s["end_s"] < s["start_s"]:
                    fail(path, f"negative-duration span {s}")
        elif kind in EVENT_TYPES:
            events += 1
            t = obj["start_s"] if kind == "handover_relay" else obj["t_s"]
            if t < last_event_t:
                fail(path, "causal events are not in nondecreasing time order")
            last_event_t = t
            if kind == "replan" and not obj["derived_seed"].startswith("0x"):
                fail(path, "replan derived_seed is not a hex string")
            if kind == "fault" and obj["kind"] not in FAULT_KINDS:
                fail(path, f"unknown fault kind {obj['kind']!r}")
        else:
            fail(path, f"unknown line type {kind!r}")
    if requests != meta["requests"] or events != meta["events"]:
        fail(path, "meta counts do not match body")
    if requests == 0:
        fail(path, "trace recorded no requests")
    return f"{requests} requests, {events} events"


def check_chrome_trace(path, doc):
    events = doc["traceEvents"]
    if not events:
        fail(path, "empty traceEvents")
    for e in events:
        if e["ph"] not in ("X", "i"):
            fail(path, f"unexpected phase {e['ph']!r}")
        if e["ph"] == "X" and (e["dur"] < 0 or e["name"] not in SPAN_KINDS):
            fail(path, f"bad complete event {e['name']!r}")
    if doc["otherData"]["format"] != "smartsplit-trace":
        fail(path, "missing smartsplit meta in otherData")
    check_schema_version(path, doc["otherData"], TRACE_SCHEMA_ACCEPTED)
    return f"{len(events)} trace events"


def check_metrics(path, doc):
    for key in ("model", "seed", "duration_s", "generated", "completed", "series"):
        if key not in doc:
            fail(path, f"missing top-level key {key!r}")
    check_schema_version(path, doc, {METRICS_SCHEMA_VERSION})
    series = doc["series"]
    if series["window_s"] <= 0 or not series["windows"]:
        fail(path, "empty or unwindowed series")
    totals = {"generated": 0, "completed": 0}
    if "dropped" in doc:
        totals["dropped"] = 0
    prev_end = 0.0
    for i, w in enumerate(series["windows"]):
        if w["index"] != i or w["start_s"] != prev_end:
            fail(path, f"window {i} does not partition the run")
        prev_end = w["end_s"]
        for key in totals:
            totals[key] += w[key]
        lat = w["latency"]
        if not lat["p50_s"] <= lat["p95_s"] <= lat["p99_s"] <= lat["max_s"]:
            fail(path, f"window {i}: latency quantiles out of order")
        if not 0.0 <= w["planner"]["hit_rate"] <= 1.0:
            fail(path, f"window {i}: hit rate out of range")
    for key, total in totals.items():
        if total != doc[key]:
            fail(path, f"per-window {key} sums to {total}, run total is {doc[key]}")
    return f"{len(series['windows'])} windows of {series['window_s']}s"


def check_slice_row(path, row, label):
    stages = row["stages"]
    if [s["stage"] for s in stages] != [
        "device_queue", "head_compute", "uplink", "edge_queue", "edge_service",
        "backhaul", "cloud_queue", "cloud_service", "downlink",
    ]:
        fail(path, f"{label}: stage rows out of pipeline order")
    for s in stages:
        for key in ("share_of_total", "share_p50", "share_p95", "share_p99"):
            # Shares may dip epsilon-below 0 / above 1: the downlink slot
            # absorbs the exact residual, which can be a tiny negative.
            if not -1e-6 <= s[key] <= 1.0 + 1e-6:
                fail(path, f"{label}/{s['stage']}: {key}={s[key]} outside [0,1]")
    share_sum = sum(s["share_of_total"] for s in stages)
    if row["latency"]["count"] > 0 and abs(share_sum - 1.0) > 1e-9:
        fail(path, f"{label}: shares sum to {share_sum}, not 1")


def check_analyze_report(path, doc):
    check_schema_version(path, doc, {ANALYZE_SCHEMA_VERSION})
    src = doc["source"]
    if src["requests"] <= 0:
        fail(path, "analysis over zero requests")
    attr = doc["attribution"]
    overall = attr["overall"]
    if overall["latency"]["count"] != src["requests"]:
        fail(path, "overall attribution count disagrees with source requests")
    check_slice_row(path, overall, "overall")
    for group in ("by_site", "by_strategy", "by_reason"):
        for row in attr[group]:
            check_slice_row(path, row, f"{group}/{row['key']}")
            if row["latency"]["count"] <= 0:
                fail(path, f"{group}/{row['key']}: empty slice emitted")
    for s in doc["slos"]:
        if s["metric"] not in SLO_METRICS or s["verdict"] not in SLO_VERDICTS:
            fail(path, f"malformed SLO outcome {s['slo']!r}")
        if s["windows_violating"] > s["windows_evaluated"]:
            fail(path, f"SLO {s['slo']!r}: more violations than evaluated windows")
        if s["verdict"] == "pass" and (not s["overall_pass"] or s["windows_violating"]):
            fail(path, f"SLO {s['slo']!r}: verdict pass contradicts its counters")
    for iv in doc["faults"]["intervals"]:
        if iv["kind"] not in FAULT_KINDS:
            fail(path, f"fault interval with unknown kind {iv['kind']!r}")
        if iv["end_s"] < iv["start_s"]:
            fail(path, f"fault interval {iv['kind']!r} runs backwards")
    return (
        f"{src['requests']} requests, {len(doc['slos'])} SLOs, "
        f"{len(doc['faults']['intervals'])} fault intervals"
    )


def check_diff(path, doc):
    check_schema_version(path, doc, {ANALYZE_SCHEMA_VERSION})
    changes = doc["changes"]
    if doc["empty"] != (len(changes) == 0) or doc["changed"] != len(changes):
        fail(path, "diff counters disagree with the change list")
    by_class = {"regression": 0, "improvement": 0, "neutral": 0}
    for c in changes:
        if c["class"] not in by_class:
            fail(path, f"unknown diff class {c['class']!r}")
        by_class[c["class"]] += 1
    if by_class["regression"] != doc["regressions"]:
        fail(path, "regression count disagrees with the change list")
    if by_class["improvement"] != doc["improvements"]:
        fail(path, "improvement count disagrees with the change list")
    return f"{len(changes)} changes, {doc['regressions']} regressions"


def main(paths):
    if not paths:
        sys.exit("usage: check_observability.py FILE...")
    for path in paths:
        with open(path) as f:
            text = f.read()
        if path.endswith(".jsonl"):
            summary = check_jsonl_trace(path, text.splitlines())
        else:
            doc = json.loads(text)
            if "traceEvents" in doc:
                summary = check_chrome_trace(path, doc)
            elif doc.get("format") == "smartsplit-analyze":
                summary = check_analyze_report(path, doc)
            elif doc.get("format") == "smartsplit-analyze-diff":
                summary = check_diff(path, doc)
            elif "series" in doc:
                summary = check_metrics(path, doc)
            else:
                fail(path, "not a recognized smartsplit export")
        print(f"ok {path}: {summary}")


if __name__ == "__main__":
    main(sys.argv[1:])
