//! Offline stand-in for the `xla` PJRT bindings (xla-rs style API).
//!
//! The real crate links libxla and executes compiled HLO on a CPU PJRT
//! client; that toolchain is unavailable in this build environment, and the
//! AOT artifacts it would load are produced by the python pipeline anyway.
//! This stub keeps the whole L3 crate compiling and every artifact-free
//! code path (optimiser, perf model, netsim, sim/, protocol, figures)
//! fully functional. [`PjRtClient::cpu`] returns an error, which surfaces
//! through `runtime::Runtime::cpu` exactly where the artifact-gated tests
//! and benches already skip.
//!
//! Like the real bindings, the handle types are intentionally neither
//! `Send` nor `Sync` (`runtime::executor` documents and relies on this).

use std::fmt;
use std::marker::PhantomData;
use std::rc::Rc;

/// Error type: a message, `Display`-formatted at every call site.
#[derive(Clone, Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!("xla stub: {what} unavailable offline (build the real xla-rs bindings to execute artifacts; see DESIGN.md §4)"))
}

/// Marker making a type `!Send + !Sync`, mirroring the Rc-backed handles
/// of the real bindings.
type NotThreadsafe = PhantomData<Rc<()>>;

/// Element types this crate exchanges with PJRT (f32 only in smartsplit).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
}

/// Host-native scalar types accepted by buffer/literal transfers.
pub trait NativeType: Copy {
    fn from_f32(v: f32) -> Self;
    fn to_f32(self) -> f32;
}

impl NativeType for f32 {
    fn from_f32(v: f32) -> Self {
        v
    }

    fn to_f32(self) -> f32 {
        self
    }
}

/// Host-side literal: shape + row-major f32 data.
#[derive(Clone, Debug)]
pub struct Literal {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        shape: &[usize],
        bytes: &[u8],
    ) -> Result<Literal> {
        let ElementType::F32 = ty;
        let expect = shape.iter().product::<usize>() * 4;
        if bytes.len() != expect {
            return Err(Error(format!(
                "literal shape {shape:?} needs {expect} bytes, got {}",
                bytes.len()
            )));
        }
        let data = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(Literal { shape: shape.to_vec(), data })
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }
}

/// Parsed HLO module text (the AOT artifact format).
#[derive(Clone, Debug)]
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading HLO text {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// An XLA computation awaiting compilation.
#[derive(Clone, Debug)]
pub struct XlaComputation {
    proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { proto: proto.clone() }
    }

    pub fn proto(&self) -> &HloModuleProto {
        &self.proto
    }
}

/// PJRT client handle. [`PjRtClient::cpu`] fails in the stub — callers
/// (`runtime::Runtime::cpu`, `runtime::executor::Executor::spawn`) already
/// propagate the error, and every artifact-dependent test/bench skips
/// before reaching it.
pub struct PjRtClient {
    _marker: NotThreadsafe,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PJRT CPU client"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("HLO compilation"))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let expect: usize = dims.iter().product();
        if data.len() != expect {
            return Err(Error(format!(
                "buffer dims {dims:?} need {expect} elements, got {}",
                data.len()
            )));
        }
        Ok(PjRtBuffer {
            shape: dims.to_vec(),
            data: data.iter().map(|v| v.to_f32()).collect(),
            _marker: PhantomData,
        })
    }
}

/// Device-resident buffer (host-backed in the stub).
pub struct PjRtBuffer {
    shape: Vec<usize>,
    data: Vec<f32>,
    _marker: NotThreadsafe,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(Literal { shape: self.shape.clone(), data: self.data.clone() })
    }
}

/// Compiled executable handle — unreachable in the stub because
/// [`PjRtClient::compile`] always errors first.
pub struct PjRtLoadedExecutable {
    _marker: NotThreadsafe,
}

impl PjRtLoadedExecutable {
    pub fn execute_b<T: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("HLO execution"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_reports_offline() {
        let e = PjRtClient::cpu().err().expect("stub must not create clients");
        assert!(e.to_string().contains("unavailable offline"));
    }

    #[test]
    fn literal_roundtrip() {
        let vals = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        assert_eq!(lit.element_count(), 3);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vals.to_vec());
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[4], &bytes).is_err()
        );
    }
}
