//! Minimal offline stand-in for `once_cell`, built on `std::sync::OnceLock`.
//! Only `sync::Lazy` is provided — the single construct `smartsplit` uses
//! (static device profiles).

pub mod sync {
    use std::ops::Deref;
    use std::sync::OnceLock;

    /// Lazily-initialised static value. `F` must be `Fn` (not `FnOnce`)
    /// so the initialiser can live in a `static`; non-capturing closures
    /// coerce to the default `fn() -> T`.
    pub struct Lazy<T, F = fn() -> T> {
        cell: OnceLock<T>,
        init: F,
    }

    impl<T, F: Fn() -> T> Lazy<T, F> {
        pub const fn new(init: F) -> Lazy<T, F> {
            Lazy { cell: OnceLock::new(), init }
        }

        pub fn force(this: &Lazy<T, F>) -> &T {
            this.cell.get_or_init(|| (this.init)())
        }
    }

    impl<T, F: Fn() -> T> Deref for Lazy<T, F> {
        type Target = T;

        fn deref(&self) -> &T {
            Lazy::force(self)
        }
    }

    impl<T: std::fmt::Debug, F: Fn() -> T> std::fmt::Debug for Lazy<T, F> {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_tuple("Lazy").field(Lazy::force(self)).finish()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::sync::Lazy;

    static N: Lazy<Vec<u32>> = Lazy::new(|| vec![1, 2, 3]);

    #[test]
    fn static_lazy_initialises_once_and_derefs() {
        assert_eq!(N.len(), 3);
        assert_eq!(N[2], 3);
        assert_eq!(*N, vec![1, 2, 3]);
    }
}
