//! Minimal offline stand-in for the `log` facade. Records are written to
//! stderr whenever `RUST_LOG` is set (any value); otherwise every macro is
//! a no-op that still type-checks its format arguments.

use std::sync::atomic::{AtomicU8, Ordering};

static STATE: AtomicU8 = AtomicU8::new(STATE_UNKNOWN);
const STATE_UNKNOWN: u8 = 0;
const STATE_OFF: u8 = 1;
const STATE_ON: u8 = 2;

/// Whether records should be emitted (cached `RUST_LOG` presence check).
pub fn enabled() -> bool {
    match STATE.load(Ordering::Relaxed) {
        STATE_ON => true,
        STATE_OFF => false,
        _ => {
            let on = std::env::var_os("RUST_LOG").is_some_and(|v| !v.is_empty());
            STATE.store(if on { STATE_ON } else { STATE_OFF }, Ordering::Relaxed);
            on
        }
    }
}

/// Emit one record (used by the level macros).
pub fn emit(level: &str, args: std::fmt::Arguments<'_>) {
    eprintln!("[{level:<5}] {args}");
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { if $crate::enabled() { $crate::emit("ERROR", format_args!($($arg)+)); } };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { if $crate::enabled() { $crate::emit("WARN", format_args!($($arg)+)); } };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { if $crate::enabled() { $crate::emit("INFO", format_args!($($arg)+)); } };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { if $crate::enabled() { $crate::emit("DEBUG", format_args!($($arg)+)); } };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { if $crate::enabled() { $crate::emit("TRACE", format_args!($($arg)+)); } };
}

#[cfg(test)]
mod tests {
    #[test]
    fn macros_typecheck_and_do_not_panic() {
        crate::info!("loaded {} layers in {:?}", 21, std::time::Duration::from_millis(3));
        crate::warn!("request failed: {}", "boom");
        crate::error!("e");
        crate::debug!("d {}", 1);
        crate::trace!("t");
    }
}
