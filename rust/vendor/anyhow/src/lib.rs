//! Minimal offline stand-in for the `anyhow` crate, exposing exactly the
//! surface `smartsplit` uses: [`Error`], [`Result`], the [`Context`]
//! extension trait, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Semantics match the real crate where it matters here:
//! * `Display` prints the outermost message; alternate `{:#}` prints the
//!   whole chain separated by `": "` (the `{e:#}` idiom in `main.rs`);
//! * `Debug` prints the message plus a `Caused by:` list (what `unwrap`
//!   and `fn main() -> Result<()>` show);
//! * a blanket `From<E: std::error::Error>` powers `?` conversions —
//!   which is why [`Error`] itself deliberately does NOT implement
//!   `std::error::Error`, exactly like the real crate.

use std::fmt;

/// Error value carrying a context chain, outermost message first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an additional outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if f.alternate() {
            for cause in &self.chain[1..] {
                write!(f, ": {cause}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(|| ...)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for std::result::Result<T, Error> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.to_string(), "gone");
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let e: Result<()> = std::result::Result::<(), std::io::Error>::Err(io_err())
            .context("reading manifest");
        let e = e.unwrap_err().context("loading model");
        assert_eq!(format!("{e}"), "loading model");
        assert_eq!(format!("{e:#}"), "loading model: reading manifest: gone");
        assert_eq!(e.root_cause(), "gone");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing flag").unwrap_err();
        assert_eq!(e.to_string(), "missing flag");
        assert_eq!(Some(5u32).context("missing").unwrap(), 5);
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(3).unwrap_err().to_string(), "three is right out");
        let e = anyhow!("split {} failed", 4);
        assert_eq!(e.to_string(), "split 4 failed");
    }
}
