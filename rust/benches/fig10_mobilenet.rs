//! Fig. 10 — SmartSplit-split CNNs vs MobileNetV2-on-phone vs
//! VGG16-on-phone: accuracy, latency, energy, memory.
//!
//! Paper shape: split VGG16 gives ~10% more accuracy than MobileNetV2 with
//! lower memory, similar energy, at a few seconds more latency.

use smartsplit::bench::Table;
use smartsplit::device::profiles;
use smartsplit::figures::{dump_json, mobilenet_comparison};
use smartsplit::optimizer::Nsga2Params;
use smartsplit::util::json::Json;

fn main() -> anyhow::Result<()> {
    println!("== Figure 10 — splitting vs smartphone-optimised model ==");
    let rows = mobilenet_comparison(profiles::samsung_j6(), 10.0, &Nsga2Params::default())?;
    let mut t = Table::new(&["configuration", "top-1 acc", "latency (s)", "energy (J)", "memory (MB)"]);
    let mut json = Vec::new();
    for r in &rows {
        t.row(&[
            r.label.clone(),
            format!("{:.2}%", r.top1_accuracy * 100.0),
            format!("{:.3}", r.latency_s),
            format!("{:.3}", r.energy_j),
            format!("{:.2}", r.memory_bytes / 1e6),
        ]);
        json.push(Json::obj(vec![
            ("label", Json::str(&r.label)),
            ("top1", Json::Num(r.top1_accuracy)),
            ("latency_s", Json::Num(r.latency_s)),
            ("energy_j", Json::Num(r.energy_j)),
            ("memory_mb", Json::Num(r.memory_bytes / 1e6)),
        ]));
    }
    t.print();
    let path = dump_json("fig10", &Json::Arr(json))?;
    println!("\nwrote {}", path.display());
    Ok(())
}
