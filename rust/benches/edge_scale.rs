//! §Scale: tiered-simulator throughput and the edge/cloud balance.
//!
//! Runs the `city_scale_tiered` scenario (devices → metro edge sites →
//! core cloud, 2-D `(l1, l2)` planning through the split-plan cache)
//! and records the numbers the CI perf trajectory tracks in
//! `BENCH_edge.json`: events/sec, decisions/sec, edge vs cloud
//! utilisation, plan-cache hit rate, and the torso share. `--smoke`
//! shrinks the fleet for CI.

use smartsplit::bench::{black_box, Bench};
use smartsplit::sim;
use smartsplit::util::json::Json;

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    // (devices, sites, virtual seconds, bench iters, warmup)
    let sizes: Vec<(usize, usize, f64, usize, usize)> = if smoke {
        vec![(2_000, 3, 120.0, 2, 1)]
    } else {
        vec![(2_000, 3, 120.0, 3, 1), (10_000, 8, 60.0, 3, 1), (50_000, 16, 30.0, 2, 0)]
    };
    println!("== edge_scale: city-tiered scenario, alexnet, seed 7 ==");

    let mut runs = Vec::new();
    for (devices, sites, duration_s, iters, warmup) in sizes {
        let cfg = sim::city_scale_tiered("alexnet", devices, sites, duration_s, 7);
        Bench::new(&format!(
            "simulate {devices} devices / {sites} edge sites / {duration_s:.0}s virtual"
        ))
        .iters(iters)
        .warmup(warmup)
        .run(|| {
            black_box(sim::run(&cfg).expect("sim run"));
        });
        let report = sim::run(&cfg)?;
        let wall_s = report.wall.as_secs_f64().max(1e-9);
        let edge_util = report.edges.iter().map(|e| e.utilization).sum::<f64>()
            / report.edges.len().max(1) as f64;
        let cloud_util = report.clouds.iter().map(|c| c.utilization).sum::<f64>()
            / report.clouds.len().max(1) as f64;
        let edge_served: u64 = report.edges.iter().map(|e| e.served).sum();
        let decisions_per_sec = report.decision_count as f64 / wall_s;
        println!(
            "    {:>6} devices: {:>9} events in {:?} → {:>12.0} events/s, \
             {:.0} decisions/s, edge util {:.1}% vs cloud util {:.1}%, \
             cache hit rate {:.1}%",
            devices,
            report.events,
            report.wall,
            report.events_per_wall_second(),
            decisions_per_sec,
            edge_util * 100.0,
            cloud_util * 100.0,
            report.planner.hit_rate() * 100.0,
        );
        // A tiered run that never uses its edge tier is a silent
        // misconfiguration, not a perf number.
        assert!(edge_served > 0, "no torso work reached the edge tier");
        runs.push(Json::obj(vec![
            ("devices", Json::Num(devices as f64)),
            ("edge_sites", Json::Num(sites as f64)),
            ("virtual_s", Json::Num(duration_s)),
            ("events", Json::Num(report.events as f64)),
            ("events_per_sec", Json::Num(report.events_per_wall_second())),
            ("decisions", Json::Num(report.decision_count as f64)),
            ("decisions_per_sec", Json::Num(decisions_per_sec)),
            ("completed", Json::Num(report.completed as f64)),
            ("edge_utilization", Json::Num(edge_util)),
            ("cloud_utilization", Json::Num(cloud_util)),
            ("edge_served", Json::Num(edge_served as f64)),
            ("cache_hit_rate", Json::Num(report.planner.hit_rate())),
            ("planner_solves", Json::Num(report.planner.solves as f64)),
            ("edge_queue_p95_s", Json::Num(report.edge_queue_delay.p95())),
            ("cloud_queue_p95_s", Json::Num(report.queue_delay.p95())),
        ]));
    }

    let json = Json::obj(vec![
        ("bench", Json::str("edge_scale")),
        ("smoke", Json::Bool(smoke)),
        ("scenario", Json::str("city_scale_tiered")),
        ("model", Json::str("alexnet")),
        ("runs", Json::Arr(runs)),
    ]);
    // Tracked at the repo root (next to BENCH_planner.json) so the perf
    // trajectory is versioned; CARGO_MANIFEST_DIR keeps the location
    // stable however cargo was invoked.
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_edge.json");
    std::fs::write(&out, json.to_string_pretty())?;
    println!("\nwrote {}", std::fs::canonicalize(&out)?.display());
    Ok(())
}
