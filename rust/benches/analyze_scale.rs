//! §Scale: trace-plane analytics throughput.
//!
//! Runs the `city_faulty` scenario with full observability, then
//! benches the analysis pipeline over the captured trace: per-request
//! stage attribution, the SLO audit + fault-impact pass, and report
//! assembly. Records the numbers the CI perf trajectory tracks in
//! `BENCH_analyze.json`: requests attributed per second (in-process)
//! and parsed per second (offline JSONL), report build time, and the
//! analysis surface (SLO outcomes, fault intervals, residuals). The
//! exact-partition invariant and the empty self-diff are asserted on
//! every record — a fast analysis that miscounts is not a perf number.
//! `--smoke` shrinks the fleet for CI.

use smartsplit::analyze::{diff_reports, AnalyzeReport, RunData, Slo};
use smartsplit::bench::{black_box, Bench};
use smartsplit::sim::{self, ObservabilityConfig};
use smartsplit::util::json::Json;

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    // (devices, sites, virtual seconds, bench iters, warmup)
    let sizes: Vec<(usize, usize, f64, usize, usize)> = if smoke {
        vec![(2_000, 4, 120.0, 3, 1)]
    } else {
        vec![(2_000, 4, 300.0, 3, 1), (10_000, 8, 120.0, 3, 1), (50_000, 16, 60.0, 2, 0)]
    };
    println!("== analyze_scale: city-faulty scenario, alexnet, seed 7 ==");

    let slos: Vec<Slo> = ["p99<30s", "p50<0.2s", "drop<50%"]
        .iter()
        .map(|s| Slo::parse(s).expect("slo grammar"))
        .collect();

    let mut runs = Vec::new();
    for (devices, sites, duration_s, iters, warmup) in sizes {
        let mut cfg = sim::city_faulty("alexnet", devices, sites, duration_s, 7);
        cfg.observability = ObservabilityConfig::full(duration_s / 12.0);
        let report = sim::run(&cfg)?;

        Bench::new(&format!(
            "attribute + audit {} traced requests ({devices} devices / {sites} sites / \
             {duration_s:.0}s virtual)",
            report.completed
        ))
        .iters(iters)
        .warmup(warmup)
        .run(|| {
            let data = RunData::from_report(&report).expect("analysis inputs");
            black_box(AnalyzeReport::build(&data, &slos));
        });

        let t0 = std::time::Instant::now();
        let data = RunData::from_report(&report)?;
        let analysis = AnalyzeReport::build(&data, &slos);
        let build_s = t0.elapsed().as_secs_f64().max(1e-9);

        // Offline path: parse the JSONL the CLI would have written.
        let jsonl = report.trace.as_ref().expect("tracing was on").to_jsonl();
        let t1 = std::time::Instant::now();
        let offline = RunData::from_export_strs(Some(&jsonl), None)?;
        let parse_s = t1.elapsed().as_secs_f64().max(1e-9);

        // Correctness gates on every record, every run.
        assert!(report.fault_events > 0, "the fault schedule never fired");
        assert_eq!(data.requests.len() as u64, report.completed, "attribution lost requests");
        assert_eq!(offline.requests.len(), data.requests.len(), "offline parse lost requests");
        for rec in data.requests.iter().chain(&offline.requests) {
            assert_eq!(
                rec.share_sum().to_bits(),
                rec.latency_s().to_bits(),
                "req {}: stage shares do not partition latency bit-for-bit",
                rec.req
            );
        }
        let doc = analysis.to_json();
        let selfdiff = diff_reports(&doc, &doc);
        assert!(selfdiff.is_empty(), "self-diff of the report is not empty");
        assert!(!analysis.faults.intervals.is_empty(), "no fault intervals attributed");

        let n = data.requests.len() as f64;
        println!(
            "    {:>6} devices: {:>8} requests analyzed in {:.3}s → {:>10.0} req/s \
             (offline parse {:>10.0} req/s), {} SLOs, {} fault intervals, {} residual-bearing",
            devices,
            data.requests.len(),
            build_s,
            n / build_s,
            n / parse_s,
            analysis.slos.len(),
            analysis.faults.intervals.len(),
            analysis.attribution.residual_requests,
        );
        runs.push(Json::obj(vec![
            ("devices", Json::Num(devices as f64)),
            ("edge_sites", Json::Num(sites as f64)),
            ("virtual_s", Json::Num(duration_s)),
            ("traced_requests", Json::Num(data.requests.len() as f64)),
            ("causal_events", Json::Num(data.events_total as f64)),
            ("windows", Json::Num(data.windows.len() as f64)),
            ("analyze_build_s", Json::Num(build_s)),
            ("requests_attributed_per_sec", Json::Num(n / build_s)),
            ("trace_parse_s", Json::Num(parse_s)),
            ("requests_parsed_per_sec", Json::Num(n / parse_s)),
            ("slo_outcomes", Json::Num(analysis.slos.len() as f64)),
            ("fault_intervals", Json::Num(analysis.faults.intervals.len() as f64)),
            ("residual_requests", Json::Num(analysis.attribution.residual_requests as f64)),
        ]));
    }

    let json = Json::obj(vec![
        ("bench", Json::str("analyze_scale")),
        ("smoke", Json::Bool(smoke)),
        ("scenario", Json::str("city_faulty")),
        ("model", Json::str("alexnet")),
        ("slos", Json::Arr(slos.iter().map(|s| Json::str(&s.raw)).collect())),
        ("runs", Json::Arr(runs)),
    ]);
    // Tracked at the repo root (next to the other BENCH_*.json files)
    // so the perf trajectory is versioned; CARGO_MANIFEST_DIR keeps the
    // location stable however cargo was invoked.
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_analyze.json");
    std::fs::write(&out, json.to_string_pretty())?;
    println!("\nwrote {}", std::fs::canonicalize(&out)?.display());
    Ok(())
}
