//! Fig. 6 — normalised latency / energy / memory of every Pareto-set
//! member produced by NSGA-II, per model.

use std::collections::BTreeMap;

use smartsplit::bench::{Bench, Table};
use smartsplit::device::profiles;
use smartsplit::figures::{dump_json, normalise_columns, pareto_and_choice, series_json, MODELS};
use smartsplit::optimizer::Nsga2Params;

fn main() -> anyhow::Result<()> {
    println!("== Figure 6 — Pareto sets from NSGA-II (pop=100, gens=250) ==");
    let params = Nsga2Params::default();
    let mut series = BTreeMap::new();
    for model in MODELS {
        let r = pareto_and_choice(model, profiles::samsung_j6(), 10.0, &params)?;
        let raw: Vec<[f64; 3]> = r.pareto.iter().map(|(_, o)| *o).collect();
        let norm = normalise_columns(&raw);
        let mut t = Table::new(&["l1", "norm latency", "norm energy", "norm memory"]);
        for ((l1, _), n) in r.pareto.iter().zip(&norm) {
            t.row(&[
                l1.to_string(),
                format!("{:.3}", n[0]),
                format!("{:.3}", n[1]),
                format!("{:.3}", n[2]),
            ]);
        }
        println!("\n-- {model} ({} Pareto members, {} evals) --",
                 r.pareto.len(), r.evaluations);
        t.print();
        for (j, key) in ["latency", "energy", "memory"].iter().enumerate() {
            series.insert(
                format!("{model}/{key}"),
                r.pareto
                    .iter()
                    .zip(&norm)
                    .map(|((l1, _), n)| (*l1 as f64, n[j]))
                    .collect(),
            );
        }
    }
    let path = dump_json("fig6", &series_json(&series))?;
    println!("\nwrote {}", path.display());

    // NSGA-II wall-time (the optimiser must be cheap enough to re-run on
    // every bandwidth change — §Perf L3).
    println!("\nsolver cost:");
    Bench::new("nsga2 alexnet pop=100 gens=250").iters(5).run(|| {
        let _ = pareto_and_choice("alexnet", profiles::samsung_j6(), 10.0, &params).unwrap();
    });
    Ok(())
}
