//! §Scale: discrete-event simulator throughput — events/sec at 1k
//! through 1M devices (city scenario, diurnal load, churn on), plus
//! the sharded engine's scaling curves. The whole point of `sim/` is
//! that fleet size costs events, not wall-clock sockets; this bench
//! pins the events/sec the engine sustains so regressions in the hot
//! loop (heap ops, planning, histogram records) show up as numbers,
//! not vibes.
//!
//! Three sections:
//!  1. the device ladder — raw events/sec at each fleet size (`--smoke`
//!     trims iterations and caps the ladder at 100k devices; the full
//!     run attempts a 1M-device short-horizon city where host memory
//!     allows);
//!  2. the observability overhead gate (<1%): with the sinks disabled
//!     every hook is an `Option` branch on `None`, and even armed at
//!     the sparsest sampling the hot loop must not slow down measurably
//!     — the "zero-cost when dark" claim of DESIGN.md §12, measured
//!     rather than asserted;
//!  3. shard-scaling curves — the tiered and mobile cities dispatched
//!     at 1/2/4 shards, recording events/sec and events/sec-per-core
//!     (normalised by `min(shards, available_parallelism)`), merged
//!     into `BENCH_edge.json` / `BENCH_mobility.json` under a
//!     `shard_scaling` key. Every sharded run is checked against the
//!     1-shard event count — a bench that silently broke replay parity
//!     would be measuring a different simulation.

use smartsplit::bench::{black_box, Bench};
use smartsplit::sim;
use smartsplit::util::json::{self, Json};

/// Best-of-N wall throughput (events per wall second) for a config —
/// min-wall filtering keeps scheduler noise out of a 1% comparison.
fn best_events_per_sec(cfg: &sim::SimConfig, iters: usize) -> (f64, u64) {
    let mut best = 0.0f64;
    let mut events = 0;
    for _ in 0..iters {
        let r = sim::run(cfg).expect("sim run");
        best = best.max(r.events_per_wall_second());
        events = r.events;
    }
    (best, events)
}

/// Cores the sharded dispatch can actually use: the engine's window
/// drains fan out at most one thread per shard, bounded by the host.
fn cores_used(shards: usize) -> usize {
    let host = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    shards.clamp(1, host)
}

/// Read-modify-write a `shard_scaling` section into a tracked
/// `BENCH_*.json` without clobbering the owning bench's own numbers
/// (edge_scale / mobility_scale write the rest of the file).
fn merge_shard_scaling(path: &std::path::Path, section: Json) -> anyhow::Result<()> {
    let mut doc = json::parse_file(path)
        .unwrap_or_else(|_| Json::obj(vec![("bench", Json::str("sim_scale"))]));
    if let Json::Obj(pairs) = &mut doc {
        if let Some(slot) = pairs.iter_mut().find(|(k, _)| k == "shard_scaling") {
            slot.1 = section;
        } else {
            pairs.push(("shard_scaling".to_string(), section));
        }
    } else {
        doc = Json::obj(vec![("shard_scaling", section)]);
    }
    std::fs::write(path, doc.to_string_pretty())?;
    println!("    merged shard_scaling into {}", path.display());
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");

    // ---------------------------------------------------- 1. device ladder
    println!("== sim_scale: city scenario, alexnet, seed 7 ==");
    // (devices, virtual seconds, bench iters, warmup)
    let sizes: Vec<(usize, f64, usize, usize)> = if smoke {
        vec![(1_000, 60.0, 2, 1), (10_000, 30.0, 1, 0), (100_000, 10.0, 1, 0)]
    } else {
        vec![
            (1_000, 120.0, 5, 1),
            (10_000, 60.0, 3, 1),
            (100_000, 30.0, 2, 0),
            // The 1M+ attempt: a short-horizon city so the fleet spawn
            // dominates memory, not the request log. Hosts that cannot
            // hold the fleet will fail loudly here rather than publish
            // a truncated ladder.
            (1_000_000, 3.0, 1, 0),
        ]
    };

    let mut ladder = Vec::new();
    for (devices, duration_s, iters, warmup) in sizes {
        let cfg = sim::city_scale("alexnet", devices, duration_s, 7);
        Bench::new(&format!("simulate {devices} devices / {duration_s:.0}s virtual"))
            .iters(iters)
            .warmup(warmup)
            .run(|| {
                black_box(sim::run(&cfg).expect("sim run"));
            });
        let report = sim::run(&cfg)?;
        println!(
            "    {:>7} devices: {:>9} events in {:?} → {:>12.0} events/s, \
             {} completed, {} re-splits",
            devices,
            report.events,
            report.wall,
            report.events_per_wall_second(),
            report.completed,
            report.resplits,
        );
        ladder.push((devices, report.events_per_wall_second()));
    }
    assert!(
        ladder.iter().any(|&(d, _)| d >= 100_000),
        "the ladder must measure at least one ≥100k-device fleet"
    );

    // ------------------------------------- 2. observability overhead gate
    // Same city, once fully dark and once with the trace recorder armed
    // at the sparsest sampling (`u64::MAX` → only request 0 is sampled,
    // so every hook still pays its branch + modulo while recording
    // almost nothing). Best-of-N wall throughput on both sides; the
    // armed side must stay within 1% of dark. Event counts must match
    // exactly — observability may never perturb the schedule.
    let (ov_devices, ov_duration, ov_iters) =
        if smoke { (10_000, 30.0, 3) } else { (10_000, 60.0, 4) };
    println!(
        "== sim_scale: observability overhead ({ov_devices} devices / {ov_duration:.0}s virtual) =="
    );
    let dark = sim::city_scale("alexnet", ov_devices, ov_duration, 7);
    let mut armed = dark.clone();
    armed.observability.trace_sample_every = u64::MAX;
    let (dark_eps, dark_events) = best_events_per_sec(&dark, ov_iters);
    let (armed_eps, armed_events) = best_events_per_sec(&armed, ov_iters);
    assert_eq!(
        dark_events, armed_events,
        "tracing must be schedule-transparent: event counts diverged"
    );
    let overhead_pct = (dark_eps / armed_eps - 1.0) * 100.0;
    println!(
        "    dark {dark_eps:>12.0} events/s | armed {armed_eps:>12.0} events/s \
         → overhead {overhead_pct:+.3}%"
    );
    assert!(
        overhead_pct < 1.0,
        "observability seam costs {overhead_pct:.3}% with tracing effectively \
         disabled — budget is <1%"
    );

    // ------------------------------------------- 3. shard-scaling curves
    // Tiered and mobile cities at 1/2/4 shards. The 1-shard run is the
    // frozen reference; every layout must dispatch the identical event
    // count (the replay-parity contract, `tests/shard_parity.rs`) —
    // what varies is wall time, reported both raw and per core.
    let shard_counts = [1usize, 2, 4];
    let scenarios: Vec<(&str, &std::path::Path, sim::SimConfig, usize)> = {
        let (td, ts, md, ms) =
            if smoke { (100_000, 10.0, 20_000, 15.0) } else { (200_000, 20.0, 50_000, 40.0) };
        vec![
            (
                "city_scale_tiered",
                std::path::Path::new("../BENCH_edge.json"),
                sim::city_scale_tiered("alexnet", td, 8, ts, 7),
                td,
            ),
            (
                "city_mobile",
                std::path::Path::new("../BENCH_mobility.json"),
                sim::city_mobile("alexnet", md, 8, ms, 7),
                md,
            ),
        ]
    };

    for (name, bench_file, cfg, devices) in scenarios {
        println!("== sim_scale: shard scaling, {name} ({devices} devices) ==");
        let mut reference_events = None;
        let mut curve = Vec::new();
        for shards in shard_counts {
            let mut sharded = cfg.clone();
            sharded.shards = shards;
            let iters = if smoke { 1 } else { 2 };
            let (eps, events) = best_events_per_sec(&sharded, iters);
            match reference_events {
                None => reference_events = Some(events),
                Some(reference) => assert_eq!(
                    events, reference,
                    "{name}: {shards} shards dispatched a different event count — \
                     the bench broke replay parity"
                ),
            }
            let cores = cores_used(shards);
            let eps_per_core = eps / cores as f64;
            println!(
                "    {shards} shard(s): {eps:>12.0} events/s over {cores} core(s) \
                 → {eps_per_core:>12.0} events/s/core"
            );
            curve.push(Json::obj(vec![
                ("shards", Json::Num(shards as f64)),
                ("cores_used", Json::Num(cores as f64)),
                ("events", Json::Num(events as f64)),
                ("events_per_sec", Json::Num(eps)),
                ("events_per_sec_per_core", Json::Num(eps_per_core)),
            ]));
        }
        let section = Json::obj(vec![
            ("scenario", Json::str(name)),
            ("devices", Json::Num(devices as f64)),
            ("virtual_s", Json::Num(cfg.duration_s)),
            ("smoke", Json::Bool(smoke)),
            ("curve", Json::Arr(curve)),
        ]);
        let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(bench_file);
        merge_shard_scaling(&out, section)?;
    }
    Ok(())
}
