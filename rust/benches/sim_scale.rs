//! §Scale: discrete-event simulator throughput — events/sec at 1k, 10k
//! and 100k devices (city scenario, diurnal load, churn on). The whole
//! point of `sim/` is that fleet size costs events, not wall-clock
//! sockets; this bench pins the events/sec the engine sustains so
//! regressions in the hot loop (heap ops, planning, histogram records)
//! show up as numbers, not vibes.

use smartsplit::bench::{black_box, Bench};
use smartsplit::sim;

fn main() -> anyhow::Result<()> {
    println!("== sim_scale: city scenario, alexnet, seed 7 ==");
    // (devices, virtual seconds, bench iters, warmup)
    let sizes: [(usize, f64, usize, usize); 3] =
        [(1_000, 120.0, 5, 1), (10_000, 60.0, 3, 1), (100_000, 30.0, 2, 0)];

    for (devices, duration_s, iters, warmup) in sizes {
        let cfg = sim::city_scale("alexnet", devices, duration_s, 7);
        Bench::new(&format!("simulate {devices} devices / {duration_s:.0}s virtual"))
            .iters(iters)
            .warmup(warmup)
            .run(|| {
                black_box(sim::run(&cfg).expect("sim run"));
            });
        let report = sim::run(&cfg)?;
        println!(
            "    {:>7} devices: {:>9} events in {:?} → {:>12.0} events/s, \
             {} completed, {} re-splits",
            devices,
            report.events,
            report.wall,
            report.events_per_wall_second(),
            report.completed,
            report.resplits,
        );
    }
    Ok(())
}
