//! §Scale: discrete-event simulator throughput — events/sec at 1k, 10k
//! and 100k devices (city scenario, diurnal load, churn on). The whole
//! point of `sim/` is that fleet size costs events, not wall-clock
//! sockets; this bench pins the events/sec the engine sustains so
//! regressions in the hot loop (heap ops, planning, histogram records)
//! show up as numbers, not vibes.
//!
//! Also measures (and gates, <1%) the observability seam's overhead:
//! with the sinks disabled every hook is an `Option` branch on `None`,
//! and even armed at the sparsest sampling the hot loop must not slow
//! down measurably — the "zero-cost when dark" claim of DESIGN.md §12,
//! measured rather than asserted.

use smartsplit::bench::{black_box, Bench};
use smartsplit::sim;

/// Best-of-N wall throughput (events per wall second) for a config —
/// min-wall filtering keeps scheduler noise out of a 1% comparison.
fn best_events_per_sec(cfg: &sim::SimConfig, iters: usize) -> (f64, u64) {
    let mut best = 0.0f64;
    let mut events = 0;
    for _ in 0..iters {
        let r = sim::run(cfg).expect("sim run");
        best = best.max(r.events_per_wall_second());
        events = r.events;
    }
    (best, events)
}

fn main() -> anyhow::Result<()> {
    println!("== sim_scale: city scenario, alexnet, seed 7 ==");
    // (devices, virtual seconds, bench iters, warmup)
    let sizes: [(usize, f64, usize, usize); 3] =
        [(1_000, 120.0, 5, 1), (10_000, 60.0, 3, 1), (100_000, 30.0, 2, 0)];

    for (devices, duration_s, iters, warmup) in sizes {
        let cfg = sim::city_scale("alexnet", devices, duration_s, 7);
        Bench::new(&format!("simulate {devices} devices / {duration_s:.0}s virtual"))
            .iters(iters)
            .warmup(warmup)
            .run(|| {
                black_box(sim::run(&cfg).expect("sim run"));
            });
        let report = sim::run(&cfg)?;
        println!(
            "    {:>7} devices: {:>9} events in {:?} → {:>12.0} events/s, \
             {} completed, {} re-splits",
            devices,
            report.events,
            report.wall,
            report.events_per_wall_second(),
            report.completed,
            report.resplits,
        );
    }

    // Observability overhead gate: same 10k-device city, once fully dark
    // and once with the trace recorder armed at the sparsest sampling
    // (`u64::MAX` → only request 0 is sampled, so every hook still pays
    // its branch + modulo while recording almost nothing). Best-of-N
    // wall throughput on both sides; the armed side must stay within 1%
    // of dark. Event counts must match exactly — observability may never
    // perturb the schedule.
    println!("== sim_scale: observability overhead (10k devices / 60s virtual) ==");
    let dark = sim::city_scale("alexnet", 10_000, 60.0, 7);
    let mut armed = dark.clone();
    armed.observability.trace_sample_every = u64::MAX;
    let (dark_eps, dark_events) = best_events_per_sec(&dark, 4);
    let (armed_eps, armed_events) = best_events_per_sec(&armed, 4);
    assert_eq!(
        dark_events, armed_events,
        "tracing must be schedule-transparent: event counts diverged"
    );
    let overhead_pct = (dark_eps / armed_eps - 1.0) * 100.0;
    println!(
        "    dark {dark_eps:>12.0} events/s | armed {armed_eps:>12.0} events/s \
         → overhead {overhead_pct:+.3}%"
    );
    assert!(
        overhead_pct < 1.0,
        "observability seam costs {overhead_pct:.3}% with tracing effectively \
         disabled — budget is <1%"
    );
    Ok(())
}
