//! Fig. 5 — client energy consumption, Samsung J6 vs Redmi Note 8.
//!
//! Paper shape: "the client energy consumption remains almost similar for
//! both the devices" (the radio, not the SoC, differentiates them).

use std::collections::BTreeMap;

use smartsplit::bench::Table;
use smartsplit::figures::{client_energy_compare, dump_json, series_json, MODELS};

fn main() -> anyhow::Result<()> {
    println!("== Figure 5 — client energy: Samsung J6 vs Redmi Note 8 ==");
    let mut series = BTreeMap::new();
    for model in MODELS {
        let rows = client_energy_compare(model, 10.0)?;
        let mut t = Table::new(&["l1", "J6 client (J)", "Redmi client (J)", "ratio"]);
        for (l1, j6, redmi) in &rows {
            t.row(&[
                l1.to_string(),
                format!("{j6:.4}"),
                format!("{redmi:.4}"),
                format!("{:.3}", redmi / j6.max(1e-12)),
            ]);
        }
        println!("\n-- {model} --");
        t.print();
        series.insert(format!("{model}/j6"), rows.iter().map(|(l, a, _)| (*l as f64, *a)).collect());
        series.insert(format!("{model}/redmi"), rows.iter().map(|(l, _, b)| (*l as f64, *b)).collect());
    }
    let path = dump_json("fig5", &series_json(&series))?;
    println!("\nwrote {}", path.display());
    Ok(())
}
