//! Table I — TOPSIS-chosen split per model; Table II — splits chosen by
//! every competing algorithm. Paper values printed alongside for the
//! paper-vs-ours comparison recorded in EXPERIMENTS.md.

use smartsplit::bench::Table;
use smartsplit::device::profiles;
use smartsplit::figures::{algorithm_comparison, dump_json, pareto_and_choice, MODELS};
use smartsplit::optimizer::{Algorithm, Nsga2Params};
use smartsplit::util::json::Json;

const PAPER_TABLE1: [(&str, usize); 4] =
    [("alexnet", 3), ("vgg11", 11), ("vgg13", 10), ("vgg16", 10)];
const PAPER_TABLE2_LBO: [(&str, usize); 4] =
    [("alexnet", 3), ("vgg11", 21), ("vgg13", 20), ("vgg16", 25)];
const PAPER_TABLE2_EBO: [(&str, usize); 4] =
    [("alexnet", 6), ("vgg11", 11), ("vgg13", 15), ("vgg16", 17)];

fn main() -> anyhow::Result<()> {
    let params = Nsga2Params::default();
    println!("== Table I — optimal smartphone layers after TOPSIS ==");
    let mut t1 = Table::new(&["model", "ours l1", "paper l1"]);
    let mut j1 = Vec::new();
    for (model, paper) in PAPER_TABLE1 {
        let r = pareto_and_choice(model, profiles::samsung_j6(), 10.0, &params)?;
        t1.row(&[model.into(), r.decision.l1.to_string(), paper.to_string()]);
        j1.push((model, r.decision.l1, paper));
    }
    t1.print();

    println!("\n== Table II — smartphone layers per competing algorithm ==");
    let cells = algorithm_comparison(profiles::samsung_j6(), 10.0, &params, 100, 7)?;
    let mut t2 = Table::new(&["algorithm", "alexnet", "vgg11", "vgg13", "vgg16", "paper row"]);
    for algo in Algorithm::ALL {
        let mut row = vec![algo.name().to_string()];
        for model in MODELS {
            let c = cells
                .iter()
                .find(|c| c.model == model && c.algorithm == algo)
                .unwrap();
            row.push(if algo == Algorithm::Rs {
                format!("{:.1}", c.mean_l1)
            } else {
                format!("{:.0}", c.mean_l1)
            });
        }
        row.push(match algo {
            Algorithm::SmartSplit => "3 / 11 / 10 / 10".into(),
            Algorithm::Lbo => "3 / 21 / 20 / 25".into(),
            Algorithm::Ebo => "6 / 11 / 15 / 17".into(),
            Algorithm::Cos => "21 / 29 / 33 / 39".into(),
            Algorithm::Coc => "0 (all cloud)".into(),
            Algorithm::Rs => "random".into(),
        });
        t2.row(&row);
    }
    t2.print();
    let _ = PAPER_TABLE2_LBO;
    let _ = PAPER_TABLE2_EBO;

    let json = Json::Arr(
        j1.into_iter()
            .map(|(m, ours, paper)| {
                Json::obj(vec![
                    ("model", Json::str(m)),
                    ("ours", Json::Num(ours as f64)),
                    ("paper", Json::Num(paper as f64)),
                ])
            })
            .collect(),
    );
    let path = dump_json("table1", &json)?;
    println!("\nwrote {}", path.display());
    Ok(())
}
