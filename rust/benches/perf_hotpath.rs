//! §Perf hot-path benchmarks: the numbers EXPERIMENTS.md §Perf records.
//!
//! L1  — per-layer PJRT execution time of the AOT artifacts (the pallas
//!       interpret-lowered kernels), including the fc layers whose tiling
//!       was the big §Perf win (32.4 s → ~30 ms).
//! L3  — optimiser cost (NSGA-II+TOPSIS must be re-runnable per bandwidth
//!       change), protocol framing throughput, router dispatch overhead,
//!       and end-to-end single-request serving time at several splits.
//!
//! Skips the artifact-dependent sections when `artifacts/` is absent.

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use smartsplit::bench::{black_box, Bench};
use smartsplit::coordinator::{Config, Deployment};
use smartsplit::device::profiles;
use smartsplit::figures::perf_model;
use smartsplit::models::zoo;
use smartsplit::optimizer::{smartsplit, Nsga2Params, SplitDecision};
use smartsplit::runtime::{ModelRuntime, Tensor};
use smartsplit::serve::{write_msg, Msg};
use smartsplit::workload::{generate, synth_images, Arrival};

fn main() -> anyhow::Result<()> {
    println!("== §Perf L3: optimiser (must be cheap enough to re-run per bandwidth change) ==");
    let profile = zoo::vgg16().analyze(1);
    let pm = perf_model(&profile, profiles::samsung_j6(), 10.0);
    Bench::new("smartsplit vgg16 pop=100 gens=250").iters(10).run(|| {
        black_box(smartsplit(&pm, &Nsga2Params::default()));
    });
    Bench::new("smartsplit vgg16 pop=40 gens=40 (adaptive loop setting)")
        .iters(30)
        .run(|| {
            black_box(smartsplit(
                &pm,
                &Nsga2Params { pop_size: 40, generations: 40, ..Default::default() },
            ));
        });

    println!("\n== §Perf L3: protocol framing ==");
    let act = Tensor::new(vec![1, 64, 27, 27], synth_images(1, 64, 27, 0)[..64 * 27 * 27].to_vec())?;
    let mut sink = Vec::with_capacity(1 << 20);
    Bench::new("frame 186k-float activation (write_msg)").iters(200).run(|| {
        sink.clear();
        write_msg(&mut sink, &Msg::Infer { request_id: 1, from_layer: 4, tensor: act.clone() })
            .unwrap();
        black_box(sink.len());
    });

    if !Path::new("artifacts/alexnet/manifest.json").exists() {
        println!("\n(artifacts not built — skipping L1/E2E sections)");
        return Ok(());
    }

    println!("\n== §Perf L1: per-layer artifact execution (alexnet b1) ==");
    let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e}"))?;
    let rt = ModelRuntime::load(&client, Path::new("artifacts"), "alexnet", 1)?;
    let img = Tensor::new(vec![1, 3, 224, 224], synth_images(1, 3, 224, 7))?;
    Bench::new("alexnet full forward (21 layers, buffer-chained)")
        .iters(20)
        .run(|| {
            black_box(rt.run_all(&client, &img).unwrap());
        });
    let head = rt.run_segment(&client, 1, 15, &img)?;
    Bench::new("alexnet fc1 (layer 16, 9216x4096)").iters(20).run(|| {
        black_box(rt.layer(16).execute(&client, &head).unwrap());
    });
    Bench::new("alexnet conv1 (layer 1)").iters(20).run(|| {
        black_box(rt.layer(1).execute(&client, &img).unwrap());
    });

    println!("\n== §Perf E2E: split serving, single request (no slowdown, 200 Mbps) ==");
    for l1 in [0usize, 3, 13, 21] {
        let cfg = Config {
            model: "alexnet".into(),
            bandwidth_mbps: 200.0,
            emulate_slowdown: false,
            ..Config::default()
        };
        let dep = Deployment::start_with_split(cfg, SplitDecision { l1 })?;
        let reqs = generate(3, Arrival::ClosedLoop, 1);
        let _ = dep.serve(&reqs)?; // warm
        let stats = Bench::new(&format!("serve 4 requests @ l1={l1}"))
            .warmup(0)
            .iters(4)
            .run(|| {
                let reqs = generate(4, Arrival::ClosedLoop, 2);
                black_box(dep.serve(&reqs).unwrap());
            });
        let _ = stats;
        dep.shutdown();
    }

    println!("\n== §Perf L3: dynamic batching ablation (b8 artifacts) ==");
    for (batch, max_batch) in [(1usize, 1usize), (8, 8)] {
        let cfg = Config {
            model: "alexnet".into(),
            batch,
            bandwidth_mbps: 200.0,
            emulate_slowdown: false,
            router: smartsplit::serve::RouterConfig {
                max_batch,
                max_wait: Duration::from_millis(40),
            },
            ..Config::default()
        };
        let dep = Deployment::start_with_split(cfg, SplitDecision { l1: 3 })?;
        let reqs = generate(16, Arrival::ClosedLoop, 3);
        let report = dep.serve(&reqs)?;
        println!(
            "  hw_batch={batch} max_batch={max_batch}: {} req in {:?} → {:.2} req/s (mean latency {})",
            report.completed, report.elapsed, report.throughput_rps,
            smartsplit::util::fmt_secs(report.latency.mean_s())
        );
        dep.shutdown();
    }
    Ok(())
}
