//! Figs. 7 / 8 / 9 — latency, energy and memory achieved by the six
//! competing algorithms on the four CNNs (100 runs, averaged — only RS
//! varies across runs).
//!
//! Paper shape: COC minimises latency+energy with zero device memory but
//! defeats on-device AI; COS maximises energy+memory; EBO low energy, high
//! latency; LBO closest to SmartSplit; SmartSplit beats LBO on energy and
//! memory at comparable latency.

use std::collections::BTreeMap;

use smartsplit::bench::Table;
use smartsplit::device::profiles;
use smartsplit::figures::{algorithm_comparison, dump_json, series_json, MODELS};
use smartsplit::optimizer::{Algorithm, Nsga2Params};

fn main() -> anyhow::Result<()> {
    let params = Nsga2Params::default();
    let cells = algorithm_comparison(profiles::samsung_j6(), 10.0, &params, 100, 7)?;

    for (fig, title, unit, get) in [
        ("fig7", "Figure 7 — latency", "s", 0usize),
        ("fig8", "Figure 8 — energy", "J", 1),
        ("fig9", "Figure 9 — memory", "MB", 2),
    ] {
        println!("\n== {title} by algorithm ({unit}) ==");
        let mut t = Table::new(&["algorithm", "alexnet", "vgg11", "vgg13", "vgg16"]);
        let mut series = BTreeMap::new();
        for algo in Algorithm::ALL {
            let mut row = vec![algo.name().to_string()];
            let mut pts = Vec::new();
            for (i, model) in MODELS.iter().enumerate() {
                let c = cells
                    .iter()
                    .find(|c| c.model == *model && c.algorithm == algo)
                    .unwrap();
                let v = match get {
                    0 => c.latency_s,
                    1 => c.energy_j,
                    _ => c.memory_bytes / 1e6,
                };
                row.push(format!("{v:.3}"));
                pts.push((i as f64, v));
            }
            series.insert(algo.name().to_string(), pts);
            t.row(&row);
        }
        t.print();
        let path = dump_json(fig, &series_json(&series))?;
        println!("wrote {}", path.display());
    }
    Ok(())
}
