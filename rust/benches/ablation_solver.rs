//! Ablation: is NSGA-II + TOPSIS actually necessary on a ≤38-point split
//! domain? (A DESIGN.md §10 design-choice check the paper does not run.)
//!
//! We compare SmartSplit's front against brute-force enumeration of every
//! split (the ground truth — feasible only because the domain is tiny) and
//! against the weighted-sum scalarisation the paper argues against (§V-A).
//! Expected: NSGA-II recovers the exact true front; weighted-sum misses
//! non-convex front members and is sensitive to its weights; the GA costs
//! milliseconds, so the generality is free.

use smartsplit::bench::{Bench, Table};
use smartsplit::device::profiles;
use smartsplit::figures::{perf_model, MODELS};
use smartsplit::models::zoo;
use smartsplit::optimizer::{
    epsilon_constrained, exhaustive_pareto_front as true_front, smartsplit, topsis,
    weighted_metric, weighted_sum, Nsga2Params,
};

fn main() -> anyhow::Result<()> {
    let params = Nsga2Params::default();
    println!("== ablation: NSGA-II front vs exhaustive ground truth ==");
    let mut t = Table::new(&["model", "true front", "NSGA-II front", "exact", "TOPSIS(true)", "TOPSIS(GA)"]);
    for model in MODELS {
        let profile = zoo::by_name(model).unwrap().analyze(1);
        let pm = perf_model(&profile, profiles::samsung_j6(), 10.0);
        let truth = true_front(&pm);
        let ga = smartsplit(&pm, &params);
        let ga_front: Vec<usize> = ga.pareto.iter().map(|(l1, _)| *l1).collect();
        // TOPSIS over the true front for reference.
        let rows: Vec<Vec<f64>> = truth.iter().map(|&i| pm.objectives(i).to_vec()).collect();
        let feas = vec![true; rows.len()];
        let t_true = truth[topsis(&rows, &feas).unwrap().chosen];
        t.row(&[
            model.into(),
            format!("{truth:?}"),
            format!("{ga_front:?}"),
            (truth == ga_front).to_string(),
            t_true.to_string(),
            ga.decision.l1.to_string(),
        ]);
        assert_eq!(truth, ga_front, "{model}: GA missed the true front");
        assert_eq!(t_true, ga.decision.l1, "{model}: decisions diverge");
    }
    t.print();

    println!("\n== ablation: weighted-sum sensitivity (the paper's §V-A argument) ==");
    let profile = zoo::vgg16().analyze(1);
    let pm = perf_model(&profile, profiles::samsung_j6(), 10.0);
    let mut t = Table::new(&["weights (f1,f2,f3)", "chosen l1"]);
    let mut choices = std::collections::BTreeSet::new();
    for w in [
        [1.0, 1.0, 1.0],
        [2.0, 1.0, 1.0],
        [1.0, 2.0, 1.0],
        [1.0, 1.0, 2.0],
        [4.0, 1.0, 1.0],
        [1.0, 4.0, 1.0],
    ] {
        let l1 = weighted_sum(&pm, w).unwrap();
        choices.insert(l1);
        t.row(&[format!("{w:?}"), l1.to_string()]);
    }
    t.print();
    println!(
        "weighted-sum gave {} different answers across 6 weightings; \
         SmartSplit needs no weights.",
        choices.len()
    );

    println!("\n== ablation: weighted-metric (p=2) and ε-constrained (§V-A kin) ==");
    let mut t = Table::new(&["method", "setting", "chosen l1"]);
    for (p, w) in [(2.0, [1.0, 1.0, 1.0]), (2.0, [1.0, 2.0, 1.0]), (8.0, [1.0, 1.0, 1.0])] {
        t.row(&[
            "weighted-metric".into(),
            format!("p={p} w={w:?}"),
            weighted_metric(&pm, w, p).unwrap().to_string(),
        ]);
    }
    for eps in [[1.0, 0.5, 0.5], [1.0, 0.2, 0.2], [1.0, 0.05, 0.05]] {
        t.row(&[
            "ε-constrained (min f1)".into(),
            format!("ε={eps:?}"),
            match epsilon_constrained(&pm, 0, eps) {
                Some(l1) => l1.to_string(),
                None => "infeasible ε-box".into(),
            },
        ]);
    }
    t.print();

    println!("\n== ablation: solver cost (GA generality is ~free) ==");
    let profile = zoo::vgg16().analyze(1);
    let pm = perf_model(&profile, profiles::samsung_j6(), 10.0);
    Bench::new("exhaustive front + TOPSIS (38 points)").iters(50).run(|| {
        let truth = true_front(&pm);
        let rows: Vec<Vec<f64>> = truth.iter().map(|&i| pm.objectives(i).to_vec()).collect();
        let feas = vec![true; rows.len()];
        smartsplit::bench::black_box(topsis(&rows, &feas).unwrap());
    });
    Bench::new("NSGA-II pop=100 gens=250 + TOPSIS").iters(10).run(|| {
        smartsplit::bench::black_box(smartsplit(&pm, &params));
    });
    Ok(())
}
