//! Fig. 3 / Fig. 4 — energy consumption vs CNN split index, two phones.
//!
//! Paper shape: upload energy dominates on Samsung J6 (802.11 b/g/n radio);
//! client energy dominates on Redmi Note 8 (802.11 ac); download energy is
//! negligible everywhere.

use std::collections::BTreeMap;

use smartsplit::bench::Table;
use smartsplit::device::profiles;
use smartsplit::figures::{dump_json, energy_sweep, series_json, MODELS};

fn main() -> anyhow::Result<()> {
    let bandwidth = 10.0;
    for (fig, phone) in [("fig3", profiles::samsung_j6()), ("fig4", profiles::redmi_note8())] {
        println!("\n== {} — energy vs split index on {} (B = {bandwidth} Mbps) ==",
                 if fig == "fig3" { "Figure 3" } else { "Figure 4" }, phone.name);
        let mut series = BTreeMap::new();
        for model in MODELS {
            let sweep = energy_sweep(model, phone, bandwidth)?;
            let mut t = Table::new(&["l1", "client (J)", "upload (J)", "download (J)", "total (J)"]);
            for (l1, e) in &sweep {
                t.row(&[
                    l1.to_string(),
                    format!("{:.4}", e.client_j),
                    format!("{:.4}", e.upload_j),
                    format!("{:.5}", e.download_j),
                    format!("{:.4}", e.total()),
                ]);
            }
            println!("\n-- {model} --");
            t.print();
            type Get = fn(&smartsplit::perfmodel::EnergyBreakdown) -> f64;
            for (key, f) in [
                ("client", (|e: &smartsplit::perfmodel::EnergyBreakdown| e.client_j) as Get),
                ("upload", |e: &smartsplit::perfmodel::EnergyBreakdown| e.upload_j),
                ("download", |e: &smartsplit::perfmodel::EnergyBreakdown| e.download_j),
                ("total", |e: &smartsplit::perfmodel::EnergyBreakdown| e.total()),
            ] {
                series.insert(
                    format!("{model}/{key}"),
                    sweep.iter().map(|(l1, e)| (*l1 as f64, f(e))).collect(),
                );
            }
        }
        let path = dump_json(fig, &series_json(&series))?;
        println!("\nwrote {}", path.display());
    }
    Ok(())
}
