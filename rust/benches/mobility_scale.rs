//! §Scale: mobile-city throughput and the cost of edge handover.
//!
//! Runs the `city_mobile` scenario (the tiered city with every device
//! on a waypoint walk between edge sites) and records the numbers the
//! CI perf trajectory tracks in `BENCH_mobility.json`: events/sec,
//! handovers (count and per virtual second), migration re-solves and
//! their share of planner requests, plan-cache hit rate, and the
//! latency tax relative to the same city frozen static. `--smoke`
//! shrinks the fleet for CI.

use smartsplit::bench::{black_box, Bench};
use smartsplit::sim::{self, Mobility};
use smartsplit::util::json::Json;

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    // (devices, sites, virtual seconds, bench iters, warmup)
    let sizes: Vec<(usize, usize, f64, usize, usize)> = if smoke {
        vec![(2_000, 4, 120.0, 2, 1)]
    } else {
        vec![(2_000, 4, 300.0, 3, 1), (10_000, 8, 120.0, 3, 1), (50_000, 16, 60.0, 2, 0)]
    };
    println!("== mobility_scale: city-mobile scenario, alexnet, seed 7 ==");

    let mut runs = Vec::new();
    for (devices, sites, duration_s, iters, warmup) in sizes {
        let cfg = sim::city_mobile("alexnet", devices, sites, duration_s, 7);
        Bench::new(&format!(
            "simulate {devices} mobile devices / {sites} edge sites / {duration_s:.0}s virtual"
        ))
        .iters(iters)
        .warmup(warmup)
        .run(|| {
            black_box(sim::run(&cfg).expect("sim run"));
        });
        let report = sim::run(&cfg)?;
        // The mobility tax: the identical city frozen static.
        let mut frozen = cfg.clone();
        frozen.mobility = Mobility::Static;
        let baseline = sim::run(&frozen)?;

        let wall_s = report.wall.as_secs_f64().max(1e-9);
        let migration_requests = report.planner.migration_requests();
        let request_total: u64 = report.planner.requests_by_reason.iter().sum();
        println!(
            "    {:>6} devices: {:>9} events in {:?} → {:>12.0} events/s, \
             {} handovers ({:.2}/virtual-s), {} migration re-plans \
             ({:.1}% of planner requests), cache hit rate {:.1}%",
            devices,
            report.events,
            report.wall,
            report.events_per_wall_second(),
            report.handovers,
            report.handovers as f64 / duration_s,
            report.migration_replans,
            100.0 * migration_requests as f64 / request_total.max(1) as f64,
            report.planner.hit_rate() * 100.0,
        );
        println!(
            "    {:>6}         p95 latency {:.2} ms mobile vs {:.2} ms static \
             ({} vs {} resplits)",
            "",
            report.latency.p95() * 1e3,
            baseline.latency.p95() * 1e3,
            report.resplits,
            baseline.resplits,
        );
        // A mobility bench in which nobody moves is a silent
        // misconfiguration, not a perf number.
        assert!(report.handovers > 0, "no handovers in the mobile city");
        assert!(report.migration_replans > 0, "handovers produced no migration re-solves");
        assert_eq!(baseline.handovers, 0, "the frozen baseline must not move");
        runs.push(Json::obj(vec![
            ("devices", Json::Num(devices as f64)),
            ("edge_sites", Json::Num(sites as f64)),
            ("virtual_s", Json::Num(duration_s)),
            ("events", Json::Num(report.events as f64)),
            ("events_per_sec", Json::Num(report.events_per_wall_second())),
            ("completed", Json::Num(report.completed as f64)),
            ("handovers", Json::Num(report.handovers as f64)),
            (
                "handovers_per_virtual_sec",
                Json::Num(report.handovers as f64 / duration_s),
            ),
            ("migration_replans", Json::Num(report.migration_replans as f64)),
            ("migration_requests", Json::Num(migration_requests as f64)),
            ("planner_requests", Json::Num(request_total as f64)),
            ("planner_solves", Json::Num(report.planner.solves as f64)),
            ("cache_hit_rate", Json::Num(report.planner.hit_rate())),
            ("latency_p95_s", Json::Num(report.latency.p95())),
            ("static_latency_p95_s", Json::Num(baseline.latency.p95())),
            ("decisions_per_sec", Json::Num(report.decision_count as f64 / wall_s)),
        ]));
    }

    let json = Json::obj(vec![
        ("bench", Json::str("mobility_scale")),
        ("smoke", Json::Bool(smoke)),
        ("scenario", Json::str("city_mobile")),
        ("model", Json::str("alexnet")),
        ("runs", Json::Arr(runs)),
    ]);
    // Tracked at the repo root (next to BENCH_planner.json /
    // BENCH_edge.json) so the perf trajectory is versioned;
    // CARGO_MANIFEST_DIR keeps the location stable however cargo was
    // invoked.
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_mobility.json");
    std::fs::write(&out, json.to_string_pretty())?;
    println!("\nwrote {}", std::fs::canonicalize(&out)?.display());
    Ok(())
}
