//! Fleet-planner throughput: the numbers behind the planner-layer perf
//! claim, measured through the planning façade (`planner::Planner`) —
//! the API every consumer now plans with.
//!
//! Measures a 10k-device re-optimisation tick three ways —
//!
//! * **baseline** — the pre-cache path: one sequential, uncached
//!   NSGA-II solve per device at the canonical 100×250 budget (measured
//!   on a subsample, extrapolated to the fleet);
//! * **tiny-uncached** — sequential and uncached, but with the
//!   [`Nsga2Params::for_tiny_genome`] preset (isolates the solver-budget
//!   win from the cache win);
//! * **optimized** — the shipped path: 25%-bucket plan-key quantisation,
//!   the façade's sharded plan cache, distinct cache misses fanned out
//!   over a [`ThreadPool`] (cold tick), then the all-hit steady state
//!   (warm tick);
//!
//! plus an allocation profile of the NSGA-II hot path (a reused
//! [`Nsga2Solver`] must not allocate per generation). Results go to
//! stdout and `BENCH_planner.json`. `--smoke` shrinks the fleet for CI;
//! the ≥10× speedup gate is asserted in both modes.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use smartsplit::bench::black_box;
use smartsplit::coordinator::battery::BatteryBand;
use smartsplit::device::{profiles, ComputeProfile};
use smartsplit::models::{zoo, ModelProfile};
use smartsplit::optimizer::{member_perf_model, Nsga2Params, Nsga2Solver, SplitProblem};
use smartsplit::planner::{PlanRequest, Planner, PlannerConfig, Strategy};
use smartsplit::util::json::Json;
use smartsplit::util::pool::ThreadPool;
use smartsplit::util::rng::Xoshiro256;

/// Counting wrapper around the system allocator: the cheapest honest way
/// to assert "allocation-free per generation".
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: same contract as the caller's; we only count.
        unsafe { System.alloc(layout) }
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        // SAFETY: same contract as the caller's; we only count.
        unsafe { System.dealloc(ptr, layout) }
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: same contract as the caller's; we only count.
        unsafe { System.realloc(ptr, layout, new_size) }
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// One synthetic device's quantisable planner inputs.
type DeviceState = (&'static ComputeProfile, f64, BatteryBand);

fn synth_fleet(n: usize, seed: u64) -> Vec<DeviceState> {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let profs = [profiles::samsung_j6(), profiles::redmi_note8()];
    let bands = [BatteryBand::Comfort, BatteryBand::Saver, BatteryBand::Critical];
    (0..n)
        .map(|i| {
            let bw = 2.0 + 58.0 * rng.next_f64();
            (profs[i % 2], bw, bands[rng.gen_range(0, 2)])
        })
        .collect()
}

/// The façade requests for a fleet of device states.
fn requests_of(states: &[DeviceState], model: &Arc<ModelProfile>) -> Vec<PlanRequest> {
    states
        .iter()
        .map(|&(p, bw, band)| {
            PlanRequest::two_tier(Arc::clone(model), p, band, bw, Strategy::SmartSplit)
        })
        .collect()
}

/// Sequential pass through an uncached planner (the pre-cache shape).
/// Uses the decision-only fast path — the fleet hot paths never pay
/// for outcome assembly, so neither do the measurements.
fn sequential_tick(planner: &Planner, requests: &[PlanRequest]) -> Duration {
    let t0 = Instant::now();
    for r in requests {
        black_box(planner.split(r));
    }
    t0.elapsed()
}

/// The shipped re-optimisation tick, exactly as `sim::on_reoptimize`
/// runs it: quantise → presolve the distinct cache misses over the
/// pool → serve every device through the counted cache path.
/// Returns (wall, solves actually run this tick).
fn cached_parallel_tick(
    planner: &Planner,
    requests: &[PlanRequest],
    pool: &ThreadPool,
) -> (Duration, u64) {
    let solves_before = planner.stats().solves;
    let t0 = Instant::now();
    let mut presolved = planner.presolve_batch(pool, requests);
    // Apply phase: every device is served through the counted cache path
    // (presolve results feed the solve closure, so accounting matches a
    // sequential pass).
    for r in requests {
        black_box(planner.split_with(r, &mut presolved));
    }
    (t0.elapsed(), planner.stats().solves - solves_before)
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let devices: usize = if smoke { 2_000 } else { 10_000 };
    let baseline_sample: usize = if smoke { 8 } else { 64 };

    let model = Arc::new(zoo::vgg16().analyze(1));
    let canonical = Nsga2Params::default();
    let tiny = Nsga2Params::for_tiny_genome();

    // ---- NSGA-II hot-path allocation profile (single-threaded, before
    // any pool exists so the counter sees only the solver).
    println!("== planner_throughput: NSGA-II allocation profile (vgg16) ==");
    let pm = member_perf_model(profiles::samsung_j6(), &model, 10.0);
    let problem = SplitProblem::new(&pm);
    let mut solver = Nsga2Solver::new();
    let gens = |g: usize| Nsga2Params {
        pop_size: 40,
        generations: g,
        stagnation_patience: 0,
        ..Default::default()
    };
    // Warm the solver's buffers at the larger shape first.
    black_box(solver.solve(&problem, &gens(1_000)));
    black_box(solver.solve(&problem, &gens(100)));
    let a0 = allocs();
    black_box(solver.solve(&problem, &gens(100)));
    let short = allocs() - a0;
    let a1 = allocs();
    black_box(solver.solve(&problem, &gens(1_000)));
    let long = allocs() - a1;
    // 900 extra generations; any per-generation allocation would show up
    // 900-fold. The residual difference is result-assembly noise.
    let per_gen = (long as f64 - short as f64) / 900.0;
    let alloc_free = per_gen < 0.5;
    println!(
        "  allocs: {short} @ 100 gens, {long} @ 1000 gens → {per_gen:.4}/generation \
         (alloc-free hot path: {alloc_free})"
    );
    assert!(
        alloc_free,
        "NSGA-II generation loop allocates ({per_gen:.3} allocations/generation)"
    );

    // ---- Fleet tick.
    println!("\n== planner_throughput: {devices}-device reoptimize tick ==");
    let states = synth_fleet(devices, 7);
    let requests = requests_of(&states, &model);

    // Pre-cache baseline: sequential, uncached, canonical budget
    // (subsample, extrapolated — the full fleet would take minutes by
    // construction).
    let baseline_planner = Planner::new(
        PlannerConfig::fleet(canonical.clone(), canonical.seed).with_cache(false),
    );
    let sample = &requests[..baseline_sample.min(requests.len())];
    let base_wall = sequential_tick(&baseline_planner, sample);
    let base_per_solve = base_wall.as_secs_f64() / sample.len() as f64;
    let base_tick_s = base_per_solve * devices as f64;
    println!(
        "  baseline   : {:.2} ms/solve sequential ×{} devices → {:.1} s/tick (extrapolated from {})",
        base_per_solve * 1e3, devices, base_tick_s, sample.len()
    );

    // Solver-budget win alone (still sequential + uncached).
    let tiny_planner =
        Planner::new(PlannerConfig::fleet(tiny.clone(), tiny.seed).with_cache(false));
    let tiny_sample = &requests[..(baseline_sample * 4).min(requests.len())];
    let tiny_wall = sequential_tick(&tiny_planner, tiny_sample);
    let tiny_per_solve = tiny_wall.as_secs_f64() / tiny_sample.len() as f64;
    let tiny_tick_s = tiny_per_solve * devices as f64;
    println!(
        "  tiny-uncach: {:.3} ms/solve sequential → {:.2} s/tick (extrapolated from {})",
        tiny_per_solve * 1e3, tiny_tick_s, tiny_sample.len()
    );

    // The shipped path: cold tick (parallel cache fill) then warm tick.
    let planner = Planner::new(
        PlannerConfig::fleet(tiny.clone(), tiny.seed).with_bucket_ratio(1.25),
    );
    let pool = ThreadPool::new(ThreadPool::default_threads(16));
    let (cold, cold_solves) = cached_parallel_tick(&planner, &requests, &pool);
    let (warm, warm_solves) = cached_parallel_tick(&planner, &requests, &pool);
    let stats = planner.stats();
    let hit_rate = stats.hit_rate();
    println!(
        "  optimized  : cold tick {:?} ({} parallel solves for {} devices), warm tick {:?} ({} solves)",
        cold, cold_solves, devices, warm, warm_solves
    );
    println!(
        "  cache      : {} distinct planner states, {:.1}% hit rate over both ticks",
        cold_solves, hit_rate * 100.0
    );

    let cold_s = cold.as_secs_f64().max(1e-9);
    let warm_s = warm.as_secs_f64().max(1e-9);
    let speedup_cold = base_tick_s / cold_s;
    let speedup_warm = base_tick_s / warm_s;
    let decisions_per_sec = devices as f64 / cold_s;
    println!(
        "  speedup    : {speedup_cold:.0}× cold, {speedup_warm:.0}× warm vs pre-PR sequential/uncached \
         ({decisions_per_sec:.0} decisions/s cold)"
    );
    assert!(warm_solves == 0, "warm tick must be all cache hits");
    assert!(
        speedup_cold >= 10.0,
        "acceptance gate: cold-tick speedup {speedup_cold:.1}× < 10× vs uncached sequential"
    );

    // ---- Hit rate over time: a windowed city run through the full sim
    // (the TimeSeries collector of DESIGN.md §12), so the JSON records
    // how fast the plan cache converges to steady state, not just the
    // end-of-run average.
    println!("\n== planner_throughput: cache hit rate over time (city sim) ==");
    let (ts_devices, ts_duration) = if smoke { (1_000, 60.0) } else { (5_000, 120.0) };
    let mut ts_cfg = smartsplit::sim::city_scale("alexnet", ts_devices, ts_duration, 7);
    ts_cfg.observability.window_s = ts_duration / 12.0;
    let ts_report = smartsplit::sim::run(&ts_cfg)?;
    let series = ts_report
        .series
        .expect("windowed run must produce a time series");
    let curve = series.hit_rate_curve();
    let curve_str: Vec<String> = curve.iter().map(|h| format!("{:.3}", h)).collect();
    println!(
        "  {} windows of {:.1}s over {} devices: [{}]",
        curve.len(),
        series.window_s,
        ts_devices,
        curve_str.join(", ")
    );

    // ---- BENCH_planner.json for the CI perf trajectory.
    let json = Json::obj(vec![
        ("bench", Json::str("planner_throughput")),
        ("smoke", Json::Bool(smoke)),
        ("devices", Json::Num(devices as f64)),
        (
            "baseline",
            Json::obj(vec![
                ("mode", Json::str("sequential_uncached_canonical_100x250")),
                ("sampled_devices", Json::Num(sample.len() as f64)),
                ("per_solve_s", Json::Num(base_per_solve)),
                ("extrapolated_tick_s", Json::Num(base_tick_s)),
                ("solves_per_sec", Json::Num(1.0 / base_per_solve.max(1e-12))),
            ]),
        ),
        (
            "tiny_uncached",
            Json::obj(vec![
                ("mode", Json::str("sequential_uncached_tiny_genome")),
                ("per_solve_s", Json::Num(tiny_per_solve)),
                ("extrapolated_tick_s", Json::Num(tiny_tick_s)),
            ]),
        ),
        (
            "optimized",
            Json::obj(vec![
                ("mode", Json::str("quantized_cached_parallel")),
                ("cold_tick_s", Json::Num(cold_s)),
                ("warm_tick_s", Json::Num(warm_s)),
                ("distinct_solves", Json::Num(cold_solves as f64)),
                ("cache_hit_rate", Json::Num(hit_rate)),
                ("decisions_per_sec_cold", Json::Num(decisions_per_sec)),
                ("decisions_per_sec_warm", Json::Num(devices as f64 / warm_s)),
            ]),
        ),
        ("speedup_cold", Json::Num(speedup_cold)),
        ("speedup_warm", Json::Num(speedup_warm)),
        (
            "alloc",
            Json::obj(vec![
                ("allocs_per_generation", Json::Num(per_gen)),
                ("alloc_free_hot_path", Json::Bool(alloc_free)),
            ]),
        ),
        (
            "hit_rate_over_time",
            Json::obj(vec![
                ("sim_devices", Json::Num(ts_devices as f64)),
                ("sim_duration_s", Json::Num(ts_duration)),
                ("window_s", Json::Num(series.window_s)),
                ("curve", Json::arr_f64(&curve)),
            ]),
        ),
    ]);
    // Tracked at the repo root (next to BENCH_edge.json) so the perf
    // trajectory is versioned; CARGO_MANIFEST_DIR keeps the location
    // stable however cargo was invoked.
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_planner.json");
    std::fs::write(&out, json.to_string_pretty())?;
    println!("\nwrote {}", std::fs::canonicalize(&out)?.display());
    Ok(())
}
