//! §Scale: faulty-city throughput and the cost of failure.
//!
//! Runs the `city_faulty` scenario (the tiered city under the scripted
//! outage + brownout + flash-crowd schedule) and records the numbers
//! the CI perf trajectory tracks in `BENCH_faults.json`: events/sec
//! through the handover storm, forced reattaches and cloud reroutes
//! (count and per virtual second), failover re-solves and their share
//! of planner requests, and the p95 latency tax relative to the same
//! city with the fault plan cleared. `--smoke` shrinks the fleet for
//! CI.

use smartsplit::bench::{black_box, Bench};
use smartsplit::sim::{self, FaultPlan};
use smartsplit::util::json::Json;

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    // (devices, sites, virtual seconds, bench iters, warmup)
    let sizes: Vec<(usize, usize, f64, usize, usize)> = if smoke {
        vec![(2_000, 4, 120.0, 2, 1)]
    } else {
        vec![(2_000, 4, 300.0, 3, 1), (10_000, 8, 120.0, 3, 1), (50_000, 16, 60.0, 2, 0)]
    };
    println!("== fault_scale: city-faulty scenario, alexnet, seed 7 ==");

    let mut runs = Vec::new();
    for (devices, sites, duration_s, iters, warmup) in sizes {
        let cfg = sim::city_faulty("alexnet", devices, sites, duration_s, 7);
        Bench::new(&format!(
            "simulate {devices} devices / {sites} edge sites / {duration_s:.0}s virtual \
             under {} fault(s)",
            cfg.faults.events.len()
        ))
        .iters(iters)
        .warmup(warmup)
        .run(|| {
            black_box(sim::run(&cfg).expect("sim run"));
        });
        let report = sim::run(&cfg)?;
        // The failure tax: the identical city with the plan cleared.
        let mut calm = cfg.clone();
        calm.faults = FaultPlan::none();
        let baseline = sim::run(&calm)?;

        let wall_s = report.wall.as_secs_f64().max(1e-9);
        let failovers = report.failover_reattaches + report.requests_rerouted;
        let failover_requests = report.planner.failover_requests();
        let request_total: u64 = report.planner.requests_by_reason.iter().sum();
        println!(
            "    {:>6} devices: {:>9} events in {:?} → {:>12.0} events/s, \
             {} forced reattaches + {} reroutes ({:.2} failovers/virtual-s), \
             {} failover re-plans ({:.1}% of planner requests)",
            devices,
            report.events,
            report.wall,
            report.events_per_wall_second(),
            report.failover_reattaches,
            report.requests_rerouted,
            failovers as f64 / duration_s,
            report.failover_replans,
            100.0 * failover_requests as f64 / request_total.max(1) as f64,
        );
        println!(
            "    {:>6}         p95 latency {:.2} ms faulty vs {:.2} ms calm \
             ({} vs {} dropped)",
            "",
            report.latency.p95() * 1e3,
            baseline.latency.p95() * 1e3,
            report.dropped,
            baseline.dropped,
        );
        // A fault bench in which nothing breaks is a silent
        // misconfiguration, not a perf number — and conservation is
        // non-negotiable even in a benchmark.
        assert!(report.fault_events > 0, "the fault schedule never fired");
        assert!(report.failover_reattaches > 0, "the outage stormed nobody");
        assert_eq!(report.generated, report.completed + report.dropped, "requests leaked");
        assert_eq!(baseline.fault_events, 0, "the calm baseline must not fault");
        runs.push(Json::obj(vec![
            ("devices", Json::Num(devices as f64)),
            ("edge_sites", Json::Num(sites as f64)),
            ("virtual_s", Json::Num(duration_s)),
            ("events", Json::Num(report.events as f64)),
            ("events_per_sec", Json::Num(report.events_per_wall_second())),
            ("completed", Json::Num(report.completed as f64)),
            ("dropped", Json::Num(report.dropped as f64)),
            ("fault_events", Json::Num(report.fault_events as f64)),
            ("failover_reattaches", Json::Num(report.failover_reattaches as f64)),
            ("requests_rerouted", Json::Num(report.requests_rerouted as f64)),
            ("failovers_per_virtual_sec", Json::Num(failovers as f64 / duration_s)),
            ("failover_replans", Json::Num(report.failover_replans as f64)),
            ("failover_requests", Json::Num(failover_requests as f64)),
            ("planner_requests", Json::Num(request_total as f64)),
            ("cache_hit_rate", Json::Num(report.planner.hit_rate())),
            ("latency_p95_s", Json::Num(report.latency.p95())),
            ("calm_latency_p95_s", Json::Num(baseline.latency.p95())),
            ("decisions_per_sec", Json::Num(report.decision_count as f64 / wall_s)),
        ]));
    }

    let json = Json::obj(vec![
        ("bench", Json::str("fault_scale")),
        ("smoke", Json::Bool(smoke)),
        ("scenario", Json::str("city_faulty")),
        ("model", Json::str("alexnet")),
        ("runs", Json::Arr(runs)),
    ]);
    // Tracked at the repo root (next to BENCH_planner.json /
    // BENCH_mobility.json) so the perf trajectory is versioned;
    // CARGO_MANIFEST_DIR keeps the location stable however cargo was
    // invoked.
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_faults.json");
    std::fs::write(&out, json.to_string_pretty())?;
    println!("\nwrote {}", std::fs::canonicalize(&out)?.display());
    Ok(())
}
