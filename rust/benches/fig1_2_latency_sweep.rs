//! Fig. 1 / Fig. 2 — latency vs CNN split index, four models × two phones.
//!
//! Paper shape: Upload Latency is the primary contributor to total latency;
//! Client Latency grows with the split index; Cloud Server Latency varies
//! little. Regenerate with `cargo bench --bench fig1_2_latency_sweep`.

use std::collections::BTreeMap;

use smartsplit::bench::Table;
use smartsplit::device::profiles;
use smartsplit::figures::{dump_json, latency_sweep, series_json, MODELS};

fn main() -> anyhow::Result<()> {
    let bandwidth = 10.0;
    for (fig, phone) in [("fig1", profiles::samsung_j6()), ("fig2", profiles::redmi_note8())] {
        println!("\n== {} — latency vs split index on {} (B = {bandwidth} Mbps) ==",
                 if fig == "fig1" { "Figure 1" } else { "Figure 2" }, phone.name);
        let mut series = BTreeMap::new();
        for model in MODELS {
            let sweep = latency_sweep(model, phone, bandwidth)?;
            let mut t = Table::new(&["l1", "client (s)", "upload (s)", "server (s)", "total (s)"]);
            for (l1, b) in &sweep {
                t.row(&[
                    l1.to_string(),
                    format!("{:.4}", b.client_s),
                    format!("{:.4}", b.upload_s),
                    format!("{:.4}", b.server_s),
                    format!("{:.4}", b.total()),
                ]);
            }
            println!("\n-- {model} --");
            t.print();
            type Get = fn(&smartsplit::perfmodel::LatencyBreakdown) -> f64;
            for (key, f) in [
                ("client", (|b: &smartsplit::perfmodel::LatencyBreakdown| b.client_s) as Get),
                ("upload", |b: &smartsplit::perfmodel::LatencyBreakdown| b.upload_s),
                ("server", |b: &smartsplit::perfmodel::LatencyBreakdown| b.server_s),
                ("total", |b: &smartsplit::perfmodel::LatencyBreakdown| b.total()),
            ] {
                series.insert(
                    format!("{model}/{key}"),
                    sweep.iter().map(|(l1, b)| (*l1 as f64, f(b))).collect(),
                );
            }
        }
        let path = dump_json(fig, &series_json(&series))?;
        println!("\nwrote {}", path.display());
    }
    Ok(())
}
