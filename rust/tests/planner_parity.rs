//! Migration parity: the planning façade reproduces the pre-redesign
//! entry points byte-for-byte.
//!
//! Two layers of pinning:
//! * direct — `planner::Planner` vs the frozen deprecated free
//!   functions (`solve_plan`, `solve_plan_tiered`, `decide`) and the
//!   scalarisation primitives, across the full (profile × band ×
//!   bandwidth × strategy) lattice, flat and tiered;
//! * end-to-end — `SimReport::decisions` streams of flat and tiered
//!   `city_scale`-style runs equal the decision stream the pre-redesign
//!   sim produced (replicated here from the frozen entry points with
//!   the same quantisation, keys, and key-derived seeds).
#![allow(deprecated)] // the frozen entry points are the parity references

use std::sync::Arc;

use smartsplit::coordinator::battery::BatteryBand;
use smartsplit::device::profiles;
use smartsplit::edge::{BackhaulLink, EdgeSite, SplitPlan, TieredPerfModel};
use smartsplit::models::zoo;
use smartsplit::models::ModelProfile;
use smartsplit::optimizer::{
    decide, epsilon_constrained, member_perf_model, model_cache_id, quantize_bandwidth,
    solve_plan, solve_plan_tiered, weighted_metric, weighted_sum, Nsga2Params, PlanKey,
    PlannerKind, TierKey,
};
use smartsplit::planner::{PlanRequest, Planner, PlannerConfig, Strategy};
use smartsplit::sim::{self, ExplicitMember, FleetSpec, PlannerPerfConfig};
use smartsplit::util::rng::Xoshiro256;
use smartsplit::workload::Arrival;

const BANDS: [BatteryBand; 3] =
    [BatteryBand::Comfort, BatteryBand::Saver, BatteryBand::Critical];

fn model() -> Arc<ModelProfile> {
    Arc::new(zoo::alexnet().analyze(1))
}

#[test]
fn facade_matches_frozen_flat_entry_points() {
    // Every (profile × band × bandwidth) state, both classic kinds:
    // the façade's decision equals the deprecated solve_plan's with the
    // identical key-derived seed.
    let model = model();
    let model_id = model_cache_id(&model);
    let params = Nsga2Params::for_tiny_genome();
    let planner = Planner::new(PlannerConfig::fleet(params.clone(), params.seed));
    for profile in [profiles::samsung_j6(), profiles::redmi_note8()] {
        for band in BANDS {
            for bw in [2.0, 10.0, 30.0, 60.0] {
                for (strategy, kind) in [
                    (Strategy::SmartSplit, PlannerKind::SmartSplit),
                    (Strategy::Topsis, PlannerKind::Topsis),
                ] {
                    let req = PlanRequest::two_tier(
                        Arc::clone(&model),
                        profile,
                        band,
                        bw,
                        strategy,
                    );
                    // The façade's key must equal the hand-built one the
                    // pre-redesign consumers constructed.
                    let key = PlanKey::new(model_id, profile, band, bw, kind);
                    assert_eq!(planner.key(&req), key);
                    let pm = member_perf_model(profile, &model, bw);
                    let frozen =
                        solve_plan(kind, &pm, band, &params, key.derived_seed(params.seed));
                    let got = planner.plan(&req);
                    assert_eq!(
                        got.plan, frozen,
                        "{} {:?} @ {bw} Mbps diverged from solve_plan",
                        profile.name, band
                    );
                    assert_eq!(got.provenance.derived_seed, key.derived_seed(params.seed));
                }
            }
        }
    }
}

#[test]
fn facade_matches_frozen_tiered_entry_points() {
    // Same lattice under an edge site, with the city-scale 25% bucket
    // ratio applied to device and backhaul links exactly as the
    // pre-redesign sim did.
    let model = model();
    let model_id = model_cache_id(&model);
    let params = Nsga2Params::for_small_genome(2);
    let ratio = 1.25;
    let planner = Planner::new(
        PlannerConfig::fleet(params.clone(), params.seed).with_bucket_ratio(ratio),
    );
    let site = EdgeSite {
        servers: 2,
        profile: profiles::edge_server(),
        backhaul: BackhaulLink::METRO_1GBE,
    };
    for profile in [profiles::samsung_j6(), profiles::redmi_note8()] {
        for band in BANDS {
            for bw in [5.0, 30.0] {
                for (strategy, kind) in [
                    (Strategy::SmartSplit, PlannerKind::SmartSplit),
                    (Strategy::Topsis, PlannerKind::Topsis),
                ] {
                    let req = PlanRequest::two_tier(
                        Arc::clone(&model),
                        profile,
                        band,
                        bw,
                        strategy,
                    )
                    .with_tier(1, site);
                    let bw_q = quantize_bandwidth(bw, ratio);
                    let backhaul_q = quantize_bandwidth(site.backhaul.bandwidth_mbps, ratio);
                    let key = PlanKey::new(model_id, profile, band, bw_q, kind)
                        .with_tier(TierKey::new(1, &site, backhaul_q));
                    assert_eq!(planner.key(&req), key);
                    let pm = member_perf_model(profile, &model, bw_q);
                    let tpm = TieredPerfModel::new(
                        pm,
                        site.profile,
                        site.servers,
                        BackhaulLink {
                            bandwidth_mbps: backhaul_q,
                            latency_s: site.backhaul.latency_s,
                        },
                    );
                    let frozen = solve_plan_tiered(
                        kind,
                        &tpm,
                        band,
                        &params,
                        key.derived_seed(params.seed),
                    );
                    let got = planner.plan(&req);
                    assert_eq!(
                        got.plan, frozen,
                        "{} {:?} @ {bw} Mbps diverged from solve_plan_tiered",
                        profile.name, band
                    );
                }
            }
        }
    }
}

#[test]
fn facade_matches_frozen_baselines_and_scalarisations() {
    // Paper-mode planner (configured seed as-is, no cache) vs the
    // frozen §VI-C dispatch and the §V-A scalarisation primitives.
    let model = model();
    let params = Nsga2Params { pop_size: 40, generations: 40, ..Default::default() };
    let planner = Planner::new(PlannerConfig::paper(params.clone()));
    for profile in [profiles::samsung_j6(), profiles::redmi_note8()] {
        for bw in [2.0, 10.0, 60.0] {
            let pm = member_perf_model(profile, &model, bw);
            let req = |s| {
                PlanRequest::two_tier(
                    Arc::clone(&model),
                    profile,
                    BatteryBand::Comfort,
                    bw,
                    s,
                )
            };
            for algo in smartsplit::optimizer::Algorithm::ALL {
                // decide() draws RS from the passed rng; a fresh rng per
                // algorithm reproduces the façade's seed-from-base draw.
                let mut rng = Xoshiro256::seed_from_u64(params.seed);
                let frozen = decide(algo, &pm, &params, &mut rng);
                let got = planner.plan(&req(Strategy::from(algo)));
                assert_eq!(
                    got.plan,
                    Some(SplitPlan::two_tier(frozen.l1)),
                    "{} {:?} @ {bw} Mbps diverged from decide()",
                    profile.name,
                    algo
                );
            }
            assert_eq!(
                planner.plan(&req(Strategy::WeightedSum)).plan,
                weighted_sum(&pm, Strategy::SCALAR_WEIGHTS).map(SplitPlan::two_tier),
            );
            assert_eq!(
                planner.plan(&req(Strategy::WeightedMetric)).plan,
                weighted_metric(&pm, Strategy::SCALAR_WEIGHTS, Strategy::METRIC_ORDER)
                    .map(SplitPlan::two_tier),
            );
            assert_eq!(
                planner.plan(&req(Strategy::EpsilonConstrained)).plan,
                epsilon_constrained(
                    &pm,
                    Strategy::EPSILON_PRIMARY,
                    Strategy::EPSILON_CEILINGS
                )
                .map(SplitPlan::two_tier),
            );
        }
    }
}

/// An explicit fleet hitting every battery band on two profiles at
/// three bandwidths — the deterministic "all bands" lattice the sim
/// stream tests replay (Explicit members consume no RNG at spawn, so
/// the expected stream is exactly computable).
fn band_lattice_members() -> Vec<ExplicitMember> {
    let mut members = Vec::new();
    for &(profile, bw) in &[
        (profiles::samsung_j6(), 10.0),
        (profiles::redmi_note8(), 30.0),
        (profiles::samsung_j6(), 3.0),
    ] {
        for soc in [1.0, 0.4, 0.1] {
            members.push(ExplicitMember {
                profile,
                bandwidth_mbps: bw,
                initial_soc: soc,
            });
        }
    }
    members
}

fn stream_config(planner: sim::Planner, seed: u64) -> sim::SimConfig {
    // Built from the two-phone preset so fields this test doesn't care
    // about (mobility, observability, faults, shards, …) track their
    // scenario defaults instead of breaking an exhaustive literal each
    // time SimConfig grows; everything the expected spawn stream
    // depends on is overridden below.
    let mut cfg = sim::two_phone_fleet("alexnet", 10.0, Nsga2Params::for_tiny_genome(), seed);
    cfg.duration_s = 30.0;
    cfg.arrival = Arrival::Poisson { rps: 2.0 };
    cfg.cloud_servers = 4;
    cfg.planner = planner;
    // Spawn decisions only: no sweeps, no churn — the expected
    // stream is the per-member frozen solve in member order.
    cfg.reopt_period_s = 0.0;
    cfg.fleet = FleetSpec::Explicit(band_lattice_members());
    cfg.planner_perf = PlannerPerfConfig {
        cache: true,
        parallel: true,
        bw_bucket_ratio: 1.25,
        record_decisions: true,
    };
    cfg.handover_cost_s = 0.0;
    cfg
}

fn spawn_stream(cfg: &sim::SimConfig) -> Vec<(u32, u32, u32)> {
    let report = sim::run(cfg).expect("sim run");
    let n = band_lattice_members().len();
    assert!(report.decisions.len() >= n, "missing spawn decisions");
    // Re-plans can only *append* after the n spawn entries (battery
    // drain during the run); the first n are the spawns in member order.
    report.decisions[..n].to_vec()
}

#[test]
fn sim_flat_spawn_stream_matches_pre_redesign_path() {
    // Both classic sim planners, every battery band: the façade-driven
    // sim's decision stream equals the frozen solve_plan pipeline
    // (quantise → key → derived seed → solve) the pre-redesign sim ran.
    let model = model();
    let model_id = model_cache_id(&model);
    let tiny = Nsga2Params { seed: 9, ..Nsga2Params::for_tiny_genome() };
    for (planner_cfg, kind, params, base_seed) in [
        (sim::Planner::Topsis, PlannerKind::Topsis, Nsga2Params::for_tiny_genome(), 9u64),
        (sim::Planner::SmartSplit(tiny.clone()), PlannerKind::SmartSplit, tiny.clone(), 9u64),
    ] {
        let cfg = stream_config(planner_cfg, 9);
        let stream = spawn_stream(&cfg);
        for (i, m) in band_lattice_members().iter().enumerate() {
            let band = BatteryBand::of_fraction(m.initial_soc);
            let bw_q = quantize_bandwidth(m.bandwidth_mbps, 1.25);
            let key = PlanKey::new(model_id, m.profile, band, bw_q, kind);
            let pm = member_perf_model(m.profile, &model, bw_q);
            let expected =
                solve_plan(kind, &pm, band, &params, key.derived_seed(base_seed))
                    .expect("frozen path found no split");
            assert_eq!(
                stream[i],
                (i as u32, expected.l1 as u32, expected.l2 as u32),
                "{kind:?}: member {i} diverged from the pre-redesign stream"
            );
        }
    }
}

#[test]
fn sim_tiered_spawn_stream_matches_pre_redesign_path() {
    // The tiered city path: same lattice behind two relay sites, 2-D
    // solves against the assigned site with bucketed backhaul.
    let model = model();
    let model_id = model_cache_id(&model);
    let small = Nsga2Params { seed: 5, ..Nsga2Params::for_small_genome(2) };
    for (planner_cfg, kind, params, base_seed) in [
        (sim::Planner::Topsis, PlannerKind::Topsis, Nsga2Params::for_tiny_genome(), 5u64),
        (sim::Planner::SmartSplit(small.clone()), PlannerKind::SmartSplit, small.clone(), 5u64),
    ] {
        let mut cfg = stream_config(planner_cfg, 5);
        cfg.edge = Some(sim::EdgeSpec::uniform(2, 2, 1000.0));
        let topo = cfg.edge.as_ref().unwrap().topology();
        let stream = spawn_stream(&cfg);
        for (i, m) in band_lattice_members().iter().enumerate() {
            let band = BatteryBand::of_fraction(m.initial_soc);
            let bw_q = quantize_bandwidth(m.bandwidth_mbps, 1.25);
            let site_idx = topo.site_of(i);
            let site = topo.sites[site_idx];
            let backhaul_q = quantize_bandwidth(site.backhaul.bandwidth_mbps, 1.25);
            let key = PlanKey::new(model_id, m.profile, band, bw_q, kind)
                .with_tier(TierKey::new(site_idx, &site, backhaul_q));
            let pm = member_perf_model(m.profile, &model, bw_q);
            let tpm = TieredPerfModel::new(
                pm,
                site.profile,
                site.servers,
                BackhaulLink { bandwidth_mbps: backhaul_q, latency_s: site.backhaul.latency_s },
            );
            let expected =
                solve_plan_tiered(kind, &tpm, band, &params, key.derived_seed(base_seed))
                    .expect("frozen tiered path found no split");
            assert_eq!(
                stream[i],
                (i as u32, expected.l1 as u32, expected.l2 as u32),
                "{kind:?}: tiered member {i} diverged from the pre-redesign stream"
            );
        }
    }
}

#[test]
fn sim_custom_strategy_streams_match_frozen_primitives() {
    // The strategies the sim could never run before the façade: their
    // spawn decisions equal the frozen §VI-C / §V-A primitives at the
    // same quantised state.
    let model = model();
    let model_id = model_cache_id(&model);
    for strategy in [
        Strategy::Lbo,
        Strategy::Ebo,
        Strategy::Cos,
        Strategy::Rs,
        Strategy::WeightedSum,
    ] {
        let cfg = stream_config(sim::Planner::Custom(strategy), 3);
        let stream = spawn_stream(&cfg);
        for (i, m) in band_lattice_members().iter().enumerate() {
            let band = BatteryBand::of_fraction(m.initial_soc);
            let bw_q = quantize_bandwidth(m.bandwidth_mbps, 1.25);
            let pm = member_perf_model(m.profile, &model, bw_q);
            let expected_l1 = match strategy {
                Strategy::Lbo => smartsplit::optimizer::lbo(&pm).l1,
                Strategy::Ebo => smartsplit::optimizer::ebo(&pm).l1,
                Strategy::Cos => smartsplit::optimizer::cos(&pm).l1,
                Strategy::Rs => {
                    let key =
                        PlanKey::new(model_id, m.profile, band, bw_q, strategy.kind());
                    let mut rng = Xoshiro256::seed_from_u64(key.derived_seed(3));
                    smartsplit::optimizer::rs(&pm, &mut rng).l1
                }
                Strategy::WeightedSum => {
                    weighted_sum(&pm, Strategy::SCALAR_WEIGHTS).expect("feasible domain")
                }
                _ => unreachable!(),
            };
            assert_eq!(
                stream[i],
                (i as u32, expected_l1 as u32, expected_l1 as u32),
                "{}: member {i} diverged from the frozen primitive",
                strategy.name()
            );
        }
    }
}
