//! Fleet-simulator integration: (a) bit-for-bit determinism under a fixed
//! seed, (b) live-path parity — a simulated two-phone fleet must agree
//! with the analytical (`PerfModel`) end-to-end latency that the live
//! `coordinator::fleet` path plans with, within 5%.

use smartsplit::device::profiles;
use smartsplit::models::zoo;
use smartsplit::optimizer::{smartsplit, Nsga2Params};
use smartsplit::perfmodel::{NetworkEnv, PerfModel};
use smartsplit::sim::{self, Planner};
use smartsplit::workload::Arrival;

fn fast_nsga2(seed: u64) -> Nsga2Params {
    Nsga2Params { pop_size: 40, generations: 40, seed, ..Default::default() }
}

#[test]
fn city_scale_runs_are_bit_identical_under_one_seed() {
    let cfg = sim::city_scale("alexnet", 1500, 120.0, 42);
    let a = sim::run(&cfg).expect("sim run a");
    let b = sim::run(&cfg).expect("sim run b");
    assert_eq!(a.summary(), b.summary());
    assert_eq!(a.events, b.events);
    assert_eq!(a.generated, b.generated);
    assert_eq!(a.devices_created, b.devices_created);
    assert_eq!(a.split_distribution, b.split_distribution);
    // And the run actually did city-scale things.
    assert!(a.completed > 1000, "only {} completed", a.completed);
    assert!(a.devices_created >= 1500);
    assert!(a.latency.count() == a.completed);
}

#[test]
fn different_seeds_diverge() {
    let mut cfg = sim::city_scale("alexnet", 300, 60.0, 1);
    let a = sim::run(&cfg).expect("sim run");
    cfg.seed = 2;
    let b = sim::run(&cfg).expect("sim run");
    assert_ne!(a.summary(), b.summary());
}

#[test]
fn request_conservation_holds() {
    let cfg = sim::city_scale("alexnet", 400, 90.0, 11);
    let r = sim::run(&cfg).expect("sim run");
    // Every generated request either completed or was dropped by the time
    // the queue drained.
    assert_eq!(r.generated, r.completed + r.dropped);
    assert_eq!(r.devices_created as u64, 400 + r.joined);
    assert_eq!(
        r.completed,
        r.clouds.iter().map(|c| c.served).sum::<u64>(),
        "cloud accounting disagrees with completions"
    );
}

#[test]
fn two_device_fleet_matches_perfmodel_latency_within_5pct() {
    // Same planning inputs as the live `fleet` subcommand: J6 at the base
    // bandwidth, Redmi Note 8 at 3x, splits from full Algorithm 1.
    let base_bw = 10.0;
    let mut cfg = sim::two_phone_fleet("alexnet", base_bw, fast_nsga2(7), 7);
    // Light open-loop load so queueing noise stays far below the 5% gate
    // (per-device utilisation ~3%), long enough for a meaningful sample.
    cfg.arrival = Arrival::Poisson { rps: 0.05 };
    cfg.duration_s = 1200.0;
    let report = sim::run(&cfg).expect("sim run");
    assert!(report.completed > 20, "too few samples: {}", report.completed);

    let profile = zoo::alexnet().analyze(1);
    for (device_profile, bw) in
        [(profiles::samsung_j6(), base_bw), (profiles::redmi_note8(), base_bw * 3.0)]
    {
        let pm = PerfModel::new(
            device_profile,
            profiles::cloud_server(),
            device_profile.wifi.unwrap().radio_power(),
            NetworkEnv::with_bandwidth(bw),
            &profile,
        );
        let decision = smartsplit(&pm, &fast_nsga2(7)).decision;
        let expected = pm.f1(decision.l1);
        let slice = report
            .per_profile
            .iter()
            .find(|p| p.name == device_profile.name)
            .unwrap_or_else(|| panic!("no slice for {}", device_profile.name));
        assert!(slice.served > 5, "{} served only {}", slice.name, slice.served);
        let mean = slice.latency.mean_s();
        let err = (mean - expected).abs() / expected;
        assert!(
            err < 0.05,
            "{}: simulated mean {mean:.4}s vs modelled {expected:.4}s ({:.1}% off)",
            slice.name,
            err * 100.0
        );
    }
}

#[test]
fn two_phone_steady_state_never_resplits() {
    // Full batteries, constant links, re-optimisation off: the fleet must
    // keep its planned splits for the whole run.
    let cfg = sim::two_phone_fleet("alexnet", 10.0, fast_nsga2(3), 3);
    let r = sim::run(&cfg).expect("sim run");
    assert_eq!(r.resplits, 0);
    assert_eq!(r.devices_active_end, 2);
    assert_eq!(r.batteries_exhausted, 0);
    assert_eq!(r.generated, r.completed);
}

#[test]
fn undersized_cloud_shows_queueing_delay() {
    // Starve the cloud: one server for 200 devices, every split pinned at
    // l1=5 so the heavy fc tail lands cloud-side. The M/G/c queue must
    // register real waiting — the contention term the 2-phone testbed can
    // never see.
    let mut cfg = sim::city_scale("alexnet", 200, 60.0, 5);
    cfg.clouds = 1;
    cfg.cloud_servers = 1;
    cfg.churn = None;
    cfg.planner = Planner::Fixed(5);
    cfg.arrival = Arrival::Poisson { rps: 40.0 };
    let r = sim::run(&cfg).expect("sim run");
    assert!(r.completed > 0);
    assert!(
        r.queue_delay.max_s() > 0.0,
        "no queueing delay despite a starved cloud"
    );
    assert!(r.resplits == 0, "pinned fleet must never re-split");
    assert!(r.clouds[0].utilization > 0.5, "cloud barely used: {}", r.clouds[0].utilization);
}

#[test]
fn battery_bands_drive_resplits_under_drain() {
    // Heavy background drain forces devices across band boundaries; the
    // event-driven trigger must produce re-splits (or dead batteries)
    // during the run.
    let mut cfg = sim::city_scale("alexnet", 100, 120.0, 9);
    cfg.churn = None;
    cfg.idle_drain_w = 200.0; // drains ~58% of a J6 battery over the run
    let r = sim::run(&cfg).expect("sim run");
    assert!(
        r.resplits > 0 || r.batteries_exhausted > 0,
        "no battery response: resplits={} dead={}",
        r.resplits,
        r.batteries_exhausted
    );
}
