//! Property tests for the 2-D split genome (`util::prop` substrate):
//!
//! * feasibility monotonicity — `l1 ≤ l2` is enforced through
//!   crossover/mutation (unordered genomes always carry a violation, and
//!   no unordered plan ever survives into a returned Pareto set);
//! * degeneracy — with zero edge servers and a free backhaul the tiered
//!   problem collapses onto the paper's two-tier problem: same Pareto
//!   front, byte-identical TOPSIS picks in every battery band.

use smartsplit::coordinator::battery::{battery_aware_split_banded, BatteryBand};
use smartsplit::device::{profiles, ComputeProfile};
use smartsplit::edge::{
    exhaustive_tiered_front, tiered_split_banded, BackhaulLink, SplitPlan, TieredPerfModel,
    TieredSplitProblem,
};
use smartsplit::models::zoo;
use smartsplit::optimizer::{exhaustive_pareto_front, optimize, Nsga2Params, Problem};
use smartsplit::perfmodel::{NetworkEnv, PerfModel, RadioPower};
use smartsplit::prop_assert;
use smartsplit::util::prop::{run_prop, Gen};

fn device_pm<'a>(
    profile: &'a smartsplit::models::ModelProfile,
    bw: f64,
    dev: &'static ComputeProfile,
) -> PerfModel<'a> {
    PerfModel::new(
        dev,
        profiles::cloud_server(),
        dev.wifi.map(|w| w.radio_power()).unwrap_or(RadioPower::PAPER_80211N),
        NetworkEnv::with_bandwidth(bw),
        profile,
    )
}

fn gen_device(g: &mut Gen) -> &'static ComputeProfile {
    if g.bool() {
        profiles::samsung_j6()
    } else {
        profiles::redmi_note8()
    }
}

fn gen_model(g: &mut Gen) -> smartsplit::models::ModelSpec {
    let names = ["alexnet", "vgg11", "mobilenet_v2"];
    zoo::by_name(names[g.usize_in(0, 2)]).unwrap()
}

#[test]
fn prop_unordered_genomes_always_violate() {
    run_prop("tiered unordered genomes violate", 40, |g| {
        let model = gen_model(g).analyze(1);
        let bw = g.f64_in(1.0, 60.0).max(0.5);
        let tpm = TieredPerfModel::new(
            device_pm(&model, bw, gen_device(g)),
            profiles::edge_server(),
            g.usize_in(0, 8),
            BackhaulLink {
                bandwidth_mbps: g.f64_in(10.0, 2000.0).max(1.0),
                latency_s: g.f64_in(0.0, 0.01),
            },
        );
        let problem = TieredSplitProblem::new(&tpm);
        let l = model.num_layers as i64;
        let a = 1 + g.usize_in(0, (l - 1) as usize) as i64;
        let b = 1 + g.usize_in(0, (l - 1) as usize) as i64;
        let (lo, hi) = (a.min(b), a.max(b));
        if hi > lo {
            prop_assert!(
                problem.violation_of(&[hi, lo]) > 0.0,
                "unordered genome [{hi},{lo}] feasible"
            );
        }
        // Violation grading: a wider inversion never scores lower.
        if hi - lo >= 2 {
            prop_assert!(
                problem.violation_of(&[hi, lo]) >= problem.violation_of(&[lo + 1, lo]),
                "violation not monotone in the inversion gap"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_solver_members_are_ordered_and_feasible() {
    run_prop("tiered NSGA-II members ordered", 12, |g| {
        let model = gen_model(g).analyze(1);
        let bw = g.f64_in(1.0, 60.0).max(0.5);
        let servers = g.usize_in(0, 8);
        let tpm = TieredPerfModel::new(
            device_pm(&model, bw, gen_device(g)),
            profiles::edge_server(),
            servers,
            BackhaulLink {
                bandwidth_mbps: g.f64_in(10.0, 2000.0).max(1.0),
                latency_s: g.f64_in(0.0, 0.01),
            },
        );
        let problem = TieredSplitProblem::new(&tpm);
        let params = Nsga2Params {
            seed: g.rng.next_u64(),
            ..Nsga2Params::for_small_genome(2)
        };
        let set = optimize(&problem, &params);
        prop_assert!(!set.members.is_empty(), "empty Pareto set");
        for m in &set.members {
            let (l1, l2) = (m.genome[0], m.genome[1]);
            prop_assert!(l1 <= l2, "unordered member ({l1},{l2}) survived");
            prop_assert!(m.violation == 0.0, "infeasible member ({l1},{l2}) survived");
            if servers == 0 {
                prop_assert!(l1 == l2, "torso plan ({l1},{l2}) with zero edge servers");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_degenerate_tier_collapses_to_two_tier() {
    run_prop("degenerate tier == two-tier", 60, |g| {
        let model = gen_model(g).analyze(1);
        let bw = g.f64_in(1.0, 60.0).max(0.5);
        let dev = gen_device(g);
        let pm = device_pm(&model, bw, dev);
        let tpm = TieredPerfModel::new(pm.clone(), profiles::edge_server(), 0, BackhaulLink::FREE);

        // Identical Pareto fronts (the tiered one lives on the diagonal).
        let tiered_front = exhaustive_tiered_front(&tpm);
        let flat_front = exhaustive_pareto_front(&pm);
        prop_assert!(
            tiered_front.iter().map(|p| p.l1).collect::<Vec<_>>() == flat_front,
            "fronts diverged: tiered {tiered_front:?} vs flat {flat_front:?}"
        );
        prop_assert!(
            tiered_front.iter().all(|p| p.is_two_tier()),
            "non-diagonal member in a degenerate front"
        );

        // Byte-identical TOPSIS picks in every battery band.
        for band in [BatteryBand::Comfort, BatteryBand::Saver, BatteryBand::Critical] {
            let tiered = tiered_split_banded(&tpm, band);
            let flat = battery_aware_split_banded(&pm, band).map(SplitPlan::two_tier);
            prop_assert!(
                tiered == flat,
                "band {band:?}: tiered pick {tiered:?} != two-tier pick {flat:?}"
            );
        }
        Ok(())
    });
}
