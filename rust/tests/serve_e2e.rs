//! End-to-end split serving over real TCP + real PJRT execution on both
//! sides: cloud daemon, device client, router/batcher, shaped link, energy
//! accounting, and live split movement. Skips without artifacts.

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use smartsplit::coordinator::{Config, Deployment};
use smartsplit::device::profiles;
use smartsplit::netsim::BandwidthTrace;
use smartsplit::optimizer::{Nsga2Params, SplitDecision};
use smartsplit::serve::RouterConfig;
use smartsplit::workload::{generate, Arrival};

fn have_artifacts() -> bool {
    let ok = Path::new("artifacts/alexnet/manifest.json").exists();
    if !ok {
        eprintln!("skipping: artifacts/ not built");
    }
    ok
}

fn test_config() -> Config {
    Config {
        model: "alexnet".into(),
        batch: 1,
        bandwidth_mbps: 200.0, // fast test link
        emulate_slowdown: false,
        nsga2: Nsga2Params { pop_size: 30, generations: 30, ..Default::default() },
        ..Config::default()
    }
}

#[test]
fn serves_closed_loop_workload() {
    if !have_artifacts() {
        return;
    }
    let dep = Deployment::start_with_split(test_config(), SplitDecision { l1: 3 }).unwrap();
    let reqs = generate(6, Arrival::ClosedLoop, 1);
    let report = dep.serve(&reqs).unwrap();
    assert_eq!(report.completed, 6);
    assert_eq!(report.errors, 0);
    assert_eq!(report.split_l1, 3);
    assert!(report.throughput_rps > 0.0);
    assert_eq!(report.latency.count(), 6);
    // Energy ledger must have all three components (client, upload,
    // download) populated — the BatteryStats analogue.
    assert!(report.client_energy_j > 0.0);
    assert!(report.upload_energy_j > 0.0);
    assert!(report.download_energy_j > 0.0);
    // M|3 for AlexNet = 1,828,608 B (conv1 params+act, relu act, pool act)
    assert_eq!(report.head_memory_bytes, 1_828_608);
    // Upload volume ≈ 6 × I|3 (64*27*27*4 B) + framing.
    let expect = 6 * 64 * 27 * 27 * 4;
    assert!(
        report.bytes_uploaded as i64 - expect as i64 >= 0
            && report.bytes_uploaded < expect as u64 + 4096,
        "uploaded {} expect ≈ {expect}",
        report.bytes_uploaded
    );
    dep.shutdown();
}

#[test]
fn cos_split_never_touches_network() {
    if !have_artifacts() {
        return;
    }
    let dep = Deployment::start_with_split(test_config(), SplitDecision { l1: 21 }).unwrap();
    let reqs = generate(2, Arrival::ClosedLoop, 2);
    let report = dep.serve(&reqs).unwrap();
    assert_eq!(report.completed, 2);
    assert_eq!(report.bytes_uploaded, 0);
    assert_eq!(report.upload_energy_j, 0.0);
    dep.shutdown();
}

#[test]
fn coc_ships_raw_images() {
    if !have_artifacts() {
        return;
    }
    let dep = Deployment::start_with_split(test_config(), SplitDecision { l1: 0 }).unwrap();
    let reqs = generate(2, Arrival::ClosedLoop, 3);
    let report = dep.serve(&reqs).unwrap();
    assert_eq!(report.completed, 2);
    let expect = 2 * 3 * 224 * 224 * 4; // two raw NCHW images
    assert!(report.bytes_uploaded >= expect as u64, "uploaded {}", report.bytes_uploaded);
    // No on-device inference → no head memory, no client compute energy.
    assert_eq!(report.head_memory_bytes, 0);
    dep.shutdown();
}

#[test]
fn dynamic_batcher_coalesces() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = test_config();
    cfg.batch = 8;
    cfg.router = RouterConfig { max_batch: 8, max_wait: Duration::from_millis(300) };
    let dep = Deployment::start_with_split(cfg, SplitDecision { l1: 3 }).unwrap();
    // Burst of 8 requests arriving together: should ride one batch.
    let reqs = generate(8, Arrival::ClosedLoop, 4);
    let report = dep.serve(&reqs).unwrap();
    assert_eq!(report.completed, 8);
    assert_eq!(report.errors, 0);
    // Batched upload: ~1 batch-8 activation (8 × I|3), not 8 separate ones
    // padded to 8 each.
    let one_batch = 8 * 64 * 27 * 27 * 4;
    assert!(
        report.bytes_uploaded < 2 * one_batch as u64,
        "batching failed: uploaded {}",
        report.bytes_uploaded
    );
    dep.shutdown();
}

#[test]
fn adaptive_split_moves_with_bandwidth_trace() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = test_config();
    // Trace: generous link then a starved one — the optimiser must move
    // the split when the trace steps down.
    let trace = BandwidthTrace {
        points: vec![
            (Duration::ZERO, 200.0),
            (Duration::from_millis(900), 0.5),
        ],
    };
    cfg.bandwidth_mbps = 200.0;
    let dep = Deployment::start(cfg).unwrap();
    let initial = dep.split.l1;
    // Slow trickle so the run spans the trace step.
    let reqs = generate(7, Arrival::Uniform { rps: 3.0 }, 5);
    let report = dep.serve_with_trace(&reqs, Some(&trace)).unwrap();
    assert_eq!(report.completed, 7);
    assert!(
        report.split_history.len() >= 2,
        "split never moved: {:?} (initial {initial})",
        report.split_history
    );
    let final_split = report.split_history.last().unwrap().1;
    assert_ne!(final_split, initial, "split unchanged after bandwidth collapse");
    dep.shutdown();
}

#[test]
fn error_paths_surface_cleanly() {
    if !have_artifacts() {
        return;
    }
    // Unknown model: the cloud Hello fails and connect returns an error.
    let cfg = Config { model: "resnet50".into(), ..test_config() };
    assert!(Deployment::start_with_split(cfg, SplitDecision { l1: 1 }).is_err());
    // Unavailable batch variant.
    let cfg = Config { batch: 64, ..test_config() };
    assert!(Deployment::start_with_split(cfg, SplitDecision { l1: 1 }).is_err());
}

#[test]
fn fleet_shares_one_cloud_across_heterogeneous_devices() {
    use smartsplit::coordinator::fleet::{Fleet, FleetConfig, FleetMember};

    if !have_artifacts() {
        return;
    }
    let cfg = FleetConfig {
        artifacts_dir: std::path::PathBuf::from("artifacts"),
        model: "alexnet".into(),
        batch: 1,
        members: vec![
            FleetMember { profile: profiles::samsung_j6(), bandwidth_mbps: 150.0 },
            FleetMember { profile: profiles::redmi_note8(), bandwidth_mbps: 150.0 },
        ],
        strategy: smartsplit::planner::Strategy::SmartSplit,
        nsga2: Nsga2Params { pop_size: 30, generations: 30, ..Default::default() },
        emulate_slowdown: false,
    };
    let fleet = Fleet::start(cfg).unwrap();
    assert_eq!(fleet.splits().len(), 2);
    let reqs = generate(8, Arrival::ClosedLoop, 11);
    let report = fleet.serve(&reqs).unwrap();
    assert_eq!(report.completed, 8);
    assert_eq!(report.errors, 0);
    // Equal conditions → the SED dispatcher must use both devices.
    assert!(
        report.members.iter().all(|m| m.served > 0),
        "one device starved: {:?}",
        report.members.iter().map(|m| m.served).collect::<Vec<_>>()
    );
    // Served counts add up and energy was metered on every active device.
    let total: u64 = report.members.iter().map(|m| m.served).sum();
    assert_eq!(total, 8);
    for m in &report.members {
        assert!(m.client_energy_j > 0.0, "{} no energy metered", m.name);
    }
    fleet.shutdown();
}
