//! Cross-checks the python-emitted manifests against the rust layer-spec
//! algebra: the two implementations of shapes / params / FLOPs / memory
//! (python `specs.py`, rust `models::spec`) must agree exactly on every
//! layer of every model. Skips when `make artifacts` has not run.

use std::path::Path;

use smartsplit::models::{zoo, Manifest};

fn artifacts() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("alexnet/manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping: artifacts/ not built");
        None
    }
}

#[test]
fn manifests_match_rust_spec_algebra() {
    let Some(dir) = artifacts() else { return };
    for name in ["alexnet", "vgg11", "vgg13", "vgg16", "mobilenet_v2"] {
        let Ok(man) = Manifest::load(dir, name) else {
            eprintln!("skipping {name}: no manifest");
            continue;
        };
        let spec = zoo::by_name(name).unwrap();
        let profile = spec.analyze(1);
        assert_eq!(man.num_layers, profile.num_layers, "{name} layer count");
        assert_eq!(man.total_params, spec.total_params(), "{name} total params");
        assert!((man.top1_accuracy - spec.top1_accuracy).abs() < 1e-9);
        for (lm, lp) in man.layers.iter().zip(&profile.layers) {
            let ctx = format!("{name} layer {}", lm.index);
            assert_eq!(lm.kind, lp.kind, "{ctx} kind");
            assert_eq!(lm.in_shape, lp.in_shape, "{ctx} in_shape");
            assert_eq!(lm.out_shape, lp.out_shape, "{ctx} out_shape");
            assert_eq!(lm.params, lp.params, "{ctx} params");
            assert_eq!(lm.param_bytes, lp.param_bytes, "{ctx} param_bytes");
            assert_eq!(lm.act_bytes, lp.act_bytes, "{ctx} act_bytes");
            assert_eq!(lm.flops, lp.flops, "{ctx} flops");
        }
    }
}

#[test]
fn weight_files_exist_with_exact_sizes() {
    let Some(dir) = artifacts() else { return };
    let man = Manifest::load(dir, "alexnet").unwrap();
    for lm in &man.layers {
        for w in &lm.weights {
            let path = man.weight_path(w);
            let meta = std::fs::metadata(&path)
                .unwrap_or_else(|e| panic!("missing weight {}: {e}", path.display()));
            assert_eq!(
                meta.len(),
                w.num_elements() as u64 * 4,
                "size of {}",
                path.display()
            );
        }
    }
}

#[test]
fn hlo_files_exist_and_declare_layouts() {
    let Some(dir) = artifacts() else { return };
    let man = Manifest::load(dir, "alexnet").unwrap();
    for lm in &man.layers {
        for b in &man.batches {
            let path = man.hlo_path(lm.index, *b).unwrap();
            let head = std::fs::read_to_string(&path)
                .unwrap_or_else(|e| panic!("missing {}: {e}", path.display()));
            let first = head.lines().next().unwrap();
            assert!(first.starts_with("HloModule"), "{}", path.display());
            // batch-scaled input shape must appear in the entry layout
            let mut in_shape = lm.in_shape.clone();
            in_shape[0] = *b;
            let dims: Vec<String> = in_shape.iter().map(|d| d.to_string()).collect();
            let expect = format!("f32[{}]", dims.join(","));
            assert!(
                first.contains(&expect),
                "{} entry layout missing {expect}: {first}",
                path.display()
            );
        }
    }
}

#[test]
fn paper_memory_quantities_from_manifest() {
    // Replays Eq. 16 / I|l1 accounting directly off the manifest and checks
    // it against the rust profile used by the optimiser — guarding against
    // drift between the serving path (manifest) and planning path (spec).
    let Some(dir) = artifacts() else { return };
    let man = Manifest::load(dir, "vgg16").unwrap();
    let profile = zoo::vgg16().analyze(1);
    for l1 in 1..=man.num_layers {
        let m_client: u64 = man.layers[..l1].iter().map(|l| l.param_bytes + l.act_bytes).sum();
        assert_eq!(m_client, profile.client_memory_bytes(l1), "M|{l1}");
        let i_l1 = man.layers[l1 - 1].act_bytes;
        assert_eq!(i_l1, profile.intermediate_bytes(l1), "I|{l1}");
    }
}
