//! Property tests for the battery-band policy boundaries
//! (`coordinator::battery::BatteryBand`), driven by the in-repo
//! `util::prop` engine.

use smartsplit::coordinator::battery::BatteryBand;
use smartsplit::prop_assert;
use smartsplit::util::prop::run_prop;

#[test]
fn band_edges_are_exact() {
    // The 0.2 / 0.5 edges belong to the *lower* band: bands are defined by
    // strict `>` comparisons, so exactly-at-threshold charge already gets
    // the more aggressive energy policy.
    assert_eq!(BatteryBand::of_fraction(0.5), BatteryBand::Saver);
    assert_eq!(BatteryBand::of_fraction(0.5 + 1e-12), BatteryBand::Comfort);
    assert_eq!(BatteryBand::of_fraction(0.2), BatteryBand::Critical);
    assert_eq!(BatteryBand::of_fraction(0.2 + 1e-12), BatteryBand::Saver);
    assert_eq!(BatteryBand::of_fraction(0.0), BatteryBand::Critical);
    assert_eq!(BatteryBand::of_fraction(1.0), BatteryBand::Comfort);
}

#[test]
fn prop_energy_weight_monotone_nonincreasing_in_soc() {
    run_prop("energy weight monotone in SoC", 500, |g| {
        let a = g.f64_in(0.0, 1.0);
        let b = g.f64_in(0.0, 1.0);
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        let w_lo = BatteryBand::of_fraction(lo).energy_weight();
        let w_hi = BatteryBand::of_fraction(hi).energy_weight();
        prop_assert!(
            w_lo >= w_hi,
            "soc {lo} weight {w_lo} < soc {hi} weight {w_hi}"
        );
        Ok(())
    });
}

#[test]
fn prop_band_of_fraction_total_and_consistent() {
    // Every SoC (including out-of-range garbage a buggy meter could
    // report) maps to a band, and the band agrees with the interval
    // definition.
    run_prop("band total + interval-consistent", 500, |g| {
        let soc = g.f64_in(-0.5, 1.5);
        let band = BatteryBand::of_fraction(soc);
        let expect = if soc > 0.5 {
            BatteryBand::Comfort
        } else if soc > 0.2 {
            BatteryBand::Saver
        } else {
            BatteryBand::Critical
        };
        prop_assert!(band == expect, "soc {soc}: got {band:?}, expected {expect:?}");
        prop_assert!(
            band.energy_weight() >= 1.0,
            "weight below neutral at soc {soc}"
        );
        Ok(())
    });
}

#[test]
fn prop_weights_cover_expected_values() {
    // The three bands map onto exactly {1, 2, 4} — a re-tuned policy must
    // update the battery tests knowingly.
    run_prop("weights in {1,2,4}", 100, |g| {
        let soc = g.f64_in(0.0, 1.0);
        let w = BatteryBand::of_fraction(soc).energy_weight();
        prop_assert!(w == 1.0 || w == 2.0 || w == 4.0, "weight {w} at soc {soc}");
        Ok(())
    });
}
