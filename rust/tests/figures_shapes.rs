//! Cross-exhibit consistency: the figures must agree with each other and
//! with the optimiser — e.g. Table I's split must appear in Fig. 6's
//! Pareto set, and Fig. 7/8/9 cell values must equal the perf model
//! evaluated at Table II's splits. Catches drift between the generators.

use smartsplit::device::profiles;
use smartsplit::figures::*;
use smartsplit::models::zoo;
use smartsplit::optimizer::{exhaustive_pareto_front, Algorithm, Nsga2Params};

fn params() -> Nsga2Params {
    Nsga2Params { pop_size: 60, generations: 60, ..Default::default() }
}

#[test]
fn table1_choice_is_a_fig6_pareto_member() {
    for model in MODELS {
        let r = pareto_and_choice(model, profiles::samsung_j6(), 10.0, &params()).unwrap();
        assert!(
            r.pareto.iter().any(|(l1, _)| *l1 == r.decision.l1),
            "{model}: TOPSIS choice {} not in its own Pareto set",
            r.decision.l1
        );
    }
}

#[test]
fn fig6_front_equals_exhaustive_front() {
    for model in MODELS {
        let profile = zoo::by_name(model).unwrap().analyze(1);
        let pm = perf_model(&profile, profiles::samsung_j6(), 10.0);
        let truth = exhaustive_pareto_front(&pm);
        let r = pareto_and_choice(model, profiles::samsung_j6(), 10.0, &params()).unwrap();
        let ga: Vec<usize> = r.pareto.iter().map(|(l1, _)| *l1).collect();
        assert_eq!(truth, ga, "{model}: GA front != exhaustive front");
    }
}

#[test]
fn figs789_cells_equal_perfmodel_at_table2_splits() {
    let cells = algorithm_comparison(profiles::samsung_j6(), 10.0, &params(), 10, 1).unwrap();
    for cell in &cells {
        if cell.algorithm == Algorithm::Rs {
            continue; // averaged over random splits
        }
        let profile = zoo::by_name(&cell.model).unwrap().analyze(1);
        let pm = perf_model(&profile, profiles::samsung_j6(), 10.0);
        let l1 = cell.mean_l1 as usize;
        assert!((pm.f1(l1) - cell.latency_s).abs() < 1e-9, "{:?}/{}", cell.algorithm, cell.model);
        assert!((pm.f2(l1) - cell.energy_j).abs() < 1e-9);
        assert!((pm.f3(l1) - cell.memory_bytes).abs() < 1e-9);
    }
}

#[test]
fn fig10_smartsplit_rows_match_table1_decisions() {
    let rows = mobilenet_comparison(profiles::samsung_j6(), 10.0, &params()).unwrap();
    for model in MODELS {
        let r = pareto_and_choice(model, profiles::samsung_j6(), 10.0, &params()).unwrap();
        let label = format!("{model}+SmartSplit(l1={})", r.decision.l1);
        assert!(
            rows.iter().any(|row| row.label == label),
            "fig10 missing row {label}; have {:?}",
            rows.iter().map(|r| r.label.clone()).collect::<Vec<_>>()
        );
    }
}

#[test]
fn latency_and_energy_sweeps_are_self_consistent() {
    // total == sum of components at every split, on both phones.
    for phone in [profiles::samsung_j6(), profiles::redmi_note8()] {
        for model in MODELS {
            for (l1, b) in latency_sweep(model, phone, 10.0).unwrap() {
                assert!(
                    (b.total() - (b.client_s + b.upload_s + b.server_s)).abs() < 1e-12,
                    "{model} l1={l1}"
                );
            }
            for (l1, e) in energy_sweep(model, phone, 10.0).unwrap() {
                assert!(
                    (e.total() - (e.client_j + e.upload_j + e.download_j)).abs() < 1e-12,
                    "{model} l1={l1}"
                );
            }
        }
    }
}

#[test]
fn sweeps_scale_correctly_with_bandwidth() {
    // Doubling B must halve upload latency exactly and leave client/server
    // latency unchanged (Eq. 4 linearity).
    let a = latency_sweep("vgg16", profiles::samsung_j6(), 10.0).unwrap();
    let b = latency_sweep("vgg16", profiles::samsung_j6(), 20.0).unwrap();
    for ((l1, x), (_, y)) in a.iter().zip(&b).take(38) {
        assert!((x.upload_s - 2.0 * y.upload_s).abs() < 1e-12, "l1={l1}");
        assert_eq!(x.client_s, y.client_s);
        assert_eq!(x.server_s, y.server_s);
    }
}
