//! D3 regression (detlint, DESIGN.md §15): trace exports must be
//! byte-identical no matter what order requests were *begun* in.
//!
//! The recorder keys open traces by request id; before this gate that
//! map was a default-hasher `HashMap` whose ordering neutrality was
//! honored only by a comment. This test is the adversarial version of
//! a perturbed-hasher-seed check: 100 reruns, each beginning and
//! recording the same requests in a different seeded shuffle of
//! insertion order (the spans themselves interleave in reverse), must
//! export the same bytes — because completion order, and only
//! completion order, defines export order.

use smartsplit::trace::{CausalEvent, SpanKind, TraceRecorder};
use smartsplit::util::rng::Xoshiro256;

const REQUESTS: u64 = 40;
const LEFT_OPEN: u64 = 5;

/// Record the same logical run with `order` controlling the insertion
/// order of `begin` and the interleaving of span appends; completion
/// order is always ascending. Returns (JSONL, Chrome trace) exports.
fn export_with_order(order: &[u64]) -> (String, String) {
    let mut rec = TraceRecorder::new(1);
    for &req in order {
        rec.begin(req, req % 7, req as f64 * 0.5);
    }
    // Append spans in the reverse of the shuffled order, so the open
    // map is exercised under a second, different access pattern.
    for &req in order.iter().rev() {
        let t0 = req as f64 * 0.5;
        rec.span(req, SpanKind::DeviceQueue, t0, t0, None);
        rec.span(req, SpanKind::HeadCompute, t0, t0 + 0.2, None);
        rec.span(req, SpanKind::Uplink, t0 + 0.2, t0 + 0.5, None);
        rec.span(req, SpanKind::CloudQueue, t0 + 0.5, t0 + 0.7, Some(0));
        rec.span(req, SpanKind::CloudService, t0 + 0.7, t0 + 1.0, Some(0));
    }
    rec.note(CausalEvent::Fault { t_s: 1.0, kind: "site_down", site: 1, value: 0.0 });
    // Completion order is part of the run's semantics — fixed. The
    // tail stays open so the unfinished count is exercised too.
    for req in 0..REQUESTS - LEFT_OPEN {
        rec.complete(req, req as f64 * 0.5 + 1.0);
    }
    let rep = rec.finish();
    assert_eq!(rep.unfinished, LEFT_OPEN);
    (rep.to_jsonl(), rep.to_chrome_trace())
}

/// Seeded Fisher–Yates over the request ids.
fn shuffled(seed: u64) -> Vec<u64> {
    let mut ids: Vec<u64> = (0..REQUESTS).collect();
    let mut rng = Xoshiro256::seed_from_u64(seed);
    for i in (1..ids.len()).rev() {
        let j = rng.gen_range(0, i);
        ids.swap(i, j);
    }
    ids
}

#[test]
fn exports_are_byte_identical_across_100_shuffled_insertion_orders() {
    let natural: Vec<u64> = (0..REQUESTS).collect();
    let (base_jsonl, base_chrome) = export_with_order(&natural);
    assert!(!base_jsonl.is_empty() && !base_chrome.is_empty());
    for trial in 0..100u64 {
        let order = shuffled(0xC0FFEE ^ trial);
        let (jsonl, chrome) = export_with_order(&order);
        assert_eq!(jsonl, base_jsonl, "JSONL diverged on trial {trial}");
        assert_eq!(chrome, base_chrome, "Chrome trace diverged on trial {trial}");
    }
}

#[test]
fn shuffles_actually_differ() {
    // Guard the guard: if the shuffle were the identity the test above
    // would pass vacuously.
    let natural: Vec<u64> = (0..REQUESTS).collect();
    let distinct = (0..100u64)
        .map(|t| shuffled(0xC0FFEE ^ t))
        .filter(|o| *o != natural)
        .count();
    assert!(distinct >= 99, "only {distinct} shuffles differed");
}
