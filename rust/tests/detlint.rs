//! The detlint gate's own contract (DESIGN.md §15): every rule fires
//! on its known-bad fixture, clean fixtures stay silent, the allow
//! suppression syntax works and is counted, the report is
//! deterministic, and — the part that keeps the CI gate honest — the
//! repository's own sources lint clean with every exemption justified.

use std::path::PathBuf;

use smartsplit::lint::{self, LintReport};

fn fixtures(which: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("lint_fixtures")
        .join(which)
}

fn scan(which: &str) -> LintReport {
    lint::scan_tree(&fixtures(which)).expect("fixture tree scans")
}

fn count(rep: &LintReport, rule: &str) -> usize {
    rep.findings.iter().filter(|f| f.rule == rule).count()
}

#[test]
fn every_rule_fires_on_its_bad_fixture() {
    let rep = scan("bad");
    assert_eq!(count(&rep, "D1"), 2, "{}", rep.render());
    assert_eq!(count(&rep, "D2"), 4, "{}", rep.render());
    assert_eq!(count(&rep, "D3"), 5, "{}", rep.render());
    assert_eq!(count(&rep, "D4"), 2, "{}", rep.render());
    assert_eq!(count(&rep, "R1"), 2, "{}", rep.render());
    assert!(!rep.clean());
    // Nothing in the bad corpus carries a usable allow.
    assert!(rep.suppressed.is_empty(), "{}", rep.render());
}

#[test]
fn findings_land_in_the_right_files() {
    let rep = scan("bad");
    for f in &rep.findings {
        let allowed: &[&str] = match f.rule {
            "D1" => &["sim/wall_clock.rs"],
            "D2" => &["planner/os_random.rs", "sim/shard_channel.rs"],
            "D3" => &["trace/map_iter.rs"],
            "D4" => &["metrics/relaxed.rs", "sim/shard_channel.rs"],
            "R1" => &["serve/panics.rs"],
            "ALLOW" => &["serve/stale_allow.rs"],
            other => panic!("unexpected rule {other}"),
        };
        let path = f.path.replace('\\', "/");
        assert!(
            allowed.iter().any(|a| path.ends_with(a)),
            "{} finding in {}, expected one of {allowed:?}",
            f.rule,
            f.path
        );
    }
    // The shard-channel fixture proves D2 and D4 guard the sharded
    // engine's cross-shard code specifically.
    for rule in ["D2", "D4"] {
        assert!(
            rep.findings
                .iter()
                .any(|f| f.rule == rule
                    && f.path.replace('\\', "/").ends_with("sim/shard_channel.rs")),
            "{rule} did not fire inside sim/shard_channel.rs:\n{}",
            rep.render()
        );
    }
}

#[test]
fn allow_hygiene_is_enforced() {
    // The stale-allow fixture holds exactly three hygiene problems: an
    // allow that suppresses nothing, an unknown rule id, and a missing
    // justification.
    let rep = scan("bad");
    assert_eq!(count(&rep, "ALLOW"), 3, "{}", rep.render());
}

#[test]
fn r1_exempts_test_modules() {
    // serve/panics.rs has unwrap/expect both in production code (lines
    // 5-6) and in its #[cfg(test)] module; only the former may fire.
    let rep = scan("bad");
    let r1_lines: Vec<usize> = rep
        .findings
        .iter()
        .filter(|f| f.rule == "R1")
        .map(|f| f.line)
        .collect();
    assert_eq!(r1_lines, vec![5, 6], "{}", rep.render());
}

#[test]
fn clean_fixtures_stay_silent_and_suppressions_are_counted() {
    let rep = scan("clean");
    assert!(rep.clean(), "{}", rep.render());
    // Exactly one justified allow in the clean corpus (sim/suppressed.rs).
    assert_eq!(rep.suppressed.len(), 1, "{}", rep.render());
    assert_eq!(rep.suppressed[0].rule, "D1");
    assert!(!rep.suppressed[0].justification.is_empty());
    assert!(rep.suppressed[0]
        .path
        .replace('\\', "/")
        .ends_with("sim/suppressed.rs"));
}

#[test]
fn report_is_deterministic() {
    let a = scan("bad").render();
    let b = scan("bad").render();
    assert_eq!(a, b);
    // Findings are stable-sorted by (path, line, rule, token).
    let rep = scan("bad");
    let mut sorted = rep.findings.clone();
    sorted.sort_by(|a, b| {
        (&a.path, a.line, a.rule, &a.token).cmp(&(&b.path, b.line, b.rule, &b.token))
    });
    assert_eq!(rep.findings, sorted);
}

#[test]
fn repository_lints_clean() {
    // The gate itself: the crate's own sources must carry zero
    // unsuppressed findings, and every exemption must be justified.
    // This is what `cargo run --bin detlint` enforces in CI; failing
    // here names the violation with file:line.
    let src = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src");
    let rep = lint::scan_tree(&src).expect("src tree scans");
    assert!(rep.clean(), "repository has lint findings:\n{}", rep.render());
    assert!(rep.files_scanned > 20, "scan missed the tree");
    for s in &rep.suppressed {
        assert!(
            !s.justification.is_empty(),
            "unjustified allow at {}:{}",
            s.path,
            s.line
        );
    }
    // Today every in-tree exemption is a wall-clock (D1) one; widening
    // this list is a deliberate act, not drift.
    for s in &rep.suppressed {
        assert_eq!(s.rule, "D1", "unexpected {} exemption at {}:{}", s.rule, s.path, s.line);
    }
}
