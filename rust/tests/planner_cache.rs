//! Planner performance layer: (a) the split-plan cache and the parallel
//! re-solve fan-out are pure wall-clock optimisations — same scenario
//! seed ⇒ byte-identical `SplitDecision` stream and sim counters with the
//! cache on and off; (b) the re-optimisation sweep re-arms on the
//! canonical absolute tick grid (`k · reopt_period_s`), not by relative
//! `now + period` scheduling.

use smartsplit::optimizer::Nsga2Params;
use smartsplit::sim::{self, Planner, PlannerPerfConfig};

/// A fleet that exercises every planning path: SmartSplit planner (full
/// Algorithm 1 per decision), battery bands engaged, bandwidth wobble
/// feeding the drift trigger, churn feeding spawn-time planning.
fn smartsplit_city(seed: u64) -> sim::SimConfig {
    let mut cfg = sim::city_scale("alexnet", 300, 120.0, seed);
    cfg.planner = Planner::SmartSplit(Nsga2Params {
        seed,
        ..Nsga2Params::for_tiny_genome()
    });
    // These tests compare the full per-decision stream, which scenarios
    // don't retain by default.
    cfg.planner_perf.record_decisions = true;
    cfg
}

#[test]
fn cached_vs_uncached_parity() {
    let mut cached = smartsplit_city(21);
    cached.planner_perf = PlannerPerfConfig {
        cache: true,
        parallel: true,
        bw_bucket_ratio: 1.25,
        record_decisions: true,
    };
    let mut uncached = smartsplit_city(21);
    uncached.planner_perf = PlannerPerfConfig {
        cache: false,
        parallel: false,
        // Quantisation is part of the planner, not the cache: both arms
        // must bucket identically for the comparison to be decision-level.
        bw_bucket_ratio: 1.25,
        record_decisions: true,
    };

    let a = sim::run(&cached).expect("cached run");
    let b = sim::run(&uncached).expect("uncached run");

    // Byte-identical decision stream (spawns + re-plans, in event order).
    assert!(!a.decisions.is_empty(), "scenario exercised no planning");
    assert_eq!(a.decisions, b.decisions, "cache changed a split decision");
    // ... and identical everything downstream of the decisions.
    assert_eq!(a.summary(), b.summary());
    assert_eq!(a.events, b.events);
    assert_eq!(a.generated, b.generated);
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.resplits, b.resplits);
    assert_eq!(a.reopt_sweeps, b.reopt_sweeps);
    assert_eq!(a.split_distribution, b.split_distribution);
    assert_eq!(a.devices_created, b.devices_created);

    // The whole point: the cached arm solved orders of magnitude less.
    assert_eq!(
        b.planner.solves,
        b.decisions.len() as u64,
        "uncached arm must solve once per decision"
    );
    assert!(
        a.planner.solves * 3 <= b.planner.solves,
        "cache barely helped: {} solves cached vs {} uncached",
        a.planner.solves,
        b.planner.solves
    );
    // Cached solves are bounded by the quantised key lattice (2 profiles ×
    // 3 bands × ~22 bandwidth buckets), not by fleet size or sweep count.
    assert!(
        a.planner.solves <= 150,
        "{} cached solves exceed the planner-state lattice",
        a.planner.solves
    );
    assert!(
        a.planner.hit_rate() > 0.5,
        "hit rate {:.2} too low for a quantised 300-device fleet",
        a.planner.hit_rate()
    );
}

#[test]
fn cached_runs_are_deterministic() {
    // Parallel cache-miss fan-out must not introduce any run-to-run
    // nondeterminism (solves are pure functions of the key).
    let cfg = smartsplit_city(5);
    let a = sim::run(&cfg).expect("run a");
    let b = sim::run(&cfg).expect("run b");
    assert_eq!(a.decisions, b.decisions);
    assert_eq!(a.summary(), b.summary());
    assert_eq!(a.planner, b.planner, "cache accounting must be deterministic");
}

#[test]
fn every_spawn_records_a_decision() {
    let cfg = smartsplit_city(11);
    let r = sim::run(&cfg).expect("run");
    assert_eq!(r.decisions.len() as u64, r.decision_count);
    assert!(
        r.decisions.len() >= r.devices_created,
        "{} decisions for {} devices",
        r.decisions.len(),
        r.devices_created
    );
    // Without opt-in, the trace stays empty but the count remains.
    let mut quiet = smartsplit_city(11);
    quiet.planner_perf.record_decisions = false;
    let q = sim::run(&quiet).expect("quiet run");
    assert!(q.decisions.is_empty());
    assert_eq!(q.decision_count, r.decision_count);
    // Non-pinned planning always lands inside the feasible split domain;
    // without an edge tier every plan is two-tier (l2 == l1).
    for &(_, l1, l2) in &r.decisions {
        assert!((1..21).contains(&(l1 as usize)), "decision l1={l1} out of domain");
        assert_eq!(l1, l2, "two-tier scenario produced a torso plan");
    }
}

/// Sweep counts on the canonical absolute grid: sweep k happens iff
/// `k · period < duration` (at `k · period == duration` the horizon event,
/// scheduled earlier, wins the FIFO tie and the sweep is a no-op).
fn expected_sweeps(period: f64, duration: f64) -> u64 {
    (1u64..)
        .take_while(|&k| k as f64 * period < duration)
        .count() as u64
}

#[test]
fn reopt_rearm_stays_on_absolute_tick_grid() {
    // Adversarial periods: not exactly representable in binary floating
    // point, so a relative `now + period` re-arm accumulates error and
    // drifts off the grid over hundreds of ticks. The canonical re-arm
    // schedules tick k at exactly `k · period` and must hit the expected
    // sweep count dead on.
    // (30, 90) pins the exact-multiple edge: tick 3 lands precisely on
    // the horizon and must lose the FIFO tie (no sweep at t == duration).
    for (period, duration, seed) in [
        (0.3f64, 90.0f64, 1u64),
        (100.0 / 3.0, 100.0, 2),
        (0.7, 63.0, 3),
        (30.0, 90.0, 4),
    ] {
        let mut cfg = sim::city_scale("alexnet", 8, duration, seed);
        cfg.planner = Planner::Fixed(5); // isolate scheduling from planning
        cfg.churn = None;
        cfg.reopt_period_s = period;
        let r = sim::run(&cfg).expect("sim run");
        assert_eq!(
            r.reopt_sweeps,
            expected_sweeps(period, duration),
            "period={period} duration={duration}"
        );
        // Pinned fleet: sweeps must never re-plan anything.
        assert_eq!(r.resplits, 0);
        let r2 = sim::run(&cfg).expect("sim rerun");
        assert_eq!(r.reopt_sweeps, r2.reopt_sweeps);
    }
}

#[test]
fn disabling_reopt_disables_sweeps() {
    let mut cfg = sim::city_scale("alexnet", 8, 30.0, 4);
    cfg.reopt_period_s = 0.0;
    cfg.churn = None;
    let r = sim::run(&cfg).expect("sim run");
    assert_eq!(r.reopt_sweeps, 0);
}
