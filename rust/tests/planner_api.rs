//! Public-API smoke test for the planning façade: every
//! [`Strategy`] variant plans through `planner::Planner`, flat and
//! tiered, with sane outcomes and provenance. CI runs this file as the
//! façade's contract check.

use std::sync::Arc;

use smartsplit::coordinator::battery::BatteryBand;
use smartsplit::device::profiles;
use smartsplit::edge::{BackhaulLink, EdgeSite};
use smartsplit::models::zoo;
use smartsplit::optimizer::Nsga2Params;
use smartsplit::planner::{
    CacheOutcome, PlanRequest, Planner, PlannerConfig, Strategy, TierContext,
};

fn fleet_planner() -> Planner {
    Planner::new(PlannerConfig::fleet(Nsga2Params::for_small_genome(2), 7))
}

fn flat_request(strategy: Strategy) -> PlanRequest {
    PlanRequest::two_tier(
        Arc::new(zoo::alexnet().analyze(1)),
        profiles::samsung_j6(),
        BatteryBand::Comfort,
        10.0,
        strategy,
    )
}

fn edge_site() -> EdgeSite {
    EdgeSite {
        servers: 2,
        profile: profiles::edge_server(),
        backhaul: BackhaulLink::METRO_1GBE,
    }
}

#[test]
fn every_strategy_plans_a_flat_request() {
    let planner = fleet_planner();
    for strategy in Strategy::ALL {
        let req = flat_request(strategy);
        let out = planner.plan(&req);
        assert_eq!(out.provenance.strategy, strategy);
        assert_eq!(out.provenance.kind, strategy.kind());
        assert_eq!(out.provenance.cache, CacheOutcome::Miss, "{}", strategy.name());
        let plan = match (strategy, out.plan) {
            // The ε box may legitimately be infeasible (covered by its
            // dedicated test below).
            (Strategy::EpsilonConstrained, None) => continue,
            (_, Some(p)) => p,
            (s, None) => panic!("{} found no flat plan", s.name()),
        };
        assert!(plan.is_two_tier(), "{}: flat request grew a torso", strategy.name());
        assert!(plan.l1 <= 21, "{}: l1={} out of range", strategy.name(), plan.l1);
        match strategy {
            Strategy::Cos => assert_eq!(plan.l1, 21),
            Strategy::Coc => assert_eq!(plan.l1, 0),
            _ => assert!((1..21).contains(&plan.l1), "{}: l1={}", strategy.name(), plan.l1),
        }
        // Predicted objectives are finite and present whenever a plan is.
        let o = out.objectives.expect("objectives for a planned outcome");
        assert!(o.iter().all(|v| v.is_finite() && *v >= 0.0), "{}: {o:?}", strategy.name());
        // Front-producing strategies surface their Pareto summary on the
        // solving call; point strategies never do.
        match strategy {
            Strategy::SmartSplit | Strategy::Topsis => {
                let front = out.pareto.expect("front strategies expose a Pareto summary");
                assert!(!front.is_empty());
                assert!(front.iter().any(|(p, _)| *p == plan), "choice must sit on the front");
            }
            _ => assert!(out.pareto.is_none(), "{}: unexpected front", strategy.name()),
        }
        // Determinism: the same request replans identically (now a hit).
        let again = planner.plan(&req);
        assert_eq!(again.plan, out.plan);
        assert_eq!(again.provenance.cache, CacheOutcome::Hit);
        assert_eq!(again.objectives, out.objectives);
    }
}

#[test]
fn every_strategy_plans_a_tiered_request() {
    let planner = fleet_planner();
    for strategy in Strategy::ALL {
        let mut req = flat_request(strategy);
        req.tier = Some(TierContext { site: 0, edge: edge_site() });
        let out = planner.plan(&req);
        let plan = match (strategy, out.plan) {
            (Strategy::EpsilonConstrained, None) => continue,
            (_, Some(p)) => p,
            (s, None) => panic!("{} found no tiered plan", s.name()),
        };
        assert!(
            plan.l1 <= plan.l2 && plan.l2 <= 21,
            "{}: unordered tiered plan {plan:?}",
            strategy.name()
        );
        let o = out.objectives.expect("objectives for a planned outcome");
        assert!(o.iter().all(|v| v.is_finite() && *v >= 0.0), "{}: {o:?}", strategy.name());
        // The tiered key never collides with the flat one.
        assert_ne!(planner.key(&req), planner.key(&flat_request(strategy)));
    }
}

#[test]
fn epsilon_box_may_be_infeasible_but_never_panics() {
    // The ε-constrained strategy is allowed to find no plan (the paper's
    // criticism: ceilings must be guessed); the outcome must say so
    // cleanly rather than panic.
    let planner = fleet_planner();
    for bw in [0.1, 1.0, 10.0, 100.0] {
        let mut req = flat_request(Strategy::EpsilonConstrained);
        req.bandwidth_mbps = bw;
        let out = planner.plan(&req);
        assert_eq!(out.plan.is_some(), out.objectives.is_some());
        if let Some(p) = out.plan {
            assert!((1..21).contains(&p.l1));
        }
    }
}

#[test]
fn bands_shift_energy_weighting_through_the_facade() {
    let planner = fleet_planner();
    let model = Arc::new(zoo::vgg11().analyze(1));
    let plan_at = |band| {
        let req = PlanRequest::two_tier(
            Arc::clone(&model),
            profiles::redmi_note8(),
            band,
            10.0,
            Strategy::Topsis,
        );
        planner.plan(&req)
    };
    let comfort = plan_at(BatteryBand::Comfort);
    let critical = plan_at(BatteryBand::Critical);
    // Same invariant the coordinator::battery tests pin: the critical
    // choice must not cost more energy than the comfort one.
    assert!(
        critical.objectives.unwrap()[1] <= comfort.objectives.unwrap()[1] + 1e-12,
        "critical band chose a higher-energy split"
    );
    // Bands are distinct planner states.
    let mut ka = flat_request(Strategy::Topsis);
    ka.band = BatteryBand::Comfort;
    let mut kb = flat_request(Strategy::Topsis);
    kb.band = BatteryBand::Critical;
    assert_ne!(planner.key(&ka), planner.key(&kb));
}

#[test]
fn strategy_names_parse_case_insensitively_with_helpful_errors() {
    assert_eq!(Strategy::by_name("smartsplit"), Ok(Strategy::SmartSplit));
    assert_eq!(Strategy::by_name("TOPSIS"), Ok(Strategy::Topsis));
    assert_eq!(Strategy::by_name("lbo"), Ok(Strategy::Lbo));
    assert_eq!(Strategy::by_name("weightedsum"), Ok(Strategy::WeightedSum));
    let err = Strategy::by_name("bogus").unwrap_err();
    for s in Strategy::ALL {
        assert!(err.contains(s.name()), "error must list {}", s.name());
    }
}
