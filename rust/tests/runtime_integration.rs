//! PJRT runtime integration: real artifacts, real execution.
//! All tests skip when `make artifacts` has not run.

use std::path::{Path, PathBuf};

use smartsplit::runtime::executor::Executor;
use smartsplit::runtime::{ModelRuntime, Tensor};
use smartsplit::workload::synth_images;

fn artifacts() -> Option<PathBuf> {
    let p = Path::new("artifacts");
    if p.join("alexnet/manifest.json").exists() {
        Some(p.to_path_buf())
    } else {
        eprintln!("skipping: artifacts/ not built");
        None
    }
}

fn image(batch: usize, seed: u64) -> Tensor {
    Tensor::new(vec![batch, 3, 224, 224], synth_images(batch, 3, 224, seed)).unwrap()
}

#[test]
fn split_equals_unsplit_everywhere_it_matters() {
    // The core serving invariant: running 1..=l1 then l1+1..=k must equal
    // running 1..=k, for several split points across the conv trunk and
    // classifier boundary.
    let Some(dir) = artifacts() else { return };
    let client = xla::PjRtClient::cpu().unwrap();
    let rt = ModelRuntime::load(&client, &dir, "alexnet", 1).unwrap();
    let img = image(1, 11);
    let reference = rt.run_all(&client, &img).unwrap();
    assert_eq!(reference.shape, vec![1, 1000]);
    for l1 in [1usize, 3, 6, 13, 15, 16, 20] {
        let head = rt.run_segment(&client, 1, l1, &img).unwrap();
        let tail = rt.run_segment(&client, l1 + 1, 21, &head).unwrap();
        let max_diff = tail
            .data
            .iter()
            .zip(&reference.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-3, "split at {l1}: max diff {max_diff}");
        assert_eq!(tail.argmax_rows(), reference.argmax_rows(), "split at {l1}");
    }
}

#[test]
fn batch8_matches_batch1_rows() {
    let Some(dir) = artifacts() else { return };
    let client = xla::PjRtClient::cpu().unwrap();
    let rt1 = ModelRuntime::load(&client, &dir, "alexnet", 1).unwrap();
    let rt8 = ModelRuntime::load(&client, &dir, "alexnet", 8).unwrap();
    // One batch-8 tensor whose row 0 equals the batch-1 image.
    let single = image(1, 5);
    let mut data8 = Vec::with_capacity(single.data.len() * 8);
    for i in 0..8 {
        if i == 0 {
            data8.extend_from_slice(&single.data);
        } else {
            data8.extend_from_slice(&image(1, 100 + i as u64).data);
        }
    }
    let batch = Tensor::new(vec![8, 3, 224, 224], data8).unwrap();
    let out1 = rt1.run_all(&client, &single).unwrap();
    let out8 = rt8.run_all(&client, &batch).unwrap();
    assert_eq!(out8.shape, vec![8, 1000]);
    let row0 = &out8.data[..1000];
    let max_diff = row0
        .iter()
        .zip(&out1.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-3, "b8 row0 vs b1: {max_diff}");
}

#[test]
fn deterministic_across_runs() {
    let Some(dir) = artifacts() else { return };
    let client = xla::PjRtClient::cpu().unwrap();
    let rt = ModelRuntime::load(&client, &dir, "mobilenet_v2", 1).unwrap();
    let img = image(1, 3);
    let a = rt.run_all(&client, &img).unwrap();
    let b = rt.run_all(&client, &img).unwrap();
    assert_eq!(a.data, b.data);
}

#[test]
fn rejects_wrong_shapes_and_ranges() {
    let Some(dir) = artifacts() else { return };
    let client = xla::PjRtClient::cpu().unwrap();
    let rt = ModelRuntime::load(&client, &dir, "alexnet", 1).unwrap();
    let bad = Tensor::zeros(vec![1, 3, 32, 32]);
    assert!(rt.run_segment(&client, 1, 3, &bad).is_err());
    let img = image(1, 0);
    assert!(rt.run_segment(&client, 0, 3, &img).is_err());
    assert!(rt.run_segment(&client, 5, 3, &img).is_err());
    assert!(rt.run_segment(&client, 1, 99, &img).is_err());
}

#[test]
fn load_range_loads_partial_model() {
    let Some(dir) = artifacts() else { return };
    let client = xla::PjRtClient::cpu().unwrap();
    let head = ModelRuntime::load_range(&client, &dir, "alexnet", 1, 1, 3).unwrap();
    assert_eq!(head.num_layers(), 3);
    assert_eq!(head.loaded_range(), (1, 3));
    let out = head.run_all(&client, &image(1, 2)).unwrap();
    assert_eq!(out.shape, vec![1, 64, 27, 27]);
    // Out-of-range segment on a partial load errors.
    assert!(head.run_segment(&client, 1, 4, &image(1, 2)).is_err());
}

#[test]
fn executor_thread_confinement_works() {
    let Some(dir) = artifacts() else { return };
    let exec = Executor::spawn(dir, "test").unwrap();
    let info = exec.load("alexnet", 1).unwrap();
    assert_eq!(info.num_layers, 21);
    assert_eq!(info.input_shape, vec![1, 3, 224, 224]);

    // Drive it from multiple threads (the handle is Send + Clone).
    let mut handles = Vec::new();
    for seed in 0..3u64 {
        let exec = exec.clone();
        handles.push(std::thread::spawn(move || {
            let out = exec
                .run_segment("alexnet", 1, 1, 6, image(1, seed))
                .unwrap();
            assert_eq!(out.shape, vec![1, 192, 13, 13]);
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    // Unknown model errors cleanly.
    assert!(exec.run_segment("nope", 1, 1, 2, image(1, 0)).is_err());
    exec.stop();
}
