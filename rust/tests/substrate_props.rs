//! Property tests over the substrates: protocol robustness, JSON
//! round-tripping, histogram quantile sanity, shaped-link arithmetic, and
//! the model-spec memory algebra — driven by the in-repo `util::prop`
//! engine (DESIGN.md §4).

use std::io::Cursor;

use smartsplit::metrics::Histogram;
use smartsplit::models::zoo;
use smartsplit::netsim::Link;
use smartsplit::prop_assert;
use smartsplit::runtime::Tensor;
use smartsplit::serve::{read_msg, wire_size, write_msg, Msg};
use smartsplit::util::json::Json;
use smartsplit::util::prop::run_prop;

#[test]
fn prop_protocol_roundtrips_arbitrary_tensors() {
    run_prop("protocol tensor roundtrip", 200, |g| {
        let ndim = g.usize_in(1, 4);
        let shape: Vec<usize> = (0..ndim).map(|_| g.usize_in(1, 8)).collect();
        let n: usize = shape.iter().product();
        let data: Vec<f32> = (0..n).map(|_| g.f64_in(-1e6, 1e6) as f32).collect();
        let t = Tensor::new(shape, data).unwrap();
        let msg = Msg::Infer {
            request_id: g.usize_in(0, usize::MAX / 2) as u64,
            from_layer: g.usize_in(1, 40) as u32,
            tensor: t,
        };
        let mut buf = Vec::new();
        let written = write_msg(&mut buf, &msg).unwrap();
        prop_assert!(written == wire_size(&msg), "wire_size mismatch");
        let got = read_msg(&mut Cursor::new(buf)).unwrap();
        prop_assert!(got == msg, "roundtrip mismatch");
        Ok(())
    });
}

#[test]
fn prop_protocol_never_panics_on_random_bytes() {
    run_prop("protocol garbage safety", 300, |g| {
        let len = g.usize_in(0, 256);
        let bytes: Vec<u8> = (0..len).map(|_| g.usize_in(0, 255) as u8).collect();
        // Must return (Ok or Err), never panic / never allocate absurdly.
        let _ = read_msg(&mut Cursor::new(bytes));
        Ok(())
    });
}

#[test]
fn prop_json_roundtrips_generated_values() {
    fn gen_value(g: &mut smartsplit::util::prop::Gen, depth: usize) -> Json {
        match if depth == 0 { g.usize_in(0, 3) } else { g.usize_in(0, 5) } {
            0 => Json::Null,
            1 => Json::Bool(g.bool()),
            2 => Json::Num((g.f64_in(-1e9, 1e9) * 100.0).round() / 100.0),
            3 => Json::Str(format!("s{}", g.usize_in(0, 9999))),
            4 => Json::Arr((0..g.usize_in(0, 4)).map(|_| gen_value(g, depth - 1)).collect()),
            _ => Json::Obj(
                (0..g.usize_in(0, 4))
                    .map(|i| (format!("k{i}"), gen_value(g, depth - 1)))
                    .collect(),
            ),
        }
    }
    run_prop("json roundtrip", 200, |g| {
        let v = gen_value(g, 3);
        let parsed = Json::parse(&v.to_string()).map_err(|e| e.to_string())?;
        prop_assert!(parsed == v, "compact roundtrip: {v}");
        let pretty = Json::parse(&v.to_string_pretty()).map_err(|e| e.to_string())?;
        prop_assert!(pretty == v, "pretty roundtrip: {v}");
        Ok(())
    });
}

#[test]
fn prop_histogram_quantiles_bounded_and_monotone() {
    run_prop("histogram quantiles", 100, |g| {
        let h = Histogram::new();
        let n = g.usize_in(1, 500);
        let mut max = 0.0f64;
        let mut min = f64::INFINITY;
        for _ in 0..n {
            let v = g.f64_in(1e-6, 100.0);
            max = max.max(v);
            min = min.min(v);
            h.record_secs(v);
        }
        let q: Vec<f64> = [0.0, 0.25, 0.5, 0.75, 0.95, 1.0]
            .iter()
            .map(|&p| h.quantile(p))
            .collect();
        for w in q.windows(2) {
            prop_assert!(w[0] <= w[1] + 1e-12, "quantiles not monotone: {q:?}");
        }
        prop_assert!(q[0] >= min - 1e-12 && q[5] <= max + 1e-12, "out of range");
        prop_assert!(h.mean_s() >= min - 1e-9 && h.mean_s() <= max + 1e-9, "mean outside");
        Ok(())
    });
}

#[test]
fn prop_link_transfer_time_linear_in_bytes_and_inverse_in_bandwidth() {
    run_prop("link arithmetic", 100, |g| {
        let mbps = g.f64_in(0.1, 1000.0).max(0.05);
        let bytes = g.usize_in(1, 10_000_000) as u64;
        let link = Link::new(mbps);
        let base = link.base_latency.as_secs_f64();
        let t = link.transfer_time(bytes).as_secs_f64() - base;
        let expect = bytes as f64 * 8.0 / (mbps * 1e6);
        // Duration rounds to whole nanoseconds, so allow 4 ns of absolute
        // slack plus relative error for minute-scale transfers.
        let tol = |x: f64| 4e-9 + 1e-9 * x.abs();
        prop_assert!((t - expect).abs() < tol(expect), "t={t} expect={expect}");
        // doubling bandwidth halves transfer time
        link.set_bandwidth_mbps(mbps * 2.0);
        let t2 = link.transfer_time(bytes).as_secs_f64() - base;
        prop_assert!(
            (t - 2.0 * t2).abs() < tol(t),
            "not inverse-linear: t={t} t2={t2}"
        );
        Ok(())
    });
}

#[test]
fn prop_model_memory_algebra() {
    run_prop("memory algebra", 60, |g| {
        let name = *g.choice(&["alexnet", "vgg11", "vgg13", "vgg16", "mobilenet_v2"]);
        let batch = *g.choice(&[1usize, 2, 8]);
        let p = zoo::by_name(name).unwrap().analyze(batch);
        let total = p.client_memory_bytes(p.num_layers);
        let l1 = g.usize_in(1, p.num_layers);
        // partition
        prop_assert!(
            p.client_memory_bytes(l1) + p.server_memory_bytes(l1) == total,
            "{name} b{batch} l1={l1} partition"
        );
        // monotone
        if l1 > 1 {
            prop_assert!(
                p.client_memory_bytes(l1) >= p.client_memory_bytes(l1 - 1),
                "client memory not monotone"
            );
        }
        // I|l1 == following layer's input bytes
        if l1 < p.num_layers {
            let next_in: usize = p.layers[l1].in_shape.iter().product();
            prop_assert!(
                p.intermediate_bytes(l1) == next_in as u64 * 4,
                "I|{l1} mismatch"
            );
        }
        // flops partition
        prop_assert!(
            p.client_flops(l1) + p.server_flops(l1) == p.total_flops(),
            "flops partition"
        );
        Ok(())
    });
}

#[test]
fn prop_tensor_le_bytes_roundtrip() {
    run_prop("tensor wire roundtrip", 150, |g| {
        let n = g.usize_in(1, 2000);
        let data: Vec<f32> = (0..n)
            .map(|_| {
                let v = g.f64_in(-3.4e37, 3.4e37) as f32;
                if g.bool() { v } else { -v }
            })
            .collect();
        let t = Tensor::new(vec![n], data).unwrap();
        let rt = Tensor::from_le_bytes(vec![n], &t.to_le_bytes()).unwrap();
        prop_assert!(rt == t, "roundtrip mismatch");
        Ok(())
    });
}
