//! Observability integration: (a) traced timelines tile a request's
//! life *exactly* — span boundaries chain bit-for-bit from issue to
//! completion, so the per-stage durations account for the recorded
//! end-to-end latency with no gaps and no overlaps, in the static
//! tiered city and in the mobile city (handover relays included);
//! (b) enabling tracing/metrics is transparent — decisions, event
//! counts, and planner accounting are byte-identical to a dark run;
//! (c) the JSONL / Chrome-trace / metrics-JSON exports are
//! byte-identical across thread configurations and repeat runs;
//! (d) windowed metrics partition the run: per-window counters sum to
//! the run totals and window boundaries are contiguous.

use smartsplit::planner::ReplanReason;
use smartsplit::sim::{self, ObservabilityConfig};
use smartsplit::trace::{CausalEvent, SpanKind, TraceReport};

/// Pipeline position of each span kind; a request's spans must be
/// strictly increasing in this rank (each stage at most once).
fn rank(kind: SpanKind) -> u32 {
    match kind {
        SpanKind::DeviceQueue => 0,
        SpanKind::HeadCompute => 1,
        SpanKind::Uplink => 2,
        SpanKind::EdgeQueue => 3,
        SpanKind::EdgeService => 4,
        SpanKind::Backhaul => 5,
        SpanKind::CloudQueue => 6,
        SpanKind::CloudService => 7,
        SpanKind::Downlink => 8,
    }
}

/// The tiling property: every sampled request's spans are ordered,
/// non-overlapping, and chain *exactly* (f64 equality, no epsilon)
/// from `issued_s` to `completed_s` — which is precisely the statement
/// that the per-stage durations, gaps accounted, sum to the recorded
/// end-to-end latency.
fn assert_tiling(report: &TraceReport) {
    assert!(!report.requests.is_empty(), "no requests traced");
    for t in &report.requests {
        assert!(t.completed_s.is_finite(), "req {} never completed", t.req);
        assert!(t.latency_s() >= 0.0);
        let spans = &t.spans;
        assert!(spans.len() >= 4, "req {} has only {} spans", t.req, spans.len());
        assert_eq!(
            spans.first().unwrap().start_s,
            t.issued_s,
            "req {}: timeline does not start at issue",
            t.req
        );
        assert_eq!(
            spans.last().unwrap().end_s,
            t.completed_s,
            "req {}: timeline does not end at completion",
            t.req
        );
        assert_eq!(spans.last().unwrap().kind, SpanKind::Downlink);
        for (i, s) in spans.iter().enumerate() {
            assert!(
                s.start_s.is_finite() && s.end_s.is_finite(),
                "req {} span {i} ({:?}) left open",
                t.req,
                s.kind
            );
            assert!(
                s.end_s >= s.start_s,
                "req {} span {i} ({:?}) has negative duration",
                t.req,
                s.kind
            );
        }
        for w in spans.windows(2) {
            // Exact chaining — no gap, no overlap, no epsilon. The
            // recorder mirrors the engine's scheduling arithmetic.
            assert_eq!(
                w[0].end_s, w[1].start_s,
                "req {}: gap/overlap between {:?} and {:?}",
                t.req, w[0].kind, w[1].kind
            );
            assert!(
                rank(w[0].kind) < rank(w[1].kind),
                "req {}: {:?} out of pipeline order vs {:?}",
                t.req,
                w[0].kind,
                w[1].kind
            );
        }
        // Mandatory stages: queue wait (possibly zero-length), head
        // compute, uplink.
        for need in [SpanKind::DeviceQueue, SpanKind::HeadCompute, SpanKind::Uplink] {
            assert!(
                spans.iter().any(|s| s.kind == need),
                "req {} is missing {need:?}",
                t.req
            );
        }
        // Queue/service pairing: an edge (cloud) service span implies
        // its queue span, carrying the same site.
        for (q, svc) in [
            (SpanKind::EdgeQueue, SpanKind::EdgeService),
            (SpanKind::CloudQueue, SpanKind::CloudService),
        ] {
            let sq = spans.iter().find(|s| s.kind == q);
            let ss = spans.iter().find(|s| s.kind == svc);
            assert_eq!(sq.is_some(), ss.is_some(), "req {}: unpaired {q:?}/{svc:?}", t.req);
            if let (Some(a), Some(b)) = (sq, ss) {
                assert_eq!(a.site, b.site, "req {}: queue/service site mismatch", t.req);
                assert!(a.site.is_some());
            }
        }
    }
}

#[test]
fn tiered_city_timelines_tile_exactly() {
    let mut cfg = sim::city_scale_tiered("alexnet", 300, 3, 90.0, 7);
    cfg.observability = ObservabilityConfig::full(10.0);
    let r = sim::run(&cfg).expect("tiered run");
    let tr = r.trace.as_ref().expect("tracing was on");
    // The queue drained, so every sampled request either completed or
    // was dropped *before* tracing began (drops never open a timeline).
    assert_eq!(tr.unfinished, 0, "open timelines after drain");
    assert_eq!(tr.requests.len() as u64, r.completed, "sample=1 must trace every completion");
    assert_tiling(tr);
    // The tiered city actually exercises the edge stages.
    assert!(
        tr.requests
            .iter()
            .any(|t| t.spans.iter().any(|s| s.kind == SpanKind::EdgeService)),
        "no traced request crossed the edge tier"
    );
    // Spawn provenance: one spawn-tagged replan annotation per device.
    let spawns = tr
        .events
        .iter()
        .filter(
            |e| matches!(e, CausalEvent::Replan { reason: ReplanReason::Spawn, .. }),
        )
        .count() as u64;
    assert_eq!(
        spawns,
        r.planner.requests_by_reason[ReplanReason::Spawn.index()],
        "spawn annotations disagree with planner accounting"
    );
    // Annotations are recorded in nondecreasing virtual time (the sim
    // notes them as the clock advances).
    for w in tr.events.windows(2) {
        assert!(w[0].t_s() <= w[1].t_s(), "annotations out of time order");
    }
}

#[test]
fn mobile_city_timelines_tile_across_handovers() {
    let mut cfg = sim::city_mobile("alexnet", 400, 3, 120.0, 9);
    cfg.observability = ObservabilityConfig::full(12.0);
    let r = sim::run(&cfg).expect("mobile run");
    let tr = r.trace.as_ref().expect("tracing was on");
    assert_eq!(tr.unfinished, 0);
    assert_eq!(tr.requests.len() as u64, r.completed);
    // In-flight work issued before a handover still tiles exactly: the
    // costs were captured at issue, the relay is charged separately.
    assert_tiling(tr);

    assert!(r.handovers > 0, "mobile city produced no handovers");
    let relays = tr
        .events
        .iter()
        .filter(|e| matches!(e, CausalEvent::HandoverRelay { .. }))
        .count() as u64;
    let reattaches = tr
        .events
        .iter()
        .filter(|e| matches!(e, CausalEvent::Reattach { .. }))
        .count() as u64;
    let migrations = tr
        .events
        .iter()
        .filter(
            |e| matches!(e, CausalEvent::Replan { reason: ReplanReason::Migration, .. }),
        )
        .count() as u64;
    // Every completed handover re-attached; superseded relays may
    // outnumber them (a quick back-crossing cancels the older relay).
    assert_eq!(reattaches, r.handovers, "one reattach annotation per handover");
    assert!(relays >= r.handovers, "{relays} relays < {} handovers", r.handovers);
    assert_eq!(
        migrations,
        r.planner.migration_requests(),
        "migration annotations disagree with planner accounting"
    );
    for e in &tr.events {
        if let CausalEvent::HandoverRelay { start_s, end_s, from_site, to_site, .. } = e {
            assert!(end_s >= start_s, "relay with negative duration");
            assert_ne!(from_site, to_site, "relay to the serving site");
        }
    }
}

#[test]
fn observability_is_transparent_to_the_simulation() {
    // Byte-identical decisions, events, and planner accounting whether
    // the sinks are on or off — observation must not perturb the run.
    let mut dark = sim::city_scale_tiered("alexnet", 300, 3, 90.0, 7);
    dark.planner_perf.record_decisions = true;
    let mut lit = dark.clone();
    lit.observability = ObservabilityConfig::full(10.0);

    let a = sim::run(&dark).expect("dark run");
    let b = sim::run(&lit).expect("observed run");
    assert!(a.series.is_none() && a.trace.is_none());
    assert!(b.series.is_some() && b.trace.is_some());
    assert!(!a.decisions.is_empty());
    assert_eq!(a.decisions, b.decisions, "observation changed a split decision");
    assert_eq!(a.summary(), b.summary(), "observation changed the measured run");
    assert_eq!(a.events, b.events, "observation changed the event stream");
    assert_eq!(a.generated, b.generated);
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.dropped, b.dropped);
    assert_eq!(a.planner, b.planner, "observation perturbed planner accounting");
    assert_eq!(a.split_distribution, b.split_distribution);
}

/// Exports for one config: (JSONL trace, Chrome trace, metrics JSON).
fn exports(cfg: &sim::SimConfig) -> (String, String, String) {
    let r = sim::run(cfg).expect("sim run");
    let tr = r.trace.as_ref().expect("tracing was on");
    let ts = r.series.as_ref().expect("series was on");
    (tr.to_jsonl(), tr.to_chrome_trace(), ts.to_json().to_string_pretty())
}

fn assert_exports_stable(mut cfg: sim::SimConfig) {
    cfg.observability = ObservabilityConfig::full(15.0);
    cfg.planner_perf.parallel = true;
    let mut sequential = cfg.clone();
    sequential.planner_perf.parallel = false;

    let a = exports(&cfg);
    let b = exports(&sequential);
    let c = exports(&cfg);
    assert!(a.0.lines().count() > 2, "trivial JSONL export");
    assert_eq!(a.0, b.0, "JSONL trace differs across thread configs");
    assert_eq!(a.1, b.1, "Chrome trace differs across thread configs");
    assert_eq!(a.2, b.2, "metrics JSON differs across thread configs");
    assert_eq!(a.0, c.0, "JSONL trace differs across reruns");
    assert_eq!(a.1, c.1, "Chrome trace differs across reruns");
    assert_eq!(a.2, c.2, "metrics JSON differs across reruns");
}

#[test]
fn tiered_exports_are_byte_identical_across_thread_configs() {
    assert_exports_stable(sim::city_scale_tiered("alexnet", 300, 3, 90.0, 7));
}

#[test]
fn mobile_exports_are_byte_identical_across_thread_configs() {
    assert_exports_stable(sim::city_mobile("alexnet", 400, 3, 120.0, 9));
}

#[test]
fn windows_partition_the_run() {
    let mut cfg = sim::city_scale_tiered("alexnet", 300, 3, 90.0, 11);
    cfg.observability.window_s = 10.0; // metrics only, no tracing
    let r = sim::run(&cfg).expect("tiered run");
    assert!(r.trace.is_none());
    let ts = r.series.as_ref().expect("series was on");
    assert_eq!(ts.window_s, 10.0);
    assert!(ts.windows.len() >= 9, "only {} windows for a 90 s run", ts.windows.len());

    // Contiguous coverage from t=0 to the drained clock.
    assert_eq!(ts.windows[0].start_s, 0.0);
    for w in ts.windows.windows(2) {
        assert_eq!(w[0].end_s, w[1].start_s, "window gap at {}", w[0].end_s);
        assert_eq!(w[0].index + 1, w[1].index);
    }
    let last = ts.windows.last().unwrap();
    assert!(
        (last.end_s - r.sim_end_s).abs() < 1e-9,
        "series ends at {} but the clock drained at {}",
        last.end_s,
        r.sim_end_s
    );

    // Per-window counters partition the run totals exactly.
    let sum = |f: fn(&smartsplit::metrics::WindowSummary) -> u64| -> u64 {
        ts.windows.iter().map(f).sum()
    };
    assert_eq!(sum(|w| w.generated), r.generated);
    assert_eq!(sum(|w| w.completed), r.completed);
    assert_eq!(sum(|w| w.dropped), r.dropped);
    assert_eq!(sum(|w| w.resplits), r.resplits);
    assert_eq!(sum(|w| w.handovers), r.handovers);
    assert_eq!(sum(|w| w.migration_replans), r.migration_replans);
    assert_eq!(sum(|w| w.cache_hits), r.planner.cache_hits);
    assert_eq!(sum(|w| w.cache_misses), r.planner.cache_misses);
    assert_eq!(sum(|w| w.latency.count), r.completed);

    // Tier quantiles and pool gauges stay sane in every window.
    for w in &ts.windows {
        assert_eq!(w.edges.len(), r.edges.len());
        assert_eq!(w.clouds.len(), r.clouds.len());
        let hr = w.hit_rate();
        assert!((0.0..=1.0).contains(&hr), "hit rate {hr} outside [0,1]");
        for tier in [&w.latency, &w.device_queue, &w.edge_queue, &w.cloud_queue] {
            if tier.count > 0 {
                assert!(tier.p50_s <= tier.p95_s + 1e-12);
                assert!(tier.p95_s <= tier.p99_s + 1e-12);
                assert!(tier.p99_s <= tier.max_s + 1e-12);
            }
        }
        for p in w.edges.iter().chain(&w.clouds) {
            assert!(p.utilization >= 0.0 && p.utilization.is_finite());
        }
    }
    assert_eq!(ts.hit_rate_curve().len(), ts.windows.len());
}

/// Property form of the partition invariant: whatever the window
/// length — commensurate with the horizon or not — the per-window
/// counters sum to the run totals and the series tiles `[0, sim_end]`
/// contiguously. The drained clock usually lands strictly inside the
/// final window, which is exactly the partial tail `finalize` must
/// flush (the zero-width boundary case is pinned by a unit test in
/// `metrics::timeseries`).
#[test]
fn windows_partition_the_run_for_any_window_length() {
    use smartsplit::prop_assert;
    use smartsplit::util::prop::run_prop;
    run_prop("windowed counters partition run totals", 6, |g| {
        let devices = g.usize_in(60, 150);
        let duration = *g.choice(&[30.0, 45.0, 60.0]);
        let seed = g.usize_in(1, 9999) as u64;
        let mut cfg = sim::city_scale_tiered("alexnet", devices, 2, duration, seed);
        cfg.observability.window_s = if g.bool() {
            duration / (g.usize_in(2, 6) as f64)
        } else {
            g.f64_in(3.0, 25.0)
        };
        let r = sim::run(&cfg).map_err(|e| format!("sim failed: {e}"))?;
        let ts = r.series.as_ref().ok_or_else(|| "series missing".to_string())?;
        prop_assert!(!ts.windows.is_empty(), "no windows emitted");
        prop_assert!(
            ts.windows[0].start_s == 0.0,
            "first window starts at {}",
            ts.windows[0].start_s
        );
        for w in ts.windows.windows(2) {
            prop_assert!(
                w[0].end_s == w[1].start_s && w[0].index + 1 == w[1].index,
                "window gap/reorder at {}",
                w[0].end_s
            );
        }
        let last_end = ts.windows.last().unwrap().end_s;
        prop_assert!(
            last_end == r.sim_end_s,
            "series ends at {last_end} but the clock drained at {}",
            r.sim_end_s
        );
        let sum = |f: fn(&smartsplit::metrics::WindowSummary) -> u64| -> u64 {
            ts.windows.iter().map(f).sum()
        };
        for (name, got, want) in [
            ("generated", sum(|w| w.generated), r.generated),
            ("completed", sum(|w| w.completed), r.completed),
            ("dropped", sum(|w| w.dropped), r.dropped),
            ("resplits", sum(|w| w.resplits), r.resplits),
            ("handovers", sum(|w| w.handovers), r.handovers),
            ("cache_hits", sum(|w| w.cache_hits), r.planner.cache_hits),
            ("cache_misses", sum(|w| w.cache_misses), r.planner.cache_misses),
            ("latency.count", sum(|w| w.latency.count), r.completed),
        ] {
            prop_assert!(got == want, "{name}: windows sum to {got}, run total {want}");
        }
        Ok(())
    });
}

#[test]
fn trace_sampling_records_every_nth_request() {
    let mut cfg = sim::city_scale_tiered("alexnet", 300, 3, 90.0, 7);
    cfg.observability.trace_sample_every = 3;
    let r = sim::run(&cfg).expect("tiered run");
    let tr = r.trace.as_ref().expect("tracing was on");
    assert_eq!(tr.sample_every, 3);
    assert_eq!(tr.unfinished, 0);
    assert!(!tr.requests.is_empty());
    assert!((tr.requests.len() as u64) < r.completed, "sampling recorded everything");
    for t in &tr.requests {
        assert_eq!(t.req % 3, 0, "off-sample request {} recorded", t.req);
    }
    assert_tiling(tr);
}
