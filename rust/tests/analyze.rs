//! Analyze-plane integration: (a) per-request stage attribution
//! *partitions* end-to-end latency — the nine shares re-fold to
//! `completed_s - issued_s` bit-for-bit in the mobile city (handover
//! relays in flight) and in the faulty city (reroutes in flight);
//! (b) the assembled analyze report is byte-identical across thread
//! configurations and reruns; (c) a run diffed against itself is
//! exactly empty; (d) analysing the serialized exports offline
//! reproduces the in-process analysis byte-for-byte, so CI gating on
//! files and tests gating on live reports agree by construction.

use smartsplit::analyze::{diff_reports, AnalyzeReport, RunData, Slo};
use smartsplit::sim::{self, ObservabilityConfig};

/// A representative SLO mix: two that comfortably hold, one latency
/// bound tight enough to exercise the violation path on these runs.
fn slos() -> Vec<Slo> {
    ["p99<30s", "p50<0.2s", "drop<50%"]
        .iter()
        .map(|s| Slo::parse(s).expect("slo grammar"))
        .collect()
}

fn assert_exact_partition(data: &RunData) {
    assert!(!data.requests.is_empty(), "no requests to attribute");
    for rec in &data.requests {
        assert!(rec.shares.iter().all(|d| d.is_finite()));
        // The partition property: re-folding the nine stage shares in
        // pipeline order reproduces the recorded latency exactly — f64
        // bit equality, no epsilon (DESIGN.md §14).
        assert_eq!(
            rec.share_sum().to_bits(),
            rec.latency_s().to_bits(),
            "req {}: shares {:?} do not re-fold to latency {} bit-for-bit",
            rec.req,
            rec.shares,
            rec.latency_s()
        );
    }
}

#[test]
fn stage_shares_partition_latency_exactly_in_the_mobile_city() {
    let mut cfg = sim::city_mobile("alexnet", 400, 3, 120.0, 9);
    cfg.observability = ObservabilityConfig::full(12.0);
    let r = sim::run(&cfg).expect("mobile run");
    assert!(r.handovers > 0, "mobile city exercised no handovers");
    let data = RunData::from_report(&r).expect("analysis inputs");
    // sample=1: one record per completion, even through relays.
    assert_eq!(data.requests.len() as u64, r.completed);
    assert_exact_partition(&data);
}

#[test]
fn stage_shares_partition_latency_exactly_under_faults() {
    let mut cfg = sim::city_faulty("alexnet", 500, 3, 120.0, 7);
    cfg.observability = ObservabilityConfig::full(12.0);
    let r = sim::run(&cfg).expect("faulty run");
    assert!(r.fault_events > 0, "faulty city fired no faults");
    let data = RunData::from_report(&r).expect("analysis inputs");
    assert_eq!(data.requests.len() as u64, r.completed);
    // Rerouted requests still tile (the reroute re-issues downstream
    // stages on the fallback path; the recorder mirrors the engine).
    assert_exact_partition(&data);

    // The fault audit pairs the scenario's annotations into closed
    // intervals and charges in-interval impact.
    assert!(!data.faults.is_empty(), "no fault annotations in the trace");
    let audit = smartsplit::analyze::slo::fault_impact(&data);
    assert!(
        audit.intervals.len() >= 3,
        "only {} fault interval(s) from the city-faulty schedule",
        audit.intervals.len()
    );
    for iv in &audit.intervals {
        assert!(iv.end_s >= iv.start_s, "{}: interval runs backwards", iv.kind);
        assert!(iv.end_s <= data.horizon_s, "{}: interval past the horizon", iv.kind);
    }
    if r.requests_rerouted > 0 {
        let charged: u64 = audit.intervals.iter().map(|iv| iv.reroutes).sum();
        assert!(charged > 0, "reroutes happened but no interval charged any");
    }
}

/// One analyze-report document for a config (pretty JSON string).
fn report_doc(cfg: &sim::SimConfig) -> String {
    let r = sim::run(cfg).expect("sim run");
    let data = RunData::from_report(&r).expect("analysis inputs");
    AnalyzeReport::build(&data, &slos()).to_json().to_string_pretty()
}

#[test]
fn analyze_reports_are_byte_identical_across_thread_configs_and_reruns() {
    let mut cfg = sim::city_faulty("alexnet", 400, 3, 90.0, 7);
    cfg.observability = ObservabilityConfig::full(15.0);
    cfg.planner_perf.parallel = true;
    let mut sequential = cfg.clone();
    sequential.planner_perf.parallel = false;

    let a = report_doc(&cfg);
    let b = report_doc(&sequential);
    let c = report_doc(&cfg);
    assert!(a.len() > 500, "trivial analyze report");
    assert_eq!(a, b, "analyze report differs across thread configs");
    assert_eq!(a, c, "analyze report differs across reruns");
}

#[test]
fn self_diff_of_a_real_run_is_exactly_empty() {
    let mut cfg = sim::city_mobile("alexnet", 400, 3, 120.0, 9);
    cfg.observability = ObservabilityConfig::full(12.0);
    let r = sim::run(&cfg).expect("mobile run");
    let data = RunData::from_report(&r).expect("analysis inputs");
    let doc = AnalyzeReport::build(&data, &slos()).to_json();
    let d = diff_reports(&doc, &doc);
    assert!(
        d.is_empty(),
        "self-diff produced {} change(s): first = {:?}",
        d.changes.len(),
        d.changes.first().map(|c| &c.path)
    );
    assert_eq!(d.regressions, 0);
    assert_eq!(d.improvements, 0);
}

#[test]
fn offline_exports_reproduce_the_in_process_analysis_byte_for_byte() {
    let mut cfg = sim::city_faulty("alexnet", 400, 3, 90.0, 7);
    cfg.observability = ObservabilityConfig::full(15.0);
    let r = sim::run(&cfg).expect("faulty run");
    let inproc = RunData::from_report(&r).expect("in-process inputs");

    // The same two documents `simulate --trace-out/--metrics-out` write.
    let jsonl = r.trace.as_ref().expect("tracing on").to_jsonl();
    let metrics = r.metrics_json().expect("series on").to_string_pretty();
    let offline =
        RunData::from_export_strs(Some(&jsonl), Some(&metrics)).expect("offline inputs");

    assert_eq!(offline.requests.len(), inproc.requests.len());
    assert_exact_partition(&offline);
    let sl = slos();
    let a = AnalyzeReport::build(&inproc, &sl).to_json().to_string_pretty();
    let b = AnalyzeReport::build(&offline, &sl).to_json().to_string_pretty();
    assert_eq!(a, b, "offline export round-trip changed the analysis");
}
