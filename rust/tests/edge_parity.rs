//! Edge-tier integration: (a) the zero-edge degenerate configuration
//! (relay-only sites over a free backhaul) reproduces the two-tier
//! decision stream and downstream metrics exactly; (b) a real tiered
//! scenario is bit-identical under one seed (per-tier queue histograms
//! included) and actually places torso work at the edge; (c) mobility —
//! `Mobility::Static` replays the immobile tiered city byte-for-byte,
//! while the `city_mobile` waypoint walk produces real handovers and
//! migration re-solves with a decision stream that is independent of
//! the planner's thread configuration; (d) the epoch guard — a stale
//! `Reattach { seq }` superseded by an outage-forced re-attachment is
//! dropped, so no device ever lands on a dead site.

use smartsplit::planner::ReplanReason;
use smartsplit::sim::{self, EdgeSpec, FaultPlan, Mobility};
use smartsplit::trace::CausalEvent;
use smartsplit::workload::Arrival;

#[test]
fn degenerate_edge_reproduces_two_tier_decision_stream() {
    let mut flat = sim::city_scale("alexnet", 300, 120.0, 21);
    flat.planner_perf.record_decisions = true;
    let mut relay = flat.clone();
    relay.edge = Some(EdgeSpec::degenerate_relay(3));

    let a = sim::run(&flat).expect("two-tier run");
    let b = sim::run(&relay).expect("degenerate tiered run");

    // Byte-identical decision stream: same devices, same l1, and the
    // relay run must never grow a torso.
    assert!(!a.decisions.is_empty(), "scenario exercised no planning");
    assert_eq!(a.decisions.len(), b.decisions.len());
    for (x, y) in a.decisions.iter().zip(&b.decisions) {
        assert_eq!((x.0, x.1), (y.0, y.1), "relay tier changed a split decision");
        assert_eq!(x.1, x.2, "flat run produced a torso plan");
        assert_eq!(y.1, y.2, "relay run produced a torso plan");
    }
    // ... and identical everything downstream of the decisions: the
    // empty-hop fast path must keep the event stream itself unchanged.
    assert_eq!(a.events, b.events, "degenerate tier changed the event stream");
    assert_eq!(a.generated, b.generated);
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.dropped, b.dropped);
    assert_eq!(a.resplits, b.resplits);
    assert_eq!(a.reopt_sweeps, b.reopt_sweeps);
    assert_eq!(a.devices_created, b.devices_created);
    assert_eq!(a.batteries_exhausted, b.batteries_exhausted);
    assert_eq!(a.latency.summary(), b.latency.summary());
    assert_eq!(a.queue_delay.summary(), b.queue_delay.summary());
    assert_eq!(a.device_queue_delay.summary(), b.device_queue_delay.summary());
    assert_eq!(a.split_distribution, b.split_distribution);
    assert!(
        (a.client_energy_j - b.client_energy_j).abs() == 0.0
            && (a.upload_energy_j - b.upload_energy_j).abs() == 0.0,
        "device energy must be untouched by a free relay tier"
    );
    // The relay tier itself must have stayed perfectly idle.
    assert_eq!(b.edge_queue_delay.count(), 0);
    assert!(b.edges.iter().all(|e| e.served == 0), "torso work on a relay-only site");
}

#[test]
fn tiered_city_runs_are_bit_identical_under_one_seed() {
    let cfg = sim::city_scale_tiered("alexnet", 800, 3, 120.0, 42);
    let a = sim::run(&cfg).expect("tiered run a");
    let b = sim::run(&cfg).expect("tiered run b");
    assert_eq!(a.summary(), b.summary());
    assert_eq!(a.events, b.events);
    assert_eq!(a.generated, b.generated);
    assert_eq!(a.devices_created, b.devices_created);
    assert_eq!(a.split_distribution, b.split_distribution);
    // Per-tier queue histograms are part of the reproducible surface.
    assert_eq!(a.edge_queue_delay.summary(), b.edge_queue_delay.summary());
    assert_eq!(a.device_queue_delay.summary(), b.device_queue_delay.summary());
    assert_eq!(a.queue_delay.summary(), b.queue_delay.summary());
    // The run did tiered things: torso plans exist and edge sites served.
    assert!(a.completed > 500, "only {} completed", a.completed);
    assert!(
        a.split_distribution.iter().any(|(p, _)| !p.is_two_tier()),
        "no tiered plan adopted: {:?}",
        a.split_distribution
    );
    assert!(
        a.edges.iter().map(|e| e.served).sum::<u64>() > 0,
        "no torso work reached the edge tier"
    );
    assert_eq!(a.edges.len(), 3);
}

#[test]
fn tiered_request_conservation_holds() {
    let cfg = sim::city_scale_tiered("alexnet", 400, 3, 90.0, 11);
    let r = sim::run(&cfg).expect("tiered run");
    // Every generated request either completed or was dropped — nothing
    // may get lost crossing the extra tier.
    assert_eq!(r.generated, r.completed + r.dropped);
    // Cloud serves the tail-bearing subset (edge-terminal plans with
    // `l2 == L` complete at the edge and never occupy a cloud server);
    // edge sites serve the torso-bearing subset.
    let cloud_served: u64 = r.clouds.iter().map(|c| c.served).sum();
    let edge_served: u64 = r.edges.iter().map(|e| e.served).sum();
    assert!(cloud_served <= r.completed, "cloud served more than completed");
    assert!(edge_served <= r.completed, "edge served more than completed");
    // The edge-slower-than-cloud profile keeps real tails in the cloud:
    // both tiers must actually serve work in the tiered city.
    assert!(cloud_served > 0, "no tail work reached the cloud");
    assert!(edge_served > 0, "no torso work reached the edge");
}

#[test]
fn static_mobility_replays_the_tiered_city_byte_for_byte() {
    // `city_mobile` differs from `city_scale_tiered` only in its
    // mobility model; freezing it back to Static must therefore replay
    // the pre-mobility scenario exactly — no extra events, no extra RNG
    // draws, no decision drift. This is the zero-mobility degeneracy
    // contract (DESIGN.md §9). Note the equality half is partly
    // structural (both arms build the same config value, pinned by
    // scenario::tests::mobile_preset_only_differs_by_mobility); the
    // load-bearing signal here is the zero mobility counters below plus
    // determinism across the two construction paths.
    let mut tiered = sim::city_scale_tiered("alexnet", 400, 3, 120.0, 21);
    tiered.planner_perf.record_decisions = true;
    let mut frozen = sim::city_mobile("alexnet", 400, 3, 120.0, 21);
    frozen.mobility = Mobility::Static;
    frozen.planner_perf.record_decisions = true;

    let a = sim::run(&tiered).expect("tiered run");
    let b = sim::run(&frozen).expect("frozen mobile run");

    assert!(!a.decisions.is_empty(), "scenario exercised no planning");
    assert_eq!(a.decisions, b.decisions, "Static mobility changed a split decision");
    assert_eq!(a.summary(), b.summary());
    assert_eq!(a.events, b.events, "Static mobility changed the event stream");
    assert_eq!(a.generated, b.generated);
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.dropped, b.dropped);
    assert_eq!(a.resplits, b.resplits);
    assert_eq!(a.reopt_sweeps, b.reopt_sweeps);
    assert_eq!(a.devices_created, b.devices_created);
    assert_eq!(a.split_distribution, b.split_distribution);
    assert_eq!(a.planner, b.planner, "Static mobility perturbed planner accounting");
    assert_eq!(a.latency.summary(), b.latency.summary());
    assert_eq!(a.edge_queue_delay.summary(), b.edge_queue_delay.summary());
    // Neither run moved anything.
    assert_eq!((a.handovers, a.migration_replans), (0, 0));
    assert_eq!((b.handovers, b.migration_replans), (0, 0));
    assert_eq!(b.planner.migration_requests(), 0);
}

#[test]
fn mobile_city_reports_handovers_and_migration_resolves() {
    let mut cfg = sim::city_mobile("alexnet", 600, 3, 120.0, 33);
    cfg.planner_perf.record_decisions = true;
    let r = sim::run(&cfg).expect("mobile run");

    // The walk actually moved devices between sites...
    assert!(r.handovers > 0, "no handovers in the mobile city");
    // ... and every completed handover re-planned through the façade,
    // tagged as a migration (visible in both the sim counters and the
    // planner's per-reason request tally).
    assert!(r.migration_replans > 0, "handovers adopted no migration re-solves");
    assert!(
        r.planner.migration_requests() >= r.migration_replans,
        "{} migration requests < {} adopted migration re-plans",
        r.planner.migration_requests(),
        r.migration_replans
    );
    assert!(
        r.planner.requests_by_reason[ReplanReason::Spawn.index()] >= r.devices_created as u64,
        "every spawn is a spawn-tagged planner request"
    );
    // Conservation still holds across the extra event class.
    assert_eq!(r.generated, r.completed + r.dropped);
    // Decision stream stays inside the ordered tiered domain.
    assert!(!r.decisions.is_empty());
    for &(_, l1, l2) in &r.decisions {
        assert!(l1 <= l2, "unordered decision ({l1}, {l2})");
    }
    // Migration re-solves are re-plans of live devices: the decision
    // count must cover spawns plus adopted re-plans.
    assert!(r.decision_count >= r.devices_created as u64 + r.migration_replans);
}

#[test]
fn mobile_decision_stream_is_thread_config_independent() {
    // Same seed ⇒ byte-identical decision streams whether cache-miss
    // solves fan out over the worker pool or run sequentially inline —
    // mobility draws come from per-device streams, and solve seeds from
    // quantised keys, so thread count cannot perturb either.
    let mut parallel = sim::city_mobile("alexnet", 400, 3, 120.0, 9);
    parallel.planner_perf.record_decisions = true;
    parallel.planner_perf.parallel = true;
    let mut sequential = parallel.clone();
    sequential.planner_perf.parallel = false;

    let a = sim::run(&parallel).expect("parallel run");
    let b = sim::run(&sequential).expect("sequential run");
    assert!(!a.decisions.is_empty());
    assert_eq!(a.decisions, b.decisions, "thread fan-out changed a mobile decision");
    assert_eq!(a.summary(), b.summary());
    assert_eq!(a.events, b.events);
    assert_eq!(a.handovers, b.handovers);
    assert_eq!(a.migration_replans, b.migration_replans);
    assert_eq!(a.planner, b.planner, "fan-out perturbed planner accounting");

    // And the run is bit-identical to itself on a re-run.
    let c = sim::run(&parallel).expect("parallel rerun");
    assert_eq!(a.decisions, c.decisions);
    assert_eq!(a.summary(), c.summary());
}

#[test]
fn stale_reattach_superseded_by_outage_is_ignored() {
    // Taking a site out mid-walk storms every device targeting it
    // through a new handover epoch; mobility `Reattach` events already
    // in flight toward that site carry the old sequence number and must
    // be dropped on arrival. The trace's reattach annotations are the
    // observable: the recorder only notes a reattach after the sequence
    // guard admits it, so none may target the dead site inside the
    // outage window.
    let (down_s, up_s) = (30.0, 90.0);
    let mut cfg = sim::city_mobile("alexnet", 600, 3, 120.0, 33);
    cfg.observability.trace_sample_every = 1;
    cfg.faults = FaultPlan::parse("30 site-down 1\n90 site-up 1").expect("scripted outage");
    let r = sim::run(&cfg).expect("faulty mobile run");

    let tr = r.trace.as_ref().expect("tracing was enabled");
    let mut landed = 0u64;
    for e in &tr.events {
        if let CausalEvent::Reattach { t_s, device, site, .. } = *e {
            landed += 1;
            assert!(
                !(site == 1 && t_s > down_s && t_s < up_s),
                "device {device} reattached to dead site 1 at {t_s:.3}s \
                 (outage window {down_s}-{up_s}s)"
            );
        }
    }
    assert!(landed > 0, "no reattach landed at all");
    // The storm really happened alongside ordinary mobility, and the
    // extra event class loses nothing.
    assert!(r.failover_reattaches > 0, "outage forced no reattaches");
    assert!(r.handovers > 0, "mobility produced no handovers");
    assert_eq!(r.generated, r.completed + r.dropped);
}

#[test]
fn starved_edge_site_shows_torso_queueing() {
    // One edge server per site for a heavy open-loop load: the per-site
    // M/G/c queues must register real torso waiting — the contention
    // term neither the two-tier sim nor Eq. 5 can see.
    let mut cfg = sim::city_scale_tiered("alexnet", 200, 3, 60.0, 5);
    if let Some(edge) = cfg.edge.as_mut() {
        edge.servers_per_site = 1;
    }
    cfg.churn = None;
    cfg.arrival = Arrival::Poisson { rps: 40.0 };
    let r = sim::run(&cfg).expect("tiered run");
    assert!(r.completed > 0);
    let edge_served: u64 = r.edges.iter().map(|e| e.served).sum();
    assert!(edge_served > 0, "no torso work at the edge");
    assert!(
        r.edge_queue_delay.max_s() > 0.0,
        "no torso queueing despite starved edge sites"
    );
}
