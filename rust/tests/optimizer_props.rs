//! Property-based tests on the optimiser invariants (the in-repo `prop`
//! substrate stands in for proptest — DESIGN.md §4).

use smartsplit::device::profiles;
use smartsplit::models::zoo;
use smartsplit::optimizer::nsga2::{
    crowding_distance, dominates, fast_non_dominated_sort, Individual,
};
use smartsplit::optimizer::{
    lbo, ebo, optimize, smartsplit, Nsga2Params, Problem, SplitProblem,
};
use smartsplit::perfmodel::{NetworkEnv, PerfModel, RadioPower};
use smartsplit::prop_assert;
use smartsplit::util::prop::run_prop;

fn ind(objs: Vec<f64>) -> Individual {
    Individual { genome: vec![], objectives: objs, violation: 0.0, rank: 0, crowding: 0.0 }
}

#[test]
fn prop_domination_is_strict_partial_order() {
    run_prop("domination strict partial order", 300, |g| {
        let m = g.usize_in(1, 4);
        let mk = |g: &mut smartsplit::util::prop::Gen| {
            ind((0..m).map(|_| g.f64_in(0.0, 10.0)).collect())
        };
        let a = mk(g);
        let b = mk(g);
        let c = mk(g);
        // irreflexive
        prop_assert!(!dominates(&a, &a), "a dominates itself");
        // antisymmetric
        prop_assert!(
            !(dominates(&a, &b) && dominates(&b, &a)),
            "mutual domination: {:?} {:?}",
            a.objectives,
            b.objectives
        );
        // transitive
        if dominates(&a, &b) && dominates(&b, &c) {
            prop_assert!(dominates(&a, &c), "transitivity failed");
        }
        Ok(())
    });
}

#[test]
fn prop_front0_is_mutually_nondominated_and_complete() {
    run_prop("front 0 correctness", 150, |g| {
        let n = g.usize_in(1, 40);
        let m = g.usize_in(1, 3);
        let mut pop: Vec<Individual> = (0..n)
            .map(|_| ind((0..m).map(|_| g.f64_in(0.0, 5.0)).collect()))
            .collect();
        let fronts = fast_non_dominated_sort(&mut pop);
        // partition check
        let total: usize = fronts.iter().map(|f| f.len()).sum();
        prop_assert!(total == n, "fronts lost members: {total} != {n}");
        // front 0: nothing dominates its members
        for &i in &fronts[0] {
            for j in 0..n {
                prop_assert!(
                    !dominates(&pop[j], &pop[i]),
                    "front-0 member {i} dominated by {j}"
                );
            }
        }
        // later fronts: every member dominated by someone in an earlier front
        for (fi, front) in fronts.iter().enumerate().skip(1) {
            for &i in front {
                let dominated = fronts[..fi]
                    .iter()
                    .flatten()
                    .any(|&j| dominates(&pop[j], &pop[i]));
                prop_assert!(dominated, "front-{fi} member {i} not dominated by earlier fronts");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_crowding_boundary_members_infinite() {
    run_prop("crowding boundaries infinite", 150, |g| {
        let n = g.usize_in(3, 30);
        let mut pop: Vec<Individual> = (0..n)
            .map(|_| ind(vec![g.f64_in(0.0, 1.0), g.f64_in(0.0, 1.0)]))
            .collect();
        let front: Vec<usize> = (0..n).collect();
        crowding_distance(&mut pop, &front);
        for obj in 0..2 {
            let min_i = (0..n)
                .min_by(|&a, &b| pop[a].objectives[obj].partial_cmp(&pop[b].objectives[obj]).unwrap())
                .unwrap();
            let max_i = (0..n)
                .max_by(|&a, &b| pop[a].objectives[obj].partial_cmp(&pop[b].objectives[obj]).unwrap())
                .unwrap();
            prop_assert!(pop[min_i].crowding.is_infinite(), "min of obj {obj} not infinite");
            prop_assert!(pop[max_i].crowding.is_infinite(), "max of obj {obj} not infinite");
        }
        for i in 0..n {
            prop_assert!(pop[i].crowding >= 0.0, "negative crowding");
        }
        Ok(())
    });
}

fn pm_for<'a>(
    profile: &'a smartsplit::models::ModelProfile,
    bandwidth: f64,
) -> PerfModel<'a> {
    PerfModel::new(
        profiles::samsung_j6(),
        profiles::cloud_server(),
        RadioPower::PAPER_80211N,
        NetworkEnv::with_bandwidth(bandwidth),
        profile,
    )
}

#[test]
fn prop_smartsplit_result_never_dominated_by_any_split() {
    // For every model and random bandwidth, the TOPSIS choice must lie on
    // the true Pareto front of the exhaustive split domain: no concrete
    // split may dominate it in (f1, f2, f3).
    run_prop("smartsplit on true front", 12, |g| {
        let name = *g.choice(&["alexnet", "vgg11", "vgg13", "vgg16"]);
        let bw = g.f64_in(1.0, 100.0).max(0.5);
        let profile = zoo::by_name(name).unwrap().analyze(1);
        let pm = pm_for(&profile, bw);
        let params = Nsga2Params { pop_size: 40, generations: 40, ..Default::default() };
        let result = smartsplit(&pm, &params);
        let chosen = result.decision.l1;
        let co = pm.objectives(chosen);
        for l1 in 1..profile.num_layers {
            let o = pm.objectives(l1);
            let dominates_choice =
                o.iter().zip(&co).all(|(a, b)| a <= b) && o.iter().zip(&co).any(|(a, b)| a < b);
            prop_assert!(
                !dominates_choice,
                "{name}@{bw:.1}Mbps: l1={l1} {o:?} dominates chosen {chosen} {co:?}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_single_objective_baselines_are_true_minima() {
    run_prop("lbo/ebo minimality", 15, |g| {
        let name = *g.choice(&["alexnet", "vgg11", "vgg16"]);
        let bw = g.f64_in(1.0, 50.0).max(0.5);
        let profile = zoo::by_name(name).unwrap().analyze(1);
        let pm = pm_for(&profile, bw);
        let l = lbo(&pm).l1;
        let e = ebo(&pm).l1;
        for l1 in 1..profile.num_layers {
            prop_assert!(pm.f1(l) <= pm.f1(l1) + 1e-12, "LBO not minimal at {l1}");
            prop_assert!(pm.f2(e) <= pm.f2(l1) + 1e-12, "EBO not minimal at {l1}");
        }
        Ok(())
    });
}

#[test]
fn prop_nsga2_front_members_feasible_and_in_bounds() {
    run_prop("nsga2 members valid", 10, |g| {
        let name = *g.choice(&["alexnet", "vgg13"]);
        let bw = g.f64_in(0.5, 200.0).max(0.25);
        let profile = zoo::by_name(name).unwrap().analyze(1);
        let pm = pm_for(&profile, bw);
        let problem = SplitProblem::new(&pm);
        let set = optimize(
            &problem,
            &Nsga2Params { pop_size: 30, generations: 25, ..Default::default() },
        );
        prop_assert!(!set.members.is_empty(), "empty Pareto set");
        let (lo, hi) = problem.bounds()[0];
        for mem in &set.members {
            let l1 = mem.genome[0];
            prop_assert!((lo..=hi).contains(&l1), "out of bounds {l1}");
            prop_assert!(mem.violation == 0.0, "infeasible member l1={l1}");
        }
        Ok(())
    });
}
