//! Fault-injection properties (DESIGN.md §13): (a) request conservation
//! — every issued request completes or is dropped exactly once — holds
//! across scripted outages, brownouts, and flash crowds, and across
//! randomized-but-reproducible schedules; (b) the zero-fault degeneracy
//! contract — an empty `FaultPlan` schedules nothing and draws nothing,
//! so the faulty construction paths replay `city_scale_tiered` and
//! `city_mobile` byte-for-byte; (c) a faulty run is deterministic and
//! independent of the planner's thread configuration; (d) the windowed
//! time series partitions the run's failover totals and tracks the
//! active-fault gauge.

use smartsplit::sim::{self, FaultPlan};

#[test]
fn conservation_holds_under_the_scripted_city_faulty_schedule() {
    let r = sim::run(&sim::city_faulty("alexnet", 500, 3, 120.0, 7)).expect("faulty run");
    // Conservation: the outage drained its site's queue into reroutes,
    // never into thin air.
    assert_eq!(r.generated, r.completed + r.dropped);
    // The schedule really fired: one outage + recovery, one brownout +
    // restore, one flash crowd start + end.
    assert_eq!(r.fault_events, 6);
    // The outage stormed devices off the dead site...
    assert!(r.failover_reattaches > 0, "outage forced no reattaches");
    // ... and failover activity as a whole is visible.
    assert!(
        r.failover_reattaches + r.requests_rerouted > 0,
        "no failover activity at all"
    );
    assert!(
        r.planner.failover_requests() >= r.failover_replans,
        "{} failover requests < {} adopted failover re-plans",
        r.planner.failover_requests(),
        r.failover_replans
    );
    assert!(r.completed > 0, "the faulty city completed nothing");
}

#[test]
fn conservation_holds_across_randomized_schedules() {
    for seed in 1..=5u64 {
        let mut cfg = sim::city_scale_tiered("alexnet", 300, 4, 90.0, seed);
        cfg.faults = FaultPlan::random(seed, 4, 90.0);
        let r = sim::run(&cfg).expect("randomized faulty run");
        assert_eq!(
            r.generated,
            r.completed + r.dropped,
            "seed {seed}: conservation broken under {:?}",
            cfg.faults
        );
        assert!(r.fault_events > 0, "seed {seed}: schedule never fired");
        assert!(r.completed > 0, "seed {seed}: nothing completed");
    }
}

#[test]
fn zero_fault_plan_replays_the_tiered_city_byte_for_byte() {
    // `city_faulty` differs from `city_scale_tiered` only in its fault
    // plan; clearing the plan must therefore replay the fault-free
    // scenario exactly — no extra events, no extra RNG draws, no
    // decision drift. As with the Static-mobility contract, the
    // equality half is partly structural (both arms build the same
    // config value, pinned by
    // scenario::tests::faulty_preset_only_differs_by_fault_plan); the
    // load-bearing signal is the zero fault counters below plus
    // determinism across the two construction paths.
    let mut tiered = sim::city_scale_tiered("alexnet", 400, 3, 120.0, 21);
    tiered.planner_perf.record_decisions = true;
    let mut disarmed = sim::city_faulty("alexnet", 400, 3, 120.0, 21);
    disarmed.faults = FaultPlan::none();
    disarmed.planner_perf.record_decisions = true;

    let a = sim::run(&tiered).expect("tiered run");
    let b = sim::run(&disarmed).expect("disarmed faulty run");

    assert!(!a.decisions.is_empty(), "scenario exercised no planning");
    assert_eq!(a.decisions, b.decisions, "an empty fault plan changed a split decision");
    assert_eq!(a.summary(), b.summary());
    assert_eq!(a.events, b.events, "an empty fault plan changed the event stream");
    assert_eq!(a.planner, b.planner, "an empty fault plan perturbed planner accounting");
    assert_eq!(a.latency.summary(), b.latency.summary());
    assert_eq!(a.edge_queue_delay.summary(), b.edge_queue_delay.summary());
    assert_eq!(a.split_distribution, b.split_distribution);
    for r in [&a, &b] {
        assert_eq!(
            (r.fault_events, r.failover_reattaches, r.requests_rerouted, r.failover_replans),
            (0, 0, 0, 0),
            "fault counters moved without a fault plan"
        );
        assert_eq!(r.planner.failover_requests(), 0);
    }
}

#[test]
fn zero_fault_plan_replays_the_mobile_city_byte_for_byte() {
    // Same degeneracy contract on top of mobility: the fault layer's
    // per-event bookkeeping (outage scan, backhaul factors, crowd
    // sampling) must leave the waypoint walk's event stream untouched
    // when the plan is empty.
    let mut mobile = sim::city_mobile("alexnet", 400, 3, 120.0, 33);
    mobile.planner_perf.record_decisions = true;
    let mut disarmed = mobile.clone();
    disarmed.faults = FaultPlan::none();

    let a = sim::run(&mobile).expect("mobile run");
    let b = sim::run(&disarmed).expect("disarmed mobile run");

    assert!(a.handovers > 0, "the walk moved nothing");
    assert_eq!(a.decisions, b.decisions);
    assert_eq!(a.summary(), b.summary());
    assert_eq!(a.events, b.events);
    assert_eq!((a.handovers, a.migration_replans), (b.handovers, b.migration_replans));
    assert_eq!((a.fault_events, a.failover_reattaches), (0, 0));
}

#[test]
fn faulty_runs_are_deterministic_and_thread_config_independent() {
    // Fault handling draws from the same per-device streams and
    // quantised solve seeds as everything else, so neither a re-run nor
    // the planner's worker-pool fan-out may perturb the decision or
    // event stream of a faulty scenario.
    let mut parallel = sim::city_faulty("alexnet", 400, 3, 120.0, 9);
    parallel.planner_perf.record_decisions = true;
    parallel.planner_perf.parallel = true;
    let mut sequential = parallel.clone();
    sequential.planner_perf.parallel = false;

    let a = sim::run(&parallel).expect("parallel faulty run");
    let b = sim::run(&sequential).expect("sequential faulty run");
    assert!(!a.decisions.is_empty());
    assert!(a.fault_events > 0, "the schedule never fired");
    assert_eq!(a.decisions, b.decisions, "thread fan-out changed a faulty decision");
    assert_eq!(a.summary(), b.summary());
    assert_eq!(a.events, b.events);
    assert_eq!(
        (a.failover_reattaches, a.requests_rerouted, a.failover_replans),
        (b.failover_reattaches, b.requests_rerouted, b.failover_replans)
    );
    assert_eq!(a.planner, b.planner, "fan-out perturbed planner accounting");

    let c = sim::run(&parallel).expect("parallel faulty rerun");
    assert_eq!(a.decisions, c.decisions);
    assert_eq!(a.summary(), c.summary());
}

#[test]
fn faults_survive_mobility_and_conserve_requests() {
    // Outage storms and voluntary waypoint handovers race through the
    // same epoch-guarded reattach path; whichever lands second
    // supersedes the other, and no request may be lost in the shuffle.
    let mut cfg = sim::city_mobile("alexnet", 500, 3, 120.0, 13);
    assert!(cfg.mobility.is_mobile());
    cfg.faults = FaultPlan::city_faulty(3, 120.0);
    let r = sim::run(&cfg).expect("mobile faulty run");
    assert_eq!(r.generated, r.completed + r.dropped);
    assert!(r.handovers > 0, "mobility stalled under faults");
    assert!(r.failover_reattaches > 0, "outage forced no reattaches");
    assert_eq!(r.fault_events, 6);
}

#[test]
fn conservation_matrix_faults_x_mobility_x_shards() {
    // The full cross product: randomized fault schedules on top of the
    // waypoint walk, dispatched through every shard layout the parity
    // suite covers. Conservation must hold at every shard count, and —
    // stronger — each sharded run must replay its own 1-shard
    // reference byte-for-byte even while outage storms and handovers
    // race across shard boundaries.
    for seed in [3u64, 11, 27] {
        let mut base = sim::city_mobile("alexnet", 300, 4, 90.0, seed);
        assert!(base.mobility.is_mobile());
        base.faults = FaultPlan::random(seed, 4, 90.0);
        base.planner_perf.record_decisions = true;
        let reference = sim::run(&base).expect("1-shard faulty mobile run");
        assert_eq!(
            reference.generated,
            reference.completed + reference.dropped,
            "seed {seed}: conservation broken at 1 shard"
        );
        assert!(reference.fault_events > 0, "seed {seed}: schedule never fired");
        for shards in [2usize, 4, 7] {
            let mut cfg = base.clone();
            cfg.shards = shards;
            let r = sim::run(&cfg).expect("sharded faulty mobile run");
            assert_eq!(
                r.generated,
                r.completed + r.dropped,
                "seed {seed}: conservation broken at {shards} shards"
            );
            assert_eq!(
                reference.decisions, r.decisions,
                "seed {seed}: {shards} shards changed a decision under faults+mobility"
            );
            assert_eq!(
                reference.summary(),
                r.summary(),
                "seed {seed}: {shards} shards changed the run under faults+mobility"
            );
            assert_eq!(reference.events, r.events);
            assert_eq!(
                (reference.failover_reattaches, reference.requests_rerouted, reference.handovers),
                (r.failover_reattaches, r.requests_rerouted, r.handovers),
                "seed {seed}: {shards} shards changed failover accounting"
            );
        }
    }
}

#[test]
fn site_down_races_an_in_flight_handover_across_a_shard_boundary() {
    // The nastiest ordering in the sharded engine: a device's waypoint
    // walk begins a handover toward a site owned by another shard, and
    // the scripted schedule kills a site while that relay is still in
    // flight. The outage storm (routed to the dead site's shard) and
    // the pending `Reattach` (routed to the target site's shard) are
    // same-window events on different shards; the epoch guard only
    // works if they dispatch in the exact global order the 1-shard
    // engine would use. `city_faulty`'s outage fires mid-run at 30 % of
    // the horizon, squarely inside the mobile city's handover churn, so
    // this schedule manufactures the race continuously for the whole
    // outage window.
    let mut base = sim::city_mobile("alexnet", 500, 3, 120.0, 13);
    base.faults = FaultPlan::city_faulty(3, 120.0);
    base.planner_perf.record_decisions = true;
    let reference = sim::run(&base).expect("1-shard race run");
    assert!(reference.handovers > 0, "mobility stalled under faults");
    assert!(reference.failover_reattaches > 0, "outage forced no reattaches");
    assert_eq!(reference.fault_events, 6);
    assert_eq!(reference.generated, reference.completed + reference.dropped);

    // One site per shard: every handover between distinct sites and the
    // whole outage storm are cross-shard by construction.
    let mut cfg = base.clone();
    cfg.shards = 3;
    let r = sim::run(&cfg).expect("3-shard race run");
    assert!(r.cross_shard_events > 0, "the race never crossed a shard boundary");
    assert_eq!(r.generated, r.completed + r.dropped, "conservation broken across the race");
    assert_eq!(reference.decisions, r.decisions, "the race changed a split decision");
    assert_eq!(reference.summary(), r.summary(), "the race changed the measured run");
    assert_eq!(reference.events, r.events, "the race changed the event stream");
    assert_eq!(
        (reference.handovers, reference.failover_reattaches, reference.requests_rerouted),
        (r.handovers, r.failover_reattaches, r.requests_rerouted),
        "the race changed handover/failover accounting"
    );
}

#[test]
fn windowed_failovers_partition_run_totals() {
    let mut cfg = sim::city_faulty("alexnet", 500, 3, 120.0, 7);
    cfg.observability.window_s = 10.0;
    let r = sim::run(&cfg).expect("faulty run with series");
    let series = r.series.as_ref().expect("collector was enabled");
    assert!(!series.windows.is_empty());

    // Per-window counters partition the run totals exactly — under
    // drops, outages, and reroutes alike.
    let sum = |f: fn(&smartsplit::metrics::WindowSummary) -> u64| -> u64 {
        series.windows.iter().map(f).sum()
    };
    assert_eq!(sum(|w| w.generated), r.generated);
    assert_eq!(sum(|w| w.completed), r.completed);
    assert_eq!(sum(|w| w.dropped), r.dropped);
    assert_eq!(
        sum(|w| w.failovers),
        r.failover_reattaches + r.requests_rerouted,
        "window failovers do not partition the run's failover total"
    );
    // The active-fault gauge saw overlapping faults mid-run and came
    // back to zero once the schedule drained (city_faulty clears its
    // last fault at 70 % of the horizon).
    let peak = series.windows.iter().map(|w| w.faults_active).max().unwrap();
    assert!(peak >= 2, "overlapping faults never registered (peak {peak})");
    assert_eq!(
        series.windows.last().unwrap().faults_active,
        0,
        "gauge did not return to zero after the schedule drained"
    );

    // Enabling the collector must not have perturbed the run itself.
    let plain = sim::run(&sim::city_faulty("alexnet", 500, 3, 120.0, 7)).expect("plain run");
    assert_eq!(r.summary(), plain.summary());
}
