//! Clean fixture: ordered containers on the export plane — D3 must
//! stay silent for `BTreeMap`/`BTreeSet` and sorted `Vec` emission.

use std::collections::{BTreeMap, BTreeSet};

pub fn export(counts: &BTreeMap<String, u64>) -> String {
    let mut out = String::new();
    let mut seen: BTreeSet<&str> = BTreeSet::new();
    for (k, v) in counts {
        if seen.insert(k) {
            out.push_str(&format!("{k}={v}\n"));
        }
    }
    out
}
