//! Clean fixture: a justified allow suppresses the D1 finding and is
//! counted in the suppression audit.

use std::time::Instant;

pub fn wall_profile() -> f64 {
    // detlint:allow(D1): wall-side profiling helper; output never feeds a decision
    let t0 = Instant::now();
    t0.elapsed().as_secs_f64()
}
