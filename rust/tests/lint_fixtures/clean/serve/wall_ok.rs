//! Clean fixture: wall-clock reads are the point of the wall-side
//! modules — D1 must stay silent here.

use std::time::Instant;

pub fn measure<F: FnOnce()>(f: F) -> f64 {
    let t0 = Instant::now();
    f();
    t0.elapsed().as_secs_f64()
}
