//! D4 fixture: relaxed atomics on the export plane must trip.

use std::sync::atomic::{AtomicU64, Ordering};

pub struct Counter(AtomicU64);

impl Counter {
    pub fn bump(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }
}
