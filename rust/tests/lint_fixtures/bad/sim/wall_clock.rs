//! D1 fixture: wall-clock reads on the decision plane (sim/) must trip.

use std::time::{Instant, SystemTime};

pub fn decide() -> f64 {
    let t = Instant::now();
    let _wall = SystemTime::now();
    t.elapsed().as_secs_f64()
}
