//! D2 + D4 fixture: the sharded engine's cross-shard channel sits on
//! the export plane (its pop order is the decision stream), so both a
//! thread-local RNG and a relaxed counter must trip here.

use std::sync::atomic::{AtomicU64, Ordering};

pub struct CrossShardChannel {
    sent: AtomicU64,
}

impl CrossShardChannel {
    pub fn pick_shard(&self, shards: usize) -> usize {
        use rand::Rng;
        rand::thread_rng().gen_range(0..shards)
    }

    pub fn sent(&self) -> u64 {
        self.sent.load(Ordering::Relaxed)
    }
}
