//! D3 fixture: default-hasher maps on the export plane must trip.

use std::collections::{HashMap, HashSet};

pub fn export(counts: &HashMap<String, u64>) -> String {
    let mut out = String::new();
    let mut seen: HashSet<&str> = HashSet::new();
    for (k, v) in counts {
        if seen.insert(k) {
            out.push_str(&format!("{k}={v}\n"));
        }
    }
    out
}
