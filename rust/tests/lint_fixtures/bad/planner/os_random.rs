//! D2 fixture: OS/thread-local randomness must trip anywhere, even in
//! test modules.

pub fn jitter() -> u64 {
    let mut rng = rand::thread_rng();
    rng.next_u64()
}

#[cfg(test)]
mod tests {
    #[test]
    fn seeded_by_the_os() {
        let _x: u64 = rand::random();
        let _m: std::collections::hash_map::RandomState = Default::default();
    }
}
