//! ALLOW-hygiene fixture: a stale allow, an unknown rule id, and a
//! malformed annotation must each surface as findings.

// detlint:allow(R1): nothing on the next line actually panics
pub fn fine(x: u64) -> u64 {
    x + 1
}

// detlint:allow(D9): no such rule
pub fn also_fine(x: u64) -> u64 {
    x + 2
}

// detlint:allow(D4)
pub fn missing_justification(x: u64) -> u64 {
    x + 3
}
