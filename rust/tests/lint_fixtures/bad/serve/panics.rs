//! R1 fixture: panicking calls on serving paths must trip, but the
//! test module below is exempt.

pub fn parse_header(line: &str) -> u64 {
    let field = line.split(':').next().unwrap();
    field.trim().parse().expect("numeric header")
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_is_exempt() {
        assert_eq!(super::parse_header("7:x"), "7".parse::<u64>().unwrap());
    }
}
