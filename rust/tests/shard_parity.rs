//! Cross-layout replay parity (DESIGN.md §16): the sharded event
//! engine is an internal reorganisation, never an observable one. For
//! any shard count the decision stream, the event/planner accounting,
//! the trace exports (JSONL + Chrome), and the metrics JSON must be
//! byte-identical to the `--shards 1` reference run — across the
//! static tiered city, the mobile city (handovers cross shard
//! boundaries), and the faulty city (outage storms cross shard
//! boundaries). A seeded property test additionally pins the core
//! invariant at the queue level: randomized shard layouts never
//! reorder same-timestamp events against the single-heap reference.

use smartsplit::sim::{self, Event, EventQueue, ObservabilityConfig, ShardLayout, ShardedQueue};
use smartsplit::util::rng::Xoshiro256;

/// Everything observable about a run: decisions, the one-line summary,
/// raw conservation counters, planner accounting, the final split
/// distribution, and all three serialized exports.
struct Artifacts {
    decisions: Vec<(u32, u32, u32)>,
    summary: String,
    events: u64,
    counts: (u64, u64, u64),
    planner: smartsplit::metrics::PlannerStats,
    splits: Vec<(smartsplit::edge::SplitPlan, u64)>,
    trace_jsonl: String,
    chrome_trace: String,
    metrics_json: String,
    report: sim::SimReport,
}

fn artifacts(mut cfg: sim::SimConfig, shards: usize) -> Artifacts {
    cfg.shards = shards;
    cfg.planner_perf.record_decisions = true;
    cfg.observability = ObservabilityConfig::full(10.0);
    let r = sim::run(&cfg).expect("sim run");
    let tr = r.trace.as_ref().expect("tracing was on");
    Artifacts {
        decisions: r.decisions.clone(),
        summary: r.summary(),
        events: r.events,
        counts: (r.generated, r.completed, r.dropped),
        planner: r.planner,
        splits: r.split_distribution.clone(),
        trace_jsonl: tr.to_jsonl(),
        chrome_trace: tr.to_chrome_trace(),
        metrics_json: r
            .metrics_json()
            .expect("series was on")
            .to_string_pretty(),
        report: r,
    }
}

/// The parity contract for one scenario: every shard count in
/// `layouts` replays the 1-shard reference byte-for-byte, on every
/// observable surface.
fn assert_parity(cfg: sim::SimConfig, layouts: &[usize]) {
    let reference = artifacts(cfg.clone(), 1);
    assert!(!reference.decisions.is_empty(), "scenario exercised no planning");
    assert!(reference.trace_jsonl.lines().count() > 2, "trivial trace export");
    assert_eq!(reference.report.shards.len(), 1, "reference layout is not single-shard");

    for &n in layouts {
        let sharded = artifacts(cfg.clone(), n);
        assert_eq!(
            reference.decisions, sharded.decisions,
            "--shards {n} changed a split decision"
        );
        assert_eq!(reference.summary, sharded.summary, "--shards {n} changed the summary");
        assert_eq!(reference.events, sharded.events, "--shards {n} changed the event count");
        assert_eq!(reference.counts, sharded.counts, "--shards {n} broke conservation parity");
        assert_eq!(
            reference.planner, sharded.planner,
            "--shards {n} perturbed planner accounting"
        );
        assert_eq!(
            reference.splits, sharded.splits,
            "--shards {n} changed the split distribution"
        );
        assert_eq!(
            reference.trace_jsonl, sharded.trace_jsonl,
            "--shards {n} changed the JSONL trace export"
        );
        assert_eq!(
            reference.chrome_trace, sharded.chrome_trace,
            "--shards {n} changed the Chrome trace export"
        );
        assert_eq!(
            reference.metrics_json, sharded.metrics_json,
            "--shards {n} changed the metrics JSON export"
        );
        // The run really went through the sharded layout — the parity
        // above is a statement about a different engine configuration,
        // not a silent fallback to one shard.
        assert_eq!(sharded.report.shards.len(), n, "--shards {n} was not honoured");
        assert!(sharded.report.shard_windows > 0, "--shards {n} crossed no window barrier");
    }
}

#[test]
fn tiered_city_replays_byte_for_byte_across_shard_counts() {
    let cfg = sim::city_scale_tiered("alexnet", 300, 3, 90.0, 7);
    assert_parity(cfg, &[2, 4, 7]);
}

#[test]
fn mobile_city_replays_byte_for_byte_across_shard_counts() {
    // Handovers re-attach devices across shard boundaries mid-run; the
    // relayed torso state and the migration re-solves must still land
    // in the identical global order.
    let cfg = sim::city_mobile("alexnet", 400, 3, 120.0, 9);
    assert_parity(cfg, &[2, 4, 7]);
}

#[test]
fn faulty_city_replays_byte_for_byte_across_shard_counts() {
    // Outage storms force reattaches and reroutes across shard
    // boundaries; the fault schedule itself is routed per-site, so the
    // scripted events land on different shards per layout — and must
    // still dispatch in the identical global order.
    let cfg = sim::city_faulty("alexnet", 500, 3, 120.0, 7);
    assert_parity(cfg, &[2, 4, 7]);
}

#[test]
fn multi_shard_runs_actually_exchange_cross_shard_events() {
    // Guard against a degenerate routing that pins everything to one
    // shard (which would make the parity tests vacuous): with the
    // fleet tick on shard 0 and sites spread over the layout, uplinks
    // must cross shard boundaries.
    let mut cfg = sim::city_scale_tiered("alexnet", 300, 3, 90.0, 7);
    cfg.shards = 2;
    let r = sim::run(&cfg).expect("sharded run");
    assert!(r.cross_shard_events > 0, "no event ever crossed a shard boundary");
    let busy = r.shards.iter().filter(|s| s.events > 0).count();
    assert!(busy >= 2, "only {busy} shard(s) dispatched events");
    let dispatched: u64 = r.shards.iter().map(|s| s.events).sum();
    assert_eq!(dispatched, r.events, "per-shard slices do not partition the dispatch total");
}

/// Integration form of the property: a randomized shard count over a
/// randomized tiered city replays the 1-shard reference exactly.
#[test]
fn random_shard_counts_replay_the_reference() {
    use smartsplit::prop_assert;
    use smartsplit::util::prop::run_prop;
    run_prop("random shard counts replay --shards 1", 5, |g| {
        let devices = g.usize_in(60, 150);
        let sites = g.usize_in(2, 6);
        let duration = *g.choice(&[30.0, 45.0, 60.0]);
        let seed = g.usize_in(1, 9999) as u64;
        let shards = g.usize_in(2, 8);
        let mut cfg = sim::city_scale_tiered("alexnet", devices, sites, duration, seed);
        cfg.planner_perf.record_decisions = true;
        let mut sharded = cfg.clone();
        sharded.shards = shards;
        let a = sim::run(&cfg).map_err(|e| format!("reference failed: {e}"))?;
        let b = sim::run(&sharded).map_err(|e| format!("sharded failed: {e}"))?;
        prop_assert!(
            a.decisions == b.decisions,
            "{shards} shards changed a decision (devices={devices} sites={sites} seed={seed})"
        );
        prop_assert!(
            a.summary() == b.summary(),
            "{shards} shards changed the summary (devices={devices} sites={sites} seed={seed})"
        );
        prop_assert!(a.events == b.events, "{shards} shards changed the event count");
        Ok(())
    });
}

/// The queue-level core of the contract, against *randomized layouts*
/// (not just the contiguous site split the simulator uses): whatever
/// shard each site lands on, the sharded queue pops the identical
/// `(time, event)` sequence as the single binary heap — including runs
/// of same-timestamp events, whose FIFO insertion order must survive
/// the per-shard heaps.
#[test]
fn random_layouts_never_reorder_same_timestamp_events() {
    use smartsplit::prop_assert;
    use smartsplit::util::prop::run_prop;
    run_prop("random layouts keep FIFO order at equal timestamps", 8, |g| {
        let sites = g.usize_in(2, 9);
        let shards = g.usize_in(2, 8);
        let seed = g.usize_in(1, u32::MAX as usize) as u64;
        let layout = ShardLayout::random(shards, sites, seed);
        let mut sharded = ShardedQueue::new(layout, 0.25);
        let mut reference = EventQueue::new();
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0x5eed);

        // Interleave schedules and pops; a coarse time grid forces
        // long runs of equal timestamps, the exact case a per-shard
        // heap could reorder.
        let devices = 16usize;
        for d in 0..devices {
            let site = if rng.gen_bool(0.8) { Some(rng.gen_range(0, sites - 1)) } else { None };
            sharded.attach_device(d, site);
        }
        for _ in 0..400 {
            if rng.gen_bool(0.7) || reference.is_empty() {
                let t = (rng.gen_range(0, 40) as f64) * 0.5;
                let ev = match rng.gen_range(0, 5) {
                    0 => Event::Arrival,
                    1 => Event::Handover { device: rng.gen_range(0, devices - 1) },
                    2 => Event::SiteDown { site: rng.gen_range(0, sites - 1) },
                    3 => Event::Leave { device: rng.gen_range(0, devices - 1) },
                    _ => Event::CloudArrive {
                        req: 1,
                        device: rng.gen_range(0, devices - 1),
                        issued: 0.0,
                        tail_s: 0.1,
                    },
                };
                sharded.schedule(t, ev.clone());
                reference.schedule(t, ev);
            } else {
                let got = sharded.pop();
                let want = reference.pop();
                prop_assert!(
                    got == want,
                    "pop diverged under layout seed {seed} ({shards} shards / {sites} sites): \
                     sharded {got:?} vs reference {want:?}"
                );
                // Mid-stream re-attachment churn must not disturb the
                // already-scheduled order either.
                if rng.gen_bool(0.2) {
                    let d = rng.gen_range(0, devices - 1);
                    let site =
                        if rng.gen_bool(0.5) { Some(rng.gen_range(0, sites - 1)) } else { None };
                    sharded.attach_device(d, site);
                }
            }
        }
        while let Some(want) = reference.pop() {
            let got = sharded.pop();
            prop_assert!(
                got == Some(want.clone()),
                "drain diverged under layout seed {seed}: sharded {got:?} vs reference {want:?}"
            );
        }
        prop_assert!(sharded.pop().is_none(), "sharded queue held extra events");
        prop_assert!(
            sharded.processed() == reference.processed(),
            "processed counters diverged"
        );
        Ok(())
    });
}
