//! # SmartSplit
//!
//! Production-grade reproduction of *SmartSplit: Latency-Energy-Memory
//! Optimisation for CNN Splitting on Smartphone Environment* (COMSNETS
//! 2022) as a three-layer rust + JAX + Pallas system:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): conv-as-im2col
//!   MXU matmul, depthwise conv, pooling; AOT-lowered, never on the
//!   request path.
//! * **L2** — JAX per-layer CNN zoo (`python/compile/model.py`): AlexNet,
//!   VGG11/13/16, MobileNetV2, each layer exported as its own HLO module
//!   so the split index is a runtime decision.
//! * **L3** — this crate: the split-serving coordinator. The paper's
//!   optimiser ([`optimizer`]: NSGA-II + TOPSIS + the five baselines), the
//!   §III latency/energy models ([`perfmodel`]), the smartphone/cloud/
//!   link simulation ([`device`], [`netsim`]), the PJRT runtime
//!   ([`runtime`]), the TCP split-serving stack ([`serve`],
//!   [`coordinator`]), the discrete-event fleet simulator ([`sim`])
//!   that scales scenarios past what sockets can host, and the
//!   hierarchical edge tier ([`edge`]) that generalises the single
//!   split point to a device→edge→cloud `(l1, l2)` partition.
//!
//! **Planning entry point:** every splitting decision goes through the
//! [`planner`] façade — one `PlanRequest → PlanOutcome` API over every
//! strategy (Algorithm 1, the exhaustive-front planner, the §VI-C
//! baselines, the §V-A scalarisations), flat or tiered. The free
//! functions it superseded are deprecated shims kept for the parity
//! tests.
//!
//! # Module map
//!
//! Mirrors `rust/DESIGN.md` §1 (the in-code comments cite that document
//! by section number):
//!
//! | Module | What lives there |
//! |---|---|
//! | [`models`] | Layer-spec algebra, the five-model zoo, artifact manifests |
//! | [`perfmodel`] | The paper's §III latency/energy models and §IV objectives |
//! | [`optimizer`] | NSGA-II (flat-SoA, zero-alloc), TOPSIS, baselines, scalarisations, the split-plan cache |
//! | [`planner`] | The façade: `PlanRequest → PlanOutcome`, strategies, replan-reason provenance |
//! | [`edge`] | Three-tier `(l1, l2)` splitting: topology + cell geometry, tiered §III tables, 2-D genome |
//! | [`device`], [`netsim`] | Smartphone/cloud compute profiles and the token-bucket WiFi link |
//! | [`runtime`] | PJRT executor over the python-AOT per-layer HLO artifacts |
//! | [`serve`], [`coordinator`] | Framed TCP serving stack; live deployments, battery bands, the N-phone fleet |
//! | [`sim`] | Discrete-event fleet simulator: virtual clock, M/G/c tiers, mobility + edge handover, scenarios |
//! | [`trace`] | Deterministic per-request span timelines + causal annotations; JSONL / Chrome `trace_event` export |
//! | [`analyze`] | Trace-plane analytics: critical-path attribution, SLO audits + fault impact, run-vs-run regression diffs |
//! | [`workload`], [`metrics`], [`figures`], [`bench`] | Arrival processes, histograms/time-series/planner counters, paper exhibits, bench harness |
//! | [`util`] | Offline substrates: CLI, PRNG, JSON, property testing, thread pool |
//! | [`lint`] | `detlint`: the in-tree determinism/robustness static-analysis pass (DESIGN.md §15) |
//!
//! See the repo-root `README.md` for the quickstart and
//! [DESIGN.md](../DESIGN.md) for the architecture, the offline
//! substrate policy (§4), and the paper-vs-model validation story.

pub mod analyze;
pub mod bench;
pub mod coordinator;
pub mod device;
pub mod edge;
pub mod figures;
pub mod lint;
pub mod metrics;
pub mod models;
pub mod netsim;
pub mod optimizer;
pub mod perfmodel;
pub mod planner;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod trace;
pub mod util;
pub mod workload;

use std::path::PathBuf;

/// Default artifacts directory: `$SMARTSPLIT_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("SMARTSPLIT_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}
