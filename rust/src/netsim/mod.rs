//! WiFi link simulator.
//!
//! The paper's testbed put both phones and the server on a 10 Mbps WiFi
//! network. We reproduce that over TCP loopback with a token-bucket shaper:
//! the serving path pushes real bytes through a real socket while
//! [`Link::throttle`] paces them to the configured bandwidth, so upload
//! latency/energy behave like Eq. 4/9 (DESIGN.md §4 substitution).
//!
//! [`BandwidthTrace`] provides time-varying bandwidth for the adaptive
//! re-optimisation example (the condition the paper's conclusion flags as
//! the reason bandwidth is "a crucial parameter").

use std::sync::Mutex;
use std::time::{Duration, Instant};

/// A shaped point-to-point link.
#[derive(Debug)]
pub struct Link {
    state: Mutex<LinkState>,
    /// Propagation delay added to every transfer.
    pub base_latency: Duration,
}

#[derive(Debug)]
struct LinkState {
    bandwidth_mbps: f64,
    /// Token bucket: available byte-budget and last refill instant.
    tokens: f64,
    last_refill: Instant,
    burst_bytes: f64,
    /// Totals for metrics.
    bytes_up: u64,
    bytes_down: u64,
}

impl Link {
    pub fn new(bandwidth_mbps: f64) -> Self {
        Link {
            state: Mutex::new(LinkState {
                bandwidth_mbps,
                tokens: 0.0,
                last_refill: Instant::now(),
                burst_bytes: 64.0 * 1024.0,
                bytes_up: 0,
                bytes_down: 0,
            }),
            base_latency: Duration::from_millis(2),
        }
    }

    pub fn bandwidth_mbps(&self) -> f64 {
        self.state.lock().unwrap().bandwidth_mbps
    }

    /// Retune the link (adaptive-bandwidth scenarios).
    ///
    /// Token-bucket behaviour on retune: refills are lazy (computed in
    /// [`Link::throttle`] from `last_refill`), so without intervention any
    /// idle time spanning the retune would be credited at the *new* rate —
    /// retuning 1 → 1000 Mbps after a 1 s gap would mint a ~125 MB stale
    /// burst that never crossed the link at either rate. To keep history
    /// honest, the bucket is settled at the **old** rate up to the retune
    /// instant, clamped to the normal burst allowance, and re-based so
    /// subsequent refills accrue purely at the new rate.
    pub fn set_bandwidth_mbps(&self, mbps: f64) {
        assert!(mbps > 0.0);
        let mut st = self.state.lock().unwrap();
        let now = Instant::now();
        let old_rate = st.bandwidth_mbps * 1e6 / 8.0; // bytes/s
        let elapsed = now.duration_since(st.last_refill).as_secs_f64();
        st.tokens = (st.tokens + elapsed * old_rate).min(st.burst_bytes);
        st.last_refill = now;
        st.bandwidth_mbps = mbps;
    }

    /// Ideal transfer time for `bytes` at the current bandwidth (Eq. 4).
    pub fn transfer_time(&self, bytes: u64) -> Duration {
        let mbps = self.bandwidth_mbps();
        Duration::from_secs_f64(bytes as f64 * 8.0 / (mbps * 1e6))
            + self.base_latency
    }

    /// Block until the token bucket admits `bytes` (called by the framing
    /// layer per chunk). Returns the time actually waited.
    pub fn throttle(&self, bytes: u64, upload: bool) -> Duration {
        let start = Instant::now();
        loop {
            let wait = {
                let mut st = self.state.lock().unwrap();
                let now = Instant::now();
                let rate = st.bandwidth_mbps * 1e6 / 8.0; // bytes/s
                let elapsed = now.duration_since(st.last_refill).as_secs_f64();
                st.tokens = (st.tokens + elapsed * rate).min(st.burst_bytes.max(bytes as f64));
                st.last_refill = now;
                if st.tokens >= bytes as f64 {
                    st.tokens -= bytes as f64;
                    if upload {
                        st.bytes_up += bytes;
                    } else {
                        st.bytes_down += bytes;
                    }
                    None
                } else {
                    let deficit = bytes as f64 - st.tokens;
                    Some(Duration::from_secs_f64(deficit / rate))
                }
            };
            match wait {
                None => return start.elapsed(),
                Some(d) => std::thread::sleep(d.min(Duration::from_millis(50))),
            }
        }
    }

    pub fn bytes_transferred(&self) -> (u64, u64) {
        let st = self.state.lock().unwrap();
        (st.bytes_up, st.bytes_down)
    }
}

/// Piecewise-constant bandwidth over time, for adaptive scenarios.
#[derive(Clone, Debug)]
pub struct BandwidthTrace {
    /// (start offset, bandwidth in Mbps), sorted by offset; first must be 0.
    pub points: Vec<(Duration, f64)>,
}

impl BandwidthTrace {
    pub fn constant(mbps: f64) -> Self {
        BandwidthTrace { points: vec![(Duration::ZERO, mbps)] }
    }

    /// A step trace: every `period` the bandwidth moves to the next value,
    /// cycling. A step begins exactly *at* its boundary: `at(k·period)`
    /// already reads step `k`'s value. Traces hold their last value past
    /// `total`, and a `total` shorter than one period still yields a
    /// (constant) one-point trace rather than an empty one.
    pub fn steps(period: Duration, values: &[f64], total: Duration) -> Self {
        assert!(!values.is_empty());
        assert!(period > Duration::ZERO, "step period must be positive");
        let mut points = vec![(Duration::ZERO, values[0])];
        let mut t = period;
        let mut i = 1;
        while t < total {
            points.push((t, values[i % values.len()]));
            i += 1;
            t += period;
        }
        BandwidthTrace { points }
    }

    /// Bandwidth at `elapsed` since trace start. Clamps: before the first
    /// point (offsets must start at 0 anyway) the first value applies,
    /// past the last point the last value holds forever.
    pub fn at(&self, elapsed: Duration) -> f64 {
        let mut current = self.points[0].1;
        for &(t, v) in &self.points {
            if elapsed >= t {
                current = v;
            } else {
                break;
            }
        }
        current
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_matches_eq4() {
        let link = Link::new(10.0);
        // 774400 bytes (AlexNet layer-1 activation) at 10 Mbps ≈ 0.61952 s
        let t = link.transfer_time(774_400);
        let ideal = 774_400.0 * 8.0 / 10e6;
        assert!((t.as_secs_f64() - ideal - 0.002).abs() < 1e-9);
    }

    #[test]
    fn throttle_paces_to_bandwidth() {
        let link = Link::new(80.0); // 10 MB/s
        let start = Instant::now();
        let mut sent = 0u64;
        while sent < 1_000_000 {
            link.throttle(64 * 1024, true);
            sent += 64 * 1024;
        }
        let took = start.elapsed().as_secs_f64();
        let ideal = sent as f64 / 10e6;
        assert!(took >= ideal * 0.8, "sent too fast: {took}s vs ideal {ideal}s");
        assert!(took < ideal * 2.0 + 0.2, "sent too slow: {took}s vs ideal {ideal}s");
        assert_eq!(link.bytes_transferred().0, sent);
    }

    #[test]
    fn set_bandwidth_applies() {
        let link = Link::new(10.0);
        link.set_bandwidth_mbps(40.0);
        assert_eq!(link.bandwidth_mbps(), 40.0);
        let t = link.transfer_time(1_000_000);
        assert!((t.as_secs_f64() - (8e6 / 40e6) - 0.002).abs() < 1e-9);
    }

    #[test]
    fn retune_rebases_tokens_at_old_rate() {
        // Regression: an idle window spanning a retune must be credited at
        // the rate that actually applied, not the new one. Idle ~200 ms on
        // a 1 Mbps link (earns ≤ 25 KB, clamped to the 64 KiB burst), then
        // retune to 80 Mbps (10 MB/s) and push 1 MB. With the re-base the
        // bucket holds ≲ 64 KiB, so the transfer must wait ≈ 94 ms for
        // refill at the new rate. Before the fix, the stale `last_refill`
        // let throttle() credit the whole idle window at 10 MB/s — a 1 MB
        // (bytes-capped) stale burst that sailed through with no wait.
        let link = Link::new(1.0);
        std::thread::sleep(Duration::from_millis(200));
        link.set_bandwidth_mbps(80.0);
        {
            let st = link.state.lock().unwrap();
            assert_eq!(st.bandwidth_mbps, 80.0);
            // Settled at the old rate and re-based at the retune instant.
            assert!(
                st.tokens <= 64.0 * 1024.0,
                "retune minted a stale burst: {} tokens",
                st.tokens
            );
            assert!(st.last_refill.elapsed() < Duration::from_millis(150));
        }
        let waited = link.throttle(1_000_000, true);
        // ≥ (1 MB − 64 KiB) / 10 MB/s ≈ 93 ms of honest pacing (sleep can
        // only overshoot, so this lower bound is robust on slow CI).
        assert!(
            waited >= Duration::from_millis(50),
            "throttle passed a stale burst through in {waited:?}"
        );
        assert_eq!(link.bytes_transferred().0, 1_000_000);
    }

    #[test]
    fn trace_steps_and_lookup() {
        let tr = BandwidthTrace::steps(
            Duration::from_secs(10),
            &[10.0, 2.0, 40.0],
            Duration::from_secs(30),
        );
        assert_eq!(tr.at(Duration::from_secs(0)), 10.0);
        assert_eq!(tr.at(Duration::from_secs(9)), 10.0);
        assert_eq!(tr.at(Duration::from_secs(10)), 2.0);
        assert_eq!(tr.at(Duration::from_secs(25)), 40.0);
        assert_eq!(tr.at(Duration::from_secs(300)), 40.0); // clamps to last
    }

    #[test]
    fn constant_trace() {
        let tr = BandwidthTrace::constant(10.0);
        assert_eq!(tr.at(Duration::from_secs(1000)), 10.0);
    }

    #[test]
    fn step_boundary_is_inclusive_on_the_new_step() {
        // Regression: `elapsed` landing *exactly* on a step boundary must
        // read the new step's value, one nanosecond earlier the old one.
        let p = Duration::from_secs(10);
        let tr = BandwidthTrace::steps(p, &[10.0, 2.0, 40.0], Duration::from_secs(40));
        for (k, expect) in [(0u32, 10.0), (1, 2.0), (2, 40.0), (3, 10.0)] {
            let boundary = p * k;
            assert_eq!(tr.at(boundary), expect, "boundary k={k}");
            if k > 0 {
                let just_before = boundary - Duration::from_nanos(1);
                let prev = [10.0, 2.0, 40.0][(k as usize - 1) % 3];
                assert_eq!(tr.at(just_before), prev, "just before boundary k={k}");
            }
        }
    }

    #[test]
    fn trace_longer_than_total_truncates_and_clamps() {
        // More cycle values than fit under `total`: construction stops at
        // the last step that *starts* before `total` (no phantom step at
        // or past it), and queries beyond hold the final value.
        let tr = BandwidthTrace::steps(
            Duration::from_secs(10),
            &[1.0, 2.0, 3.0, 4.0, 5.0],
            Duration::from_secs(30),
        );
        assert_eq!(tr.points.len(), 3, "steps must stop strictly before total");
        assert_eq!(tr.points.last().unwrap().0, Duration::from_secs(20));
        assert_eq!(tr.at(Duration::from_secs(29)), 3.0);
        // `total` is not a step: the value from the last real step holds.
        assert_eq!(tr.at(Duration::from_secs(30)), 3.0);
        assert_eq!(tr.at(Duration::from_secs(1_000_000)), 3.0);
        // An exact-multiple total never emits a step at t == total.
        let exact = BandwidthTrace::steps(
            Duration::from_secs(10),
            &[1.0, 2.0],
            Duration::from_secs(20),
        );
        assert_eq!(exact.points.len(), 2);
        assert_eq!(exact.at(Duration::from_secs(20)), 2.0);
    }

    #[test]
    fn degenerate_totals_yield_a_usable_trace() {
        // Regression: `total` shorter than one period used to produce an
        // empty point list, and `at()` panicked on first use.
        let tr = BandwidthTrace::steps(
            Duration::from_secs(10),
            &[7.0, 9.0],
            Duration::from_secs(3),
        );
        assert_eq!(tr.points.len(), 1);
        assert_eq!(tr.at(Duration::ZERO), 7.0);
        assert_eq!(tr.at(Duration::from_secs(100)), 7.0);
        let zero = BandwidthTrace::steps(Duration::from_secs(10), &[5.0], Duration::ZERO);
        assert_eq!(zero.at(Duration::from_secs(1)), 5.0);
    }

    #[test]
    #[should_panic(expected = "period must be positive")]
    fn zero_period_rejected() {
        // Regression: a zero period used to spin `steps` forever.
        let _ = BandwidthTrace::steps(Duration::ZERO, &[1.0], Duration::from_secs(1));
    }
}
