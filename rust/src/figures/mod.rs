//! Figure/table data generators: one function per paper exhibit
//! (Figs. 1–10, Tables I–II). The `cargo bench` targets print these as
//! aligned tables and dump JSON series under `target/figures/` for
//! EXPERIMENTS.md. Keeping the computation here (library) lets the
//! integration tests assert the *shape* claims the paper makes.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::device::{profiles, ComputeProfile};
use crate::models::zoo;
use crate::optimizer::{Algorithm, Nsga2Params, SmartSplitResult, SplitDecision};
use crate::perfmodel::{EnergyBreakdown, LatencyBreakdown, NetworkEnv, PerfModel};
use crate::planner::{PlanRequest, Planner, PlannerConfig, Strategy};
use crate::util::json::Json;

/// The four split-target models of the evaluation.
pub const MODELS: [&str; 4] = ["alexnet", "vgg11", "vgg13", "vgg16"];

/// Build the perf model for (model, phone) at the paper's 10 Mbps testbed.
pub fn perf_model<'a>(
    profile: &'a crate::models::ModelProfile,
    phone: &'a ComputeProfile,
    bandwidth_mbps: f64,
) -> PerfModel<'a> {
    PerfModel::new(
        phone,
        profiles::cloud_server(),
        phone.wifi.expect("phone has a radio").radio_power(),
        NetworkEnv::with_bandwidth(bandwidth_mbps),
        profile,
    )
}

// ---------------------------------------------------------------- Fig 1/2

/// Latency vs split index for one (model, phone): the paper's pilot sweep.
pub fn latency_sweep(
    model: &str,
    phone: &ComputeProfile,
    bandwidth_mbps: f64,
) -> Result<Vec<(usize, LatencyBreakdown)>> {
    let profile = zoo::by_name(model).context("unknown model")?.analyze(1);
    let pm = perf_model(&profile, phone, bandwidth_mbps);
    Ok((1..=profile.num_layers).map(|l1| (l1, pm.latency(l1))).collect())
}

// ---------------------------------------------------------------- Fig 3/4

/// Energy vs split index for one (model, phone).
pub fn energy_sweep(
    model: &str,
    phone: &ComputeProfile,
    bandwidth_mbps: f64,
) -> Result<Vec<(usize, EnergyBreakdown)>> {
    let profile = zoo::by_name(model).context("unknown model")?.analyze(1);
    let pm = perf_model(&profile, phone, bandwidth_mbps);
    Ok((1..=profile.num_layers).map(|l1| (l1, pm.energy(l1))).collect())
}

// ------------------------------------------------------------------ Fig 5

/// Client-energy-only comparison between the two phones (paper: "client
/// energy consumption remains almost similar for both devices").
pub fn client_energy_compare(
    model: &str,
    bandwidth_mbps: f64,
) -> Result<Vec<(usize, f64, f64)>> {
    let profile = zoo::by_name(model).context("unknown model")?.analyze(1);
    let j6 = perf_model(&profile, profiles::samsung_j6(), bandwidth_mbps);
    let redmi = perf_model(&profile, profiles::redmi_note8(), bandwidth_mbps);
    Ok((1..=profile.num_layers)
        .map(|l1| (l1, j6.energy(l1).client_j, redmi.energy(l1).client_j))
        .collect())
}

// ----------------------------------------------------- Fig 6 + Table I

/// One paper-mode façade request for an already-analyzed model — every
/// figure plans through [`crate::planner::Planner`] with the configured
/// seed used as-is (byte-compatible with the pre-façade `smartsplit`
/// calls).
fn paper_request(
    profile: Arc<crate::models::ModelProfile>,
    phone: &'static ComputeProfile,
    bandwidth_mbps: f64,
    strategy: Strategy,
) -> PlanRequest {
    PlanRequest::two_tier(
        profile,
        phone,
        crate::coordinator::battery::BatteryBand::Comfort,
        bandwidth_mbps,
        strategy,
    )
}

/// Run Algorithm 1 for one model; the Pareto set feeds Fig. 6 and the
/// TOPSIS choice is the Table I row.
pub fn pareto_and_choice(
    model: &str,
    phone: &'static ComputeProfile,
    bandwidth_mbps: f64,
    params: &Nsga2Params,
) -> Result<SmartSplitResult> {
    let planner = Planner::new(PlannerConfig::paper(params.clone()));
    let profile = Arc::new(zoo::by_name(model).context("unknown model")?.analyze(1));
    let req = paper_request(profile, phone, bandwidth_mbps, Strategy::SmartSplit);
    let outcome = planner.plan(&req);
    let decision = outcome.plan.context("no feasible split")?;
    Ok(SmartSplitResult {
        decision: SplitDecision { l1: decision.l1 },
        pareto: outcome
            .pareto
            .unwrap_or_default()
            .into_iter()
            .map(|(p, o)| (p.l1, o))
            .collect(),
        evaluations: outcome.provenance.evaluations,
    })
}

/// Min-max normalise Fig. 6's three objective columns (the paper plots
/// normalised values).
pub fn normalise_columns(rows: &[[f64; 3]]) -> Vec<[f64; 3]> {
    let mut lo = [f64::INFINITY; 3];
    let mut hi = [f64::NEG_INFINITY; 3];
    for r in rows {
        for j in 0..3 {
            lo[j] = lo[j].min(r[j]);
            hi[j] = hi[j].max(r[j]);
        }
    }
    rows.iter()
        .map(|r| {
            let mut out = [0.0; 3];
            for j in 0..3 {
                let span = hi[j] - lo[j];
                out[j] = if span > 0.0 { (r[j] - lo[j]) / span } else { 0.0 };
            }
            out
        })
        .collect()
}

// ------------------------------------------- Table II + Figs 7/8/9

/// One algorithm × model cell: the chosen split and its objective values,
/// averaged over `runs` (only RS actually varies — the paper averages 100
/// runs the same way).
#[derive(Clone, Debug)]
pub struct AlgoCell {
    pub algorithm: Algorithm,
    pub model: String,
    pub mean_l1: f64,
    pub latency_s: f64,
    pub energy_j: f64,
    pub memory_bytes: f64,
}

pub fn algorithm_comparison(
    phone: &'static ComputeProfile,
    bandwidth_mbps: f64,
    params: &Nsga2Params,
    runs: usize,
    seed: u64,
) -> Result<Vec<AlgoCell>> {
    let planner = Planner::new(PlannerConfig::paper(params.clone()));
    let mut out = Vec::new();
    for model in MODELS {
        // One analyzed profile per model, shared between the evaluation
        // context and every request (which only vary by strategy).
        let profile = Arc::new(zoo::by_name(model).unwrap().analyze(1));
        let pm = perf_model(&profile, phone, bandwidth_mbps);
        let base_req =
            paper_request(Arc::clone(&profile), phone, bandwidth_mbps, Strategy::SmartSplit);
        for algo in Algorithm::ALL {
            let mut req = base_req.clone();
            req.strategy = Strategy::from(algo);
            let (mut l1s, mut f1, mut f2, mut f3) = (0.0, 0.0, 0.0, 0.0);
            // Deterministic algorithms: evaluate once, weight by runs.
            let n = if algo == Algorithm::Rs { runs } else { 1 };
            for i in 0..n {
                // Independent-run requests give RS a fresh draw per run
                // (salted by the caller's seed); run 0 would be the
                // canonical decision for every i.
                let run = if algo == Algorithm::Rs {
                    seed.wrapping_mul(1009).wrapping_add(i as u64 + 1)
                } else {
                    0
                };
                let d = planner
                    .plan(&req.clone().with_run(run))
                    .plan
                    .context("no feasible split")?;
                l1s += d.l1 as f64;
                f1 += pm.f1(d.l1);
                f2 += pm.f2(d.l1);
                f3 += pm.f3(d.l1);
            }
            out.push(AlgoCell {
                algorithm: algo,
                model: model.to_string(),
                mean_l1: l1s / n as f64,
                latency_s: f1 / n as f64,
                energy_j: f2 / n as f64,
                memory_bytes: f3 / n as f64,
            });
        }
    }
    Ok(out)
}

// ----------------------------------------------------------------- Fig 10

/// Fig. 10 row: a model under a strategy, with accuracy.
#[derive(Clone, Debug)]
pub struct Fig10Row {
    pub label: String,
    pub top1_accuracy: f64,
    pub latency_s: f64,
    pub energy_j: f64,
    pub memory_bytes: f64,
}

/// SmartSplit on the four CNNs vs MobileNetV2-on-phone (COS) vs
/// VGG16-on-phone (COS).
pub fn mobilenet_comparison(
    phone: &'static ComputeProfile,
    bandwidth_mbps: f64,
    params: &Nsga2Params,
) -> Result<Vec<Fig10Row>> {
    let planner = Planner::new(PlannerConfig::paper(params.clone()));
    let mut rows = Vec::new();
    for model in MODELS {
        let spec = zoo::by_name(model).unwrap();
        let profile = Arc::new(spec.analyze(1));
        let pm = perf_model(&profile, phone, bandwidth_mbps);
        let req = paper_request(Arc::clone(&profile), phone, bandwidth_mbps, Strategy::SmartSplit);
        let d = planner.plan(&req).plan.context("no feasible split")?;
        rows.push(Fig10Row {
            label: format!("{model}+SmartSplit(l1={})", d.l1),
            top1_accuracy: spec.top1_accuracy,
            latency_s: pm.f1(d.l1),
            energy_j: pm.f2(d.l1),
            memory_bytes: pm.f3(d.l1),
        });
    }
    for model in ["mobilenet_v2", "vgg16"] {
        let spec = zoo::by_name(model).unwrap();
        let profile = spec.analyze(1);
        let pm = perf_model(&profile, phone, bandwidth_mbps);
        let l = profile.num_layers;
        rows.push(Fig10Row {
            label: format!("{model}+COS"),
            top1_accuracy: spec.top1_accuracy,
            latency_s: pm.f1(l),
            energy_j: pm.f2(l),
            memory_bytes: pm.f3(l),
        });
    }
    Ok(rows)
}

// ------------------------------------------------------------- JSON dump

/// Write a figure's series to `target/figures/<name>.json`.
pub fn dump_json(name: &str, value: &Json) -> Result<std::path::PathBuf> {
    let dir = std::path::Path::new("target/figures");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, value.to_string_pretty())?;
    Ok(path)
}

/// Series helper: BTreeMap<label, Vec<(x, y)>> → Json.
pub fn series_json(series: &BTreeMap<String, Vec<(f64, f64)>>) -> Json {
    Json::Obj(
        series
            .iter()
            .map(|(k, pts)| {
                (
                    k.clone(),
                    Json::Arr(
                        pts.iter()
                            .map(|(x, y)| Json::Arr(vec![Json::Num(*x), Json::Num(*y)]))
                            .collect(),
                    ),
                )
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_params() -> Nsga2Params {
        Nsga2Params { pop_size: 40, generations: 40, ..Default::default() }
    }

    #[test]
    fn fig1_shape_upload_dominates_total_latency() {
        // Paper: "the Upload Latency being the primary contributing factor
        // to the total latency" on both phones at 10 Mbps. With ref-[39]
        // memory accounting this holds for every conv-trunk split (the
        // first half of the network, where the shipped activation is a
        // conv feature map) and for the majority of all splits.
        for phone in [profiles::samsung_j6(), profiles::redmi_note8()] {
            for model in MODELS {
                let sweep = latency_sweep(model, phone, 10.0).unwrap();
                let n = sweep.len() - 1; // COS row has no upload
                let dominant = |b: &LatencyBreakdown| {
                    b.upload_s > b.client_s && b.upload_s > b.server_s
                };
                let first_half = sweep[..n / 2].iter().filter(|(_, b)| dominant(b)).count();
                assert_eq!(
                    first_half,
                    n / 2,
                    "{model}/{}: upload not dominant across the conv trunk",
                    phone.name
                );
                let overall = sweep[..n].iter().filter(|(_, b)| dominant(b)).count();
                assert!(
                    overall * 2 > n,
                    "{model}/{}: upload dominates only {overall}/{n} splits",
                    phone.name,
                );
            }
        }
    }

    #[test]
    fn fig1_shape_client_latency_increases() {
        let sweep = latency_sweep("vgg16", profiles::samsung_j6(), 10.0).unwrap();
        for w in sweep.windows(2) {
            assert!(w[1].1.client_s >= w[0].1.client_s);
        }
    }

    #[test]
    fn fig3_4_shape_wifi_contrast() {
        // Paper key takeaway: upload energy is the primary factor on the
        // J6 (802.11n radio) — true for the majority of conv-trunk splits —
        // while client energy dominates on the Redmi Note 8 (802.11ac)
        // across the majority of ALL splits.
        for model in MODELS {
            let j6 = energy_sweep(model, profiles::samsung_j6(), 10.0).unwrap();
            let n = j6.len() - 1;
            let j6_upload_dom = j6[..n / 2]
                .iter()
                .filter(|(_, e)| e.upload_j > e.client_j)
                .count();
            assert!(
                j6_upload_dom * 2 > n / 2,
                "{model}: J6 upload-dominant at only {j6_upload_dom}/{} conv splits",
                n / 2
            );
            let redmi = energy_sweep(model, profiles::redmi_note8(), 10.0).unwrap();
            let redmi_client_dom = redmi[..n]
                .iter()
                .filter(|(_, e)| e.client_j > e.upload_j)
                .count();
            assert!(
                redmi_client_dom * 2 > n,
                "{model}: Redmi client-dominant at only {redmi_client_dom}/{n}"
            );
            // Download energy negligible everywhere (< 2% of total).
            for (l1, e) in &j6[..n] {
                assert!(e.download_j < 0.02 * e.total(), "{model} l1={l1}");
            }
        }
    }

    #[test]
    fn fig5_shape_client_energy_similar_across_phones() {
        // Paper: "the client energy consumption remains almost similar for
        // both the devices" — within a small constant factor.
        for (l1, j6, redmi) in client_energy_compare("alexnet", 10.0).unwrap() {
            let ratio = redmi / j6.max(1e-12);
            assert!(
                (0.5..=3.0).contains(&ratio),
                "l1={l1}: client energy ratio {ratio}"
            );
        }
    }

    #[test]
    fn table1_choices_are_feasible_early_splits() {
        // Paper Table I picks early/mid splits (3, 11, 10, 10) — memory-
        // light choices. Ours must be feasible and in the early half.
        for model in MODELS {
            let r = pareto_and_choice(model, profiles::samsung_j6(), 10.0, &quick_params())
                .unwrap();
            let l = zoo::by_name(model).unwrap().num_layers();
            assert!(r.decision.l1 >= 1 && r.decision.l1 < l);
            assert!(
                r.decision.l1 * 2 <= l + 2,
                "{model}: TOPSIS chose late split {} of {l}",
                r.decision.l1
            );
        }
    }

    #[test]
    fn figs789_shape_claims() {
        let cells =
            algorithm_comparison(profiles::samsung_j6(), 10.0, &quick_params(), 20, 1).unwrap();
        let get = |m: &str, a: Algorithm| {
            cells
                .iter()
                .find(|c| c.model == m && c.algorithm == a)
                .unwrap()
                .clone()
        };
        for model in MODELS {
            let ss = get(model, Algorithm::SmartSplit);
            let lbo = get(model, Algorithm::Lbo);
            let cos = get(model, Algorithm::Cos);
            let coc = get(model, Algorithm::Coc);
            // COC: minimum memory (zero on device).
            assert_eq!(coc.memory_bytes, 0.0, "{model}");
            // COS: maximum energy and memory of all algorithms.
            for c in cells.iter().filter(|c| c.model == *model) {
                assert!(cos.energy_j >= c.energy_j - 1e-9, "{model} {:?}", c.algorithm);
                assert!(cos.memory_bytes >= c.memory_bytes - 1e-9, "{model}");
            }
            // SmartSplit vs LBO (paper §VI-C): strictly lower memory, and
            // energy no worse than ~10% (lower for 3 of 4 models under our
            // calibration — EXPERIMENTS.md records the per-model ratios).
            assert!(ss.energy_j <= 1.10 * lbo.energy_j, "{model} energy vs LBO");
            assert!(ss.memory_bytes < lbo.memory_bytes, "{model} memory vs LBO");
            // LBO has the minimum latency by construction.
            for c in cells.iter().filter(|c| c.model == *model) {
                if c.algorithm != Algorithm::Coc {
                    assert!(lbo.latency_s <= c.latency_s + 1e-9, "{model} {:?}", c.algorithm);
                }
            }
        }
    }

    #[test]
    fn fig10_shape_claims() {
        let rows =
            mobilenet_comparison(profiles::samsung_j6(), 10.0, &quick_params()).unwrap();
        let vgg16_split = rows.iter().find(|r| r.label.starts_with("vgg16+Smart")).unwrap();
        let mobilenet = rows.iter().find(|r| r.label.starts_with("mobilenet")).unwrap();
        let vgg16_cos = rows.iter().find(|r| r.label == "vgg16+COS").unwrap();
        // Split memory far below running the same VGG16 fully on-phone.
        assert!(vgg16_split.memory_bytes < 0.25 * vgg16_cos.memory_bytes);
        // Split energy below VGG16-COS energy.
        assert!(vgg16_split.energy_j < vgg16_cos.energy_j);
        // Divergence note (EXPERIMENTS.md §Fig10): under ref-[39] memory
        // accounting MobileNetV2's 3.5M-param COS footprint is SMALLER
        // than a mid-network VGG16 split, so the paper's "lower memory
        // than MobileNetV2" claim only holds for l1 ≤ 2 splits; we record
        // the measured values instead of forcing the claim.
        // MobileNetV2 has lower latency (it's tiny) — the paper concedes
        // this and argues the trade-off.
        assert!(mobilenet.latency_s < vgg16_split.latency_s);
    }

    #[test]
    fn normalise_columns_unit_range() {
        let rows = vec![[1.0, 10.0, 5.0], [3.0, 20.0, 5.0], [2.0, 15.0, 5.0]];
        let n = normalise_columns(&rows);
        assert_eq!(n[0], [0.0, 0.0, 0.0]);
        assert_eq!(n[1], [1.0, 1.0, 0.0]);
        assert_eq!(n[2], [0.5, 0.5, 0.0]);
    }
}
