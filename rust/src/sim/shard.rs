//! Sharded event engine: the single binary-heap queue of
//! [`super::engine::EventQueue`] partitioned by edge site, with
//! conservative-lookahead windows (DESIGN.md §16).
//!
//! # Why sharding cannot mean parallel *dispatch* here
//!
//! The repo's determinism contract (PRs 2–7) is byte-for-byte replay:
//! one scenario RNG stream, one planner cache, one global FIFO
//! sequence. Any engine that dispatches two handlers concurrently
//! races those shared streams and the contract is gone. So the
//! sharded engine keeps dispatch **sequential in the canonical global
//! (time, seq) order** — a deterministic k-way merge over per-shard
//! heaps that share one global `seq` counter — and confines
//! parallelism to the *window drains*: at each window barrier every
//! shard pops its due entries (already locally ordered by its heap)
//! into a sorted run, on scoped threads
//! ([`crate::util::pool::scoped_for_each`]) when the backlog is worth
//! it. Pop order is identical to the one big heap by induction:
//! identical pops ⇒ identical handler execution ⇒ identical schedules
//! and `seq` assignment ⇒ identical next pop.
//!
//! # The lookahead bound
//!
//! Windows are sized by [`lookahead_bound`]: no event generated while
//! dispatching at one site can take effect at another site sooner
//! than the cheapest cross-site path — an edge handover costs at
//! least the configured handover relay plus one backhaul hop, and
//! every cloud round-trip crosses a backhaul too. The bound is a
//! *performance* parameter only (it sets how much work each drain
//! batches); the merge enforces global order unconditionally, which
//! is exactly why arbitrary — even randomized — shard layouts replay
//! the 1-shard reference byte-for-byte (`tests/shard_parity.rs`).
//!
//! Determinism note: this module is in detlint's export plane — no
//! hasher-ordered containers, no relaxed atomics, no wall clock.

use std::collections::{BinaryHeap, VecDeque};

use super::engine::{Entry, Event, SimTime};
use crate::edge::EdgeTopology;
use crate::util::pool;
use crate::util::rng::Xoshiro256;

/// Fallback lookahead when the topology gives no positive bound
/// (no edge tier, or a free backhaul with zero handover cost):
/// one default handover relay (50 ms).
pub const DEFAULT_LOOKAHEAD_S: f64 = 0.05;

/// Window drains only fork scoped threads when the heaps hold at
/// least this many entries in total; below it the per-window drain is
/// cheaper inline than the thread spawn/join. A deterministic
/// function of queue state, so the threshold can never affect replay.
const PARALLEL_DRAIN_MIN_EVENTS: usize = 4096;

/// Conservative lookahead for a scenario: the minimum cross-shard
/// event delay, `handover_cost + min(backhaul latency)`. Falls back
/// to [`DEFAULT_LOOKAHEAD_S`] when the bound degenerates to zero (or
/// there is no edge tier at all — then every event routes to shard 0
/// and the window size is moot anyway).
pub fn lookahead_bound(topology: Option<&EdgeTopology>, handover_cost_s: f64) -> f64 {
    let Some(topo) = topology else {
        return DEFAULT_LOOKAHEAD_S;
    };
    let bound = handover_cost_s.max(0.0) + topo.min_backhaul_latency_s();
    if bound.is_finite() && bound > 0.0 {
        bound
    } else {
        DEFAULT_LOOKAHEAD_S
    }
}

/// Which shard owns each edge site. Devices inherit the shard of the
/// site they are attached to; fleet-plane and cloud-plane events live
/// on shard 0. The layout decides *load balance only* — never results
/// (the parity property `tests/shard_parity.rs` pins down with
/// randomized layouts).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardLayout {
    /// `site_shard[k]` = shard owning site `k`; values `< shards`.
    site_shard: Vec<u32>,
    shards: usize,
}

impl ShardLayout {
    /// Everything on one shard — the frozen reference configuration.
    pub fn single(num_sites: usize) -> ShardLayout {
        ShardLayout { site_shard: vec![0; num_sites], shards: 1 }
    }

    /// Contiguous near-equal split of `num_sites` sites into `shards`
    /// groups (first `num_sites % shards` groups one site larger) —
    /// the same arithmetic as [`EdgeTopology::shard_map`].
    pub fn contiguous(shards: usize, num_sites: usize) -> ShardLayout {
        let shards = shards.max(1);
        let base = num_sites / shards;
        let extra = num_sites % shards;
        let mut site_shard = Vec::with_capacity(num_sites);
        for shard in 0..shards {
            let len = base + usize::from(shard < extra);
            for _ in 0..len {
                site_shard.push(shard as u32);
            }
        }
        ShardLayout { site_shard, shards }
    }

    /// The layout the simulator uses: [`EdgeTopology::shard_map`] over
    /// the scenario's real topology.
    pub fn for_topology(shards: usize, topo: &EdgeTopology) -> ShardLayout {
        ShardLayout { site_shard: topo.shard_map(shards), shards: shards.max(1) }
    }

    /// A seeded uniformly random site→shard assignment — pathological
    /// on purpose (shards may own scattered or zero sites), used by
    /// the parity property tests to show the layout cannot matter.
    pub fn random(shards: usize, num_sites: usize, seed: u64) -> ShardLayout {
        let shards = shards.max(1);
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let site_shard =
            (0..num_sites).map(|_| rng.gen_range(0, shards - 1) as u32).collect();
        ShardLayout { site_shard, shards }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    pub fn num_sites(&self) -> usize {
        self.site_shard.len()
    }

    /// Shard owning `site` (shard 0 for sites beyond the map — the
    /// flat-city degenerate where no edge tier exists).
    pub fn shard_of_site(&self, site: usize) -> u32 {
        self.site_shard.get(site).copied().unwrap_or(0)
    }

    /// How many sites `shard` owns.
    pub fn sites_in(&self, shard: u32) -> usize {
        self.site_shard.iter().filter(|&&s| s == shard).count()
    }
}

/// Per-shard event state: the unpopped heap plus the current window's
/// drained run (sorted by (time, seq) — heap pop order).
#[derive(Default)]
struct Shard {
    heap: BinaryHeap<Entry>,
    run: VecDeque<Entry>,
    popped: u64,
}

impl Shard {
    /// Move every heap entry due in the current window (`time <=
    /// window_end`, inclusive so a zero lookahead still drains the
    /// frontier events) onto the back of the run. Heap pops are
    /// (time, seq)-ordered, so the run stays sorted.
    fn drain_due(&mut self, window_end: SimTime) {
        while let Some(top) = self.heap.peek() {
            if top.time > window_end {
                break;
            }
            let entry = self.heap.pop().expect("peeked heap entry");
            self.run.push_back(entry);
        }
    }

    fn len(&self) -> usize {
        self.heap.len() + self.run.len()
    }
}

/// Per-shard share of the run, reported in
/// [`crate::sim::SimReport`]: how many sites the shard owned and how
/// many events it dispatched.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardSlice {
    pub shard: usize,
    /// Sites owned by this shard under the run's layout.
    pub sites: usize,
    /// Events dispatched from this shard's queue.
    pub events: u64,
}

/// Drop-in replacement for [`super::engine::EventQueue`] with the
/// identical scheduling API and the identical pop order, for every
/// layout. See the module docs for the protocol.
pub struct ShardedQueue {
    layout: ShardLayout,
    shards: Vec<Shard>,
    /// `device_shard[d]` = shard owning device `d`'s current edge
    /// attachment (shard 0 when detached) — maintained by
    /// [`ShardedQueue::attach_device`] from spawn/re-attach/outage
    /// paths so device-keyed events route to the owning shard.
    device_shard: Vec<u32>,
    /// One global insertion sequence across all shards: the FIFO
    /// tie-break, byte-compatible with the single-heap engine.
    seq: u64,
    now: SimTime,
    popped: u64,
    /// Current window's inclusive upper edge; entries at or below it
    /// are drained into runs and eligible to pop.
    window_end: SimTime,
    lookahead: f64,
    windows: u64,
    cross_shard: u64,
    /// Shard of the most recently popped event — the "sender" against
    /// which [`ShardedQueue::schedule`] classifies cross-shard sends.
    current_shard: u32,
}

impl ShardedQueue {
    pub fn new(layout: ShardLayout, lookahead: f64) -> ShardedQueue {
        let lookahead = if lookahead.is_finite() && lookahead > 0.0 {
            lookahead
        } else {
            DEFAULT_LOOKAHEAD_S
        };
        let shards = (0..layout.shards()).map(|_| Shard::default()).collect();
        ShardedQueue {
            layout,
            shards,
            device_shard: Vec::new(),
            seq: 0,
            now: 0.0,
            popped: 0,
            // Below every legal timestamp, so the very first pop opens
            // window 1 at the earliest scheduled event.
            window_end: f64::NEG_INFINITY,
            lookahead,
            windows: 0,
            cross_shard: 0,
            current_shard: 0,
        }
    }

    /// Current virtual time — the timestamp of the last popped event.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Events popped so far (the `events/sec` numerator in `sim_scale`).
    pub fn processed(&self) -> u64 {
        self.popped
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(Shard::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(|s| s.len() == 0)
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Window barriers crossed so far.
    pub fn windows(&self) -> u64 {
        self.windows
    }

    /// Events that were scheduled onto a different shard than the one
    /// dispatching them — the cross-shard message traffic a
    /// distributed engine would put on the wire. Setup-time seeding
    /// (schedules before the first pop) is excluded: those events were
    /// never dispatched *from* a shard.
    pub fn cross_shard_events(&self) -> u64 {
        self.cross_shard
    }

    /// The conservative lookahead this queue windows by.
    pub fn lookahead(&self) -> f64 {
        self.lookahead
    }

    /// Record device `device`'s edge attachment (`None` = detached).
    /// Pure routing metadata: it decides which shard's heap the
    /// device's events land in, never their order.
    pub fn attach_device(&mut self, device: usize, site: Option<usize>) {
        if device >= self.device_shard.len() {
            self.device_shard.resize(device + 1, 0);
        }
        self.device_shard[device] = match site {
            Some(s) => self.layout.shard_of_site(s),
            None => 0,
        };
    }

    fn shard_of_device(&self, device: usize) -> u32 {
        self.device_shard.get(device).copied().unwrap_or(0)
    }

    /// Event routing: site-keyed events go to the site's shard,
    /// device-keyed events to the device's attached site's shard, and
    /// fleet-plane / cloud-plane events to shard 0 (the coordinator
    /// shard — arrivals, churn, re-optimise sweeps, and the cloud tier
    /// are global state no site owns).
    fn route(&self, event: &Event) -> u32 {
        match event {
            Event::Arrival
            | Event::Reoptimize
            | Event::Join
            | Event::Horizon
            | Event::CloudArrive { .. }
            | Event::CloudDone { .. } => 0,
            Event::Uplinked { site, device, .. } => match site {
                Some(s) => self.layout.shard_of_site(*s),
                None => self.shard_of_device(*device),
            },
            Event::EdgeDone { site, .. }
            | Event::Reattach { site, .. }
            | Event::SiteDown { site }
            | Event::SiteUp { site }
            | Event::BackhaulDegrade { site, .. }
            | Event::BackhaulRestore { site }
            | Event::FlashCrowdStart { site, .. }
            | Event::FlashCrowdEnd { site } => self.layout.shard_of_site(*site),
            Event::Handover { device } | Event::Leave { device } => {
                self.shard_of_device(*device)
            }
        }
    }

    /// Schedule `event` at absolute time `at` (clamped to the present,
    /// like the single-heap engine).
    pub fn schedule(&mut self, at: SimTime, event: Event) {
        debug_assert!(at.is_finite(), "non-finite event time");
        let target = self.route(&event);
        // Setup-time seeding (before the first pop) has no dispatching
        // shard, so it is never attributed as cross-shard traffic.
        if self.popped > 0 && target != self.current_shard {
            self.cross_shard += 1;
        }
        let entry = Entry { time: at.max(self.now), seq: self.seq, event };
        self.seq += 1;
        self.shards[target as usize].heap.push(entry);
    }

    /// Schedule `event` at `dt` seconds from now.
    pub fn schedule_in(&mut self, dt: SimTime, event: Event) {
        debug_assert!(dt >= 0.0, "negative delay {dt}");
        self.schedule(self.now + dt.max(0.0), event);
    }

    /// Pop the global-earliest event, advancing the virtual clock —
    /// the k-way merge. The candidate set is every shard's run front
    /// plus every in-window heap top (events scheduled *during* the
    /// current window land in heaps and must compete immediately);
    /// when the window is exhausted, the next one opens at the
    /// earliest remaining heap entry plus the lookahead, and all
    /// shards drain in parallel behind that barrier.
    pub fn pop(&mut self) -> Option<(SimTime, Event)> {
        loop {
            if let Some((shard, from_run)) = self.best_candidate() {
                let entry = if from_run {
                    self.shards[shard].run.pop_front().expect("candidate run front")
                } else {
                    self.shards[shard].heap.pop().expect("candidate heap top")
                };
                self.now = entry.time;
                self.popped += 1;
                self.shards[shard].popped += 1;
                self.current_shard = shard as u32;
                return Some((entry.time, entry.event));
            }
            // No in-window work anywhere: cross the barrier into the
            // next window (runs are all empty here — run fronts are
            // unconditional candidates).
            let next = self.next_heap_time()?;
            self.window_end = next + self.lookahead;
            self.windows += 1;
            self.drain_window();
        }
    }

    /// The globally (time, seq)-smallest eligible entry:
    /// `(shard, from_run)`, or `None` when no shard has in-window work.
    fn best_candidate(&self) -> Option<(usize, bool)> {
        let mut best: Option<(SimTime, u64, usize, bool)> = None;
        for (i, sh) in self.shards.iter().enumerate() {
            if let Some(front) = sh.run.front() {
                consider(&mut best, front.time, front.seq, i, true);
            }
            if let Some(top) = sh.heap.peek() {
                if top.time <= self.window_end {
                    consider(&mut best, top.time, top.seq, i, false);
                }
            }
        }
        best.map(|(_, _, shard, from_run)| (shard, from_run))
    }

    /// Earliest timestamp still heaped across all shards.
    fn next_heap_time(&self) -> Option<SimTime> {
        let mut next: Option<SimTime> = None;
        for sh in &self.shards {
            if let Some(top) = sh.heap.peek() {
                next = Some(match next {
                    None => top.time,
                    Some(t) => t.min(top.time),
                });
            }
        }
        next
    }

    /// The window barrier's drain phase: every shard moves its due
    /// entries into its run — on scoped threads when the backlog
    /// clears [`PARALLEL_DRAIN_MIN_EVENTS`], inline otherwise. The
    /// threshold is a pure function of queue state and the drain
    /// output is per-shard-local, so thread count never touches replay.
    fn drain_window(&mut self) {
        let window_end = self.window_end;
        let backlog: usize = self.shards.iter().map(|s| s.heap.len()).sum();
        if self.shards.len() > 1 && backlog >= PARALLEL_DRAIN_MIN_EVENTS {
            pool::scoped_for_each(&mut self.shards, |_, sh| sh.drain_due(window_end));
        } else {
            for sh in &mut self.shards {
                sh.drain_due(window_end);
            }
        }
    }

    /// Per-shard dispatch stats for [`crate::sim::SimReport`].
    pub fn shard_slices(&self) -> Vec<ShardSlice> {
        self.shards
            .iter()
            .enumerate()
            .map(|(i, sh)| ShardSlice {
                shard: i,
                sites: self.layout.sites_in(i as u32),
                events: sh.popped,
            })
            .collect()
    }
}

/// Keep the (time, seq)-smallest candidate. Free function so the
/// borrow in [`ShardedQueue::best_candidate`] stays immutable.
fn consider(
    best: &mut Option<(SimTime, u64, usize, bool)>,
    time: SimTime,
    seq: u64,
    shard: usize,
    from_run: bool,
) {
    let earlier = match best {
        None => true,
        Some((t, q, _, _)) => time < *t || (time == *t && seq < *q),
    };
    if earlier {
        *best = Some((time, seq, shard, from_run));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::EventQueue;

    fn q(layout: ShardLayout) -> ShardedQueue {
        ShardedQueue::new(layout, 0.05)
    }

    #[test]
    fn single_shard_mirrors_the_reference_engine() {
        let mut a = EventQueue::new();
        let mut b = q(ShardLayout::single(3));
        for (t, ev) in [
            (3.0, Event::Arrival),
            (1.0, Event::Horizon),
            (2.0, Event::Join),
            (1.0, Event::Reoptimize),
        ] {
            a.schedule(t, ev.clone());
            b.schedule(t, ev);
        }
        while !a.is_empty() {
            assert_eq!(a.pop(), b.pop());
            assert_eq!(a.now(), b.now());
        }
        assert!(b.pop().is_none());
        assert_eq!(a.processed(), b.processed());
    }

    #[test]
    fn equal_timestamps_pop_fifo_across_shards() {
        // 100 same-timestamp events scattered over 4 shards by site:
        // the global seq tie-break must reproduce submission order.
        let mut sq = q(ShardLayout::contiguous(4, 8));
        for s in 0..100 {
            sq.schedule(5.0, Event::SiteDown { site: s % 8 });
        }
        for s in 0..100 {
            assert_eq!(sq.pop(), Some((5.0, Event::SiteDown { site: s % 8 })));
        }
        assert!(sq.pop().is_none());
    }

    #[test]
    fn past_schedules_clamp_and_schedule_in_is_relative() {
        let mut sq = q(ShardLayout::contiguous(2, 4));
        sq.schedule(10.0, Event::Arrival);
        sq.pop();
        sq.schedule(4.0, Event::SiteDown { site: 3 }); // the past clamps
        sq.schedule_in(2.5, Event::Horizon);
        assert_eq!(sq.pop(), Some((10.0, Event::SiteDown { site: 3 })));
        assert_eq!(sq.pop(), Some((12.5, Event::Horizon)));
    }

    #[test]
    fn mid_window_schedules_compete_immediately() {
        // An event scheduled during the current window, earlier than
        // remaining drained work, must pop before it — the heap-top
        // candidate path.
        let mut sq = ShardedQueue::new(ShardLayout::contiguous(2, 4), 10.0);
        sq.schedule(1.0, Event::Arrival);
        sq.schedule(5.0, Event::SiteDown { site: 3 });
        assert_eq!(sq.pop(), Some((1.0, Event::Arrival)));
        // Window is [1, 11]; both below entries are in-window but only
        // in the heap, never pre-drained.
        sq.schedule(2.0, Event::SiteUp { site: 3 });
        assert_eq!(sq.pop(), Some((2.0, Event::SiteUp { site: 3 })));
        assert_eq!(sq.pop(), Some((5.0, Event::SiteDown { site: 3 })));
    }

    #[test]
    fn window_count_tracks_the_lookahead() {
        // Three events one window apart: three barriers. Three events
        // inside one lookahead: one barrier.
        let mut sparse = ShardedQueue::new(ShardLayout::contiguous(2, 4), 0.05);
        for t in [0.0, 10.0, 20.0] {
            sparse.schedule(t, Event::Arrival);
        }
        while sparse.pop().is_some() {}
        assert_eq!(sparse.windows(), 3);

        let mut dense = ShardedQueue::new(ShardLayout::contiguous(2, 4), 0.05);
        for t in [0.0, 0.01, 0.02] {
            dense.schedule(t, Event::Arrival);
        }
        while dense.pop().is_some() {}
        assert_eq!(dense.windows(), 1);
    }

    #[test]
    fn routing_follows_sites_and_device_attachments() {
        let mut sq = q(ShardLayout::contiguous(2, 4)); // sites {0,1}→0, {2,3}→1
        sq.attach_device(7, Some(3));
        assert_eq!(sq.route(&Event::SiteDown { site: 1 }), 0);
        assert_eq!(sq.route(&Event::SiteDown { site: 2 }), 1);
        assert_eq!(sq.route(&Event::Handover { device: 7 }), 1);
        assert_eq!(sq.route(&Event::Leave { device: 99 }), 0, "unknown device → shard 0");
        assert_eq!(sq.route(&Event::Arrival), 0, "fleet plane → shard 0");
        assert_eq!(
            sq.route(&Event::CloudDone { req: 0, cloud: 0, device: 7, issued: 0.0 }),
            0,
            "cloud plane → shard 0"
        );
        sq.attach_device(7, None);
        assert_eq!(sq.route(&Event::Handover { device: 7 }), 0, "detached → shard 0");
    }

    #[test]
    fn cross_shard_sends_are_counted() {
        let mut sq = q(ShardLayout::contiguous(2, 4));
        // Setup-time seeding precedes any dispatch — no event has a
        // "from" shard yet, so nothing counts as a cross-shard send.
        sq.schedule(1.0, Event::SiteDown { site: 2 });
        sq.schedule(1.0, Event::Arrival);
        assert_eq!(sq.cross_shard_events(), 0);
        // After popping the site-2 event we dispatch *from* shard 1, so
        // a site-3 (same shard) send is local…
        sq.pop(); // site 2 (t=1.0, seq 0)
        sq.schedule(2.0, Event::SiteUp { site: 3 });
        assert_eq!(sq.cross_shard_events(), 0);
        // …and a shard-0 send crosses.
        sq.schedule(2.0, Event::Reoptimize);
        assert_eq!(sq.cross_shard_events(), 1);
        // Dispatching from shard 0 (the arrival), a site-2 send
        // crosses back the other way.
        sq.pop(); // arrival (t=1.0, seq 1)
        sq.schedule(2.0, Event::SiteDown { site: 2 });
        assert_eq!(sq.cross_shard_events(), 2);
    }

    #[test]
    fn shard_slices_account_sites_and_events() {
        let mut sq = q(ShardLayout::contiguous(2, 3)); // sites {0,1}→0, {2}→1
        sq.schedule(1.0, Event::SiteDown { site: 0 });
        sq.schedule(2.0, Event::SiteDown { site: 2 });
        sq.schedule(3.0, Event::SiteUp { site: 2 });
        while sq.pop().is_some() {}
        let slices = sq.shard_slices();
        assert_eq!(slices.len(), 2);
        assert_eq!(slices[0], ShardSlice { shard: 0, sites: 2, events: 1 });
        assert_eq!(slices[1], ShardSlice { shard: 1, sites: 1, events: 2 });
        assert_eq!(sq.processed(), 3);
    }

    #[test]
    fn parallel_drain_path_preserves_global_order() {
        // Enough backlog to clear PARALLEL_DRAIN_MIN_EVENTS so the
        // scoped-thread drain actually runs, mirrored against the
        // single-heap reference.
        let mut rng = Xoshiro256::seed_from_u64(99);
        let mut reference = EventQueue::new();
        let mut sq = ShardedQueue::new(ShardLayout::contiguous(4, 16), 1.0);
        for _ in 0..6000 {
            let t = rng.next_f64() * 3.0; // dense: most land in window 1
            let ev = Event::SiteDown { site: rng.gen_range(0, 15) };
            reference.schedule(t, ev.clone());
            sq.schedule(t, ev);
        }
        loop {
            let a = reference.pop();
            let b = sq.pop();
            assert_eq!(a, b);
            if a.is_none() {
                break;
            }
        }
        assert_eq!(sq.processed(), 6000);
    }

    fn random_event(rng: &mut Xoshiro256, sites: usize, devices: usize) -> Event {
        match rng.gen_range(0, 7) {
            0 => Event::Arrival,
            1 => Event::Reoptimize,
            2 => Event::SiteDown { site: rng.gen_range(0, sites - 1) },
            3 => Event::SiteUp { site: rng.gen_range(0, sites - 1) },
            4 => Event::Handover { device: rng.gen_range(0, devices - 1) },
            5 => Event::Leave { device: rng.gen_range(0, devices - 1) },
            _ => Event::FlashCrowdEnd { site: rng.gen_range(0, sites - 1) },
        }
    }

    /// The heart of the parity contract: for seeded *random* layouts
    /// (scattered, unbalanced, some shards siteless), a random op
    /// stream of bursty same-timestamp schedules, interleaved pops,
    /// and mid-stream re-attachments pops identically to the
    /// single-heap reference, event for event, clock tick for clock
    /// tick.
    #[test]
    fn random_layouts_never_reorder_against_the_reference() {
        const SITES: usize = 7;
        const DEVICES: usize = 12;
        for seed in 0..8u64 {
            let layout = ShardLayout::random(1 + (seed as usize % 7), SITES, seed * 31 + 5);
            let mut reference = EventQueue::new();
            let mut sq = ShardedQueue::new(layout, 0.02);
            let mut rng = Xoshiro256::seed_from_u64(seed);
            for d in 0..DEVICES {
                sq.attach_device(d, Some(d % SITES));
            }
            let mut scheduled = 0u32;
            loop {
                if scheduled < 400 {
                    for _ in 0..rng.gen_range(0, 3) {
                        // Coarse time grid → frequent FIFO ties.
                        let t = reference.now() + rng.gen_range(0, 4) as f64 * 0.01;
                        let ev = random_event(&mut rng, SITES, DEVICES);
                        reference.schedule(t, ev.clone());
                        sq.schedule(t, ev);
                        scheduled += 1;
                    }
                }
                if rng.gen_bool(0.1) {
                    // Routing churn mid-stream: must not affect order.
                    let d = rng.gen_range(0, DEVICES - 1);
                    let s = rng.gen_range(0, SITES - 1);
                    sq.attach_device(d, Some(s));
                }
                let a = reference.pop();
                let b = sq.pop();
                assert_eq!(a, b, "seed {seed}");
                assert_eq!(reference.now(), sq.now(), "seed {seed}");
                if a.is_none() && scheduled >= 400 {
                    break;
                }
            }
            assert_eq!(reference.processed(), sq.processed(), "seed {seed}");
        }
    }

    #[test]
    fn lookahead_bound_derivation() {
        use crate::device::profiles;
        use crate::edge::{BackhaulLink, EdgeSite};
        let topo = EdgeTopology::uniform(
            3,
            EdgeSite {
                servers: 1,
                profile: profiles::edge_server(),
                backhaul: BackhaulLink::METRO_1GBE,
            },
        );
        // handover cost + cheapest backhaul hop.
        assert_eq!(lookahead_bound(Some(&topo), 0.05), 0.05 + 2e-3);
        assert_eq!(lookahead_bound(Some(&topo), -1.0), 2e-3, "negative cost clamps");
        // Degenerate bounds fall back.
        let free = EdgeTopology::uniform(
            2,
            EdgeSite { servers: 1, profile: profiles::edge_server(), backhaul: BackhaulLink::FREE },
        );
        assert_eq!(lookahead_bound(Some(&free), 0.0), DEFAULT_LOOKAHEAD_S);
        assert_eq!(lookahead_bound(None, 123.0), DEFAULT_LOOKAHEAD_S);
        // A free backhaul with a real handover cost still bounds.
        assert_eq!(lookahead_bound(Some(&free), 0.2), 0.2);
    }

    #[test]
    fn layout_constructors_are_coherent() {
        let single = ShardLayout::single(5);
        assert_eq!(single.shards(), 1);
        assert!((0..5).all(|s| single.shard_of_site(s) == 0));

        let contig = ShardLayout::contiguous(3, 7);
        assert_eq!(contig.shards(), 3);
        assert_eq!(contig.num_sites(), 7);
        assert_eq!(contig.sites_in(0) + contig.sites_in(1) + contig.sites_in(2), 7);
        assert!(contig.sites_in(0) >= contig.sites_in(2));
        assert!(contig.sites_in(0) - contig.sites_in(2) <= 1);

        // Random layouts are seed-deterministic and in range.
        let a = ShardLayout::random(4, 9, 42);
        let b = ShardLayout::random(4, 9, 42);
        assert_eq!(a, b);
        assert!((0..9).all(|s| (a.shard_of_site(s) as usize) < 4));
        assert_ne!(a, ShardLayout::random(4, 9, 43), "different seed, different layout");

        assert_eq!(ShardLayout::contiguous(0, 3).shards(), 1, "0 clamps to 1");
    }
}
