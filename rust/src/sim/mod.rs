//! Discrete-event fleet simulator — city-scale SmartSplit without sockets.
//!
//! The live stack (`serve/`, `coordinator/fleet.rs`) pushes real bytes
//! through real TCP in real time, which caps experiments at a handful of
//! devices. This module runs thousands-to-millions of *virtual* devices
//! against virtual cloud servers on a single thread by replacing wall
//! time with an event queue and measured costs with the §III analytical
//! models ([`crate::perfmodel`]) — the same per-request cost functions the
//! optimiser already trusts:
//!
//! * [`engine`] — virtual clock + binary-heap event queue (deterministic
//!   under a fixed seed, FIFO tie-breaking);
//! * [`shard`] — that queue partitioned by edge site (`--shards N`,
//!   DESIGN.md §16): per-shard heaps drained in parallel behind
//!   conservative-lookahead window barriers, dispatched sequentially
//!   in the canonical global order, so every shard layout replays the
//!   1-shard run byte-for-byte (`tests/shard_parity.rs`);
//! * [`device`] — virtual smartphones: a [`crate::device::ComputeProfile`],
//!   a battery integrating the §III power draw (driving
//!   [`crate::coordinator::battery::BatteryBand`] re-splits as charge
//!   falls), and a time-varying link ([`crate::netsim::BandwidthTrace`]);
//! * [`cloud`] — M/G/c cloud queues whose service time comes from
//!   [`crate::perfmodel::PerfModel`], so cloud contention — invisible on
//!   the paper's two-phone testbed — becomes measurable;
//! * [`edge`] — per-site M/G/c torso queues mirroring the cloud, so
//!   tiered plans ([`crate::edge`]) contend at their metro site while
//!   tails contend in the cloud;
//! * [`mobility`] — per-device waypoint walks over the edge topology's
//!   site cells: crossing into another site's cell triggers an edge
//!   handover (torso state relayed over the old backhaul, re-attach,
//!   migration re-solve through the planner façade);
//! * [`scenario`] — presets: the paper's two-phone fleet (live-parity
//!   testing), a diurnal city of 10k+ devices with churn, the same
//!   city behind a metro edge tier ([`scenario::city_scale_tiered`]),
//!   and that tiered city with devices on the move
//!   ([`scenario::city_mobile`]).
//!
//! Reports reuse [`crate::metrics::Histogram`], so simulated and
//! socket-measured runs read the same.
//!
//! **Observability** ([`scenario::ObservabilityConfig`], DESIGN.md §12)
//! is opt-in: per-request span timelines through [`crate::trace`]
//! (exportable via `simulate --trace-out`) and a windowed
//! [`crate::metrics::TimeSeries`] (`--metrics-out`). Both stamp the
//! virtual clock only, never change decisions or event order, and cost
//! nothing when disabled — `tests/observability.rs` pins transparency
//! and byte-identical exports across thread configs. [`crate::analyze`]
//! consumes both sinks (in-process via [`SimReport`] or offline from
//! the exports) for critical-path attribution, SLO audits, and
//! run-vs-run diffs (DESIGN.md §14).

pub mod cloud;
pub mod device;
pub mod edge;
pub mod engine;
pub mod faults;
pub mod mobility;
pub mod scenario;
pub mod shard;

use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::battery::BatteryBand;
use crate::device::ComputeProfile;
use crate::edge::{EdgeTopology, SplitPlan};
use crate::metrics::{
    Histogram, PlannerStats, PoolGauge, ThroughputMeter, TimeSeries, TimeSeriesReport,
};
use crate::models::{zoo, ModelProfile};
use crate::optimizer::{Nsga2Params, PlanKey};
use crate::planner::{PlanRequest, PlannerConfig, ReplanReason, TierContext};
use crate::trace::{CausalEvent, SpanKind, TraceRecorder, TraceReport};
use crate::util::pool::ThreadPool;
use crate::util::rng::Xoshiro256;
use crate::workload::next_interarrival;

pub use cloud::SimCloud;
pub use device::{EdgeAttachment, Planner, SimDevice};
pub use edge::SimEdge;
pub use engine::{Event, EventQueue, SimTime};
pub use faults::{FaultEvent, FaultKind, FaultPlan};
pub use mobility::{Mobility, WaypointWalk};
pub use scenario::{
    city_faulty, city_mobile, city_scale, city_scale_tiered, two_phone_fleet, ChurnConfig,
    EdgeSpec, ExplicitMember, FleetSpec, ObservabilityConfig, PlannerPerfConfig, SimConfig,
};
pub use shard::{lookahead_bound, ShardLayout, ShardSlice, ShardedQueue};

/// Per-profile slice of the fleet report (devices sharing a
/// [`crate::device::ComputeProfile`]).
#[derive(Debug)]
pub struct ProfileSlice {
    pub name: &'static str,
    pub devices: usize,
    pub served: u64,
    pub latency: Histogram,
}

/// Per-cloud slice of the fleet report.
#[derive(Debug)]
pub struct CloudSlice {
    pub servers: usize,
    pub served: u64,
    pub utilization: f64,
    pub peak_queue: usize,
}

/// Everything a simulation run measured.
#[derive(Debug)]
pub struct SimReport {
    pub model: String,
    pub seed: u64,
    /// Configured horizon (no new work is issued after this virtual time).
    pub duration_s: f64,
    /// Virtual time at which the last event drained.
    pub sim_end_s: f64,
    pub wall: Duration,
    pub events: u64,
    /// Per-shard dispatch slices (one per configured engine shard).
    /// Deliberately absent from [`SimReport::summary`] and every
    /// export: shard accounting is layout-dependent by nature, while
    /// exports must be layout-independent (the parity contract,
    /// `tests/shard_parity.rs`).
    pub shards: Vec<ShardSlice>,
    /// Lookahead window barriers the sharded engine crossed.
    pub shard_windows: u64,
    /// Events scheduled across a shard boundary (cross-shard traffic);
    /// setup-time seeding before the first dispatch is excluded.
    pub cross_shard_events: u64,
    pub devices_created: usize,
    pub devices_active_end: usize,
    pub joined: u64,
    pub left: u64,
    pub batteries_exhausted: u64,
    pub generated: u64,
    pub completed: u64,
    pub dropped: u64,
    /// Fleet-wide end-to-end latency (merged from the per-profile shards).
    pub latency: Histogram,
    /// Cloud queueing delay (merged across clouds).
    pub queue_delay: Histogram,
    /// Time requests spent queued on their (serial) device before its
    /// head compute could start — the device-tier queue delay.
    pub device_queue_delay: Histogram,
    /// Edge-site torso queueing delay (merged across sites; empty when
    /// the scenario has no edge tier or no plan grew a torso).
    pub edge_queue_delay: Histogram,
    pub per_profile: Vec<ProfileSlice>,
    pub clouds: Vec<CloudSlice>,
    /// Per-edge-site slices (same shape as the cloud slices); empty
    /// without an edge tier.
    pub edges: Vec<CloudSlice>,
    /// Adopted plan *moves* — re-plans whose `(l1, l2)` actually
    /// changed — from any trigger: battery-band crossing, drift sweep,
    /// or migration. Slice re-plans by cause via
    /// [`SimReport::migration_replans`] and
    /// [`crate::metrics::PlannerStats::requests_by_reason`].
    pub resplits: u64,
    /// Completed edge handovers: a device crossed into another site's
    /// cell and re-attached there (0 under [`Mobility::Static`] or
    /// without an edge tier).
    pub handovers: u64,
    /// Migration re-solves adopted after a handover (the
    /// [`crate::planner::ReplanReason::Migration`] slice of
    /// [`SimReport::planner`], as decisions rather than requests).
    pub migration_replans: u64,
    /// Completed *forced* re-attachments: a fault (site outage or
    /// recovery re-balance, [`faults::FaultPlan`]) moved the device,
    /// as opposed to a voluntary mobility handover. Always 0 with an
    /// empty fault plan.
    pub failover_reattaches: u64,
    /// In-flight or queued requests a site outage relayed onward to the
    /// cloud instead of losing them with the site. Conservation
    /// (`generated == completed + dropped`) holds across outages
    /// because of exactly this path — pinned by
    /// `tests/fault_injection.rs`.
    pub requests_rerouted: u64,
    /// Re-solves adopted under [`crate::planner::ReplanReason::Failover`]
    /// (forced re-attachments and brownout re-plans that produced a
    /// decision).
    pub failover_replans: u64,
    /// Scripted fault events applied (outages, recoveries, brownout
    /// edges, flash-crowd edges). 0 with an empty plan.
    pub fault_events: u64,
    pub client_energy_j: f64,
    pub upload_energy_j: f64,
    /// Final split distribution: (plan, active devices running it).
    /// Two-tier plans have `l1 == l2`.
    pub split_distribution: Vec<(SplitPlan, u64)>,
    /// Re-optimisation sweeps actually performed (one per tick of the
    /// canonical absolute-time re-arm grid).
    pub reopt_sweeps: u64,
    /// Split-planner accounting: optimiser solves vs plan-cache traffic.
    pub planner: PlannerStats,
    /// Split decisions adopted over the run (spawns + re-plans).
    pub decision_count: u64,
    /// The full per-decision stream, in event order: `(device, l1, l2)`
    /// for spawns and re-plans alike (`l2 == l1` for two-tier plans).
    /// Only populated when [`PlannerPerfConfig::record_decisions`] is
    /// set (the cached and uncached planner paths must produce
    /// byte-identical streams — `tests/planner_cache.rs`); empty
    /// otherwise.
    pub decisions: Vec<(u32, u32, u32)>,
    /// Windowed time series ([`ObservabilityConfig::window_s`] > 0);
    /// `None` when the collector was disabled. Exported by
    /// `simulate --metrics-out`.
    pub series: Option<TimeSeriesReport>,
    /// Per-request span timelines + causal annotations
    /// ([`ObservabilityConfig::trace_sample_every`] > 0); `None` when
    /// tracing was disabled. Exported by `simulate --trace-out`.
    pub trace: Option<TraceReport>,
}

impl SimReport {
    /// Completed requests per second of *virtual* time.
    pub fn throughput_rps(&self) -> f64 {
        if self.duration_s <= 0.0 {
            return 0.0;
        }
        self.completed as f64 / self.duration_s
    }

    /// Events processed per second of *wall* time (the `sim_scale` metric).
    pub fn events_per_wall_second(&self) -> f64 {
        let w = self.wall.as_secs_f64();
        if w <= 0.0 {
            return f64::INFINITY;
        }
        self.events as f64 / w
    }

    /// The `--metrics-out` document: run identity + totals + the windowed
    /// time series, self-describing via `format` / `schema_version` so the
    /// offline `analyze` reader ([`crate::analyze::RunData`]) can validate
    /// what it was handed. `None` when the collector was disabled.
    ///
    /// Everything here is seed-reproducible (no wall-clock fields), so the
    /// serialized document is byte-identical across reruns and thread
    /// configs — the property `tests/observability.rs` pins.
    pub fn metrics_json(&self) -> Option<crate::util::json::Json> {
        use crate::util::json::Json;
        let ts = self.series.as_ref()?;
        Some(Json::obj(vec![
            ("format", Json::str("smartsplit-metrics")),
            ("schema_version", Json::Num(crate::metrics::METRICS_SCHEMA_VERSION as f64)),
            ("model", Json::str(&self.model)),
            ("seed", Json::Num(self.seed as f64)),
            ("duration_s", Json::Num(self.duration_s)),
            ("generated", Json::Num(self.generated as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("dropped", Json::Num(self.dropped as f64)),
            ("series", ts.to_json()),
        ]))
    }

    /// Deterministic one-line digest: everything seed-reproducible, nothing
    /// wall-clock. Two runs at the same seed must produce identical
    /// strings (`tests/sim_determinism.rs`).
    pub fn summary(&self) -> String {
        let util: Vec<String> =
            self.clouds.iter().map(|c| format!("{:.4}", c.utilization)).collect();
        let eutil: Vec<String> =
            self.edges.iter().map(|e| format!("{:.4}", e.utilization)).collect();
        format!(
            "model={} seed={} completed={} dropped={} joined={} left={} dead={} \
             resplits={} handovers={} migrations={} failovers={} rerouted={} freplans={} \
             faults={} latency[{}] deviceq[{}] edgeq[{}] cloudq[{}] \
             E_client={:.6}J E_up={:.6}J util=[{}] eutil=[{}]",
            self.model,
            self.seed,
            self.completed,
            self.dropped,
            self.joined,
            self.left,
            self.batteries_exhausted,
            self.resplits,
            self.handovers,
            self.migration_replans,
            self.failover_reattaches,
            self.requests_rerouted,
            self.failover_replans,
            self.fault_events,
            self.latency.summary(),
            self.device_queue_delay.summary(),
            self.edge_queue_delay.summary(),
            self.queue_delay.summary(),
            self.client_energy_j,
            self.upload_energy_j,
            util.join(","),
            eutil.join(","),
        )
    }

    pub fn print(&self) {
        println!("== sim report: {} ({} devices) ==", self.model, self.devices_created);
        println!(
            "  virtual    : {:.1}s horizon, drained at {:.1}s",
            self.duration_s, self.sim_end_s
        );
        println!(
            "  wall       : {:?} for {} events ({:.0} events/s)",
            self.wall,
            self.events,
            self.events_per_wall_second()
        );
        if self.shards.len() > 1 {
            let per: Vec<String> = self
                .shards
                .iter()
                .map(|s| format!("{}:{}ev/{}sites", s.shard, s.events, s.sites))
                .collect();
            println!(
                "  shards     : {} shards, {} windows, {} cross-shard events [{}]",
                self.shards.len(),
                self.shard_windows,
                self.cross_shard_events,
                per.join(" ")
            );
        }
        println!(
            "  fleet      : {} created, {} active at end, {} joined, {} left, {} dead batteries",
            self.devices_created,
            self.devices_active_end,
            self.joined,
            self.left,
            self.batteries_exhausted
        );
        println!(
            "  requests   : {} generated, {} completed, {} dropped ({:.3} req/s virtual)",
            self.generated,
            self.completed,
            self.dropped,
            self.throughput_rps()
        );
        println!("  latency    : {}", self.latency.summary());
        // Per-tier queue delay: where requests actually waited.
        for (tier, h) in [
            ("deviceq", &self.device_queue_delay),
            ("edgeq", &self.edge_queue_delay),
            ("cloudq", &self.queue_delay),
        ] {
            println!(
                "  {:<10} : n={} p50={} p95={} p99={}",
                tier,
                h.count(),
                crate::util::fmt_secs(h.p50()),
                crate::util::fmt_secs(h.p95()),
                crate::util::fmt_secs(h.p99()),
            );
        }
        for (i, c) in self.clouds.iter().enumerate() {
            println!(
                "  cloud {:<4} : {} servers, served={}, util={:.1}%, peak queue={}",
                i,
                c.servers,
                c.served,
                c.utilization * 100.0,
                c.peak_queue
            );
        }
        for (i, e) in self.edges.iter().enumerate() {
            println!(
                "  edge {:<5} : {} servers, served={}, util={:.1}%, peak queue={}",
                i,
                e.servers,
                e.served,
                e.utilization * 100.0,
                e.peak_queue
            );
        }
        for p in &self.per_profile {
            println!(
                "  {:<12} : {} devices, served={}, {}",
                p.name, p.devices, p.served,
                p.latency.summary()
            );
        }
        println!(
            "  energy     : client {:.2} J, upload {:.2} J ({} re-splits)",
            self.client_energy_j, self.upload_energy_j, self.resplits
        );
        println!(
            "  planner    : {} solves for {} decisions, cache {} hits / {} misses ({:.1}% hit rate), {} sweeps",
            self.planner.solves,
            self.decision_count,
            self.planner.cache_hits,
            self.planner.cache_misses,
            self.planner.hit_rate() * 100.0,
            self.reopt_sweeps,
        );
        println!(
            "  mobility   : {} handovers, {} migration re-plans ({} migration requests to the planner)",
            self.handovers,
            self.migration_replans,
            self.planner.migration_requests(),
        );
        if self.fault_events > 0 {
            println!(
                "  faults     : {} fault events, {} forced re-attachments, {} requests rerouted, {} failover re-plans ({} failover requests to the planner)",
                self.fault_events,
                self.failover_reattaches,
                self.requests_rerouted,
                self.failover_replans,
                self.planner.failover_requests(),
            );
        }
        if let Some(ts) = &self.series {
            ts.print_brief();
        }
        if let Some(tr) = &self.trace {
            println!(
                "  trace      : {} requests sampled (every {}), {} causal events, {} unfinished",
                tr.requests.len(),
                tr.sample_every,
                tr.events.len(),
                tr.unfinished
            );
        }
        let splits: Vec<String> = self
            .split_distribution
            .iter()
            .map(|(p, n)| {
                if p.is_two_tier() {
                    format!("l1={}:{n}", p.l1)
                } else {
                    format!("l1={}/l2={}:{n}", p.l1, p.l2)
                }
            })
            .collect();
        println!("  splits     : {}", splits.join(" "));
    }
}

/// Active-device index with O(1) insert/remove and deterministic uniform
/// sampling.
#[derive(Debug, Default)]
struct ActiveSet {
    members: Vec<usize>,
    /// `pos[d]` = index of device `d` in `members`, or `usize::MAX`.
    pos: Vec<usize>,
}

impl ActiveSet {
    fn insert(&mut self, d: usize) {
        if self.pos.len() <= d {
            self.pos.resize(d + 1, usize::MAX);
        }
        if self.pos[d] == usize::MAX {
            self.pos[d] = self.members.len();
            self.members.push(d);
        }
    }

    fn remove(&mut self, d: usize) {
        let Some(&p) = self.pos.get(d) else { return };
        if p == usize::MAX {
            return;
        }
        let last = *self.members.last().unwrap();
        self.members.swap_remove(p);
        self.pos[d] = usize::MAX;
        if p < self.members.len() {
            self.pos[last] = p;
        }
    }

    fn sample(&self, rng: &mut Xoshiro256) -> Option<usize> {
        if self.members.is_empty() {
            return None;
        }
        Some(self.members[rng.gen_range(0, self.members.len() - 1)])
    }

    fn len(&self) -> usize {
        self.members.len()
    }

    fn snapshot(&self) -> Vec<usize> {
        self.members.clone()
    }
}

#[derive(Debug, Default)]
struct Counters {
    generated: u64,
    completed: u64,
    dropped: u64,
    joined: u64,
    left: u64,
    exhausted: u64,
    handovers: u64,
    migrations: u64,
    /// Forced (fault-driven) re-attachments that landed.
    failover_reattaches: u64,
    /// Requests relayed to the cloud off a dead site (queued or in
    /// flight at outage time) instead of being lost.
    rerouted: u64,
    /// Adopted re-plans under [`ReplanReason::Failover`].
    failover_replans: u64,
    /// Scripted fault events applied.
    faults: u64,
}

/// The event-loop state. Lives for one [`run`] call.
struct Sim<'a> {
    cfg: &'a SimConfig,
    /// Shared with the parallel re-solve workers (the plan solves are
    /// pure functions of `(model, profile, bandwidth bucket, band)`).
    model: Arc<ModelProfile>,
    rng: Xoshiro256,
    /// The sharded event engine ([`ShardedQueue`], DESIGN.md §16) —
    /// API- and replay-identical to the single-heap [`EventQueue`];
    /// `cfg.shards == 1` is the frozen reference layout.
    q: ShardedQueue,
    devices: Vec<SimDevice>,
    active: ActiveSet,
    clouds: Vec<SimCloud>,
    /// Per-site torso queues; empty without an edge tier.
    edges: Vec<SimEdge>,
    /// Expanded edge tier, shared by the planner (tiered keys/solves)
    /// and the engine (site routing).
    topology: Option<EdgeTopology>,
    /// Waypoint-walk parameters, `Some` only when the scenario both
    /// moves devices and has an edge tier to move them between.
    walk: Option<WaypointWalk>,
    /// Per-device walk state, index-parallel with `devices` whenever
    /// `walk` is `Some` (empty otherwise). Each walker owns a private
    /// RNG stream, so mobility never touches the scenario RNG.
    walkers: Vec<mobility::Walker>,
    /// Per-device *decided* attachment: the current site, or the target
    /// of an in-flight re-attachment. Crossings are judged against this
    /// (not the lagging attachment), so a quick back-crossing during a
    /// slow relay still schedules the corrective handover. Fault storms
    /// share it: an outage retargets every device decided onto the dead
    /// site. `usize::MAX` marks a device detached by a total outage.
    /// Index-parallel with `devices` whenever the scenario has an edge
    /// tier (empty otherwise).
    target_site: Vec<usize>,
    /// Per-device handover sequence number; stamped into each scheduled
    /// [`Event::Reattach`] so a stale (superseded) re-attachment that
    /// lands out of order is dropped instead of overwriting a newer
    /// one. Mobility handovers and fault storms bump the same epoch, so
    /// either path supersedes the other's in-flight re-attachments.
    /// Index-parallel with `target_site`.
    handover_seq: Vec<u64>,
    /// `site_down[s]` while a scripted [`Event::SiteDown`] outage holds
    /// site `s`. All-false (and never consulted beyond a cheap scan)
    /// with an empty fault plan.
    site_down: Vec<bool>,
    /// Brownout state: `< 1.0` scales site `s`'s backhaul bandwidth
    /// until the matching restore. Exactly `1.0` (and bit-transparent:
    /// the degraded copy is never even constructed) otherwise.
    backhaul_factor: Vec<f64>,
    /// Active flash crowd, if any: `(pinned site, arrival boost)`.
    crowd: Option<(usize, f64)>,
    /// Concurrently-active injected faults (outages + brownouts +
    /// crowds), mirrored into the time series as a gauge.
    faults_active: u64,
    latency_by_profile: BTreeMap<&'static str, Histogram>,
    devices_by_profile: BTreeMap<&'static str, usize>,
    /// Device-tier queue delay (backlog wait before head compute).
    device_wait: Histogram,
    counters: Counters,
    horizon_reached: bool,
    /// The planning façade: quantisation → key → seed → cache, one
    /// [`crate::planner::PlanRequest`] per decision.
    facade: crate::planner::Planner,
    /// Lazily spawned worker pool for cache-miss fan-out.
    pool: Option<ThreadPool>,
    /// Index of the *next* scheduled re-optimisation tick: sweep k fires
    /// at exactly `k · reopt_period_s` on the absolute grid.
    reopt_tick: u64,
    sweeps: u64,
    decision_count: u64,
    /// Full decision trace; only fed when `planner_perf.record_decisions`.
    decisions: Vec<(u32, u32, u32)>,
    /// Virtual-time throughput meter: completions accumulate on the hot
    /// path, the elapsed override is pinned to the horizon at report
    /// time — `rps()` never reads the wall clock in a sim.
    meter: ThroughputMeter,
    /// Per-request span recorder; `Some` iff
    /// `observability.trace_sample_every > 0`.
    trace: Option<TraceRecorder>,
    /// Windowed telemetry collector; `Some` iff
    /// `observability.window_s > 0`.
    series: Option<TimeSeries>,
}

/// Boundary snapshot of every pool for the time-series collector.
fn pool_gauges(edges: &[SimEdge], clouds: &[SimCloud]) -> (Vec<PoolGauge>, Vec<PoolGauge>) {
    let snap_e = edges
        .iter()
        .map(|e| PoolGauge { queue_len: e.queue_len(), busy_time_s: e.busy_time_s(), servers: e.servers })
        .collect();
    let snap_c = clouds
        .iter()
        .map(|c| PoolGauge { queue_len: c.queue_len(), busy_time_s: c.busy_time_s(), servers: c.servers })
        .collect();
    (snap_e, snap_c)
}

impl<'a> Sim<'a> {
    fn new(cfg: &'a SimConfig) -> Result<Sim<'a>> {
        let spec = zoo::by_name(&cfg.model)
            .with_context(|| format!("unknown model {}", cfg.model))?;
        match cfg.arrival {
            crate::workload::Arrival::ClosedLoop => {
                bail!("sim needs an open-loop arrival process (ClosedLoop would generate unboundedly at t=0)")
            }
            crate::workload::Arrival::Poisson { rps } | crate::workload::Arrival::Uniform { rps } => {
                if !(rps > 0.0) || !rps.is_finite() {
                    bail!("sim arrival rate must be positive and finite, got {rps} rps");
                }
            }
            crate::workload::Arrival::Diurnal { base_rps, peak_rps, .. } => {
                let envelope = base_rps.max(peak_rps);
                if !(envelope > 0.0) || !envelope.is_finite() {
                    bail!("sim diurnal arrival needs a positive finite peak rate, got base {base_rps} / peak {peak_rps} rps");
                }
            }
        }
        if !(cfg.duration_s > 0.0) || !cfg.duration_s.is_finite() {
            bail!("sim duration must be positive and finite, got {}", cfg.duration_s);
        }
        if cfg.fleet.initial_count() == 0 {
            bail!("sim needs at least one initial device");
        }
        if cfg.shards == 0 {
            bail!("sim needs at least one event-engine shard (--shards 1 is the reference layout)");
        }
        let obs = cfg.observability;
        if !(obs.window_s >= 0.0) || !obs.window_s.is_finite() {
            bail!(
                "time-series window must be a finite non-negative number of seconds, got {}",
                obs.window_s
            );
        }
        let model = Arc::new(spec.analyze(1));
        let topology = cfg.edge.as_ref().map(|spec| spec.topology());
        let edges = topology
            .as_ref()
            .map(|t| t.sites.iter().map(|s| SimEdge::new(s.servers)).collect())
            .unwrap_or_default();
        let walk = match (&cfg.mobility, &topology) {
            (Mobility::Waypoint(w), Some(_)) => {
                if !(cfg.handover_cost_s >= 0.0) || !cfg.handover_cost_s.is_finite() {
                    bail!(
                        "handover cost must be a finite non-negative number of seconds, got {}",
                        cfg.handover_cost_s
                    );
                }
                Some(*w)
            }
            (Mobility::Waypoint(_), None) => bail!(
                "mobility needs an edge tier to move devices between \
                 (add --edge-sites, or use --scenario city-mobile)"
            ),
            (Mobility::Static, _) => None,
        };
        // The façade owns quantisation → key → derived seed → cache.
        // Base seed and NSGA-II budget follow the configured planner:
        // only [`Planner::SmartSplit`] consumes the budget (the other
        // strategies are parameter-free), and its params are
        // authoritative — tiered SmartSplit scenarios should carry
        // [`Nsga2Params::for_small_genome`]`(2)`.
        let (params, base_seed) = match &cfg.planner {
            Planner::SmartSplit(p) => (p.clone(), p.seed),
            _ => (Nsga2Params::for_tiny_genome(), cfg.seed),
        };
        let facade = crate::planner::Planner::new(
            PlannerConfig::fleet(params, base_seed)
                .with_bucket_ratio(cfg.planner_perf.bw_bucket_ratio)
                .with_cache(cfg.planner_perf.cache),
        );
        let edge_sites: usize = topology.as_ref().map(|t| t.num_sites()).unwrap_or(0);
        if !cfg.faults.is_empty() {
            if topology.is_none() {
                bail!(
                    "a fault plan needs an edge tier to injure \
                     (add --edge-sites, or use --scenario city-faulty)"
                );
            }
            if !(cfg.handover_cost_s >= 0.0) || !cfg.handover_cost_s.is_finite() {
                bail!(
                    "handover cost must be a finite non-negative number of seconds, got {}",
                    cfg.handover_cost_s
                );
            }
            if let Err(e) = cfg.faults.validate(edge_sites) {
                bail!("invalid fault plan: {e}");
            }
        }
        let trace = if obs.trace_sample_every > 0 {
            Some(TraceRecorder::new(obs.trace_sample_every))
        } else {
            None
        };
        let series = if obs.window_s > 0.0 {
            Some(TimeSeries::new(obs.window_s, edge_sites, cfg.clouds.max(1)))
        } else {
            None
        };
        // Shard layout: the topology's contiguous site partition, or a
        // degenerate siteless layout without an edge tier (every event
        // then routes to shard 0). The lookahead window is the minimum
        // cross-shard delay — correctness never depends on it (the
        // merge enforces global order), only drain batch size does.
        let layout = match &topology {
            Some(t) => ShardLayout::for_topology(cfg.shards, t),
            None => ShardLayout::contiguous(cfg.shards, 0),
        };
        let lookahead = lookahead_bound(topology.as_ref(), cfg.handover_cost_s);
        Ok(Sim {
            cfg,
            model,
            rng: Xoshiro256::seed_from_u64(cfg.seed),
            q: ShardedQueue::new(layout, lookahead),
            devices: Vec::new(),
            active: ActiveSet::default(),
            clouds: (0..cfg.clouds.max(1))
                .map(|_| SimCloud::new(cfg.cloud_servers.max(1)))
                .collect(),
            edges,
            topology,
            walk,
            walkers: Vec::new(),
            target_site: Vec::new(),
            handover_seq: Vec::new(),
            site_down: vec![false; edge_sites],
            backhaul_factor: vec![1.0; edge_sites],
            crowd: None,
            faults_active: 0,
            latency_by_profile: BTreeMap::new(),
            devices_by_profile: BTreeMap::new(),
            device_wait: Histogram::new(),
            counters: Counters::default(),
            horizon_reached: false,
            facade,
            pool: None,
            reopt_tick: 0,
            sweeps: 0,
            decision_count: 0,
            decisions: Vec::new(),
            meter: ThroughputMeter::virtual_time(0.0),
            trace,
            series,
        })
    }

    /// Site `site` as the fleet currently experiences it: the configured
    /// [`crate::edge::EdgeSite`] verbatim, except under a brownout
    /// ([`Event::BackhaulDegrade`]) when its backhaul bandwidth is
    /// scaled by the scripted factor. The un-degraded copy is returned
    /// bit-for-bit untouched (no arithmetic on it at all), which is
    /// what makes the zero-fault byte-parity guarantee trivial.
    fn effective_site(&self, site: usize) -> crate::edge::EdgeSite {
        let t = self.topology.as_ref().expect("site lookup without an edge tier");
        let mut s = t.sites[site];
        let f = self.backhaul_factor[site];
        if f < 1.0 {
            s.backhaul.bandwidth_mbps *= f;
        }
        s
    }

    /// The attachment for site `site` of the edge tier, reflecting any
    /// active brownout on its backhaul.
    fn attachment_at(&self, site: usize) -> EdgeAttachment {
        let s = self.effective_site(site);
        EdgeAttachment { site, profile: s.profile, backhaul: s.backhaul }
    }

    /// The spawn placement rule: the topology's natural site, routed
    /// around any sites currently down. `None` only when every site is
    /// down (the device spawns unattached and plans two-tier).
    fn spawn_site(&self, member: usize, t: &EdgeTopology) -> Option<usize> {
        if self.site_down.iter().any(|&d| d) {
            t.attach_avoiding(member, None, &self.site_down)
        } else {
            Some(t.site_of(member))
        }
    }

    /// This device's spawn-time edge attachment (assigned site, routed
    /// around outages), if the scenario has an edge tier. Later
    /// handovers and fault storms replace it via `on_reattach`.
    fn attachment(&self, device: usize) -> Option<EdgeAttachment> {
        let t = self.topology.as_ref()?;
        let site = self.spawn_site(device, t)?;
        Some(self.attachment_at(site))
    }

    /// The site device `member` is *currently* attached to: its live
    /// attachment once it exists (mobility and faults move it; `None`
    /// while detached by a total outage), the spawn placement rule
    /// before the device is constructed (the spawn path plans first).
    fn current_site(&self, member: usize, t: &EdgeTopology) -> Option<usize> {
        match self.devices.get(member) {
            Some(d) => d.edge.as_ref().map(|e| e.site),
            None => self.spawn_site(member, t),
        }
    }

    /// Account one adopted split decision (and retain it in the trace
    /// when the scenario asked for the full stream).
    fn note_decision(&mut self, d: usize, plan: SplitPlan) {
        self.decision_count += 1;
        if self.cfg.planner_perf.record_decisions {
            self.decisions.push((d as u32, plan.l1 as u32, plan.l2 as u32));
        }
    }

    // ---------------------------------------------------- planner layer

    /// The façade request for device `member`'s current conditions —
    /// exact bandwidth in (the façade buckets it), the *currently*
    /// attached edge site when the scenario has a tier (handover moves
    /// it), and the reason tag for provenance/accounting.
    fn plan_request(
        &self,
        member: usize,
        profile: &'static ComputeProfile,
        bw_exact: f64,
        band: BatteryBand,
        reason: ReplanReason,
    ) -> PlanRequest {
        let strategy = self
            .cfg
            .planner
            .strategy()
            .expect("pinned (Fixed) devices never reach the planner");
        let mut req = PlanRequest::two_tier(
            Arc::clone(&self.model),
            profile,
            band,
            bw_exact,
            strategy,
        )
        .with_reason(reason);
        if let Some(t) = self.topology.as_ref() {
            // A brownout flows into the tier context here: the degraded
            // backhaul quantises into a different `TierKey` bucket, so
            // the façade treats it as a distinct planner state and
            // solves it fresh instead of serving the healthy plan.
            if let Some(site) = self.current_site(member, t) {
                req.tier = Some(TierContext { site, edge: self.effective_site(site) });
            }
        }
        req
    }

    /// One cache-aware split decision. Identical inputs give identical
    /// decisions whether served from cache, solved inline, or solved on
    /// a pool worker — the seed comes from the key. A cache miss is
    /// served from `presolved` when a batch fan-out already solved this
    /// key (falling back to an inline solve). Counting runs through the
    /// façade's counted cache path either way, so the parallel path's
    /// `PlannerStats` are identical to a sequential pass. Uses the
    /// façade's decision-only fast path: a cache hit stays one map
    /// lookup.
    fn plan_split_with(
        &self,
        member: usize,
        profile: &'static ComputeProfile,
        bw_exact: f64,
        band: BatteryBand,
        reason: ReplanReason,
        presolved: &mut HashMap<PlanKey, Option<SplitPlan>>,
    ) -> Option<SplitPlan> {
        let req = self.plan_request(member, profile, bw_exact, band, reason);
        self.facade.split_with(&req, presolved)
    }

    /// As [`Sim::plan_split_with`], additionally noting a
    /// [`CausalEvent::Replan`] annotation (with the façade's full
    /// [`crate::planner::PlanOutcome`] provenance) when tracing is on.
    /// The full-outcome path counts identically to the decision-only
    /// fast path — pinned by
    /// `planner::tests::split_fast_path_matches_plan_and_counts_identically`
    /// — so enabling tracing cannot perturb `PlannerStats` or any
    /// decision.
    #[allow(clippy::too_many_arguments)]
    fn plan_split_traced(
        &mut self,
        member: usize,
        profile: &'static ComputeProfile,
        bw_exact: f64,
        band: BatteryBand,
        reason: ReplanReason,
        now: SimTime,
        presolved: &mut HashMap<PlanKey, Option<SplitPlan>>,
    ) -> Option<SplitPlan> {
        if self.trace.is_none() {
            return self.plan_split_with(member, profile, bw_exact, band, reason, presolved);
        }
        let req = self.plan_request(member, profile, bw_exact, band, reason);
        let outcome = self.facade.plan_with(&req, presolved);
        let p = &outcome.provenance;
        let ev = CausalEvent::Replan {
            t_s: now,
            device: member as u64,
            reason: p.reason,
            strategy: p.strategy,
            cache: p.cache,
            plan: outcome.plan.map(|pl| (pl.l1 as u32, pl.l2 as u32)),
            quantized_bw_mbps: p.quantized_bw_mbps,
            derived_seed: p.derived_seed,
        };
        self.trace.as_mut().expect("tracing checked on").note(ev);
        outcome.plan
    }

    /// Cache-aware unconditional re-plan of device `d` at `now` (the
    /// event-driven battery-band trigger).
    fn replan_device(&mut self, d: usize, now: SimTime) {
        if self.devices[d].pinned() {
            return;
        }
        let profile = self.devices[d].profile;
        let bw = self.devices[d].bandwidth_at(now);
        let band = BatteryBand::of_fraction(self.devices[d].soc());
        let Some(plan) = self.plan_split_traced(
            d,
            profile,
            bw,
            band,
            ReplanReason::BandCrossing,
            now,
            &mut HashMap::new(),
        ) else {
            return;
        };
        let moved = self.devices[d].apply_split(plan, &self.model, bw);
        if moved {
            if let Some(s) = self.series.as_mut() {
                s.on_resplit();
            }
        }
        self.note_decision(d, plan);
    }

    /// Solve the distinct not-yet-cached planner states behind a sweep's
    /// pending re-plans, fanned out over the worker pool, and return the
    /// presolved plans for the apply phase. Each job is a pure function
    /// of its key (key-derived seed), so scheduling order and thread
    /// interleaving cannot change any decision — and since neither cache
    /// contents nor counters are touched here, the apply phase's
    /// accounting is byte-identical to a sequential pass.
    fn solve_pending_parallel(
        &mut self,
        pending: &[(usize, f64, BatteryBand)],
    ) -> HashMap<PlanKey, Option<SplitPlan>> {
        if !self.cfg.planner_perf.cache || !self.cfg.planner_perf.parallel || pending.len() < 2 {
            return HashMap::new();
        }
        let requests: Vec<PlanRequest> = pending
            .iter()
            .map(|&(d, bw, band)| {
                self.plan_request(d, self.devices[d].profile, bw, band, ReplanReason::Drift)
            })
            .collect();
        let pool = self
            .pool
            .get_or_insert_with(|| ThreadPool::new(ThreadPool::default_threads(16)));
        self.facade.presolve_batch(pool, &requests)
    }

    /// Create one device (fleet member `member`), register it as active,
    /// and — under churn — schedule its departure. The initial split goes
    /// through the plan cache like every later re-plan, so a homogeneous
    /// 10k-device spawn costs a handful of solves, not 10k.
    fn spawn_device(&mut self, at: SimTime, member: usize) {
        let (profile, trace, soc) = self.cfg.fleet.instantiate(member, &mut self.rng);
        let id = self.devices.len();
        let cloud = id % self.clouds.len();
        let bw = trace.at(Duration::from_secs_f64(at.max(0.0)));
        let fixed = match &self.cfg.planner {
            Planner::Fixed(l1) => Some(*l1),
            _ => None,
        };
        let (plan, pinned) = match fixed {
            Some(l1) => {
                let l1 = l1.clamp(1, self.model.num_layers.saturating_sub(1).max(1));
                (SplitPlan::two_tier(l1), true)
            }
            None => {
                let band = BatteryBand::of_fraction(soc.clamp(0.0, 1.0));
                let plan = self
                    .plan_split_traced(
                        id,
                        profile,
                        bw,
                        band,
                        ReplanReason::Spawn,
                        at,
                        &mut HashMap::new(),
                    )
                    .expect("no feasible split for device");
                (plan, false)
            }
        };
        let edge = self.attachment(id);
        let d = SimDevice::with_split(
            profile,
            trace,
            cloud,
            edge,
            soc,
            at,
            &self.model,
            plan,
            pinned,
        );
        self.note_decision(id, plan);
        *self.devices_by_profile.entry(profile.name).or_insert(0) += 1;
        self.devices.push(d);
        self.active.insert(id);
        if self.topology.is_some() {
            // Decided attachment + re-attach epoch exist for every
            // device under an edge tier: mobility handovers and fault
            // storms share the same epoch-guarded Reattach path.
            // `usize::MAX` marks a device spawned during a total outage.
            self.target_site.push(edge.map(|e| e.site).unwrap_or(usize::MAX));
            self.handover_seq.push(0);
        }
        // Shard routing metadata: the device's events live on its
        // serving site's shard (shard 0 while unattached).
        self.q.attach_device(id, edge.map(|e| e.site));
        if let Some(walk) = self.walk {
            // The walker starts in its spawn site's *natural* cell (its
            // physical position — under an outage the serving site may
            // be farther away) on a private RNG stream; its first tick
            // (after the initial dwell) aims at a waypoint. Churn joins
            // get walkers exactly like the initial fleet.
            let topo = self.topology.as_ref().expect("mobility without an edge tier");
            let cell = topo.site_of(id);
            let mut walker = mobility::Walker::new(self.cfg.seed, id, cell);
            let (dwell, crossed) = walker.step(topo, &walk);
            debug_assert!(crossed.is_none(), "a fresh walker cannot cross");
            self.walkers.push(walker);
            self.q.schedule(at + dwell, Event::Handover { device: id });
        }
        if let Some(churn) = &self.cfg.churn {
            let lifetime = self.rng.next_exp(1.0 / churn.mean_lifetime_s.max(1e-9));
            self.q.schedule(at + lifetime, Event::Leave { device: id });
        }
    }

    /// Deactivate a device, dropping whatever it had queued locally.
    fn deactivate(&mut self, d: usize) {
        self.devices[d].active = false;
        let backlogged = self.devices[d].backlog.len() as u64;
        self.counters.dropped += backlogged;
        if backlogged > 0 {
            if let Some(s) = self.series.as_mut() {
                s.on_dropped(backlogged);
            }
        }
        self.devices[d].backlog.clear();
        self.active.remove(d);
    }

    /// Start request `req` (issued at `issued`) on an idle device `d` at
    /// `now`; schedules its uplink-complete event carrying the captured
    /// per-hop costs.
    fn start_on(&mut self, d: usize, req: u64, issued: SimTime, now: SimTime) {
        self.devices[d].apply_idle_drain(now, self.cfg.idle_drain_w);
        match self.devices[d].start_request(now) {
            Some(cost) => {
                // Device-tier queue delay: the serial phone made this
                // request wait `now - issued` (0 when started at once).
                self.device_wait.record_secs(now - issued);
                if let Some(s) = self.series.as_mut() {
                    s.on_device_wait(now - issued);
                }
                if let Some(tr) = self.trace.as_mut() {
                    // Span boundaries mirror the engine's scheduling
                    // arithmetic bit-for-bit (same parenthesisation), so
                    // the timeline tiles the event timestamps exactly —
                    // the invariant tests/observability.rs pins.
                    tr.begin(req, d as u64, issued);
                    tr.span(req, SpanKind::DeviceQueue, issued, now, None);
                    tr.span(req, SpanKind::HeadCompute, now, now + cost.head_s, None);
                    tr.span(
                        req,
                        SpanKind::Uplink,
                        now + cost.head_s,
                        now + (cost.head_s + cost.upload_s),
                        None,
                    );
                }
                self.q.schedule_in(
                    cost.head_s + cost.upload_s,
                    Event::Uplinked {
                        req,
                        device: d,
                        issued,
                        site: cost.edge_site,
                        torso_s: cost.torso_s,
                        backhaul_s: cost.backhaul_s,
                        tail_s: cost.tail_s,
                    },
                );
            }
            None => {
                self.counters.dropped += 1;
                self.counters.exhausted += 1;
                if let Some(s) = self.series.as_mut() {
                    s.on_dropped(1);
                }
                self.deactivate(d);
            }
        }
    }

    /// Request fully served: completion accounting shared by the cloud
    /// tail and the edge-terminal path.
    fn complete_request(&mut self, req: u64, device: usize, issued: SimTime, now: SimTime) {
        self.counters.completed += 1;
        self.meter.record(1);
        self.devices[device].served += 1;
        self.latency_by_profile
            .entry(self.devices[device].profile.name)
            .or_insert_with(Histogram::new)
            .record_secs(now - issued);
        if let Some(s) = self.series.as_mut() {
            s.on_completed(now - issued);
        }
        if let Some(tr) = self.trace.as_mut() {
            tr.complete(req, now);
        }
    }

    /// Hand a request to its device's cloud queue (tail layers). An
    /// edge-terminal plan (`l2 == L`, `tail_s == 0`) completes here
    /// directly: the tiered model charges it zero cloud cost, so it
    /// must not occupy a cloud server or queue behind real tail work.
    /// (Two-tier plans always have a non-empty tail — `l1 ≤ L-1` is
    /// enforced — so this path cannot fire for them.)
    fn offer_cloud(&mut self, req: u64, device: usize, issued: SimTime, tail_s: f64, now: SimTime) {
        if tail_s <= 0.0 {
            self.complete_request(req, device, issued, now);
            return;
        }
        let c = self.devices[device].cloud;
        match self.clouds[c].offer(req, device, issued, now, tail_s) {
            Some(svc) => {
                if let Some(s) = self.series.as_mut() {
                    s.on_cloud_wait(0.0);
                }
                if let Some(tr) = self.trace.as_mut() {
                    tr.span(req, SpanKind::CloudQueue, now, now, Some(c as u32));
                    tr.span(req, SpanKind::CloudService, now, now + svc, Some(c as u32));
                }
                self.q.schedule_in(svc, Event::CloudDone { req, cloud: c, device, issued });
            }
            None => {
                // Queued: the span stays open until a server frees up
                // (closed in on_cloud_done when this request dequeues).
                if let Some(tr) = self.trace.as_mut() {
                    tr.begin_span(req, SpanKind::CloudQueue, now, Some(c as u32));
                }
            }
        }
    }

    /// Biased device pick while a flash crowd pins `site`: bounded
    /// rejection sampling — up to 8 uniform draws from the scenario RNG,
    /// returning the first device decided onto the crowded site (else
    /// the last draw, so a crowd at an empty site degrades gracefully).
    /// All randomness still flows through `active.sample`, so the
    /// decision stream stays a pure function of the seed.
    fn sample_crowded(&mut self, site: usize) -> Option<usize> {
        let mut last = None;
        for _ in 0..8 {
            let d = self.active.sample(&mut self.rng)?;
            last = Some(d);
            if self.target_site.get(d).copied() == Some(site) {
                break;
            }
        }
        last
    }

    fn on_arrival(&mut self, now: SimTime) {
        if self.horizon_reached {
            return;
        }
        let mut gap = next_interarrival(self.cfg.arrival, now, &mut self.rng);
        if let Some((_, boost)) = self.crowd {
            // Flash crowd: the fleet offers `boost`× the configured load
            // for the scripted window.
            gap /= boost;
        }
        self.q.schedule(now + gap, Event::Arrival);
        // The pre-increment value is this request's fleet-wide ordinal —
        // the key every trace span and causal annotation hangs off.
        let req = self.counters.generated;
        self.counters.generated += 1;
        if let Some(s) = self.series.as_mut() {
            s.on_generated();
        }
        let pick = match self.crowd {
            None => self.active.sample(&mut self.rng),
            Some((site, _)) => self.sample_crowded(site),
        };
        match pick {
            None => {
                self.counters.dropped += 1;
                if let Some(s) = self.series.as_mut() {
                    s.on_dropped(1);
                }
            }
            Some(d) => {
                if self.devices[d].busy {
                    self.devices[d].backlog.push_back((req, now));
                } else {
                    self.start_on(d, req, now, now);
                }
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn on_uplinked(
        &mut self,
        req: u64,
        device: usize,
        issued: SimTime,
        site: Option<usize>,
        torso_s: f64,
        backhaul_s: f64,
        tail_s: f64,
        now: SimTime,
    ) {
        self.devices[device].busy = false;
        // Route by the costs — and the site — captured at issue: torso
        // work contends at the edge site the request was *issued*
        // under (a handover mid-flight must not reroute in-flight work;
        // the handover cost charges the state relay instead), then
        // crosses the backhaul; empty hops are skipped entirely, so a
        // two-tier plan (torso == backhaul == 0) takes exactly the
        // classic device→cloud path — the zero-edge degeneracy
        // `tests/edge_parity.rs` pins.
        if torso_s > 0.0 {
            let site = site.expect("torso work without an edge attachment");
            if self.site_down[site] {
                // The site died while this request was uplinking: relay
                // the whole remainder onward — torso *and* tail run at
                // the cloud — instead of queueing work on a corpse. The
                // request completes exactly once (conservation).
                self.reroute_to_cloud(req, device, issued, torso_s + tail_s, backhaul_s, site, now);
                self.after_uplink(device, now);
                return;
            }
            match self.edges[site].offer(req, device, issued, now, torso_s, backhaul_s, tail_s) {
                Some(svc) => {
                    if let Some(s) = self.series.as_mut() {
                        s.on_edge_wait(0.0);
                    }
                    if let Some(tr) = self.trace.as_mut() {
                        tr.span(req, SpanKind::EdgeQueue, now, now, Some(site as u32));
                        tr.span(req, SpanKind::EdgeService, now, now + svc, Some(site as u32));
                    }
                    self.q.schedule_in(
                        svc,
                        Event::EdgeDone { req, site, device, issued, backhaul_s, tail_s },
                    );
                }
                None => {
                    if let Some(tr) = self.trace.as_mut() {
                        tr.begin_span(req, SpanKind::EdgeQueue, now, Some(site as u32));
                    }
                }
            }
        } else if backhaul_s > 0.0 {
            if let Some(tr) = self.trace.as_mut() {
                tr.span(
                    req,
                    SpanKind::Backhaul,
                    now,
                    now + backhaul_s,
                    site.map(|s| s as u32),
                );
            }
            self.q.schedule_in(backhaul_s, Event::CloudArrive { req, device, issued, tail_s });
        } else {
            self.offer_cloud(req, device, issued, tail_s, now);
        }
        self.after_uplink(device, now);
    }

    /// Post-uplink device bookkeeping shared by the normal and the
    /// dead-site-reroute paths: the event-driven battery-band trigger,
    /// then the serial device picking up its next backlogged request.
    fn after_uplink(&mut self, device: usize, now: SimTime) {
        // The drain from this request may have crossed a battery band
        // boundary — the event-driven re-split trigger.
        if self.devices[device].active {
            if self.devices[device].exhausted() {
                self.counters.exhausted += 1;
                self.deactivate(device);
            } else {
                let band = BatteryBand::of_fraction(self.devices[device].soc());
                if band != self.devices[device].band {
                    self.replan_device(device, now);
                }
            }
        }
        // Serial device: pick up the next locally queued request.
        if self.devices[device].active {
            if let Some((req2, issued2)) = self.devices[device].backlog.pop_front() {
                self.start_on(device, req2, issued2, now);
            }
        }
    }

    /// Relay a request off a dead site to its device's cloud: the
    /// remaining compute (`cloud_tail_s`, the captured torso + tail) is
    /// served there after the captured backhaul crossing. Counted as a
    /// failover in both the run totals and the active window; never
    /// dropped — `tests/fault_injection.rs` pins conservation on
    /// exactly this path.
    #[allow(clippy::too_many_arguments)]
    fn reroute_to_cloud(
        &mut self,
        req: u64,
        device: usize,
        issued: SimTime,
        cloud_tail_s: f64,
        backhaul_s: f64,
        from_site: usize,
        now: SimTime,
    ) {
        self.counters.rerouted += 1;
        if let Some(s) = self.series.as_mut() {
            s.on_failover();
        }
        if let Some(tr) = self.trace.as_mut() {
            tr.note(CausalEvent::Failover {
                t_s: now,
                req,
                device: device as u64,
                from_site: from_site as u32,
            });
        }
        if backhaul_s > 0.0 {
            if let Some(tr) = self.trace.as_mut() {
                tr.span(req, SpanKind::Backhaul, now, now + backhaul_s, Some(from_site as u32));
            }
            self.q.schedule_in(
                backhaul_s,
                Event::CloudArrive { req, device, issued, tail_s: cloud_tail_s },
            );
        } else {
            self.offer_cloud(req, device, issued, cloud_tail_s, now);
        }
    }

    /// An edge server finished this request's torso: send it over the
    /// backhaul (or straight to the cloud when the backhaul is free) and
    /// start the next queued torso, if any.
    #[allow(clippy::too_many_arguments)]
    fn on_edge_done(
        &mut self,
        req: u64,
        site: usize,
        device: usize,
        issued: SimTime,
        backhaul_s: f64,
        tail_s: f64,
        now: SimTime,
    ) {
        if backhaul_s > 0.0 {
            if let Some(tr) = self.trace.as_mut() {
                tr.span(req, SpanKind::Backhaul, now, now + backhaul_s, Some(site as u32));
            }
            self.q.schedule_in(backhaul_s, Event::CloudArrive { req, device, issued, tail_s });
        } else {
            self.offer_cloud(req, device, issued, tail_s, now);
        }
        if let Some(next) = self.edges[site].finish(now) {
            if let Some(s) = self.series.as_mut() {
                s.on_edge_wait(next.waited_s);
            }
            if let Some(tr) = self.trace.as_mut() {
                // Close the open edge_queue span and start service.
                tr.end_span(next.req, now);
                tr.span(
                    next.req,
                    SpanKind::EdgeService,
                    now,
                    now + next.service_s,
                    Some(site as u32),
                );
            }
            self.q.schedule_in(
                next.service_s,
                Event::EdgeDone {
                    req: next.req,
                    site,
                    device: next.device,
                    issued: next.issued,
                    backhaul_s: next.backhaul_s,
                    tail_s: next.tail_s,
                },
            );
        }
    }

    fn on_cloud_done(&mut self, req: u64, cloud: usize, device: usize, issued: SimTime, now: SimTime) {
        self.complete_request(req, device, issued, now);
        if let Some(next) = self.clouds[cloud].finish(now) {
            if let Some(s) = self.series.as_mut() {
                s.on_cloud_wait(next.waited_s);
            }
            if let Some(tr) = self.trace.as_mut() {
                // Close the open cloud_queue span and start service.
                tr.end_span(next.req, now);
                tr.span(
                    next.req,
                    SpanKind::CloudService,
                    now,
                    now + next.service_s,
                    Some(cloud as u32),
                );
            }
            self.q.schedule_in(
                next.service_s,
                Event::CloudDone { req: next.req, cloud, device: next.device, issued: next.issued },
            );
        }
    }

    fn on_reoptimize(&mut self, now: SimTime) {
        if self.horizon_reached {
            return;
        }
        self.sweeps += 1;
        // Pass 1: integrate idle drain, retire dead batteries, and collect
        // the devices whose planned state (battery band / link bandwidth)
        // drifted past the threshold.
        let mut pending: Vec<(usize, f64, BatteryBand)> = Vec::new();
        for d in self.active.snapshot() {
            self.devices[d].apply_idle_drain(now, self.cfg.idle_drain_w);
            if self.devices[d].exhausted() {
                self.counters.exhausted += 1;
                self.deactivate(d);
            } else if let Some((bw, band)) =
                self.devices[d].drift_state(now, self.cfg.drift_threshold)
            {
                pending.push((d, bw, band));
            }
        }
        // Pass 2: fan the distinct cache-miss solves out over the pool.
        let mut presolved = self.solve_pending_parallel(&pending);
        // Pass 3: adopt decisions in deterministic device order, serving
        // pass-2 results through the normal (counted) cache path.
        for (d, bw, band) in pending {
            let profile = self.devices[d].profile;
            let Some(plan) = self.plan_split_traced(
                d,
                profile,
                bw,
                band,
                ReplanReason::Drift,
                now,
                &mut presolved,
            ) else {
                continue;
            };
            let moved = self.devices[d].apply_split(plan, &self.model, bw);
            if moved {
                if let Some(s) = self.series.as_mut() {
                    s.on_resplit();
                }
            }
            self.note_decision(d, plan);
        }
        // Canonical re-arm: sweep k fires at exactly k·period on the
        // absolute grid. A relative `schedule_in(period)` re-arm would
        // accumulate floating-point error and drift off the grid —
        // regression-pinned by tests/planner_cache.rs.
        self.reopt_tick += 1;
        self.q
            .schedule(self.cfg.reopt_period_s * self.reopt_tick as f64, Event::Reoptimize);
    }

    /// Mobility tick: advance the device's waypoint walk one step. A
    /// step that crosses into a cell whose site differs from the
    /// device's *decided* attachment (current site, or the target of an
    /// in-flight re-attachment — so a quick back-crossing during a slow
    /// relay is not lost) begins the handover: the in-flight torso
    /// state (the layer-`l1` activation) is relayed over the backhaul
    /// of the site currently serving the device, plus the configured
    /// control-plane cost, and the re-attachment lands when the relay
    /// completes. The walk stops at the horizon (and on deactivation)
    /// so the event queue drains.
    fn on_handover(&mut self, device: usize, now: SimTime) {
        if self.horizon_reached || !self.devices[device].active {
            return;
        }
        let Some(walk) = self.walk else { return };
        let topo = self.topology.as_ref().expect("mobility without an edge tier");
        let (dwell, crossed) = self.walkers[device].step(topo, &walk);
        if let Some(cell) = crossed {
            // Under an outage the crossing routes around dead sites —
            // the healthy path is byte-identical to `attach` (pinned by
            // edge/topology tests), so a zero-fault run never diverges.
            let routed = if self.site_down.iter().any(|&x| x) {
                topo.attach_avoiding(device, Some(cell), &self.site_down)
            } else {
                Some(topo.attach(device, Some(cell)))
            };
            if let Some(new_site) = routed {
                if new_site != self.target_site[device] {
                    self.target_site[device] = new_site;
                    self.handover_seq[device] += 1;
                    match self.devices[device].edge {
                        Some(serving) => {
                            let plan = self.devices[device].plan();
                            let state_bytes = if plan.is_two_tier() {
                                0
                            } else {
                                self.model.intermediate_bytes(plan.l1)
                            };
                            let cost = self.cfg.handover_cost_s.max(0.0)
                                + serving.backhaul.transfer_s(state_bytes);
                            if let Some(tr) = self.trace.as_mut() {
                                tr.note(CausalEvent::HandoverRelay {
                                    start_s: now,
                                    end_s: now + cost,
                                    device: device as u64,
                                    from_site: serving.site as u32,
                                    to_site: new_site as u32,
                                    state_bytes: state_bytes as u64,
                                });
                            }
                            self.q.schedule_in(
                                cost,
                                Event::Reattach {
                                    device,
                                    site: new_site,
                                    seq: self.handover_seq[device],
                                    failover: false,
                                },
                            );
                        }
                        None => {
                            // Detached by a total outage: nothing to
                            // relay — a forced re-attachment at the
                            // control-plane cost alone.
                            self.q.schedule_in(
                                self.cfg.handover_cost_s.max(0.0),
                                Event::Reattach {
                                    device,
                                    site: new_site,
                                    seq: self.handover_seq[device],
                                    failover: true,
                                },
                            );
                        }
                    }
                }
            }
        }
        self.q.schedule_in(dwell, Event::Handover { device });
    }

    /// Handover complete: adopt the new attachment, refresh the cached
    /// §III hop costs against it, and re-plan with the new tier context
    /// — the *migration* re-solve. The new site's `TierKey` makes this
    /// a distinct planner state, so the decision matches what any
    /// device already at that site would plan; the cache makes repeat
    /// migrations onto a known state one map lookup. A `seq` that no
    /// longer matches the device's latest crossing is superseded (a
    /// newer re-attachment exists or already landed) and is dropped;
    /// after the horizon pending re-attachments are dropped too, so the
    /// drain runs entirely on the attachments that served the in-flight
    /// work.
    fn on_reattach(&mut self, device: usize, site: usize, seq: u64, failover: bool, now: SimTime) {
        if self.horizon_reached || !self.devices[device].active {
            return;
        }
        if self.handover_seq[device] != seq {
            return;
        }
        let attachment = self.attachment_at(site);
        self.devices[device].edge = Some(attachment);
        // The device's events follow it onto the new site's shard.
        self.q.attach_device(device, Some(site));
        if failover {
            self.counters.failover_reattaches += 1;
            if let Some(s) = self.series.as_mut() {
                s.on_failover();
            }
        } else {
            self.counters.handovers += 1;
            if let Some(s) = self.series.as_mut() {
                s.on_handover();
            }
        }
        let reason = if failover { ReplanReason::Failover } else { ReplanReason::Migration };
        let bw = self.devices[device].bandwidth_at(now);
        if self.devices[device].pinned() {
            // Pinned splits never re-plan, but the cached hop costs
            // must follow the attachment that now serves them.
            let plan = self.devices[device].plan();
            self.devices[device].apply_split(plan, &self.model, bw);
            if let Some(tr) = self.trace.as_mut() {
                tr.note(CausalEvent::Reattach {
                    t_s: now,
                    device: device as u64,
                    site: site as u32,
                    replanned: false,
                });
            }
            return;
        }
        let profile = self.devices[device].profile;
        let band = BatteryBand::of_fraction(self.devices[device].soc());
        // The Replan annotation (inside plan_split_traced) lands before
        // the Reattach annotation below — cause before effect, in the
        // deterministic order the export contract pins.
        let planned = self.plan_split_traced(
            device,
            profile,
            bw,
            band,
            reason,
            now,
            &mut HashMap::new(),
        );
        // Adopt the migration plan; with no feasible plan at the new
        // state, keep the old plan but still refresh its cached hop
        // costs against the site now serving it.
        let plan = planned.unwrap_or_else(|| self.devices[device].plan());
        let moved = self.devices[device].apply_split(plan, &self.model, bw);
        if moved {
            if let Some(s) = self.series.as_mut() {
                s.on_resplit();
            }
        }
        if planned.is_some() {
            if failover {
                self.counters.failover_replans += 1;
            } else {
                self.counters.migrations += 1;
                if let Some(s) = self.series.as_mut() {
                    s.on_migration();
                }
            }
            self.note_decision(device, plan);
        }
        if let Some(tr) = self.trace.as_mut() {
            tr.note(CausalEvent::Reattach {
                t_s: now,
                device: device as u64,
                site: site as u32,
                replanned: planned.is_some(),
            });
        }
    }

    // ------------------------------------------------ fault injection

    /// Shared fault-edge bookkeeping: count the event, move the
    /// active-fault gauge by `delta`, mirror it into the time series,
    /// and drop a causal [`CausalEvent::Fault`] annotation.
    fn note_fault(&mut self, now: SimTime, kind: &'static str, site: usize, value: f64, delta: i64) {
        self.counters.faults += 1;
        self.faults_active = if delta >= 0 {
            self.faults_active + delta as u64
        } else {
            self.faults_active.saturating_sub(delta.unsigned_abs())
        };
        if let Some(s) = self.series.as_mut() {
            s.set_faults_active(self.faults_active);
        }
        if let Some(tr) = self.trace.as_mut() {
            tr.note(CausalEvent::Fault { t_s: now, kind, site: site as u32, value });
        }
    }

    /// Re-plan device `d` under [`ReplanReason::Failover`] after a fault
    /// changed the tier serving it in place (brownout edge, detachment
    /// by a total outage). The cached hop costs are refreshed even when
    /// the plan stands — and for pinned devices, which never re-plan
    /// but must still see the degraded backhaul in their hop costs.
    fn failover_replan(&mut self, d: usize, now: SimTime) {
        let bw = self.devices[d].bandwidth_at(now);
        if self.devices[d].pinned() {
            let plan = self.devices[d].plan();
            self.devices[d].apply_split(plan, &self.model, bw);
            return;
        }
        let profile = self.devices[d].profile;
        let band = BatteryBand::of_fraction(self.devices[d].soc());
        let planned = self.plan_split_traced(
            d,
            profile,
            bw,
            band,
            ReplanReason::Failover,
            now,
            &mut HashMap::new(),
        );
        let plan = planned.unwrap_or_else(|| self.devices[d].plan());
        let moved = self.devices[d].apply_split(plan, &self.model, bw);
        if moved {
            if let Some(s) = self.series.as_mut() {
                s.on_resplit();
            }
        }
        if planned.is_some() {
            self.counters.failover_replans += 1;
            self.note_decision(d, plan);
        }
    }

    /// Scripted site outage. Three obligations, in order: mark the site
    /// dead (new uplinks reroute), evacuate its waiting torso queue to
    /// the cloud (nothing queued dies with the site — conservation),
    /// and storm every device decided onto it through the epoch-guarded
    /// Reattach path to the nearest live site. In-service torso work
    /// finishes normally (its `EdgeDone` is already scheduled).
    fn on_site_down(&mut self, site: usize, now: SimTime) {
        if self.horizon_reached || self.site_down[site] {
            return;
        }
        self.site_down[site] = true;
        self.note_fault(now, "site_down", site, 0.0, 1);
        let drained = self.edges[site].drain(now);
        for q in &drained {
            if let Some(s) = self.series.as_mut() {
                s.on_edge_wait(q.waited_s);
            }
            if let Some(tr) = self.trace.as_mut() {
                // Close the open edge_queue span before relaying on.
                tr.end_span(q.req, now);
            }
            // Torso + tail both run at the cloud for evacuated work.
            self.reroute_to_cloud(
                q.req,
                q.device,
                q.issued,
                q.service_s + q.tail_s,
                q.backhaul_s,
                site,
                now,
            );
        }
        // Handover storm: mass forced re-attachment, one control-plane
        // cost each, all stamped with a fresh epoch so any in-flight
        // voluntary re-attachments onto the dead site are superseded.
        for d in 0..self.devices.len() {
            if !self.devices[d].active || self.target_site[d] != site {
                continue;
            }
            self.handover_seq[d] += 1;
            let fallback = self
                .topology
                .as_ref()
                .expect("fault without an edge tier")
                .attach_avoiding(d, Some(site), &self.site_down);
            match fallback {
                Some(new_site) => {
                    self.target_site[d] = new_site;
                    let seq = self.handover_seq[d];
                    self.q.schedule_in(
                        self.cfg.handover_cost_s.max(0.0),
                        Event::Reattach { device: d, site: new_site, seq, failover: true },
                    );
                }
                None => {
                    // Every site is down: detach — the device plans
                    // two-tier until a site comes back.
                    self.target_site[d] = usize::MAX;
                    self.devices[d].edge = None;
                    self.q.attach_device(d, None);
                    self.failover_replan(d, now);
                }
            }
        }
    }

    /// Scripted site recovery: re-balance. Devices whose *natural*
    /// placement (walker cell under mobility, the spawn rule otherwise)
    /// routes onto the recovered site — plus any left detached by a
    /// total outage — storm back through the same epoch-guarded path.
    fn on_site_up(&mut self, site: usize, now: SimTime) {
        if self.horizon_reached || !self.site_down[site] {
            return;
        }
        self.site_down[site] = false;
        self.note_fault(now, "site_up", site, 0.0, -1);
        for d in 0..self.devices.len() {
            if !self.devices[d].active {
                continue;
            }
            let desired = {
                let t = self.topology.as_ref().expect("fault without an edge tier");
                let cell = if self.walk.is_some() { Some(self.walkers[d].cell()) } else { None };
                if self.site_down.iter().any(|&x| x) {
                    t.attach_avoiding(d, cell, &self.site_down)
                } else {
                    Some(t.attach(d, cell))
                }
            };
            let Some(desired) = desired else { continue };
            if desired == self.target_site[d] {
                continue;
            }
            if desired == site || self.target_site[d] == usize::MAX {
                self.handover_seq[d] += 1;
                self.target_site[d] = desired;
                let seq = self.handover_seq[d];
                self.q.schedule_in(
                    self.cfg.handover_cost_s.max(0.0),
                    Event::Reattach { device: d, site: desired, seq, failover: true },
                );
            }
        }
    }

    /// Scripted brownout edge: scale the site's backhaul bandwidth and
    /// push the degraded tier context through every attached device —
    /// refreshed hop costs for all, a [`ReplanReason::Failover`]
    /// re-solve for the unpinned (the degraded bandwidth buckets into a
    /// distinct `TierKey`, so the planner genuinely reconsiders).
    fn on_backhaul_degrade(&mut self, site: usize, factor: f64, now: SimTime) {
        if self.horizon_reached {
            return;
        }
        let was_degraded = self.backhaul_factor[site] < 1.0;
        self.backhaul_factor[site] = factor;
        self.note_fault(now, "backhaul_degrade", site, factor, if was_degraded { 0 } else { 1 });
        self.refresh_site_attachments(site, now);
    }

    /// Scripted brownout end: the backhaul returns to its configured
    /// bandwidth and the site's devices re-plan back.
    fn on_backhaul_restore(&mut self, site: usize, now: SimTime) {
        if self.horizon_reached || self.backhaul_factor[site] >= 1.0 {
            return;
        }
        self.backhaul_factor[site] = 1.0;
        self.note_fault(now, "backhaul_restore", site, 1.0, -1);
        self.refresh_site_attachments(site, now);
    }

    /// Re-issue the (possibly degraded) attachment to every active
    /// device attached to `site`, then run the failover re-plan.
    fn refresh_site_attachments(&mut self, site: usize, now: SimTime) {
        for d in 0..self.devices.len() {
            if !self.devices[d].active {
                continue;
            }
            if self.devices[d].edge.map(|e| e.site) != Some(site) {
                continue;
            }
            self.devices[d].edge = Some(self.attachment_at(site));
            self.failover_replan(d, now);
        }
    }

    /// Flash-crowd start: arrivals are boosted and pinned toward the
    /// crowded site until the matching end event. Overlapping crowds
    /// don't stack — the first active crowd wins and a latecomer is
    /// dropped (its end event finds a different site and no-ops).
    fn on_flash_crowd_start(&mut self, site: usize, boost: f64, now: SimTime) {
        if self.horizon_reached || self.crowd.is_some() {
            return;
        }
        self.crowd = Some((site, boost));
        self.note_fault(now, "flash_crowd_start", site, boost, 1);
    }

    /// Flash-crowd end: disperse, if this site's crowd is the one
    /// active.
    fn on_flash_crowd_end(&mut self, site: usize, now: SimTime) {
        if self.horizon_reached || self.crowd.map(|(s, _)| s) != Some(site) {
            return;
        }
        self.crowd = None;
        self.note_fault(now, "flash_crowd_end", site, 0.0, -1);
    }

    fn on_join(&mut self, now: SimTime) {
        if self.horizon_reached {
            return;
        }
        if let Some(churn) = self.cfg.churn.clone() {
            let member = self.devices.len();
            self.spawn_device(now, member);
            self.counters.joined += 1;
            self.q.schedule_in(self.rng.next_exp(churn.joins_per_s), Event::Join);
        }
    }

    fn on_leave(&mut self, device: usize) {
        if self.devices[device].active {
            self.counters.left += 1;
            self.deactivate(device);
        }
    }

    fn run_loop(&mut self) {
        // Horizon is scheduled before any other event so that it wins the
        // FIFO tie against anything landing at exactly `duration_s` —
        // in particular a re-optimisation tick whose grid point coincides
        // with the horizon (sweep k fires iff k·period < duration).
        self.q.schedule(self.cfg.duration_s, Event::Horizon);
        // The scripted fault schedule enters the queue up front, on the
        // virtual clock like everything else. An empty plan schedules
        // nothing and draws nothing — the event-sequence numbers (and
        // therefore every FIFO tie-break) are untouched, which is what
        // makes a zero-fault run replay the frozen scenarios
        // byte-for-byte (tests/fault_injection.rs).
        let cfg = self.cfg;
        for e in &cfg.faults.events {
            match e.kind {
                FaultKind::SiteDown { site } => {
                    self.q.schedule(e.at_s, Event::SiteDown { site })
                }
                FaultKind::SiteUp { site } => self.q.schedule(e.at_s, Event::SiteUp { site }),
                FaultKind::BackhaulDegrade { site, factor } => {
                    self.q.schedule(e.at_s, Event::BackhaulDegrade { site, factor })
                }
                FaultKind::BackhaulRestore { site } => {
                    self.q.schedule(e.at_s, Event::BackhaulRestore { site })
                }
                FaultKind::FlashCrowd { site, duration_s, boost } => {
                    self.q.schedule(e.at_s, Event::FlashCrowdStart { site, boost });
                    self.q.schedule(e.at_s + duration_s, Event::FlashCrowdEnd { site });
                }
            }
        }
        for member in 0..self.cfg.fleet.initial_count() {
            self.spawn_device(0.0, member);
        }
        let first = next_interarrival(self.cfg.arrival, 0.0, &mut self.rng);
        self.q.schedule(first, Event::Arrival);
        if let Some(churn) = &self.cfg.churn {
            if churn.joins_per_s > 0.0 {
                let gap = self.rng.next_exp(churn.joins_per_s);
                self.q.schedule(gap, Event::Join);
            }
        }
        if self.cfg.reopt_period_s > 0.0 {
            // Tick 1 of the absolute re-arm grid (see on_reoptimize).
            self.reopt_tick = 1;
            self.q.schedule(self.cfg.reopt_period_s, Event::Reoptimize);
        }

        while let Some((now, event)) = self.q.pop() {
            // Close any windows the virtual clock just crossed *before*
            // dispatching: the event at `now` belongs to the window
            // containing `now`, and boundary snapshots (queue depth,
            // busy time, planner counters) are taken at the crossing.
            if self.series.as_ref().map_or(false, |s| s.needs_roll(now)) {
                let planner = self.facade.stats();
                let (e_gauges, c_gauges) = pool_gauges(&self.edges, &self.clouds);
                if let Some(s) = self.series.as_mut() {
                    s.roll(now, planner, &e_gauges, &c_gauges);
                }
            }
            match event {
                Event::Horizon => self.horizon_reached = true,
                Event::Arrival => self.on_arrival(now),
                Event::Uplinked { req, device, issued, site, torso_s, backhaul_s, tail_s } => {
                    self.on_uplinked(req, device, issued, site, torso_s, backhaul_s, tail_s, now)
                }
                Event::EdgeDone { req, site, device, issued, backhaul_s, tail_s } => {
                    self.on_edge_done(req, site, device, issued, backhaul_s, tail_s, now)
                }
                Event::CloudArrive { req, device, issued, tail_s } => {
                    self.offer_cloud(req, device, issued, tail_s, now)
                }
                Event::CloudDone { req, cloud, device, issued } => {
                    self.on_cloud_done(req, cloud, device, issued, now)
                }
                Event::Handover { device } => self.on_handover(device, now),
                Event::Reattach { device, site, seq, failover } => {
                    self.on_reattach(device, site, seq, failover, now)
                }
                Event::SiteDown { site } => self.on_site_down(site, now),
                Event::SiteUp { site } => self.on_site_up(site, now),
                Event::BackhaulDegrade { site, factor } => {
                    self.on_backhaul_degrade(site, factor, now)
                }
                Event::BackhaulRestore { site } => self.on_backhaul_restore(site, now),
                Event::FlashCrowdStart { site, boost } => {
                    self.on_flash_crowd_start(site, boost, now)
                }
                Event::FlashCrowdEnd { site } => self.on_flash_crowd_end(site, now),
                Event::Reoptimize => self.on_reoptimize(now),
                Event::Join => self.on_join(now),
                Event::Leave { device } => self.on_leave(device),
            }
        }
    }

    fn report(mut self, wall: Duration) -> SimReport {
        // Finalise the observability sinks first: the time series closes
        // its partial tail window at the drained clock (which may run
        // past the horizon), and the tracer seals its completion-ordered
        // request list. Both consume only virtual-clock state, so the
        // reports are deterministic across thread configs and reruns.
        let series = self.series.take().map(|s| {
            let (e_gauges, c_gauges) = pool_gauges(&self.edges, &self.clouds);
            s.finalize(self.q.now(), self.facade.stats(), &e_gauges, &c_gauges)
        });
        let trace = self.trace.take().map(|t| t.finish());
        // The meter ran on virtual time: pin its elapsed window to the
        // configured horizon so `rps()` reports offered-load throughput.
        self.meter.set_elapsed_s(self.cfg.duration_s);
        debug_assert_eq!(self.meter.completed(), self.counters.completed);
        let latency = Histogram::new();
        let mut per_profile = Vec::new();
        for (name, hist) in self.latency_by_profile {
            latency.merge(&hist);
            let served = self
                .devices
                .iter()
                .filter(|d| d.profile.name == name)
                .map(|d| d.served)
                .sum();
            per_profile.push(ProfileSlice {
                name,
                devices: self.devices_by_profile.get(name).copied().unwrap_or(0),
                served,
                latency: hist,
            });
        }
        let queue_delay = Histogram::new();
        let clouds: Vec<CloudSlice> = self
            .clouds
            .iter()
            .map(|c| {
                queue_delay.merge(&c.queue_delay);
                CloudSlice {
                    servers: c.servers,
                    served: c.served,
                    utilization: c.utilization(self.cfg.duration_s),
                    peak_queue: c.peak_queue(),
                }
            })
            .collect();
        let edge_queue_delay = Histogram::new();
        let edges: Vec<CloudSlice> = self
            .edges
            .iter()
            .map(|e| {
                edge_queue_delay.merge(&e.queue_delay);
                CloudSlice {
                    servers: e.servers,
                    served: e.served,
                    utilization: e.utilization(self.cfg.duration_s),
                    peak_queue: e.peak_queue(),
                }
            })
            .collect();
        let mut split_counts: BTreeMap<SplitPlan, u64> = BTreeMap::new();
        for d in self.devices.iter().filter(|d| d.active) {
            *split_counts.entry(d.plan()).or_insert(0) += 1;
        }
        SimReport {
            model: self.cfg.model.clone(),
            seed: self.cfg.seed,
            duration_s: self.cfg.duration_s,
            sim_end_s: self.q.now(),
            wall,
            events: self.q.processed(),
            shards: self.q.shard_slices(),
            shard_windows: self.q.windows(),
            cross_shard_events: self.q.cross_shard_events(),
            devices_created: self.devices.len(),
            devices_active_end: self.active.len(),
            joined: self.counters.joined,
            left: self.counters.left,
            batteries_exhausted: self.counters.exhausted,
            generated: self.counters.generated,
            completed: self.counters.completed,
            dropped: self.counters.dropped,
            latency,
            queue_delay,
            device_queue_delay: self.device_wait,
            edge_queue_delay,
            per_profile,
            clouds,
            edges,
            resplits: self.devices.iter().map(|d| d.resplits).sum(),
            handovers: self.counters.handovers,
            migration_replans: self.counters.migrations,
            failover_reattaches: self.counters.failover_reattaches,
            requests_rerouted: self.counters.rerouted,
            failover_replans: self.counters.failover_replans,
            fault_events: self.counters.faults,
            client_energy_j: self.devices.iter().map(|d| d.client_energy_j).sum(),
            upload_energy_j: self.devices.iter().map(|d| d.upload_energy_j).sum(),
            split_distribution: split_counts.into_iter().collect(),
            reopt_sweeps: self.sweeps,
            planner: self.facade.stats(),
            decision_count: self.decision_count,
            decisions: self.decisions,
            series,
            trace,
        }
    }
}

/// Run a scenario to completion (all in-flight work drained past the
/// horizon) and report.
pub fn run(cfg: &SimConfig) -> Result<SimReport> {
    // detlint:allow(D1): wall-clock throughput measurement only; never feeds a decision or an export payload
    let wall_start = Instant::now();
    let mut sim = Sim::new(cfg)?;
    sim.run_loop();
    Ok(sim.report(wall_start.elapsed()))
}
