//! Deterministic discrete-event core: a virtual clock and a binary-heap
//! event queue with FIFO tie-breaking.
//!
//! Everything the simulator does is an [`Event`] popped off this queue in
//! (time, insertion-order) order. No wall clock, no threads, no sockets —
//! given one seed, two runs pop the identical event sequence, which is the
//! property `tests/sim_determinism.rs` pins down.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Virtual timestamp: seconds since simulation start.
pub type SimTime = f64;

/// The simulator's event vocabulary.
#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// Fleet-level workload tick: dispatch one request to a device.
    Arrival,
    /// A device finished head compute + activation upload; the request
    /// reaches the next tier (its edge site's torso queue, or directly
    /// the cloud when the plan has no torso). `issued` is the original
    /// arrival time; the per-hop costs — and `site`, the edge
    /// attachment — are captured at issue (a re-split or a mobility
    /// re-attachment mid-flight must not change in-flight work):
    /// `torso_s` edge service at `site`, `backhaul_s` edge→cloud
    /// transfer, `tail_s` cloud service. Two-tier plans carry
    /// `torso_s == 0` — but an edge-attached device still relays
    /// through its site, so its `backhaul_s` is 0 only when the
    /// backhaul itself is free (the degenerate-parity condition) or the
    /// tail is empty. `site` is `None` for devices with no edge
    /// attachment (and then `torso_s == 0` always). `req` is the
    /// fleet-wide request ordinal (assigned at generation), carried so
    /// the tracer can stitch every hop of one request into one
    /// timeline.
    Uplinked {
        req: u64,
        device: usize,
        issued: SimTime,
        site: Option<usize>,
        torso_s: f64,
        backhaul_s: f64,
        tail_s: f64,
    },
    /// An edge-site server finished the torso layers of this device's
    /// request; next stop is the backhaul (then the cloud).
    EdgeDone {
        req: u64,
        site: usize,
        device: usize,
        issued: SimTime,
        backhaul_s: f64,
        tail_s: f64,
    },
    /// A request crossed the backhaul and reaches its cloud's queue.
    CloudArrive { req: u64, device: usize, issued: SimTime, tail_s: f64 },
    /// A cloud server finished the tail layers of this device's request.
    CloudDone { req: u64, cloud: usize, device: usize, issued: SimTime },
    /// Mobility tick: advance this device's waypoint walk one step
    /// ([`crate::sim::mobility::Walker::step`]). A tick that crosses
    /// into another site's cell begins an edge handover — the in-flight
    /// torso state is relayed over the old site's backhaul — and
    /// schedules [`Event::Reattach`] at the relay's completion.
    Handover { device: usize },
    /// Edge handover complete: the device attaches to `site` and
    /// re-plans its split with the new tier context (a *migration*
    /// re-solve, accounted via
    /// [`crate::planner::ReplanReason::Migration`]). `seq` is the
    /// device's handover sequence number at scheduling time: relay
    /// delays vary per crossing, so re-attachments can land out of
    /// order, and only the event matching the device's *latest*
    /// crossing may apply — stale ones are dropped. `failover` marks
    /// re-attachments forced by an injected fault (site outage or
    /// recovery re-balance): they re-plan under
    /// [`crate::planner::ReplanReason::Failover`] instead and are
    /// tallied apart from voluntary mobility.
    Reattach { device: usize, site: usize, seq: u64, failover: bool },
    /// Fault injection ([`crate::sim::faults::FaultPlan`]): an edge
    /// site dies. Its queued torso work is relayed onward and every
    /// attached device storms through the epoch-guarded
    /// [`Event::Reattach`] path to the nearest live site.
    SiteDown { site: usize },
    /// Fault injection: a dead site recovers; devices whose natural
    /// attachment is this site re-balance back onto it.
    SiteUp { site: usize },
    /// Fault injection: scale `site`'s backhaul bandwidth by `factor`
    /// (a brownout) until the matching [`Event::BackhaulRestore`].
    BackhaulDegrade { site: usize, factor: f64 },
    /// Fault injection: end a brownout — the site's backhaul returns
    /// to its configured bandwidth.
    BackhaulRestore { site: usize },
    /// Fault injection: a flash crowd pins itself to `site`'s cell —
    /// arrivals are boosted by `boost` and biased toward devices
    /// attached there until [`Event::FlashCrowdEnd`].
    FlashCrowdStart { site: usize, boost: f64 },
    /// Fault injection: the flash crowd at `site` disperses.
    FlashCrowdEnd { site: usize },
    /// Periodic fleet sweep: re-run the split optimiser for devices whose
    /// bandwidth or battery band drifted.
    Reoptimize,
    /// Churn: a new device joins the fleet.
    Join,
    /// Churn: a device leaves the fleet.
    Leave { device: usize },
    /// End of the simulated horizon: stop issuing new work.
    Horizon,
}

/// One queued event: a timestamp, the global insertion sequence number
/// (the FIFO tie-break), and the payload. Shared with the sharded
/// engine ([`super::shard::ShardedQueue`]), whose per-shard heaps hold
/// exactly these entries — same ordering, same tie-break, one global
/// `seq` stream — so the two engines pop the identical total order.
pub(crate) struct Entry {
    pub(crate) time: SimTime,
    pub(crate) seq: u64,
    pub(crate) event: Event,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Entry {}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Entry {
    /// Reversed (time, seq) so `BinaryHeap`'s max-heap pops the earliest
    /// event first, FIFO among equal timestamps.
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Min-heap event queue owning the virtual clock.
pub struct EventQueue {
    heap: BinaryHeap<Entry>,
    seq: u64,
    now: SimTime,
    popped: u64,
}

impl Default for EventQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue { heap: BinaryHeap::new(), seq: 0, now: 0.0, popped: 0 }
    }

    /// Current virtual time — the timestamp of the last popped event.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Events popped so far (the `events/sec` numerator in `sim_scale`).
    pub fn processed(&self) -> u64 {
        self.popped
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `event` at absolute time `at` (clamped to the present —
    /// the past is immutable in this establishment).
    pub fn schedule(&mut self, at: SimTime, event: Event) {
        debug_assert!(at.is_finite(), "non-finite event time");
        let entry = Entry { time: at.max(self.now), seq: self.seq, event };
        self.seq += 1;
        self.heap.push(entry);
    }

    /// Schedule `event` at `dt` seconds from now.
    pub fn schedule_in(&mut self, dt: SimTime, event: Event) {
        debug_assert!(dt >= 0.0, "negative delay {dt}");
        self.schedule(self.now + dt.max(0.0), event);
    }

    /// Pop the earliest event, advancing the virtual clock to it.
    pub fn pop(&mut self) -> Option<(SimTime, Event)> {
        let entry = self.heap.pop()?;
        self.now = entry.time;
        self.popped += 1;
        Some((entry.time, entry.event))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order_and_advances_clock() {
        let mut q = EventQueue::new();
        q.schedule(3.0, Event::Arrival);
        q.schedule(1.0, Event::Horizon);
        q.schedule(2.0, Event::Join);
        assert_eq!(q.pop(), Some((1.0, Event::Horizon)));
        assert_eq!(q.now(), 1.0);
        assert_eq!(q.pop(), Some((2.0, Event::Join)));
        assert_eq!(q.pop(), Some((3.0, Event::Arrival)));
        assert_eq!(q.now(), 3.0);
        assert!(q.pop().is_none());
        assert_eq!(q.processed(), 3);
    }

    #[test]
    fn equal_timestamps_pop_fifo() {
        let mut q = EventQueue::new();
        for d in 0..100 {
            q.schedule(5.0, Event::Leave { device: d });
        }
        for d in 0..100 {
            assert_eq!(q.pop(), Some((5.0, Event::Leave { device: d })));
        }
    }

    #[test]
    fn schedule_in_is_relative_to_virtual_now() {
        let mut q = EventQueue::new();
        q.schedule(10.0, Event::Arrival);
        q.pop();
        q.schedule_in(2.5, Event::Horizon);
        assert_eq!(q.pop(), Some((12.5, Event::Horizon)));
    }

    #[test]
    fn past_schedules_clamp_to_now() {
        let mut q = EventQueue::new();
        q.schedule(10.0, Event::Arrival);
        q.pop();
        q.schedule(4.0, Event::Horizon); // "4.0" is in the past
        assert_eq!(q.pop(), Some((10.0, Event::Horizon)));
    }

    #[test]
    fn interleaved_same_time_ordering_is_stable() {
        let mut q = EventQueue::new();
        q.schedule(1.0, Event::Arrival);
        q.schedule(1.0, Event::Reoptimize);
        q.schedule(0.5, Event::Join);
        assert_eq!(q.pop().unwrap().1, Event::Join);
        assert_eq!(q.pop().unwrap().1, Event::Arrival);
        assert_eq!(q.pop().unwrap().1, Event::Reoptimize);
    }
}
