//! Device mobility: a deterministic per-device waypoint walk over the
//! edge topology's site cells — the workload axis the paper's
//! conclusion flags ("time-varying bandwidth ... the crucial
//! parameter") but its fixed two-phone testbed cannot exercise.
//!
//! The metro footprint is the 1-D ring of cells the
//! [`EdgeTopology`] defines (one cell per site; see
//! `edge/topology.rs`). Each mobile device runs its own
//! random-waypoint state machine ([`Walker`]): pause at the current
//! cell, pick a waypoint cell uniformly, walk toward it one cell per
//! hop along the shortest arc, pause again, repeat. Every hop that
//! crosses into another site's cell begins an **edge handover** in the
//! simulator: the in-flight torso state is relayed over the *old*
//! site's backhaul (plus a fixed control-plane cost), the device
//! re-attaches via the topology's assignment rule, and its split is
//! re-planned through the planner façade with the new
//! [`crate::planner::TierContext`] — a migration re-solve, accounted
//! distinctly from battery/drift re-splits via
//! [`crate::planner::ReplanReason::Migration`].
//!
//! # Determinism contract
//!
//! * [`Mobility::Static`] schedules **no** events and draws **no**
//!   randomness: a Static run replays the corresponding immobile
//!   scenario byte-for-byte (`tests/edge_parity.rs` pins
//!   `city_mobile`-frozen-Static against `city_scale_tiered`).
//! * Each [`Walker`] owns a private RNG stream derived from
//!   `(scenario seed, device id)`, so mobility never perturbs the
//!   scenario RNG (spawn order, arrival sampling) and the walk is
//!   identical whatever the planner fan-out or thread count does.

use crate::edge::EdgeTopology;
use crate::util::rng::{SplitMix64, Xoshiro256};

/// Shortest dwell between two mobility events of one device, seconds —
/// a floor against degenerate configs scheduling zero-interval event
/// storms.
const MIN_DWELL_S: f64 = 1e-3;

/// Random-waypoint walk parameters (per scenario; every mobile device
/// draws from these ranges out of its own RNG stream).
#[derive(Clone, Copy, Debug)]
pub struct WaypointWalk {
    /// Mean pause at a reached waypoint before picking the next one,
    /// seconds (exponentially distributed).
    pub pause_mean_s: f64,
    /// Time to cross one cell, drawn uniformly from this range per hop,
    /// seconds.
    pub cell_crossing_s: (f64, f64),
}

impl WaypointWalk {
    /// City preset scaled to the virtual horizon: a device pauses
    /// ~`duration/12` between legs and crosses a cell in
    /// `duration/60 .. duration/30`, so a full run sees several
    /// handovers per mobile device without the walk dominating the
    /// event budget.
    pub fn city_default(duration_s: f64) -> WaypointWalk {
        let d = duration_s.max(1.0);
        WaypointWalk { pause_mean_s: d / 12.0, cell_crossing_s: (d / 60.0, d / 30.0) }
    }
}

/// How devices move between edge-site cells over a run.
#[derive(Clone, Copy, Debug)]
pub enum Mobility {
    /// Devices never move — the pre-mobility world. Schedules no
    /// events, draws no randomness: a Static run is byte-identical to
    /// the immobile scenario it froze.
    Static,
    /// Per-device random-waypoint walk over the topology's site cells
    /// (requires an edge tier — there is nothing to hand over between
    /// otherwise).
    Waypoint(WaypointWalk),
}

impl Mobility {
    /// Does this model ever move a device?
    pub fn is_mobile(&self) -> bool {
        matches!(self, Mobility::Waypoint(_))
    }
}

/// One device's walk state: its private RNG stream, the cell it stands
/// in, and the waypoint it is heading for (if any).
#[derive(Debug)]
pub struct Walker {
    rng: Xoshiro256,
    cell: usize,
    waypoint: Option<usize>,
}

impl Walker {
    /// A walker for device `device` starting in `cell`. The RNG stream
    /// is derived from `(seed, device)` so it is private to this device
    /// — mobility draws must not perturb the scenario RNG (Static
    /// parity) and must not depend on event interleaving.
    pub fn new(seed: u64, device: usize, cell: usize) -> Walker {
        let stream = SplitMix64::new(
            seed ^ (device as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        )
        .next_u64();
        Walker { rng: Xoshiro256::seed_from_u64(stream), cell, waypoint: None }
    }

    /// The cell this device currently stands in.
    pub fn cell(&self) -> usize {
        self.cell
    }

    /// Advance the walk one tick. Returns `(dwell_s, crossed)`:
    /// `dwell_s` is the time until this device's next mobility tick,
    /// and `crossed` is `Some(new_cell)` when this tick stepped into
    /// another cell (the caller checks whether the serving site changed
    /// and, if so, runs the handover). Ticks that pause or pick a new
    /// waypoint return `None`.
    pub fn step(&mut self, topo: &EdgeTopology, walk: &WaypointWalk) -> (f64, Option<usize>) {
        match self.waypoint {
            Some(w) if w != self.cell => {
                let next = topo.step_toward(self.cell, w);
                self.cell = next;
                if next == w {
                    // Arrived: the next tick pauses and re-aims.
                    self.waypoint = None;
                }
                let (lo, hi) = walk.cell_crossing_s;
                let dt = lo + (hi - lo).max(0.0) * self.rng.next_f64();
                (dt.max(MIN_DWELL_S), Some(next))
            }
            _ => {
                // At a waypoint (or freshly spawned): pause, then aim
                // somewhere — possibly the current cell, which is a
                // longer stay.
                self.waypoint = Some(self.rng.gen_range(0, topo.num_cells() - 1));
                let pause = if walk.pause_mean_s > 0.0 {
                    self.rng.next_exp(1.0 / walk.pause_mean_s)
                } else {
                    MIN_DWELL_S
                };
                (pause.max(MIN_DWELL_S), None)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::edge::{BackhaulLink, EdgeSite};

    fn topo(sites: usize) -> EdgeTopology {
        EdgeTopology::uniform(
            sites,
            EdgeSite {
                servers: 1,
                profile: profiles::edge_server(),
                backhaul: BackhaulLink::METRO_1GBE,
            },
        )
    }

    fn walk() -> WaypointWalk {
        WaypointWalk { pause_mean_s: 10.0, cell_crossing_s: (2.0, 4.0) }
    }

    #[test]
    fn walker_is_deterministic_per_seed_and_device() {
        let t = topo(5);
        let w = walk();
        let mut a = Walker::new(7, 3, 3 % 5);
        let mut b = Walker::new(7, 3, 3 % 5);
        for _ in 0..200 {
            assert_eq!(a.step(&t, &w), b.step(&t, &w));
            assert_eq!(a.cell(), b.cell());
        }
    }

    #[test]
    fn device_streams_are_independent() {
        // Two devices with the same scenario seed must walk different
        // paths (their streams are keyed by device id).
        let t = topo(5);
        let w = walk();
        let mut a = Walker::new(7, 0, 0);
        let mut b = Walker::new(7, 1, 0);
        let mut diverged = false;
        for _ in 0..100 {
            a.step(&t, &w);
            b.step(&t, &w);
            if a.cell() != b.cell() {
                diverged = true;
            }
        }
        assert!(diverged, "device streams never diverged");
    }

    #[test]
    fn walk_visits_other_cells_and_stays_in_bounds() {
        let t = topo(4);
        let w = walk();
        let mut walker = Walker::new(42, 0, 0);
        let mut visited = std::collections::HashSet::new();
        let mut virtual_t = 0.0;
        for _ in 0..400 {
            let (dwell, crossed) = walker.step(&t, &w);
            assert!(dwell >= MIN_DWELL_S && dwell.is_finite());
            virtual_t += dwell;
            if let Some(c) = crossed {
                assert!(c < t.num_cells(), "walked off the ring: {c}");
                assert_eq!(c, walker.cell());
                visited.insert(c);
            }
        }
        assert!(virtual_t > 0.0);
        assert!(visited.len() >= 2, "walk never left its spawn cell: {visited:?}");
    }

    #[test]
    fn crossings_are_single_hops() {
        // Every crossing moves to a ring neighbour — the walk cannot
        // teleport over a site.
        let t = topo(6);
        let w = walk();
        let mut walker = Walker::new(9, 2, 2);
        let mut prev = walker.cell();
        for _ in 0..300 {
            let (_, crossed) = walker.step(&t, &w);
            if let Some(c) = crossed {
                assert_eq!(t.cell_distance(prev, c), 1, "crossing {prev}→{c} is not one hop");
                prev = c;
            }
        }
    }

    #[test]
    fn single_site_ring_never_hands_over() {
        let t = topo(1);
        let w = walk();
        let mut walker = Walker::new(11, 0, 0);
        for _ in 0..100 {
            let (_, crossed) = walker.step(&t, &w);
            assert!(crossed.is_none(), "a one-cell ring produced a crossing");
            assert_eq!(walker.cell(), 0);
        }
    }

    #[test]
    fn static_mobility_is_inert() {
        assert!(!Mobility::Static.is_mobile());
        assert!(Mobility::Waypoint(walk()).is_mobile());
        let d = WaypointWalk::city_default(600.0);
        assert!(d.pause_mean_s > 0.0);
        assert!(d.cell_crossing_s.0 > 0.0 && d.cell_crossing_s.1 >= d.cell_crossing_s.0);
    }
}
