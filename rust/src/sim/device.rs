//! Virtual smartphone: a [`ComputeProfile`] plus battery, time-varying
//! link, and a SmartSplit decision that adapts as conditions drift.
//!
//! Latency and energy come straight from the §III analytical models
//! ([`PerfModel`], tiered via [`TieredPerfModel`]), so a simulated device
//! behaves exactly like the modelled cost of the live serving path — that
//! equivalence is asserted by `tests/sim_determinism.rs` against the
//! 2-phone fleet.
//!
//! A device under an edge topology carries its current
//! [`EdgeAttachment`] (assigned site, site profile, backhaul), and its
//! [`SplitPlan`] may put torso layers there; with no attachment every
//! plan is the paper's two-tier split (`l1 == l2`). Under mobility
//! ([`crate::sim::mobility`]) the attachment changes over the run —
//! each request captures its hop costs *and* its site at issue time, so
//! in-flight work never sees a later re-split or re-attachment.

use std::collections::VecDeque;

use crate::coordinator::battery::{battery_aware_split, BatteryBand};
use crate::device::ComputeProfile;
use crate::edge::{BackhaulLink, SplitPlan, TieredPerfModel};
use crate::models::ModelProfile;
use crate::netsim::BandwidthTrace;
use crate::optimizer::{smartsplit, Nsga2Params};
use crate::perfmodel::{NetworkEnv, PerfModel};
use crate::sim::engine::SimTime;

/// How a device picks (and re-picks) its split. Spawns and re-plans
/// both honour the configured planner; every decision flows through the
/// sim's planning façade ([`crate::planner::Planner`]) with the battery
/// band folded into the TOPSIS stage.
#[derive(Clone, Debug)]
pub enum Planner {
    /// Full Algorithm 1 (NSGA-II + TOPSIS) — what the live `fleet` path
    /// runs. Right for live-parity tests; fleet-scale runs should pair
    /// it with [`Nsga2Params::for_tiny_genome`], and tiered (edge)
    /// scenarios with [`Nsga2Params::for_small_genome`]`(2)` — the
    /// configured params are used as-is for every solve.
    SmartSplit(Nsga2Params),
    /// TOPSIS over the exhaustive true Pareto front, battery-band
    /// weighted. O(L) per decision (O(L²) tiered) — the city-scale
    /// default.
    Topsis,
    /// Any other façade strategy (the §VI-C baselines and §V-A
    /// scalarisation methods) — what `simulate --planner lbo` maps to.
    /// The strategy must be *total* (find a plan for every device
    /// state): one that returns no plan panics the run, which is why
    /// the simulate CLI rejects `EpsilonConstrained` (its ε box can be
    /// legitimately infeasible).
    Custom(crate::planner::Strategy),
    /// Pin every device to this two-tier split (clamped to `1..=L-1`)
    /// and never re-plan — controlled experiments (e.g. forcing cloud
    /// contention).
    Fixed(usize),
}

impl Planner {
    /// The façade strategy this planner solves with; `None` for
    /// [`Planner::Fixed`] (pinned devices never solve).
    pub fn strategy(&self) -> Option<crate::planner::Strategy> {
        match self {
            Planner::SmartSplit(_) => Some(crate::planner::Strategy::SmartSplit),
            Planner::Topsis => Some(crate::planner::Strategy::Topsis),
            Planner::Custom(s) => Some(*s),
            Planner::Fixed(_) => None,
        }
    }
}

/// A device's place in the edge topology: which site serves it and
/// what that site looks like (for the §III-tiered cost tables). Fixed
/// for the device's life under [`crate::sim::Mobility::Static`];
/// replaced by each completed handover under a waypoint walk.
#[derive(Clone, Copy, Debug)]
pub struct EdgeAttachment {
    pub site: usize,
    pub profile: &'static ComputeProfile,
    pub backhaul: BackhaulLink,
}

/// One virtual device.
#[derive(Debug)]
pub struct SimDevice {
    pub profile: &'static ComputeProfile,
    /// Link bandwidth over virtual time (Mbps).
    pub trace: BandwidthTrace,
    /// Index of the cloud this device offloads its tail to.
    pub cloud: usize,
    /// Assigned edge site, if the scenario has an edge tier.
    pub edge: Option<EdgeAttachment>,
    /// Head depth: layers `1..=l1` run on the device.
    pub l1: usize,
    /// Torso end: layers `l1+1..=l2` run at the edge site (`l2 == l1`
    /// means no torso — the paper's two-tier split).
    pub l2: usize,
    /// Battery band the current split was planned in.
    pub band: BatteryBand,
    /// Bandwidth (Mbps) the current split was planned at.
    pub planned_bw_mbps: f64,

    // Cached per-split §III quantities, refreshed on every adopted plan.
    head_s: f64,
    torso_s: f64,
    tail_s: f64,
    upload_bits: f64,
    backhaul_s: f64,
    /// Eq. 6 dynamic compute power (split-independent; cached from
    /// [`PerfModel::client_power_w`] so the formula lives in one place).
    client_power_w: f64,

    // Battery state.
    capacity_j: f64,
    initial_soc: f64,
    drained_j: f64,
    /// Virtual time up to which background (idle) drain has been applied.
    last_drain_t: SimTime,

    /// `Planner::Fixed` devices never re-plan.
    pinned: bool,

    // Serial execution: one request at a time on the phone. The backlog
    // holds `(request ordinal, issue time)` — the ordinal keys the
    // request's trace timeline across its whole journey.
    pub busy: bool,
    pub backlog: VecDeque<(u64, SimTime)>,
    pub active: bool,

    // Accounting.
    pub served: u64,
    pub resplits: u64,
    pub client_energy_j: f64,
    pub upload_energy_j: f64,
}

/// Cost of running one request's device half, captured at issue time —
/// together with the downstream hop costs the engine will need once the
/// uplink completes (in-flight work must not see later re-splits).
#[derive(Clone, Copy, Debug)]
pub struct DeviceCost {
    pub head_s: f64,
    pub upload_s: f64,
    /// Edge site attached when the request was issued (`None` without
    /// an edge tier). In-flight work routes to *this* site even if a
    /// mobility handover re-attaches the device mid-flight — the
    /// handover cost charges the state relay instead.
    pub edge_site: Option<usize>,
    /// Torso service time at the edge site (0 for two-tier plans).
    pub torso_s: f64,
    /// Edge→cloud backhaul transfer time (0 for two-tier plans).
    pub backhaul_s: f64,
    /// Tail service time at the cloud for the plan this request used.
    pub tail_s: f64,
    pub energy_j: f64,
}

impl SimDevice {
    /// Create a device at virtual time `spawned_at` (0 for the initial
    /// fleet, the join time under churn — idle drain must not be charged
    /// for time before the device existed) and plan its initial split for
    /// `soc` state of charge and the trace's bandwidth at that instant.
    ///
    /// Uncached two-tier *reference* constructor (plain un-banded
    /// `smartsplit` / exact-bandwidth TOPSIS, like [`SimDevice::replan`];
    /// no edge attachment) — used by unit tests. The sim event loop plans
    /// through the split-plan cache with band weighting and quantisation
    /// and builds devices via [`SimDevice::with_split`]; decisions can
    /// differ from this path.
    pub fn new(
        profile: &'static ComputeProfile,
        trace: BandwidthTrace,
        cloud: usize,
        initial_soc: f64,
        spawned_at: SimTime,
        model: &ModelProfile,
        planner: &Planner,
    ) -> SimDevice {
        let bw = trace.at(std::time::Duration::from_secs_f64(spawned_at.max(0.0)));
        let mut d = SimDevice::unplanned(
            profile,
            trace,
            cloud,
            None,
            initial_soc,
            spawned_at,
            matches!(planner, Planner::Fixed(_)),
        );
        let l1 = match planner {
            Planner::SmartSplit(params) => smartsplit(&d.perf_model(model, bw), params).decision.l1,
            Planner::Topsis => battery_aware_split(&d.perf_model(model, bw), d.soc())
                .expect("no feasible split for device"),
            Planner::Custom(_) => {
                panic!("custom strategies plan through planner::Planner; use SimDevice::with_split")
            }
            Planner::Fixed(l1) => (*l1).clamp(1, model.num_layers.saturating_sub(1).max(1)),
        };
        d.adopt_split(SplitPlan::two_tier(l1), model, bw);
        d
    }

    /// Create a device whose split was decided externally — the
    /// cache-aware planner path in [`crate::sim`] (the split-plan cache
    /// plus parallel re-solve fan-out own the decision; the device only
    /// adopts it).
    #[allow(clippy::too_many_arguments)]
    pub fn with_split(
        profile: &'static ComputeProfile,
        trace: BandwidthTrace,
        cloud: usize,
        edge: Option<EdgeAttachment>,
        initial_soc: f64,
        spawned_at: SimTime,
        model: &ModelProfile,
        plan: SplitPlan,
        pinned: bool,
    ) -> SimDevice {
        let bw = trace.at(std::time::Duration::from_secs_f64(spawned_at.max(0.0)));
        let mut d =
            SimDevice::unplanned(profile, trace, cloud, edge, initial_soc, spawned_at, pinned);
        d.adopt_split(plan, model, bw);
        d
    }

    fn unplanned(
        profile: &'static ComputeProfile,
        trace: BandwidthTrace,
        cloud: usize,
        edge: Option<EdgeAttachment>,
        initial_soc: f64,
        spawned_at: SimTime,
        pinned: bool,
    ) -> SimDevice {
        let capacity_j = profile.battery_mah.unwrap_or(f64::INFINITY) * 3.6 * 3.85;
        let bw = trace.at(std::time::Duration::from_secs_f64(spawned_at.max(0.0)));
        SimDevice {
            profile,
            trace,
            cloud,
            edge,
            l1: 1,
            l2: 1,
            band: BatteryBand::of_fraction(initial_soc),
            planned_bw_mbps: bw,
            head_s: 0.0,
            torso_s: 0.0,
            tail_s: 0.0,
            upload_bits: 0.0,
            backhaul_s: 0.0,
            client_power_w: 0.0,
            capacity_j,
            initial_soc: initial_soc.clamp(0.0, 1.0),
            drained_j: 0.0,
            last_drain_t: spawned_at,
            pinned,
            busy: false,
            backlog: VecDeque::new(),
            active: true,
            served: 0,
            resplits: 0,
            client_energy_j: 0.0,
            upload_energy_j: 0.0,
        }
    }

    /// `Planner::Fixed` devices never re-plan.
    pub fn pinned(&self) -> bool {
        self.pinned
    }

    /// The §III evaluation context at bandwidth `bw_mbps`.
    pub fn perf_model<'a>(&self, model: &'a ModelProfile, bw_mbps: f64) -> PerfModel<'a> {
        PerfModel::new(
            self.profile,
            crate::device::profiles::cloud_server(),
            self.profile.wifi.expect("sim device needs a radio").radio_power(),
            NetworkEnv::with_bandwidth(bw_mbps),
            model,
        )
    }

    /// The tiered evaluation context at bandwidth `bw_mbps` — only
    /// meaningful for devices with an edge attachment.
    pub fn tiered_perf_model<'a>(
        &self,
        model: &'a ModelProfile,
        bw_mbps: f64,
    ) -> Option<TieredPerfModel<'a>> {
        let e = self.edge.as_ref()?;
        // The server count does not affect per-request cost tables; 1
        // keeps torso plans evaluable (feasibility is the planner's job).
        Some(TieredPerfModel::new(self.perf_model(model, bw_mbps), e.profile, 1, e.backhaul))
    }

    fn adopt_split(&mut self, plan: SplitPlan, model: &ModelProfile, bw_mbps: f64) {
        debug_assert!(plan.l1 <= plan.l2, "unordered plan {plan:?}");
        debug_assert!(
            self.edge.is_some() || plan.is_two_tier(),
            "torso plan {plan:?} without an edge attachment"
        );
        let pm = self.perf_model(model, bw_mbps);
        self.l1 = plan.l1;
        self.l2 = plan.l2;
        self.client_power_w = pm.client_power_w();
        self.head_s = pm.client_latency_s(plan.l1);
        self.tail_s = pm.server_latency_s(plan.l2);
        self.upload_bits = if plan.l1 >= model.num_layers {
            0.0
        } else if plan.l1 == 0 {
            // COC embedding: the raw input is the "intermediate".
            model.input_bytes() as f64 * 8.0
        } else {
            model.intermediate_bytes(plan.l1) as f64 * 8.0
        };
        match self.tiered_perf_model(model, bw_mbps) {
            Some(tpm) => {
                self.torso_s = tpm.torso_latency_s(plan);
                self.backhaul_s = tpm.backhaul_latency_s(plan);
            }
            None => {
                self.torso_s = 0.0;
                self.backhaul_s = 0.0;
            }
        }
        self.planned_bw_mbps = bw_mbps;
        self.band = BatteryBand::of_fraction(self.soc());
    }

    /// Battery state of charge in [0, 1].
    pub fn soc(&self) -> f64 {
        (self.initial_soc - self.drained_j / self.capacity_j).max(0.0)
    }

    /// Battery empty?
    pub fn exhausted(&self) -> bool {
        self.soc() <= 0.0
    }

    /// Integrate background draw (`idle_w` Watts) since the last drain
    /// checkpoint — the standby/app load BatteryStats would attribute to
    /// everything that isn't this workload.
    pub fn apply_idle_drain(&mut self, now: SimTime, idle_w: f64) {
        if now > self.last_drain_t {
            self.drained_j += idle_w * (now - self.last_drain_t);
            self.last_drain_t = now;
        }
    }

    /// Bandwidth of this device's link at virtual time `t`.
    pub fn bandwidth_at(&self, t: SimTime) -> f64 {
        self.trace.at(std::time::Duration::from_secs_f64(t.max(0.0)))
    }

    /// Modelled tail-layer service time at the cloud for this plan.
    pub fn service_s(&self) -> f64 {
        self.tail_s
    }

    /// Modelled torso service time at the edge site for this plan.
    pub fn torso_s(&self) -> f64 {
        self.torso_s
    }

    /// The plan currently adopted.
    pub fn plan(&self) -> SplitPlan {
        SplitPlan { l1: self.l1, l2: self.l2 }
    }

    /// Modelled end-to-end latency of one uncontended request at
    /// bandwidth `bw_mbps` — head + upload + torso + backhaul + tail,
    /// download excluded as in the paper (Eq. 14 generalised).
    pub fn expected_latency_s(&self, bw_mbps: f64) -> f64 {
        self.head_s + self.upload_bits / (bw_mbps * 1e6) + self.torso_s + self.backhaul_s
            + self.tail_s
    }

    /// Start one request at time `t`: compute the device-side cost, drain
    /// the battery, and return the cost so the engine can schedule the
    /// uplink-complete event. Returns `None` (and deactivates) if the
    /// battery is already flat.
    pub fn start_request(&mut self, t: SimTime) -> Option<DeviceCost> {
        if self.exhausted() {
            self.active = false;
            return None;
        }
        let bw = self.bandwidth_at(t);
        let head_s = self.head_s;
        let upload_s = self.upload_bits / (bw * 1e6);
        // Eq. 6 dynamic compute power + Eq. 8 radio power at τ_u = bw.
        // Only the head and the first hop touch the battery: torso,
        // backhaul and tail run on mains power.
        let radio = self.profile.wifi.expect("sim device needs a radio").radio_power();
        let client_j = self.client_power_w * head_s;
        let upload_j = radio.upload_power_w(bw) * upload_s;
        self.client_energy_j += client_j;
        self.upload_energy_j += upload_j;
        self.drained_j += client_j + upload_j;
        self.busy = true;
        Some(DeviceCost {
            head_s,
            upload_s,
            edge_site: self.edge.as_ref().map(|e| e.site),
            torso_s: self.torso_s,
            backhaul_s: self.backhaul_s,
            tail_s: self.tail_s,
            energy_j: client_j + upload_j,
        })
    }

    /// Has this device drifted out of the state its split was planned in?
    /// Returns the (bandwidth, battery band) to re-plan at when the band
    /// changed or the link moved more than `drift` (relative); `None`
    /// when the current plan still stands (or the device is inactive /
    /// pinned). Read-only: the decision itself is made by the sim's
    /// cache-aware planner layer and applied via [`SimDevice::apply_split`].
    pub fn drift_state(&self, t: SimTime, drift: f64) -> Option<(f64, BatteryBand)> {
        if !self.active || self.pinned {
            return None;
        }
        let bw = self.bandwidth_at(t);
        let band = BatteryBand::of_fraction(self.soc());
        let bw_moved = (bw - self.planned_bw_mbps).abs() / self.planned_bw_mbps > drift;
        if band == self.band && !bw_moved {
            return None;
        }
        Some((bw, band))
    }

    /// Adopt an externally decided plan at link bandwidth `bw` (refreshes
    /// the cached §III costs and the planned-state markers). Returns true
    /// — and counts a re-split — when the plan actually moved.
    pub fn apply_split(&mut self, plan: SplitPlan, model: &ModelProfile, bw: f64) -> bool {
        let moved = plan.l1 != self.l1 || plan.l2 != self.l2;
        self.adopt_split(plan, model, bw);
        if moved {
            self.resplits += 1;
        }
        moved
    }

    /// Re-run the split decision if battery band or bandwidth drifted
    /// beyond `drift`. Returns true when the split moved.
    pub fn maybe_replan(&mut self, t: SimTime, model: &ModelProfile, drift: f64) -> bool {
        if self.drift_state(t, drift).is_none() {
            return false;
        }
        self.replan(t, model)
    }

    /// Unconditional two-tier re-plan at current conditions
    /// (battery-band weighted TOPSIS over the exhaustive front) — the
    /// uncached reference path; the sim's event loop goes through the
    /// split-plan cache instead (tiered when an edge tier exists).
    /// Returns true if the split moved.
    pub fn replan(&mut self, t: SimTime, model: &ModelProfile) -> bool {
        if self.pinned {
            return false;
        }
        let bw = self.bandwidth_at(t);
        let Some(l1) = battery_aware_split(&self.perf_model(model, bw), self.soc()) else {
            return false;
        };
        self.apply_split(SplitPlan::two_tier(l1), model, bw)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::models::zoo;

    fn model() -> ModelProfile {
        zoo::alexnet().analyze(1)
    }

    fn device(model: &ModelProfile) -> SimDevice {
        SimDevice::new(
            profiles::redmi_note8(),
            BandwidthTrace::constant(30.0),
            0,
            1.0,
            0.0,
            model,
            &Planner::Topsis,
        )
    }

    fn attachment() -> EdgeAttachment {
        EdgeAttachment {
            site: 0,
            profile: profiles::edge_server(),
            backhaul: BackhaulLink::METRO_1GBE,
        }
    }

    #[test]
    fn late_join_pays_no_retroactive_idle_drain() {
        let m = model();
        let mut d = SimDevice::new(
            profiles::samsung_j6(),
            BandwidthTrace::constant(30.0),
            0,
            1.0,
            500.0, // joined at t = 500 s
            &m,
            &Planner::Topsis,
        );
        d.apply_idle_drain(500.0, 100.0);
        assert_eq!(d.soc(), 1.0, "drain charged for time before the join");
        d.apply_idle_drain(510.0, 100.0);
        assert!((d.soc() - (1.0 - 1000.0 / d.capacity_j)).abs() < 1e-12);
    }

    #[test]
    fn cached_costs_match_perf_model() {
        let m = model();
        let d = device(&m);
        assert!(d.plan().is_two_tier());
        let pm = d.perf_model(&m, 30.0);
        assert!((d.head_s - pm.client_latency_s(d.l1)).abs() < 1e-15);
        assert!((d.service_s() - pm.server_latency_s(d.l1)).abs() < 1e-15);
        assert!((d.expected_latency_s(30.0) - pm.f1(d.l1)).abs() < 1e-12);
        assert_eq!(d.client_power_w, pm.client_power_w());
        assert_eq!(d.torso_s(), 0.0);
        assert_eq!(d.backhaul_s, 0.0);
    }

    #[test]
    fn tiered_plan_caches_all_five_hop_costs() {
        let m = model();
        let plan = SplitPlan { l1: 3, l2: 10 };
        let d = SimDevice::with_split(
            profiles::redmi_note8(),
            BandwidthTrace::constant(30.0),
            0,
            Some(attachment()),
            1.0,
            0.0,
            &m,
            plan,
            false,
        );
        let tpm = d.tiered_perf_model(&m, 30.0).unwrap();
        let lat = tpm.latency(plan);
        assert!((d.head_s - lat.head_s).abs() < 1e-15);
        assert!((d.torso_s() - lat.torso_s).abs() < 1e-15);
        assert!((d.backhaul_s - lat.backhaul_s).abs() < 1e-15);
        assert!((d.service_s() - lat.tail_s).abs() < 1e-15);
        assert!((d.expected_latency_s(30.0) - tpm.f1(plan)).abs() < 1e-12);
        // Hop costs ride into the captured request cost.
        let mut d = d;
        let cost = d.start_request(0.0).unwrap();
        assert_eq!(cost.torso_s, d.torso_s());
        assert_eq!(cost.backhaul_s, d.backhaul_s);
        assert_eq!(cost.tail_s, d.service_s());
        // The issue-time site rides along too (mobility routing).
        assert_eq!(cost.edge_site, Some(0));
    }

    #[test]
    fn start_request_drains_battery() {
        let m = model();
        let mut d = device(&m);
        let soc0 = d.soc();
        let cost = d.start_request(0.0).unwrap();
        assert!(cost.energy_j > 0.0);
        assert!(d.soc() < soc0);
        assert!(d.busy);
        assert!((d.client_energy_j + d.upload_energy_j - cost.energy_j).abs() < 1e-12);
    }

    #[test]
    fn torso_never_touches_the_battery() {
        // Two devices, same head, one with a deep torso: identical
        // device-side energy per request (mains power does the rest).
        let m = model();
        let mut flat = SimDevice::with_split(
            profiles::redmi_note8(),
            BandwidthTrace::constant(30.0),
            0,
            None,
            1.0,
            0.0,
            &m,
            SplitPlan::two_tier(3),
            false,
        );
        let mut tiered = SimDevice::with_split(
            profiles::redmi_note8(),
            BandwidthTrace::constant(30.0),
            0,
            Some(attachment()),
            1.0,
            0.0,
            &m,
            SplitPlan { l1: 3, l2: 15 },
            false,
        );
        let a = flat.start_request(0.0).unwrap();
        let b = tiered.start_request(0.0).unwrap();
        assert_eq!(a.energy_j, b.energy_j);
        assert_eq!(flat.soc(), tiered.soc());
    }

    #[test]
    fn band_crossing_triggers_replan() {
        let m = model();
        let mut d = device(&m);
        assert_eq!(d.band, BatteryBand::Comfort);
        // Force the battery down into the critical band.
        d.drained_j = d.capacity_j * 0.85;
        assert!(d.soc() < 0.2);
        d.maybe_replan(0.0, &m, 0.2);
        assert_eq!(d.band, BatteryBand::Critical);
        // The critical split must not cost more energy than the comfort one
        // (same invariant the coordinator::battery tests pin).
        let pm = d.perf_model(&m, 30.0);
        let comfort = battery_aware_split(&pm, 1.0).unwrap();
        assert!(pm.f2(d.l1) <= pm.f2(comfort) + 1e-12);
    }

    #[test]
    fn bandwidth_drift_triggers_replan_and_steady_state_does_not() {
        let m = model();
        let mut d = device(&m);
        assert!(!d.maybe_replan(0.0, &m, 0.2), "no drift must mean no replan");
        // A 10× bandwidth collapse moves the planned point.
        d.trace = BandwidthTrace::constant(3.0);
        assert!(d.maybe_replan(0.0, &m, 0.2) || d.planned_bw_mbps == 3.0);
        assert_eq!(d.planned_bw_mbps, 3.0);
    }

    #[test]
    fn exhausted_battery_deactivates() {
        let m = model();
        let mut d = device(&m);
        d.drained_j = d.capacity_j * 2.0;
        assert!(d.exhausted());
        assert!(d.start_request(0.0).is_none());
        assert!(!d.active);
    }
}
