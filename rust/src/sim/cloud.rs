//! Virtual cloud: an M/G/c-style queue whose service time is the §III
//! tail-layer latency ([`crate::perfmodel::PerfModel::server_latency_s`])
//! of the requesting device's split, captured when the request was issued
//! (a re-split mid-flight must not retroactively change in-flight work).
//!
//! The live testbed never sees cloud contention — two phones cannot
//! saturate the server — but ten thousand virtual phones can, and the
//! queueing delay measured here is exactly the term Eq. 5 omits.

use std::collections::VecDeque;

use crate::metrics::Histogram;
use crate::sim::engine::SimTime;

/// One queued request.
#[derive(Clone, Copy, Debug)]
struct Queued {
    req: u64,
    device: usize,
    issued: SimTime,
    enqueued: SimTime,
    service_s: f64,
}

/// A request popped off the queue when a server frees up.
#[derive(Clone, Copy, Debug)]
pub struct Dequeued {
    pub req: u64,
    pub device: usize,
    pub issued: SimTime,
    pub service_s: f64,
    /// Time this request spent queued (`now - enqueued`), surfaced so
    /// the caller can feed the windowed time series and close the
    /// request's `cloud_queue` trace span without re-deriving it.
    pub waited_s: f64,
}

/// A virtual cloud server pool.
#[derive(Debug)]
pub struct SimCloud {
    /// Parallel servers (`c` in M/G/c). The live cloud daemon runs one
    /// serial PJRT executor, so 1 mirrors the testbed; raise it to model
    /// a scaled-out deployment.
    pub servers: usize,
    busy: usize,
    queue: VecDeque<Queued>,
    /// Time requests spent waiting for a free server.
    pub queue_delay: Histogram,
    pub served: u64,
    busy_time_s: f64,
    peak_queue: usize,
}

impl SimCloud {
    pub fn new(servers: usize) -> SimCloud {
        assert!(servers > 0, "a cloud needs at least one server");
        SimCloud {
            servers,
            busy: 0,
            queue: VecDeque::new(),
            queue_delay: Histogram::new(),
            served: 0,
            busy_time_s: 0.0,
            peak_queue: 0,
        }
    }

    /// A request arrives. Returns `Some(service_s)` if a server is free
    /// (caller schedules `CloudDone` at `now + service_s`); otherwise the
    /// request queues FIFO.
    pub fn offer(
        &mut self,
        req: u64,
        device: usize,
        issued: SimTime,
        now: SimTime,
        service_s: f64,
    ) -> Option<f64> {
        if self.busy < self.servers {
            self.busy += 1;
            self.busy_time_s += service_s;
            self.queue_delay.record_secs(0.0);
            Some(service_s)
        } else {
            self.queue.push_back(Queued { req, device, issued, enqueued: now, service_s });
            self.peak_queue = self.peak_queue.max(self.queue.len());
            None
        }
    }

    /// A server finished. Pops the next queued request, if any — the
    /// caller schedules its `CloudDone` at `now + service_s`.
    pub fn finish(&mut self, now: SimTime) -> Option<Dequeued> {
        self.served += 1;
        match self.queue.pop_front() {
            Some(q) => {
                self.queue_delay.record_secs(now - q.enqueued);
                self.busy_time_s += q.service_s;
                Some(Dequeued {
                    req: q.req,
                    device: q.device,
                    issued: q.issued,
                    service_s: q.service_s,
                    waited_s: now - q.enqueued,
                })
            }
            None => {
                self.busy -= 1;
                None
            }
        }
    }

    pub fn busy(&self) -> usize {
        self.busy
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn peak_queue(&self) -> usize {
        self.peak_queue
    }

    /// Cumulative committed service time, in seconds. The windowed
    /// time series differences boundary snapshots of this to get
    /// per-window utilisation.
    pub fn busy_time_s(&self) -> f64 {
        self.busy_time_s
    }

    /// Offered utilisation: busy-seconds accrued per server-second of the
    /// `horizon_s` window. Deliberately NOT clamped at 1.0 — a value of
    /// 3.0 means three horizons' worth of work was offered and the drain
    /// spilled past the horizon, which a clamp would hide.
    pub fn utilization(&self, horizon_s: f64) -> f64 {
        if horizon_s <= 0.0 {
            return 0.0;
        }
        self.busy_time_s / (horizon_s * self.servers as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serves_immediately_when_free() {
        let mut c = SimCloud::new(2);
        assert_eq!(c.offer(10, 0, 0.0, 0.0, 0.5), Some(0.5));
        assert_eq!(c.offer(11, 1, 0.0, 0.0, 0.5), Some(0.5));
        assert_eq!(c.busy(), 2);
        assert_eq!(c.offer(12, 2, 0.1, 0.1, 0.5), None);
        assert_eq!(c.queue_len(), 1);
    }

    #[test]
    fn finish_dequeues_fifo_with_captured_service_time() {
        let mut c = SimCloud::new(1);
        assert!(c.offer(10, 0, 0.0, 0.0, 1.0).is_some());
        assert!(c.offer(11, 1, 0.2, 0.2, 0.7).is_none());
        assert!(c.offer(12, 2, 0.3, 0.3, 0.9).is_none());
        // Server frees at t=1.0: device 1 (queued first) starts with the
        // service time captured at issue.
        let d = c.finish(1.0).unwrap();
        assert_eq!(d.req, 11);
        assert_eq!(d.device, 1);
        assert_eq!(d.issued, 0.2);
        assert_eq!(d.service_s, 0.7);
        // Its queue delay was 1.0 - 0.2 = 0.8 s.
        assert!((d.waited_s - 0.8).abs() < 1e-12);
        assert!((c.queue_delay.max_s() - 0.8).abs() < 1e-12);
        let d = c.finish(1.7).unwrap();
        assert_eq!(d.req, 12);
        assert_eq!(d.device, 2);
        assert!(c.finish(2.6).is_none());
        assert_eq!(c.busy(), 0);
        assert_eq!(c.served, 3);
        assert_eq!(c.peak_queue(), 2);
    }

    #[test]
    fn utilization_is_busy_time_over_capacity() {
        let mut c = SimCloud::new(2);
        c.offer(0, 0, 0.0, 0.0, 3.0);
        c.offer(1, 1, 0.0, 0.0, 1.0);
        c.finish(1.0);
        c.finish(3.0);
        // 4 busy-seconds over 2 servers × 4 s horizon = 0.5.
        assert!((c.utilization(4.0) - 0.5).abs() < 1e-12);
        assert!((c.busy_time_s() - 4.0).abs() < 1e-12);
        assert_eq!(c.utilization(0.0), 0.0);
    }
}
