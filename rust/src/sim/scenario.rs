//! Scenario definitions and presets for the fleet simulator.
//!
//! A [`SimConfig`] fully determines a run (together with its seed): the
//! fleet composition, workload shape, cloud capacity, adaptation policy
//! and churn. Two presets cover the interesting extremes:
//!
//! * [`two_phone_fleet`] — the paper's §VI testbed as the live
//!   `coordinator::fleet` path builds it (Samsung J6 at the base
//!   bandwidth, Redmi Note 8 at 3×), used by the live-parity tests;
//! * [`city_scale`] — 10k+ heterogeneous devices under a diurnal load
//!   swing with churn, per-device bandwidth wobble and battery drain —
//!   the scale the ROADMAP aims at and the testbed cannot reach.
//!
//! [`city_scale_tiered`] puts the same city behind a metro edge tier,
//! and [`city_mobile`] additionally sets its devices walking between
//! the sites (waypoint mobility → edge handovers → migration
//! re-solves).

use std::time::Duration;

use crate::device::{profiles, ComputeProfile};
use crate::edge::{AssignmentPolicy, BackhaulLink, EdgeSite, EdgeTopology};
use crate::netsim::BandwidthTrace;
use crate::optimizer::Nsga2Params;
use crate::sim::device::Planner;
use crate::sim::faults::FaultPlan;
use crate::sim::mobility::{Mobility, WaypointWalk};
use crate::util::rng::Xoshiro256;
use crate::workload::Arrival;

/// Default fixed control-plane cost per edge handover, seconds (the
/// torso-state relay over the old site's backhaul is charged on top) —
/// a 4G/5G-handover-class interruption.
pub const DEFAULT_HANDOVER_COST_S: f64 = 0.05;

/// Device churn: Poisson joins, exponential lifetimes.
#[derive(Clone, Debug)]
pub struct ChurnConfig {
    pub joins_per_s: f64,
    pub mean_lifetime_s: f64,
}

/// Opt-in observability (DESIGN.md §12): per-request span tracing and
/// the windowed time series. Both default to *off* — the recording
/// hooks are `Option`-gated so a disabled run does no extra work —
/// and neither may change decisions or event order
/// (`tests/observability.rs` pins transparency).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ObservabilityConfig {
    /// Record every n-th request's span timeline (by fleet-wide request
    /// ordinal); `0` disables tracing entirely, `1` traces everything.
    pub trace_sample_every: u64,
    /// Fixed virtual-time window width for the
    /// [`crate::metrics::TimeSeries`] collector, seconds; `0` disables
    /// the collector.
    pub window_s: f64,
}

impl Default for ObservabilityConfig {
    fn default() -> Self {
        ObservabilityConfig::disabled()
    }
}

impl ObservabilityConfig {
    /// Everything off — what every preset ships with.
    pub fn disabled() -> ObservabilityConfig {
        ObservabilityConfig { trace_sample_every: 0, window_s: 0.0 }
    }

    /// Trace every request and window the series — the configuration
    /// the determinism tests and the `--trace-out`/`--metrics-out` CLI
    /// path use.
    pub fn full(window_s: f64) -> ObservabilityConfig {
        ObservabilityConfig { trace_sample_every: 1, window_s }
    }
}

/// One explicitly configured fleet member.
#[derive(Clone, Debug)]
pub struct ExplicitMember {
    pub profile: &'static ComputeProfile,
    pub bandwidth_mbps: f64,
    pub initial_soc: f64,
}

/// How the fleet is populated.
#[derive(Clone, Debug)]
pub enum FleetSpec {
    /// Exactly these members, in order (churn joins cycle the list).
    Explicit(Vec<ExplicitMember>),
    /// `devices` members sampled from the template.
    Sampled {
        devices: usize,
        profiles: Vec<&'static ComputeProfile>,
        /// Per-device constant bandwidth drawn uniformly from this range.
        bandwidth_mbps: (f64, f64),
        /// Initial state of charge drawn uniformly from this range.
        initial_soc: (f64, f64),
        /// `Some(p)`: give each device a cyclic 3-step bandwidth trace
        /// (nominal → congested → good) with period `p`, so drift-driven
        /// re-optimisation has something to chase.
        wobble_period_s: Option<f64>,
    },
}

impl FleetSpec {
    /// Devices present at t = 0.
    pub fn initial_count(&self) -> usize {
        match self {
            FleetSpec::Explicit(members) => members.len(),
            FleetSpec::Sampled { devices, .. } => *devices,
        }
    }

    /// Materialise fleet member `member` (deterministic given the RNG
    /// state): its profile, link trace, and initial state of charge.
    pub fn instantiate(
        &self,
        member: usize,
        rng: &mut Xoshiro256,
    ) -> (&'static ComputeProfile, BandwidthTrace, f64) {
        match self {
            FleetSpec::Explicit(members) => {
                let m = &members[member % members.len()];
                (m.profile, BandwidthTrace::constant(m.bandwidth_mbps), m.initial_soc)
            }
            FleetSpec::Sampled {
                profiles,
                bandwidth_mbps: (bw_lo, bw_hi),
                initial_soc: (soc_lo, soc_hi),
                wobble_period_s,
                ..
            } => {
                let profile = profiles[rng.gen_range(0, profiles.len() - 1)];
                let bw = bw_lo + (bw_hi - bw_lo) * rng.next_f64();
                let soc = soc_lo + (soc_hi - soc_lo) * rng.next_f64();
                let trace = match wobble_period_s {
                    None => BandwidthTrace::constant(bw),
                    Some(p) => BandwidthTrace::steps(
                        Duration::from_secs_f64(*p),
                        &[bw, bw * 0.45, bw * 1.4],
                        Duration::from_secs_f64(p * 12.0),
                    ),
                };
                (profile, trace, soc)
            }
        }
    }
}

/// The scenario-level description of an edge tier: a uniform set of
/// metro sites between the fleet and the core cloud(s). Expanded into
/// an [`EdgeTopology`] (and per-site M/G/c torso queues) by the sim.
#[derive(Clone, Debug)]
pub struct EdgeSpec {
    /// Number of metro sites.
    pub sites: usize,
    /// Torso servers per site; `0` makes every site a pure relay (the
    /// planner can then only choose two-tier plans — the degenerate
    /// configuration `tests/edge_parity.rs` pins against PR-2 behaviour
    /// when the backhaul is also [`BackhaulLink::FREE`]).
    pub servers_per_site: usize,
    /// Compute profile of one edge server.
    pub profile: &'static ComputeProfile,
    /// Edge→cloud backhaul shared by all sites.
    pub backhaul: BackhaulLink,
    /// Device→site assignment.
    pub assignment: AssignmentPolicy,
}

impl EdgeSpec {
    /// A uniform metro tier: `sites` sites of `servers_per_site` edge
    /// servers each, `backhaul_mbps` of wired uplink (2 ms one way).
    pub fn uniform(sites: usize, servers_per_site: usize, backhaul_mbps: f64) -> EdgeSpec {
        EdgeSpec {
            sites,
            servers_per_site,
            profile: profiles::edge_server(),
            backhaul: BackhaulLink { bandwidth_mbps: backhaul_mbps, latency_s: 2e-3 },
            assignment: AssignmentPolicy::RoundRobin,
        }
    }

    /// The degenerate tier: relay-only sites over a free backhaul. The
    /// planner must reproduce two-tier decisions exactly under it.
    pub fn degenerate_relay(sites: usize) -> EdgeSpec {
        EdgeSpec {
            sites,
            servers_per_site: 0,
            profile: profiles::edge_server(),
            backhaul: BackhaulLink::FREE,
            assignment: AssignmentPolicy::RoundRobin,
        }
    }

    /// Expand into the topology the planner and engine share. A
    /// zero-site spec is a contradiction (disable the tier with
    /// `SimConfig::edge = None` instead), so it is rejected loudly —
    /// mirroring [`EdgeTopology::uniform`] — rather than silently
    /// clamped to a phantom single site.
    pub fn topology(&self) -> EdgeTopology {
        assert!(self.sites > 0, "an edge tier needs at least one site (use edge: None to disable)");
        EdgeTopology {
            sites: vec![
                EdgeSite {
                    servers: self.servers_per_site,
                    profile: self.profile,
                    backhaul: self.backhaul,
                };
                self.sites
            ],
            assignment: self.assignment,
        }
    }
}

/// Planner performance layer knobs (split-plan cache + parallel
/// re-solve fan-out; see `optimizer::cache` and `rust/DESIGN.md`
/// §"Planner performance").
///
/// Invariant: none of these change decisions except `bw_bucket_ratio`,
/// which quantises the bandwidth *fed to the solver* identically in the
/// cached and uncached paths. `cache`/`parallel` are pure wall-clock
/// toggles (pinned by `tests/planner_cache.rs`).
#[derive(Clone, Debug)]
pub struct PlannerPerfConfig {
    /// Memoise split solves in a [`crate::optimizer::SplitPlanCache`].
    pub cache: bool,
    /// Fan cache-miss re-solves of a re-optimisation sweep out over a
    /// [`crate::util::pool::ThreadPool`] (requires `cache`).
    pub parallel: bool,
    /// Geometric bandwidth bucket ratio for plan keys; ≤ 1.0 plans at
    /// exact bandwidth (every distinct link is its own planner state).
    pub bw_bucket_ratio: f64,
    /// Retain the full per-decision `(device, l1)` stream in
    /// `SimReport::decisions`. Off by default: at city scale the stream
    /// grows with every spawn and re-plan for the whole run, and only
    /// the cached-vs-uncached parity tests read it
    /// (`SimReport::decision_count` is always maintained).
    pub record_decisions: bool,
}

impl Default for PlannerPerfConfig {
    /// Exact-bandwidth planning with memoisation: identical decisions to
    /// the uncached sequential path, cheaper whenever states repeat.
    fn default() -> Self {
        PlannerPerfConfig {
            cache: true,
            parallel: true,
            bw_bucket_ratio: 1.0,
            record_decisions: false,
        }
    }
}

impl PlannerPerfConfig {
    /// City-scale preset: bucket links at the same 25% granularity the
    /// drift trigger uses, so a 10k-device fleet collapses onto a handful
    /// of planner states.
    pub fn fleet_scale() -> Self {
        PlannerPerfConfig { bw_bucket_ratio: 1.25, ..Default::default() }
    }

    /// The pre-cache reference path: every decision is a fresh sequential
    /// solve (the `planner_throughput` bench baseline and the parity
    /// test's control arm).
    pub fn uncached_sequential() -> Self {
        PlannerPerfConfig { cache: false, parallel: false, ..Default::default() }
    }
}

/// Full description of one simulation run.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub model: String,
    /// Virtual horizon: no new work is issued after this time; in-flight
    /// work drains.
    pub duration_s: f64,
    pub seed: u64,
    /// Fleet-level request arrival process.
    pub arrival: Arrival,
    pub clouds: usize,
    /// Parallel servers per cloud (`c` of the M/G/c queue).
    pub cloud_servers: usize,
    pub planner: Planner,
    /// Period of the fleet-wide re-optimisation sweep; 0 disables it.
    pub reopt_period_s: f64,
    /// Relative bandwidth drift that triggers a re-plan during the sweep.
    pub drift_threshold: f64,
    /// Background battery draw per device, Watts (screen, radios, other
    /// apps). Compressed-day scenarios scale this up — see [`city_scale`].
    pub idle_drain_w: f64,
    pub fleet: FleetSpec,
    pub churn: Option<ChurnConfig>,
    /// Split-plan cache / parallel re-solve configuration.
    pub planner_perf: PlannerPerfConfig,
    /// Metro edge tier between the fleet and the cloud(s); `None` is the
    /// paper's two-tier world (every plan has an empty torso).
    pub edge: Option<EdgeSpec>,
    /// Device mobility between edge-site cells. [`Mobility::Static`]
    /// (every preset's default) schedules no events and draws no
    /// randomness — a Static run replays the corresponding immobile
    /// scenario byte-for-byte. [`Mobility::Waypoint`] requires an edge
    /// tier.
    pub mobility: Mobility,
    /// Fixed control-plane latency charged per completed handover,
    /// seconds; the in-flight torso-state relay over the old site's
    /// backhaul is added on top. Only read when `mobility` moves
    /// devices.
    pub handover_cost_s: f64,
    /// Opt-in tracing / time-series collection; disabled in every
    /// preset (enabling it must not change the run — see
    /// `tests/observability.rs`).
    pub observability: ObservabilityConfig,
    /// Scripted fault injection ([`FaultPlan`], DESIGN.md §13): site
    /// outages, backhaul brownouts, flash crowds. The default (empty)
    /// plan schedules no events and draws no randomness — a zero-fault
    /// run replays the corresponding healthy scenario byte-for-byte
    /// (`tests/fault_injection.rs`). A non-empty plan requires an edge
    /// tier.
    pub faults: FaultPlan,
    /// Event-engine shards ([`crate::sim::shard::ShardedQueue`],
    /// DESIGN.md §16): the queue is partitioned over the edge sites
    /// into this many shards and drained behind conservative-lookahead
    /// window barriers. `1` (every preset's default) is the frozen
    /// single-heap reference layout; any other count must replay it
    /// byte-for-byte (`tests/shard_parity.rs`) — the knob trades wall
    /// clock, never results.
    pub shards: usize,
}

/// The paper's two-phone testbed, matching `main.rs`'s live `fleet`
/// subcommand: a Samsung J6 at `bandwidth_mbps` and a Redmi Note 8 at 3×,
/// splits planned by full Algorithm 1. Light open-loop load, no churn, no
/// drift — the configuration `tests/sim_determinism.rs` compares against
/// the analytical fleet latency.
pub fn two_phone_fleet(
    model: &str,
    bandwidth_mbps: f64,
    nsga2: Nsga2Params,
    seed: u64,
) -> SimConfig {
    SimConfig {
        model: model.to_string(),
        duration_s: 120.0,
        seed,
        arrival: Arrival::Poisson { rps: 0.4 },
        clouds: 1,
        cloud_servers: 1,
        planner: Planner::SmartSplit(nsga2),
        reopt_period_s: 0.0,
        drift_threshold: 0.25,
        idle_drain_w: 0.0,
        fleet: FleetSpec::Explicit(vec![
            ExplicitMember {
                profile: profiles::samsung_j6(),
                bandwidth_mbps,
                initial_soc: 1.0,
            },
            ExplicitMember {
                profile: profiles::redmi_note8(),
                bandwidth_mbps: bandwidth_mbps * 3.0,
                initial_soc: 1.0,
            },
        ]),
        churn: None,
        // Live-parity configuration: exact-bandwidth planning (cache on,
        // but every decision equals the uncached solve bit-for-bit).
        planner_perf: PlannerPerfConfig::default(),
        edge: None,
        mobility: Mobility::Static,
        handover_cost_s: DEFAULT_HANDOVER_COST_S,
        observability: ObservabilityConfig::disabled(),
        faults: FaultPlan::none(),
        shards: 1,
    }
}

/// A city block of `devices` heterogeneous phones over one compressed day:
/// sinusoidal diurnal load (trough 0.02·N rps, peak 0.1·N rps), per-device
/// bandwidth wobble, battery bands engaged from a spread of initial
/// charge, and slow churn. `idle_drain_w` is scaled as if `duration_s` of
/// virtual time stood for 24 h of phone standby, so state-of-charge moves
/// visibly within the run.
pub fn city_scale(model: &str, devices: usize, duration_s: f64, seed: u64) -> SimConfig {
    let n = devices as f64;
    // ~0.2 W of real standby draw, compressed into the shortened day.
    let compression = (86_400.0 / duration_s.max(1.0)).clamp(1.0, 1000.0);
    SimConfig {
        model: model.to_string(),
        duration_s,
        seed,
        arrival: Arrival::Diurnal {
            base_rps: 0.02 * n,
            peak_rps: 0.1 * n,
            period: Duration::from_secs_f64(duration_s),
        },
        clouds: (devices / 500).max(1),
        cloud_servers: 8,
        planner: Planner::Topsis,
        reopt_period_s: duration_s / 10.0,
        drift_threshold: 0.25,
        idle_drain_w: 0.2 * compression,
        fleet: FleetSpec::Sampled {
            devices,
            profiles: vec![profiles::samsung_j6(), profiles::redmi_note8()],
            bandwidth_mbps: (2.0, 60.0),
            initial_soc: (0.15, 1.0),
            wobble_period_s: Some(duration_s / 6.0),
        },
        churn: Some(ChurnConfig {
            joins_per_s: 0.05 * n / duration_s,
            mean_lifetime_s: duration_s * 2.0,
        }),
        planner_perf: PlannerPerfConfig::fleet_scale(),
        edge: None,
        mobility: Mobility::Static,
        handover_cost_s: DEFAULT_HANDOVER_COST_S,
        observability: ObservabilityConfig::disabled(),
        faults: FaultPlan::none(),
        shards: 1,
    }
}

/// [`city_scale`] with a metro edge tier: `sites` sites of 4 edge
/// servers each behind a metro-Ethernet backhaul, devices assigned
/// round-robin. The planner solves the 2-D `(l1, l2)` genome per
/// quantised state; torso work contends at the sites while tails
/// contend in the cloud.
pub fn city_scale_tiered(
    model: &str,
    devices: usize,
    sites: usize,
    duration_s: f64,
    seed: u64,
) -> SimConfig {
    let mut cfg = city_scale(model, devices, duration_s, seed);
    cfg.edge = Some(EdgeSpec {
        sites: sites.max(1),
        servers_per_site: 4,
        profile: profiles::edge_server(),
        backhaul: BackhaulLink::METRO_1GBE,
        assignment: AssignmentPolicy::RoundRobin,
    });
    cfg
}

/// [`city_scale_tiered`] with the devices on the move: each phone runs
/// a deterministic waypoint walk over the sites' cells
/// ([`WaypointWalk::city_default`] scaled to the horizon), so the run
/// exercises edge handovers — torso-state relays over the old site's
/// backhaul — and migration re-solves through the planner façade.
/// Freezing `mobility` back to [`Mobility::Static`] makes this
/// scenario byte-identical to [`city_scale_tiered`]
/// (`tests/edge_parity.rs` pins it).
pub fn city_mobile(
    model: &str,
    devices: usize,
    sites: usize,
    duration_s: f64,
    seed: u64,
) -> SimConfig {
    let mut cfg = city_scale_tiered(model, devices, sites, duration_s, seed);
    cfg.mobility = Mobility::Waypoint(WaypointWalk::city_default(duration_s));
    cfg
}

/// [`city_scale_tiered`] under the canonical scripted fault schedule
/// ([`FaultPlan::city_faulty`]): one mid-run site outage with recovery,
/// one backhaul brownout, one flash crowd. The schedule is embedded in
/// the config (no external plan file needed), fully deterministic, and
/// draws no randomness — the `--scenario city-faulty` CLI preset and
/// `examples/edge_faulty.rs` both build on it. Replacing the plan with
/// [`FaultPlan::none`] makes this scenario byte-identical to
/// [`city_scale_tiered`].
pub fn city_faulty(
    model: &str,
    devices: usize,
    sites: usize,
    duration_s: f64,
    seed: u64,
) -> SimConfig {
    let mut cfg = city_scale_tiered(model, devices, sites, duration_s, seed);
    cfg.faults = FaultPlan::city_faulty(sites.max(1), duration_s);
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn explicit_members_cycle() {
        let spec = FleetSpec::Explicit(vec![
            ExplicitMember {
                profile: profiles::samsung_j6(),
                bandwidth_mbps: 10.0,
                initial_soc: 1.0,
            },
            ExplicitMember {
                profile: profiles::redmi_note8(),
                bandwidth_mbps: 30.0,
                initial_soc: 0.8,
            },
        ]);
        assert_eq!(spec.initial_count(), 2);
        let mut rng = Xoshiro256::seed_from_u64(1);
        let (p0, t0, s0) = spec.instantiate(0, &mut rng);
        assert_eq!(p0.name, "samsung_j6");
        assert_eq!(t0.at(Duration::ZERO), 10.0);
        assert_eq!(s0, 1.0);
        // member 2 cycles back to member 0's template.
        let (p2, _, _) = spec.instantiate(2, &mut rng);
        assert_eq!(p2.name, "samsung_j6");
    }

    #[test]
    fn sampled_members_deterministic_and_in_range() {
        let spec = FleetSpec::Sampled {
            devices: 100,
            profiles: vec![profiles::samsung_j6(), profiles::redmi_note8()],
            bandwidth_mbps: (2.0, 60.0),
            initial_soc: (0.15, 1.0),
            wobble_period_s: Some(100.0),
        };
        let mut a = Xoshiro256::seed_from_u64(9);
        let mut b = Xoshiro256::seed_from_u64(9);
        for m in 0..100 {
            let (pa, ta, sa) = spec.instantiate(m, &mut a);
            let (pb, tb, sb) = spec.instantiate(m, &mut b);
            assert_eq!(pa.name, pb.name);
            assert_eq!(sa, sb);
            let bw = ta.at(Duration::ZERO);
            assert_eq!(bw, tb.at(Duration::ZERO));
            assert!((2.0..=60.0).contains(&bw), "bw {bw}");
            assert!((0.15..=1.0).contains(&sa), "soc {sa}");
            // Wobble: the trace actually moves.
            assert_ne!(ta.at(Duration::from_secs(100)), bw);
        }
    }

    #[test]
    fn city_scale_config_is_coherent() {
        let cfg = city_scale("alexnet", 10_000, 600.0, 7);
        assert_eq!(cfg.fleet.initial_count(), 10_000);
        assert!(cfg.clouds >= 1 && cfg.cloud_servers >= 1);
        match cfg.arrival {
            Arrival::Diurnal { base_rps, peak_rps, .. } => {
                assert!(base_rps > 0.0 && peak_rps > base_rps);
            }
            other => panic!("city scale should be diurnal, got {other:?}"),
        }
        assert!(cfg.churn.is_some());
        assert!(cfg.idle_drain_w > 0.0);
        // Every preset ships on the 1-shard reference engine layout.
        assert_eq!(cfg.shards, 1);
        assert_eq!(two_phone_fleet("alexnet", 10.0, Nsga2Params::for_tiny_genome(), 7).shards, 1);
        assert_eq!(city_scale_tiered("alexnet", 100, 3, 60.0, 7).shards, 1);
        assert_eq!(city_mobile("alexnet", 100, 3, 60.0, 7).shards, 1);
        assert_eq!(city_faulty("alexnet", 100, 3, 60.0, 7).shards, 1);
        // Small fleets still get at least one cloud.
        assert_eq!(city_scale("alexnet", 10, 60.0, 7).clouds, 1);
    }

    #[test]
    fn tiered_preset_attaches_an_edge_tier() {
        let cfg = city_scale_tiered("alexnet", 1000, 3, 120.0, 7);
        let spec = cfg.edge.as_ref().expect("tiered preset must carry an edge tier");
        assert_eq!(spec.sites, 3);
        assert!(spec.servers_per_site > 0);
        let topo = spec.topology();
        assert_eq!(topo.num_sites(), 3);
        assert!(topo.sites.iter().all(|s| s.servers == spec.servers_per_site));
        // Everything else matches the flat city (same fleet, same load).
        let flat = city_scale("alexnet", 1000, 120.0, 7);
        assert_eq!(cfg.fleet.initial_count(), flat.fleet.initial_count());
        assert_eq!(cfg.clouds, flat.clouds);
        // The degenerate relay spec really is degenerate.
        let relay = EdgeSpec::degenerate_relay(3);
        assert_eq!(relay.servers_per_site, 0);
        assert!(relay.backhaul.is_free());
        assert_eq!(relay.topology().num_sites(), 3);
    }

    #[test]
    fn mobile_preset_only_differs_by_mobility() {
        let mobile = city_mobile("alexnet", 1000, 3, 120.0, 7);
        assert!(mobile.mobility.is_mobile(), "city_mobile must move devices");
        assert!(mobile.handover_cost_s >= 0.0 && mobile.handover_cost_s.is_finite());
        // Everything except the mobility model matches the tiered city —
        // the byte-for-byte Static replay in tests/edge_parity.rs
        // depends on this.
        let tiered = city_scale_tiered("alexnet", 1000, 3, 120.0, 7);
        assert!(!tiered.mobility.is_mobile());
        assert_eq!(mobile.handover_cost_s, tiered.handover_cost_s);
        assert_eq!(mobile.fleet.initial_count(), tiered.fleet.initial_count());
        assert_eq!(mobile.clouds, tiered.clouds);
        assert_eq!(mobile.edge.as_ref().unwrap().sites, tiered.edge.as_ref().unwrap().sites);
        assert_eq!(mobile.reopt_period_s, tiered.reopt_period_s);
        assert_eq!(mobile.idle_drain_w, tiered.idle_drain_w);
        // Observability ships disabled everywhere.
        assert_eq!(mobile.observability, ObservabilityConfig::disabled());
        assert_eq!(tiered.observability, ObservabilityConfig::default());
        assert_eq!(mobile.observability.trace_sample_every, 0);
        assert_eq!(ObservabilityConfig::full(10.0).trace_sample_every, 1);
        // The walk parameters scale with the horizon.
        match mobile.mobility {
            Mobility::Waypoint(w) => {
                assert!(w.pause_mean_s > 0.0);
                assert!(w.cell_crossing_s.0 > 0.0 && w.cell_crossing_s.1 >= w.cell_crossing_s.0);
                assert!(w.pause_mean_s < 120.0, "a device should move within the run");
            }
            Mobility::Static => unreachable!(),
        }
    }

    #[test]
    fn faulty_preset_only_differs_by_fault_plan() {
        let faulty = city_faulty("alexnet", 1000, 3, 120.0, 7);
        assert!(!faulty.faults.is_empty(), "city_faulty must script faults");
        faulty.faults.validate(3).expect("embedded schedule must be valid for its own tier");
        // Everything except the fault plan matches the tiered city —
        // the zero-fault byte-for-byte replay in
        // tests/fault_injection.rs depends on this.
        let tiered = city_scale_tiered("alexnet", 1000, 3, 120.0, 7);
        assert!(tiered.faults.is_empty());
        assert_eq!(faulty.fleet.initial_count(), tiered.fleet.initial_count());
        assert_eq!(faulty.clouds, tiered.clouds);
        assert_eq!(faulty.edge.as_ref().unwrap().sites, tiered.edge.as_ref().unwrap().sites);
        assert_eq!(faulty.reopt_period_s, tiered.reopt_period_s);
        assert_eq!(faulty.handover_cost_s, tiered.handover_cost_s);
        assert!(!faulty.mobility.is_mobile());
        let mut defaulted = faulty.clone();
        defaulted.faults = FaultPlan::none();
        assert_eq!(defaulted.faults, tiered.faults);
    }

    #[test]
    fn planner_perf_presets() {
        // City scale buckets links at the drift granularity; the default
        // (and two-phone live-parity) configuration plans at exact
        // bandwidth so memoisation cannot change decisions.
        let city = city_scale("alexnet", 100, 60.0, 7);
        assert!(city.planner_perf.cache && city.planner_perf.parallel);
        assert!((city.planner_perf.bw_bucket_ratio - 1.25).abs() < 1e-12);
        let two = two_phone_fleet("alexnet", 10.0, Nsga2Params::for_tiny_genome(), 7);
        assert!(two.planner_perf.cache);
        assert!(two.planner_perf.bw_bucket_ratio <= 1.0);
        let base = PlannerPerfConfig::uncached_sequential();
        assert!(!base.cache && !base.parallel);
        // The full decision trace is test-only opt-in everywhere.
        assert!(!city.planner_perf.record_decisions);
        assert!(!two.planner_perf.record_decisions);
        assert!(!base.record_decisions);
    }
}
