//! Virtual edge site: an M/G/c queue mirroring [`crate::sim::SimCloud`],
//! whose service time is the torso latency
//! ([`crate::edge::TieredPerfModel::torso_latency_s`]) of the requesting
//! device's plan, captured at issue time (a re-split mid-flight must not
//! retroactively change in-flight work).
//!
//! Unlike the cloud queue, a dequeued edge request still has two hops
//! left — the backhaul transfer and the cloud tail — so the queue
//! carries those captured costs alongside each request.

use std::collections::VecDeque;

use crate::metrics::Histogram;
use crate::sim::engine::SimTime;

/// One queued torso request.
#[derive(Clone, Copy, Debug)]
struct Queued {
    req: u64,
    device: usize,
    issued: SimTime,
    enqueued: SimTime,
    service_s: f64,
    backhaul_s: f64,
    tail_s: f64,
}

/// A torso request popped off the queue when an edge server frees up.
#[derive(Clone, Copy, Debug)]
pub struct EdgeDequeued {
    pub req: u64,
    pub device: usize,
    pub issued: SimTime,
    pub service_s: f64,
    pub backhaul_s: f64,
    pub tail_s: f64,
    /// Time this request spent queued (`now - enqueued`), surfaced so
    /// the caller can feed the windowed time series and close the
    /// request's `edge_queue` trace span without re-deriving it.
    pub waited_s: f64,
}

/// A virtual edge-site server pool.
#[derive(Debug)]
pub struct SimEdge {
    /// Parallel torso servers (`c` in M/G/c). `0` marks a relay-only
    /// site: the planner never produces torso work for it, and offering
    /// work to it is a logic error.
    pub servers: usize,
    busy: usize,
    queue: VecDeque<Queued>,
    /// Time torso requests spent waiting for a free edge server.
    pub queue_delay: Histogram,
    pub served: u64,
    busy_time_s: f64,
    peak_queue: usize,
}

impl SimEdge {
    pub fn new(servers: usize) -> SimEdge {
        SimEdge {
            servers,
            busy: 0,
            queue: VecDeque::new(),
            queue_delay: Histogram::new(),
            served: 0,
            busy_time_s: 0.0,
            peak_queue: 0,
        }
    }

    /// A torso request arrives. Returns `Some(service_s)` if a server is
    /// free (caller schedules `EdgeDone` at `now + service_s`); otherwise
    /// the request queues FIFO.
    #[allow(clippy::too_many_arguments)]
    pub fn offer(
        &mut self,
        req: u64,
        device: usize,
        issued: SimTime,
        now: SimTime,
        service_s: f64,
        backhaul_s: f64,
        tail_s: f64,
    ) -> Option<f64> {
        assert!(self.servers > 0, "torso work offered to a relay-only edge site");
        if self.busy < self.servers {
            self.busy += 1;
            self.busy_time_s += service_s;
            self.queue_delay.record_secs(0.0);
            Some(service_s)
        } else {
            self.queue.push_back(Queued {
                req,
                device,
                issued,
                enqueued: now,
                service_s,
                backhaul_s,
                tail_s,
            });
            self.peak_queue = self.peak_queue.max(self.queue.len());
            None
        }
    }

    /// An edge server finished. Pops the next queued torso, if any — the
    /// caller schedules its `EdgeDone` at `now + service_s`.
    pub fn finish(&mut self, now: SimTime) -> Option<EdgeDequeued> {
        self.served += 1;
        match self.queue.pop_front() {
            Some(q) => {
                self.queue_delay.record_secs(now - q.enqueued);
                self.busy_time_s += q.service_s;
                Some(EdgeDequeued {
                    req: q.req,
                    device: q.device,
                    issued: q.issued,
                    service_s: q.service_s,
                    backhaul_s: q.backhaul_s,
                    tail_s: q.tail_s,
                    waited_s: now - q.enqueued,
                })
            }
            None => {
                self.busy -= 1;
                None
            }
        }
    }

    /// Evacuate the whole waiting queue — a site outage
    /// ([`crate::sim::faults`]). Every queued torso request is popped
    /// (recording its queue delay up to `now`) and handed back so the
    /// caller can relay it onward to the cloud; requests must never be
    /// silently lost with the site. In-service work is untouched: those
    /// requests already committed their service time and their
    /// `EdgeDone` events complete normally, so `busy`, `served`, and
    /// `busy_time_s` are deliberately not modified here.
    pub fn drain(&mut self, now: SimTime) -> Vec<EdgeDequeued> {
        let mut out = Vec::with_capacity(self.queue.len());
        while let Some(q) = self.queue.pop_front() {
            self.queue_delay.record_secs(now - q.enqueued);
            out.push(EdgeDequeued {
                req: q.req,
                device: q.device,
                issued: q.issued,
                service_s: q.service_s,
                backhaul_s: q.backhaul_s,
                tail_s: q.tail_s,
                waited_s: now - q.enqueued,
            });
        }
        out
    }

    pub fn busy(&self) -> usize {
        self.busy
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn peak_queue(&self) -> usize {
        self.peak_queue
    }

    /// Cumulative committed service time, in seconds (same role as
    /// [`crate::sim::SimCloud::busy_time_s`]).
    pub fn busy_time_s(&self) -> f64 {
        self.busy_time_s
    }

    /// Offered utilisation — same convention as
    /// [`crate::sim::SimCloud::utilization`] (deliberately unclamped).
    /// Relay-only sites report 0.
    pub fn utilization(&self, horizon_s: f64) -> f64 {
        if horizon_s <= 0.0 || self.servers == 0 {
            return 0.0;
        }
        self.busy_time_s / (horizon_s * self.servers as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serves_immediately_when_free() {
        let mut e = SimEdge::new(2);
        assert_eq!(e.offer(10, 0, 0.0, 0.0, 0.5, 0.1, 0.2), Some(0.5));
        assert_eq!(e.offer(11, 1, 0.0, 0.0, 0.5, 0.1, 0.2), Some(0.5));
        assert_eq!(e.busy(), 2);
        assert_eq!(e.offer(12, 2, 0.1, 0.1, 0.5, 0.1, 0.2), None);
        assert_eq!(e.queue_len(), 1);
    }

    #[test]
    fn finish_dequeues_fifo_with_captured_hop_costs() {
        let mut e = SimEdge::new(1);
        assert!(e.offer(10, 0, 0.0, 0.0, 1.0, 0.01, 0.3).is_some());
        assert!(e.offer(11, 1, 0.2, 0.2, 0.7, 0.02, 0.4).is_none());
        let d = e.finish(1.0).unwrap();
        assert_eq!(d.req, 11);
        assert_eq!(d.device, 1);
        assert_eq!(d.issued, 0.2);
        assert_eq!(d.service_s, 0.7);
        // The downstream hop costs ride through the queue untouched.
        assert_eq!(d.backhaul_s, 0.02);
        assert_eq!(d.tail_s, 0.4);
        assert!((d.waited_s - 0.8).abs() < 1e-12);
        assert!((e.queue_delay.max_s() - 0.8).abs() < 1e-12);
        assert!(e.finish(1.7).is_none());
        assert_eq!(e.busy(), 0);
        assert_eq!(e.served, 2);
    }

    #[test]
    fn utilization_mirrors_cloud_convention() {
        let mut e = SimEdge::new(2);
        e.offer(0, 0, 0.0, 0.0, 3.0, 0.0, 0.0);
        e.offer(1, 1, 0.0, 0.0, 1.0, 0.0, 0.0);
        e.finish(1.0);
        e.finish(3.0);
        assert!((e.utilization(4.0) - 0.5).abs() < 1e-12);
        assert!((e.busy_time_s() - 4.0).abs() < 1e-12);
        assert_eq!(e.utilization(0.0), 0.0);
        assert_eq!(SimEdge::new(0).utilization(10.0), 0.0);
    }

    #[test]
    fn drain_evacuates_the_queue_without_touching_service_state() {
        let mut e = SimEdge::new(1);
        assert!(e.offer(10, 0, 0.0, 0.0, 1.0, 0.01, 0.3).is_some());
        assert!(e.offer(11, 1, 0.2, 0.2, 0.7, 0.02, 0.4).is_none());
        assert!(e.offer(12, 2, 0.3, 0.3, 0.9, 0.03, 0.5).is_none());
        let drained = e.drain(1.0);
        assert_eq!(drained.len(), 2);
        assert_eq!((drained[0].req, drained[1].req), (11, 12), "drain must be FIFO");
        assert!((drained[0].waited_s - 0.8).abs() < 1e-12);
        assert!((drained[1].waited_s - 0.7).abs() < 1e-12);
        assert_eq!(drained[0].tail_s, 0.4);
        assert_eq!(e.queue_len(), 0);
        // The in-service request is untouched by the drain...
        assert_eq!(e.busy(), 1);
        assert_eq!(e.served, 0);
        assert!((e.busy_time_s() - 1.0).abs() < 1e-12);
        // ... and completes normally, freeing the server.
        assert!(e.finish(1.0).is_none());
        assert_eq!(e.busy(), 0);
        assert_eq!(e.served, 1);
        assert!(e.drain(2.0).is_empty());
    }

    #[test]
    #[should_panic(expected = "relay-only")]
    fn relay_site_rejects_torso_work() {
        let mut e = SimEdge::new(0);
        e.offer(0, 0, 0.0, 0.0, 1.0, 0.0, 0.0);
    }
}
