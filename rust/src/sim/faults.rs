//! Deterministic fault injection: scripted site outages, backhaul
//! brownouts, and flash crowds on the simulator's virtual clock.
//!
//! The topology PRs 3–5 built is immortal — no edge site ever dies, no
//! backhaul ever degrades, and load only varies sinusoidally. A
//! [`FaultPlan`] breaks that: an ordered list of [`FaultEvent`]s, each
//! a virtual-time instant plus a [`FaultKind`], that the engine turns
//! into ordinary events on its queue (`SiteDown`/`SiteUp`/
//! `BackhaulDegrade`/`BackhaulRestore`/`FlashCrowdStart`/
//! `FlashCrowdEnd` in [`crate::sim::engine`]).
//!
//! # Scenario families
//!
//! * **Site outage** (`site-down` … `site-up`): every device attached
//!   to the dead site is re-attached to the nearest live site through
//!   the existing epoch-guarded `Reattach` path — a handover storm —
//!   and queued torso work is relayed onward to the cloud, never
//!   silently lost. Recovery re-balances devices whose natural
//!   assignment is the recovered site.
//! * **Backhaul brownout** (`backhaul-degrade` … `backhaul-restore`):
//!   the site's [`crate::edge::BackhaulLink`] bandwidth is scaled by a
//!   scripted factor for a window, forcing failover re-plans under the
//!   degraded [`crate::planner::TierContext`].
//! * **Flash crowd** (`flash-crowd`): arrivals are boosted and biased
//!   toward one site's cell for a window — the stadium scenario.
//!
//! # Determinism contract
//!
//! An **empty plan is inert**: it schedules no events and draws no
//! randomness, so a zero-fault run replays the corresponding
//! fault-free scenario byte-for-byte (`tests/fault_injection.rs` pins
//! `city_scale_tiered` and `city_mobile`; the same discipline as
//! [`crate::sim::Mobility::Static`]). [`FaultPlan::random`] draws its
//! schedule from a private seeded stream *at construction*, so runtime
//! behaviour stays a pure function of the finished plan. Conservation
//! is a property: across any schedule, every issued request completes
//! or is dropped exactly once.

use crate::util::rng::{SplitMix64, Xoshiro256};

/// One kind of injected fault. Sites are indices into the run's
/// [`crate::edge::EdgeTopology`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// Take a site out: storm-reattach its devices, relay its queue.
    SiteDown { site: usize },
    /// Bring a site back and re-balance natural attachments onto it.
    SiteUp { site: usize },
    /// Scale the site's backhaul bandwidth by `factor` (in `(0, 1]`).
    BackhaulDegrade { site: usize, factor: f64 },
    /// Restore the site's backhaul to its configured bandwidth.
    BackhaulRestore { site: usize },
    /// For `duration_s`, multiply the arrival rate by `boost` (≥ 1)
    /// and bias new work toward devices attached to `site`.
    FlashCrowd { site: usize, duration_s: f64, boost: f64 },
}

impl FaultKind {
    /// Every parseable kind name, in declaration order — the
    /// valid-name list unknown-kind errors print (the same error shape
    /// as `planner::Strategy::by_name`).
    pub const NAMES: [&'static str; 5] = [
        "site-down",
        "site-up",
        "backhaul-degrade",
        "backhaul-restore",
        "flash-crowd",
    ];

    /// The plan-file keyword for this kind.
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::SiteDown { .. } => "site-down",
            FaultKind::SiteUp { .. } => "site-up",
            FaultKind::BackhaulDegrade { .. } => "backhaul-degrade",
            FaultKind::BackhaulRestore { .. } => "backhaul-restore",
            FaultKind::FlashCrowd { .. } => "flash-crowd",
        }
    }

    /// The site this fault targets.
    pub fn site(&self) -> usize {
        match *self {
            FaultKind::SiteDown { site }
            | FaultKind::SiteUp { site }
            | FaultKind::BackhaulDegrade { site, .. }
            | FaultKind::BackhaulRestore { site }
            | FaultKind::FlashCrowd { site, .. } => site,
        }
    }
}

/// One scheduled fault: `kind` fires at virtual time `at_s`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultEvent {
    pub at_s: f64,
    pub kind: FaultKind,
}

/// A scripted fault schedule. The default (empty) plan is inert — see
/// the module docs for the zero-fault parity contract.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// Events in schedule order. Ties on `at_s` fire in list order
    /// (the engine's queue is FIFO among equal timestamps).
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// No faults at all — the inert plan.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// Does this plan inject nothing?
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Check every event against the run it will drive: site indices
    /// in `0..num_sites`, finite non-negative times, degrade factors
    /// in `(0, 1]`, crowd boosts ≥ 1 over positive windows.
    pub fn validate(&self, num_sites: usize) -> Result<(), String> {
        for (i, e) in self.events.iter().enumerate() {
            let at = e.at_s;
            if !at.is_finite() || at < 0.0 {
                return Err(format!("fault {} ({}): bad time {at}", i, e.kind.name()));
            }
            let site = e.kind.site();
            if site >= num_sites {
                return Err(format!(
                    "fault {} ({}): site {site} out of range (topology has {num_sites} site(s))",
                    i,
                    e.kind.name()
                ));
            }
            match e.kind {
                FaultKind::BackhaulDegrade { factor, .. } => {
                    if !(factor > 0.0 && factor <= 1.0) || !factor.is_finite() {
                        return Err(format!(
                            "fault {i} (backhaul-degrade): factor {factor} not in (0, 1]"
                        ));
                    }
                }
                FaultKind::FlashCrowd { duration_s, boost, .. } => {
                    if !(duration_s > 0.0) || !duration_s.is_finite() {
                        return Err(format!(
                            "fault {i} (flash-crowd): bad duration {duration_s}"
                        ));
                    }
                    if !(boost >= 1.0) || !boost.is_finite() {
                        return Err(format!("fault {i} (flash-crowd): boost {boost} < 1"));
                    }
                }
                _ => {}
            }
        }
        Ok(())
    }

    /// Parse a plan file. One event per line:
    ///
    /// ```text
    /// # <at_s> <kind> <site> [args]
    /// 30   site-down        1
    /// 45   backhaul-degrade 0  0.25     # factor in (0, 1]
    /// 60   site-up          1
    /// 75   backhaul-restore 0
    /// 90   flash-crowd      2  30  4    # duration_s, boost
    /// ```
    ///
    /// Blank lines and `#` comments (whole-line or trailing) are
    /// ignored. Unknown kinds are rejected with the valid-name list —
    /// never a panic.
    pub fn parse(text: &str) -> Result<FaultPlan, String> {
        let mut events = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let n = lineno + 1;
            let mut parts = line.split_whitespace();
            let at_s: f64 = parts
                .next()
                .unwrap()
                .parse()
                .map_err(|_| format!("line {n}: bad time in {line:?}"))?;
            let kind_name = parts.next().ok_or_else(|| format!("line {n}: missing fault kind"))?;
            let mut arg = |what: &str| -> Result<f64, String> {
                parts
                    .next()
                    .ok_or_else(|| format!("line {n} ({kind_name}): missing {what}"))?
                    .parse::<f64>()
                    .map_err(|_| format!("line {n} ({kind_name}): bad {what}"))
            };
            let site = arg("site index")? as usize;
            let kind = match kind_name {
                "site-down" => FaultKind::SiteDown { site },
                "site-up" => FaultKind::SiteUp { site },
                "backhaul-degrade" => {
                    FaultKind::BackhaulDegrade { site, factor: arg("degrade factor")? }
                }
                "backhaul-restore" => FaultKind::BackhaulRestore { site },
                "flash-crowd" => FaultKind::FlashCrowd {
                    site,
                    duration_s: arg("crowd duration")?,
                    boost: arg("arrival boost")?,
                },
                other => {
                    return Err(format!(
                        "line {n}: unknown fault kind {other:?} (valid: {})",
                        FaultKind::NAMES.join(", ")
                    ))
                }
            };
            if let Some(extra) = parts.next() {
                return Err(format!("line {n} ({kind_name}): unexpected trailing {extra:?}"));
            }
            events.push(FaultEvent { at_s, kind });
        }
        Ok(FaultPlan { events })
    }

    /// Render this plan in the format [`FaultPlan::parse`] reads
    /// (round-trips exactly for finite values).
    pub fn to_text(&self) -> String {
        let mut s = String::from("# <at_s> <kind> <site> [args]\n");
        for e in &self.events {
            match e.kind {
                FaultKind::SiteDown { site } => {
                    s.push_str(&format!("{} site-down {}\n", e.at_s, site))
                }
                FaultKind::SiteUp { site } => s.push_str(&format!("{} site-up {}\n", e.at_s, site)),
                FaultKind::BackhaulDegrade { site, factor } => {
                    s.push_str(&format!("{} backhaul-degrade {} {}\n", e.at_s, site, factor))
                }
                FaultKind::BackhaulRestore { site } => {
                    s.push_str(&format!("{} backhaul-restore {}\n", e.at_s, site))
                }
                FaultKind::FlashCrowd { site, duration_s, boost } => s.push_str(&format!(
                    "{} flash-crowd {} {} {}\n",
                    e.at_s, site, duration_s, boost
                )),
            }
        }
        s
    }

    /// The scripted city-faulty schedule the `--scenario city-faulty`
    /// preset and `examples/edge_faulty.rs` run: one mid-run outage of
    /// site 1 (down at 25 % of the horizon, back at 55 %), one brownout
    /// of site 0 (35 %–65 %, backhaul at a quarter bandwidth), and one
    /// flash crowd pinned to the last site (50 %, lasting 20 % of the
    /// horizon at 4× arrivals). Purely scripted — no randomness.
    pub fn city_faulty(sites: usize, duration_s: f64) -> FaultPlan {
        let d = duration_s.max(1.0);
        let mut events = vec![
            FaultEvent { at_s: 0.25 * d, kind: FaultKind::SiteDown { site: 1 % sites.max(1) } },
            FaultEvent { at_s: 0.55 * d, kind: FaultKind::SiteUp { site: 1 % sites.max(1) } },
            FaultEvent {
                at_s: 0.35 * d,
                kind: FaultKind::BackhaulDegrade { site: 0, factor: 0.25 },
            },
            FaultEvent { at_s: 0.65 * d, kind: FaultKind::BackhaulRestore { site: 0 } },
        ];
        if sites > 0 {
            events.push(FaultEvent {
                at_s: 0.5 * d,
                kind: FaultKind::FlashCrowd { site: sites - 1, duration_s: 0.2 * d, boost: 4.0 },
            });
        }
        events.sort_by(|a, b| a.at_s.total_cmp(&b.at_s));
        FaultPlan { events }
    }

    /// A randomized-but-reproducible schedule for property tests: the
    /// whole schedule is drawn here, from a stream derived from `seed`
    /// alone, so two calls with equal arguments build equal plans and
    /// the run itself stays deterministic. Always valid for a
    /// `sites`-site topology (site 0 is never taken down, so the fleet
    /// always has somewhere to land).
    pub fn random(seed: u64, sites: usize, duration_s: f64) -> FaultPlan {
        let mut rng = Xoshiro256::seed_from_u64(
            SplitMix64::new(seed ^ 0xFA_017_FA_017).next_u64(),
        );
        let d = duration_s.max(1.0);
        let mut events = Vec::new();
        if sites > 1 {
            for _ in 0..(1 + rng.gen_range(0, 1)) {
                let site = rng.gen_range(1, sites - 1);
                let down = d * (0.1 + 0.5 * rng.next_f64());
                let up = down + d * (0.05 + 0.25 * rng.next_f64());
                events.push(FaultEvent { at_s: down, kind: FaultKind::SiteDown { site } });
                events.push(FaultEvent { at_s: up, kind: FaultKind::SiteUp { site } });
            }
        }
        if sites > 0 {
            let site = rng.gen_range(0, sites - 1);
            let start = d * (0.1 + 0.5 * rng.next_f64());
            let factor = 0.1 + 0.6 * rng.next_f64();
            events.push(FaultEvent {
                at_s: start,
                kind: FaultKind::BackhaulDegrade { site, factor },
            });
            events.push(FaultEvent {
                at_s: start + d * (0.1 + 0.2 * rng.next_f64()),
                kind: FaultKind::BackhaulRestore { site },
            });
            let crowd_site = rng.gen_range(0, sites - 1);
            events.push(FaultEvent {
                at_s: d * (0.2 + 0.5 * rng.next_f64()),
                kind: FaultKind::FlashCrowd {
                    site: crowd_site,
                    duration_s: d * (0.05 + 0.2 * rng.next_f64()),
                    boost: 2.0 + 4.0 * rng.next_f64(),
                },
            });
        }
        events.sort_by(|a, b| a.at_s.total_cmp(&b.at_s));
        FaultPlan { events }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_plan_is_inert() {
        assert!(FaultPlan::default().is_empty());
        assert!(FaultPlan::none().is_empty());
        assert_eq!(FaultPlan::default(), FaultPlan::none());
        assert!(FaultPlan::default().validate(0).is_ok());
    }

    #[test]
    fn parse_round_trips_through_to_text() {
        let text = "\
# a comment
30 site-down 1
45 backhaul-degrade 0 0.25
60 site-up 1        # trailing comment
75 backhaul-restore 0

90 flash-crowd 2 30 4
";
        let plan = FaultPlan::parse(text).expect("parse");
        assert_eq!(plan.events.len(), 5);
        assert_eq!(plan.events[0].kind, FaultKind::SiteDown { site: 1 });
        assert_eq!(plan.events[4].kind, FaultKind::FlashCrowd {
            site: 2,
            duration_s: 30.0,
            boost: 4.0
        });
        assert!(plan.validate(3).is_ok());
        let reparsed = FaultPlan::parse(&plan.to_text()).expect("reparse");
        assert_eq!(plan, reparsed);
    }

    #[test]
    fn unknown_kind_lists_valid_names() {
        let err = FaultPlan::parse("10 meteor-strike 0").unwrap_err();
        assert!(err.contains("unknown fault kind"), "{err}");
        for name in FaultKind::NAMES {
            assert!(err.contains(name), "error {err:?} does not list {name}");
        }
    }

    #[test]
    fn parse_rejects_malformed_lines() {
        assert!(FaultPlan::parse("ten site-down 0").is_err());
        assert!(FaultPlan::parse("10 site-down").is_err());
        assert!(FaultPlan::parse("10 backhaul-degrade 0").is_err());
        assert!(FaultPlan::parse("10 site-down 0 extra").is_err());
    }

    #[test]
    fn validate_rejects_out_of_range_and_bad_args() {
        let plan = FaultPlan::parse("10 site-down 5").unwrap();
        assert!(plan.validate(3).unwrap_err().contains("out of range"));
        let plan = FaultPlan::parse("10 backhaul-degrade 0 1.5").unwrap();
        assert!(plan.validate(3).is_err());
        let plan = FaultPlan::parse("10 flash-crowd 0 30 0.5").unwrap();
        assert!(plan.validate(3).is_err());
        let plan = FaultPlan::parse("-5 site-down 0").unwrap();
        assert!(plan.validate(3).is_err());
    }

    #[test]
    fn city_faulty_is_scripted_valid_and_ordered() {
        for sites in [2, 3, 8] {
            let plan = FaultPlan::city_faulty(sites, 600.0);
            assert!(!plan.is_empty());
            assert!(plan.validate(sites).is_ok(), "sites={sites}");
            for w in plan.events.windows(2) {
                assert!(w[0].at_s <= w[1].at_s, "unordered schedule");
            }
            assert_eq!(plan, FaultPlan::city_faulty(sites, 600.0));
        }
    }

    #[test]
    fn random_plans_are_reproducible_and_valid() {
        for seed in 0..20u64 {
            let a = FaultPlan::random(seed, 4, 300.0);
            let b = FaultPlan::random(seed, 4, 300.0);
            assert_eq!(a, b, "seed {seed} not reproducible");
            assert!(a.validate(4).is_ok(), "seed {seed}: {:?}", a.validate(4));
            assert!(!a.is_empty());
            // Site 0 is the guaranteed survivor.
            assert!(a
                .events
                .iter()
                .all(|e| !matches!(e.kind, FaultKind::SiteDown { site: 0 })));
        }
        assert_ne!(
            FaultPlan::random(1, 4, 300.0),
            FaultPlan::random(2, 4, 300.0),
            "seeds do not differentiate schedules"
        );
    }
}
