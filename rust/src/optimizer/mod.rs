//! The SmartSplit optimisation algorithm (paper §V, Algorithm 1):
//! NSGA-II over split indices → Pareto set → TOPSIS → one split decision;
//! plus the §VI-C competing algorithms (LBO/EBO/COS/COC/RS).
//!
//! These are the planning *primitives*. The supported way to ask for a
//! split decision is [`crate::planner`]'s `PlanRequest → PlanOutcome`
//! façade; the deprecated dispatch entry points re-exported here
//! (`decide`, `solve_plan`, `solve_plan_tiered`) are frozen parity
//! references for `tests/planner_parity.rs`.

pub mod baselines;
pub mod cache;
pub mod nsga2;
pub mod problem;
pub mod scalarization;
pub mod topsis;

pub use baselines::{
    coc, cos, ebo, lbo, rs, smartsplit, Algorithm, SmartSplitResult, SplitDecision,
};
#[allow(deprecated)]
pub use baselines::decide;
pub use cache::{
    member_perf_model, model_cache_id, quantize_bandwidth, smartsplit_banded, PlanKey,
    PlannerKind, SplitPlanCache, TierKey,
};
#[allow(deprecated)]
pub use cache::{solve_plan, solve_plan_tiered};
pub use nsga2::{optimize, Nsga2Params, Nsga2Solver, ParetoSet, Problem};
pub use problem::SplitProblem;
pub use scalarization::{
    epsilon_constrained, exhaustive_pareto_front, weighted_metric, weighted_sum,
};
pub use topsis::{topsis, TopsisResult};
