//! The SmartSplit optimisation algorithm (paper §V, Algorithm 1):
//! NSGA-II over split indices → Pareto set → TOPSIS → one split decision;
//! plus the §VI-C competing algorithms (LBO/EBO/COS/COC/RS).

pub mod baselines;
pub mod cache;
pub mod nsga2;
pub mod problem;
pub mod scalarization;
pub mod topsis;

pub use baselines::{
    coc, cos, decide, ebo, lbo, rs, smartsplit, Algorithm, SmartSplitResult, SplitDecision,
};
pub use cache::{
    member_perf_model, model_cache_id, quantize_bandwidth, smartsplit_banded, solve_plan,
    solve_plan_tiered, PlanKey, PlannerKind, SplitPlanCache, TierKey,
};
pub use nsga2::{optimize, Nsga2Params, Nsga2Solver, ParetoSet, Problem};
pub use problem::SplitProblem;
pub use scalarization::{
    epsilon_constrained, exhaustive_pareto_front, weighted_metric, weighted_sum,
};
pub use topsis::{topsis, TopsisResult};
