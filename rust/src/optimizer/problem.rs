//! The SmartSplit optimisation problem (§IV): genome `[l1]`, objectives
//! `(f1, f2, f3)` from the perf model, Eq. 17 constraints as violations.
//!
//! §Perf note: the split-index space is tiny (≤ 38 candidates), so all
//! objective vectors are memoised up front — NSGA-II's 25k evaluations then
//! cost one table lookup each instead of re-walking the layer profile
//! (the L3 objective-memoisation optimisation recorded in EXPERIMENTS.md).

use crate::perfmodel::PerfModel;

use super::nsga2::{Genome, Problem};

/// NSGA-II view of one (model, device, network) configuration.
pub struct SplitProblem {
    num_layers: usize,
    /// Memoised `[f1, f2, f3]` for l1 = 1..=L (index l1-1).
    objectives: Vec<[f64; 3]>,
    /// Memoised Eq. 17 violation magnitude for l1 = 1..=L.
    violations: Vec<f64>,
}

impl SplitProblem {
    pub fn new(pm: &PerfModel<'_>) -> Self {
        let l = pm.profile.num_layers;
        let mut objectives = Vec::with_capacity(l);
        let mut violations = Vec::with_capacity(l);
        for l1 in 1..=l {
            objectives.push(pm.objectives(l1));
            violations.push(Self::violation_of(pm, l1));
        }
        SplitProblem { num_layers: l, objectives, violations }
    }

    fn violation_of(pm: &PerfModel<'_>, l1: usize) -> f64 {
        let mut v = 0.0;
        let l = pm.profile.num_layers;
        // l1 + l2 = L with l1, l2 ≥ 1  ⇒  1 ≤ l1 ≤ L-1 (bounds handle the
        // lower end; the upper end must be a soft violation so COS-like
        // genomes are comparable during evolution).
        if l1 + 1 > l {
            v += 1.0;
        }
        let mem = pm.profile.client_memory_bytes(l1);
        let cap = pm.client.memory_bytes;
        if mem > cap {
            v += (mem - cap) as f64 / cap as f64;
        }
        if !pm.net.satisfies_constraints() {
            v += 1.0;
        }
        v
    }

    pub fn num_layers(&self) -> usize {
        self.num_layers
    }

    /// Memoised objective lookup for a concrete split index.
    pub fn objectives_at(&self, l1: usize) -> [f64; 3] {
        self.objectives[l1 - 1]
    }

    pub fn feasible_at(&self, l1: usize) -> bool {
        self.violations[l1 - 1] == 0.0
    }
}

impl Problem for SplitProblem {
    fn bounds(&self) -> Vec<(i64, i64)> {
        vec![(1, self.num_layers as i64)]
    }

    fn objectives(&self, g: &Genome) -> Vec<f64> {
        self.objectives[(g[0] - 1) as usize].to_vec()
    }

    fn violation(&self, g: &Genome) -> f64 {
        self.violations[(g[0] - 1) as usize]
    }

    fn num_objectives(&self) -> usize {
        3
    }

    /// Zero-alloc hot path: one memo-table row copy per evaluation.
    fn objectives_into(&self, g: &[i64], out: &mut [f64]) {
        out.copy_from_slice(&self.objectives[(g[0] - 1) as usize]);
    }

    fn violation_of(&self, g: &[i64]) -> f64 {
        self.violations[(g[0] - 1) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::models::zoo;
    use crate::perfmodel::{NetworkEnv, PerfModel, RadioPower};

    fn problem() -> SplitProblem {
        let profile = zoo::alexnet().analyze(1);
        let pm = PerfModel::new(
            profiles::samsung_j6(),
            profiles::cloud_server(),
            RadioPower::PAPER_80211N,
            NetworkEnv::paper_default(),
            &profile,
        );
        SplitProblem::new(&pm)
    }

    #[test]
    fn memoisation_matches_direct_evaluation() {
        let profile = zoo::alexnet().analyze(1);
        let pm = PerfModel::new(
            profiles::samsung_j6(),
            profiles::cloud_server(),
            RadioPower::PAPER_80211N,
            NetworkEnv::paper_default(),
            &profile,
        );
        let p = SplitProblem::new(&pm);
        for l1 in 1..=21 {
            assert_eq!(p.objectives_at(l1), pm.objectives(l1));
        }
    }

    #[test]
    fn fast_paths_match_trait_defaults() {
        let p = problem();
        for l1 in 1..=21i64 {
            let g = vec![l1];
            let mut out = [0.0; 3];
            p.objectives_into(&g, &mut out);
            assert_eq!(out.to_vec(), p.objectives(&g));
            assert_eq!(p.violation_of(&g), p.violation(&g));
        }
    }

    #[test]
    fn bounds_span_split_domain() {
        let p = problem();
        assert_eq!(p.bounds(), vec![(1, 21)]);
    }

    #[test]
    fn last_layer_split_is_infeasible() {
        // l1 = L leaves l2 = 0 which violates Eq. 17.
        let p = problem();
        assert!(!p.feasible_at(21));
        assert!(p.feasible_at(20));
        assert!(p.feasible_at(1));
    }
}
