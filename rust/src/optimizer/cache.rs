//! Sharded split-plan cache — the fleet planner's memoisation layer.
//!
//! A city-scale fleet re-solves Algorithm 1 continuously, but the inputs
//! that actually change the answer collapse onto a tiny lattice: the model
//! being split, the device compute profile, the battery band (three
//! values), and the link bandwidth *bucket* (the §III models respond
//! smoothly to bandwidth, and the sim only re-plans after a ≥ drift-sized
//! move anyway). 10k devices therefore share a handful of quantised
//! planner states, and one NSGA-II+TOPSIS solve per state serves the
//! whole fleet.
//!
//! Correctness contract (pinned by `tests/planner_cache.rs`): the cache
//! is a *pure memo table*. Quantisation happens before the solver in both
//! the cached and uncached paths, and the solver seed is derived from the
//! key — so equal keys produce equal decisions regardless of cache state,
//! solve order, or which pool thread ran the solve. Turning the cache off
//! changes wall-clock only, never a single `SplitDecision`.

use std::collections::{HashMap, HashSet};
use std::sync::Mutex;

use crate::coordinator::battery::{battery_aware_split_banded, BatteryBand};
use crate::device::ComputeProfile;
use crate::edge::{tiered_smartsplit_banded, tiered_split_banded, SplitPlan, TieredPerfModel};
use crate::metrics::{PlannerCounters, PlannerStats};
use crate::models::ModelProfile;
use crate::perfmodel::{NetworkEnv, PerfModel};
use crate::util::pool::ThreadPool;
use crate::util::rng::SplitMix64;

use super::nsga2::Nsga2Params;
use super::problem::SplitProblem;
use super::topsis::topsis;

/// Which decision procedure a cached plan came from (part of the key:
/// distinct strategies disagree on purpose and must never share an
/// entry). One variant per [`crate::planner::Strategy`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PlannerKind {
    /// Full Algorithm 1: NSGA-II Pareto set → band-weighted TOPSIS.
    SmartSplit,
    /// Exhaustive true Pareto front → band-weighted TOPSIS.
    Topsis,
    /// §VI-C latency-based optimisation (argmin f1).
    Lbo,
    /// §VI-C energy-based optimisation (argmin f2).
    Ebo,
    /// §VI-C CNN-on-smartphone (`l1 = L`).
    Cos,
    /// §VI-C CNN-on-cloud (`l1 = 0`).
    Coc,
    /// §VI-C random split (seeded from the key like every solve).
    Rs,
    /// §V-A weighted-sum scalarisation.
    WeightedSum,
    /// §V-A weighted-metric scalarisation.
    WeightedMetric,
    /// §V-A ε-constrained optimisation.
    EpsilonConstrained,
}

impl PlannerKind {
    /// Stable one-byte tag for key hashing and seed derivation.
    /// `Topsis = 0` and `SmartSplit = 1` are frozen — pre-façade keys
    /// hashed exactly these bytes, and derived solve seeds (and
    /// therefore decision streams) must not move; new kinds extend the
    /// byte space.
    pub fn tag(self) -> u8 {
        match self {
            PlannerKind::Topsis => 0,
            PlannerKind::SmartSplit => 1,
            PlannerKind::Lbo => 2,
            PlannerKind::Ebo => 3,
            PlannerKind::Cos => 4,
            PlannerKind::Coc => 5,
            PlannerKind::Rs => 6,
            PlannerKind::WeightedSum => 7,
            PlannerKind::WeightedMetric => 8,
            PlannerKind::EpsilonConstrained => 9,
        }
    }
}

/// The edge-tier component of a [`PlanKey`]: which site the device is
/// assigned to and everything about that site a tiered solve depends
/// on. Absent (`PlanKey::tier == None`) for the paper's two-tier
/// planning — two-tier and tiered plans can never collide.
///
/// The site *index* is part of the state on purpose: sites are
/// independently reconfigurable (pool size, backhaul), so two devices
/// behind different sites are different planner states even when the
/// sites currently look identical. On a uniform N-site topology this
/// trades up to N× more distinct solves for that isolation — bounded
/// by the (small) site count, and each site's state is still shared by
/// its whole device population.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TierKey {
    /// Index of the assigned site in the run's [`crate::edge::EdgeTopology`].
    pub site: u32,
    /// Edge server compute profile name.
    pub edge_profile: &'static str,
    /// Torso servers at the site (`0` = relay-only, torso infeasible).
    pub edge_servers: u32,
    /// Bit pattern of the (already bucketed) backhaul bandwidth in Mbps.
    pub backhaul_mbps_bits: u64,
    /// Bit pattern of the backhaul propagation latency in seconds.
    pub backhaul_latency_bits: u64,
}

impl TierKey {
    pub fn new(site: usize, edge: &crate::edge::EdgeSite, backhaul_mbps_q: f64) -> TierKey {
        TierKey {
            site: site as u32,
            edge_profile: edge.profile.name,
            edge_servers: edge.servers as u32,
            backhaul_mbps_bits: backhaul_mbps_q.to_bits(),
            backhaul_latency_bits: edge.backhaul.latency_s.to_bits(),
        }
    }

    /// Quantised backhaul bandwidth this key was built from.
    pub fn backhaul_mbps(&self) -> f64 {
        f64::from_bits(self.backhaul_mbps_bits)
    }
}

/// Quantised device state — everything a split solve depends on.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// Stable id of the [`ModelProfile`] (see [`model_cache_id`]).
    pub model_id: u64,
    /// Compute profile name (profiles are `'static`, names unique).
    pub profile: &'static str,
    pub band: BatteryBand,
    /// Bit pattern of the (already bucketed) bandwidth in Mbps.
    pub bw_mbps_bits: u64,
    pub kind: PlannerKind,
    /// Edge-tier component; `None` plans the paper's two-tier split.
    pub tier: Option<TierKey>,
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv1a(h: u64, bytes: &[u8]) -> u64 {
    let mut h = h;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

impl PlanKey {
    pub fn new(
        model_id: u64,
        profile: &'static ComputeProfile,
        band: BatteryBand,
        bw_mbps: f64,
        kind: PlannerKind,
    ) -> PlanKey {
        PlanKey {
            model_id,
            profile: profile.name,
            band,
            bw_mbps_bits: bw_mbps.to_bits(),
            kind,
            tier: None,
        }
    }

    /// This key with an edge-tier component attached (tiered planning).
    pub fn with_tier(mut self, tier: TierKey) -> PlanKey {
        self.tier = Some(tier);
        self
    }

    /// Quantised bandwidth this key was built from.
    pub fn bw_mbps(&self) -> f64 {
        f64::from_bits(self.bw_mbps_bits)
    }

    /// Process-independent FNV-1a digest (std's `DefaultHasher` is not
    /// guaranteed stable across releases; solve seeds must be).
    pub fn stable_hash(&self) -> u64 {
        let mut h = fnv1a(FNV_OFFSET, &self.model_id.to_le_bytes());
        h = fnv1a(h, self.profile.as_bytes());
        h = fnv1a(h, &[self.band.energy_weight() as u8]);
        h = fnv1a(h, &self.bw_mbps_bits.to_le_bytes());
        h = fnv1a(h, &[self.kind.tag()]);
        match &self.tier {
            None => h = fnv1a(h, &[0u8]),
            Some(t) => {
                h = fnv1a(h, &[1u8]);
                h = fnv1a(h, &t.site.to_le_bytes());
                h = fnv1a(h, t.edge_profile.as_bytes());
                h = fnv1a(h, &t.edge_servers.to_le_bytes());
                h = fnv1a(h, &t.backhaul_mbps_bits.to_le_bytes());
                h = fnv1a(h, &t.backhaul_latency_bits.to_le_bytes());
            }
        }
        h
    }

    /// NSGA-II seed for this key: `base` (the scenario's configured seed)
    /// mixed with the key digest, so (a) parallel solves never share RNG
    /// state, and (b) every device that maps onto this key — cached or
    /// not, on any thread, in any order — runs the identical solve.
    pub fn derived_seed(&self, base: u64) -> u64 {
        SplitMix64::new(base ^ self.stable_hash()).next_u64()
    }
}

/// Stable cache id for a model profile (name + layer count + batch).
pub fn model_cache_id(model: &ModelProfile) -> u64 {
    let mut h = fnv1a(FNV_OFFSET, model.name.as_bytes());
    h = fnv1a(h, &(model.num_layers as u64).to_le_bytes());
    h = fnv1a(h, &(model.batch as u64).to_le_bytes());
    h
}

/// Geometric bandwidth bucketing: `ratio` > 1 maps `bw` onto the
/// geometric midpoint of its bucket `[ratio^k, ratio^(k+1))`, so two
/// links within one ratio step of each other share a planner state.
/// `ratio` ≤ 1 is the identity (exact-bandwidth planning, the live-parity
/// configuration). Quantisation runs *before* the solver in cached and
/// uncached paths alike — it shapes decisions, the cache never does.
///
/// Edge-case contract (regression-pinned by the `quantize_degenerate_*`
/// tests below): inputs outside the geometric domain are passed through
/// unchanged rather than clamped to a bucket — `0`, negative values,
/// `±inf` and `NaN` all return themselves. A dead link (`0 Mbps`) is
/// therefore its own planner state and can never collide with the
/// smallest positive bucket; sub-`1 Mbps` links land in negative-`k`
/// buckets (the midpoint formula is exact there, no underflow for any
/// realistic bandwidth); non-finite values key on their own bit pattern
/// (keys are bit-compared, so `NaN` states are equal to themselves and
/// distinct from everything else). The function never panics.
pub fn quantize_bandwidth(bw_mbps: f64, ratio: f64) -> f64 {
    if ratio <= 1.0 || !bw_mbps.is_finite() || bw_mbps <= 0.0 {
        return bw_mbps;
    }
    let k = (bw_mbps.ln() / ratio.ln()).floor();
    ratio.powf(k) * ratio.sqrt()
}

/// §III evaluation context for a fleet member at bandwidth `bw_mbps` —
/// the shared constructor behind every simulated / coordinated planning
/// call (cloud side fixed to the paper's server profile).
pub fn member_perf_model<'a>(
    profile: &'static ComputeProfile,
    model: &'a ModelProfile,
    bw_mbps: f64,
) -> PerfModel<'a> {
    PerfModel::new(
        profile,
        crate::device::profiles::cloud_server(),
        profile.wifi.expect("fleet member needs a radio").radio_power(),
        NetworkEnv::with_bandwidth(bw_mbps),
        model,
    )
}

thread_local! {
    /// Per-thread reusable NSGA-II engine: every fleet solve on this
    /// thread — sequential sim loop or pool worker alike — amortises the
    /// SoA arena allocations instead of rebuilding them per cache miss
    /// (solver reuse is stateless between solves; pinned by
    /// `nsga2::tests::solver_reuse_matches_fresh_runs`).
    static FLEET_SOLVER: std::cell::RefCell<super::nsga2::Nsga2Solver> =
        std::cell::RefCell::new(super::nsga2::Nsga2Solver::new());
}

/// Run `f` with this thread's reusable fleet solver (shared by the
/// two-tier and tiered SmartSplit paths — genome width is per-solve, and
/// solver reuse is stateless between solves).
pub(crate) fn with_fleet_solver<R>(f: impl FnOnce(&mut super::nsga2::Nsga2Solver) -> R) -> R {
    FLEET_SOLVER.with(|s| f(&mut *s.borrow_mut()))
}

/// Algorithm 1 with the battery band's energy emphasis folded into the
/// TOPSIS stage: NSGA-II Pareto set, f2 column scaled by
/// [`BatteryBand::energy_weight`], TOPSIS choice. The Comfort band
/// (weight 1) reduces exactly to [`super::smartsplit`]'s decision.
pub fn smartsplit_banded(
    pm: &PerfModel<'_>,
    params: &Nsga2Params,
    band: BatteryBand,
) -> Option<usize> {
    let problem = SplitProblem::new(pm);
    let set = with_fleet_solver(|s| s.solve(&problem, params));
    let w = band.energy_weight();
    let rows: Vec<Vec<f64>> = set
        .members
        .iter()
        .map(|m| {
            let o = problem.objectives_at(m.genome[0] as usize);
            vec![o[0], o[1] * w, o[2]]
        })
        .collect();
    let feasible: Vec<bool> = set
        .members
        .iter()
        .map(|m| problem.feasible_at(m.genome[0] as usize))
        .collect();
    topsis(&rows, &feasible).map(|r| set.members[r.chosen].genome[0] as usize)
}

/// Run the decision procedure `kind` for one quantised two-tier planner
/// state. `seed` is the key-derived NSGA-II seed (ignored by the
/// exhaustive planner, which is deterministic by construction). The
/// returned plan is the paper's single split embedded in the tiered
/// space (`l2 == l1`, empty torso).
///
/// Pre-façade entry point, frozen as the parity reference for
/// `tests/planner_parity.rs`. Only the classic kinds are implemented
/// (`SmartSplit`, `Topsis`); every other kind returns `None` here —
/// plan through [`crate::planner::Planner`] instead.
#[deprecated(note = "plan through planner::Planner (one PlanRequest → PlanOutcome API)")]
pub fn solve_plan(
    kind: PlannerKind,
    pm: &PerfModel<'_>,
    band: BatteryBand,
    params: &Nsga2Params,
    seed: u64,
) -> Option<SplitPlan> {
    match kind {
        PlannerKind::Topsis => battery_aware_split_banded(pm, band).map(SplitPlan::two_tier),
        PlannerKind::SmartSplit => {
            smartsplit_banded(pm, &Nsga2Params { seed, ..params.clone() }, band)
                .map(SplitPlan::two_tier)
        }
        _ => None,
    }
}

/// Tiered counterpart of [`solve_plan`]: the same decision procedures
/// over the 2-D `(l1, l2)` genome of [`crate::edge::TieredSplitProblem`].
///
/// Pre-façade entry point, frozen as the parity reference for
/// `tests/planner_parity.rs`; classic kinds only (see [`solve_plan`]).
#[deprecated(note = "plan through planner::Planner (one PlanRequest → PlanOutcome API)")]
pub fn solve_plan_tiered(
    kind: PlannerKind,
    tpm: &TieredPerfModel<'_>,
    band: BatteryBand,
    params: &Nsga2Params,
    seed: u64,
) -> Option<SplitPlan> {
    match kind {
        PlannerKind::Topsis => tiered_split_banded(tpm, band),
        PlannerKind::SmartSplit => {
            tiered_smartsplit_banded(tpm, &Nsga2Params { seed, ..params.clone() }, band)
        }
        _ => None,
    }
}

const SHARDS: usize = 16;

/// Sharded concurrent memo table `PlanKey → Option<SplitPlan>` (a
/// `None` value caches "no feasible split" so hopeless states aren't
/// re-solved; two-tier plans are stored as `l2 == l1`). Shard selection
/// comes off the stable key digest, so contention between pool workers
/// filling different keys is negligible.
pub struct SplitPlanCache {
    shards: Vec<Mutex<HashMap<PlanKey, Option<SplitPlan>>>>,
    counters: PlannerCounters,
}

impl Default for SplitPlanCache {
    fn default() -> Self {
        Self::new()
    }
}

impl SplitPlanCache {
    pub fn new() -> SplitPlanCache {
        SplitPlanCache {
            shards: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            counters: PlannerCounters::new(),
        }
    }

    fn shard(&self, key: &PlanKey) -> &Mutex<HashMap<PlanKey, Option<SplitPlan>>> {
        &self.shards[(key.stable_hash() >> 40) as usize % SHARDS]
    }

    /// Counted lookup: one hit or miss per call — the per-decision
    /// accounting surfaced in `SimReport`/`metrics`.
    pub fn lookup(&self, key: &PlanKey) -> Option<Option<SplitPlan>> {
        let got = self.shard(key).lock().unwrap().get(key).copied();
        match got {
            Some(v) => {
                self.counters.record_hit();
                Some(v)
            }
            None => {
                self.counters.record_miss();
                None
            }
        }
    }

    /// Uncounted probe — used by [`SplitPlanCache::presolve_batch`] to
    /// find missing keys without perturbing the per-decision hit/miss
    /// accounting (which happens when the decision is actually served,
    /// via [`SplitPlanCache::plan`] / [`SplitPlanCache::lookup`]).
    pub fn get(&self, key: &PlanKey) -> Option<Option<SplitPlan>> {
        self.shard(key).lock().unwrap().get(key).copied()
    }

    /// Fan the *distinct, not-yet-cached* keys of `requests` out over
    /// `pool` and return their solved plans. Neither the cache contents
    /// nor the counters are touched: feed the returned map to
    /// [`SplitPlanCache::plan`]'s solve closure in the apply phase, so
    /// accounting (and therefore `PlannerStats`) is byte-identical to a
    /// sequential pass — parallelism stays a pure wall-clock toggle.
    /// Duplicate keys are deduplicated here (first request wins), so
    /// concurrent same-key solves cannot race. Jobs must be pure
    /// functions of their key (see [`PlanKey::derived_seed`]).
    pub fn presolve_batch<F>(
        &self,
        pool: &ThreadPool,
        requests: Vec<(PlanKey, F)>,
    ) -> HashMap<PlanKey, Option<SplitPlan>>
    where
        F: FnOnce() -> Option<SplitPlan> + Send + 'static,
    {
        let mut seen: HashSet<PlanKey> = HashSet::new();
        let mut keys: Vec<PlanKey> = Vec::new();
        let mut jobs: Vec<F> = Vec::new();
        for (key, solve) in requests {
            if self.get(&key).is_none() && seen.insert(key.clone()) {
                keys.push(key);
                jobs.push(solve);
            }
        }
        if keys.is_empty() {
            return HashMap::new();
        }
        let results = pool.run_all(jobs);
        keys.into_iter().zip(results).collect()
    }

    pub fn insert(&self, key: PlanKey, plan: Option<SplitPlan>) {
        self.shard(&key).lock().unwrap().insert(key, plan);
    }

    /// Distinct planner states cached so far.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn counters(&self) -> &PlannerCounters {
        &self.counters
    }

    pub fn stats(&self) -> PlannerStats {
        self.counters.snapshot()
    }

    /// Memoised solve: serve `key` from cache or run `solve` (and cache
    /// the result). With `enabled == false` this degrades to the
    /// uncached per-decision solve — same decisions (the seed comes from
    /// the key either way), no memoisation.
    pub fn plan(
        &self,
        enabled: bool,
        key: &PlanKey,
        solve: impl FnOnce() -> Option<SplitPlan>,
    ) -> Option<SplitPlan> {
        if enabled {
            if let Some(hit) = self.lookup(key) {
                return hit;
            }
        } else {
            self.counters.record_miss();
        }
        self.counters.record_solve();
        let v = solve();
        if enabled {
            self.insert(key.clone(), v);
        }
        v
    }
}

#[cfg(test)]
// The frozen pre-façade entry points are exercised on purpose: they are
// the parity references.
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::models::zoo;
    use crate::optimizer::smartsplit;

    fn key(bw: f64, band: BatteryBand) -> PlanKey {
        PlanKey::new(7, profiles::samsung_j6(), band, bw, PlannerKind::SmartSplit)
    }

    #[test]
    fn quantize_identity_below_ratio_one() {
        for bw in [0.5, 10.0, 123.456] {
            assert_eq!(quantize_bandwidth(bw, 1.0), bw);
            assert_eq!(quantize_bandwidth(bw, 0.0), bw);
        }
    }

    #[test]
    fn quantize_buckets_collapse_nearby_links() {
        let r = 1.25;
        // Same bucket ⇒ same quantised value.
        assert_eq!(quantize_bandwidth(10.0, r), quantize_bandwidth(10.5, r));
        // Far apart ⇒ different buckets, and the midpoint stays within
        // one ratio step of the input.
        assert_ne!(quantize_bandwidth(10.0, r), quantize_bandwidth(20.0, r));
        for bw in [0.7, 3.0, 10.0, 57.0, 200.0] {
            let q = quantize_bandwidth(bw, r);
            assert!(q / bw < r && bw / q < r, "bw={bw} q={q}");
        }
    }

    #[test]
    fn quantize_degenerate_inputs_pass_through_without_panicking() {
        // 0 Mbps, negative, and non-finite inputs are identity (the
        // documented clamping contract) for every ratio.
        for ratio in [0.0, 1.0, 1.25, 2.0] {
            assert_eq!(quantize_bandwidth(0.0, ratio), 0.0);
            assert_eq!(quantize_bandwidth(-3.0, ratio), -3.0);
            assert_eq!(quantize_bandwidth(f64::INFINITY, ratio), f64::INFINITY);
            assert_eq!(
                quantize_bandwidth(f64::NEG_INFINITY, ratio),
                f64::NEG_INFINITY
            );
            assert!(quantize_bandwidth(f64::NAN, ratio).is_nan());
        }
    }

    #[test]
    fn quantize_degenerate_zero_never_collides_with_a_real_bucket() {
        // A dead link must stay its own planner state: no positive
        // bandwidth — however small — may bucket onto 0.
        for bw in [1e-9, 1e-6, 1e-3, 0.1, 0.5] {
            let q = quantize_bandwidth(bw, 1.25);
            assert!(q > 0.0 && q.is_finite(), "bw={bw} quantised to {q}");
            assert_ne!(key(q, BatteryBand::Comfort), key(0.0, BatteryBand::Comfort));
        }
    }

    #[test]
    fn quantize_degenerate_sub_unit_buckets_stay_within_one_ratio_step() {
        // Sub-1 Mbps links land in negative-k buckets; the midpoint
        // bound |q/bw| < ratio must hold there exactly as above 1 Mbps.
        let r = 1.25;
        for bw in [0.001, 0.04, 0.3, 0.9] {
            let q = quantize_bandwidth(bw, r);
            assert!(q / bw < r && bw / q < r, "bw={bw} q={q}");
        }
    }

    #[test]
    fn degenerate_bandwidth_keys_are_stable_and_distinct() {
        // Non-finite states key on their own bit pattern: equal to
        // themselves (the memo table can serve them), distinct from
        // every finite state, and seed derivation never panics.
        let nan_a = key(f64::NAN, BatteryBand::Comfort);
        let nan_b = key(f64::NAN, BatteryBand::Comfort);
        let inf = key(f64::INFINITY, BatteryBand::Comfort);
        let zero = key(0.0, BatteryBand::Comfort);
        assert_eq!(nan_a, nan_b);
        assert_eq!(nan_a.derived_seed(7), nan_b.derived_seed(7));
        assert_ne!(nan_a, inf);
        assert_ne!(inf, zero);
        let cache = SplitPlanCache::new();
        cache.insert(nan_a.clone(), Some(SplitPlan::two_tier(3)));
        assert_eq!(cache.get(&nan_b), Some(Some(SplitPlan::two_tier(3))));
    }

    #[test]
    fn kind_tags_are_frozen_and_unique() {
        // Topsis = 0 / SmartSplit = 1 are load-bearing: pre-façade keys
        // hashed exactly these bytes and derived seeds must not move.
        assert_eq!(PlannerKind::Topsis.tag(), 0);
        assert_eq!(PlannerKind::SmartSplit.tag(), 1);
        let kinds = [
            PlannerKind::SmartSplit,
            PlannerKind::Topsis,
            PlannerKind::Lbo,
            PlannerKind::Ebo,
            PlannerKind::Cos,
            PlannerKind::Coc,
            PlannerKind::Rs,
            PlannerKind::WeightedSum,
            PlannerKind::WeightedMetric,
            PlannerKind::EpsilonConstrained,
        ];
        let tags: HashSet<u8> = kinds.iter().map(|k| k.tag()).collect();
        assert_eq!(tags.len(), kinds.len());
        // Distinct kinds ⇒ distinct keys and seeds for the same state.
        let mut keys = HashSet::new();
        for k in kinds {
            let mut key = key(10.0, BatteryBand::Comfort);
            key.kind = k;
            assert!(keys.insert(key.stable_hash()));
        }
    }

    #[test]
    fn derived_seeds_stable_and_key_sensitive() {
        let a = key(10.0, BatteryBand::Comfort);
        assert_eq!(a.derived_seed(42), a.derived_seed(42));
        assert_ne!(a.derived_seed(42), a.derived_seed(43));
        assert_ne!(
            a.derived_seed(42),
            key(20.0, BatteryBand::Comfort).derived_seed(42)
        );
        assert_ne!(
            a.derived_seed(42),
            key(10.0, BatteryBand::Critical).derived_seed(42)
        );
    }

    #[test]
    fn cache_hit_miss_accounting() {
        let cache = SplitPlanCache::new();
        let k = key(10.0, BatteryBand::Comfort);
        let mut solves = 0;
        let v1 = cache.plan(true, &k, || {
            solves += 1;
            Some(SplitPlan::two_tier(5))
        });
        let v2 = cache.plan(true, &k, || {
            solves += 1;
            Some(SplitPlan { l1: 9, l2: 9 }) // must never run
        });
        assert_eq!((v1, v2, solves), (Some(SplitPlan::two_tier(5)), Some(SplitPlan::two_tier(5)), 1));
        let s = cache.stats();
        assert_eq!((s.cache_hits, s.cache_misses, s.solves), (1, 1, 1));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn disabled_cache_always_solves_but_same_answer() {
        let cache = SplitPlanCache::new();
        let k = key(10.0, BatteryBand::Comfort);
        let mut solves = 0;
        for _ in 0..3 {
            let v = cache.plan(false, &k, || {
                solves += 1;
                Some(SplitPlan::two_tier(4))
            });
            assert_eq!(v, Some(SplitPlan::two_tier(4)));
        }
        assert_eq!(solves, 3);
        assert!(cache.is_empty());
        assert_eq!(cache.stats().solves, 3);
    }

    #[test]
    fn infeasible_states_are_cached_too() {
        let cache = SplitPlanCache::new();
        let k = key(0.01, BatteryBand::Critical);
        let mut solves = 0;
        for _ in 0..2 {
            let v = cache.plan(true, &k, || {
                solves += 1;
                None
            });
            assert_eq!(v, None);
        }
        assert_eq!(solves, 1, "a cached failure must not re-solve");
    }

    #[test]
    fn comfort_band_reduces_to_smartsplit() {
        let profile = zoo::alexnet().analyze(1);
        let pm = member_perf_model(profiles::samsung_j6(), &profile, 10.0);
        let params = Nsga2Params { pop_size: 40, generations: 40, ..Default::default() };
        let banded = smartsplit_banded(&pm, &params, BatteryBand::Comfort).unwrap();
        assert_eq!(banded, smartsplit(&pm, &params).decision.l1);
    }

    #[test]
    fn banded_solve_shifts_toward_energy_under_critical() {
        let profile = zoo::vgg11().analyze(1);
        let pm = member_perf_model(profiles::redmi_note8(), &profile, 30.0);
        let params = Nsga2Params::for_tiny_genome();
        let comfort = smartsplit_banded(&pm, &params, BatteryBand::Comfort).unwrap();
        let critical = smartsplit_banded(&pm, &params, BatteryBand::Critical).unwrap();
        assert!(
            pm.f2(critical) <= pm.f2(comfort) + 1e-12,
            "critical split {critical} costs more energy than comfort {comfort}"
        );
    }

    #[test]
    fn saturating_budgets_make_decisions_seed_independent() {
        // The sim's live-parity test plans with key-derived seeds while
        // its analytical expectation uses the configured seed directly;
        // that only works because a population that saturates the tiny
        // 1-D split domain always recovers the same (full) Pareto front,
        // making the TOPSIS choice independent of the NSGA-II seed. Pin
        // that property for the parity test's exact configurations.
        let profile = zoo::alexnet().analyze(1);
        for (p, bw) in [(profiles::samsung_j6(), 10.0), (profiles::redmi_note8(), 30.0)] {
            let pm = member_perf_model(p, &profile, bw);
            let mut decisions = std::collections::HashSet::new();
            for seed in [7u64, 0xC0FFEE, 0xDEAD_BEEF, 1] {
                let params =
                    Nsga2Params { pop_size: 40, generations: 40, seed, ..Default::default() };
                decisions.insert(smartsplit_banded(&pm, &params, BatteryBand::Comfort));
            }
            assert_eq!(decisions.len(), 1, "{} @ {bw} Mbps: seed-dependent decision", p.name);
        }
    }

    #[test]
    fn solve_plan_matches_both_planners() {
        let profile = zoo::alexnet().analyze(1);
        let pm = member_perf_model(profiles::samsung_j6(), &profile, 10.0);
        let params = Nsga2Params::for_tiny_genome();
        let k = key(10.0, BatteryBand::Saver);
        let seed = k.derived_seed(params.seed);
        let a = solve_plan(PlannerKind::SmartSplit, &pm, BatteryBand::Saver, &params, seed);
        let b = solve_plan(PlannerKind::SmartSplit, &pm, BatteryBand::Saver, &params, seed);
        assert_eq!(a, b, "same key+seed must solve identically");
        assert!(a.is_some());
        let t = solve_plan(PlannerKind::Topsis, &pm, BatteryBand::Saver, &params, seed);
        assert_eq!(
            t,
            crate::coordinator::battery::battery_aware_split_banded(&pm, BatteryBand::Saver)
                .map(SplitPlan::two_tier)
        );
    }

    #[test]
    fn tier_component_separates_planner_states() {
        let site = crate::edge::EdgeSite {
            servers: 2,
            profile: profiles::edge_server(),
            backhaul: crate::edge::BackhaulLink::METRO_1GBE,
        };
        let flat = key(10.0, BatteryBand::Comfort);
        let tiered = key(10.0, BatteryBand::Comfort).with_tier(TierKey::new(0, &site, 1000.0));
        assert_ne!(flat, tiered);
        assert_ne!(flat.stable_hash(), tiered.stable_hash());
        assert_ne!(flat.derived_seed(42), tiered.derived_seed(42));
        // Site identity and backhaul bucket are both part of the state.
        let other_site = key(10.0, BatteryBand::Comfort).with_tier(TierKey::new(1, &site, 1000.0));
        assert_ne!(tiered, other_site);
        let other_backhaul =
            key(10.0, BatteryBand::Comfort).with_tier(TierKey::new(0, &site, 500.0));
        assert_ne!(tiered, other_backhaul);
        // Same inputs reproduce the same key and seed.
        let again = key(10.0, BatteryBand::Comfort).with_tier(TierKey::new(0, &site, 1000.0));
        assert_eq!(tiered, again);
        assert_eq!(tiered.derived_seed(42), again.derived_seed(42));
    }

    #[test]
    fn solve_plan_tiered_is_deterministic_and_ordered() {
        let profile = zoo::alexnet().analyze(1);
        let pm = member_perf_model(profiles::samsung_j6(), &profile, 10.0);
        let tpm = TieredPerfModel::new(
            pm,
            profiles::edge_server(),
            2,
            crate::edge::BackhaulLink::METRO_1GBE,
        );
        let params = Nsga2Params::for_small_genome(2);
        for kind in [PlannerKind::SmartSplit, PlannerKind::Topsis] {
            let a = solve_plan_tiered(kind, &tpm, BatteryBand::Comfort, &params, 99);
            let b = solve_plan_tiered(kind, &tpm, BatteryBand::Comfort, &params, 99);
            assert_eq!(a, b, "{kind:?} must be deterministic");
            let plan = a.expect("feasible tiered plan");
            assert!(plan.l1 >= 1 && plan.l1 <= plan.l2 && plan.l2 <= profile.num_layers);
        }
    }

    #[test]
    fn model_ids_distinguish_models() {
        let a = model_cache_id(&zoo::alexnet().analyze(1));
        let v = model_cache_id(&zoo::vgg16().analyze(1));
        let b8 = model_cache_id(&zoo::alexnet().analyze(8));
        assert_ne!(a, v);
        assert_ne!(a, b8);
        assert_eq!(a, model_cache_id(&zoo::alexnet().analyze(1)));
    }
}
