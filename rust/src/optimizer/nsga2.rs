//! NSGA-II (Deb et al. [43]) over integer decision vectors — the first half
//! of Algorithm 1.
//!
//! A faithful implementation of the canonical algorithm: fast non-dominated
//! sorting, crowding distance, binary-tournament mating selection on
//! (rank, crowding), elitist (μ+λ) environmental selection, blend crossover
//! + creep/reset mutation for integer genomes, and Deb's
//! constraint-domination rule for infeasible candidates.
//!
//! Generic over the genome dimension so tests can drive it with standard
//! multi-objective benchmarks (SCH, KUR) while the SmartSplit problem uses
//! a 1-D genome (`[l1]`).

use crate::util::rng::Xoshiro256;

/// Genome: integer decision vector within per-dimension inclusive bounds.
pub type Genome = Vec<i64>;

/// A problem definition for the solver.
pub trait Problem {
    /// Inclusive (lo, hi) bounds per decision variable.
    fn bounds(&self) -> Vec<(i64, i64)>;
    /// Objective vector (all minimised).
    fn objectives(&self, g: &Genome) -> Vec<f64>;
    /// Hard-constraint violation: 0.0 when feasible, larger = worse.
    fn violation(&self, _g: &Genome) -> f64 {
        0.0
    }
    fn num_objectives(&self) -> usize;
}

/// Solver parameters (paper does not report its settings; defaults follow
/// Deb's canonical choices sized to our tiny decision space).
#[derive(Clone, Debug)]
pub struct Nsga2Params {
    pub pop_size: usize,
    pub generations: usize,
    pub crossover_prob: f64,
    pub mutation_prob: f64,
    pub seed: u64,
}

impl Default for Nsga2Params {
    fn default() -> Self {
        Nsga2Params {
            pop_size: 100,
            generations: 250,
            crossover_prob: 0.9,
            mutation_prob: 0.2,
            seed: 0xC0FFEE,
        }
    }
}

/// One evaluated individual.
#[derive(Clone, Debug)]
pub struct Individual {
    pub genome: Genome,
    pub objectives: Vec<f64>,
    pub violation: f64,
    pub rank: usize,
    pub crowding: f64,
}

/// `a` dominates `b` under Deb's constraint-domination rule.
pub fn dominates(a: &Individual, b: &Individual) -> bool {
    if a.violation == 0.0 && b.violation > 0.0 {
        return true;
    }
    if a.violation > 0.0 && b.violation > 0.0 {
        return a.violation < b.violation;
    }
    if a.violation > 0.0 && b.violation == 0.0 {
        return false;
    }
    let mut strictly_better = false;
    for (x, y) in a.objectives.iter().zip(&b.objectives) {
        if x > y {
            return false;
        }
        if x < y {
            strictly_better = true;
        }
    }
    strictly_better
}

/// Fast non-dominated sort: returns fronts of indices (front 0 first) and
/// writes ranks into the individuals.
pub fn fast_non_dominated_sort(pop: &mut [Individual]) -> Vec<Vec<usize>> {
    let n = pop.len();
    let mut dominated_by: Vec<Vec<usize>> = vec![Vec::new(); n]; // i dominates these
    let mut dom_count = vec![0usize; n]; // #individuals dominating i
    for i in 0..n {
        for j in (i + 1)..n {
            if dominates(&pop[i], &pop[j]) {
                dominated_by[i].push(j);
                dom_count[j] += 1;
            } else if dominates(&pop[j], &pop[i]) {
                dominated_by[j].push(i);
                dom_count[i] += 1;
            }
        }
    }
    let mut fronts: Vec<Vec<usize>> = Vec::new();
    let mut current: Vec<usize> = (0..n).filter(|&i| dom_count[i] == 0).collect();
    let mut rank = 0;
    while !current.is_empty() {
        for &i in &current {
            pop[i].rank = rank;
        }
        let mut next = Vec::new();
        for &i in &current {
            for &j in &dominated_by[i] {
                dom_count[j] -= 1;
                if dom_count[j] == 0 {
                    next.push(j);
                }
            }
        }
        fronts.push(std::mem::take(&mut current));
        current = next;
        rank += 1;
    }
    fronts
}

/// Crowding distance within one front (written into the individuals).
pub fn crowding_distance(pop: &mut [Individual], front: &[usize]) {
    for &i in front {
        pop[i].crowding = 0.0;
    }
    if front.len() <= 2 {
        for &i in front {
            pop[i].crowding = f64::INFINITY;
        }
        return;
    }
    let m = pop[front[0]].objectives.len();
    for obj in 0..m {
        let mut order: Vec<usize> = front.to_vec();
        order.sort_by(|&a, &b| {
            pop[a].objectives[obj]
                .partial_cmp(&pop[b].objectives[obj])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let lo = pop[order[0]].objectives[obj];
        let hi = pop[*order.last().unwrap()].objectives[obj];
        pop[order[0]].crowding = f64::INFINITY;
        pop[*order.last().unwrap()].crowding = f64::INFINITY;
        let span = hi - lo;
        if span <= 0.0 {
            continue;
        }
        for w in 1..order.len() - 1 {
            let prev = pop[order[w - 1]].objectives[obj];
            let next = pop[order[w + 1]].objectives[obj];
            pop[order[w]].crowding += (next - prev) / span;
        }
    }
}

/// Binary tournament on (rank asc, crowding desc).
fn tournament<'a>(pop: &'a [Individual], rng: &mut Xoshiro256) -> &'a Individual {
    let a = &pop[rng.gen_range(0, pop.len() - 1)];
    let b = &pop[rng.gen_range(0, pop.len() - 1)];
    if a.rank != b.rank {
        if a.rank < b.rank { a } else { b }
    } else if a.crowding != b.crowding {
        if a.crowding > b.crowding { a } else { b }
    } else {
        a
    }
}

fn clamp(v: i64, (lo, hi): (i64, i64)) -> i64 {
    v.clamp(lo, hi)
}

/// Blend crossover for integer genomes: children drawn around the parents'
/// affine span, rounded and clamped.
fn crossover(
    a: &Genome,
    b: &Genome,
    bounds: &[(i64, i64)],
    rng: &mut Xoshiro256,
) -> (Genome, Genome) {
    let mut c1 = a.clone();
    let mut c2 = b.clone();
    for d in 0..a.len() {
        let (x, y) = (a[d] as f64, b[d] as f64);
        let u = rng.next_f64();
        let v1 = u * x + (1.0 - u) * y;
        let v2 = (1.0 - u) * x + u * y;
        c1[d] = clamp(v1.round() as i64, bounds[d]);
        c2[d] = clamp(v2.round() as i64, bounds[d]);
    }
    (c1, c2)
}

/// Mutation: 50/50 creep (±1..3) or uniform reset within bounds.
fn mutate(g: &mut Genome, bounds: &[(i64, i64)], prob: f64, rng: &mut Xoshiro256) {
    for d in 0..g.len() {
        if !rng.gen_bool(prob) {
            continue;
        }
        let (lo, hi) = bounds[d];
        if rng.gen_bool(0.5) {
            let step = rng.gen_range_u64(1, 3) as i64;
            let dir = if rng.gen_bool(0.5) { 1 } else { -1 };
            g[d] = clamp(g[d] + dir * step, bounds[d]);
        } else {
            g[d] = rng.gen_range_u64(0, (hi - lo) as u64) as i64 + lo;
        }
    }
}

/// Result of a run: the final population's first front (deduplicated).
#[derive(Clone, Debug)]
pub struct ParetoSet {
    pub members: Vec<Individual>,
    pub generations_run: usize,
    pub evaluations: u64,
}

/// Run NSGA-II on `problem`.
pub fn optimize<P: Problem>(problem: &P, params: &Nsga2Params) -> ParetoSet {
    let bounds = problem.bounds();
    let mut rng = Xoshiro256::seed_from_u64(params.seed);
    let mut evaluations = 0u64;

    let eval = |g: Genome, evals: &mut u64| -> Individual {
        *evals += 1;
        Individual {
            objectives: problem.objectives(&g),
            violation: problem.violation(&g),
            genome: g,
            rank: 0,
            crowding: 0.0,
        }
    };

    // Initial population: uniform random within bounds.
    let mut pop: Vec<Individual> = (0..params.pop_size)
        .map(|_| {
            let g: Genome = bounds
                .iter()
                .map(|&(lo, hi)| rng.gen_range_u64(0, (hi - lo) as u64) as i64 + lo)
                .collect();
            eval(g, &mut evaluations)
        })
        .collect();
    let fronts = fast_non_dominated_sort(&mut pop);
    for f in &fronts {
        crowding_distance(&mut pop, f);
    }

    for _gen in 0..params.generations {
        // Offspring via tournament + crossover + mutation.
        let mut offspring = Vec::with_capacity(params.pop_size);
        while offspring.len() < params.pop_size {
            let p1 = tournament(&pop, &mut rng).genome.clone();
            let p2 = tournament(&pop, &mut rng).genome.clone();
            let (mut c1, mut c2) = if rng.gen_bool(params.crossover_prob) {
                crossover(&p1, &p2, &bounds, &mut rng)
            } else {
                (p1, p2)
            };
            mutate(&mut c1, &bounds, params.mutation_prob, &mut rng);
            mutate(&mut c2, &bounds, params.mutation_prob, &mut rng);
            offspring.push(eval(c1, &mut evaluations));
            if offspring.len() < params.pop_size {
                offspring.push(eval(c2, &mut evaluations));
            }
        }

        // Elitist (μ+λ) environmental selection.
        pop.extend(offspring);
        let fronts = fast_non_dominated_sort(&mut pop);
        for f in &fronts {
            crowding_distance(&mut pop, f);
        }
        let mut next: Vec<Individual> = Vec::with_capacity(params.pop_size);
        for front in &fronts {
            if next.len() + front.len() <= params.pop_size {
                next.extend(front.iter().map(|&i| pop[i].clone()));
            } else {
                let mut rest: Vec<usize> = front.clone();
                rest.sort_by(|&a, &b| {
                    pop[b]
                        .crowding
                        .partial_cmp(&pop[a].crowding)
                        .unwrap_or(std::cmp::Ordering::Equal)
                });
                for &i in rest.iter().take(params.pop_size - next.len()) {
                    next.push(pop[i].clone());
                }
                break;
            }
        }
        pop = next;
    }

    // Final front 0, feasible only, deduplicated by genome.
    let fronts = fast_non_dominated_sort(&mut pop);
    for f in &fronts {
        crowding_distance(&mut pop, f);
    }
    let mut members: Vec<Individual> = fronts
        .first()
        .map(|f| f.iter().map(|&i| pop[i].clone()).collect())
        .unwrap_or_default();
    members.retain(|m| m.violation == 0.0);
    members.sort_by(|a, b| a.genome.cmp(&b.genome));
    members.dedup_by(|a, b| a.genome == b.genome);
    ParetoSet { members, generations_run: params.generations, evaluations }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Schaffer's SCH: f1 = x², f2 = (x-2)² — Pareto front is x ∈ [0, 2].
    struct Sch;

    impl Problem for Sch {
        fn bounds(&self) -> Vec<(i64, i64)> {
            vec![(-1000, 1000)]
        }
        fn objectives(&self, g: &Genome) -> Vec<f64> {
            let x = g[0] as f64 / 100.0;
            vec![x * x, (x - 2.0) * (x - 2.0)]
        }
        fn num_objectives(&self) -> usize {
            2
        }
    }

    fn ind(objs: Vec<f64>, violation: f64) -> Individual {
        Individual { genome: vec![], objectives: objs, violation, rank: 0, crowding: 0.0 }
    }

    #[test]
    fn domination_rules() {
        let a = ind(vec![1.0, 1.0], 0.0);
        let b = ind(vec![2.0, 1.0], 0.0);
        let c = ind(vec![0.5, 2.0], 0.0);
        assert!(dominates(&a, &b));
        assert!(!dominates(&b, &a));
        assert!(!dominates(&a, &c) && !dominates(&c, &a)); // incomparable
        assert!(!dominates(&a, &a)); // strictness
        // constraint domination
        let infeasible = ind(vec![0.0, 0.0], 1.0);
        let worse_infeasible = ind(vec![0.0, 0.0], 2.0);
        assert!(dominates(&a, &infeasible));
        assert!(!dominates(&infeasible, &a));
        assert!(dominates(&infeasible, &worse_infeasible));
    }

    #[test]
    fn non_dominated_sort_fronts() {
        let mut pop = vec![
            ind(vec![1.0, 4.0], 0.0), // front 0
            ind(vec![4.0, 1.0], 0.0), // front 0
            ind(vec![2.0, 5.0], 0.0), // dominated by 0
            ind(vec![5.0, 5.0], 0.0), // dominated by all above
        ];
        let fronts = fast_non_dominated_sort(&mut pop);
        assert_eq!(fronts[0], vec![0, 1]);
        assert_eq!(fronts[1], vec![2]);
        assert_eq!(fronts[2], vec![3]);
        assert_eq!(pop[3].rank, 2);
    }

    #[test]
    fn crowding_extremes_infinite() {
        let mut pop = vec![
            ind(vec![0.0, 3.0], 0.0),
            ind(vec![1.0, 2.0], 0.0),
            ind(vec![2.0, 1.0], 0.0),
            ind(vec![3.0, 0.0], 0.0),
        ];
        let front: Vec<usize> = (0..4).collect();
        crowding_distance(&mut pop, &front);
        assert!(pop[0].crowding.is_infinite());
        assert!(pop[3].crowding.is_infinite());
        assert!(pop[1].crowding.is_finite() && pop[1].crowding > 0.0);
    }

    #[test]
    fn solves_sch() {
        let set = optimize(&Sch, &Nsga2Params { pop_size: 60, generations: 60, ..Default::default() });
        assert!(!set.members.is_empty());
        // Every member of the front must be in [0, 2] (x scaled by 100).
        for m in &set.members {
            let x = m.genome[0] as f64 / 100.0;
            assert!(
                (-0.05..=2.05).contains(&x),
                "non-Pareto member x={x} objs={:?}",
                m.objectives
            );
        }
        // The front should cover the range reasonably well.
        let xs: Vec<f64> = set.members.iter().map(|m| m.genome[0] as f64 / 100.0).collect();
        let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert!(min < 0.3, "front min {min}");
        assert!(max > 1.7, "front max {max}");
    }

    #[test]
    fn deterministic_for_seed() {
        let p = Nsga2Params { pop_size: 30, generations: 20, ..Default::default() };
        let a = optimize(&Sch, &p);
        let b = optimize(&Sch, &p);
        let g = |s: &ParetoSet| s.members.iter().map(|m| m.genome.clone()).collect::<Vec<_>>();
        assert_eq!(g(&a), g(&b));
    }

    #[test]
    fn infeasible_candidates_excluded_from_result() {
        struct OnlyBig;
        impl Problem for OnlyBig {
            fn bounds(&self) -> Vec<(i64, i64)> {
                vec![(0, 10)]
            }
            fn objectives(&self, g: &Genome) -> Vec<f64> {
                vec![g[0] as f64, -(g[0] as f64)]
            }
            fn violation(&self, g: &Genome) -> f64 {
                if g[0] >= 5 { 0.0 } else { (5 - g[0]) as f64 }
            }
            fn num_objectives(&self) -> usize {
                2
            }
        }
        let set = optimize(&OnlyBig, &Nsga2Params { pop_size: 20, generations: 30, ..Default::default() });
        assert!(!set.members.is_empty());
        for m in &set.members {
            assert!(m.genome[0] >= 5, "infeasible member {:?}", m.genome);
        }
    }

    #[test]
    fn evaluation_count_reported() {
        let p = Nsga2Params { pop_size: 10, generations: 5, ..Default::default() };
        let set = optimize(&Sch, &p);
        assert_eq!(set.evaluations, 10 + 5 * 10);
    }
}
