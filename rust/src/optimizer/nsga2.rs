//! NSGA-II (Deb et al. [43]) over integer decision vectors — the first half
//! of Algorithm 1.
//!
//! A faithful implementation of the canonical algorithm: fast non-dominated
//! sorting, crowding distance, binary-tournament mating selection on
//! (rank, crowding), elitist (μ+λ) environmental selection, blend crossover
//! + creep/reset mutation for integer genomes, and Deb's
//! constraint-domination rule for infeasible candidates.
//!
//! Generic over the genome dimension so tests can drive it with standard
//! multi-objective benchmarks (SCH, KUR) while the SmartSplit problem uses
//! a 1-D genome (`[l1]`).
//!
//! §Perf: the generation loop runs entirely on flat SoA storage inside a
//! reusable [`Nsga2Solver`] — genomes, objectives, violations, ranks and
//! crowding live in preallocated flat arrays indexed by slot, and every
//! intermediate (dominance lists, fronts, crowding sort order, survivor
//! compaction) reuses scratch buffers. After the first generation the hot
//! path performs no heap allocation, which is what lets a fleet-scale
//! re-optimisation sweep run tens of thousands of solves per second
//! (`benches/planner_throughput.rs` asserts the allocation profile).

use crate::util::rng::Xoshiro256;

/// Genome: integer decision vector within per-dimension inclusive bounds.
pub type Genome = Vec<i64>;

/// A problem definition for the solver.
pub trait Problem {
    /// Inclusive (lo, hi) bounds per decision variable.
    fn bounds(&self) -> Vec<(i64, i64)>;
    /// Objective vector (all minimised).
    fn objectives(&self, g: &Genome) -> Vec<f64>;
    /// Hard-constraint violation: 0.0 when feasible, larger = worse.
    fn violation(&self, _g: &Genome) -> f64 {
        0.0
    }
    fn num_objectives(&self) -> usize;

    /// Allocation-free fast path: write the objective vector for `g` into
    /// `out` (`out.len() == num_objectives()`). The default delegates to
    /// [`Problem::objectives`]; hot-path problems (e.g.
    /// [`super::problem::SplitProblem`]) override it with a table write.
    fn objectives_into(&self, g: &[i64], out: &mut [f64]) {
        let v = self.objectives(&g.to_vec());
        out.copy_from_slice(&v);
    }

    /// Allocation-free violation fast path; same contract as
    /// [`Problem::objectives_into`].
    fn violation_of(&self, g: &[i64]) -> f64 {
        self.violation(&g.to_vec())
    }
}

/// Solver parameters (paper does not report its settings; defaults follow
/// Deb's canonical choices sized to our tiny decision space).
#[derive(Clone, Debug)]
pub struct Nsga2Params {
    pub pop_size: usize,
    pub generations: usize,
    pub crossover_prob: f64,
    pub mutation_prob: f64,
    pub seed: u64,
    /// Early termination: stop when the first front's genome set has been
    /// unchanged for this many consecutive generations. `0` disables the
    /// check (canonical fixed-budget behaviour, used by the paper-figure
    /// benches).
    pub stagnation_patience: usize,
}

impl Default for Nsga2Params {
    fn default() -> Self {
        Nsga2Params {
            pop_size: 100,
            generations: 250,
            crossover_prob: 0.9,
            mutation_prob: 0.2,
            seed: 0xC0FFEE,
            stagnation_patience: 0,
        }
    }
}

impl Nsga2Params {
    /// Preset sized to SmartSplit's 1-D split genome (≤ 38 candidate
    /// values): a 24-member population saturates the domain within a few
    /// generations, and the stagnation check stops the run as soon as the
    /// front stops moving. ~100× fewer objective evaluations than the
    /// canonical 100×250 budget with identical decisions on the paper's
    /// models — the fleet-simulation default. Paper-figure benches keep
    /// [`Nsga2Params::default`].
    pub fn for_tiny_genome() -> Self {
        Nsga2Params::for_small_genome(1)
    }

    /// Preset sized to a small integer genome of `dim` decision
    /// variables over a ≤ 38-value-per-dimension domain. `dim = 1` is
    /// [`Nsga2Params::for_tiny_genome`]; `dim = 2` (the tiered
    /// `(l1, l2)` split of [`crate::edge`], domain ≤ 38²) doubles the
    /// population and raises the patience so the front of the larger
    /// lattice still saturates before the stagnation check fires.
    pub fn for_small_genome(dim: usize) -> Self {
        let d = dim.max(1);
        Nsga2Params {
            pop_size: 24 * d,
            generations: 64 * d,
            stagnation_patience: 4 + 2 * d,
            ..Default::default()
        }
    }
}

/// One evaluated individual.
#[derive(Clone, Debug)]
pub struct Individual {
    pub genome: Genome,
    pub objectives: Vec<f64>,
    pub violation: f64,
    pub rank: usize,
    pub crowding: f64,
}

/// `a` dominates `b` under Deb's constraint-domination rule.
pub fn dominates(a: &Individual, b: &Individual) -> bool {
    dominates_raw(&a.objectives, a.violation, &b.objectives, b.violation)
}

/// Slice-level constraint-domination (the SoA hot path shares this with
/// the [`Individual`]-based API).
fn dominates_raw(a_obj: &[f64], a_viol: f64, b_obj: &[f64], b_viol: f64) -> bool {
    if a_viol == 0.0 && b_viol > 0.0 {
        return true;
    }
    if a_viol > 0.0 && b_viol > 0.0 {
        return a_viol < b_viol;
    }
    if a_viol > 0.0 && b_viol == 0.0 {
        return false;
    }
    let mut strictly_better = false;
    for (x, y) in a_obj.iter().zip(b_obj) {
        if x > y {
            return false;
        }
        if x < y {
            strictly_better = true;
        }
    }
    strictly_better
}

/// Fast non-dominated sort: returns fronts of indices (front 0 first) and
/// writes ranks into the individuals.
pub fn fast_non_dominated_sort(pop: &mut [Individual]) -> Vec<Vec<usize>> {
    let n = pop.len();
    let mut dominated_by: Vec<Vec<usize>> = vec![Vec::new(); n]; // i dominates these
    let mut dom_count = vec![0usize; n]; // #individuals dominating i
    for i in 0..n {
        for j in (i + 1)..n {
            if dominates(&pop[i], &pop[j]) {
                dominated_by[i].push(j);
                dom_count[j] += 1;
            } else if dominates(&pop[j], &pop[i]) {
                dominated_by[j].push(i);
                dom_count[i] += 1;
            }
        }
    }
    let mut fronts: Vec<Vec<usize>> = Vec::new();
    let mut current: Vec<usize> = (0..n).filter(|&i| dom_count[i] == 0).collect();
    let mut rank = 0;
    while !current.is_empty() {
        for &i in &current {
            pop[i].rank = rank;
        }
        let mut next = Vec::new();
        for &i in &current {
            for &j in &dominated_by[i] {
                dom_count[j] -= 1;
                if dom_count[j] == 0 {
                    next.push(j);
                }
            }
        }
        fronts.push(std::mem::take(&mut current));
        current = next;
        rank += 1;
    }
    fronts
}

/// Crowding distance within one front (written into the individuals).
pub fn crowding_distance(pop: &mut [Individual], front: &[usize]) {
    for &i in front {
        pop[i].crowding = 0.0;
    }
    if front.len() <= 2 {
        for &i in front {
            pop[i].crowding = f64::INFINITY;
        }
        return;
    }
    let m = pop[front[0]].objectives.len();
    for obj in 0..m {
        let mut order: Vec<usize> = front.to_vec();
        order.sort_by(|&a, &b| {
            pop[a].objectives[obj]
                .partial_cmp(&pop[b].objectives[obj])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let lo = pop[order[0]].objectives[obj];
        let hi = pop[*order.last().unwrap()].objectives[obj];
        pop[order[0]].crowding = f64::INFINITY;
        pop[*order.last().unwrap()].crowding = f64::INFINITY;
        let span = hi - lo;
        if span <= 0.0 {
            continue;
        }
        for w in 1..order.len() - 1 {
            let prev = pop[order[w - 1]].objectives[obj];
            let next = pop[order[w + 1]].objectives[obj];
            pop[order[w]].crowding += (next - prev) / span;
        }
    }
}

fn clamp(v: i64, (lo, hi): (i64, i64)) -> i64 {
    v.clamp(lo, hi)
}

/// Stable, allocation-free in-place sort of an index buffer. The std
/// stable `sort_by` heap-allocates merge scratch for slices past ~20
/// elements, which would put an allocation in every generation of the
/// hot loop; fronts here are small (≤ 2·pop), so an insertion sort is
/// both allocation-free and cheap. Produces exactly the stable-sort
/// permutation (equal elements keep their relative order), so results
/// match the [`crowding_distance`] reference bit-for-bit.
fn insertion_sort_by<F>(idx: &mut [usize], mut cmp: F)
where
    F: FnMut(usize, usize) -> std::cmp::Ordering,
{
    for i in 1..idx.len() {
        let mut j = i;
        while j > 0 && cmp(idx[j - 1], idx[j]) == std::cmp::Ordering::Greater {
            idx.swap(j - 1, j);
            j -= 1;
        }
    }
}

/// Mutation: 50/50 creep (±1..3) or uniform reset within bounds.
fn mutate(g: &mut [i64], bounds: &[(i64, i64)], prob: f64, rng: &mut Xoshiro256) {
    for d in 0..g.len() {
        if !rng.gen_bool(prob) {
            continue;
        }
        let (lo, hi) = bounds[d];
        if rng.gen_bool(0.5) {
            let step = rng.gen_range_u64(1, 3) as i64;
            let dir = if rng.gen_bool(0.5) { 1 } else { -1 };
            g[d] = clamp(g[d] + dir * step, bounds[d]);
        } else {
            g[d] = rng.gen_range_u64(0, (hi - lo) as u64) as i64 + lo;
        }
    }
}

/// Result of a run: the final population's first front (deduplicated).
#[derive(Clone, Debug)]
pub struct ParetoSet {
    pub members: Vec<Individual>,
    pub generations_run: usize,
    pub evaluations: u64,
}

/// Reusable allocation-free NSGA-II engine.
///
/// All per-generation state lives in flat structure-of-arrays buffers:
/// slot `s` of a (μ+λ)-sized arena owns `genomes[s*dim..]`,
/// `objs[s*m..]`, `viol[s]`, `rank[s]`, `crowd[s]`. Parents occupy slots
/// `0..pop`, offspring `pop..2·pop`; environmental selection compacts
/// survivors back into the parent region through swap buffers. Dominance
/// adjacency lists, front index lists, the crowding sort order and the
/// crossover parent copies are all retained scratch, so repeated
/// [`Nsga2Solver::solve`] calls (the fleet re-optimisation pattern) do
/// not allocate once buffer capacities have warmed up.
#[derive(Default)]
pub struct Nsga2Solver {
    bounds: Vec<(i64, i64)>,
    // SoA arena over 2*pop slots.
    genomes: Vec<i64>,
    objs: Vec<f64>,
    viol: Vec<f64>,
    rank: Vec<usize>,
    crowd: Vec<f64>,
    // Non-dominated-sort scratch.
    dominated_by: Vec<Vec<usize>>,
    dom_count: Vec<usize>,
    fronts: Vec<Vec<usize>>,
    fronts_used: usize,
    // Crowding / selection scratch.
    order: Vec<usize>,
    survivors: Vec<usize>,
    // Survivor-compaction swap buffers.
    tmp_genomes: Vec<i64>,
    tmp_objs: Vec<f64>,
    tmp_viol: Vec<f64>,
    tmp_rank: Vec<usize>,
    tmp_crowd: Vec<f64>,
    // Crossover parent copies + spill child (when the offspring arena is
    // full but the canonical pairing still produces a second child).
    p1: Vec<i64>,
    p2: Vec<i64>,
    c2: Vec<i64>,
    // Stagnation signatures (lexicographically ordered front-0 genomes).
    sig: Vec<i64>,
    prev_sig: Vec<i64>,
}

impl Nsga2Solver {
    pub fn new() -> Nsga2Solver {
        Nsga2Solver::default()
    }

    /// Size every buffer for a (μ+λ) arena of `cap` slots. Only grows —
    /// repeated solves at the same shape reuse capacity.
    fn reset(&mut self, cap: usize, dim: usize, m: usize, bounds: Vec<(i64, i64)>) {
        self.bounds = bounds;
        self.genomes.clear();
        self.genomes.resize(cap * dim, 0);
        self.objs.clear();
        self.objs.resize(cap * m, 0.0);
        self.viol.clear();
        self.viol.resize(cap, 0.0);
        self.rank.clear();
        self.rank.resize(cap, 0);
        self.crowd.clear();
        self.crowd.resize(cap, 0.0);
        if self.dominated_by.len() < cap {
            self.dominated_by.resize_with(cap, Vec::new);
        }
        self.dom_count.clear();
        self.dom_count.resize(cap, 0);
        self.tmp_genomes.clear();
        self.tmp_genomes.resize(cap * dim, 0);
        self.tmp_objs.clear();
        self.tmp_objs.resize(cap * m, 0.0);
        self.tmp_viol.clear();
        self.tmp_viol.resize(cap, 0.0);
        self.tmp_rank.clear();
        self.tmp_rank.resize(cap, 0);
        self.tmp_crowd.clear();
        self.tmp_crowd.resize(cap, 0.0);
        self.p1.clear();
        self.p1.resize(dim, 0);
        self.p2.clear();
        self.p2.resize(dim, 0);
        self.c2.clear();
        self.c2.resize(dim, 0);
        self.sig.clear();
        self.prev_sig.clear();
        self.fronts_used = 0;
    }

    fn eval_slot<P: Problem>(&mut self, problem: &P, s: usize, dim: usize, m: usize) {
        let g = &self.genomes[s * dim..(s + 1) * dim];
        problem.objectives_into(g, &mut self.objs[s * m..(s + 1) * m]);
        self.viol[s] = problem.violation_of(g);
    }

    fn dominates_slot(&self, i: usize, j: usize, m: usize) -> bool {
        dominates_raw(
            &self.objs[i * m..(i + 1) * m],
            self.viol[i],
            &self.objs[j * m..(j + 1) * m],
            self.viol[j],
        )
    }

    /// Fast non-dominated sort over slots `0..n` into `self.fronts`
    /// (ranks written to `self.rank`), then crowding per front.
    fn sort_and_crowd(&mut self, n: usize, m: usize) {
        for i in 0..n {
            self.dominated_by[i].clear();
            self.dom_count[i] = 0;
        }
        for i in 0..n {
            for j in (i + 1)..n {
                if self.dominates_slot(i, j, m) {
                    self.dominated_by[i].push(j);
                    self.dom_count[j] += 1;
                } else if self.dominates_slot(j, i, m) {
                    self.dominated_by[j].push(i);
                    self.dom_count[i] += 1;
                }
            }
        }
        if self.fronts.is_empty() {
            self.fronts.push(Vec::new());
        }
        self.fronts[0].clear();
        for i in 0..n {
            if self.dom_count[i] == 0 {
                self.rank[i] = 0;
                self.fronts[0].push(i);
            }
        }
        let mut k = 0;
        while !self.fronts[k].is_empty() {
            if self.fronts.len() <= k + 1 {
                self.fronts.push(Vec::new());
            }
            self.fronts[k + 1].clear();
            for pos in 0..self.fronts[k].len() {
                let i = self.fronts[k][pos];
                for dd in 0..self.dominated_by[i].len() {
                    let j = self.dominated_by[i][dd];
                    self.dom_count[j] -= 1;
                    if self.dom_count[j] == 0 {
                        self.rank[j] = k + 1;
                        self.fronts[k + 1].push(j);
                    }
                }
            }
            k += 1;
        }
        self.fronts_used = k; // fronts[k] is the empty sentinel
        for f in 0..self.fronts_used {
            self.crowd_front(f, m);
        }
    }

    /// Crowding distance for front `k` (into `self.crowd`).
    fn crowd_front(&mut self, k: usize, m: usize) {
        let n = self.fronts[k].len();
        if n <= 2 {
            for pos in 0..n {
                let i = self.fronts[k][pos];
                self.crowd[i] = f64::INFINITY;
            }
            return;
        }
        for pos in 0..n {
            let i = self.fronts[k][pos];
            self.crowd[i] = 0.0;
        }
        for obj in 0..m {
            // Re-seed the sort order from front order for every objective
            // (matching [`crowding_distance`]): a stable sort started from
            // the previous objective's permutation would rank tied values
            // differently and change seeded selection results.
            self.order.clear();
            self.order.extend_from_slice(&self.fronts[k]);
            let objs = &self.objs;
            insertion_sort_by(&mut self.order, |a, b| {
                objs[a * m + obj]
                    .partial_cmp(&objs[b * m + obj])
                    .unwrap_or(std::cmp::Ordering::Equal)
            });
            let lo = self.objs[self.order[0] * m + obj];
            let hi = self.objs[self.order[n - 1] * m + obj];
            self.crowd[self.order[0]] = f64::INFINITY;
            self.crowd[self.order[n - 1]] = f64::INFINITY;
            let span = hi - lo;
            if span <= 0.0 {
                continue;
            }
            for w in 1..n - 1 {
                let prev = self.objs[self.order[w - 1] * m + obj];
                let next = self.objs[self.order[w + 1] * m + obj];
                self.crowd[self.order[w]] += (next - prev) / span;
            }
        }
    }

    /// Binary tournament on (rank asc, crowding desc) over parent slots.
    fn tournament(&self, pop: usize, rng: &mut Xoshiro256) -> usize {
        let a = rng.gen_range(0, pop - 1);
        let b = rng.gen_range(0, pop - 1);
        if self.rank[a] != self.rank[b] {
            if self.rank[a] < self.rank[b] { a } else { b }
        } else if self.crowd[a] != self.crowd[b] {
            if self.crowd[a] > self.crowd[b] { a } else { b }
        } else {
            a
        }
    }

    /// Elitist (μ+λ) selection over the sorted arena: fill
    /// `self.survivors` with exactly `pop` slot indices.
    fn select_survivors(&mut self, pop: usize) {
        self.survivors.clear();
        for k in 0..self.fronts_used {
            let flen = self.fronts[k].len();
            if self.survivors.len() + flen <= pop {
                self.survivors.extend_from_slice(&self.fronts[k]);
            } else {
                self.order.clear();
                self.order.extend_from_slice(&self.fronts[k]);
                let crowd = &self.crowd;
                insertion_sort_by(&mut self.order, |a, b| {
                    crowd[b]
                        .partial_cmp(&crowd[a])
                        .unwrap_or(std::cmp::Ordering::Equal)
                });
                let need = pop - self.survivors.len();
                self.survivors.extend_from_slice(&self.order[..need]);
                break;
            }
            if self.survivors.len() == pop {
                break;
            }
        }
    }

    /// Copy survivor rows into the parent region through the swap buffers.
    fn compact(&mut self, dim: usize, m: usize) {
        for (s, &old) in self.survivors.iter().enumerate() {
            self.tmp_genomes[s * dim..(s + 1) * dim]
                .copy_from_slice(&self.genomes[old * dim..(old + 1) * dim]);
            self.tmp_objs[s * m..(s + 1) * m]
                .copy_from_slice(&self.objs[old * m..(old + 1) * m]);
            self.tmp_viol[s] = self.viol[old];
            self.tmp_rank[s] = self.rank[old];
            self.tmp_crowd[s] = self.crowd[old];
        }
        std::mem::swap(&mut self.genomes, &mut self.tmp_genomes);
        std::mem::swap(&mut self.objs, &mut self.tmp_objs);
        std::mem::swap(&mut self.viol, &mut self.tmp_viol);
        std::mem::swap(&mut self.rank, &mut self.tmp_rank);
        std::mem::swap(&mut self.crowd, &mut self.tmp_crowd);
    }

    /// Lexicographically ordered concatenation of the *distinct* rank-0
    /// parent genomes — the stagnation signature. Deduplicated on
    /// purpose: a converged population keeps shuffling duplicate copies
    /// of front members between generations, and that churn must not
    /// mask a front whose genome set stopped moving.
    fn front_signature(&mut self, pop: usize, dim: usize) {
        self.order.clear();
        for s in 0..pop {
            if self.rank[s] == 0 {
                self.order.push(s);
            }
        }
        let genomes = &self.genomes;
        insertion_sort_by(&mut self.order, |a, b| {
            genomes[a * dim..(a + 1) * dim].cmp(&genomes[b * dim..(b + 1) * dim])
        });
        self.sig.clear();
        for w in 0..self.order.len() {
            let s = self.order[w];
            if w > 0 {
                let prev = self.order[w - 1];
                if self.genomes[s * dim..(s + 1) * dim]
                    == self.genomes[prev * dim..(prev + 1) * dim]
                {
                    continue;
                }
            }
            self.sig.extend_from_slice(&self.genomes[s * dim..(s + 1) * dim]);
        }
    }

    /// Run NSGA-II; equivalent to [`optimize`] but reuses this solver's
    /// buffers across calls.
    pub fn solve<P: Problem>(&mut self, problem: &P, params: &Nsga2Params) -> ParetoSet {
        let bounds = problem.bounds();
        let dim = bounds.len();
        let m = problem.num_objectives();
        let pop = params.pop_size.max(2);
        let cap = 2 * pop;
        self.reset(cap, dim, m, bounds);
        let mut rng = Xoshiro256::seed_from_u64(params.seed);
        let mut evaluations = 0u64;

        // Initial population: uniform random within bounds.
        for s in 0..pop {
            for d in 0..dim {
                let (lo, hi) = self.bounds[d];
                self.genomes[s * dim + d] = rng.gen_range_u64(0, (hi - lo) as u64) as i64 + lo;
            }
            self.eval_slot(problem, s, dim, m);
            evaluations += 1;
        }
        self.sort_and_crowd(pop, m);

        let mut generations_run = 0usize;
        let mut stagnant = 0usize;
        for _gen in 0..params.generations {
            generations_run += 1;
            // Offspring via tournament + crossover + mutation, written
            // directly into arena slots pop..2·pop.
            let mut filled = 0usize;
            while filled < pop {
                let pa = self.tournament(pop, &mut rng);
                let pb = self.tournament(pop, &mut rng);
                self.p1.copy_from_slice(&self.genomes[pa * dim..(pa + 1) * dim]);
                self.p2.copy_from_slice(&self.genomes[pb * dim..(pb + 1) * dim]);
                let s1 = pop + filled;
                if rng.gen_bool(params.crossover_prob) {
                    // Blend crossover: children drawn around the parents'
                    // affine span, rounded and clamped.
                    for d in 0..dim {
                        let (x, y) = (self.p1[d] as f64, self.p2[d] as f64);
                        let u = rng.next_f64();
                        let v1 = u * x + (1.0 - u) * y;
                        let v2 = (1.0 - u) * x + u * y;
                        self.genomes[s1 * dim + d] = clamp(v1.round() as i64, self.bounds[d]);
                        self.c2[d] = clamp(v2.round() as i64, self.bounds[d]);
                    }
                } else {
                    self.genomes[s1 * dim..(s1 + 1) * dim].copy_from_slice(&self.p1);
                    self.c2.copy_from_slice(&self.p2);
                }
                mutate(
                    &mut self.genomes[s1 * dim..(s1 + 1) * dim],
                    &self.bounds,
                    params.mutation_prob,
                    &mut rng,
                );
                mutate(&mut self.c2, &self.bounds, params.mutation_prob, &mut rng);
                self.eval_slot(problem, s1, dim, m);
                evaluations += 1;
                filled += 1;
                if filled < pop {
                    let s2 = pop + filled;
                    let (c2, genomes) = (&self.c2, &mut self.genomes);
                    genomes[s2 * dim..(s2 + 1) * dim].copy_from_slice(c2);
                    self.eval_slot(problem, s2, dim, m);
                    evaluations += 1;
                    filled += 1;
                }
            }

            // Elitist (μ+λ) environmental selection.
            self.sort_and_crowd(cap, m);
            self.select_survivors(pop);
            self.compact(dim, m);

            if params.stagnation_patience > 0 {
                self.front_signature(pop, dim);
                if self.sig == self.prev_sig {
                    stagnant += 1;
                } else {
                    stagnant = 0;
                }
                std::mem::swap(&mut self.sig, &mut self.prev_sig);
                if stagnant >= params.stagnation_patience {
                    break;
                }
            }
        }

        // Final front 0, feasible only, deduplicated by genome.
        self.sort_and_crowd(pop, m);
        let mut members: Vec<Individual> = self.fronts[0]
            .iter()
            .map(|&s| Individual {
                genome: self.genomes[s * dim..(s + 1) * dim].to_vec(),
                objectives: self.objs[s * m..(s + 1) * m].to_vec(),
                violation: self.viol[s],
                rank: 0,
                crowding: self.crowd[s],
            })
            .collect();
        members.retain(|m| m.violation == 0.0);
        members.sort_by(|a, b| a.genome.cmp(&b.genome));
        members.dedup_by(|a, b| a.genome == b.genome);
        ParetoSet { members, generations_run, evaluations }
    }
}

/// Run NSGA-II on `problem` with one-shot solver state. Fleet paths that
/// solve repeatedly should hold a [`Nsga2Solver`] and call
/// [`Nsga2Solver::solve`] to amortise the buffer allocations.
pub fn optimize<P: Problem>(problem: &P, params: &Nsga2Params) -> ParetoSet {
    Nsga2Solver::new().solve(problem, params)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Schaffer's SCH: f1 = x², f2 = (x-2)² — Pareto front is x ∈ [0, 2].
    struct Sch;

    impl Problem for Sch {
        fn bounds(&self) -> Vec<(i64, i64)> {
            vec![(-1000, 1000)]
        }
        fn objectives(&self, g: &Genome) -> Vec<f64> {
            let x = g[0] as f64 / 100.0;
            vec![x * x, (x - 2.0) * (x - 2.0)]
        }
        fn num_objectives(&self) -> usize {
            2
        }
    }

    fn ind(objs: Vec<f64>, violation: f64) -> Individual {
        Individual { genome: vec![], objectives: objs, violation, rank: 0, crowding: 0.0 }
    }

    #[test]
    fn domination_rules() {
        let a = ind(vec![1.0, 1.0], 0.0);
        let b = ind(vec![2.0, 1.0], 0.0);
        let c = ind(vec![0.5, 2.0], 0.0);
        assert!(dominates(&a, &b));
        assert!(!dominates(&b, &a));
        assert!(!dominates(&a, &c) && !dominates(&c, &a)); // incomparable
        assert!(!dominates(&a, &a)); // strictness
        // constraint domination
        let infeasible = ind(vec![0.0, 0.0], 1.0);
        let worse_infeasible = ind(vec![0.0, 0.0], 2.0);
        assert!(dominates(&a, &infeasible));
        assert!(!dominates(&infeasible, &a));
        assert!(dominates(&infeasible, &worse_infeasible));
    }

    #[test]
    fn non_dominated_sort_fronts() {
        let mut pop = vec![
            ind(vec![1.0, 4.0], 0.0), // front 0
            ind(vec![4.0, 1.0], 0.0), // front 0
            ind(vec![2.0, 5.0], 0.0), // dominated by 0
            ind(vec![5.0, 5.0], 0.0), // dominated by all above
        ];
        let fronts = fast_non_dominated_sort(&mut pop);
        assert_eq!(fronts[0], vec![0, 1]);
        assert_eq!(fronts[1], vec![2]);
        assert_eq!(fronts[2], vec![3]);
        assert_eq!(pop[3].rank, 2);
    }

    #[test]
    fn crowding_extremes_infinite() {
        let mut pop = vec![
            ind(vec![0.0, 3.0], 0.0),
            ind(vec![1.0, 2.0], 0.0),
            ind(vec![2.0, 1.0], 0.0),
            ind(vec![3.0, 0.0], 0.0),
        ];
        let front: Vec<usize> = (0..4).collect();
        crowding_distance(&mut pop, &front);
        assert!(pop[0].crowding.is_infinite());
        assert!(pop[3].crowding.is_infinite());
        assert!(pop[1].crowding.is_finite() && pop[1].crowding > 0.0);
    }

    #[test]
    fn solves_sch() {
        let set = optimize(&Sch, &Nsga2Params { pop_size: 60, generations: 60, ..Default::default() });
        assert!(!set.members.is_empty());
        // Every member of the front must be in [0, 2] (x scaled by 100).
        for m in &set.members {
            let x = m.genome[0] as f64 / 100.0;
            assert!(
                (-0.05..=2.05).contains(&x),
                "non-Pareto member x={x} objs={:?}",
                m.objectives
            );
        }
        // The front should cover the range reasonably well.
        let xs: Vec<f64> = set.members.iter().map(|m| m.genome[0] as f64 / 100.0).collect();
        let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert!(min < 0.3, "front min {min}");
        assert!(max > 1.7, "front max {max}");
    }

    #[test]
    fn deterministic_for_seed() {
        let p = Nsga2Params { pop_size: 30, generations: 20, ..Default::default() };
        let a = optimize(&Sch, &p);
        let b = optimize(&Sch, &p);
        let g = |s: &ParetoSet| s.members.iter().map(|m| m.genome.clone()).collect::<Vec<_>>();
        assert_eq!(g(&a), g(&b));
    }

    #[test]
    fn solver_reuse_matches_fresh_runs() {
        // A reused solver must be stateless between solves: alternating
        // problems and shapes, every result equals a fresh-solver run.
        let mut solver = Nsga2Solver::new();
        for (pop, gens) in [(20usize, 15usize), (40, 25), (12, 10)] {
            let p = Nsga2Params { pop_size: pop, generations: gens, ..Default::default() };
            let reused = solver.solve(&Sch, &p);
            let fresh = optimize(&Sch, &p);
            let g = |s: &ParetoSet| s.members.iter().map(|m| m.genome.clone()).collect::<Vec<_>>();
            assert_eq!(g(&reused), g(&fresh), "pop={pop} gens={gens}");
            assert_eq!(reused.evaluations, fresh.evaluations);
        }
    }

    /// SCH at 1/10 scale: a compact 21-point true front that a 40-member
    /// population saturates — the shape the stagnation check targets
    /// (SmartSplit's split domain is this small).
    struct SmallSch;

    impl Problem for SmallSch {
        fn bounds(&self) -> Vec<(i64, i64)> {
            vec![(-50, 50)]
        }
        fn objectives(&self, g: &Genome) -> Vec<f64> {
            let x = g[0] as f64 / 10.0;
            vec![x * x, (x - 2.0) * (x - 2.0)]
        }
        fn num_objectives(&self) -> usize {
            2
        }
    }

    #[test]
    fn stagnation_stops_early_with_valid_front() {
        // A population that saturates the tiny front stops churning its
        // distinct genome set quickly; the stagnation check must fire
        // well before the generation budget, and every member of the
        // early-stopped front must still lie on the true front.
        let patient = Nsga2Params {
            pop_size: 40,
            generations: 300,
            stagnation_patience: 6,
            ..Default::default()
        };
        let set = optimize(&SmallSch, &patient);
        assert!(
            set.generations_run < 300,
            "no early stop: ran {} generations",
            set.generations_run
        );
        assert!(set.evaluations < 40 + 300 * 40);
        assert!(!set.members.is_empty());
        for m in &set.members {
            let x = m.genome[0] as f64 / 10.0;
            assert!((0.0..=2.0).contains(&x), "off-front member x={x}");
        }
        // The stagnation check only fires after the front held still for
        // `patience` generations, so the early-stopped front is at least
        // patience-generations stable — it must span the trade-off, not
        // collapse to a corner.
        let xs: Vec<f64> = set.members.iter().map(|m| m.genome[0] as f64 / 10.0).collect();
        let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert!(max - min > 1.0, "degenerate early-stopped front [{min}, {max}]");
    }

    #[test]
    fn tiny_genome_preset_is_budgeted() {
        let p = Nsga2Params::for_tiny_genome();
        assert!(p.pop_size * p.generations < 2000, "preset not tiny");
        assert!(p.stagnation_patience > 0, "preset must early-stop");
        // Canonical defaults stay canonical for the paper benches.
        let d = Nsga2Params::default();
        assert_eq!((d.pop_size, d.generations, d.stagnation_patience), (100, 250, 0));
    }

    #[test]
    fn small_genome_preset_scales_with_dim() {
        let one = Nsga2Params::for_small_genome(1);
        let tiny = Nsga2Params::for_tiny_genome();
        assert_eq!((one.pop_size, one.generations), (tiny.pop_size, tiny.generations));
        assert_eq!(one.stagnation_patience, tiny.stagnation_patience);
        let two = Nsga2Params::for_small_genome(2);
        assert!(two.pop_size > one.pop_size && two.generations > one.generations);
        assert!(two.stagnation_patience > one.stagnation_patience);
        // Degenerate dim clamps to 1.
        assert_eq!(Nsga2Params::for_small_genome(0).pop_size, one.pop_size);
    }

    #[test]
    fn infeasible_candidates_excluded_from_result() {
        struct OnlyBig;
        impl Problem for OnlyBig {
            fn bounds(&self) -> Vec<(i64, i64)> {
                vec![(0, 10)]
            }
            fn objectives(&self, g: &Genome) -> Vec<f64> {
                vec![g[0] as f64, -(g[0] as f64)]
            }
            fn violation(&self, g: &Genome) -> f64 {
                if g[0] >= 5 { 0.0 } else { (5 - g[0]) as f64 }
            }
            fn num_objectives(&self) -> usize {
                2
            }
        }
        let set = optimize(&OnlyBig, &Nsga2Params { pop_size: 20, generations: 30, ..Default::default() });
        assert!(!set.members.is_empty());
        for m in &set.members {
            assert!(m.genome[0] >= 5, "infeasible member {:?}", m.genome);
        }
    }

    #[test]
    fn evaluation_count_reported() {
        let p = Nsga2Params { pop_size: 10, generations: 5, ..Default::default() };
        let set = optimize(&Sch, &p);
        assert_eq!(set.evaluations, 10 + 5 * 10);
    }

    #[test]
    fn insertion_sort_matches_std_stable_sort() {
        // Same permutation as slice::sort_by (stability included), on a
        // tie-heavy input longer than std's allocation-free threshold.
        let mut rng = Xoshiro256::seed_from_u64(99);
        let vals: Vec<f64> = (0..60).map(|_| rng.gen_range(0, 7) as f64).collect();
        let mut std_sorted: Vec<usize> = (0..vals.len()).collect();
        let mut ours = std_sorted.clone();
        std_sorted.sort_by(|&x, &y| vals[x].partial_cmp(&vals[y]).unwrap());
        insertion_sort_by(&mut ours, |x, y| vals[x].partial_cmp(&vals[y]).unwrap());
        assert_eq!(std_sorted, ours);
    }

    #[test]
    fn soa_sort_and_crowd_matches_reference_under_ties() {
        // Duplicate and tied objective rows are the norm on SmartSplit's
        // tiny split domain; the SoA engine must assign exactly the ranks
        // and crowding distances of the retained reference functions
        // (stable-sort tie handling included), or seeded selection drifts.
        let rows: Vec<Vec<f64>> = vec![
            vec![0.0, 3.0],
            vec![1.0, 2.0],
            vec![1.0, 2.0], // duplicate of the row above
            vec![2.0, 1.0],
            vec![0.0, 3.0], // duplicate of row 0
            vec![3.0, 0.0],
            vec![2.0, 2.0], // dominated
            vec![1.0, 2.5], // dominated, tied with row 1 on obj 0
        ];
        let mut pop: Vec<Individual> = rows.iter().map(|r| ind(r.clone(), 0.0)).collect();
        let fronts = fast_non_dominated_sort(&mut pop);
        for f in &fronts {
            crowding_distance(&mut pop, f);
        }
        let n = rows.len();
        let mut solver = Nsga2Solver::new();
        solver.reset(n, 1, 2, vec![(0, 10)]);
        for (s, r) in rows.iter().enumerate() {
            solver.objs[s * 2..(s + 1) * 2].copy_from_slice(r);
        }
        solver.sort_and_crowd(n, 2);
        for s in 0..n {
            assert_eq!(solver.rank[s], pop[s].rank, "rank of row {s}");
            let (a, b) = (solver.crowd[s], pop[s].crowding);
            assert!(
                a == b || (a.is_infinite() && b.is_infinite()),
                "crowding of row {s}: {a} vs {b}"
            );
        }
    }

    #[test]
    fn objectives_into_default_matches_objectives() {
        let g: Genome = vec![150];
        let direct = Sch.objectives(&g);
        let mut out = vec![0.0; 2];
        Sch.objectives_into(&g, &mut out);
        assert_eq!(direct, out);
        assert_eq!(Sch.violation_of(&g), Sch.violation(&g));
    }
}
