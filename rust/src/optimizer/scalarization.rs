//! Classical scalarisation solvers the paper argues NSGA-II against
//! (§V-A): weighted sum [50], weighted metric [51], and ε-constrained
//! optimisation [49]. Implemented as first-class baselines so the
//! `ablation_solver` bench can quantify the §V-A claim ("NSGA-II provides
//! solutions much closer to the Pareto front than ... ε-constrained
//! optimisation, weighted sum, or weighted metric methods") instead of
//! taking it on faith.
//!
//! All three operate on the same memoised objective table as the GA
//! ([`SplitProblem`]-style enumeration — the split domain is tiny) with
//! min-max normalised objectives, so differences are purely about the
//! selection rule, not the evaluation.

use crate::perfmodel::PerfModel;

/// Min-max normalised objective matrix over the feasible split domain.
/// Returns (split indices, normalised rows).
fn normalised_domain(pm: &PerfModel<'_>) -> (Vec<usize>, Vec<[f64; 3]>) {
    let l = pm.profile.num_layers;
    let splits: Vec<usize> = (1..l).filter(|&i| pm.feasible(i)).collect();
    let raw: Vec<[f64; 3]> = splits.iter().map(|&i| pm.objectives(i)).collect();
    let mut lo = [f64::INFINITY; 3];
    let mut hi = [f64::NEG_INFINITY; 3];
    for r in &raw {
        for j in 0..3 {
            lo[j] = lo[j].min(r[j]);
            hi[j] = hi[j].max(r[j]);
        }
    }
    let norm = raw
        .iter()
        .map(|r| {
            let mut out = [0.0; 3];
            for j in 0..3 {
                let span = hi[j] - lo[j];
                out[j] = if span > 0.0 { (r[j] - lo[j]) / span } else { 0.0 };
            }
            out
        })
        .collect();
    (splits, norm)
}

/// Weighted-sum method (Marler & Arora [50]): argmin Σ w_j · f'_j.
/// Provably blind to non-convex regions of the Pareto front.
pub fn weighted_sum(pm: &PerfModel<'_>, weights: [f64; 3]) -> Option<usize> {
    let (splits, norm) = normalised_domain(pm);
    splits
        .iter()
        .zip(&norm)
        .min_by(|(_, a), (_, b)| {
            let sa: f64 = a.iter().zip(&weights).map(|(x, w)| x * w).sum();
            let sb: f64 = b.iter().zip(&weights).map(|(x, w)| x * w).sum();
            sa.partial_cmp(&sb).unwrap()
        })
        .map(|(&i, _)| i)
}

/// Weighted-metric (compromise programming, [51]): argmin ‖w ⊙ f'‖_p.
/// `p = 2` is the common Euclidean variant; `p → ∞` approaches Chebyshev.
pub fn weighted_metric(pm: &PerfModel<'_>, weights: [f64; 3], p: f64) -> Option<usize> {
    assert!(p >= 1.0, "metric order must be ≥ 1");
    let (splits, norm) = normalised_domain(pm);
    splits
        .iter()
        .zip(&norm)
        .min_by(|(_, a), (_, b)| {
            let m = |r: &[f64; 3]| -> f64 {
                r.iter()
                    .zip(&weights)
                    .map(|(x, w)| (w * x).powf(p))
                    .sum::<f64>()
                    .powf(1.0 / p)
            };
            m(a).partial_cmp(&m(b)).unwrap()
        })
        .map(|(&i, _)| i)
}

/// ε-constrained optimisation (Chankong & Haimes [49]): minimise the
/// `primary` objective subject to the other two staying under the given
/// normalised ceilings. Returns `None` when the ε box is infeasible —
/// the practical weakness the paper alludes to (ceilings must be guessed).
pub fn epsilon_constrained(
    pm: &PerfModel<'_>,
    primary: usize,
    epsilon: [f64; 3],
) -> Option<usize> {
    assert!(primary < 3);
    let (splits, norm) = normalised_domain(pm);
    splits
        .iter()
        .zip(&norm)
        .filter(|(_, r)| (0..3).all(|j| j == primary || r[j] <= epsilon[j]))
        .min_by(|(_, a), (_, b)| a[primary].partial_cmp(&b[primary]).unwrap())
        .map(|(&i, _)| i)
}

/// The exhaustive true Pareto front of the feasible split domain
/// (ground truth for the solver ablation; tractable because |domain| < 40).
pub fn exhaustive_pareto_front(pm: &PerfModel<'_>) -> Vec<usize> {
    let l = pm.profile.num_layers;
    let cands: Vec<(usize, [f64; 3])> =
        (1..l).filter(|&i| pm.feasible(i)).map(|i| (i, pm.objectives(i))).collect();
    cands
        .iter()
        .filter(|(_, a)| {
            !cands.iter().any(|(_, b)| {
                b.iter().zip(a).all(|(x, y)| x <= y) && b.iter().zip(a).any(|(x, y)| x < y)
            })
        })
        .map(|(i, _)| *i)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::models::zoo;
    use crate::perfmodel::{NetworkEnv, RadioPower};

    fn pm(profile: &crate::models::ModelProfile) -> PerfModel<'_> {
        PerfModel::new(
            profiles::samsung_j6(),
            profiles::cloud_server(),
            RadioPower::PAPER_80211N,
            NetworkEnv::paper_default(),
            profile,
        )
    }

    #[test]
    fn scalarisation_picks_live_on_the_true_front() {
        // Any scalarisation optimum must be Pareto-optimal (sanity for all
        // three methods).
        let profile = zoo::vgg16().analyze(1);
        let m = pm(&profile);
        let front = exhaustive_pareto_front(&m);
        for w in [[1.0, 1.0, 1.0], [3.0, 1.0, 1.0], [1.0, 5.0, 1.0]] {
            let ws = weighted_sum(&m, w).unwrap();
            assert!(front.contains(&ws), "weighted_sum {w:?} chose off-front {ws}");
            let wm = weighted_metric(&m, w, 2.0).unwrap();
            assert!(front.contains(&wm), "weighted_metric {w:?} chose off-front {wm}");
        }
    }

    #[test]
    fn weighted_sum_extreme_weights_recover_single_objective_optima() {
        let profile = zoo::alexnet().analyze(1);
        let m = pm(&profile);
        let latency_only = weighted_sum(&m, [1.0, 0.0, 0.0]).unwrap();
        assert_eq!(latency_only, crate::optimizer::lbo(&m).l1);
        let energy_only = weighted_sum(&m, [0.0, 1.0, 0.0]).unwrap();
        assert_eq!(energy_only, crate::optimizer::ebo(&m).l1);
    }

    #[test]
    fn epsilon_constrained_respects_ceilings() {
        let profile = zoo::vgg11().analyze(1);
        let m = pm(&profile);
        let (splits, norm) = super::normalised_domain(&m);
        let eps = [1.0, 0.3, 0.3];
        if let Some(choice) = epsilon_constrained(&m, 0, eps) {
            let idx = splits.iter().position(|&s| s == choice).unwrap();
            assert!(norm[idx][1] <= 0.3 && norm[idx][2] <= 0.3);
        }
        // Impossible box → None, not a bogus answer.
        assert_eq!(epsilon_constrained(&m, 0, [1.0, -0.1, -0.1]), None);
    }

    #[test]
    fn weighted_metric_p1_equals_weighted_sum() {
        let profile = zoo::vgg13().analyze(1);
        let m = pm(&profile);
        for w in [[1.0, 1.0, 1.0], [2.0, 1.0, 3.0]] {
            assert_eq!(weighted_metric(&m, w, 1.0), weighted_sum(&m, w));
        }
    }

    #[test]
    fn exhaustive_front_is_mutually_nondominated() {
        let profile = zoo::alexnet().analyze(1);
        let m = pm(&profile);
        let front = exhaustive_pareto_front(&m);
        assert!(!front.is_empty());
        for &a in &front {
            for &b in &front {
                if a == b {
                    continue;
                }
                let oa = m.objectives(a);
                let ob = m.objectives(b);
                let dom = ob.iter().zip(&oa).all(|(x, y)| x <= y)
                    && ob.iter().zip(&oa).any(|(x, y)| x < y);
                assert!(!dom, "{b} dominates {a} inside the front");
            }
        }
    }
}
