//! TOPSIS decision analysis (Behzadian et al. [44]) — the second half of
//! Algorithm 1: pick the single best compromise from the NSGA-II Pareto
//! set.
//!
//! Steps exactly as the paper's Algorithm 1 lines 2–7:
//! 1. decision matrix `F` (n solutions × m objectives);
//! 2. column (vector) normalisation → `F'`;
//! 3. drop constraint-violating rows → `F''`;
//! 4. ideal point = column-wise minimum (all objectives minimised);
//! 5. Euclidean distance of every row to the ideal;
//! 6. select the row with minimum distance.

/// Outcome of TOPSIS over a candidate matrix.
#[derive(Clone, Debug)]
pub struct TopsisResult {
    /// Index (into the *input* rows) of the chosen solution.
    pub chosen: usize,
    /// Distance to the ideal point per retained row (input indexing;
    /// `f64::INFINITY` for rows dropped by the constraint filter).
    pub distances: Vec<f64>,
    /// The normalised ideal point.
    pub ideal: Vec<f64>,
}

/// Run TOPSIS. `rows[i]` is the objective vector of solution `i`;
/// `feasible[i]` is the Eq. 17 constraint check (Algorithm 1's reduction
/// from `F'` to `F''`). Returns `None` when no feasible row exists.
pub fn topsis(rows: &[Vec<f64>], feasible: &[bool]) -> Option<TopsisResult> {
    assert_eq!(rows.len(), feasible.len());
    if rows.is_empty() {
        return None;
    }
    let m = rows[0].len();
    assert!(rows.iter().all(|r| r.len() == m), "ragged objective matrix");

    // Column-wise vector normalisation: f'_ij = f_ij / sqrt(Σ_i f_ij²).
    let mut norms = vec![0.0f64; m];
    for r in rows {
        for (j, v) in r.iter().enumerate() {
            norms[j] += v * v;
        }
    }
    for n in &mut norms {
        *n = n.sqrt();
        if *n == 0.0 {
            *n = 1.0; // constant-zero column: normalised values stay 0
        }
    }
    let normalised: Vec<Vec<f64>> = rows
        .iter()
        .map(|r| r.iter().enumerate().map(|(j, v)| v / norms[j]).collect())
        .collect();

    // Ideal point over feasible rows only.
    let mut ideal = vec![f64::INFINITY; m];
    for (i, r) in normalised.iter().enumerate() {
        if !feasible[i] {
            continue;
        }
        for (j, v) in r.iter().enumerate() {
            ideal[j] = ideal[j].min(*v);
        }
    }
    if ideal.iter().any(|v| v.is_infinite()) {
        return None; // no feasible rows
    }

    // Euclidean distances; infeasible rows excluded.
    let mut best = None;
    let distances: Vec<f64> = normalised
        .iter()
        .enumerate()
        .map(|(i, r)| {
            if !feasible[i] {
                return f64::INFINITY;
            }
            let d = r
                .iter()
                .zip(&ideal)
                .map(|(v, id)| (v - id) * (v - id))
                .sum::<f64>()
                .sqrt();
            match best {
                None => best = Some((i, d)),
                Some((_, bd)) if d < bd => best = Some((i, d)),
                _ => {}
            }
            d
        })
        .collect();

    best.map(|(chosen, _)| TopsisResult { chosen, distances, ideal })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_prop;
    use crate::prop_assert;

    #[test]
    fn picks_dominating_row() {
        let rows = vec![
            vec![1.0, 1.0, 1.0], // dominates everything
            vec![2.0, 3.0, 4.0],
            vec![5.0, 1.5, 2.0],
        ];
        let r = topsis(&rows, &[true, true, true]).unwrap();
        assert_eq!(r.chosen, 0);
        assert_eq!(r.distances[0], 0.0); // the ideal itself
    }

    #[test]
    fn trades_off_between_extremes() {
        // Two extreme specialists and one balanced row: the balanced row is
        // closest to the joint ideal.
        let rows = vec![
            vec![0.0, 10.0],
            vec![10.0, 0.0],
            vec![2.0, 2.0],
        ];
        let r = topsis(&rows, &[true, true, true]).unwrap();
        assert_eq!(r.chosen, 2);
    }

    #[test]
    fn constraint_filter_excludes_rows() {
        let rows = vec![
            vec![0.1, 0.1], // infeasible — would otherwise win
            vec![5.0, 5.0],
        ];
        let r = topsis(&rows, &[false, true]).unwrap();
        assert_eq!(r.chosen, 1);
        assert!(r.distances[0].is_infinite());
    }

    #[test]
    fn no_feasible_rows_is_none() {
        assert!(topsis(&[vec![1.0]], &[false]).is_none());
        assert!(topsis(&[], &[]).is_none());
    }

    #[test]
    fn zero_column_handled() {
        let rows = vec![vec![0.0, 1.0], vec![0.0, 2.0]];
        let r = topsis(&rows, &[true, true]).unwrap();
        assert_eq!(r.chosen, 0);
    }

    #[test]
    fn scale_invariance_of_choice() {
        // Vector normalisation makes the choice invariant to per-column
        // positive rescaling.
        let rows = vec![
            vec![1.0, 8.0, 3.0],
            vec![4.0, 2.0, 6.0],
            vec![3.0, 3.0, 3.0],
        ];
        let a = topsis(&rows, &[true, true, true]).unwrap().chosen;
        let scaled: Vec<Vec<f64>> = rows
            .iter()
            .map(|r| vec![r[0] * 1000.0, r[1] * 0.01, r[2] * 7.0])
            .collect();
        let b = topsis(&scaled, &[true, true, true]).unwrap().chosen;
        assert_eq!(a, b);
    }

    #[test]
    fn prop_chosen_is_feasible_and_min_distance() {
        run_prop("topsis picks feasible min-distance row", 200, |g| {
            let n = g.usize_in(1, 30);
            let m = g.usize_in(1, 5);
            let rows: Vec<Vec<f64>> =
                (0..n).map(|_| (0..m).map(|_| g.f64_in(0.0, 100.0)).collect()).collect();
            let feasible: Vec<bool> = (0..n).map(|_| g.bool()).collect();
            match topsis(&rows, &feasible) {
                None => {
                    prop_assert!(
                        feasible.iter().all(|f| !f),
                        "returned None with feasible rows present"
                    );
                }
                Some(r) => {
                    prop_assert!(feasible[r.chosen], "chose infeasible row");
                    let min = r
                        .distances
                        .iter()
                        .cloned()
                        .fold(f64::INFINITY, f64::min);
                    prop_assert!(
                        (r.distances[r.chosen] - min).abs() < 1e-12,
                        "chosen {} dist {} but min {}",
                        r.chosen, r.distances[r.chosen], min
                    );
                }
            }
            Ok(())
        });
    }
}
