//! The paper's competing algorithms (§VI-C): LBO, EBO, COS, COC, RS —
//! plus SmartSplit itself behind the same [`Splitter`] interface so the
//! comparison benches treat all six uniformly.

use crate::perfmodel::PerfModel;
use crate::util::rng::Xoshiro256;

use super::nsga2::{optimize, Nsga2Params};
use super::problem::SplitProblem;
use super::topsis::topsis;

/// A split decision: how many layers stay on the smartphone.
/// `l1 == 0` means COC (everything on the cloud); `l1 == L` means COS.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SplitDecision {
    pub l1: usize,
}

/// The six §VI-C algorithms.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algorithm {
    SmartSplit,
    /// Latency-based optimisation: argmin f1.
    Lbo,
    /// Energy-based optimisation: argmin f2.
    Ebo,
    /// CNN on smartphone: l1 = L.
    Cos,
    /// CNN on cloud: l1 = 0.
    Coc,
    /// Random split per run.
    Rs,
}

impl Algorithm {
    pub const ALL: [Algorithm; 6] = [
        Algorithm::SmartSplit,
        Algorithm::Lbo,
        Algorithm::Ebo,
        Algorithm::Cos,
        Algorithm::Coc,
        Algorithm::Rs,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::SmartSplit => "SmartSplit",
            Algorithm::Lbo => "LBO",
            Algorithm::Ebo => "EBO",
            Algorithm::Cos => "COS",
            Algorithm::Coc => "COC",
            Algorithm::Rs => "RS",
        }
    }

    /// Case-insensitive lookup; the error lists every valid name.
    /// (The full strategy space — these six plus Topsis and the
    /// scalarisation methods — parses via
    /// [`crate::planner::Strategy::by_name`].)
    pub fn by_name(name: &str) -> Result<Algorithm, String> {
        Self::ALL
            .iter()
            .copied()
            .find(|a| a.name().eq_ignore_ascii_case(name))
            .ok_or_else(|| {
                let names: Vec<&str> = Self::ALL.iter().map(|a| a.name()).collect();
                format!("unknown algorithm {name:?} (valid: {})", names.join(", "))
            })
    }
}

/// Feasible split domain for the single-variable baselines: 1..=L-1
/// (Eq. 17 requires at least one layer on each side).
fn feasible_splits(pm: &PerfModel<'_>) -> Vec<usize> {
    (1..pm.profile.num_layers).filter(|&l1| pm.feasible(l1)).collect()
}

/// Latency-based optimisation: the best split under f1 alone (Tang et
/// al. [14]-style).
pub fn lbo(pm: &PerfModel<'_>) -> SplitDecision {
    let l1 = feasible_splits(pm)
        .into_iter()
        .min_by(|&a, &b| pm.f1(a).partial_cmp(&pm.f1(b)).unwrap())
        .expect("no feasible split");
    SplitDecision { l1 }
}

/// Energy-based optimisation: the best split under f2 alone (the paper
/// designs this baseline itself, §VI-C2).
pub fn ebo(pm: &PerfModel<'_>) -> SplitDecision {
    let l1 = feasible_splits(pm)
        .into_iter()
        .min_by(|&a, &b| pm.f2(a).partial_cmp(&pm.f2(b)).unwrap())
        .expect("no feasible split");
    SplitDecision { l1 }
}

/// Everything on the phone.
pub fn cos(pm: &PerfModel<'_>) -> SplitDecision {
    SplitDecision { l1: pm.profile.num_layers }
}

/// Everything on the cloud.
pub fn coc(_pm: &PerfModel<'_>) -> SplitDecision {
    SplitDecision { l1: 0 }
}

/// Random split, uniform over 1..=L-1 (paper: "a random number is selected
/// for each run").
pub fn rs(pm: &PerfModel<'_>, rng: &mut Xoshiro256) -> SplitDecision {
    SplitDecision { l1: rng.gen_range(1, pm.profile.num_layers - 1) }
}

/// Output of a full SmartSplit run (Algorithm 1): the Pareto set and the
/// TOPSIS choice.
#[derive(Clone, Debug)]
pub struct SmartSplitResult {
    pub decision: SplitDecision,
    /// Pareto-set split indices (sorted) with their objective vectors.
    pub pareto: Vec<(usize, [f64; 3])>,
    pub evaluations: u64,
}

/// Algorithm 1: NSGA-II → Pareto set → TOPSIS → optimal split.
pub fn smartsplit(pm: &PerfModel<'_>, params: &Nsga2Params) -> SmartSplitResult {
    let problem = SplitProblem::new(pm);
    let set = optimize(&problem, params);
    let pareto: Vec<(usize, [f64; 3])> = set
        .members
        .iter()
        .map(|m| {
            let l1 = m.genome[0] as usize;
            (l1, problem.objectives_at(l1))
        })
        .collect();
    let rows: Vec<Vec<f64>> = pareto.iter().map(|(_, o)| o.to_vec()).collect();
    let feasible: Vec<bool> = pareto.iter().map(|(l1, _)| problem.feasible_at(*l1)).collect();
    let choice = topsis(&rows, &feasible).expect("Pareto set has no feasible member");
    SmartSplitResult {
        decision: SplitDecision { l1: pareto[choice.chosen].0 },
        pareto,
        evaluations: set.evaluations,
    }
}

/// Uniform interface over the six §VI-C algorithms.
///
/// Pre-façade entry point, frozen as the parity reference for
/// `tests/planner_parity.rs` — plan through
/// [`crate::planner::Planner`] instead.
#[deprecated(note = "plan through planner::Planner (one PlanRequest → PlanOutcome API)")]
pub fn decide(
    algo: Algorithm,
    pm: &PerfModel<'_>,
    params: &Nsga2Params,
    rng: &mut Xoshiro256,
) -> SplitDecision {
    match algo {
        Algorithm::SmartSplit => smartsplit(pm, params).decision,
        Algorithm::Lbo => lbo(pm),
        Algorithm::Ebo => ebo(pm),
        Algorithm::Cos => cos(pm),
        Algorithm::Coc => coc(pm),
        Algorithm::Rs => rs(pm, rng),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::models::zoo;
    use crate::perfmodel::{NetworkEnv, PerfModel, RadioPower};

    fn pm(profile: &crate::models::ModelProfile) -> PerfModel<'_> {
        PerfModel::new(
            profiles::samsung_j6(),
            profiles::cloud_server(),
            RadioPower::PAPER_80211N,
            NetworkEnv::paper_default(),
            profile,
        )
    }

    #[test]
    fn lbo_minimises_latency_over_domain() {
        let p = zoo::alexnet().analyze(1);
        let m = pm(&p);
        let d = lbo(&m);
        for l1 in 1..21 {
            assert!(m.f1(d.l1) <= m.f1(l1) + 1e-12);
        }
    }

    #[test]
    fn ebo_minimises_energy_over_domain() {
        let p = zoo::vgg11().analyze(1);
        let m = pm(&p);
        let d = ebo(&m);
        for l1 in 1..29 {
            assert!(m.f2(d.l1) <= m.f2(l1) + 1e-12);
        }
    }

    #[test]
    fn cos_coc_extremes() {
        let p = zoo::alexnet().analyze(1);
        let m = pm(&p);
        assert_eq!(cos(&m).l1, 21);
        assert_eq!(coc(&m).l1, 0);
    }

    #[test]
    fn rs_stays_in_split_domain() {
        let p = zoo::alexnet().analyze(1);
        let m = pm(&p);
        let mut rng = Xoshiro256::seed_from_u64(1);
        for _ in 0..200 {
            let d = rs(&m, &mut rng);
            assert!((1..21).contains(&d.l1));
        }
    }

    #[test]
    fn smartsplit_decision_is_on_pareto_front_and_feasible() {
        let p = zoo::alexnet().analyze(1);
        let m = pm(&p);
        let params = Nsga2Params { pop_size: 40, generations: 40, ..Default::default() };
        let r = smartsplit(&m, &params);
        assert!(m.feasible(r.decision.l1));
        assert!(r.pareto.iter().any(|(l1, _)| *l1 == r.decision.l1));
        // No Pareto member may dominate another (front invariant).
        for (i, (_, a)) in r.pareto.iter().enumerate() {
            for (j, (_, b)) in r.pareto.iter().enumerate() {
                if i == j {
                    continue;
                }
                let dominates =
                    a.iter().zip(b).all(|(x, y)| x <= y) && a.iter().zip(b).any(|(x, y)| x < y);
                assert!(!dominates, "pareto member {j} dominated by {i}");
            }
        }
    }

    #[test]
    fn algorithm_names_roundtrip() {
        for a in Algorithm::ALL {
            assert_eq!(Algorithm::by_name(a.name()), Ok(a));
        }
        assert_eq!(Algorithm::by_name("smartsplit"), Ok(Algorithm::SmartSplit));
        assert_eq!(Algorithm::by_name("LBO"), Ok(Algorithm::Lbo));
        let err = Algorithm::by_name("nope").unwrap_err();
        for a in Algorithm::ALL {
            assert!(err.contains(a.name()), "error {err:?} misses {}", a.name());
        }
    }
}
