//! The tiered optimisation problem: a 2-D genome `[l1, l2]` over the
//! same NSGA-II engine, plus the exhaustive tiered Pareto front and the
//! band-weighted TOPSIS pick — Algorithm 1 generalised to two split
//! points.
//!
//! §Perf note: the 2-D split domain is still tiny (`L² ≤ 1444`
//! candidates), so both objective vectors and violations are memoised
//! up front exactly like [`crate::optimizer::SplitProblem`]; the
//! solver's ~10⁴ evaluations are table reads. Candidates with
//! `l1 > l2` keep a graded violation so Deb's constraint-domination
//! rule breeds them out — every member of the returned front satisfies
//! `l1 ≤ l2` by construction (`tests/edge_props.rs`).

use crate::coordinator::battery::BatteryBand;
use crate::optimizer::nsga2::{Genome, Nsga2Params, Problem};
use crate::optimizer::topsis::topsis;

use super::perfmodel::TieredPerfModel;
use super::SplitPlan;

/// NSGA-II view of one tiered (model, device, edge site, network)
/// configuration.
pub struct TieredSplitProblem {
    num_layers: usize,
    /// Memoised `[f1, f2, f3]` for every `(l1, l2)` pair (row-major,
    /// index `(l1-1)·L + (l2-1)`). Unordered pairs store the sorted
    /// pair's objectives so values stay finite; their violation marks
    /// them infeasible regardless.
    objectives: Vec<[f64; 3]>,
    violations: Vec<f64>,
}

impl TieredSplitProblem {
    pub fn new(tpm: &TieredPerfModel<'_>) -> Self {
        let l = tpm.num_layers();
        let mut objectives: Vec<[f64; 3]> = Vec::with_capacity(l * l);
        let mut violations = Vec::with_capacity(l * l);
        for l1 in 1..=l {
            for l2 in 1..=l {
                // Unordered pairs mirror the sorted pair's (already
                // computed — it lives in an earlier row) objectives, so
                // only the feasible triangle walks the layer tables.
                let obj = if l2 >= l1 {
                    tpm.objectives(SplitPlan { l1, l2 })
                } else {
                    objectives[(l2 - 1) * l + (l1 - 1)]
                };
                objectives.push(obj);
                violations.push(tpm.violation(SplitPlan { l1, l2 }));
            }
        }
        TieredSplitProblem { num_layers: l, objectives, violations }
    }

    pub fn num_layers(&self) -> usize {
        self.num_layers
    }

    fn idx(&self, plan: SplitPlan) -> usize {
        (plan.l1 - 1) * self.num_layers + (plan.l2 - 1)
    }

    /// Memoised objective lookup for a concrete plan.
    pub fn objectives_at(&self, plan: SplitPlan) -> [f64; 3] {
        self.objectives[self.idx(plan)]
    }

    pub fn feasible_at(&self, plan: SplitPlan) -> bool {
        self.violations[self.idx(plan)] == 0.0
    }
}

impl Problem for TieredSplitProblem {
    fn bounds(&self) -> Vec<(i64, i64)> {
        vec![(1, self.num_layers as i64), (1, self.num_layers as i64)]
    }

    fn objectives(&self, g: &Genome) -> Vec<f64> {
        let i = (g[0] - 1) as usize * self.num_layers + (g[1] - 1) as usize;
        self.objectives[i].to_vec()
    }

    fn violation(&self, g: &Genome) -> f64 {
        let i = (g[0] - 1) as usize * self.num_layers + (g[1] - 1) as usize;
        self.violations[i]
    }

    fn num_objectives(&self) -> usize {
        3
    }

    /// Zero-alloc hot path: one memo-table row copy per evaluation.
    fn objectives_into(&self, g: &[i64], out: &mut [f64]) {
        let i = (g[0] - 1) as usize * self.num_layers + (g[1] - 1) as usize;
        out.copy_from_slice(&self.objectives[i]);
    }

    fn violation_of(&self, g: &[i64]) -> f64 {
        let i = (g[0] - 1) as usize * self.num_layers + (g[1] - 1) as usize;
        self.violations[i]
    }
}

/// The true Pareto front of the tiered problem with its objective
/// vectors, by exhaustive enumeration of the feasible `(l1, l2)`
/// triangle, in lexicographic order.
fn tiered_front_with_objectives(tpm: &TieredPerfModel<'_>) -> Vec<(SplitPlan, [f64; 3])> {
    let l = tpm.num_layers();
    let mut cands: Vec<(SplitPlan, [f64; 3])> = Vec::new();
    for l1 in 1..=l {
        for l2 in l1..=l {
            let plan = SplitPlan { l1, l2 };
            if tpm.feasible(plan) {
                cands.push((plan, tpm.objectives(plan)));
            }
        }
    }
    cands
        .iter()
        .filter(|(_, a)| {
            !cands.iter().any(|(_, b)| {
                b.iter().zip(a).all(|(x, y)| x <= y) && b.iter().zip(a).any(|(x, y)| x < y)
            })
        })
        .copied()
        .collect()
}

/// The true Pareto front of the tiered problem, by exhaustive
/// enumeration of the feasible `(l1, l2)` triangle. Returned in
/// lexicographic `(l1, l2)` order — with a disabled edge tier this is
/// exactly [`crate::optimizer::exhaustive_pareto_front`]'s order, which
/// is what makes the degenerate TOPSIS pick byte-comparable.
pub fn exhaustive_tiered_front(tpm: &TieredPerfModel<'_>) -> Vec<SplitPlan> {
    tiered_front_with_objectives(tpm).into_iter().map(|(p, _)| p).collect()
}

/// Battery-band-weighted TOPSIS over the exhaustive tiered front — the
/// tiered analogue of
/// [`crate::coordinator::battery::battery_aware_split_banded`] (the
/// `Topsis` planner kind). Deterministic by construction.
pub fn tiered_split_banded(tpm: &TieredPerfModel<'_>, band: BatteryBand) -> Option<SplitPlan> {
    let front = tiered_front_with_objectives(tpm);
    if front.is_empty() {
        return None;
    }
    let w = band.energy_weight();
    let rows: Vec<Vec<f64>> = front
        .iter()
        .map(|(_, o)| vec![o[0], o[1] * w, o[2]])
        .collect();
    let feasible = vec![true; rows.len()];
    topsis(&rows, &feasible).map(|r| front[r.chosen].0)
}

/// Full Algorithm 1 on the 2-D genome: NSGA-II Pareto set (through the
/// shared per-thread fleet solver), f2 column scaled by the battery
/// band, TOPSIS choice — the tiered analogue of
/// [`crate::optimizer::smartsplit_banded`].
pub fn tiered_smartsplit_banded(
    tpm: &TieredPerfModel<'_>,
    params: &Nsga2Params,
    band: BatteryBand,
) -> Option<SplitPlan> {
    let problem = TieredSplitProblem::new(tpm);
    let set = crate::optimizer::cache::with_fleet_solver(|s| s.solve(&problem, params));
    let plans: Vec<SplitPlan> = set
        .members
        .iter()
        .map(|m| SplitPlan { l1: m.genome[0] as usize, l2: m.genome[1] as usize })
        .collect();
    if plans.is_empty() {
        return None;
    }
    let w = band.energy_weight();
    let rows: Vec<Vec<f64>> = plans
        .iter()
        .map(|&p| {
            let o = problem.objectives_at(p);
            vec![o[0], o[1] * w, o[2]]
        })
        .collect();
    let feasible: Vec<bool> = plans.iter().map(|&p| problem.feasible_at(p)).collect();
    topsis(&rows, &feasible).map(|r| plans[r.chosen])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::edge::BackhaulLink;
    use crate::models::zoo;
    use crate::optimizer::exhaustive_pareto_front;
    use crate::perfmodel::{NetworkEnv, PerfModel, RadioPower};

    fn tiered(profile: &crate::models::ModelProfile, servers: usize) -> TieredPerfModel<'_> {
        TieredPerfModel::new(
            PerfModel::new(
                profiles::samsung_j6(),
                profiles::cloud_server(),
                RadioPower::PAPER_80211N,
                NetworkEnv::paper_default(),
                profile,
            ),
            profiles::edge_server(),
            servers,
            BackhaulLink::METRO_1GBE,
        )
    }

    #[test]
    fn memoisation_matches_direct_evaluation() {
        let profile = zoo::alexnet().analyze(1);
        let tpm = tiered(&profile, 2);
        let p = TieredSplitProblem::new(&tpm);
        for l1 in 1..=21 {
            for l2 in l1..=21 {
                let plan = SplitPlan { l1, l2 };
                assert_eq!(p.objectives_at(plan), tpm.objectives(plan));
                assert_eq!(p.feasible_at(plan), tpm.feasible(plan));
            }
        }
    }

    #[test]
    fn fast_paths_match_trait_defaults() {
        let profile = zoo::alexnet().analyze(1);
        let tpm = tiered(&profile, 2);
        let p = TieredSplitProblem::new(&tpm);
        for l1 in 1..=21i64 {
            for l2 in 1..=21i64 {
                let g = vec![l1, l2];
                let mut out = [0.0; 3];
                p.objectives_into(&g, &mut out);
                assert_eq!(out.to_vec(), p.objectives(&g));
                assert_eq!(p.violation_of(&g), p.violation(&g));
            }
        }
    }

    #[test]
    fn bounds_span_both_split_points() {
        let profile = zoo::alexnet().analyze(1);
        let tpm = tiered(&profile, 2);
        assert_eq!(TieredSplitProblem::new(&tpm).bounds(), vec![(1, 21), (1, 21)]);
    }

    #[test]
    fn unordered_genomes_are_infeasible() {
        let profile = zoo::alexnet().analyze(1);
        let tpm = tiered(&profile, 2);
        let p = TieredSplitProblem::new(&tpm);
        for l1 in 2..=21i64 {
            for l2 in 1..l1 {
                assert!(p.violation_of(&[l1, l2]) > 0.0, "({l1},{l2}) must violate");
            }
        }
    }

    #[test]
    fn disabled_edge_front_equals_two_tier_front() {
        // Zero servers + free backhaul: the tiered front must be the
        // two-tier front embedded on the diagonal, in the same order.
        let profile = zoo::alexnet().analyze(1);
        let mut tpm = tiered(&profile, 0);
        tpm.backhaul = BackhaulLink::FREE;
        let front = exhaustive_tiered_front(&tpm);
        let two_tier = exhaustive_pareto_front(&tpm.device);
        assert_eq!(
            front.iter().map(|p| p.l1).collect::<Vec<_>>(),
            two_tier,
            "tiered front diverged from the two-tier front"
        );
        assert!(front.iter().all(|p| p.l1 == p.l2), "non-diagonal plan in a relay topology");
    }

    #[test]
    fn nsga2_members_respect_ordering() {
        let profile = zoo::vgg16().analyze(1);
        let tpm = tiered(&profile, 4);
        let problem = TieredSplitProblem::new(&tpm);
        let params = Nsga2Params::for_small_genome(2);
        let set = crate::optimizer::optimize(&problem, &params);
        assert!(!set.members.is_empty());
        for m in &set.members {
            assert!(
                m.genome[0] <= m.genome[1],
                "solver returned unordered plan {:?}",
                m.genome
            );
            assert_eq!(m.violation, 0.0);
        }
    }

    #[test]
    fn slow_backhaul_pulls_torso_to_the_edge() {
        // The edge is slower per byte than the cloud, so torso placement
        // is only worth it while shrinking the activation saves more
        // backhaul time than the slower compute costs. On a congested
        // backhaul that trade is strongly positive for the conv trunk:
        // the TOPSIS pick must carry a real torso — and with the edge
        // disabled (relay sites) it never can.
        let profile = zoo::vgg16().analyze(1);
        let mut tpm = tiered(&profile, 8);
        tpm.backhaul = BackhaulLink { bandwidth_mbps: 20.0, latency_s: 5e-3 };
        let plan = tiered_split_banded(&tpm, BatteryBand::Comfort).unwrap();
        assert!(plan.l2 > plan.l1, "slow backhaul should favour edge torso, got {plan:?}");
        let mut relay = tiered(&profile, 0);
        relay.backhaul = BackhaulLink { bandwidth_mbps: 20.0, latency_s: 5e-3 };
        let plan = tiered_split_banded(&relay, BatteryBand::Comfort).unwrap();
        assert_eq!(plan.l1, plan.l2);
    }
}
