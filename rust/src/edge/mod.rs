//! Three-tier hierarchical splitting: device → metro edge → core cloud.
//!
//! SmartSplit's formulation assumes one split point between a phone and
//! one cloud. Realistic deployments put a metro edge tier in between
//! (SplitPlace, Tuli 2021; Tassi et al.'s head/torso/tail partition):
//! the phone runs the *head*, its assigned edge site the *torso*, and
//! the core cloud the *tail*. This module owns everything that
//! generalisation needs:
//!
//! * [`topology`] — [`EdgeTopology`]: edge sites with per-site server
//!   pools, a device→site [`AssignmentPolicy`], and wired
//!   [`BackhaulLink`]s up to the core (no radio-power term — backhaul
//!   costs time, never device energy);
//! * [`perfmodel`] — [`TieredPerfModel`]: the §III tables evaluated at a
//!   `(l1, l2)` partition, charging two transfers (device→edge over the
//!   radio, edge→cloud over the backhaul);
//! * [`problem`] — [`TieredSplitProblem`]: the 2-D genome over the same
//!   allocation-free NSGA-II engine, plus the exhaustive tiered front
//!   and the band-weighted TOPSIS picks.
//!
//! The degeneracy contract (DESIGN.md §7): a topology with zero edge
//! servers and a [`BackhaulLink::FREE`] backhaul makes every objective,
//! the Pareto front, and the TOPSIS pick collapse to the paper's
//! two-tier values bit-for-bit — pinned by `tests/edge_parity.rs` and
//! `tests/edge_props.rs`.

pub mod perfmodel;
pub mod problem;
pub mod topology;

pub use perfmodel::{TieredLatencyBreakdown, TieredPerfModel};
pub use problem::{
    exhaustive_tiered_front, tiered_smartsplit_banded, tiered_split_banded, TieredSplitProblem,
};
pub use topology::{AssignmentPolicy, BackhaulLink, EdgeSite, EdgeTopology};

/// A two-point split decision: layers `1..=l1` on the device (head),
/// `l1+1..=l2` at the edge (torso), `l2+1..=L` in the cloud (tail).
/// `l1 == l2` is the paper's two-tier split (empty torso); `l2 == L`
/// runs the whole tail at the edge (nothing crosses the backhaul).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SplitPlan {
    pub l1: usize,
    pub l2: usize,
}

impl SplitPlan {
    /// The paper's single-split decision embedded in the tiered space.
    pub fn two_tier(l1: usize) -> SplitPlan {
        SplitPlan { l1, l2: l1 }
    }

    /// Torso depth in layers; `0` means no edge compute.
    pub fn torso_layers(&self) -> usize {
        self.l2.saturating_sub(self.l1)
    }

    /// Does this plan skip the edge compute tier entirely?
    pub fn is_two_tier(&self) -> bool {
        self.l1 == self.l2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_tier_embedding() {
        let p = SplitPlan::two_tier(5);
        assert_eq!(p, SplitPlan { l1: 5, l2: 5 });
        assert!(p.is_two_tier());
        assert_eq!(p.torso_layers(), 0);
        let t = SplitPlan { l1: 3, l2: 9 };
        assert!(!t.is_two_tier());
        assert_eq!(t.torso_layers(), 6);
    }
}
