//! §III generalised to a three-tier hierarchy: a `(l1, l2)` partition
//! runs layers `1..=l1` on the phone (head), `l1+1..=l2` on the
//! assigned edge site (torso) and `l2+1..=L` in the core cloud (tail).
//!
//! The first hop (device→edge) is the paper's radio link unchanged —
//! Eq. 4 transfer time, Eq. 8 upload power — so the device-side energy
//! and memory objectives are *identical* to the two-tier model at the
//! same `l1`. The second hop (edge→cloud) rides the site's wired
//! [`BackhaulLink`]: it costs latency only, never device energy.
//!
//! Degeneracy contract (pinned by `tests/edge_parity.rs` and the
//! property tests): with an empty torso (`l1 == l2`) and a free
//! backhaul, every objective equals [`PerfModel`]'s value at `l1`
//! bit-for-bit, so a zero-edge-server topology with
//! [`BackhaulLink::FREE`] reproduces the paper's two-tier decisions
//! exactly.

use crate::device::ComputeProfile;
use crate::perfmodel::PerfModel;

use super::topology::BackhaulLink;
use super::SplitPlan;

/// Component breakdown of the tiered end-to-end latency (seconds).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TieredLatencyBreakdown {
    /// Head compute on the phone (Eq. 2).
    pub head_s: f64,
    /// Device→edge activation upload over the radio link (Eq. 4).
    pub hop1_s: f64,
    /// Torso compute at the edge site (Eq. 3 with the edge profile).
    pub torso_s: f64,
    /// Edge→cloud activation transfer over the wired backhaul.
    pub backhaul_s: f64,
    /// Tail compute in the core cloud (Eq. 3).
    pub tail_s: f64,
}

impl TieredLatencyBreakdown {
    /// End-to-end latency; the result download is excluded exactly as
    /// the paper excludes it from Eq. 5 totals.
    pub fn total(&self) -> f64 {
        self.head_s + self.hop1_s + self.torso_s + self.backhaul_s + self.tail_s
    }
}

/// Evaluation context for one device under a three-tier hierarchy.
#[derive(Clone, Debug)]
pub struct TieredPerfModel<'a> {
    /// The paper's two-tier model for this device: client profile,
    /// radio, device link, model profile, and the *cloud* server profile
    /// (the tail still runs there).
    pub device: PerfModel<'a>,
    /// Compute profile of one server at the assigned edge site.
    pub edge: &'static ComputeProfile,
    /// Torso servers at the site; `0` disables the compute tier (only
    /// empty-torso plans are feasible — the site is a pure relay).
    pub edge_servers: usize,
    pub backhaul: BackhaulLink,
}

impl<'a> TieredPerfModel<'a> {
    pub fn new(
        device: PerfModel<'a>,
        edge: &'static ComputeProfile,
        edge_servers: usize,
        backhaul: BackhaulLink,
    ) -> Self {
        TieredPerfModel { device, edge, edge_servers, backhaul }
    }

    pub fn num_layers(&self) -> usize {
        self.device.profile.num_layers
    }

    /// Torso working set in bytes: layers `l1+1..=l2` (params + activations).
    pub fn torso_memory_bytes(&self, plan: SplitPlan) -> u64 {
        assert!(plan.l1 <= plan.l2, "unordered plan {plan:?}");
        self.device.profile.client_memory_bytes(plan.l2)
            - self.device.profile.client_memory_bytes(plan.l1)
    }

    /// Torso compute time at the edge (Eq. 3 with the edge profile).
    pub fn torso_latency_s(&self, plan: SplitPlan) -> f64 {
        let m = self.torso_memory_bytes(plan) as f64;
        m * self.edge.cycles_per_byte / (self.edge.cores as f64 * self.edge.clock_hz)
    }

    /// Edge→cloud transfer time of the activation at `l2`; zero when the
    /// tail is empty (`l2 == L`: nothing crosses the backhaul). The COC
    /// embedding (`l2 == 0`) relays the raw input, mirroring
    /// [`crate::perfmodel::PerfModel::latency`] at `l1 == 0`.
    pub fn backhaul_latency_s(&self, plan: SplitPlan) -> f64 {
        if plan.l2 >= self.num_layers() {
            return 0.0;
        }
        let bytes = if plan.l2 == 0 {
            self.device.profile.input_bytes()
        } else {
            self.device.profile.intermediate_bytes(plan.l2)
        };
        self.backhaul.transfer_s(bytes)
    }

    /// Full latency breakdown at `plan`. The first two hops come from
    /// the two-tier breakdown so the COC embedding (`l1 == 0`, raw
    /// input uploaded) is handled in exactly one place.
    pub fn latency(&self, plan: SplitPlan) -> TieredLatencyBreakdown {
        let two_tier = self.device.latency(plan.l1);
        TieredLatencyBreakdown {
            head_s: two_tier.client_s,
            hop1_s: two_tier.upload_s,
            torso_s: self.torso_latency_s(plan),
            backhaul_s: self.backhaul_latency_s(plan),
            tail_s: self.device.server_latency_s(plan.l2),
        }
    }

    /// Eq. 14 generalised: end-to-end latency (seconds).
    pub fn f1(&self, plan: SplitPlan) -> f64 {
        self.latency(plan).total()
    }

    /// Eq. 15: device energy. Depends on `l1` only — the head compute
    /// and the radio upload are the phone's entire bill; torso, backhaul
    /// and tail never touch its battery.
    pub fn f2(&self, plan: SplitPlan) -> f64 {
        self.device.f2(plan.l1)
    }

    /// Eq. 16: device memory — `l1` only, as in the two-tier model.
    pub fn f3(&self, plan: SplitPlan) -> f64 {
        self.device.f3(plan.l1)
    }

    pub fn objectives(&self, plan: SplitPlan) -> [f64; 3] {
        [self.f1(plan), self.f2(plan), self.f3(plan)]
    }

    /// Eq. 17 generalised. Graded (for constraint domination during
    /// evolution); `0.0` iff the plan is feasible:
    /// * `1 ≤ l1 ≤ l2 ≤ L` (ordering violations graded by the gap);
    /// * `l1 == L` (COS — every layer on the phone) stays infeasible,
    ///   mirroring [`crate::optimizer::SplitProblem`];
    /// * a non-empty torso needs at least one edge server;
    /// * the head working set must fit the phone (graded);
    /// * throughput constraints `τ ≤ B` on the radio link.
    pub fn violation(&self, plan: SplitPlan) -> f64 {
        let l = self.num_layers();
        let mut v = 0.0;
        if plan.l1 > plan.l2 {
            v += 1.0 + (plan.l1 - plan.l2) as f64 / l as f64;
        }
        if plan.l1 + 1 > l {
            v += 1.0;
        }
        if plan.l2 > plan.l1 && self.edge_servers == 0 {
            // Graded by torso depth so constraint domination has a
            // gradient toward the (feasible) diagonal on relay-only
            // sites — a flat penalty would leave the GA searching for
            // `l1 == l2` by blind luck.
            v += 1.0 + (plan.l2 - plan.l1) as f64 / l as f64;
        }
        let mem = self.device.profile.client_memory_bytes(plan.l1.min(l));
        let cap = self.device.client.memory_bytes;
        if mem > cap {
            v += (mem - cap) as f64 / cap as f64;
        }
        if !self.device.net.satisfies_constraints() {
            v += 1.0;
        }
        v
    }

    pub fn feasible(&self, plan: SplitPlan) -> bool {
        self.violation(plan) == 0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::models::zoo;
    use crate::perfmodel::{NetworkEnv, RadioPower};

    fn device_pm(profile: &crate::models::ModelProfile) -> PerfModel<'_> {
        PerfModel::new(
            profiles::samsung_j6(),
            profiles::cloud_server(),
            RadioPower::PAPER_80211N,
            NetworkEnv::paper_default(),
            profile,
        )
    }

    fn tiered(profile: &crate::models::ModelProfile) -> TieredPerfModel<'_> {
        TieredPerfModel::new(
            device_pm(profile),
            profiles::edge_server(),
            2,
            BackhaulLink::METRO_1GBE,
        )
    }

    #[test]
    fn empty_torso_free_backhaul_equals_two_tier_exactly() {
        let profile = zoo::alexnet().analyze(1);
        let mut t = tiered(&profile);
        t.backhaul = BackhaulLink::FREE;
        for l1 in 1..=21 {
            let plan = SplitPlan::two_tier(l1);
            assert_eq!(t.f1(plan), t.device.f1(l1), "f1 at l1={l1}");
            assert_eq!(t.f2(plan), t.device.f2(l1), "f2 at l1={l1}");
            assert_eq!(t.f3(plan), t.device.f3(l1), "f3 at l1={l1}");
        }
    }

    #[test]
    fn torso_offload_shortens_cloud_tail() {
        let profile = zoo::alexnet().analyze(1);
        let t = tiered(&profile);
        let two = SplitPlan { l1: 3, l2: 3 };
        let three = SplitPlan { l1: 3, l2: 10 };
        let b2 = t.latency(two);
        let b3 = t.latency(three);
        assert_eq!(b2.torso_s, 0.0);
        assert!(b3.torso_s > 0.0);
        assert!(b3.tail_s < b2.tail_s, "torso must shrink the tail");
        // Head-side terms are untouched by l2.
        assert_eq!(b2.head_s, b3.head_s);
        assert_eq!(b2.hop1_s, b3.hop1_s);
    }

    #[test]
    fn torso_memory_partitions_the_model() {
        let profile = zoo::alexnet().analyze(1);
        let t = tiered(&profile);
        let total = profile.client_memory_bytes(profile.num_layers);
        for (l1, l2) in [(1, 5), (3, 3), (5, 21)] {
            let plan = SplitPlan { l1, l2 };
            let head = profile.client_memory_bytes(l1);
            let tail = profile.server_memory_bytes(l2);
            assert_eq!(head + t.torso_memory_bytes(plan) + tail, total);
        }
    }

    #[test]
    fn backhaul_charged_only_when_tail_nonempty() {
        let profile = zoo::alexnet().analyze(1);
        let t = tiered(&profile);
        assert!(t.backhaul_latency_s(SplitPlan { l1: 3, l2: 10 }) > 0.0);
        // Tail empty: nothing crosses the backhaul.
        assert_eq!(t.backhaul_latency_s(SplitPlan { l1: 3, l2: 21 }), 0.0);
    }

    #[test]
    fn device_energy_is_independent_of_l2() {
        let profile = zoo::alexnet().analyze(1);
        let t = tiered(&profile);
        for l2 in 5..=21 {
            assert_eq!(t.f2(SplitPlan { l1: 5, l2 }), t.f2(SplitPlan { l1: 5, l2: 5 }));
        }
    }

    #[test]
    fn violation_rules() {
        let profile = zoo::alexnet().analyze(1);
        let t = tiered(&profile);
        // Ordering: l1 > l2 always infeasible.
        assert!(t.violation(SplitPlan { l1: 10, l2: 3 }) > 0.0);
        // COS stays infeasible (mirrors SplitProblem).
        assert!(t.violation(SplitPlan { l1: 21, l2: 21 }) > 0.0);
        // Edge-only tail (l2 == L, torso at the edge) is legal.
        assert!(t.feasible(SplitPlan { l1: 3, l2: 21 }));
        // Plain plans are feasible.
        assert!(t.feasible(SplitPlan { l1: 3, l2: 10 }));
        assert!(t.feasible(SplitPlan::two_tier(5)));
        // Zero servers: torso plans infeasible, relays stay legal.
        let mut relay = tiered(&profile);
        relay.edge_servers = 0;
        assert!(relay.violation(SplitPlan { l1: 3, l2: 10 }) > 0.0);
        assert!(relay.feasible(SplitPlan::two_tier(3)));
    }
}
