//! Edge topology: metro sites between the phones and the core cloud.
//!
//! An [`EdgeTopology`] is the static description the planner and the
//! fleet simulator share: a set of [`EdgeSite`]s (each a small server
//! pool with a wired backhaul up to the core cloud) plus the
//! device→site [`AssignmentPolicy`]. Devices talk to their assigned
//! site over their own radio link (the §III device model, unchanged);
//! the site talks to the cloud over its [`BackhaulLink`] — wired, so
//! no [`crate::perfmodel::RadioPower`] term and no device energy is
//! charged for the second hop.

use crate::device::ComputeProfile;

/// Wired edge→cloud link: fixed bandwidth plus a propagation latency.
/// No radio power model — backhaul transfers cost time, never device
/// energy (the phone's radio finished its part at the first hop).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BackhaulLink {
    pub bandwidth_mbps: f64,
    /// One-way propagation delay added to every transfer.
    pub latency_s: f64,
}

impl BackhaulLink {
    /// Metro-Ethernet-class default: 1 Gbps, 2 ms one way.
    pub const METRO_1GBE: BackhaulLink =
        BackhaulLink { bandwidth_mbps: 1000.0, latency_s: 2e-3 };

    /// A cost-free backhaul (infinite bandwidth, zero latency): the
    /// degenerate configuration under which the tiered planner must
    /// collapse to the paper's two-tier split (DESIGN.md §7).
    pub const FREE: BackhaulLink =
        BackhaulLink { bandwidth_mbps: f64::INFINITY, latency_s: 0.0 };

    /// Transfer time for `bytes` over this link (Eq. 4 with the wired
    /// bandwidth, plus propagation). An infinite-bandwidth link costs
    /// exactly `latency_s`.
    pub fn transfer_s(&self, bytes: u64) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        let serialize = if self.bandwidth_mbps.is_finite() {
            bytes as f64 * 8.0 / (self.bandwidth_mbps * 1e6)
        } else {
            0.0
        };
        serialize + self.latency_s
    }

    /// A backhaul that never costs anything — neither serialisation nor
    /// propagation.
    pub fn is_free(&self) -> bool {
        !self.bandwidth_mbps.is_finite() && self.latency_s == 0.0
    }
}

/// One metro edge site: a server pool and its uplink to the core cloud.
#[derive(Clone, Copy, Debug)]
pub struct EdgeSite {
    /// Parallel torso servers at this site (`c` of the site's M/G/c
    /// queue). `0` disables the compute tier: the site degrades to a
    /// pure relay and only empty-torso plans (`l1 == l2`) are feasible.
    pub servers: usize,
    /// Compute profile of one edge server.
    pub profile: &'static ComputeProfile,
    pub backhaul: BackhaulLink,
}

/// How devices map onto edge sites.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AssignmentPolicy {
    /// `device_id % sites` — the deterministic default (a city where
    /// homes are spread uniformly over the metro footprint).
    RoundRobin,
}

/// The full edge tier: sites plus the device→site assignment.
#[derive(Clone, Debug)]
pub struct EdgeTopology {
    pub sites: Vec<EdgeSite>,
    pub assignment: AssignmentPolicy,
}

impl EdgeTopology {
    /// A uniform topology: `sites` identical sites.
    pub fn uniform(sites: usize, site: EdgeSite) -> EdgeTopology {
        assert!(sites > 0, "an edge topology needs at least one site");
        EdgeTopology { sites: vec![site; sites], assignment: AssignmentPolicy::RoundRobin }
    }

    /// Site index serving device `device_id`.
    pub fn site_of(&self, device_id: usize) -> usize {
        match self.assignment {
            AssignmentPolicy::RoundRobin => device_id % self.sites.len(),
        }
    }

    pub fn num_sites(&self) -> usize {
        self.sites.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;

    #[test]
    fn backhaul_transfer_time() {
        let b = BackhaulLink { bandwidth_mbps: 100.0, latency_s: 0.001 };
        // 1 MB at 100 Mbps = 80 ms + 1 ms propagation.
        assert!((b.transfer_s(1_000_000) - 0.081).abs() < 1e-12);
        assert_eq!(b.transfer_s(0), 0.0);
    }

    #[test]
    fn free_backhaul_costs_nothing() {
        assert!(BackhaulLink::FREE.is_free());
        assert_eq!(BackhaulLink::FREE.transfer_s(10_000_000), 0.0);
        assert!(!BackhaulLink::METRO_1GBE.is_free());
    }

    #[test]
    fn round_robin_assignment_cycles() {
        let topo = EdgeTopology::uniform(
            3,
            EdgeSite {
                servers: 2,
                profile: profiles::edge_server(),
                backhaul: BackhaulLink::METRO_1GBE,
            },
        );
        assert_eq!(topo.num_sites(), 3);
        for d in 0..9 {
            assert_eq!(topo.site_of(d), d % 3);
        }
    }
}
