//! Edge topology: metro sites between the phones and the core cloud.
//!
//! An [`EdgeTopology`] is the static description the planner and the
//! fleet simulator share: a set of [`EdgeSite`]s (each a small server
//! pool with a wired backhaul up to the core cloud) plus the
//! device→site [`AssignmentPolicy`]. Devices talk to their assigned
//! site over their own radio link (the §III device model, unchanged);
//! the site talks to the cloud over its [`BackhaulLink`] — wired, so
//! no [`crate::perfmodel::RadioPower`] term and no device energy is
//! charged for the second hop.

use crate::device::ComputeProfile;

/// Wired edge→cloud link: fixed bandwidth plus a propagation latency.
/// No radio power model — backhaul transfers cost time, never device
/// energy (the phone's radio finished its part at the first hop).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BackhaulLink {
    pub bandwidth_mbps: f64,
    /// One-way propagation delay added to every transfer.
    pub latency_s: f64,
}

impl BackhaulLink {
    /// Metro-Ethernet-class default: 1 Gbps, 2 ms one way.
    pub const METRO_1GBE: BackhaulLink =
        BackhaulLink { bandwidth_mbps: 1000.0, latency_s: 2e-3 };

    /// A cost-free backhaul (infinite bandwidth, zero latency): the
    /// degenerate configuration under which the tiered planner must
    /// collapse to the paper's two-tier split (DESIGN.md §7).
    pub const FREE: BackhaulLink =
        BackhaulLink { bandwidth_mbps: f64::INFINITY, latency_s: 0.0 };

    /// Transfer time for `bytes` over this link (Eq. 4 with the wired
    /// bandwidth, plus propagation). An infinite-bandwidth link costs
    /// exactly `latency_s`.
    pub fn transfer_s(&self, bytes: u64) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        let serialize = if self.bandwidth_mbps.is_finite() {
            bytes as f64 * 8.0 / (self.bandwidth_mbps * 1e6)
        } else {
            0.0
        };
        serialize + self.latency_s
    }

    /// A backhaul that never costs anything — neither serialisation nor
    /// propagation.
    pub fn is_free(&self) -> bool {
        !self.bandwidth_mbps.is_finite() && self.latency_s == 0.0
    }
}

/// One metro edge site: a server pool and its uplink to the core cloud.
#[derive(Clone, Copy, Debug)]
pub struct EdgeSite {
    /// Parallel torso servers at this site (`c` of the site's M/G/c
    /// queue). `0` disables the compute tier: the site degrades to a
    /// pure relay and only empty-torso plans (`l1 == l2`) are feasible.
    pub servers: usize,
    /// Compute profile of one edge server.
    pub profile: &'static ComputeProfile,
    pub backhaul: BackhaulLink,
}

/// How devices map onto edge sites.
///
/// Spawn placement takes only the device id (no position is known yet);
/// mobility re-attachment ([`EdgeTopology::attach`]) feeds the cell the
/// device walked into, and the policy maps that cell onto its serving
/// site.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AssignmentPolicy {
    /// `device_id % sites` — the deterministic default (a city where
    /// homes are spread uniformly over the metro footprint). Under
    /// mobility a device standing in cell `c` attaches to site `c`
    /// (one cell per site — see the cell geometry on
    /// [`EdgeTopology`]).
    RoundRobin,
}

/// The full edge tier: sites plus the device→site assignment.
///
/// # Cell geometry
///
/// For mobility ([`crate::sim::mobility`]) the metro footprint is
/// modelled as a 1-D ring of equal **cells**, one per site: cell `k` is
/// the coverage area of site `k`, and walking off either end of the
/// ring wraps around (a beltway city). The geometry helpers
/// ([`EdgeTopology::cell_neighbors`], [`EdgeTopology::cell_distance`],
/// [`EdgeTopology::step_toward`]) are pure functions of the site count,
/// so the waypoint walk that uses them is deterministic by
/// construction.
#[derive(Clone, Debug)]
pub struct EdgeTopology {
    pub sites: Vec<EdgeSite>,
    pub assignment: AssignmentPolicy,
}

impl EdgeTopology {
    /// A uniform topology: `sites` identical sites.
    pub fn uniform(sites: usize, site: EdgeSite) -> EdgeTopology {
        assert!(sites > 0, "an edge topology needs at least one site");
        EdgeTopology { sites: vec![site; sites], assignment: AssignmentPolicy::RoundRobin }
    }

    /// Site index serving device `device_id` at spawn (no position
    /// known yet). Equivalent to [`EdgeTopology::attach`] with no cell.
    pub fn site_of(&self, device_id: usize) -> usize {
        self.attach(device_id, None)
    }

    /// The attachment rule, shared by spawn placement and mobility
    /// re-attachment: the site serving `device_id`, standing in `cell`
    /// when one is known (`None` at spawn — the policy then places by
    /// id alone).
    pub fn attach(&self, device_id: usize, cell: Option<usize>) -> usize {
        match self.assignment {
            AssignmentPolicy::RoundRobin => cell.unwrap_or(device_id) % self.sites.len(),
        }
    }

    /// Outage-aware attachment ([`crate::sim::faults`]): the site that
    /// serves `device_id` when some sites are down. `down[k]` marks
    /// site `k` unavailable. Returns the natural [`EdgeTopology::attach`]
    /// site when it is up; otherwise the nearest live site by ring
    /// [`EdgeTopology::cell_distance`] from the natural site's cell,
    /// ties broken clockwise (lowest forward distance first) so the
    /// fallback is deterministic. `None` when every site is down.
    pub fn attach_avoiding(
        &self,
        device_id: usize,
        cell: Option<usize>,
        down: &[bool],
    ) -> Option<usize> {
        let natural = self.attach(device_id, cell);
        if !down.get(natural).copied().unwrap_or(false) {
            return Some(natural);
        }
        let n = self.num_cells();
        // Walk outward from the natural cell: clockwise neighbour at
        // each distance before the counter-clockwise one (the same
        // clockwise preference as `step_toward`).
        for d in 1..n {
            let cw = (natural + d) % n;
            if !down[cw] {
                return Some(cw);
            }
            let ccw = (natural + n - d) % n;
            if !down[ccw] {
                return Some(ccw);
            }
        }
        None
    }

    pub fn num_sites(&self) -> usize {
        self.sites.len()
    }

    /// The smallest one-way backhaul propagation delay over all sites —
    /// the topology's contribution to the sharded engine's conservative
    /// lookahead bound ([`crate::sim::shard::lookahead_bound`]): no event
    /// generated at one site can take effect at another sooner than the
    /// cheapest wired hop. Infinity when the topology has no sites.
    pub fn min_backhaul_latency_s(&self) -> f64 {
        self.sites.iter().fold(f64::INFINITY, |m, s| m.min(s.backhaul.latency_s))
    }

    /// Contiguous near-equal partition of the sites into `shards` groups:
    /// `shard_map(s)[k]` is the shard owning site `k`. The first
    /// `num_sites % shards` shards take one extra site, so group sizes
    /// differ by at most one and every shard owns at least one site when
    /// `shards <= num_sites` (beyond that the surplus shards stay empty
    /// by construction — callers clamp). Pure function of the site count:
    /// the same topology always shards the same way.
    pub fn shard_map(&self, shards: usize) -> Vec<u32> {
        let shards = shards.max(1);
        let n = self.sites.len();
        let base = n / shards;
        let extra = n % shards;
        let mut map = Vec::with_capacity(n);
        for shard in 0..shards {
            let len = base + usize::from(shard < extra);
            for _ in 0..len {
                map.push(shard as u32);
            }
        }
        debug_assert_eq!(map.len(), n);
        map
    }

    /// Number of mobility cells — one per site (cell `k` is site `k`'s
    /// coverage area).
    pub fn num_cells(&self) -> usize {
        self.sites.len()
    }

    /// The ring neighbours `(counter-clockwise, clockwise)` of `cell`.
    /// Degenerate rings fold onto themselves: with one cell both
    /// neighbours are the cell itself, with two they coincide.
    pub fn cell_neighbors(&self, cell: usize) -> (usize, usize) {
        let n = self.num_cells();
        ((cell + n - 1) % n, (cell + 1) % n)
    }

    /// Minimum number of cell crossings between `a` and `b` on the ring.
    pub fn cell_distance(&self, a: usize, b: usize) -> usize {
        let n = self.num_cells();
        let fwd = (b + n - a) % n;
        fwd.min(n - fwd)
    }

    /// The next cell on a shortest ring path from `from` to `to`
    /// (`from` itself when they are equal; an exact-opposite tie breaks
    /// clockwise, so the walk is deterministic).
    pub fn step_toward(&self, from: usize, to: usize) -> usize {
        let n = self.num_cells();
        let fwd = (to + n - from) % n;
        if fwd == 0 {
            from
        } else if fwd <= n - fwd {
            (from + 1) % n
        } else {
            (from + n - 1) % n
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;

    #[test]
    fn backhaul_transfer_time() {
        let b = BackhaulLink { bandwidth_mbps: 100.0, latency_s: 0.001 };
        // 1 MB at 100 Mbps = 80 ms + 1 ms propagation.
        assert!((b.transfer_s(1_000_000) - 0.081).abs() < 1e-12);
        assert_eq!(b.transfer_s(0), 0.0);
    }

    #[test]
    fn free_backhaul_costs_nothing() {
        assert!(BackhaulLink::FREE.is_free());
        assert_eq!(BackhaulLink::FREE.transfer_s(10_000_000), 0.0);
        assert!(!BackhaulLink::METRO_1GBE.is_free());
    }

    #[test]
    fn cell_ring_geometry_is_coherent() {
        let topo = EdgeTopology::uniform(
            5,
            EdgeSite {
                servers: 1,
                profile: profiles::edge_server(),
                backhaul: BackhaulLink::METRO_1GBE,
            },
        );
        assert_eq!(topo.num_cells(), 5);
        assert_eq!(topo.cell_neighbors(0), (4, 1));
        assert_eq!(topo.cell_neighbors(4), (3, 0));
        // Distance is symmetric, zero on the diagonal, wraps the ring.
        for a in 0..5 {
            assert_eq!(topo.cell_distance(a, a), 0);
            for b in 0..5 {
                assert_eq!(topo.cell_distance(a, b), topo.cell_distance(b, a));
                assert!(topo.cell_distance(a, b) <= 2);
            }
        }
        assert_eq!(topo.cell_distance(0, 4), 1, "the ring must wrap");
        // Stepping toward a waypoint strictly shrinks the distance and
        // arrives in exactly `cell_distance` hops.
        for from in 0..5 {
            for to in 0..5 {
                let mut cur = from;
                let mut hops = 0;
                while cur != to {
                    let next = topo.step_toward(cur, to);
                    assert!(
                        topo.cell_distance(next, to) < topo.cell_distance(cur, to),
                        "step {cur}→{next} toward {to} did not shrink the distance"
                    );
                    cur = next;
                    hops += 1;
                    assert!(hops <= 5, "walk {from}→{to} failed to terminate");
                }
                assert_eq!(hops, topo.cell_distance(from, to));
            }
        }
        assert_eq!(topo.step_toward(2, 2), 2, "a reached waypoint is a fixed point");
    }

    #[test]
    fn degenerate_rings_fold_onto_themselves() {
        let site = EdgeSite {
            servers: 1,
            profile: profiles::edge_server(),
            backhaul: BackhaulLink::METRO_1GBE,
        };
        let one = EdgeTopology::uniform(1, site);
        assert_eq!(one.cell_neighbors(0), (0, 0));
        assert_eq!(one.step_toward(0, 0), 0);
        assert_eq!(one.cell_distance(0, 0), 0);
        let two = EdgeTopology::uniform(2, site);
        assert_eq!(two.cell_neighbors(0), (1, 1));
        assert_eq!(two.step_toward(0, 1), 1);
        assert_eq!(two.cell_distance(0, 1), 1);
    }

    #[test]
    fn attach_matches_spawn_placement_and_follows_cells() {
        let topo = EdgeTopology::uniform(
            3,
            EdgeSite {
                servers: 2,
                profile: profiles::edge_server(),
                backhaul: BackhaulLink::METRO_1GBE,
            },
        );
        for d in 0..9 {
            // Spawn placement (no cell) is the round-robin rule.
            assert_eq!(topo.attach(d, None), topo.site_of(d));
            // A known cell overrides the id: the device attaches to the
            // site whose coverage area it stands in.
            for cell in 0..3 {
                assert_eq!(topo.attach(d, Some(cell)), cell);
            }
        }
    }

    #[test]
    fn attach_avoiding_routes_around_outages_deterministically() {
        let topo = EdgeTopology::uniform(
            4,
            EdgeSite {
                servers: 2,
                profile: profiles::edge_server(),
                backhaul: BackhaulLink::METRO_1GBE,
            },
        );
        // All up: identical to the natural rule (zero-fault parity).
        for d in 0..8 {
            for cell in [None, Some(0), Some(3)] {
                assert_eq!(
                    topo.attach_avoiding(d, cell, &[false; 4]),
                    Some(topo.attach(d, cell))
                );
            }
        }
        // Natural site down: nearest live site, clockwise tie-break.
        let down1 = [false, true, false, false];
        assert_eq!(topo.attach_avoiding(1, None, &down1), Some(2), "1's neighbours tie; clockwise wins");
        assert_eq!(topo.attach_avoiding(5, Some(1), &down1), Some(2));
        assert_eq!(topo.attach_avoiding(0, None, &down1), Some(0), "live sites are untouched");
        // Two adjacent sites down: the walk keeps widening.
        let down12 = [false, true, true, false];
        assert_eq!(topo.attach_avoiding(1, None, &down12), Some(0), "ccw at distance 1 beats cw at 2");
        assert_eq!(topo.attach_avoiding(2, None, &down12), Some(3));
        // Everything down: nowhere to attach.
        assert_eq!(topo.attach_avoiding(0, None, &[true; 4]), None);
    }

    #[test]
    fn min_backhaul_latency_takes_the_cheapest_hop() {
        let mut topo = EdgeTopology::uniform(
            3,
            EdgeSite {
                servers: 1,
                profile: profiles::edge_server(),
                backhaul: BackhaulLink::METRO_1GBE,
            },
        );
        assert_eq!(topo.min_backhaul_latency_s(), 2e-3);
        topo.sites[1].backhaul = BackhaulLink { bandwidth_mbps: 100.0, latency_s: 5e-4 };
        assert_eq!(topo.min_backhaul_latency_s(), 5e-4);
        topo.sites[2].backhaul = BackhaulLink::FREE;
        assert_eq!(topo.min_backhaul_latency_s(), 0.0);
    }

    #[test]
    fn shard_map_partitions_sites_contiguously_and_evenly() {
        let topo = EdgeTopology::uniform(
            7,
            EdgeSite {
                servers: 1,
                profile: profiles::edge_server(),
                backhaul: BackhaulLink::METRO_1GBE,
            },
        );
        for shards in 1..=9 {
            let map = topo.shard_map(shards);
            assert_eq!(map.len(), 7);
            // Non-decreasing (contiguous groups) and in range.
            for w in map.windows(2) {
                assert!(w[0] <= w[1]);
                assert!(w[1] < shards as u32);
            }
            // Group sizes differ by at most one; every shard that can
            // own a site does.
            let used = shards.min(7);
            let mut counts = vec![0usize; shards];
            for &s in &map {
                counts[s as usize] += 1;
            }
            assert!(counts.iter().take(used).all(|&c| c > 0));
            let (min_used, max) = (
                counts.iter().take(used).min().copied().unwrap(),
                counts.iter().max().copied().unwrap(),
            );
            assert!(max - min_used <= 1, "shards={shards} counts={counts:?}");
        }
        assert_eq!(topo.shard_map(2), vec![0, 0, 0, 0, 1, 1, 1]);
        assert_eq!(topo.shard_map(0), topo.shard_map(1), "0 clamps to 1");
    }

    #[test]
    fn round_robin_assignment_cycles() {
        let topo = EdgeTopology::uniform(
            3,
            EdgeSite {
                servers: 2,
                profile: profiles::edge_server(),
                backhaul: BackhaulLink::METRO_1GBE,
            },
        );
        assert_eq!(topo.num_sites(), 3);
        for d in 0..9 {
            assert_eq!(topo.site_of(d), d % 3);
        }
    }
}
