//! Benchmark harness substrate (`criterion` is unavailable offline —
//! DESIGN.md §4). Drives every `cargo bench` target: warmup, fixed-count
//! or time-budgeted measurement, robust stats, and aligned table output
//! for the paper-figure emitters.

use std::time::{Duration, Instant};

/// Measurement statistics over the recorded iteration times.
#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub n: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub max_s: f64,
}

impl Stats {
    pub fn from_samples(name: &str, mut samples: Vec<f64>) -> Stats {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n as f64;
        let q = |p: f64| samples[(p * (n - 1) as f64).round() as usize];
        Stats {
            name: name.to_string(),
            n,
            mean_s: mean,
            std_s: var.sqrt(),
            min_s: samples[0],
            p50_s: q(0.50),
            p95_s: q(0.95),
            max_s: samples[n - 1],
        }
    }

    pub fn row(&self) -> String {
        format!(
            "{:<42} n={:<5} mean={:>10} ±{:>9} p50={:>10} p95={:>10}",
            self.name,
            self.n,
            crate::util::fmt_secs(self.mean_s),
            crate::util::fmt_secs(self.std_s),
            crate::util::fmt_secs(self.p50_s),
            crate::util::fmt_secs(self.p95_s),
        )
    }
}

/// Harness: `Bench::new("x").iters(100).run(|| work())`.
pub struct Bench {
    name: String,
    warmup: usize,
    iters: usize,
    max_time: Duration,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        Bench {
            name: name.to_string(),
            warmup: 3,
            iters: 30,
            max_time: Duration::from_secs(20),
        }
    }

    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup = n;
        self
    }

    pub fn iters(mut self, n: usize) -> Self {
        self.iters = n;
        self
    }

    pub fn max_time(mut self, d: Duration) -> Self {
        self.max_time = d;
        self
    }

    /// Measure `f`; prints the stats row and returns it.
    pub fn run<F: FnMut()>(&self, mut f: F) -> Stats {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        let budget_start = Instant::now();
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
            if budget_start.elapsed() > self.max_time && samples.len() >= 5 {
                break;
            }
        }
        let stats = Stats::from_samples(&self.name, samples);
        println!("{}", stats.row());
        stats
    }
}

/// Keep a value alive / opaque to the optimiser (std::hint-based blackbox).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Aligned-table printer for the figure/table emitters.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells.to_vec());
    }

    pub fn to_string(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = fmt_row(&self.headers);
        out.push('\n');
        out.push_str(&"-".repeat(out.len() - 1));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.to_string());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_from_known_samples() {
        let s = Stats::from_samples("t", vec![0.004, 0.002, 0.001, 0.003, 0.005]);
        assert_eq!(s.n, 5);
        assert!((s.mean_s - 0.003).abs() < 1e-12);
        assert_eq!(s.min_s, 0.001);
        assert_eq!(s.max_s, 0.005);
        assert_eq!(s.p50_s, 0.003);
    }

    #[test]
    fn bench_runs_requested_iters() {
        let mut count = 0;
        let s = Bench::new("count").warmup(2).iters(10).run(|| {
            count += 1;
        });
        assert_eq!(count, 12); // warmup + iters
        assert_eq!(s.n, 10);
    }

    #[test]
    fn bench_respects_time_budget() {
        let s = Bench::new("slow")
            .warmup(0)
            .iters(10_000)
            .max_time(Duration::from_millis(50))
            .run(|| std::thread::sleep(Duration::from_millis(5)));
        assert!(s.n < 10_000);
        assert!(s.n >= 5);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["model", "split", "latency"]);
        t.row(&["alexnet".into(), "3".into(), "1.23 s".into()]);
        t.row(&["vgg16".into(), "10".into(), "4.56 s".into()]);
        let s = t.to_string();
        assert!(s.contains("alexnet"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[1].chars().next(), Some('-'));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_wrong_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
