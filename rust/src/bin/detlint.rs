//! `detlint` — run the determinism & robustness static-analysis pass
//! (DESIGN.md §15) over the crate sources and exit nonzero on any
//! unsuppressed finding.
//!
//! ```text
//! cargo run --bin detlint              # scan rust/src/** (the CI gate)
//! cargo run --bin detlint -- --rules   # print the rule table
//! cargo run --bin detlint -- DIR ...   # scan explicit roots instead
//! ```
//!
//! The report is deterministic and stable-sorted, so two runs over the
//! same tree are byte-identical — the lint output honors the same
//! contract it enforces.

use std::path::PathBuf;
use std::process::ExitCode;

use smartsplit::lint;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--rules") {
        print!("{}", lint::rules_table());
        return ExitCode::SUCCESS;
    }
    if args.iter().any(|a| a == "--help" || a == "-h") {
        println!("usage: detlint [--rules] [DIR ...]");
        println!("scans DIR (default: this crate's src/) for determinism");
        println!("and robustness violations; see --rules for the rule set");
        return ExitCode::SUCCESS;
    }

    let roots: Vec<PathBuf> = if args.is_empty() {
        vec![PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src")]
    } else {
        args.iter().map(PathBuf::from).collect()
    };

    let mut report = lint::LintReport::default();
    for root in &roots {
        match lint::scan_tree(root) {
            Ok(rep) => report.merge(rep),
            Err(e) => {
                eprintln!("detlint: cannot scan {}: {e}", root.display());
                return ExitCode::FAILURE;
            }
        }
    }

    print!("{}", report.render());
    if report.clean() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
