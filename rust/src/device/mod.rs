//! Smartphone / cloud-server simulation substrate.
//!
//! The paper's testbed (Samsung Galaxy J6, Redmi Note 8, a Windows-10 i5
//! cloud box) is modelled as [`ComputeProfile`]s carrying exactly the
//! quantities Eq. 2–13 consume, plus an [`EnergyMeter`] that plays the role
//! of Android BatteryStats (integrating P·dt from the §III power models)
//! and a [`MemoryTracker`] enforcing the Eq. 17 capacity constraint.

use std::sync::Mutex;

use crate::perfmodel::RadioPower;

/// WiFi standard of the device radio; selects the radio power constants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WifiStandard {
    /// 802.11 b/g/n (Samsung J6) — the paper's Huang-et-al constants.
    N80211,
    /// 802.11 ac (Redmi Note 8) — energy-optimised radio.
    Ac80211,
}

impl WifiStandard {
    pub fn radio_power(self) -> RadioPower {
        match self {
            WifiStandard::N80211 => RadioPower::PAPER_80211N,
            WifiStandard::Ac80211 => RadioPower::WIFI_80211AC,
        }
    }
}

/// Hardware profile consumed by the perf model (Eq. 2–13) and by the
/// device-side executor (which scales real PJRT wall-time by
/// `slowdown_vs_host` to emulate phone-class silicon).
#[derive(Clone, Debug)]
pub struct ComputeProfile {
    pub name: &'static str,
    pub cores: usize,
    /// Processor speed `S` in Hz (Eq. 2/3 denominator).
    pub clock_hz: f64,
    /// Operating frequency `ν` in GHz (Eq. 6; = clock for our devices).
    pub freq_ghz: f64,
    /// RAM capacity `M` in bytes (Eq. 17 first constraint).
    pub memory_bytes: u64,
    /// Battery capacity in mAh (energy budget accounting; phones only).
    pub battery_mah: Option<f64>,
    /// WiFi radio (phones only; the cloud server is mains/ethernet).
    pub wifi: Option<WifiStandard>,
    /// Calibrated cycles-per-byte of CNN inference on this silicon
    /// (DESIGN.md §4: the paper's Eq. 2 assumes 1 byte/cycle/core).
    pub cycles_per_byte: f64,
    /// Wall-clock multiplier applied to real PJRT execution when this
    /// profile emulates the device side of the split runtime.
    pub slowdown_vs_host: f64,
}

pub mod profiles {
    use super::*;
    use once_cell::sync::Lazy;

    /// Samsung Galaxy J6: Exynos 7870, 8×1.6 GHz, 4 GB RAM, 3000 mAh,
    /// WiFi 802.11 b/g/n (paper §III-A / §VI-A).
    pub static SAMSUNG_J6: Lazy<ComputeProfile> = Lazy::new(|| ComputeProfile {
        name: "samsung_j6",
        cores: 8,
        clock_hz: 1.6e9,
        freq_ghz: 1.6,
        memory_bytes: 4 * 1024 * 1024 * 1024,
        battery_mah: Some(3000.0),
        wifi: Some(WifiStandard::N80211),
        cycles_per_byte: 25.0,
        slowdown_vs_host: 4.0,
    });

    /// Redmi Note 8: Snapdragon 665, 8 cores (4×2.0 + 4×1.8 GHz; modelled
    /// at 2.0), 4 GB RAM, 4000 mAh, WiFi 802.11 ac (paper §III-A).
    pub static REDMI_NOTE8: Lazy<ComputeProfile> = Lazy::new(|| ComputeProfile {
        name: "redmi_note8",
        cores: 8,
        clock_hz: 2.0e9,
        freq_ghz: 2.0,
        memory_bytes: 4 * 1024 * 1024 * 1024,
        battery_mah: Some(4000.0),
        wifi: Some(WifiStandard::Ac80211),
        cycles_per_byte: 25.0,
        slowdown_vs_host: 3.0,
    });

    /// Cloud server: Windows-10 box, 1.6 GHz quad-core i5, 8 GB RAM
    /// (paper §VI-A). Lower cycles/byte: desktop-class vector units + BLAS.
    pub static CLOUD_SERVER: Lazy<ComputeProfile> = Lazy::new(|| ComputeProfile {
        name: "cloud_server",
        cores: 4,
        clock_hz: 1.6e9,
        freq_ghz: 1.6,
        memory_bytes: 8 * 1024 * 1024 * 1024,
        battery_mah: None,
        wifi: None,
        cycles_per_byte: 2.0,
        slowdown_vs_host: 1.0,
    });

    /// Metro edge server: a small aggregation-site box of the class
    /// SplitPlace-style deployments colocate near the access network —
    /// one wired hop closer than the core cloud but slower per byte
    /// (4×2.0 GHz, general-purpose serving stack ⇒ higher cycles/byte
    /// than the cloud's tuned BLAS path). That deliberate per-byte
    /// deficit is what makes the tiered trade-off real: torso layers
    /// are worth placing at the edge exactly while shrinking the
    /// activation saves more backhaul time than the slower compute
    /// costs, so conv trunks land at the edge and the parameter-heavy
    /// fc tail stays in the cloud instead of one tier degenerately
    /// absorbing everything.
    pub static EDGE_SERVER: Lazy<ComputeProfile> = Lazy::new(|| ComputeProfile {
        name: "edge_server",
        cores: 4,
        clock_hz: 2.0e9,
        freq_ghz: 2.0,
        memory_bytes: 16 * 1024 * 1024 * 1024,
        battery_mah: None,
        wifi: None,
        cycles_per_byte: 3.0,
        slowdown_vs_host: 1.0,
    });

    pub fn samsung_j6() -> &'static ComputeProfile {
        &SAMSUNG_J6
    }

    pub fn redmi_note8() -> &'static ComputeProfile {
        &REDMI_NOTE8
    }

    pub fn cloud_server() -> &'static ComputeProfile {
        &CLOUD_SERVER
    }

    pub fn edge_server() -> &'static ComputeProfile {
        &EDGE_SERVER
    }

    pub fn by_name(name: &str) -> Option<&'static ComputeProfile> {
        match name {
            "samsung_j6" | "j6" => Some(samsung_j6()),
            "redmi_note8" | "redmi" => Some(redmi_note8()),
            "cloud_server" | "cloud" => Some(cloud_server()),
            "edge_server" | "edge" => Some(edge_server()),
            _ => None,
        }
    }
}

/// BatteryStats stand-in: a ledger of (component, power_w, duration_s)
/// samples integrated into Joules, with battery state-of-charge tracking.
///
/// The paper computes `E = V·Q` from BatteryStats dumps; we integrate the
/// §III closed-form power models directly (DESIGN.md §4 substitution).
#[derive(Debug)]
pub struct EnergyMeter {
    inner: Mutex<MeterState>,
    /// Nominal battery voltage (V) for state-of-charge conversion.
    pub nominal_voltage: f64,
    pub battery_mah: f64,
}

#[derive(Debug, Default)]
struct MeterState {
    client_j: f64,
    upload_j: f64,
    download_j: f64,
    samples: u64,
}

/// Which subsystem consumed the energy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EnergyComponent {
    ClientCompute,
    Upload,
    Download,
}

impl EnergyMeter {
    pub fn new(profile: &ComputeProfile) -> Self {
        EnergyMeter {
            inner: Mutex::new(MeterState::default()),
            nominal_voltage: 3.85,
            battery_mah: profile.battery_mah.unwrap_or(f64::INFINITY),
        }
    }

    /// Record `power_w` drawn for `duration_s` by `component`.
    pub fn record(&self, component: EnergyComponent, power_w: f64, duration_s: f64) {
        debug_assert!(power_w >= 0.0 && duration_s >= 0.0);
        let mut st = self.inner.lock().unwrap();
        let j = power_w * duration_s;
        match component {
            EnergyComponent::ClientCompute => st.client_j += j,
            EnergyComponent::Upload => st.upload_j += j,
            EnergyComponent::Download => st.download_j += j,
        }
        st.samples += 1;
    }

    pub fn client_j(&self) -> f64 {
        self.inner.lock().unwrap().client_j
    }

    pub fn upload_j(&self) -> f64 {
        self.inner.lock().unwrap().upload_j
    }

    pub fn download_j(&self) -> f64 {
        self.inner.lock().unwrap().download_j
    }

    pub fn total_j(&self) -> f64 {
        let st = self.inner.lock().unwrap();
        st.client_j + st.upload_j + st.download_j
    }

    pub fn samples(&self) -> u64 {
        self.inner.lock().unwrap().samples
    }

    /// Fraction of the battery consumed so far (E = V·Q with Q in mAh·3.6 C).
    pub fn battery_fraction_used(&self) -> f64 {
        let capacity_j = self.battery_mah * 3.6 * self.nominal_voltage;
        self.total_j() / capacity_j
    }

    pub fn reset(&self) {
        *self.inner.lock().unwrap() = MeterState::default();
    }
}

/// Tracks live allocation against the profile's capacity — the runtime
/// enforcement of Eq. 17's `M_edge|l1 ≤ M`.
#[derive(Debug)]
pub struct MemoryTracker {
    capacity: u64,
    used: Mutex<u64>,
    high_water: Mutex<u64>,
}

impl MemoryTracker {
    pub fn new(capacity_bytes: u64) -> Self {
        MemoryTracker { capacity: capacity_bytes, used: Mutex::new(0), high_water: Mutex::new(0) }
    }

    /// Try to reserve; `Err` when it would exceed capacity.
    pub fn reserve(&self, bytes: u64) -> Result<(), u64> {
        let mut used = self.used.lock().unwrap();
        if *used + bytes > self.capacity {
            return Err(self.capacity - *used);
        }
        *used += bytes;
        let mut hw = self.high_water.lock().unwrap();
        *hw = (*hw).max(*used);
        Ok(())
    }

    pub fn release(&self, bytes: u64) {
        let mut used = self.used.lock().unwrap();
        *used = used.saturating_sub(bytes);
    }

    pub fn used(&self) -> u64 {
        *self.used.lock().unwrap()
    }

    pub fn high_water(&self) -> u64 {
        *self.high_water.lock().unwrap()
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_testbed_specs() {
        let j6 = profiles::samsung_j6();
        assert_eq!(j6.cores, 8);
        assert_eq!(j6.clock_hz, 1.6e9);
        assert_eq!(j6.battery_mah, Some(3000.0));
        assert_eq!(j6.wifi, Some(WifiStandard::N80211));
        let redmi = profiles::redmi_note8();
        assert_eq!(redmi.wifi, Some(WifiStandard::Ac80211));
        assert_eq!(redmi.battery_mah, Some(4000.0));
        let cloud = profiles::cloud_server();
        assert_eq!(cloud.cores, 4);
        assert_eq!(cloud.memory_bytes, 8 * 1024 * 1024 * 1024);
        assert!(cloud.wifi.is_none());
    }

    #[test]
    fn wifi_selects_radio_constants() {
        assert_eq!(WifiStandard::N80211.radio_power(), RadioPower::PAPER_80211N);
        assert_eq!(WifiStandard::Ac80211.radio_power(), RadioPower::WIFI_80211AC);
        // The paper's key contrast: ac uploads are much cheaper per Mbps.
        assert!(
            RadioPower::WIFI_80211AC.upload_power_w(10.0)
                < 0.5 * RadioPower::PAPER_80211N.upload_power_w(10.0)
        );
    }

    #[test]
    fn energy_meter_accumulates_per_component() {
        let m = EnergyMeter::new(profiles::samsung_j6());
        m.record(EnergyComponent::ClientCompute, 2.0, 1.5);
        m.record(EnergyComponent::Upload, 3.0, 0.5);
        m.record(EnergyComponent::Upload, 3.0, 0.5);
        m.record(EnergyComponent::Download, 1.0, 0.1);
        assert!((m.client_j() - 3.0).abs() < 1e-12);
        assert!((m.upload_j() - 3.0).abs() < 1e-12);
        assert!((m.download_j() - 0.1).abs() < 1e-12);
        assert!((m.total_j() - 6.1).abs() < 1e-12);
        assert_eq!(m.samples(), 4);
        m.reset();
        assert_eq!(m.total_j(), 0.0);
    }

    #[test]
    fn battery_fraction() {
        let m = EnergyMeter::new(profiles::samsung_j6());
        // 3000 mAh * 3.6 * 3.85 V = 41580 J capacity
        m.record(EnergyComponent::ClientCompute, 41580.0, 0.5);
        assert!((m.battery_fraction_used() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn memory_tracker_enforces_capacity() {
        let t = MemoryTracker::new(100);
        assert!(t.reserve(60).is_ok());
        assert_eq!(t.reserve(50), Err(40));
        assert!(t.reserve(40).is_ok());
        assert_eq!(t.used(), 100);
        t.release(30);
        assert_eq!(t.used(), 70);
        assert_eq!(t.high_water(), 100);
        t.release(1000); // saturating
        assert_eq!(t.used(), 0);
    }
}
