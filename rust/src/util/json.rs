//! Minimal-but-complete JSON substrate (`serde_json` is unavailable offline
//! — DESIGN.md §4). Parses the python-emitted `manifest.json` files and
//! serialises bench/figure outputs.
//!
//! Full RFC 8259 value model: null / bool / number (f64) / string (with
//! escapes incl. `\uXXXX` surrogate pairs) / array / object. Objects keep
//! insertion order (Vec of pairs) so serialised output is stable.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

/// Parse/access errors (`thiserror` is unavailable offline — DESIGN.md §4,
/// so `Display`/`Error` are hand-rolled).
#[derive(Debug)]
pub enum JsonError {
    Eof(usize),
    Unexpected(char, usize),
    BadNumber(usize),
    BadUnicode(usize),
    Trailing(usize),
    Type { expected: &'static str, found: &'static str },
    MissingKey(String),
    OutOfBounds(usize, usize),
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonError::Eof(i) => write!(f, "unexpected end of input at byte {i}"),
            JsonError::Unexpected(c, i) => write!(f, "unexpected character {c:?} at byte {i}"),
            JsonError::BadNumber(i) => write!(f, "invalid number at byte {i}"),
            JsonError::BadUnicode(i) => write!(f, "invalid \\u escape at byte {i}"),
            JsonError::Trailing(i) => write!(f, "trailing garbage at byte {i}"),
            JsonError::Type { expected, found } => {
                write!(f, "type error: expected {expected}, found {found}")
            }
            JsonError::MissingKey(k) => write!(f, "missing key {k:?}"),
            JsonError::OutOfBounds(i, len) => write!(f, "index {i} out of bounds (len {len})"),
        }
    }
}

impl std::error::Error for JsonError {}

pub type Result<T> = std::result::Result<T, JsonError>;

impl Json {
    // ---------------------------------------------------------------- parse

    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(JsonError::Trailing(p.i));
        }
        Ok(v)
    }

    // ------------------------------------------------------------ accessors

    fn kind(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => Err(JsonError::Type { expected: "number", found: other.kind() }),
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        Ok(self.as_f64()? as u64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(JsonError::Type { expected: "string", found: other.kind() }),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(JsonError::Type { expected: "bool", found: other.kind() }),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => Err(JsonError::Type { expected: "array", found: other.kind() }),
        }
    }

    pub fn as_obj(&self) -> Result<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Ok(o),
            other => Err(JsonError::Type { expected: "object", found: other.kind() }),
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v)
            .ok_or_else(|| JsonError::MissingKey(key.to_string()))
    }

    /// Array index lookup.
    pub fn at(&self, idx: usize) -> Result<&Json> {
        let a = self.as_arr()?;
        a.get(idx).ok_or(JsonError::OutOfBounds(idx, a.len()))
    }

    pub fn get_f64(&self, key: &str) -> Result<f64> {
        self.get(key)?.as_f64()
    }

    pub fn get_usize(&self, key: &str) -> Result<usize> {
        self.get(key)?.as_usize()
    }

    pub fn get_str(&self, key: &str) -> Result<&str> {
        self.get(key)?.as_str()
    }

    /// `[1,2,3]` → `vec![1usize, 2, 3]` (shape lists in the manifest).
    pub fn get_usize_vec(&self, key: &str) -> Result<Vec<usize>> {
        self.get(key)?.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // --------------------------------------------------------------- build

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // ---------------------------------------------------------- serialise

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(0));
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    v.write(out, indent);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                let ind = indent.map(|d| d + 1);
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(d) = ind {
                        out.push('\n');
                        out.push_str(&" ".repeat(d));
                    }
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, ind);
                }
                if let (Some(d), false) = (indent, o.is_empty()) {
                    out.push('\n');
                    out.push_str(&" ".repeat(d));
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, None);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or(JsonError::Eof(self.i))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? == c {
            self.i += 1;
            Ok(())
        } else {
            Err(JsonError::Unexpected(self.b[self.i] as char, self.i))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(JsonError::Unexpected(self.b[self.i] as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(JsonError::Unexpected(c as char, self.i)),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                c => return Err(JsonError::Unexpected(c as char, self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            out.push((k, v));
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                c => return Err(JsonError::Unexpected(c as char, self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if self.peek()? != b'\\' {
                                    return Err(JsonError::BadUnicode(self.i));
                                }
                                self.i += 1;
                                if self.peek()? != b'u' {
                                    return Err(JsonError::BadUnicode(self.i));
                                }
                                self.i += 1;
                                let lo = self.hex4()?;
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                hi
                            };
                            s.push(
                                char::from_u32(cp).ok_or(JsonError::BadUnicode(self.i))?,
                            );
                        }
                        _ => return Err(JsonError::Unexpected(e as char, self.i - 1)),
                    }
                }
                c => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.b.len() {
                            return Err(JsonError::Eof(self.i));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..start + len])
                            .map_err(|_| JsonError::BadUnicode(start))?;
                        s.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.i + 4 > self.b.len() {
            return Err(JsonError::Eof(self.i));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| JsonError::BadUnicode(self.i))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| JsonError::BadUnicode(self.i))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError::BadNumber(start))
    }
}

/// Convenience: parse a file.
pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    Ok(Json::parse(&text)?)
}

/// Group helper used by figure emitters: ordered map of series name → rows.
pub type Series = BTreeMap<String, Vec<(f64, f64)>>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x");
        assert_eq!(j.get("a").unwrap().at(1).unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(*j.get("a").unwrap().at(2).unwrap().get("b").unwrap(), Json::Null);
    }

    #[test]
    fn parses_escapes() {
        let j = Json::parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\n\t\"\\Aé");
    }

    #[test]
    fn parses_surrogate_pair() {
        let j = Json::parse(r#""😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "😀");
    }

    #[test]
    fn parses_utf8_passthrough() {
        let j = Json::parse("\"héllo — ok\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo — ok");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"model": "alexnet", "layers": [{"index": 1, "flops": 140553600}], "acc": 0.5652, "flag": true, "none": null}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
        let j3 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, j3);
    }

    #[test]
    fn whole_numbers_serialise_without_fraction() {
        assert_eq!(Json::Num(140553600.0).to_string(), "140553600");
        assert_eq!(Json::Num(0.5652).to_string(), "0.5652");
    }

    #[test]
    fn typed_getters_error_cleanly() {
        let j = Json::parse(r#"{"a": 1}"#).unwrap();
        assert!(j.get("missing").is_err());
        assert!(j.get("a").unwrap().as_str().is_err());
        assert!(j.at(0).is_err());
    }

    #[test]
    fn usize_vec() {
        let j = Json::parse(r#"{"shape": [1, 64, 55, 55]}"#).unwrap();
        assert_eq!(j.get_usize_vec("shape").unwrap(), vec![1, 64, 55, 55]);
    }
}
