//! Seeded property-testing substrate (`proptest` is unavailable offline —
//! DESIGN.md §4).
//!
//! [`run_prop`] drives a property over `n` random cases from a deterministic
//! seed; on failure it *shrinks* the failing case by asking the generator
//! for progressively "smaller" inputs (halving the size budget) and reports
//! the smallest reproduction together with the case seed, so failures are
//! replayable.
//!
//! ```ignore
//! run_prop("sort is idempotent", 200, |g| {
//!     let mut v = g.vec_usize(0, 100, 0..50);
//!     sort(&mut v);
//!     let w = v.clone();
//!     sort(&mut v);
//!     prop_assert!(v == w, "double sort changed output: {v:?} vs {w:?}");
//!     Ok(())
//! });
//! ```

use super::rng::Xoshiro256;

/// Generator handle passed to properties; wraps the case RNG plus a size
/// budget that the shrinker lowers on failure.
pub struct Gen {
    pub rng: Xoshiro256,
    /// 0.0..=1.0 multiplier on requested sizes; shrinking lowers this.
    pub size: f64,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        let hi_scaled = lo + (((hi - lo) as f64) * self.size).round() as usize;
        self.rng.gen_range(lo, hi_scaled.max(lo))
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.size * self.rng.next_f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.gen_bool(0.5)
    }

    pub fn vec_f64(&mut self, len_lo: usize, len_hi: usize, lo: f64, hi: f64) -> Vec<f64> {
        let n = self.usize_in(len_lo, len_hi);
        (0..n).map(|_| lo + (hi - lo) * self.rng.next_f64()).collect()
    }

    pub fn vec_usize(&mut self, len_lo: usize, len_hi: usize, lo: usize, hi: usize) -> Vec<usize> {
        let n = self.usize_in(len_lo, len_hi);
        (0..n).map(|_| self.rng.gen_range(lo, hi)).collect()
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choose(xs)
    }
}

pub type PropResult = Result<(), String>;

/// Assert inside a property; returns a `PropResult` error with context.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

/// Run `prop` over `cases` random cases. Panics (with shrunk repro info) on
/// the first failure. Seed defaults derived from the name for stability.
pub fn run_prop<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> PropResult,
{
    let base_seed = fnv1a(name.as_bytes());
    for case in 0..cases {
        let seed = base_seed ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut g = Gen { rng: Xoshiro256::seed_from_u64(seed), size: 1.0 };
        if let Err(msg) = prop(&mut g) {
            // Shrink: same seed, smaller size budget.
            let mut best = (1.0f64, msg);
            let mut size = 0.5;
            for _ in 0..16 {
                let mut g = Gen { rng: Xoshiro256::seed_from_u64(seed), size };
                match prop(&mut g) {
                    Err(m) => {
                        best = (size, m);
                        size *= 0.5;
                    }
                    Ok(()) => {
                        size = (size + best.0) / 2.0;
                    }
                }
                if best.0 - size < 1e-3 {
                    break;
                }
            }
            panic!(
                "property {name:?} failed (case {case}, seed {seed:#x}, \
                 shrunk size {:.3}):\n  {}",
                best.0, best.1
            );
        }
    }
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        run_prop("always true", 50, |g| {
            let _ = g.usize_in(0, 10);
            n += 1;
            Ok(())
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics_with_context() {
        run_prop("xs len < 5", 100, |g| {
            let xs = g.vec_f64(0, 20, 0.0, 1.0);
            prop_assert!(xs.len() < 5, "len was {}", xs.len());
            Ok(())
        });
    }

    #[test]
    fn generator_is_deterministic_per_name() {
        let mut a = Vec::new();
        run_prop("det", 5, |g| {
            a.push(g.usize_in(0, 1000));
            Ok(())
        });
        let mut b = Vec::new();
        run_prop("det", 5, |g| {
            b.push(g.usize_in(0, 1000));
            Ok(())
        });
        assert_eq!(a, b);
    }

    #[test]
    fn size_budget_bounds_generation() {
        let mut g = Gen { rng: Xoshiro256::seed_from_u64(1), size: 0.0 };
        for _ in 0..100 {
            assert_eq!(g.usize_in(3, 100), 3);
        }
    }
}
