//! Declarative CLI flag parser (`clap` is unavailable offline — DESIGN.md §4).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! subcommands, defaults, and auto-generated `--help`.

use std::collections::BTreeMap;

#[derive(Clone, Debug)]
pub struct Opt {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_bool: bool,
    /// Repeatable flag: every occurrence is kept, in argv order
    /// (`--slo a --slo b`); read back with [`Parsed::get_multi`].
    pub is_multi: bool,
}

#[derive(Debug, Default)]
pub struct Cli {
    pub bin: String,
    pub about: &'static str,
    opts: Vec<Opt>,
}

#[derive(Debug)]
pub struct Parsed {
    values: BTreeMap<&'static str, String>,
    bools: BTreeMap<&'static str, bool>,
    multis: BTreeMap<&'static str, Vec<String>>,
    /// Flags the user actually typed (as opposed to declared defaults) —
    /// lets callers distinguish "explicitly asked for the default value"
    /// from "said nothing".
    provided: std::collections::BTreeSet<&'static str>,
    pub positionals: Vec<String>,
}

impl Cli {
    pub fn new(about: &'static str) -> Self {
        Self { bin: String::new(), about, opts: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, default: Some(default), is_bool: false, is_multi: false });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, default: None, is_bool: false, is_multi: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, default: None, is_bool: true, is_multi: false });
        self
    }

    /// A repeatable value flag: `--name a --name b` accumulates
    /// `["a", "b"]` (argv order); zero occurrences is fine. Read back
    /// with [`Parsed::get_multi`].
    pub fn multi(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, default: None, is_bool: false, is_multi: true });
        self
    }

    /// The shared `--planner <strategy>` flag. Declared here — and only
    /// here — so every planning subcommand (`optimize`, `simulate`,
    /// `serve`/`demo`, `fleet`) exposes the identical flag and parses it
    /// through [`Parsed::planner`].
    pub fn planner_opt(self) -> Self {
        self.opt(
            "planner",
            "SmartSplit",
            "planning strategy: SmartSplit|Topsis|LBO|EBO|COS|COC|RS|WeightedSum|WeightedMetric|EpsilonConstrained (case-insensitive)",
        )
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{}\n\nOptions:\n", self.about);
        for o in &self.opts {
            let d = match (&o.default, o.is_bool, o.is_multi) {
                (Some(d), _, _) => format!(" [default: {d}]"),
                (None, _, true) => " (repeatable)".into(),
                (None, true, _) => String::new(),
                (None, false, _) => " (required)".into(),
            };
            s.push_str(&format!("  --{:<22} {}{}\n", o.name, o.help, d));
        }
        s.push_str("  --help                   show this message\n");
        s
    }

    /// Parse argv (without the binary name). Returns Err(usage) on `--help`
    /// or bad input so callers can print and exit.
    pub fn parse(&self, args: &[String]) -> Result<Parsed, String> {
        let mut p = Parsed {
            values: BTreeMap::new(),
            bools: BTreeMap::new(),
            multis: BTreeMap::new(),
            provided: std::collections::BTreeSet::new(),
            positionals: Vec::new(),
        };
        for o in &self.opts {
            if let Some(d) = o.default {
                p.values.insert(o.name, d.to_string());
            }
            if o.is_bool {
                p.bools.insert(o.name, false);
            }
            if o.is_multi {
                p.multis.insert(o.name, Vec::new());
            }
        }
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (stripped, None),
                };
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| format!("unknown flag --{name}\n\n{}", self.usage()))?;
                p.provided.insert(opt.name);
                if opt.is_bool {
                    p.bools.insert(opt.name, true);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{name} needs a value"))?
                        }
                    };
                    if opt.is_multi {
                        p.multis.entry(opt.name).or_default().push(v);
                    } else {
                        p.values.insert(opt.name, v);
                    }
                }
            } else {
                p.positionals.push(a.clone());
            }
            i += 1;
        }
        for o in &self.opts {
            if !o.is_bool && !o.is_multi && !p.values.contains_key(o.name) {
                return Err(format!("missing required --{}\n\n{}", o.name, self.usage()));
            }
        }
        Ok(p)
    }
}

impl Parsed {
    /// Did the user explicitly pass this flag (rather than inherit its
    /// declared default)?
    pub fn provided(&self, name: &str) -> bool {
        self.provided.contains(name)
    }

    /// The `--planner` strategy (see [`Cli::planner_opt`]) —
    /// case-insensitive, with an error listing every valid name. This is
    /// the one place a strategy name is parsed.
    pub fn planner(&self) -> Result<crate::planner::Strategy, String> {
        crate::planner::Strategy::by_name(self.get("planner"))
    }

    pub fn get(&self, name: &str) -> &str {
        self.values
            .iter()
            .find(|(k, _)| **k == name)
            .map(|(_, v)| v.as_str())
            .unwrap_or_else(|| panic!("flag {name} not declared"))
    }

    pub fn get_bool(&self, name: &str) -> bool {
        *self
            .bools
            .iter()
            .find(|(k, _)| **k == name)
            .map(|(_, v)| v)
            .unwrap_or_else(|| panic!("bool flag {name} not declared"))
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects an integer, got {:?}", self.get(name)))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects a number, got {:?}", self.get(name)))
    }

    pub fn get_u64(&self, name: &str) -> u64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects an integer, got {:?}", self.get(name)))
    }

    /// Duration in seconds, accepting a bare number or an `s`/`m`/`h`
    /// suffix: `90`, `90s`, `10m`, `2h` (simulation horizons are most
    /// naturally written in minutes/hours).
    pub fn get_duration_s(&self, name: &str) -> f64 {
        let v = self.get(name).trim();
        let (num, mult) = match v.as_bytes().last().copied() {
            Some(b's') => (&v[..v.len() - 1], 1.0),
            Some(b'm') => (&v[..v.len() - 1], 60.0),
            Some(b'h') => (&v[..v.len() - 1], 3600.0),
            _ => (v, 1.0),
        };
        let n: f64 = num
            .trim()
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects a duration like 90, 90s, 10m or 2h, got {v:?}"));
        n * mult
    }

    /// Every occurrence of a repeatable flag (see [`Cli::multi`]), in
    /// argv order; empty when the user never passed it.
    pub fn get_multi(&self, name: &str) -> &[String] {
        self.multis
            .iter()
            .find(|(k, _)| **k == name)
            .map(|(_, v)| v.as_slice())
            .unwrap_or_else(|| panic!("multi flag {name} not declared"))
    }

    /// Comma-separated list.
    pub fn get_list(&self, name: &str) -> Vec<String> {
        let v = self.get(name);
        if v.is_empty() {
            return Vec::new();
        }
        v.split(',').map(|s| s.trim().to_string()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("test")
            .opt("model", "alexnet", "model name")
            .opt("bandwidth-mbps", "10", "link bandwidth")
            .flag("verbose", "chatty")
            .req("port", "tcp port")
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_required() {
        let p = cli().parse(&argv(&["--port", "9000"])).unwrap();
        assert_eq!(p.get("model"), "alexnet");
        assert_eq!(p.get_usize("port"), 9000);
        assert!(!p.get_bool("verbose"));
    }

    #[test]
    fn provided_distinguishes_explicit_from_default() {
        // Explicitly passing a flag's default value still counts as
        // provided — callers use this to respect deliberate choices.
        let p = cli().parse(&argv(&["--model", "alexnet", "--port", "1"])).unwrap();
        assert!(p.provided("model"));
        assert!(p.provided("port"));
        assert!(!p.provided("bandwidth-mbps"));
        assert!(!p.provided("verbose"));
        let q = cli().parse(&argv(&["--verbose", "--port=1"])).unwrap();
        assert!(q.provided("verbose"));
    }

    #[test]
    fn equals_form_and_flags() {
        let p = cli()
            .parse(&argv(&["--model=vgg16", "--verbose", "--port=1", "serve"]))
            .unwrap();
        assert_eq!(p.get("model"), "vgg16");
        assert!(p.get_bool("verbose"));
        assert_eq!(p.positionals, vec!["serve"]);
    }

    #[test]
    fn missing_required_errors() {
        assert!(cli().parse(&argv(&[])).is_err());
    }

    #[test]
    fn unknown_flag_errors() {
        assert!(cli().parse(&argv(&["--nope", "1", "--port", "2"])).is_err());
    }

    #[test]
    fn help_returns_usage() {
        let err = cli().parse(&argv(&["--help"])).unwrap_err();
        assert!(err.contains("--model"));
        assert!(err.contains("--port"));
    }

    #[test]
    fn planner_flag_parses_in_one_place() {
        let c = Cli::new("t").planner_opt();
        let p = c.parse(&[]).unwrap();
        assert_eq!(p.planner(), Ok(crate::planner::Strategy::SmartSplit));
        let p = c.parse(&argv(&["--planner", "lbo"])).unwrap();
        assert_eq!(p.planner(), Ok(crate::planner::Strategy::Lbo));
        let p = c.parse(&argv(&["--planner=topsis"])).unwrap();
        assert_eq!(p.planner(), Ok(crate::planner::Strategy::Topsis));
        let err = c.parse(&argv(&["--planner", "nope"])).unwrap().planner().unwrap_err();
        assert!(err.contains("SmartSplit") && err.contains("EpsilonConstrained"));
    }

    #[test]
    fn multi_flags_accumulate_in_argv_order() {
        let c = Cli::new("t").multi("slo", "an SLO clause");
        let p = c.parse(&argv(&["--slo", "p99<2.5s", "--slo=drop<0.1%"])).unwrap();
        assert_eq!(p.get_multi("slo"), ["p99<2.5s", "drop<0.1%"]);
        assert!(p.provided("slo"));
        // Zero occurrences is fine — multi flags are never required.
        let p = c.parse(&[]).unwrap();
        assert!(p.get_multi("slo").is_empty());
        assert!(!p.provided("slo"));
        // And the help line marks repeatability.
        assert!(c.usage().contains("(repeatable)"));
    }

    #[test]
    fn list_parsing() {
        let c = Cli::new("t").opt("models", "a,b , c", "list");
        let p = c.parse(&[]).unwrap();
        assert_eq!(p.get_list("models"), vec!["a", "b", "c"]);
    }

    #[test]
    fn duration_parsing() {
        let c = Cli::new("t").opt("dur", "90", "duration");
        for (arg, expect) in [
            ("90", 90.0),
            ("45s", 45.0),
            ("10m", 600.0),
            ("1.5h", 5400.0),
            ("0.25m", 15.0),
        ] {
            let p = c.parse(&[format!("--dur={arg}")]).unwrap();
            assert_eq!(p.get_duration_s("dur"), expect, "arg {arg}");
        }
        // Default path too.
        let p = c.parse(&[]).unwrap();
        assert_eq!(p.get_duration_s("dur"), 90.0);
    }

    #[test]
    #[should_panic(expected = "expects a duration")]
    fn duration_rejects_garbage() {
        let c = Cli::new("t").opt("dur", "90", "duration");
        let p = c.parse(&["--dur=soon".to_string()]).unwrap();
        p.get_duration_s("dur");
    }
}
