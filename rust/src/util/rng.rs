//! Deterministic PRNG substrate (`rand` is unavailable offline — DESIGN.md §4).
//!
//! [`SplitMix64`] seeds [`Xoshiro256`] (xoshiro256++), the same construction
//! the reference `rand_xoshiro` crate uses. Everything downstream (NSGA-II,
//! workload generators, random-split baseline, property tests) takes an
//! explicit `&mut Xoshiro256` so every run is reproducible from one seed.

/// SplitMix64 — used to expand a single `u64` seed into xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1) with 53 bits of entropy.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in `[lo, hi]` (inclusive). Uses rejection-free
    /// Lemire-style mapping; bias is < 2^-64 * range, irrelevant here.
    pub fn gen_range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        let range = hi - lo + 1;
        if range == 0 {
            return self.next_u64(); // full range
        }
        lo + (((self.next_u64() as u128 * range as u128) >> 64) as u64)
    }

    /// Uniform `usize` in `[lo, hi]` (inclusive).
    pub fn gen_range(&mut self, lo: usize, hi: usize) -> usize {
        self.gen_range_u64(lo as u64, hi as u64) as usize
    }

    /// Bernoulli(p).
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller (used by workload jitter).
    pub fn next_normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Exponential with rate `lambda` (Poisson inter-arrival times).
    pub fn next_exp(&mut self, lambda: f64) -> f64 {
        -self.next_f64().max(f64::MIN_POSITIVE).ln() / lambda
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(0, i);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(0, xs.len() - 1)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // Reference vector: seed 1234567 (public SplitMix64 test vectors).
        let mut sm = SplitMix64::new(1234567);
        let v: Vec<u64> = (0..3).map(|_| sm.next_u64()).collect();
        assert_eq!(v[0], 6457827717110365317);
        assert_eq!(v[1], 3203168211198807973);
        assert_eq!(v[2], 9817491932198370423);
    }

    #[test]
    fn deterministic_across_clones() {
        let mut a = Xoshiro256::seed_from_u64(42);
        let mut b = a.clone();
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Xoshiro256::seed_from_u64(1);
        let mut b = Xoshiro256::seed_from_u64(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_inclusive_bounds_hit() {
        let mut r = Xoshiro256::seed_from_u64(3);
        let mut seen = [false; 5];
        for _ in 0..1000 {
            seen[r.gen_range(0, 4)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gen_range_single_point() {
        let mut r = Xoshiro256::seed_from_u64(3);
        assert_eq!(r.gen_range(5, 5), 5);
    }

    #[test]
    fn normal_mean_and_var_sane() {
        let mut r = Xoshiro256::seed_from_u64(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exp_mean_is_inverse_rate() {
        let mut r = Xoshiro256::seed_from_u64(13);
        let n = 50_000;
        let mean = (0..n).map(|_| r.next_exp(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::seed_from_u64(17);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
