//! Offline substrates: JSON, PRNG, CLI parsing, property testing, thread
//! pool. Each replaces a crates.io dependency that is unresolvable in this
//! environment (DESIGN.md §4 lists the mapping).

pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;

/// Format a byte count for human-readable logs/tables.
pub fn fmt_bytes(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format seconds adaptively (µs / ms / s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.3} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(774400), "756.25 KiB");
        assert_eq!(fmt_bytes(5 * 1024 * 1024), "5.00 MiB");
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(fmt_secs(0.0000005), "0.5 µs");
        assert_eq!(fmt_secs(0.0025), "2.50 ms");
        assert_eq!(fmt_secs(2.5), "2.500 s");
    }
}
