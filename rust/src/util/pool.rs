//! Thread-pool substrate (`tokio` is unavailable offline — DESIGN.md §4).
//!
//! A fixed-size worker pool over an MPMC channel built from
//! `std::sync::{Mutex, Condvar}`. The serving path (`serve::`) uses it for
//! connection handling; `scope`-style joining is provided through
//! [`ThreadPool::run_all`] for fan-out/fan-in work.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<State>,
    cv: Condvar,
}

struct State {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

/// Per-batch fan-in state for [`ThreadPool::run_all`]: result slots plus
/// a completion count, signalled once the batch's own tasks are done.
struct Batch<T> {
    slots: Mutex<(Vec<Option<T>>, usize)>,
    done: Condvar,
}

/// Fixed worker pool; drops shut it down gracefully (workers finish queued
/// jobs first).
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    inflight: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(State { jobs: VecDeque::new(), shutdown: false }),
            cv: Condvar::new(),
        });
        let inflight = Arc::new(AtomicUsize::new(0));
        let workers = (0..threads)
            .map(|i| {
                let shared = Arc::clone(&shared);
                let inflight = Arc::clone(&inflight);
                std::thread::Builder::new()
                    .name(format!("smartsplit-pool-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let mut st = shared.queue.lock().unwrap();
                            loop {
                                if let Some(j) = st.jobs.pop_front() {
                                    break j;
                                }
                                if st.shutdown {
                                    return;
                                }
                                st = shared.cv.wait(st).unwrap();
                            }
                        };
                        job();
                        inflight.fetch_sub(1, Ordering::SeqCst);
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        Self { shared, workers, inflight }
    }

    /// Queue a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.inflight.fetch_add(1, Ordering::SeqCst);
        let mut st = self.shared.queue.lock().unwrap();
        st.jobs.push_back(Box::new(f));
        drop(st);
        self.shared.cv.notify_one();
    }

    /// Number of jobs queued or running.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::SeqCst)
    }

    /// Busy-wait (with parking) until the queue drains.
    pub fn wait_idle(&self) {
        while self.inflight() > 0 {
            std::thread::sleep(std::time::Duration::from_micros(50));
        }
    }

    /// A sensible worker count for CPU-bound fan-out: the machine's
    /// available parallelism, clamped to `max`.
    pub fn default_threads(max: usize) -> usize {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .clamp(1, max.max(1))
    }

    /// Run a batch of closures returning `T`, collecting results in input
    /// order (fan-out / fan-in).
    ///
    /// Joining is per-batch (a dedicated completion count + condvar), not
    /// pool-wide: concurrent `run_all` batches — or unrelated `execute`
    /// jobs in flight — never delay this call beyond its own tasks, and
    /// each caller observes exactly its own results in input order
    /// (determinism under contention is pinned by
    /// `run_all_deterministic_under_contention`). Must not be called from
    /// inside a pool worker (the batch could deadlock waiting for its own
    /// thread).
    pub fn run_all<T, F>(&self, tasks: Vec<F>) -> Vec<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let n = tasks.len();
        if n == 0 {
            return Vec::new();
        }
        let batch: Arc<Batch<T>> = Arc::new(Batch {
            slots: Mutex::new(((0..n).map(|_| None).collect(), 0)),
            done: Condvar::new(),
        });
        for (i, t) in tasks.into_iter().enumerate() {
            let batch = Arc::clone(&batch);
            self.execute(move || {
                let out = t();
                let mut st = batch.slots.lock().unwrap();
                st.0[i] = Some(out);
                st.1 += 1;
                if st.1 == n {
                    batch.done.notify_all();
                }
            });
        }
        let mut st = batch.slots.lock().unwrap();
        while st.1 < n {
            st = batch.done.wait(st).unwrap();
        }
        let slots = std::mem::take(&mut st.0);
        drop(st);
        slots.into_iter().map(|o| o.expect("job completed")).collect()
    }
}

/// Run `f` once per item on scoped threads and join them all before
/// returning — a structural barrier. Unlike [`ThreadPool::run_all`],
/// the closures may borrow non-`'static` state (each gets exclusive
/// `&mut` access to its own item), which is exactly what the sharded
/// event engine needs for its window drains: each shard's heap is
/// drained in place, in parallel, and the scope join is the window
/// barrier (`sim::shard`, DESIGN.md §16). Spawned threads are capped at
/// the machine's available parallelism (items are chunked per thread):
/// past that point extra threads add per-barrier spawn/join cost
/// without adding concurrency. With zero or one item — or a
/// single-core host — the call runs inline: no threads, no overhead.
pub fn scoped_for_each<T, F>(items: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize, &mut T) + Sync,
{
    let threads = ThreadPool::default_threads(items.len());
    if items.len() <= 1 || threads == 1 {
        for (i, item) in items.iter_mut().enumerate() {
            f(i, item);
        }
        return;
    }
    let chunk = items.len().div_ceil(threads);
    std::thread::scope(|s| {
        for (c, slice) in items.chunks_mut(chunk).enumerate() {
            let f = &f;
            let base = c * chunk;
            s.spawn(move || {
                for (off, item) in slice.iter_mut().enumerate() {
                    f(base + off, item);
                }
            });
        }
    });
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.queue.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn run_all_preserves_order() {
        let pool = ThreadPool::new(3);
        let tasks: Vec<_> = (0..50)
            .map(|i| move || i * i)
            .collect();
        let out = pool.run_all(tasks);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn drop_finishes_queued_jobs() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..20 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
        } // drop joins
        assert_eq!(counter.load(Ordering::SeqCst), 20);
    }

    #[test]
    fn zero_threads_clamped_to_one() {
        let pool = ThreadPool::new(0);
        let out = pool.run_all(vec![|| 1, || 2]);
        assert_eq!(out, vec![1, 2]);
    }

    #[test]
    fn run_all_empty_batch() {
        let pool = ThreadPool::new(2);
        let out: Vec<u32> = pool.run_all(Vec::<fn() -> u32>::new());
        assert!(out.is_empty());
    }

    #[test]
    fn run_all_deterministic_under_contention() {
        // Several callers hammer one pool with interleaved batches whose
        // tasks finish out of order (staggered sleeps). Every caller must
        // get exactly its own results, in input order, every round — the
        // property the parallel re-solve fan-out in `sim::on_reoptimize`
        // leans on.
        let pool = Arc::new(ThreadPool::new(4));
        let mut callers = Vec::new();
        for c in 0u64..3 {
            let pool = Arc::clone(&pool);
            callers.push(std::thread::spawn(move || {
                for round in 0u64..5 {
                    let tasks: Vec<_> = (0u64..8)
                        .map(|i| {
                            move || {
                                // Reverse-staggered so completion order is
                                // the opposite of submission order.
                                std::thread::sleep(std::time::Duration::from_micros((8 - i) * 300));
                                c * 10_000 + round * 100 + i
                            }
                        })
                        .collect();
                    let out = pool.run_all(tasks);
                    let want: Vec<u64> =
                        (0u64..8).map(|i| c * 10_000 + round * 100 + i).collect();
                    assert_eq!(out, want, "caller {c} round {round}");
                }
            }));
        }
        for h in callers {
            h.join().unwrap();
        }
    }

    #[test]
    fn default_threads_clamped() {
        assert!(ThreadPool::default_threads(8) >= 1);
        assert!(ThreadPool::default_threads(8) <= 8);
        assert_eq!(ThreadPool::default_threads(0), 1);
    }

    #[test]
    fn scoped_for_each_visits_every_item_with_its_index() {
        let mut items: Vec<(usize, u64)> = (0..16).map(|i| (usize::MAX, i as u64)).collect();
        scoped_for_each(&mut items, |i, item| {
            item.0 = i;
            item.1 *= 2;
        });
        for (i, item) in items.iter().enumerate() {
            assert_eq!(item.0, i);
            assert_eq!(item.1, 2 * i as u64);
        }
    }

    #[test]
    fn scoped_for_each_borrows_local_state() {
        // The whole point vs `run_all`: closures capture references to
        // stack-local data (here a shared slice read by every worker).
        let base: Vec<u64> = (0..8).collect();
        let mut out = vec![0u64; 8];
        scoped_for_each(&mut out, |i, slot| *slot = base[i] + 100);
        assert_eq!(out, (100..108).collect::<Vec<_>>());
    }

    #[test]
    fn scoped_for_each_chunked_beyond_parallelism() {
        // Far more items than cores: chunking must still hand every
        // item its own global index exactly once (uneven final chunk
        // included — 257 is not divisible by any plausible core count).
        let mut items: Vec<u64> = vec![0; 257];
        scoped_for_each(&mut items, |i, item| *item += i as u64 + 1);
        for (i, item) in items.iter().enumerate() {
            assert_eq!(*item, i as u64 + 1, "item {i} visited exactly once");
        }
    }

    #[test]
    fn scoped_for_each_handles_empty_and_single() {
        let mut empty: Vec<u64> = Vec::new();
        scoped_for_each(&mut empty, |_, _| panic!("no items, no calls"));
        let mut one = vec![7u64];
        scoped_for_each(&mut one, |i, x| {
            assert_eq!(i, 0);
            *x += 1;
        });
        assert_eq!(one, vec![8]);
    }
}
