//! Cloud-side daemon: accepts device connections, runs the tail layers of
//! the announced model on the PJRT executor thread, and streams logits
//! back. One handler thread per connection (smartphone clients are few and
//! long-lived); all PJRT state lives on the executor thread.

use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::runtime::executor::Executor;
use crate::serve::protocol::{read_msg, write_msg, Msg};

/// Shared server state.
pub struct CloudServer {
    pub addr: std::net::SocketAddr,
    executor: Executor,
    shutdown: AtomicBool,
    pub requests_served: AtomicU64,
    listener: TcpListener,
}

impl CloudServer {
    /// Bind to `addr` (use port 0 for an ephemeral port) without starting
    /// the accept loop.
    pub fn bind(addr: &str, artifacts_dir: PathBuf) -> Result<Arc<CloudServer>> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let addr = listener.local_addr()?;
        let executor = Executor::spawn(artifacts_dir, "cloud")?;
        Ok(Arc::new(CloudServer {
            addr,
            executor,
            shutdown: AtomicBool::new(false),
            requests_served: AtomicU64::new(0),
            listener,
        }))
    }

    /// Run the accept loop on a background thread; returns the join handle.
    pub fn spawn(self: &Arc<Self>) -> Result<std::thread::JoinHandle<()>> {
        let this = Arc::clone(self);
        std::thread::Builder::new()
            .name("smartsplit-cloud-accept".into())
            .spawn(move || this.accept_loop())
            .context("spawning cloud accept-loop thread")
    }

    fn accept_loop(self: Arc<Self>) {
        // Short-poll accept so shutdown is observed promptly. A failure
        // here leaves the server unreachable but must not unwind — log
        // and bail out of the loop instead.
        if let Err(e) = self.listener.set_nonblocking(true) {
            log::warn!("cloud: cannot set listener nonblocking: {e}");
            return;
        }
        while !self.shutdown.load(Ordering::SeqCst) {
            match self.listener.accept() {
                Ok((stream, peer)) => {
                    log::info!("cloud: connection from {peer}");
                    stream.set_nodelay(true).ok();
                    let this = Arc::clone(&self);
                    let spawned = std::thread::Builder::new()
                        .name("smartsplit-cloud-conn".into())
                        .spawn(move || {
                            if let Err(e) = this.handle_conn(stream) {
                                log::warn!("cloud: connection ended: {e:#}");
                            }
                        });
                    if let Err(e) = spawned {
                        log::warn!("cloud: failed to spawn connection handler: {e}");
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(10));
                }
                Err(e) => {
                    log::warn!("cloud: accept error: {e}");
                    break;
                }
            }
        }
    }

    /// Stop accepting and mark shutdown (existing connections drain on
    /// their own Shutdown messages).
    pub fn stop(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.executor.stop();
    }

    fn handle_conn(&self, stream: TcpStream) -> Result<()> {
        stream.set_nonblocking(false)?;
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut writer = stream;
        let mut session: Option<(String, usize, usize)> = None; // model, batch, L

        loop {
            let msg = match read_msg(&mut reader) {
                Ok(m) => m,
                Err(_) if self.shutdown.load(Ordering::SeqCst) => return Ok(()),
                Err(e) => return Err(e).context("reading from device"),
            };
            match msg {
                Msg::Hello { model, batch } => {
                    let info = self.executor.load(&model, batch as usize)?;
                    write_msg(
                        &mut writer,
                        &Msg::HelloAck { num_layers: info.num_layers as u32 },
                    )?;
                    session = Some((model, batch as usize, info.num_layers));
                }
                Msg::Infer { request_id, from_layer, tensor } => {
                    let Some((model, batch, num_layers)) = session.as_ref() else {
                        write_msg(
                            &mut writer,
                            &Msg::Error { request_id, reason: "no Hello".into() },
                        )?;
                        continue;
                    };
                    let reply = match self.executor.run_segment(
                        model,
                        *batch,
                        from_layer as usize,
                        *num_layers,
                        tensor,
                    ) {
                        Ok(out) => Msg::InferResult { request_id, tensor: out },
                        Err(e) => Msg::Error { request_id, reason: format!("{e:#}") },
                    };
                    self.requests_served.fetch_add(1, Ordering::SeqCst);
                    write_msg(&mut writer, &reply)?;
                }
                Msg::SetSplit { l1 } => {
                    log::info!("cloud: device re-optimised split to l1={l1}");
                }
                Msg::Shutdown => {
                    log::info!("cloud: device said goodbye");
                    return Ok(());
                }
                other => {
                    log::warn!("cloud: unexpected message {other:?}");
                }
            }
        }
    }
}
