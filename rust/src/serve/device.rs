//! Device-side client: emulates the smartphone half of the split runtime.
//!
//! For each request it (1) executes layers `1..=l1` on the PJRT runtime,
//! scaling wall-time by the phone profile's `slowdown_vs_host`; (2) ships
//! the intermediate activation to the cloud over the token-bucket-shaped
//! TCP link; (3) waits for logits. The [`EnergyMeter`] integrates the §III
//! power models over the *measured* phase durations — the runtime analogue
//! of the paper's BatteryStats methodology — and the [`MemoryTracker`]
//! enforces `M|l1 ≤ M` (Eq. 17) at load time.

use std::io::{BufReader, Write};
use std::net::TcpStream;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::device::{ComputeProfile, EnergyComponent, EnergyMeter, MemoryTracker};
use crate::models::Manifest;
use crate::netsim::Link;
use crate::perfmodel::K_CLIENT_POWER;
use crate::runtime::executor::Executor;
use crate::runtime::Tensor;
use crate::serve::protocol::{read_msg, wire_size, write_msg, Msg};

/// Shaped-socket chunk size: small enough that the token bucket paces
/// smoothly, large enough to keep syscall overhead negligible.
const CHUNK: usize = 64 * 1024;

/// Per-request phase timings observed by the device.
#[derive(Clone, Copy, Debug, Default)]
pub struct RequestTiming {
    pub client_s: f64,
    pub upload_s: f64,
    pub cloud_and_download_s: f64,
    pub total_s: f64,
}

/// The smartphone client.
pub struct DeviceClient {
    pub profile: &'static ComputeProfile,
    pub energy: EnergyMeter,
    pub memory: MemoryTracker,
    pub link: Arc<Link>,
    executor: Executor,
    manifest: Manifest,
    batch: usize,
    num_layers: usize,
    input_shape: Vec<usize>,
    split_l1: AtomicUsize,
    conn: Mutex<Conn>,
    model: String,
    /// Emulate phone-speed compute by stretching measured PJRT time.
    pub emulate_slowdown: bool,
}

struct Conn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
}

impl DeviceClient {
    /// Connect to the cloud at `addr`, announce `model`/`batch`, and load
    /// the device-side layers.
    #[allow(clippy::too_many_arguments)]
    pub fn connect(
        addr: &str,
        artifacts_dir: &Path,
        model: &str,
        batch: usize,
        l1: usize,
        profile: &'static ComputeProfile,
        link: Arc<Link>,
    ) -> Result<DeviceClient> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true)?;
        let mut writer = stream.try_clone()?;
        let mut reader = BufReader::new(stream);

        write_msg(&mut writer, &Msg::Hello { model: model.into(), batch: batch as u32 })?;
        let ack = read_msg(&mut reader)?;
        let num_layers = match ack {
            Msg::HelloAck { num_layers } => num_layers as usize,
            other => bail!("expected HelloAck, got {other:?}"),
        };

        // Device-side PJRT lives on its own executor thread ("the phone
        // SoC"); load the whole model so the split can move at runtime —
        // the memory *accounting* below only charges the head (Eq. 17).
        let executor = Executor::spawn(artifacts_dir.to_path_buf(), "device")?;
        let info = executor.load(model, batch)?;
        if info.num_layers != num_layers {
            bail!("device/cloud layer-count mismatch: {} vs {num_layers}", info.num_layers);
        }
        if l1 > num_layers {
            bail!("split l1={l1} exceeds {num_layers} layers");
        }
        let manifest = Manifest::load(artifacts_dir, model)?;

        let memory = MemoryTracker::new(profile.memory_bytes);
        let head_bytes = Self::head_bytes(&manifest, l1);
        memory
            .reserve(head_bytes)
            .map_err(|free| anyhow::anyhow!("Eq.17 violated: head needs {head_bytes} B, {free} B free"))?;

        Ok(DeviceClient {
            profile,
            energy: EnergyMeter::new(profile),
            memory,
            link,
            executor,
            batch,
            num_layers: info.num_layers,
            input_shape: info.input_shape,
            manifest,
            split_l1: AtomicUsize::new(l1),
            conn: Mutex::new(Conn { reader, writer, next_id: 0 }),
            model: model.to_string(),
            emulate_slowdown: true,
        })
    }

    /// `M|l1`: parameter + activation bytes of the head (ref [39]).
    fn head_bytes(manifest: &Manifest, l1: usize) -> u64 {
        manifest.layers[..l1]
            .iter()
            .map(|l| l.param_bytes + l.act_bytes)
            .sum()
    }

    pub fn model(&self) -> &str {
        &self.model
    }

    /// Lock the connection, recovering from a poisoned mutex: a panic
    /// on one request thread must not wedge every later request
    /// (detlint rule R1 — serving paths never unwind on lock
    /// acquisition).
    fn conn(&self) -> std::sync::MutexGuard<'_, Conn> {
        self.conn.lock().unwrap_or_else(|e| e.into_inner())
    }

    pub fn split(&self) -> usize {
        self.split_l1.load(Ordering::SeqCst)
    }

    /// Move the split point (adaptive re-optimisation). Re-does the Eq. 17
    /// memory accounting and informs the cloud.
    pub fn set_split(&self, l1: usize) -> Result<()> {
        if l1 > self.num_layers {
            bail!("split l1={l1} out of range");
        }
        let old = self.split_l1.swap(l1, Ordering::SeqCst);
        let old_bytes = Self::head_bytes(&self.manifest, old);
        let new_bytes = Self::head_bytes(&self.manifest, l1);
        self.memory.release(old_bytes);
        self.memory
            .reserve(new_bytes)
            .map_err(|free| anyhow::anyhow!("Eq.17 violated at l1={l1}: {free} B free"))?;
        let mut conn = self.conn();
        write_msg(&mut conn.writer, &Msg::SetSplit { l1: l1 as u32 })?;
        Ok(())
    }

    /// Client power (Eq. 6) in Watts.
    fn client_power_w(&self) -> f64 {
        K_CLIENT_POWER * self.profile.cores as f64 * self.profile.freq_ghz.powi(3)
    }

    /// Serve one request end-to-end; returns (logits, timing).
    pub fn infer(&self, image: &Tensor) -> Result<(Tensor, RequestTiming)> {
        let l1 = self.split();
        let t_start = Instant::now();

        // ---- phase 1: device compute (layers 1..=l1) -------------------
        let t0 = Instant::now();
        let (intermediate, from_layer) = if l1 == 0 {
            (image.clone(), 1u32) // COC: ship the raw input
        } else {
            let out = self
                .executor
                .run_segment(&self.model, self.batch, 1, l1, image.clone())?;
            (out, (l1 + 1) as u32)
        };
        let mut client_s = t0.elapsed().as_secs_f64();
        if self.emulate_slowdown && self.profile.slowdown_vs_host > 1.0 {
            let extra = client_s * (self.profile.slowdown_vs_host - 1.0);
            std::thread::sleep(Duration::from_secs_f64(extra.min(5.0)));
            client_s = t0.elapsed().as_secs_f64();
        }
        self.energy
            .record(EnergyComponent::ClientCompute, self.client_power_w(), client_s);

        // Full model on device: no cloud interaction at all (COS).
        if l1 == self.num_layers {
            let total = t_start.elapsed().as_secs_f64();
            return Ok((
                intermediate,
                RequestTiming { client_s, upload_s: 0.0, cloud_and_download_s: 0.0, total_s: total },
            ));
        }

        // ---- phase 2: shaped upload ------------------------------------
        let t1 = Instant::now();
        let reply = {
            let mut conn = self.conn();
            conn.next_id += 1;
            let id = conn.next_id;
            let msg = Msg::Infer { request_id: id, from_layer, tensor: intermediate };
            self.send_shaped(&mut conn.writer, &msg)?;
            let upload_s = t1.elapsed().as_secs_f64();
            self.energy.record(
                EnergyComponent::Upload,
                self.link_upload_power_w()?,
                upload_s,
            );

            // ---- phase 3: cloud compute + download ---------------------
            let t2 = Instant::now();
            let reply = read_msg(&mut conn.reader)?;
            let down_s = t2.elapsed().as_secs_f64();
            self.energy.record(
                EnergyComponent::Download,
                self.link_download_power_w()?,
                // Only the transfer fraction draws radio power; the cloud
                // compute wait is idle. Approximate transfer time from size.
                self.link
                    .transfer_time(wire_size(&reply))
                    .as_secs_f64()
                    .min(down_s),
            );
            drop(conn);
            (reply, upload_s, down_s)
        };
        let (reply, upload_s, down_s) = reply;

        match reply {
            Msg::InferResult { tensor, .. } => {
                let total = t_start.elapsed().as_secs_f64();
                Ok((
                    tensor,
                    RequestTiming {
                        client_s,
                        upload_s,
                        cloud_and_download_s: down_s,
                        total_s: total,
                    },
                ))
            }
            Msg::Error { reason, .. } => bail!("cloud error: {reason}"),
            other => bail!("unexpected reply {other:?}"),
        }
    }

    fn link_upload_power_w(&self) -> Result<f64> {
        let radio = self.profile.wifi.context("device profile has no radio")?.radio_power();
        Ok(radio.upload_power_w(self.link.bandwidth_mbps()))
    }

    fn link_download_power_w(&self) -> Result<f64> {
        let radio = self.profile.wifi.context("device profile has no radio")?.radio_power();
        Ok(radio.download_power_w(self.link.bandwidth_mbps()))
    }

    /// Write `msg` through the token-bucket shaper in CHUNK pieces.
    fn send_shaped(&self, w: &mut TcpStream, msg: &Msg) -> Result<()> {
        let mut buf = Vec::with_capacity(wire_size(msg) as usize);
        write_msg(&mut buf, msg)?;
        for chunk in buf.chunks(CHUNK) {
            self.link.throttle(chunk.len() as u64, true);
            w.write_all(chunk)?;
        }
        w.flush()?;
        Ok(())
    }

    /// Orderly goodbye.
    pub fn shutdown(&self) -> Result<()> {
        let mut conn = self.conn();
        write_msg(&mut conn.writer, &Msg::Shutdown)?;
        Ok(())
    }

    pub fn num_layers(&self) -> usize {
        self.num_layers
    }

    pub fn input_shape(&self) -> &[usize] {
        &self.input_shape
    }

    /// Stop the device executor thread.
    pub fn stop(&self) {
        self.executor.stop();
    }
}
