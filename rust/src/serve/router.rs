//! Request router + dynamic batcher (the vLLM-router-shaped L3 feature).
//!
//! Callers submit single images; the batcher coalesces up to `max_batch`
//! requests that arrive within `max_wait` of the first queued one, stacks
//! them along dim 0, executes once through the [`DeviceClient`], and
//! scatters logits back to the per-request completions. When fewer than
//! `max_batch` requests are waiting the batch is padded (padding rows are
//! computed-but-dropped — the batch-ablation bench quantifies the trade).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::runtime::Tensor;
use crate::serve::device::{DeviceClient, RequestTiming};

/// Lock the queue, recovering from a poisoned mutex: a panicked
/// submitter must not wedge the dispatcher (detlint rule R1 — serving
/// paths never unwind on lock acquisition).
fn lock_queue<'a>(lock: &'a Mutex<Queue>) -> std::sync::MutexGuard<'a, Queue> {
    lock.lock().unwrap_or_else(|e| e.into_inner())
}

/// A completed inference.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    pub logits: Vec<f32>,
    pub label: usize,
    pub timing: RequestTiming,
    /// Size of the batch this request rode in.
    pub batch_size: usize,
}

struct Pending {
    id: u64,
    image: Tensor,
    tx: std::sync::mpsc::Sender<Result<Completion>>,
}

struct Queue {
    items: VecDeque<Pending>,
    closed: bool,
}

/// Router configuration.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Hardware batch of the loaded executables (1 = no batching).
    pub max_batch: usize,
    /// How long to hold the first request while waiting for peers.
    pub max_wait: Duration,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig { max_batch: 1, max_wait: Duration::from_millis(50) }
    }
}

/// The router: one dispatcher thread drains the queue into the device
/// client.
pub struct Router {
    queue: Arc<(Mutex<Queue>, Condvar)>,
    cfg: RouterConfig,
    stopped: Arc<AtomicBool>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
}

impl Router {
    pub fn start(device: Arc<DeviceClient>, cfg: RouterConfig) -> Result<Router> {
        assert!(cfg.max_batch >= 1);
        let queue = Arc::new((
            Mutex::new(Queue { items: VecDeque::new(), closed: false }),
            Condvar::new(),
        ));
        let stopped = Arc::new(AtomicBool::new(false));
        let dispatcher = {
            let queue = Arc::clone(&queue);
            let stopped = Arc::clone(&stopped);
            let cfg = cfg.clone();
            std::thread::Builder::new()
                .name("smartsplit-router".into())
                .spawn(move || dispatcher_loop(device, queue, cfg, stopped))
                .context("spawning router dispatcher thread")?
        };
        Ok(Router { queue, cfg, stopped, dispatcher: Some(dispatcher) })
    }

    /// Submit an image; returns a receiver for the completion.
    pub fn submit(
        &self,
        id: u64,
        image: Tensor,
    ) -> std::sync::mpsc::Receiver<Result<Completion>> {
        let (tx, rx) = std::sync::mpsc::channel();
        let (lock, cv) = &*self.queue;
        let mut q = lock_queue(lock);
        q.items.push_back(Pending { id, image, tx });
        cv.notify_one();
        rx
    }

    /// Convenience: submit and block for the result.
    pub fn infer_blocking(&self, id: u64, image: Tensor) -> Result<Completion> {
        self.submit(id, image)
            .recv()
            .map_err(|_| anyhow::anyhow!("router dropped request {id}"))?
    }

    pub fn config(&self) -> &RouterConfig {
        &self.cfg
    }

    /// Drain and stop the dispatcher.
    pub fn stop(mut self) {
        {
            let (lock, cv) = &*self.queue;
            lock_queue(lock).closed = true;
            cv.notify_all();
        }
        self.stopped.store(true, Ordering::SeqCst);
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

fn dispatcher_loop(
    device: Arc<DeviceClient>,
    queue: Arc<(Mutex<Queue>, Condvar)>,
    cfg: RouterConfig,
    stopped: Arc<AtomicBool>,
) {
    let (lock, cv) = &*queue;
    loop {
        // Wait for at least one request (or close). Condvar waits
        // recover the guard from a poisoned lock the same way
        // `lock_queue` does — the dispatcher must outlive a panicking
        // peer thread.
        let mut batch: Vec<Pending> = Vec::new();
        {
            let mut q = lock_queue(lock);
            let first = loop {
                if let Some(p) = q.items.pop_front() {
                    break p;
                }
                if q.closed || stopped.load(Ordering::SeqCst) {
                    return;
                }
                let (guard, _) = cv
                    .wait_timeout(q, Duration::from_millis(50))
                    .unwrap_or_else(|e| e.into_inner());
                q = guard;
            };
            batch.push(first);
            // Batching window: wait up to max_wait for peers.
            if cfg.max_batch > 1 {
                let deadline = Instant::now() + cfg.max_wait;
                while batch.len() < cfg.max_batch {
                    if let Some(p) = q.items.pop_front() {
                        batch.push(p);
                        continue;
                    }
                    let now = Instant::now();
                    if now >= deadline || q.closed {
                        break;
                    }
                    let (guard, _) = cv
                        .wait_timeout(q, deadline - now)
                        .unwrap_or_else(|e| e.into_inner());
                    q = guard;
                }
            }
        }

        let n = batch.len();
        let result = run_batch(&device, &batch, cfg.max_batch);
        match result {
            Ok(completions) => {
                for (p, c) in batch.into_iter().zip(completions) {
                    let _ = p.tx.send(Ok(c));
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for p in batch {
                    let _ = p.tx.send(Err(anyhow::anyhow!("batch of {n} failed: {msg}")));
                }
            }
        }
    }
}

/// Stack the batch (padding to the hardware batch), run, scatter.
fn run_batch(
    device: &DeviceClient,
    batch: &[Pending],
    hw_batch: usize,
) -> Result<Vec<Completion>> {
    let per_shape = &batch[0].image.shape;
    let per_elems: usize = per_shape.iter().product();
    for p in batch {
        if p.image.shape != *per_shape {
            anyhow::bail!("heterogeneous shapes in batch");
        }
        if p.image.shape[0] != 1 {
            anyhow::bail!("submit() expects batch-1 images");
        }
    }
    let mut shape = per_shape.clone();
    shape[0] = hw_batch;
    let mut data = vec![0.0f32; per_elems * hw_batch];
    for (i, p) in batch.iter().enumerate() {
        data[i * per_elems..(i + 1) * per_elems].copy_from_slice(&p.image.data);
    }
    let stacked = Tensor::new(shape, data)?;
    let (logits, timing) = device.infer(&stacked)?;

    let classes = *logits.shape.last().context("logits tensor has an empty shape")?;
    let labels = logits.argmax_rows();
    Ok(batch
        .iter()
        .enumerate()
        .map(|(i, p)| Completion {
            id: p.id,
            logits: logits.data[i * classes..(i + 1) * classes].to_vec(),
            label: labels[i],
            timing,
            batch_size: batch.len(),
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_sane() {
        let c = RouterConfig::default();
        assert_eq!(c.max_batch, 1);
        assert!(c.max_wait > Duration::ZERO);
    }
}
