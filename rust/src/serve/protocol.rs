//! Wire protocol of the split-serving stack: length-framed binary messages
//! over TCP.
//!
//! Layout of every frame (all integers little-endian):
//!
//! ```text
//!     [ u8  msg_type ]
//!     [ u64 request_id ]
//!     [ u32 aux        ]   // batch / layer index / split by type
//!     [ u8  ndim       ]
//!     [ u32 dim        ] * ndim
//!     [ u64 payload_len]
//!     [ payload bytes  ]   // f32 tensor data or UTF-8 text
//! ```
//!
//! The header is fixed-size binary (no JSON on the hot path); `Hello`
//! carries its model name as the UTF-8 payload.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use crate::runtime::Tensor;

/// Maximum accepted payload (guards the server against garbage frames):
/// the largest legitimate tensor is VGG16's b8 conv1 activation ≈ 103 MB.
pub const MAX_PAYLOAD: u64 = 1 << 30;

/// Protocol messages.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Device → cloud: announce model + batch; cloud loads/pins artifacts.
    Hello { model: String, batch: u32 },
    /// Cloud → device: ready; `num_layers` of the loaded model.
    HelloAck { num_layers: u32 },
    /// Device → cloud: run layers `from_layer..=L` on the tensor.
    Infer { request_id: u64, from_layer: u32, tensor: Tensor },
    /// Cloud → device: logits for `request_id`.
    InferResult { request_id: u64, tensor: Tensor },
    /// Device → cloud: the coordinator re-optimised; informational.
    SetSplit { l1: u32 },
    /// Either direction: orderly shutdown.
    Shutdown,
    /// Cloud → device: failure, UTF-8 reason in payload.
    Error { request_id: u64, reason: String },
}

impl Msg {
    fn type_byte(&self) -> u8 {
        match self {
            Msg::Hello { .. } => 1,
            Msg::HelloAck { .. } => 2,
            Msg::Infer { .. } => 3,
            Msg::InferResult { .. } => 4,
            Msg::SetSplit { .. } => 5,
            Msg::Shutdown => 6,
            Msg::Error { .. } => 7,
        }
    }
}

/// Serialise a message into `w`. Returns bytes written.
pub fn write_msg<W: Write>(w: &mut W, msg: &Msg) -> Result<u64> {
    let empty: &[usize] = &[];
    let (request_id, aux, shape, payload): (u64, u32, &[usize], Vec<u8>) = match msg {
        Msg::Hello { model, batch } => (0, *batch, empty, model.as_bytes().to_vec()),
        Msg::HelloAck { num_layers } => (0, *num_layers, empty, Vec::new()),
        Msg::Infer { request_id, from_layer, tensor } => {
            (*request_id, *from_layer, &tensor.shape, tensor.to_le_bytes())
        }
        Msg::InferResult { request_id, tensor } => {
            (*request_id, 0, &tensor.shape, tensor.to_le_bytes())
        }
        Msg::SetSplit { l1 } => (0, *l1, empty, Vec::new()),
        Msg::Shutdown => (0, 0, empty, Vec::new()),
        Msg::Error { request_id, reason } => {
            (*request_id, 0, empty, reason.as_bytes().to_vec())
        }
    };
    let mut head = Vec::with_capacity(32 + shape.len() * 4);
    head.push(msg.type_byte());
    head.extend_from_slice(&request_id.to_le_bytes());
    head.extend_from_slice(&aux.to_le_bytes());
    head.push(shape.len() as u8);
    for &d in shape {
        head.extend_from_slice(&(d as u32).to_le_bytes());
    }
    head.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    w.write_all(&head).context("writing frame header")?;
    w.write_all(&payload).context("writing frame payload")?;
    Ok(head.len() as u64 + payload.len() as u64)
}

fn read_arr<R: Read, const N: usize>(r: &mut R) -> Result<[u8; N]> {
    let mut buf = [0u8; N];
    r.read_exact(&mut buf).context("reading frame bytes")?;
    Ok(buf)
}

/// Read one message from `r`.
pub fn read_msg<R: Read>(r: &mut R) -> Result<Msg> {
    let ty = read_arr::<R, 1>(r)?[0];
    let request_id = u64::from_le_bytes(read_arr::<R, 8>(r)?);
    let aux = u32::from_le_bytes(read_arr::<R, 4>(r)?);
    let ndim = read_arr::<R, 1>(r)?[0] as usize;
    let mut shape = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        shape.push(u32::from_le_bytes(read_arr::<R, 4>(r)?) as usize);
    }
    let payload_len = u64::from_le_bytes(read_arr::<R, 8>(r)?);
    if payload_len > MAX_PAYLOAD {
        bail!("frame payload {payload_len} exceeds MAX_PAYLOAD");
    }
    let mut payload = vec![0u8; payload_len as usize];
    r.read_exact(&mut payload).context("reading payload")?;

    Ok(match ty {
        1 => Msg::Hello {
            model: String::from_utf8(payload).context("hello model name")?,
            batch: aux,
        },
        2 => Msg::HelloAck { num_layers: aux },
        3 => Msg::Infer {
            request_id,
            from_layer: aux,
            tensor: Tensor::from_le_bytes(shape, &payload)?,
        },
        4 => Msg::InferResult { request_id, tensor: Tensor::from_le_bytes(shape, &payload)? },
        5 => Msg::SetSplit { l1: aux },
        6 => Msg::Shutdown,
        7 => Msg::Error {
            request_id,
            reason: String::from_utf8(payload).context("error reason")?,
        },
        other => bail!("unknown message type {other}"),
    })
}

/// Size in bytes a message occupies on the wire (for shaping/energy
/// accounting without double-serialising).
pub fn wire_size(msg: &Msg) -> u64 {
    let (ndim, payload) = match msg {
        Msg::Hello { model, .. } => (0, model.len() as u64),
        Msg::HelloAck { .. } | Msg::SetSplit { .. } | Msg::Shutdown => (0, 0),
        Msg::Infer { tensor, .. } => (tensor.shape.len(), tensor.num_bytes() as u64),
        Msg::InferResult { tensor, .. } => (tensor.shape.len(), tensor.num_bytes() as u64),
        Msg::Error { reason, .. } => (0, reason.len() as u64),
    };
    1 + 8 + 4 + 1 + 4 * ndim as u64 + 8 + payload
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn roundtrip(msg: Msg) -> Msg {
        let mut buf = Vec::new();
        let written = write_msg(&mut buf, &msg).unwrap();
        assert_eq!(written, buf.len() as u64);
        assert_eq!(written, wire_size(&msg), "wire_size mismatch for {msg:?}");
        let mut cur = Cursor::new(buf);
        let out = read_msg(&mut cur).unwrap();
        assert_eq!(cur.position(), written); // consumed exactly
        out
    }

    #[test]
    fn roundtrip_all_variants() {
        let t = Tensor::new(vec![1, 2, 2], vec![1.0, -2.0, 3.5, 0.0]).unwrap();
        for msg in [
            Msg::Hello { model: "alexnet".into(), batch: 8 },
            Msg::HelloAck { num_layers: 21 },
            Msg::Infer { request_id: 42, from_layer: 4, tensor: t.clone() },
            Msg::InferResult { request_id: 42, tensor: t.clone() },
            Msg::SetSplit { l1: 11 },
            Msg::Shutdown,
            Msg::Error { request_id: 7, reason: "boom".into() },
        ] {
            assert_eq!(roundtrip(msg.clone()), msg);
        }
    }

    #[test]
    fn multiple_messages_stream() {
        let mut buf = Vec::new();
        write_msg(&mut buf, &Msg::SetSplit { l1: 3 }).unwrap();
        write_msg(&mut buf, &Msg::Shutdown).unwrap();
        let mut cur = Cursor::new(buf);
        assert_eq!(read_msg(&mut cur).unwrap(), Msg::SetSplit { l1: 3 });
        assert_eq!(read_msg(&mut cur).unwrap(), Msg::Shutdown);
        assert!(read_msg(&mut cur).is_err()); // EOF
    }

    #[test]
    fn rejects_oversize_payload() {
        let mut buf = Vec::new();
        buf.push(3u8);
        buf.extend_from_slice(&0u64.to_le_bytes());
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.push(0);
        buf.extend_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        assert!(read_msg(&mut Cursor::new(buf)).is_err());
    }

    #[test]
    fn rejects_unknown_type() {
        let mut buf = Vec::new();
        write_msg(&mut buf, &Msg::Shutdown).unwrap();
        buf[0] = 99;
        assert!(read_msg(&mut Cursor::new(buf)).is_err());
    }

    #[test]
    fn truncated_frame_is_error_not_panic() {
        let t = Tensor::new(vec![4], vec![1.0; 4]).unwrap();
        let mut buf = Vec::new();
        write_msg(&mut buf, &Msg::InferResult { request_id: 1, tensor: t }).unwrap();
        buf.truncate(buf.len() - 3);
        assert!(read_msg(&mut Cursor::new(buf)).is_err());
    }
}
