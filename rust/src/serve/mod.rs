//! The split-serving stack: framed TCP protocol, cloud daemon, device
//! client, and the request router + dynamic batcher.
//!
//! Topology (matching the paper's Android-app + Windows-server testbed):
//!
//! ```text
//!   workload ─▶ Router/Batcher ─▶ DeviceClient (layers 1..=l1, PJRT,
//!                 phone-emulated)   │ shaped TCP (netsim::Link)
//!                                   ▼
//!                               CloudServer (layers l1+1..=L, PJRT)
//! ```

pub mod cloud;
pub mod device;
pub mod protocol;
pub mod router;

pub use cloud::CloudServer;
pub use device::{DeviceClient, RequestTiming};
pub use protocol::{read_msg, wire_size, write_msg, Msg};
pub use router::{Completion, Router, RouterConfig};
