//! `detlint` — the in-tree determinism & robustness static-analysis pass
//! (DESIGN.md §15).
//!
//! Every parity claim this repo makes — byte-identical decision streams
//! across thread configs, zero-edge/zero-fault byte-for-byte replays,
//! identical exports across reruns — rests on a determinism contract
//! that used to be enforced only by convention. This module makes the
//! contract machine-checked: a pure-std token/line scanner (no new
//! dependencies — the vendored-offline build stays self-contained) that
//! walks `rust/src/**` and reports violations of five named rules:
//!
//! | Rule | Contract |
//! |---|---|
//! | `D1` | No wall clock (`Instant::now` / `SystemTime::now`) outside the wall-side allowlist ([`WALL_SIDE`]) — the sim/planner/trace/analyze decision plane uses the virtual clock only |
//! | `D2` | No OS or thread-local randomness (`thread_rng`, `rand::random`, `RandomState`) anywhere — all RNG flows from seeded [`crate::util::rng`] streams |
//! | `D3` | No default-hasher `HashMap`/`HashSet` in the export plane ([`EXPORT_PLANE`]) — iteration order would leak into exports; use `BTreeMap`/`BTreeSet` or sort before emitting |
//! | `D4` | No `Ordering::Relaxed` atomics in the export plane — counters that appear in serialized reports must not be torn across threads |
//! | `R1` | No `unwrap()`/`expect()` on the serving/export paths ([`ROBUST_PLANE`]) — protocol and file I/O must fail with errors, not panics |
//!
//! The scanner strips comments and string/char-literal contents before
//! matching (a rule named in a doc comment never trips), skips
//! `#[cfg(test)]` regions for the rules where test code is exempt, and
//! is deliberately token-level: it cannot resolve types, so the D3/D4
//! scopes are *module* approximations of "writes an export" — precise
//! enough for this tree, and auditable when they are not.
//!
//! **Suppression.** A violation is suppressible only by an inline
//! annotation — a plain (non-doc) `//` comment of the form
//! `detlint:allow(<rule>): <justification>` — on the same line as the
//! violation, or on a comment-only line directly above it. The
//! justification after the `:` is mandatory, the rule id must be real,
//! and an allow that suppresses nothing is itself a finding (rule
//! `ALLOW`) — so every exemption stays visible, justified, and alive.
//! The tool counts and prints all suppressions. Annotations are only
//! recognized in plain comments: the same marker inside a string
//! literal or a doc comment (like the ones in this header) is inert.
//!
//! Output is a deterministic, stable-sorted report (`file:line`, rule
//! id, offending token, fix hint); the `detlint` binary exits nonzero
//! on any unsuppressed finding, and the CI `lint` job gates on it.
//! `tests/detlint.rs` proves each rule fires on its fixture corpus
//! (`tests/lint_fixtures/`) and that the repository itself lints clean.

use std::io;
use std::path::{Path, PathBuf};

/// Rule id reserved for suppression-hygiene problems: malformed
/// allow syntax, unknown rule ids, missing justifications, and allows
/// that suppress nothing.
pub const ALLOW_RULE: &str = "ALLOW";

/// Wall-side modules where reading the wall clock is the point: the
/// live TCP serving stack, the real-socket link shaper, the bench
/// harness, and the PJRT runtime. Everything else is the decision
/// plane and must use the virtual clock.
pub const WALL_SIDE: &[&str] = &["serve/", "netsim/", "bench/", "runtime/", "benches/"];

/// Export-plane modules: anything here feeds a serialized report, an
/// export file, or a decision stream, so iteration order and relaxed
/// counter reads are part of the byte-identity contract. The sharded
/// event engine's cross-shard channel code (`sim/shard*`) is included
/// because its pop order IS the decision stream: a default-hasher map
/// or a relaxed counter there would break cross-layout replay parity.
pub const EXPORT_PLANE: &[&str] =
    &["trace/", "analyze/", "metrics/", "figures/", "bench/", "sim/shard"];

/// Panic-free plane: protocol and file-I/O paths that must return
/// errors with context instead of unwinding under live traffic.
pub const ROBUST_PLANE: &[&str] = &["serve/", "analyze/", "trace/export.rs"];

/// Where in the tree a rule applies, matched on the path relative to
/// the scan root (forward slashes; a full file name is a valid prefix).
#[derive(Clone, Copy, Debug)]
pub enum Scope {
    /// Applies to every scanned file.
    Everywhere,
    /// Applies only outside these path prefixes (the allowlist).
    Outside(&'static [&'static str]),
    /// Applies only within these path prefixes.
    Within(&'static [&'static str]),
}

impl Scope {
    fn applies(&self, rel: &str) -> bool {
        match self {
            Scope::Everywhere => true,
            Scope::Outside(prefixes) => !prefixes.iter().any(|p| rel.starts_with(p)),
            Scope::Within(prefixes) => prefixes.iter().any(|p| rel.starts_with(p)),
        }
    }

    /// Human-readable scope description for the `--rules` table.
    pub fn describe(&self) -> String {
        match self {
            Scope::Everywhere => "everywhere".to_string(),
            Scope::Outside(prefixes) => format!("outside {}", prefixes.join(", ")),
            Scope::Within(prefixes) => format!("within {}", prefixes.join(", ")),
        }
    }
}

/// One named rule of the determinism/robustness contract.
#[derive(Clone, Copy, Debug)]
pub struct Rule {
    /// Stable id (`D1`..`D4`, `R1`) — what an allow annotation names.
    pub id: &'static str,
    /// One-line statement of the contract clause.
    pub title: &'static str,
    /// Source tokens whose presence (at identifier boundaries, outside
    /// comments/strings) constitutes a finding.
    pub tokens: &'static [&'static str],
    /// Where the rule applies.
    pub scope: Scope,
    /// Whether `#[cfg(test)]` regions are exempt.
    pub skip_test_code: bool,
    /// What to do instead.
    pub hint: &'static str,
}

/// The enforced rule set, in report order.
pub const RULES: &[Rule] = &[
    Rule {
        id: "D1",
        title: "no wall clock on the decision plane",
        tokens: &["Instant::now", "SystemTime::now"],
        scope: Scope::Outside(WALL_SIDE),
        skip_test_code: true,
        hint: "use the sim's virtual clock; wall time belongs to serve/, netsim/, bench/, runtime/",
    },
    Rule {
        id: "D2",
        title: "no OS or thread-local randomness",
        tokens: &["thread_rng", "rand::random", "RandomState"],
        scope: Scope::Everywhere,
        skip_test_code: false,
        hint: "derive a seeded util::rng::Xoshiro256 stream so every run replays",
    },
    Rule {
        id: "D3",
        title: "no default-hasher map in the export plane",
        tokens: &["HashMap", "HashSet"],
        scope: Scope::Within(EXPORT_PLANE),
        skip_test_code: true,
        hint: "iteration order is nondeterministic; use BTreeMap/BTreeSet or sort before emitting",
    },
    Rule {
        id: "D4",
        title: "no relaxed atomics in the export plane",
        tokens: &["Ordering::Relaxed"],
        scope: Scope::Within(EXPORT_PLANE),
        skip_test_code: true,
        hint: "counters that reach serialized reports use Ordering::SeqCst",
    },
    Rule {
        id: "R1",
        title: "no panics on protocol or export I/O paths",
        tokens: &[".unwrap()", ".expect("],
        scope: Scope::Within(ROBUST_PLANE),
        skip_test_code: true,
        hint: "return an error with context (anyhow::Context); serving paths must not unwind",
    },
];

/// Look up a rule by id.
pub fn rule_by_id(id: &str) -> Option<&'static Rule> {
    RULES.iter().find(|r| r.id == id)
}

/// One unsuppressed violation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    /// Path as shown in the report (scan root joined with the relative
    /// path, so `file:line` is clickable from the repo).
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    /// Rule id (`D1`.. / `R1` / [`ALLOW_RULE`]).
    pub rule: &'static str,
    /// The offending token (or, for `ALLOW`, the problem description).
    pub token: String,
    /// Fix hint.
    pub hint: String,
}

/// One counted allow exemption that suppressed a finding.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Suppression {
    /// Path as shown in the report.
    pub path: String,
    /// 1-based line of the suppressed finding.
    pub line: usize,
    /// Rule id the allow names.
    pub rule: String,
    /// The mandatory inline justification.
    pub justification: String,
}

/// Result of scanning one file or a whole tree: unsuppressed findings
/// plus the audited exemption list, both stable-sorted.
#[derive(Clone, Debug, Default)]
pub struct LintReport {
    pub findings: Vec<Finding>,
    pub suppressed: Vec<Suppression>,
    pub files_scanned: usize,
}

impl LintReport {
    /// True when the tree honors the contract (no unsuppressed
    /// findings; counted exemptions are allowed).
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }

    fn sort(&mut self) {
        self.findings.sort_by(|a, b| {
            (&a.path, a.line, a.rule, &a.token).cmp(&(&b.path, b.line, b.rule, &b.token))
        });
        self.suppressed
            .sort_by(|a, b| (&a.path, a.line, &a.rule).cmp(&(&b.path, b.line, &b.rule)));
    }

    /// Fold another report in, keeping the merged report stable-sorted.
    pub fn merge(&mut self, other: LintReport) {
        self.findings.extend(other.findings);
        self.suppressed.extend(other.suppressed);
        self.files_scanned += other.files_scanned;
        self.sort();
    }

    /// Deterministic human-readable report: findings first (stable
    /// order), then the suppression audit, then the summary line.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!(
                "{}:{}: {} `{}` — {}\n",
                f.path, f.line, f.rule, f.token, f.hint
            ));
        }
        if !self.suppressed.is_empty() {
            out.push_str("suppressions (detlint allow):\n");
            for s in &self.suppressed {
                out.push_str(&format!(
                    "  {}:{}: {} — {}\n",
                    s.path, s.line, s.rule, s.justification
                ));
            }
        }
        out.push_str(&format!(
            "detlint: {} file(s) scanned, {} finding(s), {} suppressed\n",
            self.files_scanned,
            self.findings.len(),
            self.suppressed.len()
        ));
        out
    }
}

/// The `--rules` table: id, scope, contract, hint.
pub fn rules_table() -> String {
    let mut out = format!(
        "detlint rules (suppress with a `{ALLOW_MARKER}(<id>): <justification>` comment):\n"
    );
    for r in RULES {
        out.push_str(&format!(
            "  {}  {} [{}]\n      fix: {}\n",
            r.id,
            r.title,
            r.scope.describe(),
            r.hint
        ));
    }
    out
}

// ---------------------------------------------------------------------------
// Source splitting: one channel with comments and literal contents blanked
// (token matching), one with only plain-comment text kept (allow parsing).
// Both preserve the line structure exactly.
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq)]
enum StripState {
    Code,
    /// `is_doc` distinguishes `///` and `//!` from plain `//`.
    LineComment { is_doc: bool },
    /// Nesting depth plus the doc-ness of the outermost opener.
    BlockComment { depth: u32, is_doc: bool },
    Str,
    RawStr { hashes: u32 },
    CharLit,
}

/// Source split into matching channels with identical line structure.
struct Channels {
    /// Comments and string/char contents blanked to spaces.
    code: String,
    /// Only plain (non-doc) comment text kept; everything else blanked.
    comments: String,
}

impl Channels {
    fn push(&mut self, c: char, as_code: bool, as_comment: bool) {
        self.code.push(if as_code { c } else { ' ' });
        self.comments.push(if as_comment { c } else { ' ' });
    }

    fn newline(&mut self) {
        self.code.push('\n');
        self.comments.push('\n');
    }
}

fn at(chars: &[char], i: usize) -> char {
    chars.get(i).copied().unwrap_or('\0')
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Split source into the code and plain-comment channels. Handles
/// nested block comments, escapes, raw strings (and byte variants),
/// and the char-literal/lifetime ambiguity.
fn split_channels(src: &str) -> Channels {
    let chars: Vec<char> = src.chars().collect();
    let mut out = Channels {
        code: String::with_capacity(src.len()),
        comments: String::with_capacity(src.len()),
    };
    let mut state = StripState::Code;
    let mut i = 0usize;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            if matches!(state, StripState::LineComment { .. }) {
                state = StripState::Code;
            }
            out.newline();
            i += 1;
            continue;
        }
        match state {
            StripState::Code => {
                if c == '/' && at(&chars, i + 1) == '/' {
                    let next = at(&chars, i + 2);
                    let is_doc = next == '/' || next == '!';
                    state = StripState::LineComment { is_doc };
                    out.push(' ', false, false);
                    out.push(' ', false, false);
                    i += 2;
                } else if c == '/' && at(&chars, i + 1) == '*' {
                    let next = at(&chars, i + 2);
                    let is_doc = next == '!' || (next == '*' && at(&chars, i + 3) != '/');
                    state = StripState::BlockComment { depth: 1, is_doc };
                    out.push(' ', false, false);
                    out.push(' ', false, false);
                    i += 2;
                } else if c == '"' {
                    state = StripState::Str;
                    out.push(' ', false, false);
                    i += 1;
                } else if (c == 'r' || c == 'b') && (i == 0 || !is_ident_char(at(&chars, i - 1))) {
                    // Possible raw/byte string opener: b" r" br" r#" br##" …
                    let mut j = i;
                    if at(&chars, j) == 'b' {
                        j += 1;
                    }
                    let raw = at(&chars, j) == 'r';
                    if raw {
                        j += 1;
                    }
                    let mut hashes = 0u32;
                    while raw && at(&chars, j) == '#' {
                        hashes += 1;
                        j += 1;
                    }
                    if at(&chars, j) == '"' && (raw || c == 'b') {
                        for _ in i..=j {
                            out.push(' ', false, false);
                        }
                        i = j + 1;
                        state = if raw {
                            StripState::RawStr { hashes }
                        } else {
                            StripState::Str
                        };
                    } else {
                        out.push(c, true, false);
                        i += 1;
                    }
                } else if c == '\'' {
                    // Char literal vs lifetime: a backslash or a closing
                    // quote two chars on means literal; otherwise it is
                    // a lifetime and stays in the code channel.
                    let next = at(&chars, i + 1);
                    let is_char = next == '\\' || (next != '\0' && at(&chars, i + 2) == '\'');
                    if is_char {
                        state = StripState::CharLit;
                        out.push(' ', false, false);
                        i += 1;
                    } else {
                        out.push(c, true, false);
                        i += 1;
                    }
                } else {
                    out.push(c, true, false);
                    i += 1;
                }
            }
            StripState::LineComment { is_doc } => {
                out.push(c, false, !is_doc);
                i += 1;
            }
            StripState::BlockComment { depth, is_doc } => {
                if c == '*' && at(&chars, i + 1) == '/' {
                    state = if depth == 1 {
                        StripState::Code
                    } else {
                        StripState::BlockComment {
                            depth: depth - 1,
                            is_doc,
                        }
                    };
                    out.push(' ', false, false);
                    out.push(' ', false, false);
                    i += 2;
                } else if c == '/' && at(&chars, i + 1) == '*' {
                    state = StripState::BlockComment {
                        depth: depth + 1,
                        is_doc,
                    };
                    out.push(' ', false, false);
                    out.push(' ', false, false);
                    i += 2;
                } else {
                    out.push(c, false, !is_doc);
                    i += 1;
                }
            }
            StripState::Str => {
                if c == '\\' && at(&chars, i + 1) != '\0' && at(&chars, i + 1) != '\n' {
                    out.push(' ', false, false);
                    out.push(' ', false, false);
                    i += 2;
                } else {
                    if c == '"' {
                        state = StripState::Code;
                    }
                    out.push(' ', false, false);
                    i += 1;
                }
            }
            StripState::RawStr { hashes } => {
                if c == '"' {
                    let mut k = 0u32;
                    while k < hashes && at(&chars, i + 1 + k as usize) == '#' {
                        k += 1;
                    }
                    if k == hashes {
                        for _ in 0..=hashes {
                            out.push(' ', false, false);
                        }
                        i += 1 + hashes as usize;
                        state = StripState::Code;
                    } else {
                        out.push(' ', false, false);
                        i += 1;
                    }
                } else {
                    out.push(' ', false, false);
                    i += 1;
                }
            }
            StripState::CharLit => {
                if c == '\\' && at(&chars, i + 1) != '\0' && at(&chars, i + 1) != '\n' {
                    out.push(' ', false, false);
                    out.push(' ', false, false);
                    i += 2;
                } else {
                    if c == '\'' {
                        state = StripState::Code;
                    }
                    out.push(' ', false, false);
                    i += 1;
                }
            }
        }
    }
    out
}

/// Byte offsets of identifier-boundary occurrences of `token` in
/// `line` (already-stripped code). A token whose first/last character
/// is an identifier character must not touch another identifier
/// character (`Instant::nowhere` is not a wall-clock read).
fn token_offsets(line: &str, token: &str) -> Vec<usize> {
    let bytes = line.as_bytes();
    let first_ident = token.chars().next().map(is_ident_char).unwrap_or(false);
    let last_ident = token.chars().last().map(is_ident_char).unwrap_or(false);
    let ident_byte = |b: u8| b.is_ascii_alphanumeric() || b == b'_';
    line.match_indices(token)
        .filter(|(pos, _)| {
            let before_ok = !first_ident || *pos == 0 || !ident_byte(bytes[pos - 1]);
            let after = pos + token.len();
            let after_ok = !last_ident || after >= bytes.len() || !ident_byte(bytes[after]);
            before_ok && after_ok
        })
        .map(|(pos, _)| pos)
        .collect()
}

/// Which lines fall inside a `#[cfg(test)]` item (brace-balanced from
/// the first `{` after the attribute). Returns a per-line flag.
fn test_code_lines(code_lines: &[&str]) -> Vec<bool> {
    let mut flags = vec![false; code_lines.len()];
    let mut depth: i64 = 0;
    let mut pending = false;
    let mut region_floor: Option<i64> = None;
    for (idx, line) in code_lines.iter().enumerate() {
        let mut in_test = region_floor.is_some();
        if line.contains("#[cfg(test)]") {
            pending = true;
        }
        for c in line.chars() {
            match c {
                '{' => {
                    if pending && region_floor.is_none() {
                        region_floor = Some(depth);
                        pending = false;
                        in_test = true;
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    if let Some(floor) = region_floor {
                        if depth <= floor {
                            region_floor = None;
                            // The closing-brace line is still test code.
                            in_test = true;
                        }
                    }
                }
                _ => {}
            }
        }
        if region_floor.is_some() {
            in_test = true;
        }
        flags[idx] = in_test;
    }
    flags
}

/// A parsed allow annotation.
struct Allow {
    rule: String,
    /// 1-based line of the annotation comment.
    line: usize,
    /// 1-based line the annotation covers (same line, or the next line
    /// when the annotation sits on a comment-only line).
    target: usize,
    justification: String,
    used: bool,
}

/// The annotation marker, assembled so the scanner never reads its own
/// definition as an annotation.
const ALLOW_MARKER: &str = concat!("detlint", ":", "allow");

/// Parse every allow annotation in the plain-comment channel; syntax
/// problems become `ALLOW` findings immediately.
fn parse_allows(
    display_path: &str,
    comment_lines: &[&str],
    code_lines: &[&str],
    findings: &mut Vec<Finding>,
) -> Vec<Allow> {
    let mut allows = Vec::new();
    for (idx, text) in comment_lines.iter().enumerate() {
        let line_no = idx + 1;
        let mut cursor = 0usize;
        while let Some(p) = text[cursor..].find(ALLOW_MARKER) {
            let start = cursor + p + ALLOW_MARKER.len();
            cursor = start;
            let rest = &text[start..];
            let mut bad = |why: String| {
                findings.push(Finding {
                    path: display_path.to_string(),
                    line: line_no,
                    rule: ALLOW_RULE,
                    token: why,
                    hint: format!("syntax: // {ALLOW_MARKER}(<rule>): <justification>"),
                });
            };
            if !rest.starts_with('(') {
                bad(format!("missing (rule) after {ALLOW_MARKER}"));
                continue;
            }
            let Some(close) = rest.find(')') else {
                bad(format!("unclosed (rule) after {ALLOW_MARKER}"));
                continue;
            };
            let rule_id = rest[1..close].trim().to_string();
            if rule_by_id(&rule_id).is_none() {
                bad(format!("unknown rule `{rule_id}`"));
                continue;
            }
            let after = &rest[close + 1..];
            let Some(just) = after.strip_prefix(':') else {
                bad(format!("missing `: <justification>` for {rule_id}"));
                continue;
            };
            let justification = just.trim().to_string();
            if justification.is_empty() {
                bad(format!("empty justification for {rule_id}"));
                continue;
            }
            // A comment-only annotation line covers the line below it;
            // a trailing annotation covers its own line.
            let own_code = code_lines
                .get(idx)
                .map(|l| !l.trim().is_empty())
                .unwrap_or(false);
            let target = if own_code { line_no } else { line_no + 1 };
            allows.push(Allow {
                rule: rule_id,
                line: line_no,
                target,
                justification,
                used: false,
            });
        }
    }
    allows
}

/// Scan one file's source. `rel_path` (forward slashes, relative to the
/// scan root) drives rule scoping; `display_path` is what reports show.
pub fn scan_source(rel_path: &str, display_path: &str, source: &str) -> LintReport {
    let channels = split_channels(source);
    let code_lines: Vec<&str> = channels.code.lines().collect();
    let comment_lines: Vec<&str> = channels.comments.lines().collect();
    let in_test = test_code_lines(&code_lines);

    let mut findings = Vec::new();
    let mut allows = parse_allows(display_path, &comment_lines, &code_lines, &mut findings);
    let mut suppressed = Vec::new();

    for rule in RULES {
        if !rule.scope.applies(rel_path) {
            continue;
        }
        for (idx, line) in code_lines.iter().enumerate() {
            if rule.skip_test_code && in_test[idx] {
                continue;
            }
            let line_no = idx + 1;
            for token in rule.tokens {
                for _offset in token_offsets(line, token) {
                    let allow = allows
                        .iter_mut()
                        .find(|a| a.target == line_no && a.rule == rule.id);
                    match allow {
                        Some(a) => {
                            a.used = true;
                            suppressed.push(Suppression {
                                path: display_path.to_string(),
                                line: line_no,
                                rule: a.rule.clone(),
                                justification: a.justification.clone(),
                            });
                        }
                        None => findings.push(Finding {
                            path: display_path.to_string(),
                            line: line_no,
                            rule: rule.id,
                            token: (*token).to_string(),
                            hint: rule.hint.to_string(),
                        }),
                    }
                }
            }
        }
    }

    // A suppression nothing needed is stale: surface it so allows can
    // never outlive the code they excused.
    for a in &allows {
        if !a.used {
            findings.push(Finding {
                path: display_path.to_string(),
                line: a.line,
                rule: ALLOW_RULE,
                token: format!("{ALLOW_MARKER}({})", a.rule),
                hint: "suppresses no finding on its target line — remove the stale allow"
                    .to_string(),
            });
        }
    }

    let mut report = LintReport {
        findings,
        suppressed,
        files_scanned: 1,
    };
    report.sort();
    report
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Scan every `.rs` file under `root` (recursively, sorted) and merge
/// into one stable-sorted report. Report paths are `root/<relative>`.
pub fn scan_tree(root: &Path) -> io::Result<LintReport> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)?;
    files.sort();
    let mut merged = LintReport::default();
    for file in &files {
        let source = std::fs::read_to_string(file)?;
        let rel = match file.strip_prefix(root) {
            Ok(r) => r.to_string_lossy().replace('\\', "/"),
            Err(_) => file.to_string_lossy().replace('\\', "/"),
        };
        let display = root.join(rel.as_str()).to_string_lossy().to_string();
        let one = scan_source(&rel, &display, &source);
        merged.findings.extend(one.findings);
        merged.suppressed.extend(one.suppressed);
        merged.files_scanned += 1;
    }
    merged.sort();
    Ok(merged)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scan(rel: &str, src: &str) -> LintReport {
        scan_source(rel, rel, src)
    }

    fn allow_comment(rule: &str, justification: &str) -> String {
        format!("// {ALLOW_MARKER}({rule}): {justification}")
    }

    #[test]
    fn strips_comments_strings_and_char_literals() {
        let src = "let a = \"Instant::now\"; // Instant::now\nlet b = 'x'; /* thread_rng */ let c = r#\"HashMap\"#;\n";
        let code = split_channels(src).code;
        assert!(!code.contains("Instant::now"), "{code}");
        assert!(!code.contains("thread_rng"), "{code}");
        assert!(!code.contains("HashMap"), "{code}");
        assert!(code.contains("let a ="));
        assert_eq!(code.lines().count(), src.lines().count());
    }

    #[test]
    fn lifetimes_survive_stripping() {
        let src = "fn f<'a>(x: &'a str) -> &'static str { x }\n";
        let code = split_channels(src).code;
        assert!(code.contains("'static"), "{code}");
    }

    #[test]
    fn nested_block_comments_strip_fully() {
        let src = "/* outer /* Instant::now */ still comment */ let x = 1;\n";
        let code = split_channels(src).code;
        assert!(!code.contains("Instant::now"));
        assert!(code.contains("let x = 1;"));
    }

    #[test]
    fn comment_channel_keeps_plain_comments_only() {
        let src = format!(
            "// plain {m}\n/// doc {m}\n//! inner doc {m}\nlet s = \"{m}\";\n/* block {m} */\n/** doc block {m} */\n",
            m = ALLOW_MARKER
        );
        let comments = split_channels(&src).comments;
        let lines: Vec<&str> = comments.lines().collect();
        assert!(lines[0].contains(ALLOW_MARKER), "{comments}");
        assert!(!lines[1].contains(ALLOW_MARKER), "{comments}");
        assert!(!lines[2].contains(ALLOW_MARKER), "{comments}");
        assert!(!lines[3].contains(ALLOW_MARKER), "{comments}");
        assert!(lines[4].contains(ALLOW_MARKER), "{comments}");
        assert!(!lines[5].contains(ALLOW_MARKER), "{comments}");
    }

    #[test]
    fn token_boundaries_respected() {
        assert_eq!(token_offsets("let t = Instant::now();", "Instant::now").len(), 1);
        assert_eq!(token_offsets("Instant::nowhere()", "Instant::now").len(), 0);
        assert_eq!(token_offsets("MyInstant::now()", "Instant::now").len(), 0);
        assert_eq!(token_offsets("x.unwrap().y.unwrap()", ".unwrap()").len(), 2);
        assert_eq!(token_offsets("x.unwrap_or(0)", ".unwrap()").len(), 0);
        assert_eq!(token_offsets("x.expect_err(\"e\")", ".expect(").len(), 0);
    }

    #[test]
    fn d1_fires_outside_the_allowlist_only() {
        let src = "pub fn f() { let t = std::time::Instant::now(); }\n";
        assert_eq!(scan("sim/engine.rs", src).findings.len(), 1);
        assert_eq!(scan("sim/engine.rs", src).findings[0].rule, "D1");
        assert!(scan("serve/router.rs", src).findings.is_empty());
        assert!(scan("netsim/mod.rs", src).findings.is_empty());
    }

    #[test]
    fn r1_skips_test_modules() {
        let src = "pub fn f(x: Option<u32>) -> u32 { x.unwrap() }\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                       #[test]\n\
                       fn t() { Some(1u32).unwrap(); }\n\
                   }\n";
        let rep = scan("serve/protocol.rs", src);
        assert_eq!(rep.findings.len(), 1, "{:?}", rep.findings);
        assert_eq!(rep.findings[0].line, 1);
    }

    #[test]
    fn allow_suppresses_and_is_counted() {
        let src = format!(
            "{}\npub fn f() {{ let t = std::time::Instant::now(); }}\n",
            allow_comment("D1", "wall-side measurement only")
        );
        let rep = scan("sim/engine.rs", &src);
        assert!(rep.findings.is_empty(), "{:?}", rep.findings);
        assert_eq!(rep.suppressed.len(), 1);
        assert_eq!(rep.suppressed[0].rule, "D1");
        assert_eq!(rep.suppressed[0].justification, "wall-side measurement only");
    }

    #[test]
    fn trailing_allow_covers_its_own_line() {
        let src = format!(
            "pub fn f() {{ let t = std::time::Instant::now(); }} {}\n",
            allow_comment("D1", "wall side")
        );
        let rep = scan("sim/engine.rs", &src);
        assert!(rep.findings.is_empty(), "{:?}", rep.findings);
        assert_eq!(rep.suppressed.len(), 1);
    }

    #[test]
    fn allow_without_justification_is_a_finding() {
        let src = format!(
            "// {ALLOW_MARKER}(D1)\npub fn f() {{ let t = std::time::Instant::now(); }}\n"
        );
        let rep = scan("sim/engine.rs", &src);
        // The malformed allow plus the unsuppressed D1 finding.
        assert_eq!(rep.findings.len(), 2, "{:?}", rep.findings);
        assert!(rep.findings.iter().any(|f| f.rule == ALLOW_RULE));
        assert!(rep.findings.iter().any(|f| f.rule == "D1"));
    }

    #[test]
    fn unknown_rule_and_stale_allow_are_findings() {
        let src = format!(
            "{}\npub fn f() {{}}\n{}\npub fn g() {{}}\n",
            allow_comment("D9", "not a rule"),
            allow_comment("D2", "nothing random below")
        );
        let rep = scan("sim/engine.rs", &src);
        assert_eq!(rep.findings.len(), 2, "{:?}", rep.findings);
        assert!(rep.findings.iter().all(|f| f.rule == ALLOW_RULE));
    }

    #[test]
    fn wrong_rule_allow_does_not_suppress() {
        let src = format!(
            "{}\npub fn f() {{ let t = std::time::Instant::now(); }}\n",
            allow_comment("D2", "wrong rule named")
        );
        let rep = scan("sim/engine.rs", &src);
        assert!(rep.findings.iter().any(|f| f.rule == "D1"));
        // The D2 allow is stale on top of the live D1 finding.
        assert!(rep.findings.iter().any(|f| f.rule == ALLOW_RULE));
    }

    #[test]
    fn marker_in_string_or_doc_comment_is_inert() {
        let src = format!(
            "/// Example: {}\npub fn f() {{ let _s = \"{}(D1): in a string\"; }}\n",
            allow_comment("D1", "doc example"),
            ALLOW_MARKER
        );
        let rep = scan("sim/engine.rs", &src);
        assert!(rep.findings.is_empty(), "{:?}", rep.findings);
        assert!(rep.suppressed.is_empty());
    }

    #[test]
    fn d3_and_d4_scope_to_the_export_plane() {
        let map = "use std::collections::HashMap;\n";
        assert_eq!(scan("trace/mod.rs", map).findings.len(), 1);
        assert!(scan("optimizer/cache.rs", map).findings.is_empty());
        let relaxed = "let x = c.load(Ordering::Relaxed);\n";
        assert_eq!(scan("metrics/mod.rs", relaxed).findings.len(), 1);
        assert!(scan("serve/router.rs", relaxed).findings.is_empty());
        // The sharded engine's channel code sits on the export plane:
        // its pop order is the decision stream.
        assert_eq!(scan("sim/shard.rs", map).findings.len(), 1);
        assert_eq!(scan("sim/shard.rs", relaxed).findings.len(), 1);
        assert!(scan("sim/engine.rs", map).findings.is_empty());
    }

    #[test]
    fn d2_applies_even_in_tests() {
        let src = "#[cfg(test)]\nmod tests {\n fn t() { let r = rand::random::<u64>(); }\n}\n";
        let rep = scan("workload/mod.rs", src);
        assert_eq!(rep.findings.len(), 1);
        assert_eq!(rep.findings[0].rule, "D2");
    }

    #[test]
    fn report_is_stable_sorted() {
        let src =
            "pub fn f(x: Option<u32>) -> u32 { let t = std::time::Instant::now(); x.unwrap() }\n";
        let rep = scan("analyze/mod.rs", src);
        let rendered = rep.render();
        assert_eq!(rendered, scan("analyze/mod.rs", src).render());
        // D1 sorts before R1 on the same line.
        assert_eq!(rep.findings[0].rule, "D1");
        assert_eq!(rep.findings[1].rule, "R1");
    }

    #[test]
    fn rules_table_names_every_rule() {
        let table = rules_table();
        for r in RULES {
            assert!(table.contains(r.id));
        }
        assert!(rule_by_id("D3").is_some());
        assert!(rule_by_id("Z9").is_none());
    }
}
