//! Critical-path latency attribution (DESIGN.md §14): which pipeline
//! stage owns the latency mass, overall and at the tail, sliced by edge
//! site, planner strategy, and [`crate::planner::ReplanReason`].
//!
//! All statistics are exact order statistics over the recorded
//! requests — no histogram buckets, no re-derivation of the engine's
//! arithmetic. The per-stage totals are folds over requests in
//! completion order (the trace's export order), so the report is a pure
//! deterministic function of the trace.

use std::collections::BTreeMap;

use crate::util::json::Json;

use super::{ReqRecord, RunData, STAGES};

/// Exact latency order statistics for one request population.
#[derive(Clone, Debug, Default)]
pub struct LatencyStats {
    pub count: u64,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    pub max_s: f64,
}

/// One stage's latency share within a population: the total mass it
/// absorbed, its share of the population's total latency, and its share
/// of the p50/p95/p99 *request* — i.e. where the quantile request
/// actually spent its time, which is the "where did the tail go"
/// question.
#[derive(Clone, Debug, Default)]
pub struct StageShare {
    pub total_s: f64,
    pub share_of_total: f64,
    pub share_p50: f64,
    pub share_p95: f64,
    pub share_p99: f64,
}

/// Attribution for one population of requests (the whole run or a
/// slice of it).
#[derive(Clone, Debug, Default)]
pub struct SliceRow {
    pub key: String,
    pub latency: LatencyStats,
    pub stages: [StageShare; 9],
}

/// The full attribution block of an analyze report.
#[derive(Clone, Debug, Default)]
pub struct Attribution {
    pub overall: SliceRow,
    /// Per edge site (numeric order), then `cloud-only` for requests
    /// that never touched an edge tier.
    pub by_site: Vec<SliceRow>,
    /// Per governing planner strategy, alphabetical; `unknown` when a
    /// request predates any recorded re-plan for its device.
    pub by_strategy: Vec<SliceRow>,
    /// Per governing [`crate::planner::ReplanReason`], in the façade's
    /// canonical order (`spawn`, `drift`, `band`, `migration`,
    /// `failover`), then `unknown`; empty groups are dropped.
    pub by_reason: Vec<SliceRow>,
    /// Requests whose nine-way share fold needed a nonzero `downlink`
    /// residual to close exactly (≤ 1 ulp each — see
    /// [`super::ReqRecord::shares`]).
    pub residual_requests: u64,
}

/// Nearest-rank index of quantile `q` in a population of `n` sorted
/// samples (shared with the SLO audit's exact overall statistics).
pub(crate) fn quantile_idx(n: usize, q: f64) -> usize {
    ((q * n as f64).ceil() as usize).clamp(1, n) - 1
}

/// Indices of `members` sorted by (latency, req) — the req tiebreak
/// keeps the order total, so quantile picks are deterministic even
/// under duplicate latencies.
fn sorted_by_latency(data: &RunData, members: &[usize]) -> Vec<usize> {
    let mut idx = members.to_vec();
    idx.sort_by(|&a, &b| {
        let (ra, rb) = (&data.requests[a], &data.requests[b]);
        ra.latency_s()
            .partial_cmp(&rb.latency_s())
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(ra.req.cmp(&rb.req))
    });
    idx
}

/// Share of request `r`'s latency spent in stage `i` (0 when the
/// latency itself is zero).
fn stage_fraction(r: &ReqRecord, i: usize) -> f64 {
    let lat = r.latency_s();
    if lat <= 0.0 {
        0.0
    } else {
        r.shares[i] / lat
    }
}

/// Build one [`SliceRow`] over `members` (indices into
/// `data.requests`), which must be in request (completion) order.
fn slice_row(data: &RunData, key: &str, members: &[usize]) -> SliceRow {
    let mut row = SliceRow { key: key.to_string(), ..SliceRow::default() };
    let n = members.len();
    row.latency.count = n as u64;
    if n == 0 {
        return row;
    }
    let mut lat_total = 0.0f64;
    for &m in members {
        let r = &data.requests[m];
        lat_total += r.latency_s();
        for i in 0..9 {
            row.stages[i].total_s += r.shares[i];
        }
    }
    row.latency.mean_s = lat_total / n as f64;
    let sorted = sorted_by_latency(data, members);
    let (i50, i95, i99) = (quantile_idx(n, 0.50), quantile_idx(n, 0.95), quantile_idx(n, 0.99));
    let (r50, r95, r99) = (
        &data.requests[sorted[i50]],
        &data.requests[sorted[i95]],
        &data.requests[sorted[i99]],
    );
    row.latency.p50_s = r50.latency_s();
    row.latency.p95_s = r95.latency_s();
    row.latency.p99_s = r99.latency_s();
    // `members` is non-empty here (the n == 0 early return above), but
    // detlint rule R1 wants the guard structural, not positional.
    if let Some(&last) = sorted.last() {
        row.latency.max_s = data.requests[last].latency_s();
    }
    for i in 0..9 {
        row.stages[i].share_of_total =
            if lat_total > 0.0 { row.stages[i].total_s / lat_total } else { 0.0 };
        row.stages[i].share_p50 = stage_fraction(r50, i);
        row.stages[i].share_p95 = stage_fraction(r95, i);
        row.stages[i].share_p99 = stage_fraction(r99, i);
    }
    row
}

/// Index of the governing re-plan for each request: the latest
/// [`super::ReplanNote`] for the request's device at or before its
/// issue time. `None` when no such re-plan was recorded.
fn governing_replans(data: &RunData) -> Vec<Option<usize>> {
    // Per-device replan indices; record order is nondecreasing in t_s,
    // so each per-device list is too — partition_point applies.
    let mut by_device: BTreeMap<u64, Vec<usize>> = BTreeMap::new();
    for (i, rp) in data.replans.iter().enumerate() {
        by_device.entry(rp.device).or_default().push(i);
    }
    data.requests
        .iter()
        .map(|r| {
            let list = by_device.get(&r.device)?;
            let k = list.partition_point(|&i| data.replans[i].t_s <= r.issued_s);
            if k == 0 {
                None
            } else {
                Some(list[k - 1])
            }
        })
        .collect()
}

/// Canonical row order for the reason slice (the façade's reason order,
/// then the fallback bucket).
const REASON_ORDER: [&str; 6] = ["spawn", "drift", "band", "migration", "failover", "unknown"];

/// Run the attribution pass (see [`Attribution`]).
pub fn attribute(data: &RunData) -> Attribution {
    let all: Vec<usize> = (0..data.requests.len()).collect();
    let mut a = Attribution {
        overall: slice_row(data, "all", &all),
        ..Attribution::default()
    };
    a.residual_requests = data.requests.iter().filter(|r| r.shares[8] != 0.0).count() as u64;

    // --- by site: numeric site order, then cloud-only.
    let mut by_site: BTreeMap<u32, Vec<usize>> = BTreeMap::new();
    let mut cloud_only: Vec<usize> = Vec::new();
    for (i, r) in data.requests.iter().enumerate() {
        match r.site {
            Some(s) => by_site.entry(s).or_default().push(i),
            None => cloud_only.push(i),
        }
    }
    for (site, members) in &by_site {
        a.by_site.push(slice_row(data, &format!("site:{site}"), members));
    }
    if !cloud_only.is_empty() {
        a.by_site.push(slice_row(data, "cloud-only", &cloud_only));
    }

    // --- by strategy / by reason, via each request's governing re-plan.
    let governing = governing_replans(data);
    let mut by_strategy: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
    let mut by_reason: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
    for (i, g) in governing.iter().enumerate() {
        let (strategy, reason) = match g {
            Some(k) => (data.replans[*k].strategy.as_str(), data.replans[*k].reason.as_str()),
            None => ("unknown", "unknown"),
        };
        by_strategy.entry(strategy).or_default().push(i);
        by_reason.entry(reason).or_default().push(i);
    }
    for (strategy, members) in &by_strategy {
        a.by_strategy.push(slice_row(data, strategy, members));
    }
    for reason in REASON_ORDER {
        if let Some(members) = by_reason.remove(reason) {
            a.by_reason.push(slice_row(data, reason, &members));
        }
    }
    // A reason name outside the canonical list (a future façade) still
    // gets a row rather than silently vanishing; BTreeMap keeps the
    // leftovers alphabetical.
    for (reason, members) in &by_reason {
        a.by_reason.push(slice_row(data, reason, members));
    }
    a
}

impl LatencyStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("mean_s", Json::Num(self.mean_s)),
            ("p50_s", Json::Num(self.p50_s)),
            ("p95_s", Json::Num(self.p95_s)),
            ("p99_s", Json::Num(self.p99_s)),
            ("max_s", Json::Num(self.max_s)),
        ])
    }
}

impl SliceRow {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("key", Json::str(&self.key)),
            ("latency", self.latency.to_json()),
            (
                "stages",
                Json::Arr(
                    STAGES
                        .iter()
                        .zip(&self.stages)
                        .map(|(kind, s)| {
                            Json::obj(vec![
                                ("stage", Json::str(kind.name())),
                                ("total_s", Json::Num(s.total_s)),
                                ("share_of_total", Json::Num(s.share_of_total)),
                                ("share_p50", Json::Num(s.share_p50)),
                                ("share_p95", Json::Num(s.share_p95)),
                                ("share_p99", Json::Num(s.share_p99)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Name of the stage with the largest share of the p99 request.
    pub fn dominant_p99_stage(&self) -> &'static str {
        let mut best = 0;
        for i in 1..9 {
            if self.stages[i].share_p99 > self.stages[best].share_p99 {
                best = i;
            }
        }
        STAGES[best].name()
    }
}

impl Attribution {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("overall", self.overall.to_json()),
            ("by_site", Json::Arr(self.by_site.iter().map(SliceRow::to_json).collect())),
            (
                "by_strategy",
                Json::Arr(self.by_strategy.iter().map(SliceRow::to_json).collect()),
            ),
            ("by_reason", Json::Arr(self.by_reason.iter().map(SliceRow::to_json).collect())),
            ("residual_requests", Json::Num(self.residual_requests as f64)),
        ])
    }

    /// Console tables: the overall stage breakdown, then one line per
    /// slice with its tail owner.
    pub fn print(&self) {
        println!("-- stage attribution (overall, {} requests) --", self.overall.latency.count);
        println!(
            "{:<14} {:>12} {:>8} {:>8} {:>8} {:>8}",
            "stage", "total_s", "share", "@p50", "@p95", "@p99"
        );
        for (kind, s) in STAGES.iter().zip(&self.overall.stages) {
            println!(
                "{:<14} {:>12.4} {:>7.1}% {:>7.1}% {:>7.1}% {:>7.1}%",
                kind.name(),
                s.total_s,
                100.0 * s.share_of_total,
                100.0 * s.share_p50,
                100.0 * s.share_p95,
                100.0 * s.share_p99,
            );
        }
        for (label, rows) in
            [("site", &self.by_site), ("strategy", &self.by_strategy), ("reason", &self.by_reason)]
        {
            if rows.is_empty() {
                continue;
            }
            println!("-- by {label} --");
            for row in rows {
                println!(
                    "{:<14} n={:<7} p50={:.4}s p99={:.4}s tail-owner={}",
                    row.key,
                    row.latency.count,
                    row.latency.p50_s,
                    row.latency.p99_s,
                    row.dominant_p99_stage(),
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{ReplanNote, ReqRecord};
    use super::*;

    fn req(req: u64, device: u64, t0: f64, shares: [f64; 9], site: Option<u32>) -> ReqRecord {
        let lat: f64 = shares.iter().sum();
        ReqRecord { req, device, issued_s: t0, completed_s: t0 + lat, shares, site }
    }

    fn data3() -> RunData {
        let mut shares_a = [0.0; 9];
        shares_a[1] = 0.2; // head
        shares_a[4] = 0.8; // edge service
        let mut shares_b = [0.0; 9];
        shares_b[1] = 0.1;
        shares_b[7] = 0.4; // cloud service
        let mut shares_c = [0.0; 9];
        shares_c[2] = 2.0; // uplink-dominated straggler
        RunData {
            requests: vec![
                req(0, 0, 0.0, shares_a, Some(0)),
                req(1, 1, 1.0, shares_b, None),
                req(2, 0, 2.0, shares_c, Some(1)),
            ],
            replans: vec![
                ReplanNote { t_s: 0.0, device: 0, reason: "spawn".into(), strategy: "SmartSplit".into() },
                ReplanNote { t_s: 1.5, device: 0, reason: "drift".into(), strategy: "Topsis".into() },
            ],
            ..RunData::default()
        }
    }

    #[test]
    fn quantiles_are_nearest_rank() {
        assert_eq!(quantile_idx(1, 0.5), 0);
        assert_eq!(quantile_idx(2, 0.5), 0);
        assert_eq!(quantile_idx(3, 0.5), 1);
        assert_eq!(quantile_idx(100, 0.95), 94);
        assert_eq!(quantile_idx(100, 0.99), 98);
        assert_eq!(quantile_idx(100, 1.0), 99);
    }

    #[test]
    fn overall_shares_and_tail_owner() {
        let a = attribute(&data3());
        assert_eq!(a.overall.latency.count, 3);
        // max latency is the 2.0s uplink straggler; it owns p99.
        assert_eq!(a.overall.latency.max_s, 2.0);
        assert_eq!(a.overall.dominant_p99_stage(), "uplink");
        // share_of_total partitions to 1 across stages.
        let sum: f64 = a.overall.stages.iter().map(|s| s.share_of_total).sum();
        assert!((sum - 1.0).abs() < 1e-12);
        assert_eq!(a.residual_requests, 0);
    }

    #[test]
    fn site_slices_are_numeric_then_cloud_only() {
        let a = attribute(&data3());
        let keys: Vec<&str> = a.by_site.iter().map(|r| r.key.as_str()).collect();
        assert_eq!(keys, vec!["site:0", "site:1", "cloud-only"]);
        assert_eq!(a.by_site[2].latency.count, 1);
    }

    #[test]
    fn governing_replan_slices_by_strategy_and_reason() {
        let a = attribute(&data3());
        // req 0 (device 0, issued 0.0) governed by the spawn/SmartSplit
        // replan at t=0.0; req 2 (device 0, issued 2.0) by drift/Topsis
        // at t=1.5; req 1 (device 1) has no replan → unknown.
        let strat: Vec<(&str, u64)> =
            a.by_strategy.iter().map(|r| (r.key.as_str(), r.latency.count)).collect();
        assert_eq!(strat, vec![("SmartSplit", 1), ("Topsis", 1), ("unknown", 1)]);
        let reason: Vec<&str> = a.by_reason.iter().map(|r| r.key.as_str()).collect();
        assert_eq!(reason, vec!["spawn", "drift", "unknown"]);
    }

    #[test]
    fn empty_run_attributes_without_nan() {
        let a = attribute(&RunData::default());
        assert_eq!(a.overall.latency.count, 0);
        for s in &a.overall.stages {
            assert!(s.share_of_total == 0.0 && s.total_s == 0.0);
        }
        let text = a.to_json().to_string_pretty();
        assert!(!text.contains("NaN") && !text.contains("inf"), "{text}");
    }
}
