//! Deterministic post-run analytics over the observability plane
//! (DESIGN.md §14): critical-path latency attribution, declarative SLO
//! audits with per-fault impact accounting, and run-vs-run regression
//! diffs.
//!
//! PR 6/PR 7 gave every sampled request a span timeline that tiles
//! `issued_s → completed_s` bit-for-bit and every fault a causal
//! annotation; this module is the layer that *answers questions* from
//! them — which tier owns the p99 tail ([`attribution`]), whether the
//! run met its latency/drop objectives and what each injected fault
//! cost ([`slo`]), and whether a change regressed anything ([`diff`]).
//!
//! Two input paths produce the identical analysis:
//!
//! * **in-process** — [`RunData::from_report`] against a live
//!   [`crate::sim::SimReport`] (the `simulate --slo` / `--report-out`
//!   path);
//! * **offline** — [`RunData::from_export_files`] against the
//!   `--trace-out` JSONL and `--metrics-out` JSON files (the `analyze`
//!   subcommand), re-parsed through [`crate::util::json`]. The JSONL
//!   writer emits shortest-roundtrip f64s, so the offline path recovers
//!   the engine's exact bits and the two paths agree byte-for-byte
//!   (`tests/analyze.rs`).
//!
//! Determinism contract (same discipline as the exports themselves):
//! reports are pure functions of their inputs, serialized from
//! insertion-ordered [`Json`] objects, grouped through `BTreeMap` (never
//! a `HashMap` iteration), with every division guarded so no NaN can
//! reach the serializer — byte-identical across thread configs and
//! reruns, pinned by `tests/analyze.rs` and replayed by CI.

pub mod attribution;
pub mod diff;
pub mod slo;

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::metrics::TimeSeriesReport;
use crate::sim::SimReport;
use crate::trace::{CausalEvent, RequestTrace, SpanKind, TraceReport};
use crate::util::json::Json;

pub use attribution::{Attribution, LatencyStats, SliceRow, StageShare};
pub use diff::{diff_reports, DiffEntry, DiffReport};
pub use slo::{FaultAudit, FaultImpact, Slo, SloOutcome};

/// Version stamped into every analyze report (`schema_version`);
/// `.github/check_observability.py` validates it on the serialized
/// bytes.
pub const ANALYZE_SCHEMA_VERSION: u64 = 1;

/// Every pipeline stage, in pipeline order — the fixed row order of
/// every attribution table. `Downlink` is last and holds the (≤ 1 ulp,
/// usually zero) telescoping residual — see [`ReqRecord::shares`].
pub const STAGES: [SpanKind; 9] = [
    SpanKind::DeviceQueue,
    SpanKind::HeadCompute,
    SpanKind::Uplink,
    SpanKind::EdgeQueue,
    SpanKind::EdgeService,
    SpanKind::Backhaul,
    SpanKind::CloudQueue,
    SpanKind::CloudService,
    SpanKind::Downlink,
];

/// Index of a stage in [`STAGES`] (= its pipeline rank).
pub fn stage_index(kind: SpanKind) -> usize {
    match kind {
        SpanKind::DeviceQueue => 0,
        SpanKind::HeadCompute => 1,
        SpanKind::Uplink => 2,
        SpanKind::EdgeQueue => 3,
        SpanKind::EdgeService => 4,
        SpanKind::Backhaul => 5,
        SpanKind::CloudQueue => 6,
        SpanKind::CloudService => 7,
        SpanKind::Downlink => 8,
    }
}

/// Inverse of [`SpanKind::name`] for the offline parse path.
pub fn stage_by_name(name: &str) -> Option<SpanKind> {
    STAGES.iter().copied().find(|k| k.name() == name)
}

/// One completed request, reduced to the numbers attribution needs.
#[derive(Clone, Debug)]
pub struct ReqRecord {
    pub req: u64,
    pub device: u64,
    pub issued_s: f64,
    pub completed_s: f64,
    /// Exact per-stage decomposition of the end-to-end latency, indexed
    /// by [`STAGES`]. Stages `0..8` are the recorded span durations
    /// (each exact: consecutive span boundaries are within a factor of
    /// two, so the subtraction is exact by Sterbenz); slot 8
    /// (`Downlink`, zero-length by the paper's Eq. 14) is defined as
    /// `latency - Σ(other stages)` so that the left-to-right sum of all
    /// nine shares reproduces `completed_s - issued_s` **bit-for-bit**
    /// — the partition is exact by construction, not by tolerance
    /// (`tests/analyze.rs` asserts it with `==` over `city_mobile` and
    /// `city_faulty`). The slot is nonzero only when the f64 fold of
    /// the exact span durations rounds off the real-number telescope —
    /// at most 1 ulp, counted in
    /// [`Attribution::residual_requests`].
    pub shares: [f64; 9],
    /// Edge site of the first edge-tier span (queue/service/backhaul);
    /// `None` for requests that never touched an edge site.
    pub site: Option<u32>,
}

impl ReqRecord {
    /// Recorded end-to-end latency (the engine's own subtraction).
    pub fn latency_s(&self) -> f64 {
        self.completed_s - self.issued_s
    }

    /// Left-to-right sum of the nine stage shares. Bit-equal to
    /// [`ReqRecord::latency_s`] by construction (see
    /// [`ReqRecord::shares`]).
    pub fn share_sum(&self) -> f64 {
        self.shares.iter().fold(0.0f64, |acc, &d| acc + d)
    }
}

/// A split re-plan annotation, reduced for slicing (strategy and reason
/// keep their stable export names so the offline path needs no enum
/// round-trip).
#[derive(Clone, Debug)]
pub struct ReplanNote {
    pub t_s: f64,
    pub device: u64,
    pub reason: String,
    pub strategy: String,
}

/// A fault edge (`site_down`, `backhaul_degrade`, …) from the causal
/// stream.
#[derive(Clone, Debug)]
pub struct FaultNote {
    pub t_s: f64,
    pub kind: String,
    pub site: u32,
    pub value: f64,
}

/// A request rerouted to the cloud off a dead site.
#[derive(Clone, Debug)]
pub struct FailoverNote {
    pub t_s: f64,
    pub req: u64,
    pub device: u64,
    pub from_site: u32,
}

/// One time-series window, reduced to what the SLO audit evaluates.
#[derive(Clone, Debug, Default)]
pub struct WindowStats {
    pub index: u64,
    pub start_s: f64,
    pub end_s: f64,
    pub generated: u64,
    pub completed: u64,
    pub dropped: u64,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    pub max_s: f64,
}

/// Everything the analysis consumes, loadable from a live report or
/// from the serialized exports (the two agree bit-for-bit — module
/// docs).
#[derive(Clone, Debug, Default)]
pub struct RunData {
    /// Model name; empty when the input was a trace file alone (the
    /// trace export does not carry it).
    pub model: String,
    pub seed: Option<u64>,
    /// Completed sampled requests, in completion order.
    pub requests: Vec<ReqRecord>,
    /// Re-plan annotations in record order (nondecreasing `t_s`).
    pub replans: Vec<ReplanNote>,
    /// Fault edges in record order.
    pub faults: Vec<FaultNote>,
    /// Outage reroutes in record order.
    pub failovers: Vec<FailoverNote>,
    /// All causal annotations, including kinds the analysis only counts.
    pub events_total: u64,
    /// The trace's sampling knob (1 = every request was recorded).
    pub sample_every: u64,
    /// Window width; 0 when no series was attached.
    pub window_s: f64,
    pub windows: Vec<WindowStats>,
    /// Run totals — `None` for trace-only inputs (the sampled trace
    /// cannot reconstruct them).
    pub generated: Option<u64>,
    pub completed: Option<u64>,
    pub dropped: Option<u64>,
    /// Latest virtual time seen (drain time in-process; max of window
    /// ends / completions / event stamps offline) — closes unclosed
    /// fault intervals.
    pub horizon_s: f64,
}

/// Reduce one traced request (shared by both input paths — this is the
/// single place the exact-partition arithmetic lives).
fn req_record(t: &RequestTrace) -> ReqRecord {
    let mut shares = [0.0f64; 9];
    let mut sum = 0.0f64;
    let mut site = None;
    for s in &t.spans {
        if s.kind == SpanKind::Downlink {
            continue; // zero-length marker; slot 8 is the residual below
        }
        let d = s.end_s - s.start_s;
        shares[stage_index(s.kind)] += d;
        sum += d;
        if site.is_none()
            && matches!(s.kind, SpanKind::EdgeQueue | SpanKind::EdgeService | SpanKind::Backhaul)
        {
            site = s.site;
        }
    }
    // The residual makes the partition exact: sum + (latency - sum)
    // re-folds to latency bit-for-bit (Sterbenz: the fold of exact
    // span durations lands within a factor of two of the latency, so
    // the subtraction below is itself exact).
    shares[8] = t.latency_s() - sum;
    ReqRecord {
        req: t.req,
        device: t.device,
        issued_s: t.issued_s,
        completed_s: t.completed_s,
        shares,
        site,
    }
}

impl RunData {
    /// In-process path: consume a live [`SimReport`]. Tracing must have
    /// been enabled; the window series is attached when present.
    pub fn from_report(r: &SimReport) -> Result<RunData> {
        let tr = r.trace.as_ref().context(
            "analysis needs per-request tracing \
             (--trace-out / ObservabilityConfig::trace_sample_every >= 1)",
        )?;
        let mut d = RunData::from_trace(tr);
        d.model = r.model.clone();
        d.seed = Some(r.seed);
        d.generated = Some(r.generated);
        d.completed = Some(r.completed);
        d.dropped = Some(r.dropped);
        d.horizon_s = d.horizon_s.max(r.sim_end_s);
        if let Some(ts) = &r.series {
            d.attach_series(ts);
        }
        Ok(d)
    }

    /// Reduce a sealed trace (no run totals, no windows).
    pub fn from_trace(tr: &TraceReport) -> RunData {
        let requests: Vec<ReqRecord> = tr.requests.iter().map(req_record).collect();
        let mut d = RunData {
            sample_every: tr.sample_every,
            events_total: tr.events.len() as u64,
            ..RunData::default()
        };
        for e in &tr.events {
            match e {
                CausalEvent::Replan { t_s, device, reason, strategy, .. } => {
                    d.replans.push(ReplanNote {
                        t_s: *t_s,
                        device: *device,
                        reason: reason.name().to_string(),
                        strategy: strategy.name().to_string(),
                    });
                }
                CausalEvent::Fault { t_s, kind, site, value } => {
                    d.faults.push(FaultNote {
                        t_s: *t_s,
                        kind: (*kind).to_string(),
                        site: *site,
                        value: *value,
                    });
                }
                CausalEvent::Failover { t_s, req, device, from_site } => {
                    d.failovers.push(FailoverNote {
                        t_s: *t_s,
                        req: *req,
                        device: *device,
                        from_site: *from_site,
                    });
                }
                CausalEvent::HandoverRelay { .. } | CausalEvent::Reattach { .. } => {}
            }
            d.horizon_s = d.horizon_s.max(e.t_s());
        }
        for r in &requests {
            d.horizon_s = d.horizon_s.max(r.completed_s);
        }
        d.requests = requests;
        d
    }

    /// Attach a windowed series to trace-derived data.
    pub fn attach_series(&mut self, ts: &TimeSeriesReport) {
        self.window_s = ts.window_s;
        self.windows = ts
            .windows
            .iter()
            .map(|w| WindowStats {
                index: w.index,
                start_s: w.start_s,
                end_s: w.end_s,
                generated: w.generated,
                completed: w.completed,
                dropped: w.dropped,
                mean_s: w.latency.mean_s,
                p50_s: w.latency.p50_s,
                p95_s: w.latency.p95_s,
                p99_s: w.latency.p99_s,
                max_s: w.latency.max_s,
            })
            .collect();
        if let Some(last) = self.windows.last() {
            self.horizon_s = self.horizon_s.max(last.end_s);
        }
    }

    /// Offline path: parse the `--trace-out` JSONL and/or the
    /// `--metrics-out` JSON. At least one must be given; attribution
    /// and fault impact need the trace, the windowed SLO audit the
    /// metrics.
    pub fn from_export_files(trace: Option<&Path>, metrics: Option<&Path>) -> Result<RunData> {
        let read = |p: &Path| {
            std::fs::read_to_string(p).with_context(|| format!("reading {}", p.display()))
        };
        let trace_text = trace.map(read).transpose()?;
        let metrics_text = metrics.map(read).transpose()?;
        RunData::from_export_strs(trace_text.as_deref(), metrics_text.as_deref())
    }

    /// [`RunData::from_export_files`] on in-memory strings (the form the
    /// round-trip tests use).
    pub fn from_export_strs(trace_jsonl: Option<&str>, metrics_json: Option<&str>) -> Result<RunData> {
        if trace_jsonl.is_none() && metrics_json.is_none() {
            bail!("analysis needs a trace JSONL and/or a metrics JSON export");
        }
        let mut d = match trace_jsonl {
            Some(text) => parse_trace_jsonl(text)?,
            None => RunData::default(),
        };
        if let Some(text) = metrics_json {
            parse_metrics_json(text, &mut d)?;
        }
        Ok(d)
    }

    /// Overall drop rate in `[0, 1]`: run totals when known, else the
    /// window sums, else 0.
    pub fn drop_rate(&self) -> f64 {
        let (gen, dropped) = match (self.generated, self.dropped) {
            (Some(g), Some(x)) => (g, x),
            _ => (
                self.windows.iter().map(|w| w.generated).sum(),
                self.windows.iter().map(|w| w.dropped).sum(),
            ),
        };
        if gen == 0 {
            return 0.0;
        }
        dropped as f64 / gen as f64
    }
}

/// Accepted trace schema versions: 1 (PR 6/PR 7, `"version"`) and the
/// current `"schema_version"`.
const TRACE_SCHEMA_ACCEPTED: [u64; 2] = [1, crate::trace::export::TRACE_SCHEMA_VERSION];

fn parse_trace_jsonl(text: &str) -> Result<RunData> {
    let mut lines = text.lines().enumerate();
    let (_, first) = lines.next().context("empty trace file")?;
    let meta = Json::parse(first).context("trace line 1 (meta header)")?;
    if meta.get_str("type").ok() != Some("meta")
        || meta.get_str("format").ok() != Some("smartsplit-trace")
    {
        bail!("not a smartsplit-trace JSONL export (missing meta header)");
    }
    let version = meta
        .get("schema_version")
        .or_else(|_| meta.get("version"))
        .and_then(|v| v.as_u64())
        .context("trace meta carries no schema version")?;
    if !TRACE_SCHEMA_ACCEPTED.contains(&version) {
        bail!(
            "unsupported trace schema_version {version} (this build reads {:?})",
            TRACE_SCHEMA_ACCEPTED
        );
    }
    let mut d = RunData {
        sample_every: meta.get("sample_every").and_then(|v| v.as_u64()).unwrap_or(1),
        ..RunData::default()
    };
    for (i, line) in lines {
        if line.trim().is_empty() {
            continue;
        }
        let obj = Json::parse(line).with_context(|| format!("trace line {}", i + 1))?;
        let kind = obj.get_str("type").map_err(|e| anyhow::anyhow!("trace line {}: {e}", i + 1))?;
        match kind {
            "request" => {
                let spans = obj
                    .get("spans")
                    .and_then(|s| s.as_arr())
                    .map_err(|e| anyhow::anyhow!("trace line {}: {e}", i + 1))?;
                let mut t = RequestTrace {
                    req: obj.get("req").and_then(|v| v.as_u64()).unwrap_or(0),
                    device: obj.get("device").and_then(|v| v.as_u64()).unwrap_or(0),
                    issued_s: obj.get_f64("issued_s").unwrap_or(0.0),
                    completed_s: obj.get_f64("completed_s").unwrap_or(0.0),
                    spans: Vec::with_capacity(spans.len()),
                };
                for s in spans {
                    let name = s.get_str("kind").unwrap_or("");
                    let kind = stage_by_name(name)
                        .with_context(|| format!("trace line {}: unknown span kind {name:?}", i + 1))?;
                    t.spans.push(crate::trace::Span {
                        kind,
                        start_s: s.get_f64("start_s").unwrap_or(0.0),
                        end_s: s.get_f64("end_s").unwrap_or(0.0),
                        site: s.get("site").ok().and_then(|v| v.as_u64().ok()).map(|v| v as u32),
                    });
                }
                d.horizon_s = d.horizon_s.max(t.completed_s);
                d.requests.push(req_record(&t));
            }
            "replan" => {
                d.events_total += 1;
                let t_s = obj.get_f64("t_s").unwrap_or(0.0);
                d.horizon_s = d.horizon_s.max(t_s);
                d.replans.push(ReplanNote {
                    t_s,
                    device: obj.get("device").and_then(|v| v.as_u64()).unwrap_or(0),
                    reason: obj.get_str("reason").unwrap_or("unknown").to_string(),
                    strategy: obj.get_str("strategy").unwrap_or("unknown").to_string(),
                });
            }
            "fault" => {
                d.events_total += 1;
                let t_s = obj.get_f64("t_s").unwrap_or(0.0);
                d.horizon_s = d.horizon_s.max(t_s);
                d.faults.push(FaultNote {
                    t_s,
                    kind: obj.get_str("kind").unwrap_or("unknown").to_string(),
                    site: obj.get("site").and_then(|v| v.as_u64()).unwrap_or(0) as u32,
                    value: obj.get_f64("value").unwrap_or(0.0),
                });
            }
            "failover" => {
                d.events_total += 1;
                let t_s = obj.get_f64("t_s").unwrap_or(0.0);
                d.horizon_s = d.horizon_s.max(t_s);
                d.failovers.push(FailoverNote {
                    t_s,
                    req: obj.get("req").and_then(|v| v.as_u64()).unwrap_or(0),
                    device: obj.get("device").and_then(|v| v.as_u64()).unwrap_or(0),
                    from_site: obj.get("from_site").and_then(|v| v.as_u64()).unwrap_or(0) as u32,
                });
            }
            "handover_relay" | "reattach" => {
                d.events_total += 1;
                let t_s = obj
                    .get_f64("t_s")
                    .or_else(|_| obj.get_f64("start_s"))
                    .unwrap_or(0.0);
                d.horizon_s = d.horizon_s.max(t_s);
            }
            other => bail!("trace line {}: unknown line type {other:?}", i + 1),
        }
    }
    Ok(d)
}

fn parse_metrics_json(text: &str, d: &mut RunData) -> Result<()> {
    let doc = Json::parse(text).context("parsing metrics JSON")?;
    if let Ok(v) = doc.get("schema_version").and_then(|v| v.as_u64()) {
        if v > crate::metrics::METRICS_SCHEMA_VERSION {
            bail!(
                "unsupported metrics schema_version {v} (this build reads <= {})",
                crate::metrics::METRICS_SCHEMA_VERSION
            );
        }
    }
    if d.model.is_empty() {
        d.model = doc.get_str("model").unwrap_or("").to_string();
    }
    if d.seed.is_none() {
        d.seed = doc.get("seed").ok().and_then(|v| v.as_u64().ok());
    }
    if let Ok(g) = doc.get("generated").and_then(|v| v.as_u64()) {
        d.generated = Some(g);
    }
    if let Ok(c) = doc.get("completed").and_then(|v| v.as_u64()) {
        d.completed = Some(c);
    }
    if let Ok(x) = doc.get("dropped").and_then(|v| v.as_u64()) {
        d.dropped = Some(x);
    }
    let series = doc.get("series").context("metrics JSON carries no \"series\"")?;
    d.window_s = series.get_f64("window_s").context("series.window_s")?;
    let windows = series.get("windows").and_then(|w| w.as_arr())?;
    d.windows = windows
        .iter()
        .map(|w| -> Result<WindowStats> {
            let lat = w.get("latency").context("window.latency")?;
            Ok(WindowStats {
                index: w.get("index").and_then(|v| v.as_u64()).unwrap_or(0),
                start_s: w.get_f64("start_s").unwrap_or(0.0),
                end_s: w.get_f64("end_s").unwrap_or(0.0),
                generated: w.get("generated").and_then(|v| v.as_u64()).unwrap_or(0),
                completed: w.get("completed").and_then(|v| v.as_u64()).unwrap_or(0),
                dropped: w.get("dropped").and_then(|v| v.as_u64()).unwrap_or(0),
                mean_s: lat.get_f64("mean_s").unwrap_or(0.0),
                p50_s: lat.get_f64("p50_s").unwrap_or(0.0),
                p95_s: lat.get_f64("p95_s").unwrap_or(0.0),
                p99_s: lat.get_f64("p99_s").unwrap_or(0.0),
                max_s: lat.get_f64("max_s").unwrap_or(0.0),
            })
        })
        .collect::<Result<Vec<_>>>()?;
    if let Some(last) = d.windows.last() {
        d.horizon_s = d.horizon_s.max(last.end_s);
    }
    Ok(())
}

/// The assembled analysis: attribution + SLO audit + fault impact, with
/// a versioned byte-stable JSON form and a console table form.
#[derive(Clone, Debug)]
pub struct AnalyzeReport {
    pub model: String,
    pub seed: Option<u64>,
    pub requests: u64,
    pub events: u64,
    pub windows: u64,
    pub attribution: Attribution,
    pub slos: Vec<SloOutcome>,
    pub faults: FaultAudit,
}

impl AnalyzeReport {
    /// Run the full analysis. Pure: same data + same SLOs → the same
    /// report, byte-for-byte.
    pub fn build(data: &RunData, slos: &[Slo]) -> AnalyzeReport {
        AnalyzeReport {
            model: data.model.clone(),
            seed: data.seed,
            requests: data.requests.len() as u64,
            events: data.events_total,
            windows: data.windows.len() as u64,
            attribution: attribution::attribute(data),
            slos: slo::audit(data, slos),
            faults: slo::fault_impact(data),
        }
    }

    /// The versioned report document (`--report-out`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("format", Json::str("smartsplit-analyze")),
            ("schema_version", Json::Num(ANALYZE_SCHEMA_VERSION as f64)),
            ("model", Json::str(&self.model)),
            (
                "seed",
                match self.seed {
                    Some(s) => Json::Num(s as f64),
                    None => Json::Null,
                },
            ),
            (
                "source",
                Json::obj(vec![
                    ("requests", Json::Num(self.requests as f64)),
                    ("events", Json::Num(self.events as f64)),
                    ("windows", Json::Num(self.windows as f64)),
                ]),
            ),
            ("attribution", self.attribution.to_json()),
            ("slos", Json::Arr(self.slos.iter().map(SloOutcome::to_json).collect())),
            ("faults", self.faults.to_json()),
        ])
    }

    /// Console tables: the overall stage table, the per-slice tails,
    /// SLO verdicts, and per-fault impact lines.
    pub fn print(&self) {
        println!(
            "== analyze: {} — {} requests, {} events, {} windows ==",
            if self.model.is_empty() { "(unknown model)" } else { &self.model },
            self.requests,
            self.events,
            self.windows,
        );
        self.attribution.print();
        if !self.slos.is_empty() {
            println!("-- SLOs --");
            for s in &self.slos {
                s.print();
            }
        }
        self.faults.print();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::TraceRecorder;

    fn traced(rec: &mut TraceRecorder, req: u64, t0: f64, spans: &[(SpanKind, f64)]) {
        rec.begin(req, req, t0);
        let mut t = t0;
        for &(kind, d) in spans {
            let site = matches!(
                kind,
                SpanKind::EdgeQueue | SpanKind::EdgeService | SpanKind::Backhaul
            )
            .then_some(1);
            rec.span(req, kind, t, t + d, site);
            t += d;
        }
        rec.complete(req, t);
    }

    #[test]
    fn shares_partition_latency_bit_for_bit() {
        let mut rec = TraceRecorder::new(1);
        traced(
            &mut rec,
            0,
            10.0,
            &[
                (SpanKind::DeviceQueue, 0.0),
                (SpanKind::HeadCompute, 0.125),
                (SpanKind::Uplink, 0.1),
                (SpanKind::EdgeQueue, 0.3),
                (SpanKind::EdgeService, 0.2),
            ],
        );
        // Awkward magnitudes on purpose: non-representable sums.
        traced(
            &mut rec,
            1,
            1234.567,
            &[
                (SpanKind::DeviceQueue, 0.1),
                (SpanKind::HeadCompute, 0.2),
                (SpanKind::Uplink, 0.3),
                (SpanKind::CloudQueue, 0.0001),
                (SpanKind::CloudService, 0.7),
            ],
        );
        let d = RunData::from_trace(&rec.finish());
        assert_eq!(d.requests.len(), 2);
        for r in &d.requests {
            assert_eq!(
                r.share_sum().to_bits(),
                r.latency_s().to_bits(),
                "request {} shares do not partition its latency exactly",
                r.req
            );
        }
        assert_eq!(d.requests[0].site, Some(1));
        assert_eq!(d.requests[1].site, None);
    }

    #[test]
    fn trace_jsonl_round_trip_preserves_exact_bits() {
        let mut rec = TraceRecorder::new(1);
        traced(
            &mut rec,
            0,
            987.654321,
            &[
                (SpanKind::DeviceQueue, 0.0),
                (SpanKind::HeadCompute, 1.0 / 3.0),
                (SpanKind::Uplink, 0.1),
                (SpanKind::EdgeQueue, 1e-7),
                (SpanKind::EdgeService, 0.25),
                (SpanKind::Backhaul, 0.0125),
                (SpanKind::CloudQueue, 0.0),
                (SpanKind::CloudService, 2.0 / 7.0),
            ],
        );
        rec.note(CausalEvent::Fault { t_s: 30.0, kind: "site_down", site: 1, value: 0.0 });
        rec.note(CausalEvent::Failover { t_s: 30.0, req: 0, device: 0, from_site: 1 });
        let report = rec.finish();
        let live = RunData::from_trace(&report);
        let parsed = RunData::from_export_strs(Some(&report.to_jsonl()), None).expect("parses");
        assert_eq!(live.requests.len(), parsed.requests.len());
        for (a, b) in live.requests.iter().zip(&parsed.requests) {
            assert_eq!(a.issued_s.to_bits(), b.issued_s.to_bits());
            assert_eq!(a.completed_s.to_bits(), b.completed_s.to_bits());
            for i in 0..9 {
                assert_eq!(a.shares[i].to_bits(), b.shares[i].to_bits(), "stage {i} drifted");
            }
        }
        assert_eq!(parsed.faults.len(), 1);
        assert_eq!(parsed.failovers.len(), 1);
        assert_eq!(parsed.events_total, 2);
    }

    #[test]
    fn span_order_matches_stage_rank_order() {
        // The exact-partition argument needs the span order and the
        // STAGES order to coincide; pin the table against SpanKind.
        for (i, k) in STAGES.iter().enumerate() {
            assert_eq!(stage_index(*k), i);
            assert_eq!(stage_by_name(k.name()), Some(*k));
        }
        assert_eq!(stage_by_name("nope"), None);
    }

    #[test]
    fn rejects_unknown_schema_and_garbage() {
        assert!(RunData::from_export_strs(None, None).is_err());
        assert!(RunData::from_export_strs(Some("not json"), None).is_err());
        let bad_version = "{\"type\": \"meta\", \"format\": \"smartsplit-trace\", \
                           \"schema_version\": 999, \"sample_every\": 1}";
        let err = RunData::from_export_strs(Some(bad_version), None).unwrap_err();
        assert!(format!("{err:#}").contains("schema_version 999"), "{err:#}");
    }

    #[test]
    fn zero_length_request_is_partitioned_without_nan() {
        let mut rec = TraceRecorder::new(1);
        traced(&mut rec, 0, 5.0, &[(SpanKind::DeviceQueue, 0.0), (SpanKind::HeadCompute, 0.0)]);
        let d = RunData::from_trace(&rec.finish());
        assert_eq!(d.requests[0].latency_s(), 0.0);
        assert_eq!(d.requests[0].share_sum().to_bits(), 0.0f64.to_bits());
    }
}
