//! Run-vs-run regression diff (DESIGN.md §14): a structural walk over
//! two analyze-report JSON trees that emits every changed leaf, tagged
//! `regression` / `improvement` / `neutral` by a direction-aware
//! classifier.
//!
//! The diff is defined on the *serialized report*, not on re-analyzed
//! inputs — so a run diffed against itself is exactly empty (the
//! reports are byte-identical; `tests/analyze.rs` and CI pin this), and
//! whatever the report records is exactly what the diff can flag.

use crate::util::json::Json;

use super::ANALYZE_SCHEMA_VERSION;

/// One changed leaf between two reports.
#[derive(Clone, Debug)]
pub struct DiffEntry {
    /// Dotted path to the leaf (`attribution.overall.latency.p99_s`,
    /// `slos[0].verdict`, …).
    pub path: String,
    pub baseline: Json,
    pub candidate: Json,
    /// `"regression"`, `"improvement"`, or `"neutral"`.
    pub class: &'static str,
}

/// The assembled diff.
#[derive(Clone, Debug, Default)]
pub struct DiffReport {
    pub changes: Vec<DiffEntry>,
    pub regressions: u64,
    pub improvements: u64,
}

/// Leaf keys where a larger candidate value is worse (latency mass,
/// drops, SLO burn).
const WORSE_UP: [&str; 15] = [
    "mean_s",
    "p50_s",
    "p95_s",
    "p99_s",
    "max_s",
    "total_s",
    "dropped",
    "overall_value",
    "windows_violating",
    "violation_time_s",
    "longest_streak",
    "burn_fraction",
    "latency_tax_s",
    "mean_latency_in_s",
    "reroutes",
];

/// Leaf keys where a smaller candidate value is worse.
const WORSE_DOWN: [&str; 2] = ["completed", "completions_in"];

/// Direction-aware classification of one changed leaf.
fn classify(leaf_key: &str, baseline: &Json, candidate: &Json) -> &'static str {
    match (baseline, candidate) {
        (Json::Num(a), Json::Num(b)) => {
            if WORSE_UP.contains(&leaf_key) {
                if b > a {
                    "regression"
                } else {
                    "improvement"
                }
            } else if WORSE_DOWN.contains(&leaf_key) {
                if b < a {
                    "regression"
                } else {
                    "improvement"
                }
            } else {
                "neutral"
            }
        }
        (Json::Str(a), Json::Str(b)) if leaf_key == "verdict" => {
            match (a.as_str(), b.as_str()) {
                (_, "fail") => "regression",
                ("fail", "pass") => "improvement",
                _ => "neutral",
            }
        }
        (Json::Bool(a), Json::Bool(b)) if leaf_key == "overall_pass" => {
            if *a && !*b {
                "regression"
            } else if !*a && *b {
                "improvement"
            } else {
                "neutral"
            }
        }
        _ => "neutral",
    }
}

/// Last path segment without any `[i]` index (the classifier key).
fn leaf_key(path: &str) -> &str {
    let last = path.rsplit('.').next().unwrap_or(path);
    match last.find('[') {
        Some(i) => &last[..i],
        None => last,
    }
}

fn walk(path: &str, baseline: &Json, candidate: &Json, out: &mut Vec<DiffEntry>) {
    match (baseline, candidate) {
        (Json::Obj(a), Json::Obj(b)) => {
            // Candidate key order first, then keys only the baseline has.
            for (k, bv) in b {
                let sub = if path.is_empty() { k.clone() } else { format!("{path}.{k}") };
                match a.iter().find(|(ak, _)| ak == k) {
                    Some((_, av)) => walk(&sub, av, bv, out),
                    None => push(out, &sub, Json::Null, bv.clone()),
                }
            }
            for (k, av) in a {
                if !b.iter().any(|(bk, _)| bk == k) {
                    let sub = if path.is_empty() { k.clone() } else { format!("{path}.{k}") };
                    push(out, &sub, av.clone(), Json::Null);
                }
            }
        }
        (Json::Arr(a), Json::Arr(b)) => {
            for (i, (av, bv)) in a.iter().zip(b).enumerate() {
                walk(&format!("{path}[{i}]"), av, bv, out);
            }
            if a.len() != b.len() {
                push(
                    out,
                    &format!("{path}.len"),
                    Json::Num(a.len() as f64),
                    Json::Num(b.len() as f64),
                );
            }
        }
        _ => {
            if baseline != candidate {
                push(out, path, baseline.clone(), candidate.clone());
            }
        }
    }
}

fn push(out: &mut Vec<DiffEntry>, path: &str, baseline: Json, candidate: Json) {
    let class = classify(leaf_key(path), &baseline, &candidate);
    out.push(DiffEntry { path: path.to_string(), baseline, candidate, class });
}

/// Diff two analyze-report documents (baseline vs candidate).
pub fn diff_reports(baseline: &Json, candidate: &Json) -> DiffReport {
    let mut changes = Vec::new();
    walk("", baseline, candidate, &mut changes);
    let regressions = changes.iter().filter(|c| c.class == "regression").count() as u64;
    let improvements = changes.iter().filter(|c| c.class == "improvement").count() as u64;
    DiffReport { changes, regressions, improvements }
}

impl DiffEntry {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("path", Json::str(&self.path)),
            ("baseline", self.baseline.clone()),
            ("candidate", self.candidate.clone()),
            ("class", Json::str(self.class)),
        ])
    }
}

impl DiffReport {
    /// True iff the two reports were byte-equivalent.
    pub fn is_empty(&self) -> bool {
        self.changes.is_empty()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("format", Json::str("smartsplit-analyze-diff")),
            ("schema_version", Json::Num(ANALYZE_SCHEMA_VERSION as f64)),
            ("empty", Json::Bool(self.is_empty())),
            ("changed", Json::Num(self.changes.len() as f64)),
            ("regressions", Json::Num(self.regressions as f64)),
            ("improvements", Json::Num(self.improvements as f64)),
            ("changes", Json::Arr(self.changes.iter().map(DiffEntry::to_json).collect())),
        ])
    }

    pub fn print(&self) {
        if self.is_empty() {
            println!("-- diff: reports are identical --");
            return;
        }
        println!(
            "-- diff: {} changed leaves ({} regressions, {} improvements) --",
            self.changes.len(),
            self.regressions,
            self.improvements,
        );
        for c in &self.changes {
            println!("[{:<11}] {}: {} -> {}", c.class, c.path, c.baseline, c.candidate);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(p99: f64, verdict: &str, completed: u64) -> Json {
        Json::obj(vec![
            ("format", Json::str("smartsplit-analyze")),
            (
                "attribution",
                Json::obj(vec![(
                    "overall",
                    Json::obj(vec![(
                        "latency",
                        Json::obj(vec![
                            ("p99_s", Json::Num(p99)),
                            ("completed", Json::Num(completed as f64)),
                        ]),
                    )]),
                )]),
            ),
            (
                "slos",
                Json::Arr(vec![Json::obj(vec![("verdict", Json::str(verdict))])]),
            ),
        ])
    }

    #[test]
    fn self_diff_is_exactly_empty() {
        let r = report(1.5, "pass", 100);
        let d = diff_reports(&r, &r);
        assert!(d.is_empty());
        assert_eq!((d.regressions, d.improvements), (0, 0));
        let j = d.to_json();
        assert_eq!(j.get("empty").unwrap(), &Json::Bool(true));
        assert_eq!(j.get("changed").unwrap(), &Json::Num(0.0));
    }

    #[test]
    fn directional_classification() {
        let d = diff_reports(&report(1.5, "pass", 100), &report(2.5, "fail", 90));
        assert_eq!(d.changes.len(), 3);
        assert_eq!(d.regressions, 3);
        let paths: Vec<&str> = d.changes.iter().map(|c| c.path.as_str()).collect();
        assert!(paths.contains(&"attribution.overall.latency.p99_s"));
        assert!(paths.contains(&"slos[0].verdict"));
        // And the reverse direction is all improvements.
        let back = diff_reports(&report(2.5, "fail", 90), &report(1.5, "pass", 100));
        assert_eq!(back.improvements, 3);
        assert_eq!(back.regressions, 0);
    }

    #[test]
    fn added_and_removed_keys_and_length_changes_surface() {
        let a = Json::obj(vec![("x", Json::Num(1.0)), ("gone", Json::Bool(true))]);
        let b = Json::obj(vec![
            ("x", Json::Num(1.0)),
            ("added", Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)])),
        ]);
        let d = diff_reports(&a, &b);
        let paths: Vec<&str> = d.changes.iter().map(|c| c.path.as_str()).collect();
        assert_eq!(paths, vec!["added", "gone"]);
        let arr_len = diff_reports(
            &Json::Arr(vec![Json::Num(1.0)]),
            &Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)]),
        );
        assert_eq!(arr_len.changes[0].path, ".len");
    }

    #[test]
    fn leaf_key_strips_indices() {
        assert_eq!(leaf_key("slos[0].verdict"), "verdict");
        assert_eq!(leaf_key("attribution.by_site[2].latency.p99_s"), "p99_s");
        assert_eq!(leaf_key("changes[3]"), "changes");
        assert_eq!(leaf_key("top"), "top");
    }
}
