//! Declarative SLO audit and per-fault impact attribution
//! (DESIGN.md §14).
//!
//! An SLO is a one-liner like `p99<2.5s` or `drop<0.1%` ([`Slo::parse`]
//! documents the grammar). Each SLO is judged twice: once against the
//! exact overall statistics of the run, and once per time-series window
//! with burn accounting — how many windows violated, for how much
//! virtual time, in how long a streak. The fault audit pairs PR 7's
//! `Fault` edges into intervals and charges each one with what happened
//! causally inside it: reroutes, completions, the latency tax over the
//! calm-run baseline, and (at window resolution) drops.

use std::collections::BTreeMap;

use crate::util::json::Json;

use super::attribution::quantile_idx;
use super::RunData;

/// What an SLO measures. Latency metrics are in seconds; `Drop` is the
/// dropped/generated fraction in `[0, 1]`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SloMetric {
    P50,
    P95,
    P99,
    Mean,
    Max,
    Drop,
}

impl SloMetric {
    pub fn name(self) -> &'static str {
        match self {
            SloMetric::P50 => "p50",
            SloMetric::P95 => "p95",
            SloMetric::P99 => "p99",
            SloMetric::Mean => "mean",
            SloMetric::Max => "max",
            SloMetric::Drop => "drop",
        }
    }
}

/// The comparison an SLO asserts (`value op threshold` must hold).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SloOp {
    Lt,
    Le,
    Gt,
    Ge,
}

impl SloOp {
    pub fn symbol(self) -> &'static str {
        match self {
            SloOp::Lt => "<",
            SloOp::Le => "<=",
            SloOp::Gt => ">",
            SloOp::Ge => ">=",
        }
    }
}

/// A parsed `--slo` clause.
#[derive(Clone, Debug)]
pub struct Slo {
    /// The clause as the user wrote it (echoed in reports).
    pub raw: String,
    pub metric: SloMetric,
    pub op: SloOp,
    /// Seconds for latency metrics, a `[0, 1]` fraction for `drop`.
    pub threshold: f64,
}

fn grammar_error(raw: &str, detail: &str) -> String {
    format!(
        "bad SLO {raw:?}: {detail} — grammar is <metric><op><value>[unit] with \
         metric ∈ p50|p95|p99|mean|max|drop, op ∈ <|<=|>|>=, \
         unit ∈ ms|s for latency or % for drop; e.g. \"p99<2.5s\", \"drop<0.1%\""
    )
}

impl Slo {
    /// Parse one clause; the error message teaches the grammar.
    pub fn parse(raw: &str) -> Result<Slo, String> {
        let s = raw.trim();
        const METRICS: [(&str, SloMetric); 6] = [
            ("p50", SloMetric::P50),
            ("p95", SloMetric::P95),
            ("p99", SloMetric::P99),
            ("mean", SloMetric::Mean),
            ("max", SloMetric::Max),
            ("drop", SloMetric::Drop),
        ];
        let (name, metric) = METRICS
            .iter()
            .find(|(n, _)| s.starts_with(n))
            .ok_or_else(|| grammar_error(raw, "unknown metric"))?;
        let rest = s[name.len()..].trim_start();
        let (op, rest) = if let Some(r) = rest.strip_prefix("<=") {
            (SloOp::Le, r)
        } else if let Some(r) = rest.strip_prefix(">=") {
            (SloOp::Ge, r)
        } else if let Some(r) = rest.strip_prefix('<') {
            (SloOp::Lt, r)
        } else if let Some(r) = rest.strip_prefix('>') {
            (SloOp::Gt, r)
        } else {
            return Err(grammar_error(raw, "missing comparison operator"));
        };
        let body = rest.trim();
        let (num, unit) = if let Some(v) = body.strip_suffix("ms") {
            (v, "ms")
        } else if let Some(v) = body.strip_suffix('s') {
            (v, "s")
        } else if let Some(v) = body.strip_suffix('%') {
            (v, "%")
        } else {
            (body, "")
        };
        let value: f64 = num
            .trim()
            .parse()
            .map_err(|_| grammar_error(raw, "threshold is not a number"))?;
        if !value.is_finite() || value < 0.0 {
            return Err(grammar_error(raw, "threshold must be finite and >= 0"));
        }
        let threshold = match (*metric, unit) {
            (SloMetric::Drop, "%") => value / 100.0,
            (SloMetric::Drop, "") => value,
            (SloMetric::Drop, _) => {
                return Err(grammar_error(raw, "drop takes % or a bare fraction, not a time unit"))
            }
            (_, "ms") => value / 1000.0,
            (_, "s") | (_, "") => value,
            (_, "%") => return Err(grammar_error(raw, "% only applies to drop")),
        };
        Ok(Slo { raw: s.to_string(), metric: *metric, op, threshold })
    }

    /// Does `value` satisfy the clause?
    pub fn holds(&self, value: f64) -> bool {
        match self.op {
            SloOp::Lt => value < self.threshold,
            SloOp::Le => value <= self.threshold,
            SloOp::Gt => value > self.threshold,
            SloOp::Ge => value >= self.threshold,
        }
    }
}

/// Verdict of one SLO clause over one run.
#[derive(Clone, Debug)]
pub struct SloOutcome {
    pub slo: Slo,
    /// The run-level metric value (exact order statistics when the
    /// trace is present; the worst evaluated window otherwise — a
    /// conservative window-resolution stand-in, see [`audit`]).
    pub overall_value: f64,
    pub overall_pass: bool,
    pub windows_total: u64,
    /// Windows that carried enough traffic to be judged (latency
    /// clauses need completions, drop clauses need arrivals).
    pub windows_evaluated: u64,
    pub windows_violating: u64,
    /// Virtual time spent inside violating windows.
    pub violation_time_s: f64,
    /// Longest run of consecutive violating windows (idle windows
    /// neither extend nor break a streak — a traffic gap should not
    /// clear a burn).
    pub longest_streak: u64,
    pub first_violation_s: Option<f64>,
    /// `violation_time_s` over total evaluated window time.
    pub burn_fraction: f64,
    /// `"pass"` iff the overall value passes and no window violated.
    pub verdict: &'static str,
}

/// Per-window value of a clause; `None` when the window carries no
/// signal for it.
fn window_value(slo: &Slo, w: &super::WindowStats) -> Option<f64> {
    if slo.metric == SloMetric::Drop {
        if w.generated == 0 {
            return None;
        }
        return Some(w.dropped as f64 / w.generated as f64);
    }
    if w.completed == 0 {
        return None;
    }
    Some(match slo.metric {
        SloMetric::P50 => w.p50_s,
        SloMetric::P95 => w.p95_s,
        SloMetric::P99 => w.p99_s,
        SloMetric::Mean => w.mean_s,
        SloMetric::Max => w.max_s,
        SloMetric::Drop => unreachable!("handled above"),
    })
}

/// The run-level value of a clause: exact order statistics over the
/// traced requests when available, else the worst evaluated window.
fn overall_value(slo: &Slo, data: &RunData) -> f64 {
    if slo.metric == SloMetric::Drop {
        return data.drop_rate();
    }
    if !data.requests.is_empty() {
        let mut lats: Vec<f64> = data.requests.iter().map(super::ReqRecord::latency_s).collect();
        lats.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let n = lats.len();
        return match slo.metric {
            SloMetric::P50 => lats[quantile_idx(n, 0.50)],
            SloMetric::P95 => lats[quantile_idx(n, 0.95)],
            SloMetric::P99 => lats[quantile_idx(n, 0.99)],
            SloMetric::Mean => lats.iter().sum::<f64>() / n as f64,
            SloMetric::Max => lats[n - 1],
            SloMetric::Drop => unreachable!(),
        };
    }
    // Metrics-only input: take the worst window (conservative — an SLO
    // that passes every window passes this too).
    data.windows
        .iter()
        .filter_map(|w| window_value(slo, w))
        .fold(0.0f64, f64::max)
}

/// Judge every clause (see [`SloOutcome`]).
pub fn audit(data: &RunData, slos: &[Slo]) -> Vec<SloOutcome> {
    slos.iter()
        .map(|slo| {
            let overall = overall_value(slo, data);
            let overall_pass = slo.holds(overall);
            let mut evaluated = 0u64;
            let mut violating = 0u64;
            let mut violation_time_s = 0.0f64;
            let mut evaluated_time_s = 0.0f64;
            let mut streak = 0u64;
            let mut longest_streak = 0u64;
            let mut first_violation_s = None;
            for w in &data.windows {
                let Some(v) = window_value(slo, w) else { continue };
                evaluated += 1;
                evaluated_time_s += w.end_s - w.start_s;
                if slo.holds(v) {
                    streak = 0;
                } else {
                    violating += 1;
                    violation_time_s += w.end_s - w.start_s;
                    streak += 1;
                    longest_streak = longest_streak.max(streak);
                    if first_violation_s.is_none() {
                        first_violation_s = Some(w.start_s);
                    }
                }
            }
            SloOutcome {
                slo: slo.clone(),
                overall_value: overall,
                overall_pass,
                windows_total: data.windows.len() as u64,
                windows_evaluated: evaluated,
                windows_violating: violating,
                violation_time_s,
                longest_streak,
                first_violation_s,
                burn_fraction: if evaluated_time_s > 0.0 {
                    violation_time_s / evaluated_time_s
                } else {
                    0.0
                },
                verdict: if overall_pass && violating == 0 { "pass" } else { "fail" },
            }
        })
        .collect()
}

impl SloOutcome {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("slo", Json::str(&self.slo.raw)),
            ("metric", Json::str(self.slo.metric.name())),
            ("op", Json::str(self.slo.op.symbol())),
            ("threshold", Json::Num(self.slo.threshold)),
            ("overall_value", Json::Num(self.overall_value)),
            ("overall_pass", Json::Bool(self.overall_pass)),
            ("windows_total", Json::Num(self.windows_total as f64)),
            ("windows_evaluated", Json::Num(self.windows_evaluated as f64)),
            ("windows_violating", Json::Num(self.windows_violating as f64)),
            ("violation_time_s", Json::Num(self.violation_time_s)),
            ("longest_streak", Json::Num(self.longest_streak as f64)),
            (
                "first_violation_s",
                match self.first_violation_s {
                    Some(t) => Json::Num(t),
                    None => Json::Null,
                },
            ),
            ("burn_fraction", Json::Num(self.burn_fraction)),
            ("verdict", Json::str(self.verdict)),
        ])
    }

    pub fn print(&self) {
        println!(
            "{:<16} {:<4} value={:.6} threshold={:.6} windows={}/{} violating \
             (burn {:.1}%, longest streak {})",
            self.slo.raw,
            self.verdict.to_uppercase(),
            self.overall_value,
            self.slo.threshold,
            self.windows_violating,
            self.windows_evaluated,
            100.0 * self.burn_fraction,
            self.longest_streak,
        );
    }
}

/// What one fault interval cost. `latency_tax_s` is the mean latency of
/// completions inside the interval minus the calm baseline (negative
/// when the interval was calmer than baseline). Drops are charged at
/// window resolution — the finest the metrics plane records — so
/// `dropped_in_windows` sums windows *overlapping* the interval and is
/// `None` without a time series.
#[derive(Clone, Debug)]
pub struct FaultImpact {
    /// The opening edge's kind (`site_down`, `backhaul_degrade`,
    /// `flash_crowd_start`).
    pub kind: String,
    pub site: u32,
    pub start_s: f64,
    /// Close edge time; the run horizon when the fault never lifted.
    pub end_s: f64,
    /// `Failover` reroutes off this site inside the interval.
    pub reroutes: u64,
    pub completions_in: u64,
    pub mean_latency_in_s: f64,
    pub latency_tax_s: f64,
    pub dropped_in_windows: Option<u64>,
}

/// The fault block of an analyze report.
#[derive(Clone, Debug, Default)]
pub struct FaultAudit {
    /// Mean latency of completions outside every fault interval.
    pub baseline_mean_latency_s: f64,
    pub baseline_completions: u64,
    /// Paired intervals, ordered by (start, site, kind).
    pub intervals: Vec<FaultImpact>,
}

/// Fault-edge families: the opening kind and its closing kind.
const FAULT_FAMILIES: [(&str, &str); 3] = [
    ("site_down", "site_up"),
    ("backhaul_degrade", "backhaul_restore"),
    ("flash_crowd_start", "flash_crowd_end"),
];

/// Pair fault edges into intervals and charge each with its causal
/// impact (see [`FaultImpact`]).
pub fn fault_impact(data: &RunData) -> FaultAudit {
    // Pair open/close edges per (family, site); record order is
    // time-ordered, so a simple open-slot map suffices.
    let mut open: BTreeMap<(usize, u32), f64> = BTreeMap::new();
    let mut intervals: Vec<(usize, u32, f64, f64)> = Vec::new();
    for f in &data.faults {
        if let Some(fam) = FAULT_FAMILIES.iter().position(|(start, _)| *start == f.kind) {
            open.insert((fam, f.site), f.t_s);
        } else if let Some(fam) = FAULT_FAMILIES.iter().position(|(_, end)| *end == f.kind) {
            if let Some(start) = open.remove(&(fam, f.site)) {
                intervals.push((fam, f.site, start, f.t_s));
            }
        }
    }
    for ((fam, site), start) in open {
        intervals.push((fam, site, start, data.horizon_s));
    }
    intervals.sort_by(|a, b| {
        a.2.partial_cmp(&b.2)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.1.cmp(&b.1))
            .then(a.0.cmp(&b.0))
    });

    // Baseline: completions causally outside every interval.
    let inside =
        |t: f64| intervals.iter().any(|&(_, _, s, e)| t >= s && t < e);
    let mut baseline_sum = 0.0f64;
    let mut baseline_n = 0u64;
    for r in &data.requests {
        if !inside(r.completed_s) {
            baseline_sum += r.latency_s();
            baseline_n += 1;
        }
    }
    let baseline_mean = if baseline_n > 0 { baseline_sum / baseline_n as f64 } else { 0.0 };

    let impacts = intervals
        .iter()
        .map(|&(fam, site, start, end)| {
            let mut sum = 0.0f64;
            let mut n = 0u64;
            for r in &data.requests {
                if r.completed_s >= start && r.completed_s < end {
                    sum += r.latency_s();
                    n += 1;
                }
            }
            let mean_in = if n > 0 { sum / n as f64 } else { 0.0 };
            let reroutes = data
                .failovers
                .iter()
                .filter(|fo| fo.from_site == site && fo.t_s >= start && fo.t_s < end)
                .count() as u64;
            let dropped_in_windows = if data.windows.is_empty() {
                None
            } else {
                Some(
                    data.windows
                        .iter()
                        .filter(|w| w.start_s < end && w.end_s > start)
                        .map(|w| w.dropped)
                        .sum(),
                )
            };
            FaultImpact {
                kind: FAULT_FAMILIES[fam].0.to_string(),
                site,
                start_s: start,
                end_s: end,
                reroutes,
                completions_in: n,
                mean_latency_in_s: mean_in,
                latency_tax_s: if n > 0 { mean_in - baseline_mean } else { 0.0 },
                dropped_in_windows,
            }
        })
        .collect();

    FaultAudit {
        baseline_mean_latency_s: baseline_mean,
        baseline_completions: baseline_n,
        intervals: impacts,
    }
}

impl FaultImpact {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::str(&self.kind)),
            ("site", Json::Num(self.site as f64)),
            ("start_s", Json::Num(self.start_s)),
            ("end_s", Json::Num(self.end_s)),
            ("reroutes", Json::Num(self.reroutes as f64)),
            ("completions_in", Json::Num(self.completions_in as f64)),
            ("mean_latency_in_s", Json::Num(self.mean_latency_in_s)),
            ("latency_tax_s", Json::Num(self.latency_tax_s)),
            (
                "dropped_in_windows",
                match self.dropped_in_windows {
                    Some(d) => Json::Num(d as f64),
                    None => Json::Null,
                },
            ),
        ])
    }
}

impl FaultAudit {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("baseline_mean_latency_s", Json::Num(self.baseline_mean_latency_s)),
            ("baseline_completions", Json::Num(self.baseline_completions as f64)),
            ("intervals", Json::Arr(self.intervals.iter().map(FaultImpact::to_json).collect())),
        ])
    }

    pub fn print(&self) {
        if self.intervals.is_empty() {
            return;
        }
        println!(
            "-- fault impact (baseline mean {:.4}s over {} calm completions) --",
            self.baseline_mean_latency_s, self.baseline_completions
        );
        for i in &self.intervals {
            println!(
                "{:<18} site {} [{:.1}s, {:.1}s): {} reroutes, {} completions, \
                 latency tax {:+.4}s{}",
                i.kind,
                i.site,
                i.start_s,
                i.end_s,
                i.reroutes,
                i.completions_in,
                i.latency_tax_s,
                match i.dropped_in_windows {
                    Some(d) => format!(", {d} dropped in overlapping windows"),
                    None => String::new(),
                },
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{FailoverNote, FaultNote, ReqRecord, WindowStats};
    use super::*;

    fn req(id: u64, t0: f64, lat: f64) -> ReqRecord {
        let mut shares = [0.0; 9];
        shares[1] = lat;
        ReqRecord { req: id, device: 0, issued_s: t0, completed_s: t0 + lat, shares, site: None }
    }

    #[test]
    fn grammar_accepts_the_documented_forms() {
        let s = Slo::parse("p99<2.5s").unwrap();
        assert_eq!((s.metric, s.op), (SloMetric::P99, SloOp::Lt));
        assert_eq!(s.threshold, 2.5);
        assert_eq!(Slo::parse("mean<=250ms").unwrap().threshold, 0.25);
        assert_eq!(Slo::parse("drop<0.1%").unwrap().threshold, 0.001);
        assert_eq!(Slo::parse("drop<0.05").unwrap().threshold, 0.05);
        assert_eq!(Slo::parse(" max < 10 ").unwrap().threshold, 10.0);
        assert_eq!(Slo::parse("p50>=1").unwrap().op, SloOp::Ge);
    }

    #[test]
    fn grammar_rejections_teach_the_grammar() {
        for bad in ["p42<1", "p99=1", "p99<abc", "drop<5ms", "p99<5%", "p99<-1", "p99<inf"] {
            let err = Slo::parse(bad).unwrap_err();
            assert!(err.contains("grammar"), "{bad}: {err}");
        }
    }

    #[test]
    fn audit_counts_burn_and_streaks() {
        let mut d = RunData::default();
        // Four 10s windows with p99 = 1, 3, 3, 1 against p99<2.5s:
        // windows 1 and 2 violate (streak 2), 20s of 40s burn.
        for (i, p99) in [1.0, 3.0, 3.0, 1.0].into_iter().enumerate() {
            d.windows.push(WindowStats {
                index: i as u64,
                start_s: 10.0 * i as f64,
                end_s: 10.0 * (i + 1) as f64,
                generated: 10,
                completed: 10,
                p50_s: p99,
                p95_s: p99,
                p99_s: p99,
                mean_s: p99,
                max_s: p99,
                ..WindowStats::default()
            });
        }
        let out = audit(&d, &[Slo::parse("p99<2.5s").unwrap()]);
        assert_eq!(out.len(), 1);
        let o = &out[0];
        assert_eq!(o.windows_evaluated, 4);
        assert_eq!(o.windows_violating, 2);
        assert_eq!(o.longest_streak, 2);
        assert_eq!(o.first_violation_s, Some(10.0));
        assert!((o.violation_time_s - 20.0).abs() < 1e-12);
        assert!((o.burn_fraction - 0.5).abs() < 1e-12);
        assert_eq!(o.verdict, "fail");
        // overall (worst window, no requests attached) = 3.0.
        assert_eq!(o.overall_value, 3.0);
    }

    #[test]
    fn overall_uses_exact_request_stats_when_traced() {
        let mut d = RunData::default();
        for i in 0..100 {
            d.requests.push(req(i, i as f64, if i < 99 { 1.0 } else { 9.0 }));
        }
        let out = audit(&d, &[Slo::parse("p95<2s").unwrap(), Slo::parse("max<2s").unwrap()]);
        assert_eq!(out[0].overall_value, 1.0);
        assert_eq!(out[0].verdict, "pass");
        assert_eq!(out[1].overall_value, 9.0);
        assert_eq!(out[1].verdict, "fail");
    }

    #[test]
    fn drop_clause_reads_totals() {
        let mut d = RunData::default();
        d.generated = Some(1000);
        d.dropped = Some(5);
        let out = audit(&d, &[Slo::parse("drop<1%").unwrap()]);
        assert_eq!(out[0].overall_value, 0.005);
        assert_eq!(out[0].verdict, "pass");
    }

    #[test]
    fn fault_intervals_pair_charge_and_close_at_horizon() {
        let mut d = RunData::default();
        d.horizon_s = 100.0;
        d.faults = vec![
            FaultNote { t_s: 20.0, kind: "site_down".into(), site: 1, value: 0.0 },
            FaultNote { t_s: 40.0, kind: "site_up".into(), site: 1, value: 0.0 },
            FaultNote { t_s: 50.0, kind: "backhaul_degrade".into(), site: 0, value: 0.25 },
            // never restored → closes at the horizon
        ];
        d.failovers = vec![
            FailoverNote { t_s: 21.0, req: 5, device: 2, from_site: 1 },
            FailoverNote { t_s: 45.0, req: 9, device: 2, from_site: 1 }, // outside
        ];
        // Calm completions at latency 1.0, in-outage completions at 3.0.
        d.requests.push(req(0, 5.0, 1.0));
        d.requests.push(req(1, 10.0, 1.0));
        d.requests.push(req(2, 22.0, 3.0));
        let audit = fault_impact(&d);
        assert_eq!(audit.baseline_completions, 2);
        assert_eq!(audit.baseline_mean_latency_s, 1.0);
        assert_eq!(audit.intervals.len(), 2);
        let outage = &audit.intervals[0];
        assert_eq!((outage.kind.as_str(), outage.site), ("site_down", 1));
        assert_eq!((outage.start_s, outage.end_s), (20.0, 40.0));
        assert_eq!(outage.reroutes, 1);
        assert_eq!(outage.completions_in, 1);
        assert!((outage.latency_tax_s - 2.0).abs() < 1e-12);
        assert_eq!(outage.dropped_in_windows, None);
        let brownout = &audit.intervals[1];
        assert_eq!((brownout.start_s, brownout.end_s), (50.0, 100.0));
        assert_eq!(brownout.completions_in, 0);
        assert_eq!(brownout.latency_tax_s, 0.0);
    }

    #[test]
    fn outcome_json_has_no_nan_even_when_empty() {
        let out = audit(&RunData::default(), &[Slo::parse("p99<1s").unwrap()]);
        let text = out[0].to_json().to_string_pretty();
        assert!(!text.contains("NaN"), "{text}");
        assert_eq!(out[0].verdict, "pass"); // vacuously: no data, 0 < threshold
    }
}
