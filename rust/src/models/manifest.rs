//! Loader for the python-emitted `artifacts/<model>/manifest.json` — the
//! wire contract between the AOT compile path (L1/L2) and the rust runtime
//! (L3). See `python/compile/aot.py` for the writer.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// One weight tensor: raw little-endian f32 on disk.
#[derive(Clone, Debug)]
pub struct WeightMeta {
    pub name: String,
    /// Path relative to the model directory.
    pub file: String,
    pub shape: Vec<usize>,
}

impl WeightMeta {
    pub fn num_elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One layer's manifest entry.
#[derive(Clone, Debug)]
pub struct LayerManifest {
    pub index: usize, // 1-based
    pub kind: String,
    pub in_shape: Vec<usize>,
    pub out_shape: Vec<usize>,
    pub params: u64,
    pub param_bytes: u64,
    pub act_bytes: u64,
    pub flops: u64,
    pub weights: Vec<WeightMeta>,
    /// batch size → HLO path relative to the model dir.
    pub hlo: BTreeMap<usize, String>,
}

/// Whole-model manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub model: String,
    pub dir: PathBuf,
    pub impl_name: String,
    pub seed: u64,
    pub num_layers: usize,
    pub paper_layers: usize,
    pub input_hw: usize,
    pub input_ch: usize,
    pub num_classes: usize,
    pub top1_accuracy: f64,
    pub total_params: u64,
    pub batches: Vec<usize>,
    pub layers: Vec<LayerManifest>,
}

impl Manifest {
    /// Load `artifacts_dir/<model>/manifest.json`.
    pub fn load(artifacts_dir: &Path, model: &str) -> Result<Manifest> {
        let dir = artifacts_dir.join(model);
        let path = dir.join("manifest.json");
        let j = crate::util::json::parse_file(&path)
            .with_context(|| format!("loading manifest {}", path.display()))?;
        Self::from_json(&j, dir)
    }

    pub fn from_json(j: &Json, dir: PathBuf) -> Result<Manifest> {
        let layers = j
            .get("layers")?
            .as_arr()?
            .iter()
            .map(|l| -> Result<LayerManifest> {
                let weights = l
                    .get("weights")?
                    .as_arr()?
                    .iter()
                    .map(|w| -> Result<WeightMeta> {
                        Ok(WeightMeta {
                            name: w.get_str("name")?.to_string(),
                            file: w.get_str("file")?.to_string(),
                            shape: w.get_usize_vec("shape")?,
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
                let mut hlo = BTreeMap::new();
                for (k, v) in l.get("hlo")?.as_obj()? {
                    hlo.insert(
                        k.parse::<usize>().context("hlo batch key")?,
                        v.as_str()?.to_string(),
                    );
                }
                Ok(LayerManifest {
                    index: l.get_usize("index")?,
                    kind: l.get_str("kind")?.to_string(),
                    in_shape: l.get_usize_vec("in_shape")?,
                    out_shape: l.get_usize_vec("out_shape")?,
                    params: l.get_f64("params")? as u64,
                    param_bytes: l.get_f64("param_bytes")? as u64,
                    act_bytes: l.get_f64("act_bytes")? as u64,
                    flops: l.get_f64("flops")? as u64,
                    weights,
                    hlo,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let m = Manifest {
            model: j.get_str("model")?.to_string(),
            dir,
            impl_name: j.get_str("impl")?.to_string(),
            seed: j.get_f64("seed")? as u64,
            num_layers: j.get_usize("num_layers")?,
            paper_layers: j.get_usize("paper_layers")?,
            input_hw: j.get_usize("input_hw")?,
            input_ch: j.get_usize("input_ch")?,
            num_classes: j.get_usize("num_classes")?,
            top1_accuracy: j.get_f64("top1_accuracy")?,
            total_params: j.get_f64("total_params")? as u64,
            batches: j
                .get("batches")?
                .as_arr()?
                .iter()
                .map(|b| b.as_usize())
                .collect::<std::result::Result<Vec<_>, _>>()?,
            layers,
        };
        m.validate()?;
        Ok(m)
    }

    /// Structural invariants every manifest must satisfy.
    pub fn validate(&self) -> Result<()> {
        if self.layers.len() != self.num_layers {
            bail!(
                "manifest {}: {} layer entries but num_layers={}",
                self.model,
                self.layers.len(),
                self.num_layers
            );
        }
        for (i, l) in self.layers.iter().enumerate() {
            if l.index != i + 1 {
                bail!("manifest {}: layer {} has index {}", self.model, i, l.index);
            }
            if i + 1 < self.layers.len() && l.out_shape != self.layers[i + 1].in_shape {
                bail!(
                    "manifest {}: layer {} out {:?} != layer {} in {:?}",
                    self.model, l.index, l.out_shape, l.index + 1,
                    self.layers[i + 1].in_shape
                );
            }
            for b in &self.batches {
                if !l.hlo.contains_key(b) {
                    bail!("manifest {}: layer {} missing hlo for batch {b}", self.model, l.index);
                }
            }
        }
        Ok(())
    }

    /// Absolute path of a layer's HLO for a batch size.
    pub fn hlo_path(&self, index: usize, batch: usize) -> Result<PathBuf> {
        let l = &self.layers[index - 1];
        let rel = l
            .hlo
            .get(&batch)
            .with_context(|| format!("{} layer {index} has no batch-{batch} HLO", self.model))?;
        Ok(self.dir.join(rel))
    }

    /// Absolute path of a weight file.
    pub fn weight_path(&self, w: &WeightMeta) -> PathBuf {
        self.dir.join(&w.file)
    }

    /// List models available under an artifacts dir.
    pub fn available_models(artifacts_dir: &Path) -> Vec<String> {
        let mut out = Vec::new();
        if let Ok(rd) = std::fs::read_dir(artifacts_dir) {
            for e in rd.flatten() {
                if e.path().join("manifest.json").exists() {
                    out.push(e.file_name().to_string_lossy().to_string());
                }
            }
        }
        out.sort();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_json() -> String {
        r#"{
          "model": "tiny", "impl": "pallas", "seed": 0,
          "num_layers": 2, "paper_layers": 2,
          "input_hw": 8, "input_ch": 3, "num_classes": 4,
          "top1_accuracy": 0.5, "total_params": 112, "batches": [1],
          "layers": [
            {"index": 1, "kind": "conv2d", "in_shape": [1,3,8,8],
             "out_shape": [1,4,8,8], "params": 112, "param_bytes": 448,
             "act_bytes": 1024, "flops": 55296,
             "weights": [{"name": "w", "file": "weights/layer_001_w.bin", "shape": [4,3,3,3]}],
             "hlo": {"1": "b1/layer_001.hlo.txt"}},
            {"index": 2, "kind": "relu", "in_shape": [1,4,8,8],
             "out_shape": [1,4,8,8], "params": 0, "param_bytes": 0,
             "act_bytes": 1024, "flops": 256, "weights": [],
             "hlo": {"1": "b1/layer_002.hlo.txt"}}
          ]
        }"#
        .to_string()
    }

    #[test]
    fn parses_toy_manifest() {
        let j = Json::parse(&toy_json()).unwrap();
        let m = Manifest::from_json(&j, PathBuf::from("/tmp/x")).unwrap();
        assert_eq!(m.model, "tiny");
        assert_eq!(m.layers.len(), 2);
        assert_eq!(m.layers[0].weights[0].num_elements(), 108);
        assert_eq!(
            m.hlo_path(2, 1).unwrap(),
            PathBuf::from("/tmp/x/b1/layer_002.hlo.txt")
        );
        assert!(m.hlo_path(1, 8).is_err());
    }

    #[test]
    fn validate_rejects_shape_mismatch() {
        let bad = toy_json().replace("\"in_shape\": [1,4,8,8]", "\"in_shape\": [1,5,8,8]");
        let j = Json::parse(&bad).unwrap();
        assert!(Manifest::from_json(&j, PathBuf::from("/tmp/x")).is_err());
    }

    #[test]
    fn validate_rejects_wrong_index() {
        let bad = toy_json().replace("\"index\": 2", "\"index\": 3");
        let j = Json::parse(&bad).unwrap();
        assert!(Manifest::from_json(&j, PathBuf::from("/tmp/x")).is_err());
    }
}
