//! CNN model descriptors: the layer-spec algebra (shapes, params, FLOPs,
//! the paper's memory quantities), the five-model zoo, and the
//! `manifest.json` loader that binds the rust side to the python AOT
//! artifacts.

pub mod manifest;
pub mod spec;
pub mod zoo;

pub use manifest::{LayerManifest, Manifest, WeightMeta};
pub use spec::{Layer, LayerProfile, ModelProfile, ModelSpec, Shape, DTYPE_BYTES};
