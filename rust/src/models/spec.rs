//! CNN layer-spec algebra: shape propagation, parameter counts, FLOPs and
//! the paper's memory quantities (`M|l1`, `I|l1`) — the rust mirror of
//! `python/compile/specs.py`. The two implementations are cross-checked by
//! the integration test that replays every `manifest.json` through this
//! module (`rust/tests/manifest_crosscheck.rs`).
//!
//! Memory accounting follows the paper's reference [39]:
//! `M_client|l1 = Σ_{i≤l1} (param_bytes_i + act_bytes_i)`,
//! `I|l1 = act_bytes_{l1}` (what must be uploaded at the split).

pub const DTYPE_BYTES: u64 = 4; // f32 end to end

/// One paper "layer" (torchvision module granularity).
#[derive(Clone, Debug, PartialEq)]
pub enum Layer {
    Conv2d {
        in_ch: usize,
        out_ch: usize,
        kernel: usize,
        stride: usize,
        padding: usize,
        bias: bool,
        folded_bn: bool,
    },
    ReLU,
    ReLU6,
    MaxPool2d { kernel: usize, stride: usize },
    AdaptiveAvgPool2d { out_hw: usize },
    Dropout,
    Linear { in_features: usize, out_features: usize, bias: bool, global_pool: bool },
    InvertedResidual { in_ch: usize, out_ch: usize, stride: usize, expand_ratio: usize },
}

/// Tensor shape: `[N, C, H, W]` through the conv trunk, `[N, F]` after a
/// Linear.
pub type Shape = Vec<usize>;

impl Layer {
    pub fn kind(&self) -> &'static str {
        match self {
            Layer::Conv2d { .. } => "conv2d",
            Layer::ReLU => "relu",
            Layer::ReLU6 => "relu6",
            Layer::MaxPool2d { .. } => "maxpool2d",
            Layer::AdaptiveAvgPool2d { .. } => "adaptiveavgpool2d",
            Layer::Dropout => "dropout",
            Layer::Linear { .. } => "linear",
            Layer::InvertedResidual { .. } => "inverted_residual",
        }
    }

    /// `(H + 2P - K) / S + 1`
    pub fn conv_out_hw(h: usize, kernel: usize, stride: usize, padding: usize) -> usize {
        (h + 2 * padding - kernel) / stride + 1
    }

    pub fn out_shape(&self, input: &Shape) -> Shape {
        match self {
            Layer::Conv2d { in_ch, out_ch, kernel, stride, padding, .. } => {
                assert_eq!(input[1], *in_ch, "conv channel mismatch");
                let oh = Self::conv_out_hw(input[2], *kernel, *stride, *padding);
                let ow = Self::conv_out_hw(input[3], *kernel, *stride, *padding);
                vec![input[0], *out_ch, oh, ow]
            }
            Layer::ReLU | Layer::ReLU6 | Layer::Dropout => input.clone(),
            Layer::MaxPool2d { kernel, stride } => {
                let oh = Self::conv_out_hw(input[2], *kernel, *stride, 0);
                let ow = Self::conv_out_hw(input[3], *kernel, *stride, 0);
                vec![input[0], input[1], oh, ow]
            }
            Layer::AdaptiveAvgPool2d { out_hw } => {
                vec![input[0], input[1], *out_hw, *out_hw]
            }
            Layer::Linear { in_features, out_features, global_pool, .. } => {
                let f = if input.len() == 4 && *global_pool {
                    input[1]
                } else {
                    input[1..].iter().product()
                };
                assert_eq!(f, *in_features, "linear feature mismatch");
                vec![input[0], *out_features]
            }
            Layer::InvertedResidual { in_ch, out_ch, stride, .. } => {
                assert_eq!(input[1], *in_ch, "block channel mismatch");
                let oh = Self::conv_out_hw(input[2], 3, *stride, 1);
                let ow = Self::conv_out_hw(input[3], 3, *stride, 1);
                vec![input[0], *out_ch, oh, ow]
            }
        }
    }

    pub fn param_count(&self) -> u64 {
        match self {
            Layer::Conv2d { in_ch, out_ch, kernel, bias, folded_bn, .. } => {
                let mut n = (out_ch * in_ch * kernel * kernel) as u64;
                if *bias {
                    n += *out_ch as u64;
                }
                if *folded_bn {
                    n += 2 * *out_ch as u64;
                }
                n
            }
            Layer::Linear { in_features, out_features, bias, .. } => {
                let mut n = (in_features * out_features) as u64;
                if *bias {
                    n += *out_features as u64;
                }
                n
            }
            Layer::InvertedResidual { in_ch, out_ch, expand_ratio, .. } => {
                let hid = in_ch * expand_ratio;
                let mut n = 0u64;
                if *expand_ratio != 1 {
                    n += (in_ch * hid + 2 * hid) as u64;
                }
                n += (hid * 9 + 2 * hid) as u64;
                n += (hid * out_ch + 2 * out_ch) as u64;
                n
            }
            _ => 0,
        }
    }

    /// 2·MAC FLOPs, mirroring `specs.flop_count`.
    pub fn flops(&self, input: &Shape) -> u64 {
        let out = self.out_shape(input);
        let prod = |s: &Shape| s.iter().product::<usize>() as u64;
        match self {
            Layer::Conv2d { in_ch, kernel, .. } => {
                let (n, oc, oh, ow) = (out[0], out[1], out[2], out[3]);
                2 * (n * oc * oh * ow * in_ch * kernel * kernel) as u64
            }
            Layer::Linear { in_features, out_features, global_pool, .. } => {
                let n = input[0] as u64;
                let mut f = 2 * n * (*in_features as u64) * (*out_features as u64);
                if input.len() == 4 && *global_pool {
                    f += prod(input);
                }
                f
            }
            Layer::ReLU | Layer::ReLU6 | Layer::AdaptiveAvgPool2d { .. } => prod(input),
            Layer::MaxPool2d { kernel, .. } => prod(&out) * (kernel * kernel) as u64,
            Layer::InvertedResidual { in_ch, out_ch, expand_ratio, .. } => {
                let (n, h, w) = (input[0] as u64, input[2] as u64, input[3] as u64);
                let (oh, ow) = (out[2] as u64, out[3] as u64);
                let hid = (in_ch * expand_ratio) as u64;
                let mut macs = 0u64;
                if *expand_ratio != 1 {
                    macs += n * h * w * (*in_ch as u64) * hid;
                }
                macs += n * oh * ow * hid * 9;
                macs += n * oh * ow * hid * (*out_ch as u64);
                let mut f = 2 * macs;
                if self.uses_residual() {
                    f += prod(&out);
                }
                f
            }
            Layer::Dropout => 0,
        }
    }

    pub fn uses_residual(&self) -> bool {
        matches!(self, Layer::InvertedResidual { in_ch, out_ch, stride, .. }
                 if *stride == 1 && in_ch == out_ch)
    }
}

/// Whole-model spec plus derived per-layer profile.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    pub layers: Vec<Layer>,
    pub input_hw: usize,
    pub input_ch: usize,
    pub num_classes: usize,
    /// Published ImageNet top-1 (Fig. 10's accuracy axis).
    pub top1_accuracy: f64,
}

/// Per-layer derived quantities at a given batch size.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerProfile {
    /// 1-based index matching the paper's split indices.
    pub index: usize,
    pub kind: &'static str,
    pub in_shape: Shape,
    pub out_shape: Shape,
    pub params: u64,
    pub param_bytes: u64,
    /// Output activation bytes — `I|l` when the split is after this layer.
    pub act_bytes: u64,
    pub flops: u64,
}

/// A fully analysed model: the single source the perf model, optimiser and
/// coordinator all consume.
#[derive(Clone, Debug)]
pub struct ModelProfile {
    pub name: String,
    pub num_layers: usize,
    pub batch: usize,
    pub top1_accuracy: f64,
    pub layers: Vec<LayerProfile>,
}

impl ModelSpec {
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn analyze(&self, batch: usize) -> ModelProfile {
        let mut shape: Shape = vec![batch, self.input_ch, self.input_hw, self.input_hw];
        let mut layers = Vec::with_capacity(self.layers.len());
        for (i, layer) in self.layers.iter().enumerate() {
            let out = layer.out_shape(&shape);
            let params = layer.param_count();
            layers.push(LayerProfile {
                index: i + 1,
                kind: layer.kind(),
                in_shape: shape.clone(),
                out_shape: out.clone(),
                params,
                param_bytes: params * DTYPE_BYTES,
                act_bytes: out.iter().product::<usize>() as u64 * DTYPE_BYTES,
                flops: layer.flops(&shape),
            });
            shape = out;
        }
        ModelProfile {
            name: self.name.clone(),
            num_layers: self.layers.len(),
            batch,
            top1_accuracy: self.top1_accuracy,
            layers,
        }
    }

    pub fn total_params(&self) -> u64 {
        self.layers.iter().map(|l| l.param_count()).sum()
    }
}

impl ModelProfile {
    /// `M_client | l1` in bytes (Eq. 16 / f3).
    pub fn client_memory_bytes(&self, l1: usize) -> u64 {
        self.layers[..l1].iter().map(|l| l.param_bytes + l.act_bytes).sum()
    }

    /// `M_server | l2` in bytes where `l2 = L - l1`.
    pub fn server_memory_bytes(&self, l1: usize) -> u64 {
        self.layers[l1..].iter().map(|l| l.param_bytes + l.act_bytes).sum()
    }

    /// `I | l1` in bytes — the activation shipped at the split.
    pub fn intermediate_bytes(&self, l1: usize) -> u64 {
        assert!((1..=self.num_layers).contains(&l1), "split {l1} out of range");
        self.layers[l1 - 1].act_bytes
    }

    /// Raw input tensor size in bytes — the "intermediate" a COC split
    /// (`l1 = 0`) ships instead of an activation.
    pub fn input_bytes(&self) -> u64 {
        self.layers
            .first()
            .map(|l| l.in_shape.iter().product::<usize>() as u64 * DTYPE_BYTES)
            .unwrap_or(0)
    }

    /// FLOPs of layers `1..=l1`.
    pub fn client_flops(&self, l1: usize) -> u64 {
        self.layers[..l1].iter().map(|l| l.flops).sum()
    }

    /// FLOPs of layers `l1+1..=L`.
    pub fn server_flops(&self, l1: usize) -> u64 {
        self.layers[l1..].iter().map(|l| l.flops).sum()
    }

    pub fn total_flops(&self) -> u64 {
        self.layers.iter().map(|l| l.flops).sum()
    }

    pub fn total_param_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.param_bytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_out_hw_matches_paper_models() {
        assert_eq!(Layer::conv_out_hw(224, 11, 4, 2), 55); // AlexNet conv1
        assert_eq!(Layer::conv_out_hw(224, 3, 1, 1), 224); // VGG conv
        assert_eq!(Layer::conv_out_hw(224, 3, 2, 1), 112); // MobileNet stem
        assert_eq!(Layer::conv_out_hw(55, 3, 2, 0), 27); // AlexNet pool1
    }

    #[test]
    fn conv_shape_and_params() {
        let conv = Layer::Conv2d {
            in_ch: 3, out_ch: 64, kernel: 11, stride: 4, padding: 2,
            bias: true, folded_bn: false,
        };
        assert_eq!(conv.out_shape(&vec![1, 3, 224, 224]), vec![1, 64, 55, 55]);
        assert_eq!(conv.param_count(), 64 * 3 * 11 * 11 + 64);
        assert_eq!(conv.flops(&vec![1, 3, 224, 224]), 2 * 64 * 55 * 55 * 3 * 11 * 11);
    }

    #[test]
    fn linear_implicit_flatten_and_global_pool() {
        let lin = Layer::Linear { in_features: 9216, out_features: 4096, bias: true, global_pool: false };
        assert_eq!(lin.out_shape(&vec![1, 256, 6, 6]), vec![1, 4096]);
        let gp = Layer::Linear { in_features: 1280, out_features: 1000, bias: true, global_pool: true };
        assert_eq!(gp.out_shape(&vec![1, 1280, 7, 7]), vec![1, 1000]);
    }

    #[test]
    fn inverted_residual_rules() {
        let res = Layer::InvertedResidual { in_ch: 16, out_ch: 16, stride: 1, expand_ratio: 6 };
        assert!(res.uses_residual());
        let strided = Layer::InvertedResidual { in_ch: 16, out_ch: 16, stride: 2, expand_ratio: 6 };
        assert!(!strided.uses_residual());
        assert_eq!(strided.out_shape(&vec![1, 16, 56, 56]), vec![1, 16, 28, 28]);
    }

    #[test]
    #[should_panic(expected = "split 0 out of range")]
    fn intermediate_bytes_rejects_zero() {
        let spec = ModelSpec {
            name: "t".into(),
            layers: vec![Layer::ReLU],
            input_hw: 4,
            input_ch: 1,
            num_classes: 2,
            top1_accuracy: 0.0,
        };
        spec.analyze(1).intermediate_bytes(0);
    }

    #[test]
    fn memory_partition_sums_to_total() {
        let spec = crate::models::zoo::alexnet();
        let p = spec.analyze(1);
        let total = p.client_memory_bytes(p.num_layers);
        for l1 in 1..=p.num_layers {
            assert_eq!(p.client_memory_bytes(l1) + p.server_memory_bytes(l1), total);
        }
    }
}
