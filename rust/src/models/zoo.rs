//! The paper's CNN zoo in rust — identical layer sequences to
//! `python/compile/zoo.py` (the manifest cross-check test enforces this).

use super::spec::{Layer, ModelSpec};

fn conv(in_ch: usize, out_ch: usize, kernel: usize, stride: usize, padding: usize) -> Layer {
    Layer::Conv2d { in_ch, out_ch, kernel, stride, padding, bias: true, folded_bn: false }
}

/// AlexNet — 21 layers (paper Table I/II split domain 1..=21).
pub fn alexnet() -> ModelSpec {
    let layers = vec![
        conv(3, 64, 11, 4, 2),
        Layer::ReLU,
        Layer::MaxPool2d { kernel: 3, stride: 2 },
        conv(64, 192, 5, 1, 2),
        Layer::ReLU,
        Layer::MaxPool2d { kernel: 3, stride: 2 },
        conv(192, 384, 3, 1, 1),
        Layer::ReLU,
        conv(384, 256, 3, 1, 1),
        Layer::ReLU,
        conv(256, 256, 3, 1, 1),
        Layer::ReLU,
        Layer::MaxPool2d { kernel: 3, stride: 2 },
        Layer::AdaptiveAvgPool2d { out_hw: 6 },
        Layer::Dropout,
        Layer::Linear { in_features: 256 * 6 * 6, out_features: 4096, bias: true, global_pool: false },
        Layer::ReLU,
        Layer::Dropout,
        Layer::Linear { in_features: 4096, out_features: 4096, bias: true, global_pool: false },
        Layer::ReLU,
        Layer::Linear { in_features: 4096, out_features: 1000, bias: true, global_pool: false },
    ];
    ModelSpec {
        name: "alexnet".into(),
        layers,
        input_hw: 224,
        input_ch: 3,
        num_classes: 1000,
        top1_accuracy: 0.5652,
    }
}

fn vgg(name: &str, cfg: &[i32], top1: f64) -> ModelSpec {
    let mut layers = Vec::new();
    let mut in_ch = 3usize;
    for &v in cfg {
        if v < 0 {
            layers.push(Layer::MaxPool2d { kernel: 2, stride: 2 });
        } else {
            layers.push(conv(in_ch, v as usize, 3, 1, 1));
            layers.push(Layer::ReLU);
            in_ch = v as usize;
        }
    }
    layers.push(Layer::AdaptiveAvgPool2d { out_hw: 7 });
    layers.extend([
        Layer::Dropout,
        Layer::Linear { in_features: 512 * 7 * 7, out_features: 4096, bias: true, global_pool: false },
        Layer::ReLU,
        Layer::Dropout,
        Layer::Linear { in_features: 4096, out_features: 4096, bias: true, global_pool: false },
        Layer::ReLU,
        Layer::Linear { in_features: 4096, out_features: 1000, bias: true, global_pool: false },
    ]);
    ModelSpec {
        name: name.into(),
        layers,
        input_hw: 224,
        input_ch: 3,
        num_classes: 1000,
        top1_accuracy: top1,
    }
}

/// VGG11 — 29 layers.
pub fn vgg11() -> ModelSpec {
    vgg("vgg11", &[64, -1, 128, -1, 256, 256, -1, 512, 512, -1, 512, 512, -1], 0.6902)
}

/// VGG13 — 33 layers.
pub fn vgg13() -> ModelSpec {
    vgg("vgg13", &[64, 64, -1, 128, 128, -1, 256, 256, -1, 512, 512, -1, 512, 512, -1], 0.6992)
}

/// VGG16 — 39 layers.
pub fn vgg16() -> ModelSpec {
    vgg(
        "vgg16",
        &[64, 64, -1, 128, 128, -1, 256, 256, 256, -1, 512, 512, 512, -1, 512, 512, 512, -1],
        0.7159,
    )
}

/// MobileNetV2 — 21 layers (stem + 17 inverted residuals + head + dropout +
/// global-pool linear).
pub fn mobilenet_v2() -> ModelSpec {
    let inverted_cfg: [(usize, usize, usize, usize); 7] = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut layers = vec![Layer::Conv2d {
        in_ch: 3, out_ch: 32, kernel: 3, stride: 2, padding: 1, bias: false, folded_bn: true,
    }];
    let mut in_ch = 32usize;
    for (t, c, n, s) in inverted_cfg {
        for i in 0..n {
            layers.push(Layer::InvertedResidual {
                in_ch,
                out_ch: c,
                stride: if i == 0 { s } else { 1 },
                expand_ratio: t,
            });
            in_ch = c;
        }
    }
    layers.push(Layer::Conv2d {
        in_ch, out_ch: 1280, kernel: 1, stride: 1, padding: 0, bias: false, folded_bn: true,
    });
    layers.push(Layer::Dropout);
    layers.push(Layer::Linear { in_features: 1280, out_features: 1000, bias: true, global_pool: true });
    ModelSpec {
        name: "mobilenet_v2".into(),
        layers,
        input_hw: 224,
        input_ch: 3,
        num_classes: 1000,
        top1_accuracy: 0.7188,
    }
}

/// All paper models by name.
pub fn by_name(name: &str) -> Option<ModelSpec> {
    match name {
        "alexnet" => Some(alexnet()),
        "vgg11" => Some(vgg11()),
        "vgg13" => Some(vgg13()),
        "vgg16" => Some(vgg16()),
        "mobilenet_v2" => Some(mobilenet_v2()),
        _ => None,
    }
}

/// The four split-target models of Tables I/II (MobileNetV2 is the Fig. 10
/// comparison baseline, never split).
pub const SPLIT_MODELS: [&str; 4] = ["alexnet", "vgg11", "vgg13", "vgg16"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_layer_counts() {
        assert_eq!(alexnet().num_layers(), 21);
        assert_eq!(vgg11().num_layers(), 29);
        assert_eq!(vgg13().num_layers(), 33);
        assert_eq!(vgg16().num_layers(), 39);
        assert_eq!(mobilenet_v2().num_layers(), 21);
    }

    #[test]
    fn published_param_counts() {
        assert_eq!(alexnet().total_params(), 61_100_840);
        assert_eq!(vgg11().total_params(), 132_863_336);
        assert_eq!(vgg13().total_params(), 133_047_848);
        assert_eq!(vgg16().total_params(), 138_357_544);
        let m = mobilenet_v2().total_params() as f64;
        assert!((m - 3_504_872.0).abs() / 3_504_872.0 < 0.01);
    }

    #[test]
    fn shapes_chain_to_logits() {
        for name in ["alexnet", "vgg11", "vgg13", "vgg16", "mobilenet_v2"] {
            let p = by_name(name).unwrap().analyze(1);
            assert_eq!(p.layers.last().unwrap().out_shape, vec![1, 1000], "{name}");
            for w in p.layers.windows(2) {
                assert_eq!(w[0].out_shape, w[1].in_shape, "{name}");
            }
        }
    }

    #[test]
    fn by_name_unknown_is_none() {
        assert!(by_name("resnet50").is_none());
    }

    #[test]
    fn vgg16_flops_magnitude() {
        let p = vgg16().analyze(1);
        let total = p.total_flops() as f64;
        assert!(total > 29e9 && total < 33e9, "vgg16 flops {total}");
    }
}
