//! Top-level coordinator: the piece a deployment actually drives.
//!
//! Responsibilities:
//! 1. run SmartSplit (or any §VI-C baseline) against the current device /
//!    network conditions to pick the split;
//! 2. stand up the split topology (cloud daemon + device client + router);
//! 3. serve workloads, collecting latency / energy / memory metrics;
//! 4. **adapt**: watch the link bandwidth and re-run the optimiser when it
//!    drifts, moving the split on the live system (the knob the paper's
//!    conclusion calls out: "network bandwidth is a crucial parameter").

pub mod battery;
pub mod fleet;

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::battery::BatteryBand;
use crate::device::{profiles, ComputeProfile};
use crate::metrics::{Histogram, ThroughputMeter};
use crate::models::zoo;
use crate::netsim::{BandwidthTrace, Link};
use crate::optimizer::{Nsga2Params, SplitDecision};
use crate::planner::{PlanOutcome, PlanRequest, Planner, PlannerConfig, Strategy};
use crate::runtime::Tensor;
use crate::serve::{CloudServer, DeviceClient, Router, RouterConfig};
use crate::workload::{synth_images, Request};

/// Coordinator configuration (CLI-mappable).
#[derive(Clone, Debug)]
pub struct Config {
    pub artifacts_dir: PathBuf,
    pub model: String,
    pub batch: usize,
    pub device_profile: &'static ComputeProfile,
    pub bandwidth_mbps: f64,
    /// Planning strategy ([`crate::planner::Strategy`]) the deployment
    /// splits with.
    pub strategy: Strategy,
    pub nsga2: Nsga2Params,
    pub router: RouterConfig,
    /// Emulate phone-speed compute (stretch PJRT wall time).
    pub emulate_slowdown: bool,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            artifacts_dir: crate::artifacts_dir(),
            model: "alexnet".into(),
            batch: 1,
            device_profile: profiles::samsung_j6(),
            bandwidth_mbps: 10.0,
            strategy: Strategy::SmartSplit,
            nsga2: Nsga2Params::default(),
            router: RouterConfig::default(),
            emulate_slowdown: true,
            seed: 7,
        }
    }
}

/// The façade request for this config at bandwidth `bandwidth_mbps`
/// (full battery — the live coordinator serves mains-adjacent demos;
/// band-aware planning lives in the fleet/sim paths).
fn plan_request_at(cfg: &Config, bandwidth_mbps: f64) -> Result<PlanRequest> {
    let spec = zoo::by_name(&cfg.model)
        .with_context(|| format!("unknown model {}", cfg.model))?;
    anyhow::ensure!(cfg.device_profile.wifi.is_some(), "device profile has no radio");
    Ok(PlanRequest::two_tier(
        Arc::new(spec.analyze(cfg.batch)),
        cfg.device_profile,
        BatteryBand::Comfort,
        bandwidth_mbps,
        cfg.strategy,
    ))
}

/// One paper-mode planner for this config: the configured NSGA-II seed
/// used as-is, no memoisation — byte-compatible with the pre-façade
/// `smartsplit`/`decide` calls this module used to make (the CLI sets
/// both seeds from `--seed`).
fn paper_planner(cfg: &Config) -> Planner {
    Planner::new(PlannerConfig::paper(cfg.nsga2.clone()))
}

/// Pick the split for the configured conditions using the analytical model
/// (Eq. 2–17) — this is what runs on the phone before any bytes move.
pub fn plan_split(cfg: &Config) -> Result<SplitDecision> {
    plan_split_at_bandwidth(cfg, cfg.bandwidth_mbps)
}

pub fn plan_split_at_bandwidth(cfg: &Config, bandwidth_mbps: f64) -> Result<SplitDecision> {
    let outcome = plan_outcome_at_bandwidth(cfg, bandwidth_mbps)?;
    let plan = outcome
        .plan
        .with_context(|| format!("{} found no feasible split", cfg.strategy.name()))?;
    Ok(SplitDecision { l1: plan.l1 })
}

/// The full façade answer (plan, predicted objectives, Pareto summary,
/// provenance) for this config at the given bandwidth.
pub fn plan_outcome_at_bandwidth(cfg: &Config, bandwidth_mbps: f64) -> Result<PlanOutcome> {
    let req = plan_request_at(cfg, bandwidth_mbps)?;
    Ok(paper_planner(cfg).plan(&req))
}

/// Results of a served workload.
#[derive(Debug)]
pub struct ServeReport {
    pub model: String,
    pub split_l1: usize,
    pub completed: u64,
    pub errors: u64,
    pub elapsed: Duration,
    pub latency: Histogram,
    pub throughput_rps: f64,
    pub client_energy_j: f64,
    pub upload_energy_j: f64,
    pub download_energy_j: f64,
    pub head_memory_bytes: u64,
    pub bytes_uploaded: u64,
    /// Splits used over the run: (request index, l1) change points.
    pub split_history: Vec<(u64, usize)>,
}

impl ServeReport {
    pub fn total_energy_j(&self) -> f64 {
        self.client_energy_j + self.upload_energy_j + self.download_energy_j
    }

    pub fn print(&self) {
        println!("== serve report: {} (l1={}) ==", self.model, self.split_l1);
        println!("  requests   : {} ok, {} errors in {:?}", self.completed, self.errors, self.elapsed);
        println!("  throughput : {:.3} req/s", self.throughput_rps);
        println!("  latency    : {}", self.latency.summary());
        println!(
            "  energy     : client {:.2} J + upload {:.2} J + download {:.2} J = {:.2} J",
            self.client_energy_j, self.upload_energy_j, self.download_energy_j,
            self.total_energy_j()
        );
        println!(
            "  memory     : head M|l1 = {}",
            crate::util::fmt_bytes(self.head_memory_bytes)
        );
        println!("  uploaded   : {}", crate::util::fmt_bytes(self.bytes_uploaded));
        if self.split_history.len() > 1 {
            println!("  splits     : {:?}", self.split_history);
        }
    }
}

/// A fully wired split-serving deployment (in-process cloud + device).
pub struct Deployment {
    pub cfg: Config,
    pub cloud: Arc<CloudServer>,
    pub device: Arc<DeviceClient>,
    pub link: Arc<Link>,
    pub split: SplitDecision,
    accept_handle: Option<std::thread::JoinHandle<()>>,
}

impl Deployment {
    /// Plan the split and stand up cloud + device + link.
    pub fn start(cfg: Config) -> Result<Deployment> {
        let split = plan_split(&cfg)?;
        Self::start_with_split(cfg, split)
    }

    pub fn start_with_split(cfg: Config, split: SplitDecision) -> Result<Deployment> {
        let cloud = CloudServer::bind("127.0.0.1:0", cfg.artifacts_dir.clone())?;
        let accept_handle = cloud.spawn()?;
        let link = Arc::new(Link::new(cfg.bandwidth_mbps));
        let mut device = DeviceClient::connect(
            &cloud.addr.to_string(),
            &cfg.artifacts_dir,
            &cfg.model,
            cfg.batch,
            split.l1,
            cfg.device_profile,
            Arc::clone(&link),
        )?;
        device.emulate_slowdown = cfg.emulate_slowdown;
        Ok(Deployment {
            cfg,
            cloud,
            device: Arc::new(device),
            link,
            split,
            accept_handle: Some(accept_handle),
        })
    }

    /// Serve a closed/open-loop workload through the router; blocks until
    /// all requests complete.
    pub fn serve(&self, requests: &[Request]) -> Result<ServeReport> {
        self.serve_with_trace(requests, None)
    }

    /// Serve while following a bandwidth trace; the coordinator re-runs the
    /// optimiser at every trace step and moves the split live.
    pub fn serve_with_trace(
        &self,
        requests: &[Request],
        trace: Option<&BandwidthTrace>,
    ) -> Result<ServeReport> {
        let router = Router::start(Arc::clone(&self.device), self.cfg.router.clone())?;
        let latency = Histogram::new();
        let meter = ThroughputMeter::new();
        // detlint:allow(D1): live serving pacing against real sockets; the sim path never runs this
        let start = Instant::now();
        let mut errors = 0u64;
        let shape = self.device.input_shape().to_vec();
        let (c, hw) = (shape[1], shape[2]);
        let mut split_history = vec![(0u64, self.device.split())];

        // Submit respecting arrival offsets; receive in submission order.
        let mut rxs = std::collections::VecDeque::new();
        for req in requests {
            // Adaptive step: retune the link + split per the trace.
            if let Some(tr) = trace {
                let now = start.elapsed();
                let bw = tr.at(now);
                if (bw - self.link.bandwidth_mbps()).abs() > 1e-9 {
                    self.link.set_bandwidth_mbps(bw);
                    let new_split = plan_split_at_bandwidth(&self.cfg, bw)?;
                    if new_split.l1 != self.device.split() {
                        log::info!(
                            "coordinator: bandwidth {bw} Mbps → moving split to l1={}",
                            new_split.l1
                        );
                        self.device.set_split(new_split.l1)?;
                        split_history.push((req.id, new_split.l1));
                    }
                }
            }
            let now = start.elapsed();
            if req.arrival > now {
                std::thread::sleep(req.arrival - now);
            }
            let img = Tensor::new(
                vec![1, c, hw, hw],
                synth_images(1, c, hw, req.image_seed),
            )?;
            rxs.push_back(router.submit(req.id, img));

            // Keep the pipe shallow: harvest finished completions.
            while rxs.len() > 2 * self.cfg.router.max_batch {
                match rxs.pop_front().unwrap().recv() {
                    Ok(Ok(c)) => {
                        latency.record_secs(c.timing.total_s);
                        meter.record(1);
                    }
                    Ok(Err(e)) => {
                        log::warn!("request failed: {e:#}");
                        errors += 1;
                    }
                    Err(_) => errors += 1,
                }
            }
        }
        for rx in rxs {
            match rx.recv() {
                Ok(Ok(c)) => {
                    latency.record_secs(c.timing.total_s);
                    meter.record(1);
                }
                Ok(Err(e)) => {
                    log::warn!("request failed: {e:#}");
                    errors += 1;
                }
                Err(_) => errors += 1,
            }
        }
        router.stop();

        let (bytes_up, _) = self.link.bytes_transferred();
        Ok(ServeReport {
            model: self.cfg.model.clone(),
            split_l1: self.device.split(),
            completed: meter.completed(),
            errors,
            elapsed: start.elapsed(),
            latency,
            throughput_rps: meter.rps(),
            client_energy_j: self.device.energy.client_j(),
            upload_energy_j: self.device.energy.upload_j(),
            download_energy_j: self.device.energy.download_j(),
            head_memory_bytes: self.device.memory.used(),
            bytes_uploaded: bytes_up,
            split_history,
        })
    }

    /// Tear down: device goodbye, stop cloud.
    pub fn shutdown(mut self) {
        let _ = self.device.shutdown();
        self.device.stop();
        self.cloud.stop();
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

/// One-shot optimisation report for the CLI: the configured strategy's
/// decision, the SmartSplit Pareto set, and every strategy's decision
/// under the analytical model — all through the planning façade.
pub fn optimize_report(cfg: &Config) -> Result<String> {
    let planner = paper_planner(cfg);
    let mut out = String::new();
    // One analyzed model profile (Arc'd) shared by every request below.
    let base_req = plan_request_at(cfg, cfg.bandwidth_mbps)?;

    // The strategy the user asked for (--planner).
    let chosen = planner.plan(&base_req);
    if let (Some(plan), Some(o)) = (chosen.plan, chosen.objectives) {
        out.push_str(&format!(
            "strategy {}: l1={} f1={:.4}s f2={:.4}J f3={:.2}MB\n\n",
            cfg.strategy.name(), plan.l1, o[0], o[1], o[2] / 1e6
        ));
    } else {
        out.push_str(&format!("strategy {}: no feasible split\n\n", cfg.strategy.name()));
    }

    // Algorithm 1's Pareto set (the paper's Fig. 6 / Table I view).
    let mut req = base_req.clone();
    req.strategy = Strategy::SmartSplit;
    let result = if cfg.strategy == Strategy::SmartSplit {
        chosen.clone()
    } else {
        planner.plan(&req)
    };
    let pareto = result.pareto.clone().unwrap_or_default();
    out.push_str(&format!(
        "model {} on {} @ {} Mbps — Pareto set ({} members, {} evals):\n",
        cfg.model, cfg.device_profile.name, cfg.bandwidth_mbps,
        pareto.len(), result.provenance.evaluations
    ));
    let mut t = crate::bench::Table::new(&["l1", "latency f1 (s)", "energy f2 (J)", "memory f3 (MB)", "chosen"]);
    for (p, o) in &pareto {
        t.row(&[
            p.l1.to_string(),
            format!("{:.4}", o[0]),
            format!("{:.4}", o[1]),
            format!("{:.2}", o[2] / 1e6),
            if Some(*p) == result.plan { "◀ TOPSIS".into() } else { String::new() },
        ]);
    }
    out.push_str(&t.to_string());
    out.push_str("\nper-strategy decisions:\n");
    for strategy in Strategy::ALL {
        let mut req = base_req.clone();
        req.strategy = strategy;
        let outcome = planner.plan(&req);
        match (outcome.plan, outcome.objectives) {
            (Some(p), Some(o)) => out.push_str(&format!(
                "  {:<18} l1={:<3} f1={:.4}s f2={:.4}J f3={:.2}MB\n",
                strategy.name(), p.l1, o[0], o[1], o[2] / 1e6
            )),
            _ => out.push_str(&format!(
                "  {:<18} no feasible split (e.g. infeasible ε box)\n",
                strategy.name()
            )),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_split_deterministic_and_feasible() {
        let cfg = Config::default();
        // Planning needs no artifacts — pure analytical model.
        let a = plan_split(&cfg).unwrap();
        let b = plan_split(&cfg).unwrap();
        assert_eq!(a, b);
        assert!(a.l1 >= 1 && a.l1 < 21);
    }

    #[test]
    fn bandwidth_changes_move_the_split() {
        let cfg = Config::default();
        let slow = plan_split_at_bandwidth(&cfg, 0.5).unwrap();
        let fast = plan_split_at_bandwidth(&cfg, 1000.0).unwrap();
        // At 1 Gbps shipping early activations is ~free; at 0.5 Mbps the
        // optimiser must avoid big uploads. The decisions must differ.
        assert_ne!(slow.l1, fast.l1, "split should react to bandwidth");
    }

    #[test]
    fn optimize_report_renders() {
        let cfg = Config {
            nsga2: Nsga2Params { pop_size: 30, generations: 30, ..Default::default() },
            ..Config::default()
        };
        let r = optimize_report(&cfg).unwrap();
        assert!(r.contains("Pareto set"));
        assert!(r.contains("SmartSplit"));
        assert!(r.contains("TOPSIS"));
    }
}
