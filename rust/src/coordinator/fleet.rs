//! Heterogeneous fleet coordinator — the paper's future-work item (iii):
//! "smartphones combined with other edge devices to create a heterogeneous
//! edge ecosystem performing shared AI tasks".
//!
//! N phones (different profiles, different link bandwidths) share ONE
//! cloud daemon. Each device gets its own SmartSplit decision (its radio
//! and link differ, so its optimal split differs), and the fleet
//! dispatcher routes each incoming request to the device with the lowest
//! expected completion time (queue depth × modelled per-request latency) —
//! a shortest-expected-delay policy.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::battery::BatteryBand;
use crate::device::ComputeProfile;
use crate::edge::SplitPlan;
use crate::metrics::{Histogram, PlannerStats, ThroughputMeter};
use crate::models::zoo;
use crate::netsim::Link;
use crate::optimizer::{member_perf_model, Nsga2Params};
use crate::planner::{PlanRequest, Planner, PlannerConfig, ReplanReason, Strategy};
use crate::runtime::Tensor;
use crate::serve::{CloudServer, DeviceClient};
use crate::util::pool::ThreadPool;
use crate::workload::{synth_images, Request};

/// One fleet member: a phone profile and its own link bandwidth.
#[derive(Clone, Debug)]
pub struct FleetMember {
    pub profile: &'static ComputeProfile,
    pub bandwidth_mbps: f64,
}

/// Fleet-level configuration.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    pub artifacts_dir: std::path::PathBuf,
    pub model: String,
    pub batch: usize,
    pub members: Vec<FleetMember>,
    /// Planning strategy every member's split is decided with (the
    /// shared `--planner` flag).
    pub strategy: Strategy,
    pub nsga2: Nsga2Params,
    pub emulate_slowdown: bool,
}

struct FleetDevice {
    device: Arc<DeviceClient>,
    /// Modelled per-request latency at this device's split (for dispatch).
    expected_s: f64,
    inflight: AtomicU64,
    served: AtomicU64,
    /// Per-device latency; merged into the fleet-wide histogram at report
    /// time (same sharding scheme as `sim::SimReport`).
    latency: Histogram,
}

/// Per-device slice of the fleet report.
#[derive(Debug)]
pub struct MemberReport {
    pub name: &'static str,
    pub bandwidth_mbps: f64,
    pub split_l1: usize,
    pub served: u64,
    pub client_energy_j: f64,
    pub upload_energy_j: f64,
    pub head_memory_bytes: u64,
    /// This member's own latency distribution.
    pub latency: Histogram,
}

/// Whole-fleet serving report.
#[derive(Debug)]
pub struct FleetReport {
    pub completed: u64,
    pub errors: u64,
    pub elapsed_s: f64,
    pub throughput_rps: f64,
    pub latency: Histogram,
    pub members: Vec<MemberReport>,
    /// Split-planner accounting from fleet start (spawn-tagged façade
    /// requests; distinct member states share one solve) — the same
    /// shape the simulator reports.
    pub planner: PlannerStats,
}

impl FleetReport {
    pub fn print(&self) {
        println!("== fleet report ==");
        println!("  requests   : {} ok, {} errors in {:.2}s", self.completed, self.errors, self.elapsed_s);
        println!("  throughput : {:.3} req/s (fleet)", self.throughput_rps);
        println!("  latency    : {}", self.latency.summary());
        println!(
            "  planner    : {} solves, cache {} hits / {} misses",
            self.planner.solves, self.planner.cache_hits, self.planner.cache_misses
        );
        for m in &self.members {
            println!(
                "  {:<14} @{:>6.1} Mbps  l1={:<2} served={:<4} E_client={:.2}J E_up={:.2}J M|l1={}",
                m.name, m.bandwidth_mbps, m.split_l1, m.served,
                m.client_energy_j, m.upload_energy_j,
                crate::util::fmt_bytes(m.head_memory_bytes)
            );
            println!("  {:<14} {}", "", m.latency.summary());
        }
    }
}

/// The fleet: one cloud, many devices.
pub struct Fleet {
    pub cloud: Arc<CloudServer>,
    devices: Vec<Arc<FleetDevice>>,
    pool: ThreadPool,
    cfg: FleetConfig,
    /// Planner accounting snapshotted after the start-up planning pass.
    planner_stats: PlannerStats,
    accept_handle: Option<std::thread::JoinHandle<()>>,
}

impl Fleet {
    /// Plan per-member splits and stand everything up.
    pub fn start(cfg: FleetConfig) -> Result<Fleet> {
        anyhow::ensure!(!cfg.members.is_empty(), "empty fleet");
        let cloud = CloudServer::bind("127.0.0.1:0", cfg.artifacts_dir.clone())?;
        let accept_handle = cloud.spawn()?;
        let spec = zoo::by_name(&cfg.model).context("unknown model")?;
        let profile = Arc::new(spec.analyze(cfg.batch));
        for m in &cfg.members {
            anyhow::ensure!(m.profile.wifi.is_some(), "member {} has no radio", m.profile.name);
        }

        // Plan every member's split up front through the façade:
        // distinct (profile, bandwidth) states are deduplicated and
        // solved once, fanned out over a worker pool, then served to
        // each member through the counted cache path. Each solve seeds
        // from its key, so fan-out order cannot change a decision.
        let planner = Planner::new(PlannerConfig::fleet(cfg.nsga2.clone(), cfg.nsga2.seed));
        let plan_pool = ThreadPool::new(ThreadPool::default_threads(cfg.members.len().max(1)));
        let requests: Vec<PlanRequest> = cfg
            .members
            .iter()
            .map(|m| {
                PlanRequest::two_tier(
                    Arc::clone(&profile),
                    m.profile,
                    BatteryBand::Comfort,
                    m.bandwidth_mbps,
                    cfg.strategy,
                )
                .with_reason(ReplanReason::Spawn)
            })
            .collect();
        let mut presolved = planner.presolve_batch(&plan_pool, &requests);
        let planned: Vec<Option<SplitPlan>> = requests
            .iter()
            .map(|r| planner.split_with(r, &mut presolved))
            .collect();
        let stats = planner.stats();
        log::info!(
            "fleet planner: {} members, {} solves, {:.0}% cache hit rate",
            cfg.members.len(),
            stats.solves,
            stats.hit_rate() * 100.0
        );

        let mut devices = Vec::new();
        for (member, planned_split) in cfg.members.iter().zip(planned) {
            // Same §III context the split was planned under.
            let pm = member_perf_model(member.profile, &profile, member.bandwidth_mbps);
            // The live serving stack is two-tier: planned plans are
            // two-tier embeddings (l2 == l1), so l1 is the whole story.
            let l1 = planned_split.context("no feasible split for fleet member")?.l1;
            let link = Arc::new(Link::new(member.bandwidth_mbps));
            let mut device = DeviceClient::connect(
                &cloud.addr.to_string(),
                &cfg.artifacts_dir,
                &cfg.model,
                cfg.batch,
                l1,
                member.profile,
                link,
            )?;
            device.emulate_slowdown = cfg.emulate_slowdown;
            devices.push(Arc::new(FleetDevice {
                device: Arc::new(device),
                expected_s: pm.f1(l1) * if cfg.emulate_slowdown { 1.0 } else { 0.25 },
                inflight: AtomicU64::new(0),
                served: AtomicU64::new(0),
                latency: Histogram::new(),
            }));
            log::info!(
                "fleet: {} @ {} Mbps → l1={}",
                member.profile.name, member.bandwidth_mbps, l1
            );
        }
        let pool = ThreadPool::new(devices.len());
        Ok(Fleet {
            cloud,
            devices,
            pool,
            cfg,
            planner_stats: stats,
            accept_handle: Some(accept_handle),
        })
    }

    /// Splits chosen per member (ordered as configured).
    pub fn splits(&self) -> Vec<usize> {
        self.devices.iter().map(|d| d.device.split()).collect()
    }

    /// Shortest-expected-delay dispatch: queue depth × modelled latency.
    fn pick_device(&self) -> Arc<FleetDevice> {
        Arc::clone(
            self.devices
                .iter()
                .min_by(|a, b| {
                    let ca = (a.inflight.load(Ordering::SeqCst) + 1) as f64 * a.expected_s;
                    let cb = (b.inflight.load(Ordering::SeqCst) + 1) as f64 * b.expected_s;
                    ca.partial_cmp(&cb).unwrap()
                })
                .unwrap(),
        )
    }

    /// Serve a workload across the fleet; blocks for completion.
    pub fn serve(&self, requests: &[Request]) -> Result<FleetReport> {
        let latency = Arc::new(Histogram::new());
        let meter = Arc::new(ThroughputMeter::new());
        let errors = Arc::new(AtomicU64::new(0));
        // detlint:allow(D1): live fleet pacing against real sockets; the sim path never runs this
        let start = Instant::now();
        let shape = self.devices[0].device.input_shape().to_vec();
        let (c, hw) = (shape[1], shape[2]);

        for req in requests {
            let now = start.elapsed();
            if req.arrival > now {
                std::thread::sleep(req.arrival - now);
            }
            let dev = self.pick_device();
            dev.inflight.fetch_add(1, Ordering::SeqCst);
            let latency = Arc::clone(&latency);
            let meter = Arc::clone(&meter);
            let errors = Arc::clone(&errors);
            let seed = req.image_seed;
            self.pool.execute(move || {
                let img = Tensor::new(vec![1, c, hw, hw], synth_images(1, c, hw, seed))
                    .expect("image");
                match dev.device.infer(&img) {
                    Ok((_, timing)) => {
                        latency.record_secs(timing.total_s);
                        dev.latency.record_secs(timing.total_s);
                        meter.record(1);
                        dev.served.fetch_add(1, Ordering::SeqCst);
                    }
                    Err(e) => {
                        log::warn!("fleet request failed: {e:#}");
                        errors.fetch_add(1, Ordering::SeqCst);
                    }
                }
                dev.inflight.fetch_sub(1, Ordering::SeqCst);
            });
        }
        self.pool.wait_idle();

        let members = self
            .devices
            .iter()
            .zip(&self.cfg.members)
            .map(|(d, m)| {
                // Snapshot the member's running histogram (serve() can be
                // called repeatedly; the member keeps accumulating).
                let member_latency = Histogram::new();
                member_latency.merge(&d.latency);
                MemberReport {
                    name: m.profile.name,
                    bandwidth_mbps: m.bandwidth_mbps,
                    split_l1: d.device.split(),
                    served: d.served.load(Ordering::SeqCst),
                    client_energy_j: d.device.energy.client_j(),
                    upload_energy_j: d.device.energy.upload_j(),
                    head_memory_bytes: d.device.memory.used(),
                    latency: member_latency,
                }
            })
            .collect();
        let latency = Arc::try_unwrap(latency).unwrap_or_else(|_| panic!("latency still shared"));
        // Freeze the serving interval into the meter so the report's
        // elapsed/throughput come from one clock source. Live serving is
        // genuinely wall-clock (unlike `sim::`, which pins the meter to
        // the virtual clock); pinning the measured interval here keeps
        // the two derived fields consistent with each other.
        meter.set_elapsed_s(start.elapsed().as_secs_f64());
        Ok(FleetReport {
            completed: meter.completed(),
            errors: errors.load(Ordering::SeqCst),
            elapsed_s: meter.elapsed_s(),
            throughput_rps: meter.rps(),
            latency,
            members,
            planner: self.planner_stats,
        })
    }

    pub fn shutdown(mut self) {
        for d in &self.devices {
            let _ = d.device.shutdown();
            d.device.stop();
        }
        self.cloud.stop();
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::optimizer::smartsplit;
    use crate::perfmodel::{NetworkEnv, PerfModel};

    #[test]
    fn per_member_splits_differ_with_conditions() {
        // Planning only (no artifacts): a starved J6 and a fast Redmi must
        // generally receive different split decisions.
        let spec = zoo::alexnet();
        let profile = spec.analyze(1);
        let params = Nsga2Params { pop_size: 40, generations: 40, ..Default::default() };
        let starved = PerfModel::new(
            profiles::samsung_j6(),
            profiles::cloud_server(),
            crate::perfmodel::RadioPower::PAPER_80211N,
            NetworkEnv::with_bandwidth(0.5),
            &profile,
        );
        let fast = PerfModel::new(
            profiles::redmi_note8(),
            profiles::cloud_server(),
            crate::perfmodel::RadioPower::WIFI_80211AC,
            NetworkEnv::with_bandwidth(200.0),
            &profile,
        );
        let a = smartsplit(&starved, &params).decision.l1;
        let b = smartsplit(&fast, &params).decision.l1;
        assert_ne!(a, b, "identical splits under opposite network conditions");
    }

    #[test]
    #[allow(deprecated)] // the frozen pre-façade entry point is the parity reference
    fn parallel_cached_planning_matches_direct_solves() {
        // The exact planning pipeline Fleet::start runs (façade
        // presolve fan-out, then counted cache serving) must reproduce
        // the pre-façade per-member direct solve bit-for-bit, members
        // sharing a (profile, bandwidth) state must share one cache
        // entry, and the solve count must equal the number of distinct
        // states — not the member count, and never scheduling-dependent.
        use crate::optimizer::{model_cache_id, solve_plan, PlanKey, PlannerKind};

        let model = Arc::new(zoo::alexnet().analyze(1));
        let model_id = model_cache_id(&model);
        let params = Nsga2Params::for_tiny_genome();
        let members: Vec<(&'static ComputeProfile, f64)> = vec![
            (profiles::samsung_j6(), 10.0),
            (profiles::redmi_note8(), 30.0),
            (profiles::samsung_j6(), 10.0), // duplicate state
        ];
        let planner = Planner::new(PlannerConfig::fleet(params.clone(), params.seed));
        let pool = ThreadPool::new(2);
        let requests: Vec<PlanRequest> = members
            .iter()
            .map(|&(p, bw)| {
                PlanRequest::two_tier(
                    Arc::clone(&model),
                    p,
                    BatteryBand::Comfort,
                    bw,
                    Strategy::SmartSplit,
                )
            })
            .collect();
        let mut presolved = planner.presolve_batch(&pool, &requests);
        let planned: Vec<Option<SplitPlan>> = requests
            .iter()
            .map(|r| planner.plan_with(r, &mut presolved).plan)
            .collect();
        for (&(p, bw), got) in members.iter().zip(&planned) {
            let key = PlanKey::new(model_id, p, BatteryBand::Comfort, bw, PlannerKind::SmartSplit);
            let pm = member_perf_model(p, &model, bw);
            let direct = solve_plan(
                PlannerKind::SmartSplit,
                &pm,
                BatteryBand::Comfort,
                &params,
                key.derived_seed(params.seed),
            );
            assert_eq!(*got, direct, "{} @ {bw} Mbps", p.name);
        }
        assert_eq!(planned[0], planned[2], "duplicate member states must agree");
        assert_eq!(planner.cache_len(), 2, "two distinct planner states expected");
        let stats = planner.stats();
        assert_eq!(
            (stats.solves, stats.cache_misses, stats.cache_hits),
            (2, 2, 1),
            "accounting must be deterministic: one solve+miss per state, one hit for the dupe"
        );
    }
}
