//! Battery-aware split policy — an extension the paper's conclusion
//! motivates ("minimal memory and energy utilisation is essential as many
//! applications are run concurrently"): as the battery drains, the
//! coordinator shifts the TOPSIS trade-off toward energy by tightening the
//! Eq. 15 objective with a state-of-charge weight, pushing the split
//! toward offloading (or, on an 802.11n radio where uploads are the
//! expensive part, toward whichever side the energy model actually
//! favours — the policy reasons through f2, not a heuristic).

use crate::optimizer::{exhaustive_pareto_front, topsis};
use crate::perfmodel::PerfModel;

/// Battery-state bands and the f2 emphasis they apply.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BatteryBand {
    /// > 50% charge: paper-standard TOPSIS (equal emphasis).
    Comfort,
    /// 20–50%: energy column doubled before TOPSIS.
    Saver,
    /// < 20%: energy column quadrupled; memory still enforced via Eq. 17.
    Critical,
}

impl BatteryBand {
    pub fn of_fraction(state_of_charge: f64) -> BatteryBand {
        if state_of_charge > 0.5 {
            BatteryBand::Comfort
        } else if state_of_charge > 0.2 {
            BatteryBand::Saver
        } else {
            BatteryBand::Critical
        }
    }

    pub fn energy_weight(self) -> f64 {
        match self {
            BatteryBand::Comfort => 1.0,
            BatteryBand::Saver => 2.0,
            BatteryBand::Critical => 4.0,
        }
    }
}

/// Pick a split with the energy objective emphasised per the battery band:
/// TOPSIS over the true Pareto front with the f2 column scaled. (Scaling a
/// column before vector normalisation changes the ideal-distance geometry
/// exactly like a TOPSIS attribute weight.)
pub fn battery_aware_split(pm: &PerfModel<'_>, state_of_charge: f64) -> Option<usize> {
    battery_aware_split_banded(pm, BatteryBand::of_fraction(state_of_charge))
}

/// Band-level entry point: the quantised form the split-plan cache keys
/// on ([`crate::optimizer::cache`]) — two devices in the same band (and
/// bandwidth bucket) share this decision by construction.
pub fn battery_aware_split_banded(pm: &PerfModel<'_>, band: BatteryBand) -> Option<usize> {
    let w = band.energy_weight();
    let front = exhaustive_pareto_front(pm);
    if front.is_empty() {
        return None;
    }
    let rows: Vec<Vec<f64>> = front
        .iter()
        .map(|&l1| {
            let o = pm.objectives(l1);
            vec![o[0], o[1] * w, o[2]]
        })
        .collect();
    let feasible = vec![true; rows.len()];
    topsis(&rows, &feasible).map(|r| front[r.chosen])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::models::zoo;
    use crate::perfmodel::{NetworkEnv, RadioPower};

    fn pm(profile: &crate::models::ModelProfile) -> PerfModel<'_> {
        PerfModel::new(
            profiles::redmi_note8(),
            profiles::cloud_server(),
            RadioPower::WIFI_80211AC,
            NetworkEnv::paper_default(),
            profile,
        )
    }

    #[test]
    fn bands() {
        assert_eq!(BatteryBand::of_fraction(0.9), BatteryBand::Comfort);
        assert_eq!(BatteryBand::of_fraction(0.5), BatteryBand::Saver);
        assert_eq!(BatteryBand::of_fraction(0.21), BatteryBand::Saver);
        assert_eq!(BatteryBand::of_fraction(0.1), BatteryBand::Critical);
    }

    #[test]
    fn low_battery_never_costs_more_energy() {
        // Monotonicity: the critical-band choice must not consume more
        // energy (f2) than the comfort-band choice.
        for model in ["alexnet", "vgg11", "vgg13", "vgg16"] {
            let profile = zoo::by_name(model).unwrap().analyze(1);
            let m = pm(&profile);
            let comfort = battery_aware_split(&m, 1.0).unwrap();
            let critical = battery_aware_split(&m, 0.05).unwrap();
            assert!(
                m.f2(critical) <= m.f2(comfort) + 1e-12,
                "{model}: critical split {critical} uses more energy than comfort {comfort}"
            );
        }
    }

    #[test]
    fn choices_stay_on_true_front() {
        let profile = zoo::vgg16().analyze(1);
        let m = pm(&profile);
        let front = exhaustive_pareto_front(&m);
        for soc in [1.0, 0.4, 0.1] {
            let c = battery_aware_split(&m, soc).unwrap();
            assert!(front.contains(&c));
        }
    }

    #[test]
    fn critical_band_moves_toward_energy_optimum() {
        // Tightening the band must move the choice monotonically toward
        // (or keep it at) the energy optimum: f2(critical) ≤ f2(saver) ≤
        // f2(comfort), and critical lands within 2× of EBO's absolute
        // optimum (TOPSIS still trades against latency and memory).
        let profile = zoo::vgg11().analyze(1);
        let m = pm(&profile);
        let comfort = battery_aware_split(&m, 1.0).unwrap();
        let saver = battery_aware_split(&m, 0.4).unwrap();
        let critical = battery_aware_split(&m, 0.05).unwrap();
        assert!(m.f2(saver) <= m.f2(comfort) + 1e-12);
        assert!(m.f2(critical) <= m.f2(saver) + 1e-12);
        let ebo = crate::optimizer::ebo(&m).l1;
        assert!(m.f2(critical) <= 2.0 * m.f2(ebo), "critical {critical} vs ebo {ebo}");
    }
}
